//! Precision study (Figs. 5/6): why the per-sample adaptive scaling is the
//! thing that makes low-precision MPS sampling possible at scale.
//!
//! Reproduces (at CPU-testbed scale) the paper's two observations:
//! - Fig. 5: the spread of left-environment magnitudes across samples grows
//!   by orders of magnitude with the site index — one global scale cannot
//!   cover it;
//! - Fig. 6: with the baseline's global auto-scaling in f32, sampling
//!   collapses to zeros mid-chain, while per-sample scaling survives the
//!   whole chain.
//!
//! ```bash
//! cargo run --release --example precision_study
//! ```

use std::sync::Arc;

use fastmps::config::{ComputePrecision, EngineKind, Preset, RunConfig, ScalingMode};
use fastmps::coordinator::data_parallel;
use fastmps::io::{GammaStore, StoreCodec, StorePrecision};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // M8176-analog with the full-chain dynamic range compressed into 96
    // sites: decay tuned so f32 underflows mid-chain exactly like the
    // paper's site-3000 collapse.
    let mut spec = Preset::M8176.scaled_spec(13);
    spec.m = 96;
    spec.chi_cap = 48;
    spec.decay_k = 0.02;
    spec.branch_skew = 0.0;
    // Random displacement is the physical noise that spreads per-sample
    // magnitudes (e^{-|mu|^2/2} random walk): ~sqrt(site) decades of spread.
    spec.displacement_sigma = 1.6;
    let dir = std::env::temp_dir().join("fastmps-precision");
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(GammaStore::create(
        &dir,
        &spec,
        StorePrecision::F32,
        StoreCodec::Raw,
    )?);

    let run = |scaling: ScalingMode, compute: ComputePrecision, env_f16: bool| {
        let mut cfg = RunConfig::new(store.spec.clone());
        cfg.n_samples = 512;
        cfg.n1_macro = 512;
        cfg.n2_micro = 128;
        cfg.engine = EngineKind::Native;
        cfg.compute = compute;
        cfg.scaling = scaling;
        // FP16 left-env storage (S3.3.2) compresses the paper's f32 range
        // into this testbed's 96 sites (7.7 decades vs 38).
        cfg.env_f16 = env_f16;
        data_parallel::run(&cfg, &store, &[8, 24, 56, 88])
    };

    println!("== Fig. 5 analog: left-env per-sample spread growth (per-sample scaling)");
    let rep = run(ScalingMode::Global, ComputePrecision::F64, false)?;
    for (site, probes) in &rep.env_probes {
        let mean_max: f64 =
            probes.iter().map(|(m, _)| m).sum::<f64>() / probes.len() as f64;
        let max_ratio = probes
            .iter()
            .map(|(_, r)| *r)
            .filter(|r| r.is_finite())
            .fold(0.0f64, f64::max);
        println!(
            "  site {site:>3}: mean max|env| {mean_max:.3e}, worst max/min ratio {max_ratio:.3e} \
             (paper: intra-sample range ≤1e6, inter-sample range explodes)"
        );
    }

    println!("\n== Fig. 6 analog: mean photons per site — collapse vs survival (f32)");
    let bad = run(ScalingMode::Global, ComputePrecision::F32, true)?;
    let good = run(ScalingMode::PerSample, ComputePrecision::F32, true)?;
    let oracle = run(ScalingMode::PerSample, ComputePrecision::F64, false)?;
    let (mb, mg, mo) = (
        bad.sink.mean_photons(),
        good.sink.mean_photons(),
        oracle.sink.mean_photons(),
    );
    println!("  site | global-f32 | per-sample-f32 | f64 oracle");
    for site in (0..spec.m).step_by(8) {
        println!(
            "  {site:>4} | {:>10.4} | {:>14.4} | {:>10.4}",
            mb[site], mg[site], mo[site]
        );
    }
    let collapse_site = mb.iter().position(|&m| m == 0.0);
    println!(
        "\n  global-f32 dead rows: {} (collapse at site {:?}; paper: site ~3000/8176)",
        bad.dead_rows, collapse_site
    );
    println!("  per-sample-f32 dead rows: {} (survives all {} sites)", good.dead_rows, spec.m);
    let drift: f64 = mg
        .iter()
        .zip(&mo)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f64::max);
    println!("  per-sample f32 vs f64 max ⟨n⟩ drift: {drift:.4}");

    std::fs::remove_dir_all(&dir)?;
    Ok(())
}
