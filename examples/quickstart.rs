//! Quickstart: generate a small synthetic GBS MPS, sample it with the
//! data-parallel coordinator, and print the outcome statistics.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use fastmps::config::{ComputePrecision, EngineKind, RunConfig, ScalingMode};
use fastmps::coordinator::data_parallel;
use fastmps::io::{GammaStore, StoreCodec, StorePrecision};
use fastmps::mps::gbs::GbsSpec;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. Describe a dataset: 32 modes, bond dimension up to 64.
    let spec = GbsSpec {
        name: "quickstart".into(),
        m: 32,
        d: 3,
        chi_cap: 64,
        asp: 5.0,
        decay_k: 0.05,
        displacement_sigma: 0.3,
            branch_skew: 0.0,
        seed: 7,
        dynamic_chi: true,
        step_ratio_override: None,
    };

    // 2. Write it to an on-disk Γ store (FP16 blobs, like production).
    let dir = std::env::temp_dir().join("fastmps-quickstart");
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(GammaStore::create(
        &dir,
        &spec,
        StorePrecision::F16,
        StoreCodec::Zstd,
    )?);
    println!(
        "store: {} sites, {} on disk",
        store.num_sites(),
        fastmps::util::human_bytes(store.total_bytes())
    );

    // 3. Configure a data-parallel run: 2 workers × 1024-sample macro
    //    batches, per-sample adaptive scaling (§3.3.1).
    let mut cfg = RunConfig::new(spec);
    cfg.n_samples = 4096;
    cfg.n1_macro = 1024;
    cfg.n2_micro = 256;
    cfg.p1 = 2;
    cfg.engine = EngineKind::Native;
    cfg.compute = ComputePrecision::F32;
    cfg.scaling = ScalingMode::PerSample;

    // 4. Sample and report.
    let report = data_parallel::run(&cfg, &store, &[])?;
    println!("run: {}", report.metrics.summary());
    let means = report.sink.mean_photons();
    println!(
        "mean photons (first 8 sites): {:?}",
        &means[..8.min(means.len())]
            .iter()
            .map(|m| (m * 1000.0).round() / 1000.0)
            .collect::<Vec<_>>()
    );
    println!(
        "total ⟨n⟩ = {:.3}, dead rows = {}",
        means.iter().sum::<f64>(),
        report.dead_rows
    );
    std::fs::remove_dir_all(&dir)?;
    Ok(())
}
