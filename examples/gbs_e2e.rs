//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): the full three-layer stack on a
//! real small workload.
//!
//! Pipeline proven here:
//!   1. `make artifacts` lowered the Pallas/JAX per-site step to HLO text;
//!   2. a Borealis-M288-analog GBS MPS (M=72, χ≤96, ASP 10.69) is generated
//!      and stored in FP16;
//!   3. the rust data-parallel coordinator samples 16k samples through the
//!      PJRT CPU client executing those artifacts (python is NOT running);
//!   4. results are validated against exact transfer-matrix marginals —
//!      the paper's Fig. 9 correlation-slope test — and compared against
//!      the native engine and the model-parallel baseline [19].
//!
//! ```bash
//! make artifacts && cargo run --release --example gbs_e2e
//! ```

use std::path::Path;
use std::sync::Arc;

use fastmps::config::{ComputePrecision, EngineKind, Preset, RunConfig, ScalingMode};
use fastmps::coordinator::{data_parallel, model_parallel};
use fastmps::io::{GammaStore, StoreCodec, StorePrecision};
use fastmps::metrics::keys;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let artifacts = Path::new("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(2);
    }

    // Borealis-M288 analog, scaled to the CPU testbed (DESIGN.md
    // §Substitutions): M 288→72, χ 10⁴→96, same ASP.
    let mut spec = Preset::BorealisM288.scaled_spec(2025);
    spec.displacement_sigma = 0.0; // validation needs the undisplaced state
    let dir = std::env::temp_dir().join("fastmps-e2e");
    let _ = std::fs::remove_dir_all(&dir);
    println!("generating {} (M={}, χcap={}, ASP={})...", spec.name, spec.m, spec.chi_cap, spec.asp);
    let store = Arc::new(GammaStore::create(
        &dir,
        &spec,
        StorePrecision::F16,
        StoreCodec::Raw,
    )?);
    let plan = store.spec.chi_plan();
    println!(
        "  store {} | equi-χ {:.0} | comp ratio {:.1}%",
        fastmps::util::human_bytes(store.total_bytes()),
        plan.equivalent_chi(),
        plan.comp_ratio() * 100.0
    );

    let mut cfg = RunConfig::new(store.spec.clone());
    cfg.n_samples = 16_384;
    cfg.n1_macro = 2048;
    cfg.n2_micro = 256; // the artifact micro-batch bucket
    cfg.p1 = 2;
    cfg.engine = EngineKind::Xla;
    cfg.artifacts_dir = artifacts.to_path_buf();
    cfg.compute = ComputePrecision::F32;
    cfg.scaling = ScalingMode::PerSample;
    cfg.store_precision = StorePrecision::F16;

    // --- The FastMPS hot path: XLA artifacts through PJRT. -------------
    println!("\n[1/3] FastMPS data-parallel × XLA artifacts (the production path)");
    let t0 = std::time::Instant::now();
    let xla_report = data_parallel::run(&cfg, &store, &[])?;
    let xla_wall = t0.elapsed().as_secs_f64();
    println!("  {}", xla_report.metrics.summary());
    println!(
        "  wall {} | throughput {:.0} site-samples/s",
        fastmps::util::human_secs(xla_wall),
        (cfg.n_samples * spec.m as u64) as f64 / xla_wall
    );

    // --- Native engine on the same work (oracle + speed reference). ----
    println!("\n[2/3] native engine (same seeds)");
    let mut native_cfg = cfg.clone();
    native_cfg.engine = EngineKind::Native;
    let t1 = std::time::Instant::now();
    let native_report = data_parallel::run(&native_cfg, &store, &[])?;
    let native_wall = t1.elapsed().as_secs_f64();
    println!(
        "  wall {} | engines agree on ⟨n⟩: {:.4} vs {:.4}",
        fastmps::util::human_secs(native_wall),
        xla_report.sink.mean_photons().iter().sum::<f64>(),
        native_report.sink.mean_photons().iter().sum::<f64>(),
    );

    // --- The model-parallel baseline [19] at reduced sample count. -----
    println!("\n[3/3] model-parallel baseline [19] (FP64 + global autoscale)");
    let mut mp_cfg = native_cfg.clone();
    mp_cfg.n_samples = 2048;
    mp_cfg.compute = ComputePrecision::F64;
    mp_cfg.scaling = ScalingMode::Global;
    let t2 = std::time::Instant::now();
    let mp_report = model_parallel::run(&mp_cfg, &store)?;
    let mp_wall = t2.elapsed().as_secs_f64();
    let mp_rate = (mp_cfg.n_samples * spec.m as u64) as f64 / mp_wall;
    let dp_rate = (cfg.n_samples * spec.m as u64) as f64 / native_wall;
    println!(
        "  wall {} for {} samples | FastMPS/native is {:.1}× the baseline's rate",
        fastmps::util::human_secs(mp_wall),
        mp_cfg.n_samples,
        dp_rate / mp_rate
    );
    println!(
        "  baseline comm: {} over {} collective/p2p ops",
        fastmps::util::human_bytes(mp_report.metrics.get(keys::COMM_BYTES)),
        spec.m
    );

    // --- Validation: Fig. 9 correlation slopes. ------------------------
    println!("\nvalidation (Fig. 9): sampled vs exact transfer-matrix marginals");
    let mps = store.load_all()?;
    let v = fastmps::validate::validate(&mps, &xla_report.sink)?;
    println!(
        "  1st-order slope {:.4} (paper 0.97, ideal 1) | 2nd-order slope {:.4} (paper 0.96) | pairs {}",
        v.first_order_slope, v.second_order_slope, v.pairs
    );
    let ok = (v.first_order_slope - 1.0).abs() < 0.08 && (v.second_order_slope - 1.0).abs() < 0.15;
    println!("  verdict: {}", if ok { "PASS" } else { "FAIL" });

    std::fs::remove_dir_all(&dir)?;
    if !ok {
        std::process::exit(1);
    }
    Ok(())
}
