//! Scaling study (Figs. 12/13 shapes): data-parallel weak/strong scaling on
//! the simulated fabric + measured threads, and the tensor-parallel
//! single- vs double-site comparison on NVLink3/PCIe presets.
//!
//! ```bash
//! cargo run --release --example scaling_study
//! ```

use std::sync::Arc;

use fastmps::comm::NetPreset;
use fastmps::config::{ComputePrecision, EngineKind, Preset, RunConfig, ScalingMode};
use fastmps::coordinator::{data_parallel, tensor_parallel};
use fastmps::io::{GammaStore, StoreCodec, StorePrecision};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut spec = Preset::M8176.scaled_spec(31);
    spec.m = 48;
    spec.chi_cap = 48;
    spec.displacement_sigma = 0.0;
    spec.decay_k = 0.02;
    let dir = std::env::temp_dir().join("fastmps-scaling");
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(GammaStore::create(
        &dir,
        &spec,
        StorePrecision::F16,
        StoreCodec::Raw,
    )?);

    let base = |p1: usize, n: u64| {
        let mut cfg = RunConfig::new(store.spec.clone());
        cfg.n_samples = n;
        cfg.n1_macro = 256;
        cfg.n2_micro = 128;
        cfg.p1 = p1;
        cfg.engine = EngineKind::Native;
        cfg.compute = ComputePrecision::F32;
        cfg.scaling = ScalingMode::PerSample;
        cfg.net = NetPreset::Tianhe3;
        cfg.disk_bw = Some(5e9);
        cfg.vdevice_flops = Some(50e9); // modelled device per rank
        cfg
    };

    println!("== data-parallel strong scaling (fixed 8192 samples; Fig. 12b/d shape)");
    let t1 = data_parallel::run(&base(1, 8192), &store, &[])?.wall;
    for p in [1usize, 2, 4, 8] {
        let rep = data_parallel::run(&base(p, 8192), &store, &[])?;
        let eff = t1 / (rep.wall * p as f64) * 100.0;
        println!(
            "  p={p:<2} wall={:<10} vtime={:<10} efficiency={:.1}% (paper ≥95%)",
            fastmps::util::human_secs(rep.wall),
            fastmps::util::human_secs(rep.vtime),
            eff
        );
    }

    println!("\n== data-parallel weak scaling (2048 samples/worker; Fig. 12a/c shape)");
    let tw1 = data_parallel::run(&base(1, 2048), &store, &[])?.wall;
    for p in [1usize, 2, 4, 8] {
        let rep = data_parallel::run(&base(p, 2048 * p as u64), &store, &[])?;
        let eff = tw1 / rep.wall * 100.0;
        println!(
            "  p={p:<2} wall={:<10} efficiency={:.1}%",
            fastmps::util::human_secs(rep.wall),
            eff
        );
    }

    println!("\n== tensor-parallel strong scaling (Fig. 13 shape, virtual network time)");
    for net in [NetPreset::NvLink3, NetPreset::Pcie4] {
        for double in [true, false] {
            let mut t_base = 0.0;
            for p2 in [1usize, 2, 4] {
                let mut cfg = base(1, 1024);
                cfg.p2 = p2;
                cfg.compute = ComputePrecision::F64;
                cfg.net = net;
                cfg.double_site = double;
                cfg.vdevice_flops = Some(1e12); // keeps the paper's comm/compute balance
                let rep = tensor_parallel::run(&cfg, &store)?;
                if p2 == 1 {
                    t_base = rep.vtime;
                }
                let eff = t_base / (rep.vtime * p2 as f64) * 100.0;
                println!(
                    "  {}/{}-site p2={p2}: vtime={:<10} eff={:.1}%  (paper: 4-GPU decay 9.8% double / 39% single on NVLink3)",
                    net.name(),
                    if double { "double" } else { "single" },
                    fastmps::util::human_secs(rep.vtime),
                    eff
                );
            }
        }
    }

    println!("\n== §4.3 decision probe");
    for net in [NetPreset::NvLink3, NetPreset::Pcie4, NetPreset::InfinibandHdr] {
        let (ar, rs, d) = tensor_parallel::comm_bench(net, 64 << 20, 4);
        println!(
            "  {}: AllReduce {:.2} ms vs ReduceScatter {:.2} ms → {}",
            net.name(),
            ar * 1e3,
            rs * 1e3,
            if d { "double-site" } else { "single-site" }
        );
    }

    std::fs::remove_dir_all(&dir)?;
    Ok(())
}
