#!/usr/bin/env bash
# Validate the repo-root BENCH_*.json KPI files against
# docs/bench.schema.json using jq only — no Rust toolchain needed, so
# this gate runs even where cargo cannot.
#
# Enforced rules (see the schema's description):
#   - required keys present (bench, measured, wall_secs) with the
#     declared types;
#   - non-empty bench name;
#   - at least one KPI field (key matching the schema's x-kpi-pattern);
#   - no placeholder/measured drift: measured:true demands a numeric
#     wall_secs and at least one numeric KPI field.
set -euo pipefail
cd "$(dirname "$0")/../.."

schema=docs/bench.schema.json
if ! jq empty "$schema" 2>/dev/null; then
  echo "FAIL $schema is not valid JSON" >&2
  exit 1
fi

shopt -s nullglob
files=(BENCH_*.json)
if [ ${#files[@]} -eq 0 ]; then
  echo "no BENCH_*.json files found at the repo root" >&2
  exit 1
fi

status=0
for f in "${files[@]}"; do
  if jq -e --slurpfile schema "$schema" '
    $schema[0] as $s
    | . as $doc
    | ($s.required - ($doc | keys)) as $missing
    | if ($missing | length) > 0
        then error("missing required keys: " + ($missing | join(", ")))
      else . end
    | reduce ($s.properties | to_entries[]) as $p (.;
        if ($doc | has($p.key) | not) then .
        else
          (($doc[$p.key]) | type) as $t
          | (if ($p.value.type | type) == "array"
               then $p.value.type
             else [$p.value.type] end) as $want
          | if ($want | index($t)) == null
              then error("key " + $p.key + ": got " + $t
                         + ", want " + ($want | join("|")))
            else . end
        end)
    | if ($doc.bench | length) == 0
        then error("empty bench name")
      else . end
    | ([$doc | keys[] | select(test($s["x-kpi-pattern"]))]) as $kpis
    | if ($kpis | length) == 0
        then error("no KPI field matching " + $s["x-kpi-pattern"])
      else . end
    | if $doc.measured == true and (($doc.wall_secs | type) != "number")
        then error("measured:true but wall_secs is not a number")
      else . end
    | if $doc.measured == true
         and (([$kpis[] | $doc[.] | select(type == "number")] | length) == 0)
        then error("measured:true but every KPI field is null")
      else . end
  ' "$f" > /dev/null; then
    echo "ok   $f"
  else
    echo "FAIL $f violates $schema" >&2
    status=1
  fi
done
exit $status
