#!/usr/bin/env bash
# Validate the committed Prometheus text-format fixtures
# (docs/exposition.fixture*.prom) using awk only — no Rust toolchain
# needed, so this gate runs even where cargo cannot. The fixture is the
# documented shape of `GET /metrics` (server and router); if the
# exporter changes, the fixture must change with it.
#
# Enforced rules (Prometheus exposition format 0.0.4):
#   - metric names match [a-zA-Z_:][a-zA-Z0-9_:]* and label names match
#     [a-zA-Z_][a-zA-Z0-9_]*;
#   - every family declares `# HELP` then `# TYPE` exactly once, before
#     its first sample; TYPE is counter|gauge|histogram; no other
#     comment lines;
#   - counter families end in `_total` with non-negative samples;
#   - histogram bucket series are cumulative: per label set the `le`
#     edges strictly increase, counts never decrease, the `+Inf` bucket
#     equals the `_count` sample, and `_sum` is present.
#
# The same rules live in rust/src/telemetry/prom.rs
# (validate_exposition), and a unit test there runs against this very
# fixture — the two validators cannot drift apart silently.
set -euo pipefail
cd "$(dirname "$0")/../.."

shopt -s nullglob
files=(docs/exposition.fixture*.prom)
if [ ${#files[@]} -eq 0 ]; then
  echo "no docs/exposition.fixture*.prom files found" >&2
  exit 1
fi

status=0
for f in "${files[@]}"; do
  if awk '
    function fail(msg) {
      printf "%s:%d: %s\n", FILENAME, NR, msg > "/dev/stderr"
      bad = 1
    }
    function numval(s) { if (s == "+Inf") return 1e308; return s + 0 }

    /^$/ { next }

    /^# HELP / {
      name = $3
      if (name in help) fail("duplicate HELP for " name)
      if (name in sampled) fail("HELP for " name " after its samples")
      help[name] = 1
      next
    }
    /^# TYPE / {
      name = $3; kind = $4
      if (!(name in help)) fail("TYPE without preceding HELP for " name)
      if (name in type) fail("duplicate TYPE for " name)
      if (name in sampled) fail("TYPE for " name " after its samples")
      if (kind != "counter" && kind != "gauge" && kind != "histogram")
        fail("TYPE " name ": unknown kind " kind)
      type[name] = kind
      next
    }
    /^#/ { fail("comment is neither HELP nor TYPE: " $0); next }

    {
      line = $0
      if (match(line, /^[a-zA-Z_:][a-zA-Z0-9_:]*/) == 0) {
        fail("bad metric name: " line)
        next
      }
      name = substr(line, 1, RLENGTH)
      rest = substr(line, RLENGTH + 1)
      labels = ""
      if (substr(rest, 1, 1) == "{") {
        close_idx = index(rest, "}")
        if (close_idx == 0) { fail("unterminated label block: " line); next }
        labels = substr(rest, 2, close_idx - 2)
        rest = substr(rest, close_idx + 1)
      }
      sub(/^[ \t]+/, "", rest)
      value = rest
      if (value !~ /^(-?[0-9][0-9.eE+-]*|[+-]Inf|NaN)$/) {
        fail("bad sample value \"" value "\": " line)
        next
      }

      # Resolve the family: exact name, or histogram suffix.
      fam = ""
      if (name in type) {
        fam = name
      } else {
        base = name
        if (sub(/_bucket$/, "", base) || sub(/_sum$/, "", base) ||
            sub(/_count$/, "", base)) {
          if (base in type && type[base] == "histogram") fam = base
        }
      }
      if (fam == "") { fail("sample for undeclared family: " name); next }
      sampled[fam] = 1

      # Label hygiene; pull out le and the le-less label set.
      le = ""; lset = ""
      if (labels != "") {
        n = split(labels, parts, /",/)
        for (i = 1; i <= n; i++) {
          p = parts[i]
          sub(/"$/, "", p)
          eq = index(p, "=\"")
          if (eq == 0) { fail("malformed label \"" p "\": " line); continue }
          k = substr(p, 1, eq - 1)
          v = substr(p, eq + 2)
          if (k !~ /^[a-zA-Z_][a-zA-Z0-9_]*$/)
            fail("bad label name \"" k "\": " line)
          if (k == "le") le = v
          else lset = lset k "=" v ";"
        }
      }

      if (type[fam] == "counter") {
        if (name != fam) fail("counter " fam " with suffix sample " name)
        if (fam !~ /_total$/) fail("counter " fam " does not end in _total")
        if (value + 0 < 0) fail("counter " fam " is negative: " value)
      }

      if (type[fam] == "histogram") {
        key = fam SUBSEP lset
        hseen[key] = fam
        if (name == fam "_bucket") {
          if (le == "") { fail("bucket without le label: " line); next }
          e = numval(le)
          c = value + 0
          if ((key in lastle) && e <= lastle[key])
            fail("histogram " fam ": le edges not strictly increasing")
          if ((key in lastcum) && c < lastcum[key])
            fail("histogram " fam ": cumulative counts decreased")
          lastle[key] = e
          lastcum[key] = c
          if (le == "+Inf") { haveinf[key] = 1; infcnt[key] = c }
        } else if (name == fam "_sum") {
          havesum[key] = 1
        } else if (name == fam "_count") {
          havecount[key] = 1
          cnt[key] = value + 0
        } else if (name == fam) {
          fail("histogram " fam " with a bare sample line")
        }
      }
    }

    END {
      for (key in hseen) {
        fam = hseen[key]
        if (!(key in haveinf)) {
          printf "histogram %s: series without +Inf bucket\n", fam > "/dev/stderr"
          bad = 1
        }
        if (!(key in havecount)) {
          printf "histogram %s: series without _count\n", fam > "/dev/stderr"
          bad = 1
        } else if ((key in haveinf) && infcnt[key] != cnt[key]) {
          printf "histogram %s: +Inf bucket %d != _count %d\n", fam, infcnt[key], cnt[key] > "/dev/stderr"
          bad = 1
        }
        if (!(key in havesum)) {
          printf "histogram %s: series without _sum\n", fam > "/dev/stderr"
          bad = 1
        }
      }
      exit bad ? 1 : 0
    }
  ' "$f" > /dev/null; then
    echo "ok   $f"
  else
    echo "FAIL $f violates the exposition format rules" >&2
    status=1
  fi
done
exit $status
