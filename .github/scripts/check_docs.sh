#!/usr/bin/env bash
# Docs drift gate — grep/awk only, no toolchain, so it runs even where
# cargo cannot. Two promises the documentation makes are enforced here:
#
#   1. Intra-repo markdown links resolve. Every `[text](path)` in the
#      scanned files whose target is not an external URL must point at
#      an existing file (relative to the file containing the link), and
#      a `path#anchor` / `#anchor` target must match a heading in the
#      target file (GitHub slug rules: lowercase, punctuation stripped,
#      spaces become hyphens).
#
#   2. CLI docs and the CLI agree. Every `fastmps <subcommand>` a doc
#      mentions must exist in the `run_cli` dispatch of
#      rust/src/cli/commands.rs, and every dispatched subcommand must
#      be documented in the HELP text — so a renamed or removed command
#      cannot leave stale walkthroughs behind.
set -u
cd "$(dirname "$0")/../.." || exit 1

DOCS=(README.md ROADMAP.md docs/*.md rust/README.md)
CLI=rust/src/cli/commands.rs
status=0

# GitHub heading slug: lowercase; drop everything but alphanumerics,
# spaces, hyphens, and underscores; spaces to hyphens.
slugs_of() {
  grep -E '^#{1,6} ' "$1" | sed -E 's/^#{1,6} +//' \
    | tr '[:upper:]' '[:lower:]' \
    | sed -E 's/[^a-z0-9 _-]//g; s/ /-/g'
}

check_link() { # file lineno target
  local f=$1 ln=$2 target=$3 path anchor resolved
  case "$target" in
    http://* | https://* | mailto:*) return 0 ;;
  esac
  path=$target anchor=""
  case "$target" in
    *'#'*)
      path=${target%%#*}
      anchor=${target#*#}
      ;;
  esac
  if [ -n "$path" ]; then
    resolved="$(dirname "$f")/$path"
    if [ ! -e "$resolved" ]; then
      echo "$f:$ln: broken link: $target ($resolved does not exist)" >&2
      return 1
    fi
  else
    resolved=$f
  fi
  if [ -n "$anchor" ]; then
    case "$resolved" in
      *.md)
        if ! slugs_of "$resolved" | grep -qx "$anchor"; then
          echo "$f:$ln: broken anchor: #$anchor is not a heading in $resolved" >&2
          return 1
        fi
        ;;
    esac
  fi
  return 0
}

links=0
for f in "${DOCS[@]}"; do
  [ -f "$f" ] || continue
  # One `lineno:(target)` pair per line; tolerates several links on one
  # source line. Process substitution keeps `status` out of a subshell.
  while IFS=: read -r ln target; do
    [ -n "$target" ] || continue
    links=$((links + 1))
    check_link "$f" "$ln" "$target" || status=1
  done < <(grep -noE '\]\([^)]+\)' "$f" | sed -E 's/\]\((.*)\)$/\1/')
done
if [ "$links" -eq 0 ]; then
  echo "no markdown links found at all — the link extractor is broken" >&2
  status=1
fi

# --- CLI subcommands: docs -> dispatch ------------------------------------

dispatched=$(sed -n '/match args.command.as_str/,/^    }/p' "$CLI" \
  | grep -oE '"[a-z-]+" =>' | tr -d '">= ')
if [ -z "$dispatched" ]; then
  echo "could not extract the run_cli dispatch from $CLI" >&2
  exit 1
fi

mentioned=$(grep -rhoE 'fastmps +[a-z][a-z0-9-]*' "${DOCS[@]}" 2>/dev/null \
  | awk '{print $2}' | sort -u)
for cmd in $mentioned; do
  case "$cmd" in help) continue ;; esac # handled before the match
  if ! printf '%s\n' "$dispatched" | grep -qx "$cmd"; then
    echo "docs mention 'fastmps $cmd' but $CLI does not dispatch it:" >&2
    grep -rn "fastmps $cmd" "${DOCS[@]}" 2>/dev/null | head -3 >&2
    status=1
  fi
done

# --- CLI subcommands: dispatch -> HELP ------------------------------------

for cmd in $dispatched; do
  if ! grep -qE "^  $cmd( |\$)" "$CLI"; then
    echo "subcommand '$cmd' is dispatched but missing from the HELP text in $CLI" >&2
    status=1
  fi
done

if [ "$status" -eq 0 ]; then
  echo "ok   $links intra-repo links/anchors and the CLI subcommand docs agree"
fi
exit $status
