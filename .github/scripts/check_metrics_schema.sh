#!/usr/bin/env bash
# Validate the committed `fastmps metrics --json` fixtures
# (docs/metrics.fixture*.json) against docs/metrics.schema.json using
# jq only — no Rust toolchain needed, so this gate runs even where
# cargo cannot. The fixtures are the documented reply shapes (server +
# router); if the code changes the shape, the fixture must change with
# it, and this script keeps the fixture honest against the schema.
#
# Enforced rules (see the schema's description):
#   - required keys present (config, run; run.phases/counters/
#     achieved_flops) with the declared types;
#   - run.phases and run.counters values are all numbers;
#   - every *_secs field is a number, null, or a histogram object —
#     durations are seconds, never strings or milliseconds;
#   - each run.hists entry has the full HistogramStats key set, sparse
#     ascending [index, count] bucket pairs that sum to `count`,
#     numeric stats when count > 0 and null stats when count == 0,
#     and min ≤ p50 ≤ p99 ≤ max.
set -euo pipefail
cd "$(dirname "$0")/../.."

schema=docs/metrics.schema.json
if ! jq empty "$schema" 2>/dev/null; then
  echo "FAIL $schema is not valid JSON" >&2
  exit 1
fi

shopt -s nullglob
files=(docs/metrics.fixture*.json)
if [ ${#files[@]} -eq 0 ]; then
  echo "no docs/metrics.fixture*.json files found" >&2
  exit 1
fi

status=0
for f in "${files[@]}"; do
  if jq -e --slurpfile schema "$schema" '
    $schema[0] as $s
    | . as $doc
    | ($s.required - ($doc | keys)) as $missing
    | if ($missing | length) > 0
        then error("missing required keys: " + ($missing | join(", ")))
      else . end
    | reduce ($s.properties | to_entries[]) as $p (.;
        if ($doc | has($p.key) | not) then .
        else
          (($doc[$p.key]) | type) as $t
          | (if ($p.value.type | type) == "array"
               then $p.value.type
             else [$p.value.type] end) as $want
          | if ($want | index($t)) == null
              then error("key " + $p.key + ": got " + $t
                         + ", want " + ($want | join("|")))
            else . end
        end)
    | ($s.properties.run.required - ($doc.run | keys)) as $rmissing
    | if ($rmissing | length) > 0
        then error("run missing keys: " + ($rmissing | join(", ")))
      else . end
    | if ([$doc.run.phases[] | select(type != "number")] | length) > 0
        then error("run.phases has a non-numeric value")
      else . end
    | if ([$doc.run.counters[] | select(type != "number")] | length) > 0
        then error("run.counters has a non-numeric value")
      else . end
    | ([$doc | .. | objects | to_entries[]
        | select(.key | endswith($s["x-duration-suffix"]))
        | select((.value | type) as $t
                 | ($t != "number" and $t != "null" and $t != "object"))
        | .key]) as $baddur
    | if ($baddur | length) > 0
        then error("non-numeric duration fields: " + ($baddur | join(", ")))
      else . end
    | reduce (($doc.run.hists // {}) | to_entries[]) as $h (.;
        $h.value as $v
        | ($s["x-hist-required"] - ($v | keys)) as $hm
        | if ($hm | length) > 0
            then error("hist " + $h.key + " missing: " + ($hm | join(", ")))
          else . end
        | if ($v.count | type) != "number"
            then error("hist " + $h.key + ": count is not a number")
          else . end
        | if ([$v.buckets[]
               | select((type != "array") or (length != 2)
                        or ((.[0] | type) != "number")
                        or ((.[1] | type) != "number"))] | length) > 0
            then error("hist " + $h.key + ": malformed bucket pair")
          else . end
        | ([$v.buckets[] | .[1]] | add // 0) as $bsum
        | if $bsum != $v.count
            then error("hist " + $h.key + ": bucket counts sum to "
                       + ($bsum | tostring) + ", count says "
                       + ($v.count | tostring))
          else . end
        | ([$v.buckets[] | .[0]]) as $idx
        | if ($idx | sort) != $idx
            then error("hist " + $h.key + ": bucket indices not ascending")
          else . end
        | if $v.count == 0
             and ([$v.min_secs, $v.max_secs, $v.mean_secs,
                   $v.p50_secs, $v.p99_secs] | any(. != null))
            then error("hist " + $h.key + ": empty hist must report null stats")
          else . end
        | if $v.count > 0
             and ([$v.min_secs, $v.max_secs, $v.mean_secs,
                   $v.p50_secs, $v.p99_secs]
                  | any(type != "number"))
            then error("hist " + $h.key + ": non-empty hist must report numeric stats")
          else . end
        | if $v.count > 0
             and ($v.min_secs > $v.p50_secs or $v.p50_secs > $v.p99_secs
                  or $v.p99_secs > $v.max_secs)
            then error("hist " + $h.key + ": expect min <= p50 <= p99 <= max")
          else . end)
  ' "$f" > /dev/null; then
    echo "ok   $f"
  else
    echo "FAIL $f violates $schema" >&2
    status=1
  fi
done
exit $status
