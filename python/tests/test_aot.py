"""AOT path: manifest-driven lowering produces loadable HLO text."""

import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def tiny_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = {
        "variants": [
            {"kind": "step", "n": 8, "x": 4, "y": 4, "d": 3},
            {"kind": "step_disp", "n": 8, "x": 4, "y": 4, "d": 3},
            {"kind": "partial", "n": 8, "x": 2, "y": 4, "d": 3},
            {"kind": "finalize", "n": 8, "y": 4, "d": 3},
        ]
    }
    aot.build(manifest, str(out))
    return out


def test_artifacts_written(tiny_artifacts):
    files = sorted(os.listdir(tiny_artifacts))
    assert "manifest.json" in files
    hlos = [f for f in files if f.endswith(".hlo.txt")]
    assert len(hlos) == 4


def test_hlo_text_is_hlo(tiny_artifacts):
    path = tiny_artifacts / "step_n8_x4_y4_d3.hlo.txt"
    text = path.read_text()
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    # Fused module: contraction (dot) and the threshold compare all present.
    assert "dot(" in text or "dot." in text
    assert "compare" in text


def test_manifest_index_round_trips(tiny_artifacts):
    idx = json.loads((tiny_artifacts / "manifest.json").read_text())
    assert idx["format"] == "fastmps-artifacts-v1"
    by_name = {v["name"]: v for v in idx["variants"]}
    step = by_name["step_n8_x4_y4_d3"]
    assert step["inputs"] == [[8, 4], [8, 4], [4, 4, 3], [4, 4, 3], [4], [8]]
    assert [o["shape"] for o in step["outputs"]] == [[8, 4], [8, 4], [8]]
    assert by_name["step_n8_x4_y4_d3_disp"]["inputs"][-1] == [8]


def test_lowering_is_deterministic(tiny_artifacts, tmp_path):
    v = {"kind": "step", "n": 8, "x": 4, "y": 4, "d": 3}
    t1, _, _ = aot.lower_variant(v)
    t2, _, _ = aot.lower_variant(v)
    assert t1 == t2


def test_variant_names():
    assert aot.variant_name({"kind": "step", "n": 256, "x": 96, "y": 96, "d": 3}) == (
        "step_n256_x96_y96_d3"
    )
    assert (
        aot.variant_name(
            {"kind": "step", "n": 256, "x": 96, "y": 96, "d": 3, "tf32": True}
        )
        == "step_n256_x96_y96_d3_tf32"
    )
    with pytest.raises(ValueError):
        aot.variant_name({"kind": "bogus", "n": 1, "d": 1})


def test_default_manifest_covers_buckets():
    m = aot.default_manifest()
    kinds = {v["kind"] for v in m["variants"]}
    assert {"step", "step_disp", "partial", "finalize"} <= kinds
    # χ_l = 1 boundary variant must exist for site 0.
    assert any(v.get("x") == 1 for v in m["variants"])
