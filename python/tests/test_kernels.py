"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the core correctness signal of the build path — the HLO the rust
runtime executes is lowered from exactly these kernels. Hypothesis sweeps
shapes and value scales; fixed seeds keep the suite deterministic.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import contract as kcontract
from compile.kernels import displace as kdisplace
from compile.kernels import measure as kmeasure
from compile.kernels import ref as kref

jax.config.update("jax_platform_name", "cpu")


def rand_planes(rng, *shape, scale=1.0):
    return (
        jnp.asarray(rng.normal(size=shape) * scale, dtype=jnp.float32),
        jnp.asarray(rng.normal(size=shape) * scale, dtype=jnp.float32),
    )


@pytest.mark.parametrize(
    "n,x,y,d",
    [
        (4, 3, 5, 2),
        (16, 8, 8, 3),
        (32, 1, 16, 3),  # boundary site χ_l = 1
        (64, 96, 32, 4),
        (128, 64, 96, 3),
    ],
)
def test_contract_matches_ref(n, x, y, d):
    rng = np.random.default_rng(42)
    er, ei = rand_planes(rng, n, x)
    gr, gi = rand_planes(rng, x, y, d)
    want_r, want_i = kref.contract_ref(er, ei, gr, gi)
    got_r, got_i = kcontract.contract_env(er, ei, gr, gi)
    np.testing.assert_allclose(got_r, want_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got_i, want_i, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 48),
    x=st.integers(1, 40),
    y=st.integers(1, 40),
    d=st.integers(2, 5),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_contract_hypothesis(n, x, y, d, scale):
    rng = np.random.default_rng(n * 1000 + x * 100 + y * 10 + d)
    er, ei = rand_planes(rng, n, x, scale=scale)
    gr, gi = rand_planes(rng, x, y, d)
    want_r, want_i = kref.contract_ref(er, ei, gr, gi)
    got_r, got_i = kcontract.contract_env(er, ei, gr, gi)
    tol = max(1e-5 * scale * np.sqrt(x), 1e-6)
    np.testing.assert_allclose(got_r, want_r, rtol=1e-4, atol=tol)
    np.testing.assert_allclose(got_i, want_i, rtol=1e-4, atol=tol)


@pytest.mark.parametrize("n,y,d", [(8, 4, 2), (32, 16, 3), (128, 96, 4)])
def test_measure_matches_ref(n, y, d):
    rng = np.random.default_rng(7)
    tr, ti = rand_planes(rng, n, y, d)
    lam = jnp.asarray(np.abs(rng.normal(size=y)) + 0.1, dtype=jnp.float32)
    unif = jnp.asarray(rng.uniform(size=n), dtype=jnp.float32)

    wr, wi, ws = kref.measure_ref(tr, ti, lam, unif)
    wr, wi = kref.rescale_ref(wr, wi)
    gr, gi, gs = kmeasure.measure_rescale(tr, ti, lam, unif)
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))
    np.testing.assert_allclose(gr, wr, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(gi, wi, rtol=1e-5, atol=1e-6)


def test_measure_samples_in_range_and_env_is_gather():
    rng = np.random.default_rng(11)
    n, y, d = 64, 12, 3
    tr, ti = rand_planes(rng, n, y, d)
    lam = jnp.ones((y,), dtype=jnp.float32)
    unif = jnp.asarray(rng.uniform(size=n), dtype=jnp.float32)
    er, ei, s = kmeasure.measure_rescale(tr, ti, lam, unif, rescale=False)
    s = np.asarray(s)
    assert s.min() >= 0 and s.max() < d
    # env row = temp[n, :, s_n].
    for i in [0, 5, 63]:
        np.testing.assert_allclose(np.asarray(er)[i], np.asarray(tr)[i, :, s[i]], rtol=1e-6)


def test_measure_statistics_follow_born_rule():
    # Single dominant weight: outcome distribution must match probs.
    rng = np.random.default_rng(13)
    n, y, d = 4096, 2, 3
    # Construct temp so that |temp|²·Λ gives probs ∝ [0.2, 0.3, 0.5].
    probs = np.array([0.2, 0.3, 0.5])
    tr = np.zeros((n, y, d), dtype=np.float32)
    tr[:, 0, :] = np.sqrt(probs)[None, :]
    ti = np.zeros_like(tr)
    lam = jnp.ones((y,), dtype=jnp.float32)
    unif = jnp.asarray(rng.uniform(size=n), dtype=jnp.float32)
    _, _, s = kmeasure.measure_rescale(
        jnp.asarray(tr), jnp.asarray(ti), lam, unif, rescale=False
    )
    counts = np.bincount(np.asarray(s), minlength=d) / n
    np.testing.assert_allclose(counts, probs, atol=0.03)


def test_rescale_rows_have_unit_max():
    rng = np.random.default_rng(17)
    n, y = 32, 20
    er, ei = rand_planes(rng, n, y, scale=1e-6)
    rr, ri = kref.rescale_ref(er, ei)
    mag = np.sqrt(np.asarray(rr) ** 2 + np.asarray(ri) ** 2)
    np.testing.assert_allclose(mag.max(axis=1), 1.0, rtol=1e-5)
    # Zero rows untouched.
    z_r, z_i = kref.rescale_ref(jnp.zeros((2, 3)), jnp.zeros((2, 3)))
    assert np.all(np.asarray(z_r) == 0)


@pytest.mark.parametrize("d", [2, 3, 4, 6])
def test_displace_kernel_matches_ref(d):
    rng = np.random.default_rng(19)
    n, y = 32, 8
    tr, ti = rand_planes(rng, n, y, d)
    mu_re = jnp.asarray(rng.normal(size=n) * 0.4, dtype=jnp.float32)
    mu_im = jnp.asarray(rng.normal(size=n) * 0.4, dtype=jnp.float32)
    dr, di = kref.displace_matrices_ref(mu_re, mu_im, d)
    want_r, want_i = kref.apply_displacement_ref(tr, ti, dr, di)
    coef = kref.displace_coef(d)
    got_r, got_i = kdisplace.displace_apply(tr, ti, mu_re, mu_im, coef)
    np.testing.assert_allclose(got_r, want_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(got_i, want_i, rtol=1e-4, atol=1e-5)


def test_displacement_is_unitary_on_low_photons():
    # D(mu)·D(mu)† ≈ I away from the truncation corner.
    d = 8
    mu_re = jnp.asarray([0.3], dtype=jnp.float32)
    mu_im = jnp.asarray([-0.2], dtype=jnp.float32)
    dr, di = kref.displace_matrices_ref(mu_re, mu_im, d)
    D = np.asarray(dr)[0] + 1j * np.asarray(di)[0]
    P = D @ D.conj().T
    np.testing.assert_allclose(P[:4, :4], np.eye(4), atol=1e-3)


def test_displacement_zero_mu_is_identity():
    d = 4
    z = jnp.zeros((3,), dtype=jnp.float32)
    dr, di = kref.displace_matrices_ref(z, z, d)
    for i in range(3):
        np.testing.assert_allclose(np.asarray(dr)[i], np.eye(d), atol=1e-7)
        np.testing.assert_allclose(np.asarray(di)[i], 0.0, atol=1e-7)


def test_tf32_rounding_keeps_10_bits():
    x = jnp.asarray([1.0 + 1.0 / 1024.0, 1.0 + 1.0 / 4096.0], dtype=jnp.float32)
    r = np.asarray(kref.round_tf32(x))
    assert r[0] == np.float32(1.0 + 1.0 / 1024.0)
    assert r[1] != np.float32(1.0 + 1.0 / 4096.0)
    assert abs(r[1] - (1.0 + 1.0 / 4096.0)) <= 1.0 / 2048.0


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 32),
    y=st.integers(1, 24),
    d=st.integers(2, 4),
)
def test_measure_hypothesis_matches_ref(n, y, d):
    rng = np.random.default_rng(n * 71 + y * 7 + d)
    tr, ti = rand_planes(rng, n, y, d)
    lam = jnp.asarray(np.abs(rng.normal(size=y)) + 0.05, dtype=jnp.float32)
    unif = jnp.asarray(rng.uniform(size=n), dtype=jnp.float32)
    wr, wi, ws = kref.measure_ref(tr, ti, lam, unif)
    wr, wi = kref.rescale_ref(wr, wi)
    gr, gi, gs = kmeasure.measure_rescale(tr, ti, lam, unif)
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))
    np.testing.assert_allclose(gr, wr, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(gi, wi, rtol=1e-5, atol=1e-6)
