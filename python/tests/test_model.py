"""L2 correctness: composed step functions vs the oracle, shape behaviour,
tf32 arm, and the tensor-parallel decomposition identity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref as kref

jax.config.update("jax_platform_name", "cpu")


def make_inputs(rng, n, x, y, d, decay=1.0):
    er = jnp.asarray(rng.normal(size=(n, x)) * decay, dtype=jnp.float32)
    ei = jnp.asarray(rng.normal(size=(n, x)) * decay, dtype=jnp.float32)
    gr = jnp.asarray(rng.normal(size=(x, y, d)), dtype=jnp.float32)
    gi = jnp.asarray(rng.normal(size=(x, y, d)), dtype=jnp.float32)
    lam = jnp.asarray(np.abs(rng.normal(size=y)) + 0.1, dtype=jnp.float32)
    unif = jnp.asarray(rng.uniform(size=n), dtype=jnp.float32)
    return er, ei, gr, gi, lam, unif


@pytest.mark.parametrize("n,x,y,d", [(16, 8, 8, 3), (64, 32, 48, 3), (32, 1, 8, 4)])
def test_step_matches_oracle(n, x, y, d):
    rng = np.random.default_rng(23)
    args = make_inputs(rng, n, x, y, d)
    step = model.build_step()
    ref = model.reference_step()
    gr_, gi_, gs = step(*args)
    wr_, wi_, ws = ref(*args)
    np.testing.assert_array_equal(np.asarray(gs), np.asarray(ws))
    np.testing.assert_allclose(gr_, wr_, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(gi_, wi_, rtol=1e-5, atol=1e-6)


def test_step_displaced_matches_oracle():
    rng = np.random.default_rng(29)
    n, x, y, d = 32, 16, 16, 3
    er, ei, gr, gi, lam, unif = make_inputs(rng, n, x, y, d)
    mu_re = jnp.asarray(rng.normal(size=n) * 0.3, dtype=jnp.float32)
    mu_im = jnp.asarray(rng.normal(size=n) * 0.3, dtype=jnp.float32)
    coef = kref.displace_coef(d)
    got = model.build_step_displaced()(er, ei, gr, gi, lam, unif, mu_re, mu_im, coef)
    want = kref.step_displaced_ref(er, ei, gr, gi, lam, unif, mu_re, mu_im)
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(want[2]))
    np.testing.assert_allclose(got[0], want[0], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(got[1], want[1], rtol=1e-4, atol=1e-5)


def test_tf32_step_close_but_not_identical():
    rng = np.random.default_rng(31)
    n, x, y, d = 64, 48, 48, 3
    args = make_inputs(rng, n, x, y, d)
    exact = model.build_step(tf32=False)(*args)
    rounded = model.build_step(tf32=True)(*args)
    # Identical sampling decisions at this scale, slightly different envs.
    np.testing.assert_array_equal(np.asarray(exact[2]), np.asarray(rounded[2]))
    diff = np.abs(np.asarray(exact[0]) - np.asarray(rounded[0])).max()
    assert diff < 1e-2
    assert diff > 0.0  # tf32 must actually change something


def test_tensor_parallel_decomposition_identity():
    """Split-K over p2 shards + fabric-style reduction == plain step."""
    rng = np.random.default_rng(37)
    n, x, y, d, p2 = 16, 32, 24, 3, 4
    er, ei, gr, gi, lam, unif = make_inputs(rng, n, x, y, d)

    partial = model.build_contract_partial()
    finalize = model.build_measure_update()

    acc_r = np.zeros((n, y * d), dtype=np.float32)
    acc_i = np.zeros((n, y * d), dtype=np.float32)
    sh = x // p2
    for r in range(p2):
        pr, pi = partial(
            er[:, r * sh : (r + 1) * sh],
            ei[:, r * sh : (r + 1) * sh],
            gr[r * sh : (r + 1) * sh],
            gi[r * sh : (r + 1) * sh],
        )
        acc_r += np.asarray(pr)
        acc_i += np.asarray(pi)

    fr, fi, fs = finalize(jnp.asarray(acc_r), jnp.asarray(acc_i), lam, unif, d=d)
    wr, wi, ws = model.build_step()(er, ei, gr, gi, lam, unif)
    np.testing.assert_array_equal(np.asarray(fs), np.asarray(ws))
    np.testing.assert_allclose(fr, wr, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(fi, wi, rtol=1e-4, atol=1e-5)


def test_step_is_deterministic():
    rng = np.random.default_rng(41)
    args = make_inputs(rng, 32, 16, 16, 3)
    s = model.build_step()
    a = s(*args)
    b = s(*args)
    for x_, y_ in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x_), np.asarray(y_))


def test_chain_of_steps_keeps_env_normalized():
    """Walking several sites with per-sample rescale keeps |env| max ≈ 1 —
    the §3.3.1 stability property."""
    rng = np.random.default_rng(43)
    n, chi, d = 32, 24, 3
    er = jnp.asarray(rng.normal(size=(n, 1)), dtype=jnp.float32)
    ei = jnp.asarray(rng.normal(size=(n, 1)), dtype=jnp.float32)
    step = model.build_step()
    x = 1
    for site in range(6):
        y = chi
        gr = jnp.asarray(rng.normal(size=(x, y, d)) * 1e-3, dtype=jnp.float32)
        gi = jnp.asarray(rng.normal(size=(x, y, d)) * 1e-3, dtype=jnp.float32)
        lam = jnp.ones((y,), dtype=jnp.float32)
        unif = jnp.asarray(rng.uniform(size=n), dtype=jnp.float32)
        er, ei, _ = step(er, ei, gr, gi, lam, unif)
        x = y
        mag = np.sqrt(np.asarray(er) ** 2 + np.asarray(ei) ** 2).max(axis=1)
        np.testing.assert_allclose(mag, 1.0, rtol=1e-4)
