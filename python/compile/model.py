"""L2: the per-site sampling step, composing the L1 Pallas kernels.

Each builder returns a plain jax function over split-plane f32 arrays (the
PJRT boundary types) that `aot.py` lowers to one fused HLO module per shape
variant. Python never runs at sampling time: the rust coordinator feeds Γ,
Λ, thresholds and (optionally) displacement draws, and gets back the next
left environment plus the collapsed outcomes.

Variants
  step               contract → measure → per-sample rescale
  step_displaced     contract → displace → measure → rescale
  contract_partial   tensor-parallel shard: (N, χ_l/p₂) × (χ_l/p₂, χ_r, d)
                     partial split-K product (reduced by the L3 fabric)
  measure_update     measurement-only finalize after the reduction
"""

import jax.numpy as jnp

from compile.kernels import contract as kcontract
from compile.kernels import displace as kdisplace
from compile.kernels import measure as kmeasure
from compile.kernels import ref as kref


def _maybe_tf32(tf32, *arrays):
    if not tf32:
        return arrays
    return tuple(kref.round_tf32(a) for a in arrays)


def build_step(tf32=False, rescale=True):
    """Plain per-site step.

    Inputs : env_re/env_im (N, χ_l), g_re/g_im (χ_l, χ_r, d), lam (χ_r,),
             unif (N,)
    Outputs: (env_re', env_im' (N, χ_r), samples i32 (N,))
    """

    def step(env_re, env_im, g_re, g_im, lam, unif):
        env_re, env_im, g_re, g_im = _maybe_tf32(tf32, env_re, env_im, g_re, g_im)
        t_re, t_im = kcontract.contract_env(env_re, env_im, g_re, g_im)
        return kmeasure.measure_rescale(t_re, t_im, lam, unif, rescale=rescale)

    return step


def build_step_displaced(tf32=False, rescale=True):
    """Per-site step with per-sample displacement (GBS path).

    Extra inputs: mu_re/mu_im (N,), coef (d, d) factorial table.
    """

    def step(env_re, env_im, g_re, g_im, lam, unif, mu_re, mu_im, coef):
        env_re, env_im, g_re, g_im = _maybe_tf32(tf32, env_re, env_im, g_re, g_im)
        t_re, t_im = kcontract.contract_env(env_re, env_im, g_re, g_im)
        t_re, t_im = kdisplace.displace_apply(t_re, t_im, mu_re, mu_im, coef)
        return kmeasure.measure_rescale(t_re, t_im, lam, unif, rescale=rescale)

    return step


def build_contract_partial(tf32=False):
    """Tensor-parallel split-K shard: returns the *partial* temp planes
    (N, χ_r·d) flattened for the fabric reduction."""

    def partial(env_re, env_im, g_re, g_im):
        env_re, env_im, g_re, g_im = _maybe_tf32(tf32, env_re, env_im, g_re, g_im)
        t_re, t_im = kcontract.contract_env(env_re, env_im, g_re, g_im)
        n = t_re.shape[0]
        return t_re.reshape(n, -1), t_im.reshape(n, -1)

    return partial


def build_measure_update(rescale=True):
    """Finalize after the reduction: (N, χ_r·d) planes → env + samples."""

    def finalize(t_re_flat, t_im_flat, lam, unif, d):
        n = t_re_flat.shape[0]
        y = t_re_flat.shape[1] // d
        t_re = t_re_flat.reshape(n, y, d)
        t_im = t_im_flat.reshape(n, y, d)
        return kmeasure.measure_rescale(t_re, t_im, lam, unif, rescale=rescale)

    return finalize


def reference_step(tf32=False):
    """The pure-jnp oracle with the same signature as `build_step()`."""

    def step(env_re, env_im, g_re, g_im, lam, unif):
        return kref.step_ref(env_re, env_im, g_re, g_im, lam, unif, tf32=tf32)

    return step
