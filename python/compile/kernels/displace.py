"""L1 Pallas kernel: batched displacement operator (§3.4.1).

Builds `D(mu_n) = e^{-|mu|^2/2}·e^{mu a†}·e^{-mu* a}` for every sample from
the analytic triangular factors (no expm, no LU — the paper's >10×
displacement speedup) and applies it to the unmeasured temp tensor in the
same kernel. The batch axis is the leading block axis, so the per-(j,k)
element loop runs contiguously over samples — the Pallas analog of the
paper's bank-conflict-avoiding batch-last transpose on GPUs.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _displace_kernel(t_re_ref, t_im_ref, mu_re_ref, mu_im_ref, coef_ref, or_ref, oi_ref):
    t_re = t_re_ref[...]  # (bn, Y, d)
    t_im = t_im_ref[...]
    mu_re = mu_re_ref[...]  # (bn,)
    mu_im = mu_im_ref[...]
    coef = coef_ref[...]  # (d, d) lower-tri sqrt(j!/m!)/(j-m)!

    d = t_re.shape[2]
    # Powers of mu and (-mu*): p = 0..d-1, shapes (bn, d).
    pr = [jnp.ones_like(mu_re)]
    pi = [jnp.zeros_like(mu_im)]
    nr = [jnp.ones_like(mu_re)]
    ni = [jnp.zeros_like(mu_im)]
    for _ in range(d - 1):
        pr.append(pr[-1] * mu_re - pi[-1] * mu_im)
        pi.append(pr[-2] * mu_im + pi[-1] * mu_re)
        # (-mu*) = (-mu_re, mu_im)
        nr.append(nr[-1] * (-mu_re) - ni[-1] * mu_im)
        ni.append(nr[-2] * mu_im + ni[-1] * (-mu_re))
    pows_re = jnp.stack(pr, axis=1)  # (bn, d)
    pows_im = jnp.stack(pi, axis=1)
    npows_re = jnp.stack(nr, axis=1)
    npows_im = jnp.stack(ni, axis=1)

    # L[n,j,m] = mu^{j-m}·coef[j,m];  U[n,m,k] = (-mu*)^{k-m}·coef[k,m].
    jm = jnp.arange(d)[:, None] - jnp.arange(d)[None, :]
    lvalid = (jm >= 0).astype(jnp.float32) * coef
    idx = jnp.clip(jm, 0, d - 1)
    L_re = pows_re[:, idx] * lvalid[None]
    L_im = pows_im[:, idx] * lvalid[None]
    km = jnp.arange(d)[None, :] - jnp.arange(d)[:, None]
    uvalid = (km >= 0).astype(jnp.float32) * coef.T
    idxu = jnp.clip(km, 0, d - 1)
    U_re = npows_re[:, idxu] * uvalid[None]
    U_im = npows_im[:, idxu] * uvalid[None]

    # D = pref · L@U (complex, batched, d×d so this is tiny VPU work).
    D_re = jnp.einsum("njm,nmk->njk", L_re, U_re) - jnp.einsum(
        "njm,nmk->njk", L_im, U_im
    )
    D_im = jnp.einsum("njm,nmk->njk", L_re, U_im) + jnp.einsum(
        "njm,nmk->njk", L_im, U_re
    )
    pref = jnp.exp(-0.5 * (mu_re * mu_re + mu_im * mu_im))[:, None, None]
    D_re = D_re * pref
    D_im = D_im * pref

    # Apply: temp'[n,y,k] = Σ_j temp[n,y,j]·D[n,j,k].
    or_ref[...] = jnp.einsum("nyj,njk->nyk", t_re, D_re) - jnp.einsum(
        "nyj,njk->nyk", t_im, D_im
    )
    oi_ref[...] = jnp.einsum("nyj,njk->nyk", t_re, D_im) + jnp.einsum(
        "nyj,njk->nyk", t_im, D_re
    )


def _pick_block(n, target):
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bn",))
def displace_apply(t_re, t_im, mu_re, mu_im, coef, bn=256):
    """Apply per-sample displacements to (N, Y, d) temp planes.

    `coef` is the (d, d) lower-triangular factorial table
    (`ref.displace_coef(d)`), passed as an input so the kernel stays
    shape-generic.
    """
    n, y, d = t_re.shape
    bn = _pick_block(n, bn)
    grid = (n // bn,)

    t_spec = pl.BlockSpec((bn, y, d), lambda i: (i, 0, 0))
    mu_spec = pl.BlockSpec((bn,), lambda i: (i,))
    coef_spec = pl.BlockSpec((d, d), lambda i: (0, 0))

    o_re, o_im = pl.pallas_call(
        _displace_kernel,
        grid=grid,
        in_specs=[t_spec, t_spec, mu_spec, mu_spec, coef_spec],
        out_specs=[t_spec, t_spec],
        out_shape=[
            jax.ShapeDtypeStruct((n, y, d), jnp.float32),
            jax.ShapeDtypeStruct((n, y, d), jnp.float32),
        ],
        interpret=True,
    )(t_re, t_im, mu_re, mu_im, coef)
    return o_re, o_im
