"""Pure-jnp reference oracle for the L1 Pallas kernels.

Everything here is the *semantic contract*: the Pallas kernels
(`contract.py`, `measure.py`, `displace.py`) and, transitively, the rust
native engine must agree with these functions. Complex values travel as
split (re, im) float32 planes — the representation used across the PJRT
boundary (the `xla` crate has no complex Literal constructors).

Shapes follow the paper (Fig. 1 / Alg. 1):
  left_env   (N, chi_l)          per-sample left environment
  gamma      (chi_l, chi_r, d)   MPS site tensor
  temp       (N, chi_r, d)       unmeasured left environment
  lam        (chi_r,)            coefficient vector Λ (all-ones for
                                 right-canonical states)
  unif       (N,)                measurement thresholds in [0, 1)
"""

import math

import jax
import jax.numpy as jnp


def contract_ref(env_re, env_im, g_re, g_im):
    """Bond contraction: (N,x) × (x,y,d) → (N,y,d), complex via 4 real GEMMs."""
    tr = jnp.einsum("nx,xyd->nyd", env_re, g_re) - jnp.einsum(
        "nx,xyd->nyd", env_im, g_im
    )
    ti = jnp.einsum("nx,xyd->nyd", env_re, g_im) + jnp.einsum(
        "nx,xyd->nyd", env_im, g_re
    )
    return tr, ti


def displace_coef(d):
    """Coefficient table c[j, m] = sqrt(j!/m!)/(j-m)! for j >= m else 0."""
    coef = [[0.0] * d for _ in range(d)]
    for j in range(d):
        for m in range(j + 1):
            coef[j][m] = math.sqrt(
                math.factorial(j) / math.factorial(m)
            ) / math.factorial(j - m)
    return jnp.asarray(coef, dtype=jnp.float32)


def displace_matrices_ref(mu_re, mu_im, d):
    """Batched fast displacement D(mu) (paper Eq. 6), (N, d, d) split planes.

    D = e^{-|mu|^2/2} · L(mu) · U(-mu*), with analytic triangular factors
      L[j,m] = mu^{j-m}   · sqrt(j!/m!)/(j-m)!      (j >= m)
      U[m,k] = (-mu*)^{k-m} · sqrt(k!/m!)/(k-m)!    (k >= m)
    """
    mu = (mu_re + 1j * mu_im).astype(jnp.complex64)
    coef = displace_coef(d)

    # Powers mu^p and (-mu*)^p for p in 0..d-1: (N, d).
    pows = jnp.stack([mu**p for p in range(d)], axis=1)
    npows = jnp.stack([(-jnp.conj(mu)) ** p for p in range(d)], axis=1)

    # L[n, j, m] = pows[n, j-m] * coef[j, m].
    jm = jnp.arange(d)[:, None] - jnp.arange(d)[None, :]  # (d, d) j-m
    valid = (jm >= 0).astype(jnp.float32)
    idx = jnp.clip(jm, 0, d - 1)
    L = pows[:, idx] * (coef * valid)[None, :, :]  # (N, d, d)
    # U[n, m, k] = npows[n, k-m] * coef[k, m].
    km = jnp.arange(d)[None, :] - jnp.arange(d)[:, None]  # at [m, k]: k-m
    validu = (km >= 0).astype(jnp.float32)
    idxu = jnp.clip(km, 0, d - 1)
    U = npows[:, idxu] * (coef.T * validu)[None, :, :]  # (N, d, d), [m, k]

    pref = jnp.exp(-0.5 * (mu_re**2 + mu_im**2)).astype(jnp.complex64)
    D = pref[:, None, None] * jnp.einsum("njm,nmk->njk", L, U)
    return jnp.real(D).astype(jnp.float32), jnp.imag(D).astype(jnp.float32)


def apply_displacement_ref(t_re, t_im, d_re, d_im):
    """temp'[n,y,k] = sum_j temp[n,y,j] · D[n,j,k] (complex)."""
    tr = jnp.einsum("nyj,njk->nyk", t_re, d_re) - jnp.einsum(
        "nyj,njk->nyk", t_im, d_im
    )
    ti = jnp.einsum("nyj,njk->nyk", t_re, d_im) + jnp.einsum(
        "nyj,njk->nyk", t_im, d_re
    )
    return tr, ti


def measure_ref(t_re, t_im, lam, unif):
    """Alg. 1: measure the physical index and collapse the left environment.

    Returns (env_re, env_im, samples_i32); the environment is NOT yet
    rescaled (see `rescale_ref`).
    """
    w = t_re * t_re + t_im * t_im  # (N, y, d) Born weights
    probs = jnp.einsum("nyd,y->nd", w, lam)  # (N, d)
    tot = jnp.sum(probs, axis=1, keepdims=True)
    # Degenerate rows (all-zero: underflow collapse) sample outcome 0.
    safe = jnp.where(tot > 0, tot, 1.0)
    cum = jnp.cumsum(probs / safe, axis=1)
    samples = jnp.sum((unif[:, None] > cum).astype(jnp.int32), axis=1)
    samples = jnp.clip(samples, 0, probs.shape[1] - 1)
    onehot = (samples[:, None] == jnp.arange(probs.shape[1])[None, :]).astype(
        jnp.float32
    )
    env_re = jnp.einsum("nyd,nd->ny", t_re, onehot)
    env_im = jnp.einsum("nyd,nd->ny", t_im, onehot)
    return env_re, env_im, samples


def rescale_ref(env_re, env_im):
    """Per-sample adaptive rescale (§3.3.1): divide each row by its max |z|.

    Zero rows are left untouched (scale 1) — they stay diagnosable.
    """
    mag2 = env_re**2 + env_im**2
    m = jnp.sqrt(jnp.max(mag2, axis=1, keepdims=True))
    scale = jnp.where(m > 0, 1.0 / m, 1.0)
    return env_re * scale, env_im * scale


def global_rescale_ref(env_re, env_im):
    """The baseline auto-scaling of [19]: one scale for the whole batch."""
    mag2 = env_re**2 + env_im**2
    m = jnp.sqrt(jnp.max(mag2))
    scale = jnp.where(m > 0, 1.0 / m, 1.0)
    return env_re * scale, env_im * scale


def round_tf32(x):
    """Emulate TF32 tensor-core input rounding: f32 with a 10-bit mantissa
    (round-to-nearest-even)."""
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    rem = bits & jnp.uint32(0x1FFF)
    out = bits >> jnp.uint32(13)
    round_up = (rem > 0x1000) | ((rem == 0x1000) & ((out & 1) == 1))
    out = out + round_up.astype(jnp.uint32)
    return jax.lax.bitcast_convert_type(out << jnp.uint32(13), jnp.float32)


def step_ref(env_re, env_im, g_re, g_im, lam, unif, tf32=False):
    """One full per-site step: contract → measure → per-sample rescale."""
    if tf32:
        env_re, env_im = round_tf32(env_re), round_tf32(env_im)
        g_re, g_im = round_tf32(g_re), round_tf32(g_im)
    t_re, t_im = contract_ref(env_re, env_im, g_re, g_im)
    e_re, e_im, samples = measure_ref(t_re, t_im, lam, unif)
    e_re, e_im = rescale_ref(e_re, e_im)
    return e_re, e_im, samples


def step_displaced_ref(
    env_re, env_im, g_re, g_im, lam, unif, mu_re, mu_im, tf32=False
):
    """Per-site step with the batched displacement applied before measurement."""
    if tf32:
        env_re, env_im = round_tf32(env_re), round_tf32(env_im)
        g_re, g_im = round_tf32(g_re), round_tf32(g_im)
    t_re, t_im = contract_ref(env_re, env_im, g_re, g_im)
    d = t_re.shape[2]
    d_re, d_im = displace_matrices_ref(mu_re, mu_im, d)
    t_re, t_im = apply_displacement_ref(t_re, t_im, d_re, d_im)
    e_re, e_im, samples = measure_ref(t_re, t_im, lam, unif)
    e_re, e_im = rescale_ref(e_re, e_im)
    return e_re, e_im, samples
