"""L1 Pallas kernel: the bond contraction `left_env × Γ` — the paper's hot
spot (complexity N·χ²·d per site).

TPU-shaped design (DESIGN.md §Hardware-Adaptation): the complex contraction
is decomposed into four real matmuls (what an MXU/tensor-core actually
executes), the operands stream HBM→VMEM in (bn × bk) / (bk × bj) tiles
declared by `BlockSpec`s, and a fori-style k-grid accumulates into the
output block — the Pallas equivalent of the paper's macro/micro-batch GEMM
tiling on A100s. Run with `interpret=True` everywhere on this CPU image
(real TPU lowering emits Mosaic calls the CPU PJRT plugin cannot execute).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(n, target):
    """Largest divisor of n that is ≤ target (shapes are static)."""
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


def _contract_kernel(er_ref, ei_ref, gr_ref, gi_ref, or_ref, oi_ref, *, nk):
    """One (bn × bj) output tile; grid axis 2 walks the k (χ_l) dimension."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        or_ref[...] = jnp.zeros_like(or_ref)
        oi_ref[...] = jnp.zeros_like(oi_ref)

    er = er_ref[...]
    ei = ei_ref[...]
    gr = gr_ref[...]
    gi = gi_ref[...]
    # Complex MAC via four real matmuls (MXU-friendly f32 dot).
    or_ref[...] += jnp.dot(er, gr, preferred_element_type=jnp.float32) - jnp.dot(
        ei, gi, preferred_element_type=jnp.float32
    )
    oi_ref[...] += jnp.dot(er, gi, preferred_element_type=jnp.float32) + jnp.dot(
        ei, gr, preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bn", "bj", "bk"))
def contract(env_re, env_im, gmat_re, gmat_im, bn=128, bj=192, bk=128):
    """(N, K) × (K, J) complex-as-planes matmul via Pallas.

    `gmat_*` is Γ unfolded to (χ_l, χ_r·d); the caller reshapes the output
    to (N, χ_r, d). Block sizes are clamped to divisors of the problem.
    """
    n, k = env_re.shape
    k2, j = gmat_re.shape
    assert k == k2, f"contract: K mismatch {k} vs {k2}"
    bn = _pick_block(n, bn)
    bj = _pick_block(j, bj)
    bk = _pick_block(k, bk)
    grid = (n // bn, j // bj, k // bk)

    env_spec = pl.BlockSpec((bn, bk), lambda i, jj, kk: (i, kk))
    g_spec = pl.BlockSpec((bk, bj), lambda i, jj, kk: (kk, jj))
    out_spec = pl.BlockSpec((bn, bj), lambda i, jj, kk: (i, jj))

    out_shape = [
        jax.ShapeDtypeStruct((n, j), jnp.float32),
        jax.ShapeDtypeStruct((n, j), jnp.float32),
    ]
    kernel = functools.partial(_contract_kernel, nk=grid[2])
    o_re, o_im = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[env_spec, env_spec, g_spec, g_spec],
        out_specs=[out_spec, out_spec],
        out_shape=out_shape,
        interpret=True,
    )(env_re, env_im, gmat_re, gmat_im)
    return o_re, o_im


def contract_env(env_re, env_im, g_re, g_im):
    """Convenience wrapper with the paper's tensor shapes:
    (N, χ_l) × (χ_l, χ_r, d) → (N, χ_r, d) split planes."""
    chi_l, chi_r, d = g_re.shape
    gm_re = g_re.reshape(chi_l, chi_r * d)
    gm_im = g_im.reshape(chi_l, chi_r * d)
    o_re, o_im = contract(env_re, env_im, gm_re, gm_im)
    n = env_re.shape[0]
    return o_re.reshape(n, chi_r, d), o_im.reshape(n, chi_r, d)


def vmem_bytes(bn, bj, bk):
    """Estimated VMEM footprint of one grid step (f32 planes ×2 for re/im):
    env tile + Γ tile + out tile. Used by the §Perf L1 analysis."""
    return 4 * 2 * (bn * bk + bk * bj + bn * bj)
