"""L1 Pallas kernel: fused measurement (Alg. 1) + per-sample adaptive
rescale (§3.3.1).

One grid step owns a block of samples; everything after the contraction for
those samples — Born weights, Λ-weighted probabilities, normalized cumsum,
threshold sampling, the one-hot collapse gather, and the per-sample rescale
— happens in VMEM without another HBM round-trip. Fusing the rescale here
is exactly why it is free: the paper's observation that "normalization
further cancels the restoration after scaling" means no reverse-scale pass
ever touches memory.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _measure_kernel(t_re_ref, t_im_ref, lam_ref, unif_ref, er_ref, ei_ref, s_ref):
    t_re = t_re_ref[...]  # (bn, Y, d)
    t_im = t_im_ref[...]
    lam = lam_ref[...]  # (Y,)
    unif = unif_ref[...]  # (bn,)

    d = t_re.shape[2]
    w = t_re * t_re + t_im * t_im
    probs = jnp.einsum("nyd,y->nd", w, lam)
    tot = jnp.sum(probs, axis=1, keepdims=True)
    safe = jnp.where(tot > 0, tot, 1.0)
    cum = jnp.cumsum(probs / safe, axis=1)
    samples = jnp.sum((unif[:, None] > cum).astype(jnp.int32), axis=1)
    samples = jnp.clip(samples, 0, d - 1)

    onehot = (samples[:, None] == jnp.arange(d)[None, :]).astype(jnp.float32)
    env_re = jnp.einsum("nyd,nd->ny", t_re, onehot)
    env_im = jnp.einsum("nyd,nd->ny", t_im, onehot)

    # Per-sample adaptive rescale.
    mag2 = env_re * env_re + env_im * env_im
    m = jnp.sqrt(jnp.max(mag2, axis=1, keepdims=True))
    scale = jnp.where(m > 0, 1.0 / m, 1.0)

    er_ref[...] = env_re * scale
    ei_ref[...] = env_im * scale
    s_ref[...] = samples


def _pick_block(n, target):
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bn", "rescale"))
def measure_rescale(t_re, t_im, lam, unif, bn=256, rescale=True):
    """(N, Y, d) temp planes + Λ + thresholds → ((N, Y) env planes, (N,) i32).

    `rescale=False` gives the raw Alg. 1 output (the global-autoscale
    baseline path applies its own batch-wide factor afterwards).
    """
    n, y, d = t_re.shape
    bn = _pick_block(n, bn)
    grid = (n // bn,)

    t_spec = pl.BlockSpec((bn, y, d), lambda i: (i, 0, 0))
    lam_spec = pl.BlockSpec((y,), lambda i: (0,))
    unif_spec = pl.BlockSpec((bn,), lambda i: (i,))
    env_spec = pl.BlockSpec((bn, y), lambda i: (i, 0))
    s_spec = pl.BlockSpec((bn,), lambda i: (i,))

    kernel = _measure_kernel if rescale else _measure_kernel_noscale
    e_re, e_im, samples = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[t_spec, t_spec, lam_spec, unif_spec],
        out_specs=[env_spec, env_spec, s_spec],
        out_shape=[
            jax.ShapeDtypeStruct((n, y), jnp.float32),
            jax.ShapeDtypeStruct((n, y), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=True,
    )(t_re, t_im, lam, unif)
    return e_re, e_im, samples


def _measure_kernel_noscale(t_re_ref, t_im_ref, lam_ref, unif_ref, er_ref, ei_ref, s_ref):
    t_re = t_re_ref[...]
    t_im = t_im_ref[...]
    lam = lam_ref[...]
    unif = unif_ref[...]
    d = t_re.shape[2]
    w = t_re * t_re + t_im * t_im
    probs = jnp.einsum("nyd,y->nd", w, lam)
    tot = jnp.sum(probs, axis=1, keepdims=True)
    safe = jnp.where(tot > 0, tot, 1.0)
    cum = jnp.cumsum(probs / safe, axis=1)
    samples = jnp.sum((unif[:, None] > cum).astype(jnp.int32), axis=1)
    samples = jnp.clip(samples, 0, d - 1)
    onehot = (samples[:, None] == jnp.arange(d)[None, :]).astype(jnp.float32)
    er_ref[...] = jnp.einsum("nyd,nd->ny", t_re, onehot)
    ei_ref[...] = jnp.einsum("nyd,nd->ny", t_im, onehot)
    s_ref[...] = samples
