"""AOT lowering: JAX step functions → HLO text artifacts for the rust
runtime.

Interchange is HLO *text*, not serialized HloModuleProto: jax ≥ 0.5 emits
protos with 64-bit instruction ids that the crate's xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

A JSON manifest lists the shape variants to build. Output:

  artifacts/<name>.hlo.txt    one per variant
  artifacts/manifest.json     index the rust ArtifactRegistry loads

Variant names encode the shape: step_n{N}_x{χl}_y{χr}_d{D}[_tf32][_disp],
partial_n{N}_x{χl}_y{χr}_d{D}, finalize_n{N}_y{χr}_d{D}.

Usage: python -m compile.aot --out ../artifacts [--manifest path.json]
"""

import argparse
import functools
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import ref as kref

F32 = jnp.float32


def to_hlo_text(lowered):
    """StableHLO → XlaComputation → HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def default_manifest():
    """The variant set the scaled experiments need: χ buckets × the default
    micro batch, plain + displaced + tf32, and the TP kernels."""
    buckets = [32, 64, 96]
    n = 256
    d = 3
    variants = []
    for x in buckets:
        for y in buckets:
            variants.append({"kind": "step", "n": n, "x": x, "y": y, "d": d})
    variants += [
        {"kind": "step", "n": n, "x": 96, "y": 96, "d": d, "tf32": True},
        {"kind": "step", "n": n, "x": 96, "y": 96, "d": 4},
        {"kind": "step_disp", "n": n, "x": 96, "y": 96, "d": d},
        {"kind": "step_disp", "n": n, "x": 64, "y": 64, "d": d},
        {"kind": "partial", "n": n, "x": 48, "y": 96, "d": d},
        {"kind": "finalize", "n": n, "y": 96, "d": d},
        # Boundary site: χ_l = 1.
        {"kind": "step", "n": n, "x": 1, "y": 32, "d": d},
        {"kind": "step_disp", "n": n, "x": 1, "y": 32, "d": d},
    ]
    return {"variants": variants}


def variant_name(v):
    kind = v["kind"]
    n, d = v["n"], v["d"]
    tf = "_tf32" if v.get("tf32") else ""
    if kind == "step":
        return f"step_n{n}_x{v['x']}_y{v['y']}_d{d}{tf}"
    if kind == "step_disp":
        return f"step_n{n}_x{v['x']}_y{v['y']}_d{d}{tf}_disp"
    if kind == "partial":
        return f"partial_n{n}_x{v['x']}_y{v['y']}_d{d}{tf}"
    if kind == "finalize":
        return f"finalize_n{n}_y{v['y']}_d{d}"
    raise ValueError(f"unknown variant kind {kind!r}")


def lower_variant(v):
    """Returns (hlo_text, input_specs, output_specs)."""
    kind = v["kind"]
    n, d = v["n"], v["d"]
    tf32 = bool(v.get("tf32"))

    def spec(*shape):
        return jax.ShapeDtypeStruct(shape, F32)

    if kind == "step":
        x, y = v["x"], v["y"]
        fn = model.build_step(tf32=tf32)
        args = [spec(n, x), spec(n, x), spec(x, y, d), spec(x, y, d), spec(y), spec(n)]
    elif kind == "step_disp":
        x, y = v["x"], v["y"]
        raw = model.build_step_displaced(tf32=tf32)
        # Bake the (d, d) coefficient table in as a constant: the rust side
        # should not need to know the factorial table.
        coef = kref.displace_coef(d)

        def fn(env_re, env_im, g_re, g_im, lam, unif, mu_re, mu_im, _coef=coef):
            return raw(env_re, env_im, g_re, g_im, lam, unif, mu_re, mu_im, _coef)

        args = [
            spec(n, x),
            spec(n, x),
            spec(x, y, d),
            spec(x, y, d),
            spec(y),
            spec(n),
            spec(n),
            spec(n),
        ]
    elif kind == "partial":
        x, y = v["x"], v["y"]
        fn = model.build_contract_partial(tf32=tf32)
        args = [spec(n, x), spec(n, x), spec(x, y, d), spec(x, y, d)]
    elif kind == "finalize":
        y = v["y"]
        raw = model.build_measure_update()
        fn = functools.partial(raw, d=d)
        args = [spec(n, y * d), spec(n, y * d), spec(y), spec(n)]
    else:
        raise ValueError(f"unknown variant kind {kind!r}")

    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    in_specs = [list(a.shape) for a in args]
    out = jax.eval_shape(fn, *args)
    out_specs = [{"shape": list(o.shape), "dtype": str(o.dtype)} for o in out]
    return text, in_specs, out_specs


def build(manifest, out_dir):
    os.makedirs(out_dir, exist_ok=True)
    index = []
    for v in manifest["variants"]:
        name = variant_name(v)
        text, in_specs, out_specs = lower_variant(v)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entry = dict(v)
        entry["name"] = name
        entry["file"] = f"{name}.hlo.txt"
        entry["inputs"] = in_specs
        entry["outputs"] = out_specs
        index.append(entry)
        print(f"  {name}: {len(text)} chars", file=sys.stderr)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump({"format": "fastmps-artifacts-v1", "variants": index}, f, indent=2, sort_keys=True)
    print(f"wrote {len(index)} artifacts to {out_dir}", file=sys.stderr)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--manifest", default=None, help="variant manifest JSON")
    args = ap.parse_args()
    if args.manifest:
        with open(args.manifest) as f:
            manifest = json.load(f)
    else:
        manifest = default_manifest()
    build(manifest, args.out)


if __name__ == "__main__":
    main()
