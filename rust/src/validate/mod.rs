//! Validation: sampled statistics vs exact marginals (paper Fig. 9).
//!
//! First-order correlation: per-site sampled ⟨n_i⟩ against the exact
//! transfer-matrix marginals; the paper reports the least-squares slope
//! (0.97 ≈ ideal 1). Second-order: E[n_i n_j] over near-diagonal pairs
//! (slope 0.96). Truncation error vs χ comes from the dynamic-χ plan's
//! spectrum model (Fig. 9b).

use crate::mps::exact::{correlation_slope, exact_mean_photons, exact_pair_moments};
use crate::mps::Mps;
use crate::sampler::sink::SampleSink;
use crate::util::error::Result;

/// Fig. 9 summary for one run.
#[derive(Debug, Clone)]
pub struct ValidationReport {
    /// Least-squares slope of sampled vs exact ⟨n_i⟩.
    pub first_order_slope: f64,
    /// Slope of sampled vs exact E[n_i n_j].
    pub second_order_slope: f64,
    /// Max |sampled − exact| over sites (first order).
    pub first_order_max_err: f64,
    /// Number of sites / pairs compared.
    pub sites: usize,
    pub pairs: usize,
}

/// Compare a sink against the exact marginals of `mps`.
pub fn validate(mps: &Mps, sink: &SampleSink) -> Result<ValidationReport> {
    let ideal = exact_mean_photons(mps)?;
    let sampled = sink.mean_photons();
    let first_order_slope = correlation_slope(&ideal, &sampled);
    let first_order_max_err = ideal
        .iter()
        .zip(&sampled)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);

    let ideal_pairs = exact_pair_moments(mps, sink.max_gap)?;
    let sampled_pairs = sink.pair_moments();
    // Align by (i, j).
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for (i, j, v) in &ideal_pairs {
        if let Some((_, _, s)) = sampled_pairs
            .iter()
            .find(|(a, b, _)| a == i && b == j)
        {
            xs.push(*v);
            ys.push(*s);
        }
    }
    let second_order_slope = correlation_slope(&xs, &ys);

    Ok(ValidationReport {
        first_order_slope,
        second_order_slope,
        first_order_max_err,
        sites: ideal.len(),
        pairs: xs.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ComputePrecision, EngineKind, Preset, RunConfig, ScalingMode};
    use crate::io::{GammaStore, StoreCodec, StorePrecision};
    use std::sync::Arc;

    #[test]
    fn sampled_slopes_near_one() {
        let dir = std::env::temp_dir().join(format!("fastmps-val-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut spec = Preset::Jiuzhang2.scaled_spec(23);
        spec.m = 10;
        spec.chi_cap = 10;
        spec.decay_k = 0.0;
        spec.displacement_sigma = 0.0;
        let store = Arc::new(
            GammaStore::create(&dir, &spec, StorePrecision::F64, StoreCodec::Raw).unwrap(),
        );
        let mut cfg = RunConfig::new(spec.clone());
        cfg.n_samples = 6000;
        cfg.n1_macro = 1500;
        cfg.n2_micro = 500;
        cfg.p1 = 2;
        cfg.engine = EngineKind::Native;
        cfg.compute = ComputePrecision::F64;
        cfg.scaling = ScalingMode::PerSample;
        let rep = crate::coordinator::data_parallel::run(&cfg, &store, &[]).unwrap();
        let mps = store.load_all().unwrap();
        let v = validate(&mps, &rep.sink).unwrap();
        assert!(
            (v.first_order_slope - 1.0).abs() < 0.05,
            "first-order slope {}",
            v.first_order_slope
        );
        assert!(
            (v.second_order_slope - 1.0).abs() < 0.12,
            "second-order slope {}",
            v.second_order_slope
        );
        assert!(v.pairs > 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
