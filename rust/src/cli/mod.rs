//! Hand-rolled CLI (clap is unavailable offline).
//!
//! ```text
//! fastmps gen-data  --preset bm288 --out data/bm288 [--precision f16]
//! fastmps sample    --data data/bm288 --samples 10000 [--engine xla] ...
//! fastmps validate  --data data/bm288 --samples 20000
//! fastmps perf-model --preset bm288 [--gpus 8]
//! fastmps bench-comm --net nvlink3 --bytes 67108864 --p2 4
//! fastmps info      --data data/bm288
//! ```

mod args;
mod commands;

pub use args::Args;
pub use commands::run_cli;
