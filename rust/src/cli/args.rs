//! Flag parsing: `--key value` and `--flag` forms, with typed getters.

use std::collections::BTreeMap;

use crate::util::error::{Error, Result};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    /// Bare arguments after the subcommand (`fastmps trace 7`), in
    /// order. A positional is only legal where a command reads it via
    /// [`Args::pos`] — `finish` rejects leftovers like flags.
    positionals: Vec<String>,
    /// Every occurrence of `--key value`, in order — repeatable flags
    /// (`--backend a --backend b`) keep all values; scalar getters read
    /// the last one, shell-override style.
    values: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
    pos_consumed: std::cell::Cell<usize>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut it = argv.iter().peekable();
        let command = it
            .next()
            .cloned()
            .ok_or_else(|| Error::config("missing subcommand (try 'fastmps help')"))?;
        let mut positionals = Vec::new();
        let mut values: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut flags = Vec::new();
        while let Some(a) = it.next() {
            let Some(key) = a.strip_prefix("--") else {
                positionals.push(a.clone());
                continue;
            };
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    values
                        .entry(key.to_string())
                        .or_default()
                        .push(it.next().unwrap().clone());
                }
                _ => flags.push(key.to_string()),
            }
        }
        Ok(Args {
            command,
            positionals,
            values,
            flags,
            consumed: Default::default(),
            pos_consumed: std::cell::Cell::new(0),
        })
    }

    /// The `i`-th bare argument after the subcommand, if given.
    pub fn pos(&self, i: usize) -> Option<&str> {
        if i + 1 > self.pos_consumed.get() {
            self.pos_consumed.set(i + 1);
        }
        self.positionals.get(i).map(|s| s.as_str())
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.consumed.borrow_mut().push(key.to_string());
        self.values
            .get(key)
            .and_then(|vs| vs.last())
            .map(|s| s.as_str())
    }

    /// All values of a repeatable option, in argv order; each occurrence
    /// may also be comma-separated (`--backend a:1,b:1`).
    pub fn str_list(&self, key: &str) -> Vec<String> {
        self.consumed.borrow_mut().push(key.to_string());
        self.values
            .get(key)
            .map(|vs| {
                vs.iter()
                    .flat_map(|v| v.split(','))
                    .filter(|s| !s.is_empty())
                    .map(String::from)
                    .collect()
            })
            .unwrap_or_default()
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or(default).to_string()
    }

    pub fn req(&self, key: &str) -> Result<&str> {
        self.str_opt(key)
            .ok_or_else(|| Error::config(format!("missing required --{key}")))
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::config(format!("--{key}: '{v}' is not an integer"))),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::config(format!("--{key}: '{v}' is not an integer"))),
        }
    }

    pub fn f64_opt(&self, key: &str) -> Result<Option<f64>> {
        match self.str_opt(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| Error::config(format!("--{key}: '{v}' is not a number"))),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.consumed.borrow_mut().push(key.to_string());
        self.flags.iter().any(|f| f == key)
    }

    /// Error on unknown keys (catches typos) — call after all getters.
    pub fn finish(&self) -> Result<()> {
        let consumed = self.consumed.borrow();
        for k in self.values.keys() {
            if !consumed.contains(k) {
                return Err(Error::config(format!("unknown option --{k}")));
            }
        }
        for k in &self.flags {
            if !consumed.contains(k) {
                return Err(Error::config(format!("unknown flag --{k}")));
            }
        }
        if self.positionals.len() > self.pos_consumed.get() {
            return Err(Error::config(format!(
                "unexpected positional '{}'",
                self.positionals[self.pos_consumed.get()]
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_values_and_flags() {
        let a = Args::parse(&argv("sample --data d --samples 100 --verbose")).unwrap();
        assert_eq!(a.command, "sample");
        assert_eq!(a.req("data").unwrap(), "d");
        assert_eq!(a.u64_or("samples", 0).unwrap(), 100);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        a.finish().unwrap();
    }

    #[test]
    fn missing_required() {
        let a = Args::parse(&argv("sample")).unwrap();
        assert!(a.req("data").is_err());
    }

    #[test]
    fn unknown_option_caught() {
        let a = Args::parse(&argv("sample --bogus 3")).unwrap();
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_number_rejected() {
        let a = Args::parse(&argv("x --n abc")).unwrap();
        assert!(a.usize_or("n", 1).is_err());
    }

    #[test]
    fn no_subcommand_is_error() {
        assert!(Args::parse(&[]).is_err());
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = Args::parse(&argv("x --k 2")).unwrap();
        assert_eq!(a.usize_or("k", 0).unwrap(), 2);
    }

    #[test]
    fn positionals_read_in_order_and_leftovers_caught() {
        let a = Args::parse(&argv("trace 7 --connect h:1")).unwrap();
        assert_eq!(a.pos(0), Some("7"));
        assert_eq!(a.pos(1), None);
        assert_eq!(a.req("connect").unwrap(), "h:1");
        a.finish().unwrap();
        // An unread positional is a usage error, like an unknown flag.
        let b = Args::parse(&argv("jobs 7 --connect h:1")).unwrap();
        let _ = b.req("connect");
        assert!(b.finish().is_err());
    }

    #[test]
    fn repeated_options_collect_and_scalar_reads_last() {
        let a = Args::parse(&argv(
            "route --backend a:1 --backend b:2,c:3 --workers 2 --workers 4",
        ))
        .unwrap();
        assert_eq!(a.str_list("backend"), vec!["a:1", "b:2", "c:3"]);
        assert_eq!(a.usize_or("workers", 0).unwrap(), 4, "last wins");
        a.finish().unwrap();
        let b = Args::parse(&argv("route")).unwrap();
        assert!(b.str_list("backend").is_empty());
    }
}
