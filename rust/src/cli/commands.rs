//! Subcommand implementations.

use std::path::PathBuf;
use std::sync::Arc;

use super::args::Args;
use crate::comm::NetPreset;
use crate::config::{
    ComputePrecision, EngineKind, NetConfig, Preset, RouterConfig, RunConfig, ScalingMode,
    ServiceConfig,
};
use crate::net::{Client, NetServer};
use crate::router::Router;
use crate::coordinator::{data_parallel, model_parallel, tensor_parallel};
use crate::io::{GammaStore, StoreCodec, StorePrecision};
use crate::mps::gbs::GbsSpec;
use crate::mps::qubit::QubitSpec;
use crate::mps::workload::{WorkloadKind, WorkloadSpec};
use crate::perfmodel;
use crate::util::error::{Error, Result};
use crate::util::json::Json;

const HELP: &str = "fastmps — multi-level parallel MPS sampling (FastMPS reproduction)

USAGE: fastmps <command> [--options]

COMMANDS:
  gen-data    Generate a synthetic MPS store (see docs/WORKLOADS.md)
              [--workload gbs|qubit] --out DIR
              [--precision f64|f32|f16] [--codec raw|lz] [--seed N]
              gbs:   --preset <jiuzhang2|jiuzhang3h|bm216h|bm288|m8176>
                     | --m/--chi/--d/--asp
                     [--full-scale] [--fixed-chi] [--decay K] [--sigma S]
              qubit: --m/--chi [--bias B]  (d = 2, fixed χ plan)
  sample      Run the sampler on a store
              --data DIR --samples N [--scheme dp|mp|tp] [--engine xla|native]
              [--p1 N] [--p2 N] [--single-site] [--n1 N] [--n2 N]
              [--compute f64|f32|tf32] [--scaling per-sample|global|none]
              [--threads N] [--gemm-split auto|rows|cols]
              [--layout auto|interleaved|planar]
              [--net nvlink3|pcie4|ib|tianhe3|sunway|ideal] [--disk-bw BPS]
              [--artifacts DIR] [--json]
  validate    Sample + compare against exact marginals (Fig. 9)
              --data DIR [--samples N] [--engine ...] [--json]
  perf-model  Paper-scale analytic predictions (Tables 2/3 shape)
              [--preset P] [--gpus N] [--n1 N]
  bench-comm  AllReduce vs ReduceScatter decision probe (§4.3)
              [--net P] [--bytes B] [--p2 N]
  info        Describe a store
              --data DIR
  serve       Run the resident batched sampling service
              --jobs DIR | --listen ADDR   (file transport | TCP transport)
              [--workers N] [--max-queue N] [--max-samples N]
              [--cache N] [--linger-ms N] [--poll-ms N] [--n2 N]
              [--target-batch N] [--compute C] [--scaling S] [--engine E]
              [--threads N] [--gemm-split auto|rows|cols] [--prep-mb N]
              [--layout auto|interleaved|planar]
              [--disk-bw BPS] [--artifacts DIR] [--trace-buf N]
              [--max-seconds S] [--log-level L] [--json]
              file only: [--drain]
              tcp only:  [--max-conns N] [--frame-mb N]
                         [--read-timeout-ms N] [--write-timeout-ms N]
                         [--push-dir DIR] [--chunk-kb N] [--staging-mb N]
                         [--telemetry-interval S] [--metrics-listen ADDR]
                         [--tp-timeout-ms N]
  route       Front a fleet of TCP serve instances with store-affinity routing
              --listen ADDR --backend ADDR [--backend ADDR ...]
              [--probe-ms N] [--degraded-after N] [--down-after N]
              [--retry-budget N] [--backoff-ms N] [--backoff-cap-ms N]
              [--jitter-ms N] [--drain-cap-s N] [--seed N]
              [--shard-budget-mb N] (auto-upgrade keyed f32 jobs to TP
              when a complete shard group bigger than N MB is registered)
              [--max-conns N] [--frame-mb N] [--trace-buf N]
              [--read-timeout-ms N] [--write-timeout-ms N]
              [--telemetry-interval S] [--metrics-listen ADDR]
              [--max-seconds S] [--log-level L] [--json]
  push        Upload a store to a server/router (chunked, content-addressed)
              --connect ADDR --data STORE [--chunk-kb N] [--tp N] [--json]
              Prints the content key; submit jobs with --key afterwards —
              no shared data volume needed. --tp N splits the store into
              N column shards and pushes each one (through a router the
              shards spread across the fleet and register a TP group;
              see docs/TENSOR_PARALLEL.md).
  submit      Submit a sampling job to a running serve instance
              (--jobs DIR | --connect ADDR) (--data STORE | --key HEX)
              --samples N
              [--sample-base B] [--compute C] [--tag T] [--wait]
              [--timeout-s S] [--poll-ms N] [--tp N] [--json]
              [--workload gbs|qubit] (declare the store's measurement
              model; the server rejects the job if its manifest
              disagrees — see docs/WORKLOADS.md)
              --tp N runs the job as an N-way tensor-parallel group
              (requires --key naming the unsharded store and a router
              that has its shard group registered; f32 compute only).
  jobs        List job statuses (job directory or TCP server)
              (--jobs DIR | --connect ADDR) [--json]
  metrics     Fetch live service + net metrics from a TCP server
              --connect ADDR [--json]
              --json emits the full machine-readable document
              (schema: docs/metrics.schema.json, docs/OBSERVABILITY.md)
  trace       Replay one job's end-to-end timeline from the flight recorder
              <job> --connect ADDR [--trace HEX] [--chrome FILE] [--json]
              Works against a server or a router (router timelines stitch
              in the owning backend's events). --chrome writes Chrome
              trace_event JSON for chrome://tracing / Perfetto.
  top         Live terminal dashboard from a server/router telemetry ring
              --connect ADDR [--interval S] [--once] [--log-level L]
              Shows queue depth, jobs/s, net bytes/s, cache hit rate, and
              p50/p99 latency sparklines; per-backend rows against a
              router. --once prints a single frame and exits.
  stop        Gracefully drain and stop a TCP server, print final metrics
              --connect ADDR [--timeout-s S] [--json]
  bench-service  Smoke-benchmark the service path, emit KPI JSON
              [--n-jobs N] [--samples N] [--out FILE]
  help        This text

--log-level L (error|warn|info|debug|trace) overrides the FASTMPS_LOG
environment variable for this invocation.
";

pub fn run_cli(argv: &[String]) -> Result<()> {
    if argv.is_empty() || argv[0] == "help" || argv[0] == "--help" {
        println!("{HELP}");
        return Ok(());
    }
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "gen-data" => cmd_gen_data(&args),
        "sample" => cmd_sample(&args),
        "validate" => cmd_validate(&args),
        "perf-model" => cmd_perf_model(&args),
        "bench-comm" => cmd_bench_comm(&args),
        "info" => cmd_info(&args),
        "serve" => cmd_serve(&args),
        "route" => cmd_route(&args),
        "push" => cmd_push(&args),
        "submit" => cmd_submit(&args),
        "jobs" => cmd_jobs(&args),
        "metrics" => cmd_metrics(&args),
        "top" => cmd_top(&args),
        "trace" => cmd_trace(&args),
        "stop" => cmd_stop(&args),
        "bench-service" => cmd_bench_service(&args),
        other => Err(Error::config(format!(
            "unknown command '{other}' (try 'fastmps help')"
        ))),
    }
}

fn spec_from_args(args: &Args) -> Result<GbsSpec> {
    let seed = args.u64_or("seed", 1234)?;
    let mut spec = match args.str_opt("preset") {
        Some(p) => {
            let preset = Preset::parse(p)?;
            if args.flag("full-scale") {
                preset.full_spec(seed)
            } else {
                preset.scaled_spec(seed)
            }
        }
        None => {
            let m = args.usize_or("m", 64)?;
            let chi = args.usize_or("chi", 64)?;
            let d = args.usize_or("d", 3)?;
            GbsSpec {
                name: "custom".into(),
                m,
                d,
                chi_cap: chi,
                asp: 4.0,
                decay_k: 0.1,
                displacement_sigma: 0.3,
            branch_skew: 0.0,
                seed,
                dynamic_chi: true,
                step_ratio_override: None,
            }
        }
    };
    if let Some(asp) = args.f64_opt("asp")? {
        spec.asp = asp;
        spec.step_ratio_override = None;
    }
    if let Some(k) = args.f64_opt("decay")? {
        spec.decay_k = k;
    }
    if let Some(s) = args.f64_opt("sigma")? {
        spec.displacement_sigma = s;
    }
    if args.flag("fixed-chi") {
        spec.dynamic_chi = false;
    }
    Ok(spec)
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    let spec: WorkloadSpec = match WorkloadKind::parse(&args.str_or("workload", "gbs"))? {
        WorkloadKind::Gbs => spec_from_args(args)?.into(),
        WorkloadKind::Qubit => {
            let m = args.usize_or("m", 64)?;
            let chi = args.usize_or("chi", 64)?;
            let seed = args.u64_or("seed", 1234)?;
            let mut q = QubitSpec::new("custom-qubit", m, chi, seed);
            if let Some(b) = args.f64_opt("bias")? {
                q.bias = b;
            }
            q.into()
        }
    };
    let out = PathBuf::from(args.req("out")?);
    let precision = StorePrecision::parse(&args.str_or("precision", "f16"))?;
    let codec = StoreCodec::parse(&args.str_or("codec", "raw"))?;
    args.finish()?;
    let t0 = std::time::Instant::now();
    let store = GammaStore::create(&out, spec.clone(), precision, codec)?;
    println!(
        "wrote {} {} sites (χ cap {}, d {}, {}) to {} in {} — {}",
        spec.m(),
        spec.tag(),
        spec.chi_cap(),
        spec.d(),
        precision.as_str(),
        out.display(),
        crate::util::human_secs(t0.elapsed().as_secs_f64()),
        crate::util::human_bytes(store.total_bytes()),
    );
    let plan = spec.chi_plan();
    println!(
        "dynamic χ: equi {} | step ratio {:.2}% | comp ratio {:.2}%",
        plan.equivalent_chi().round(),
        plan.step_ratio() * 100.0,
        plan.comp_ratio() * 100.0
    );
    Ok(())
}

fn config_from_args(args: &Args, store: &GammaStore) -> Result<RunConfig> {
    let mut cfg = RunConfig::new(store.spec.clone());
    cfg.n_samples = args.u64_or("samples", 4096)?;
    cfg.n1_macro = args.usize_or("n1", 1024)?;
    cfg.n2_micro = args.usize_or("n2", 256)?;
    cfg.p1 = args.usize_or("p1", 1)?;
    cfg.p2 = args.usize_or("p2", 1)?;
    cfg.gemm_threads = args.usize_or("threads", 1)?;
    cfg.gemm_split = crate::linalg::GemmSplit::parse(&args.str_or("gemm-split", "auto"))?;
    cfg.layout = crate::config::Layout::parse(&args.str_or("layout", "auto"))?;
    cfg.compute = ComputePrecision::parse(&args.str_or("compute", "f32"))?;
    cfg.scaling = ScalingMode::parse(&args.str_or("scaling", "per-sample"))?;
    cfg.engine = EngineKind::parse(&args.str_or("engine", "native"))?;
    cfg.net = NetPreset::parse(&args.str_or("net", "ideal"))
        .ok_or_else(|| Error::config("bad --net"))?;
    cfg.double_site = !args.flag("single-site");
    cfg.artifacts_dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
    cfg.disk_bw = args.f64_opt("disk-bw")?;
    cfg.store_precision = store.precision;
    Ok(cfg)
}

fn cmd_sample(args: &Args) -> Result<()> {
    let data = PathBuf::from(args.req("data")?);
    let store = Arc::new(GammaStore::open(&data)?);
    let cfg = config_from_args(args, &store)?;
    let scheme = args.str_or("scheme", "dp");
    let as_json = args.flag("json");
    args.finish()?;

    let report = match scheme.as_str() {
        "dp" => data_parallel::run(&cfg, &store, &[])?,
        "mp" => model_parallel::run(&cfg, &store)?,
        "tp" => tensor_parallel::run(&cfg, &store)?,
        s => return Err(Error::config(format!("unknown scheme '{s}' (dp|mp|tp)"))),
    };

    let mean = report.sink.mean_photons();
    let total_mean: f64 = mean.iter().sum();
    if as_json {
        let j = Json::obj(vec![
            ("scheme", Json::Str(scheme)),
            ("config", cfg.to_json()),
            ("wall_secs", Json::Num(report.wall)),
            ("virtual_secs", Json::Num(report.vtime)),
            ("dead_rows", Json::Num(report.dead_rows as f64)),
            ("total_mean_photons", Json::Num(total_mean)),
            ("metrics", report.metrics.to_json()),
        ]);
        println!("{}", j.pretty());
    } else {
        println!("scheme={scheme} {}", report.metrics.summary());
        println!(
            "wall={} virtual={} total⟨n⟩={:.4} dead_rows={}",
            crate::util::human_secs(report.wall),
            crate::util::human_secs(report.vtime),
            total_mean,
            report.dead_rows
        );
    }
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    let data = PathBuf::from(args.req("data")?);
    let store = Arc::new(GammaStore::open(&data)?);
    let mut cfg = config_from_args(args, &store)?;
    if args.str_opt("samples").is_none() {
        cfg.n_samples = 20_000;
    }
    let as_json = args.flag("json");
    args.finish()?;

    let report = data_parallel::run(&cfg, &store, &[])?;
    let mps = store.load_all()?;
    let v = crate::validate::validate(&mps, &report.sink)?;
    if as_json {
        let j = Json::obj(vec![
            ("first_order_slope", Json::Num(v.first_order_slope)),
            ("second_order_slope", Json::Num(v.second_order_slope)),
            ("first_order_max_err", Json::Num(v.first_order_max_err)),
            ("sites", Json::Num(v.sites as f64)),
            ("pairs", Json::Num(v.pairs as f64)),
            ("samples", Json::Num(cfg.n_samples as f64)),
        ]);
        println!("{}", j.pretty());
    } else {
        println!(
            "validation over {} samples: 1st-order slope {:.4} (ideal 1; paper 0.97), \
             2nd-order slope {:.4} (paper 0.96), max ⟨n⟩ err {:.4}",
            cfg.n_samples, v.first_order_slope, v.second_order_slope, v.first_order_max_err
        );
    }
    Ok(())
}

fn cmd_perf_model(args: &Args) -> Result<()> {
    let preset = Preset::parse(&args.str_or("preset", "bm288"))?;
    let gpus = args.usize_or("gpus", 8)?;
    let n1 = args.usize_or("n1", 100_000)?;
    args.finish()?;
    let spec = preset.full_spec(1);
    let w_fast = perfmodel::Workload {
        m: spec.m,
        chi: spec.chi_cap as u64,
        d: spec.d as u64,
        n_total: 10_000_000,
        n1: n1 as u64,
        scalar_bytes: 2,
    };
    let w_base = perfmodel::Workload {
        scalar_bytes: 8,
        ..w_fast
    };
    let net = NetPreset::InfinibandHdr.model();
    let t_mp = perfmodel::time_model_parallel(&w_base, &perfmodel::A100_FP64, &net);
    let t_dp = perfmodel::time_data_parallel(&w_fast, &perfmodel::A100_TF32, &net, gpus);
    let t_dp1 = perfmodel::time_data_parallel(&w_fast, &perfmodel::A100_TF32, &net, 1);
    println!(
        "preset {} (M={}, χ={}, d={}, N=10⁷, A100 constants)",
        preset.name(),
        spec.m,
        spec.chi_cap,
        spec.d
    );
    println!(
        "  baseline [19] model-parallel, {} GPUs (FP64):  {:8.1} min",
        spec.m,
        t_mp / 60.0
    );
    println!("  FastMPS data-parallel, 1 GPU (TF32+FP16 Γ): {:8.1} min", t_dp1 / 60.0);
    println!("  FastMPS data-parallel, {gpus} GPUs:              {:8.1} min", t_dp / 60.0);
    println!(
        "  memory/worker (Eq.3, complex64): {}",
        crate::util::human_bytes(perfmodel::memory_demand(
            w_fast.n1, w_fast.chi, w_fast.d, 4
        ))
    );
    println!(
        "  overlap N₁ threshold (§3.1): {}",
        perfmodel::min_macro_batch_for_overlap(&perfmodel::A100_TF32, 2)
    );
    Ok(())
}

fn cmd_bench_comm(args: &Args) -> Result<()> {
    let net = NetPreset::parse(&args.str_or("net", "nvlink3"))
        .ok_or_else(|| Error::config("bad --net"))?;
    let bytes = args.u64_or("bytes", 64 << 20)?;
    let p2 = args.usize_or("p2", 4)?;
    args.finish()?;
    let (t_ar, t_rs, prefer_double) = tensor_parallel::comm_bench(net, bytes, p2);
    println!(
        "{} @ {} over {p2} ranks: AllReduce {:.3} ms, ReduceScatter {:.3} ms → {} scheme",
        net.name(),
        crate::util::human_bytes(bytes),
        t_ar * 1e3,
        t_rs * 1e3,
        if prefer_double { "double-site" } else { "single-site" }
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let data = PathBuf::from(args.req("data")?);
    args.finish()?;
    let store = GammaStore::open(&data)?;
    let plan = store.spec.chi_plan();
    // GBS-specific knobs only exist on GBS stores.
    let extra = store
        .spec
        .as_gbs()
        .map(|g| format!(" asp={}", g.asp))
        .unwrap_or_default();
    println!(
        "{} [{}]: M={} d={} χcap={}{} precision={} codec={} bytes={}",
        store.spec.name(),
        store.spec.tag(),
        store.spec.m(),
        store.spec.d(),
        store.spec.chi_cap(),
        extra,
        store.precision.as_str(),
        store.codec.as_str(),
        crate::util::human_bytes(store.total_bytes())
    );
    println!(
        "χ plan: equi {:.0} | step {:.2}% | comp {:.2}% | params {}",
        plan.equivalent_chi(),
        plan.step_ratio() * 100.0,
        plan.comp_ratio() * 100.0,
        store
            .bonds
            .iter()
            .map(|&(l, r)| (l * r * store.spec.d()) as u64)
            .sum::<u64>()
    );
    Ok(())
}

fn service_config_from_args(args: &Args) -> Result<ServiceConfig> {
    let d = ServiceConfig::default();
    let target_batch = match args.str_opt("target-batch") {
        None => None,
        Some(v) => Some(v.parse().map_err(|_| {
            Error::config(format!("--target-batch: '{v}' is not an integer"))
        })?),
    };
    Ok(ServiceConfig {
        workers: args.usize_or("workers", d.workers)?,
        max_queue: args.usize_or("max-queue", d.max_queue)?,
        max_samples_per_job: args.u64_or("max-samples", d.max_samples_per_job)?,
        cache_entries: args.usize_or("cache", d.cache_entries)?,
        linger_ms: args.u64_or("linger-ms", d.linger_ms)?,
        poll_ms: args.u64_or("poll-ms", d.poll_ms)?,
        n2_micro: args.usize_or("n2", d.n2_micro)?,
        target_batch,
        compute: ComputePrecision::parse(&args.str_or("compute", "f32"))?,
        scaling: ScalingMode::parse(&args.str_or("scaling", "per-sample"))?,
        engine: EngineKind::parse(&args.str_or("engine", "native"))?,
        gemm_threads: args.usize_or("threads", d.gemm_threads)?,
        gemm_split: crate::linalg::GemmSplit::parse(&args.str_or("gemm-split", "auto"))?,
        layout: crate::config::Layout::parse(&args.str_or("layout", "auto"))?,
        prep_cache_bytes: args.u64_or("prep-mb", d.prep_cache_bytes >> 20)? << 20,
        disk_bw: args.f64_opt("disk-bw")?,
        artifacts_dir: PathBuf::from(args.str_or("artifacts", "artifacts")),
        trace_buf: args.usize_or("trace-buf", d.trace_buf)?,
        tp_step_timeout_ms: args.u64_or("tp-timeout-ms", d.tp_step_timeout_ms)?,
        ..d
    })
}

fn net_config_from_args(args: &Args, addr: String) -> Result<NetConfig> {
    let d = NetConfig::default();
    Ok(NetConfig {
        addr,
        max_conns: args.usize_or("max-conns", d.max_conns)?,
        max_frame_bytes: args.usize_or("frame-mb", d.max_frame_bytes >> 20)? << 20,
        read_timeout_ms: args.u64_or("read-timeout-ms", d.read_timeout_ms)?,
        write_timeout_ms: args.u64_or("write-timeout-ms", d.write_timeout_ms)?,
        push_dir: args.str_opt("push-dir").map(PathBuf::from),
        push_chunk_bytes: args.usize_or("chunk-kb", d.push_chunk_bytes >> 10)? << 10,
        push_staging_bytes: args.u64_or("staging-mb", d.push_staging_bytes >> 20)? << 20,
        telemetry_interval_ms: match args.f64_opt("telemetry-interval")? {
            Some(s) => (s * 1000.0).round() as u64,
            None => d.telemetry_interval_ms,
        },
        metrics_listen: args.str_opt("metrics-listen").map(String::from),
    })
}

/// Apply `--log-level` (overrides the `FASTMPS_LOG` environment variable).
fn apply_log_level(args: &Args) -> Result<()> {
    use crate::util::logging::{set_level, Level};
    if let Some(l) = args.str_opt("log-level") {
        set_level(match l {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "info" => Level::Info,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            other => {
                return Err(Error::config(format!(
                    "--log-level: '{other}' (error|warn|info|debug|trace)"
                )))
            }
        });
    }
    Ok(())
}

fn connect(addr: &str) -> Result<Client> {
    Client::connect(addr, &NetConfig::default())
}

fn cmd_serve(args: &Args) -> Result<()> {
    apply_log_level(args)?;
    if let Some(addr) = args.str_opt("listen").map(String::from) {
        return cmd_serve_net(args, addr);
    }
    let jobs_dir = PathBuf::from(args.req("jobs")?);
    let cfg = service_config_from_args(args)?;
    let mut opts = crate::service::api::ServeOptions::new(&jobs_dir);
    opts.poll_ms = cfg.poll_ms;
    opts.drain = args.flag("drain");
    opts.max_secs = args.f64_opt("max-seconds")?;
    let as_json = args.flag("json");
    args.finish()?;
    println!(
        "serving {} with {} workers (stop: touch {}/stop)",
        jobs_dir.display(),
        cfg.workers,
        jobs_dir.display()
    );
    let metrics = crate::service::api::serve(cfg, &opts)?;
    if as_json {
        println!("{}", metrics.pretty());
    } else {
        let rate = metrics
            .get("cache_hit_rate")
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0);
        let occ = metrics
            .get("batch_occupancy")
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0);
        println!(
            "served; cache hit rate {:.1}% | batch occupancy {:.1}% | metrics in {}/service_metrics.json",
            rate * 100.0,
            occ * 100.0,
            jobs_dir.display()
        );
    }
    Ok(())
}

fn cmd_serve_net(args: &Args, addr: String) -> Result<()> {
    let cfg = service_config_from_args(args)?;
    let net = net_config_from_args(args, addr)?;
    let max_secs = args.f64_opt("max-seconds")?;
    let as_json = args.flag("json");
    args.finish()?;
    let server = NetServer::start(cfg, net)?;
    let addr = server.local_addr();
    println!("listening on {addr} (stop: fastmps stop --connect {addr})");
    if let Some(m) = server.metrics_addr() {
        println!("prometheus exposition on http://{m}/metrics");
    }
    server.run_until_shutdown(max_secs);
    let metrics = server.shutdown();
    if as_json {
        println!("{}", metrics.pretty());
    } else {
        let counter = |k: &str| {
            metrics
                .get("net")
                .and_then(|n| n.get("counters"))
                .and_then(|c| c.get(k))
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0)
        };
        println!(
            "served on {addr}; {} conns | {} frames in / {} out | {} busy rejects",
            counter("net_conns"),
            counter("net_frames_in"),
            counter("net_frames_out"),
            counter("net_rejects_busy") + counter("net_rejects_conn"),
        );
    }
    Ok(())
}

fn router_config_from_args(args: &Args) -> Result<RouterConfig> {
    let d = RouterConfig::default();
    Ok(RouterConfig {
        backends: args.str_list("backend"),
        probe_interval_ms: args.u64_or("probe-ms", d.probe_interval_ms)?,
        degraded_after: args.u64_or("degraded-after", u64::from(d.degraded_after))? as u32,
        down_after: args.u64_or("down-after", u64::from(d.down_after))? as u32,
        retry_budget: args.usize_or("retry-budget", d.retry_budget)?,
        backoff_base_ms: args.u64_or("backoff-ms", d.backoff_base_ms)?,
        backoff_cap_ms: args.u64_or("backoff-cap-ms", d.backoff_cap_ms)?,
        jitter_ms: args.u64_or("jitter-ms", d.jitter_ms)?,
        drain_cap_secs: args.u64_or("drain-cap-s", d.drain_cap_secs)?,
        seed: args.u64_or("seed", d.seed)?,
        trace_buf: args.usize_or("trace-buf", d.trace_buf)?,
        shard_budget_bytes: args.u64_or("shard-budget-mb", d.shard_budget_bytes >> 20)? << 20,
    })
}

fn cmd_route(args: &Args) -> Result<()> {
    apply_log_level(args)?;
    let addr = args.req("listen")?.to_string();
    let cfg = router_config_from_args(args)?;
    let net = net_config_from_args(args, addr)?;
    let max_secs = args.f64_opt("max-seconds")?;
    let as_json = args.flag("json");
    args.finish()?;
    let router = Router::start(cfg, net)?;
    let addr = router.local_addr();
    println!(
        "routing on {addr} across {} backends (stop: fastmps stop --connect {addr})",
        router.health().len()
    );
    if let Some(m) = router.metrics_addr() {
        println!("prometheus exposition on http://{m}/metrics");
    }
    router.run_until_shutdown(max_secs);
    let metrics = router.shutdown();
    if as_json {
        println!("{}", metrics.pretty());
    } else {
        let counter = |k: &str| {
            metrics
                .get("run")
                .and_then(|r| r.get("counters"))
                .and_then(|c| c.get(k))
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0)
        };
        println!(
            "routed on {addr}; {} jobs placed | {} spillovers | {} busy rejects | {} dropped",
            counter("router_submits"),
            counter("router_spillovers"),
            counter("router_busy_rejects"),
            counter("router_dropped_jobs"),
        );
    }
    Ok(())
}

fn cmd_push(args: &Args) -> Result<()> {
    let addr = args.req("connect")?.to_string();
    let data = PathBuf::from(args.req("data")?);
    let d = NetConfig::default();
    let chunk = args.usize_or("chunk-kb", d.push_chunk_bytes >> 10)? << 10;
    let tp = args.usize_or("tp", 1)?;
    let as_json = args.flag("json");
    args.finish()?;
    if tp == 0 {
        return Err(Error::config("--tp: group size must be ≥ 1 (≥ 2 to shard)"));
    }
    if tp >= 2 {
        return push_sharded(&addr, &data, chunk, tp, as_json);
    }
    let t0 = std::time::Instant::now();
    let report = connect(&addr)?.push_store(&data, chunk)?;
    let secs = t0.elapsed().as_secs_f64();
    if as_json {
        let j = Json::obj(vec![
            ("key", Json::Str(format!("{:016x}", report.key))),
            ("dedup", Json::Bool(report.dedup)),
            ("chunks", Json::Num(report.chunks as f64)),
            ("raw_bytes", Json::Num(report.raw_bytes as f64)),
            ("wall_secs", Json::Num(secs)),
        ]);
        println!("{}", j.pretty());
    } else if report.dedup {
        println!(
            "{addr} already has this store — key {:016x} (deduplicated, nothing sent)",
            report.key
        );
    } else {
        let rate = if secs > 0.0 {
            (report.raw_bytes as f64 / secs) as u64
        } else {
            0
        };
        println!(
            "pushed {} as key {:016x}: {} in {} chunks over {} ({}/s)",
            data.display(),
            report.key,
            crate::util::human_bytes(report.raw_bytes),
            report.chunks,
            crate::util::human_secs(secs),
            crate::util::human_bytes(rate),
        );
        println!(
            "submit against it with: fastmps submit --connect {addr} --key {:016x} --samples N",
            report.key
        );
    }
    Ok(())
}

/// `push --tp N`: slice the store into `N` column shards (each a
/// self-contained FMPS1 store, see `GammaStore::write_shard`) in a
/// scratch directory, push every shard through the one connection, and
/// clean up. Through a router the shards spread across the fleet by
/// content-key affinity and their announced shard identity registers
/// the TP group (`docs/TENSOR_PARALLEL.md` § Group lifecycle).
fn push_sharded(addr: &str, data: &PathBuf, chunk: usize, of: usize, as_json: bool) -> Result<()> {
    let store = GammaStore::open(data)?;
    let base = crate::io::manifest_hash_at(data)?;
    let scratch = std::env::temp_dir().join(format!(
        "fastmps-push-tp-{}-{base:016x}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&scratch);
    let t0 = std::time::Instant::now();
    let mut reports = Vec::with_capacity(of);
    let outcome = (|| -> Result<()> {
        let mut client = connect(addr)?;
        for k in 0..of {
            let dir = scratch.join(format!("shard-{k:02}"));
            store.write_shard(&dir, k, of)?;
            reports.push(client.push_store(&dir, chunk)?);
        }
        Ok(())
    })();
    let _ = std::fs::remove_dir_all(&scratch);
    outcome?;
    let secs = t0.elapsed().as_secs_f64();
    if as_json {
        let shards = Json::Arr(
            reports
                .iter()
                .enumerate()
                .map(|(k, r)| {
                    Json::obj(vec![
                        ("index", Json::Num(k as f64)),
                        ("key", Json::Str(format!("{:016x}", r.key))),
                        ("dedup", Json::Bool(r.dedup)),
                        ("chunks", Json::Num(r.chunks as f64)),
                        ("raw_bytes", Json::Num(r.raw_bytes as f64)),
                    ])
                })
                .collect(),
        );
        let j = Json::obj(vec![
            ("base", Json::Str(format!("{base:016x}"))),
            ("of", Json::Num(of as f64)),
            ("shards", shards),
            ("wall_secs", Json::Num(secs)),
        ]);
        println!("{}", j.pretty());
    } else {
        for (k, r) in reports.iter().enumerate() {
            println!(
                "shard {k}/{of}: key {:016x}, {} in {} chunks{}",
                r.key,
                crate::util::human_bytes(r.raw_bytes),
                r.chunks,
                if r.dedup { " (deduplicated)" } else { "" },
            );
        }
        println!(
            "pushed {of} shards of {} (base {base:016x}) in {}",
            data.display(),
            crate::util::human_secs(secs),
        );
        println!(
            "submit against the group with: fastmps submit --connect {addr} --key {base:016x} --tp {of} --samples N"
        );
    }
    Ok(())
}

fn job_spec_from_args(args: &Args) -> Result<crate::service::JobSpec> {
    let samples: u64 = {
        let v = args.req("samples")?;
        v.parse()
            .map_err(|_| Error::config(format!("--samples: '{v}' is not an integer")))?
    };
    let mut spec = match (args.str_opt("key"), args.str_opt("data")) {
        (Some(k), _) => {
            let key = u64::from_str_radix(k, 16)
                .map_err(|_| Error::config(format!("--key: '{k}' is not a hex store key")))?;
            crate::service::JobSpec::by_key(key, samples)
        }
        (None, Some(d)) => crate::service::JobSpec::new(PathBuf::from(d), samples),
        (None, None) => return Err(Error::config("submit needs --data DIR or --key HEX")),
    };
    spec.sample_base = args.u64_or("sample-base", 0)?;
    spec.compute = match args.str_opt("compute") {
        None => None,
        Some(c) => Some(ComputePrecision::parse(c)?),
    };
    spec.tag = args.str_or("tag", "");
    // Unknown names die here with the valid set in the message, before
    // anything is sent (satisfying `submit --workload bogus` locally).
    spec.workload = WorkloadKind::parse(&args.str_or("workload", "gbs"))?;
    let tp = args.usize_or("tp", 1)?;
    if tp >= 2 {
        // A TP *request*: `of` and the full store's key; the router
        // resolves the peer list from its shard map.
        let Some(base) = spec.key else {
            return Err(Error::config(
                "--tp needs --key HEX naming the unsharded store (push its shards first)",
            ));
        };
        spec.tp = Some(crate::service::TpGroup {
            of: tp,
            base,
            peers: Vec::new(),
        });
    } else if tp == 0 {
        return Err(Error::config("--tp: group size must be ≥ 2"));
    }
    Ok(spec)
}

fn print_result(label: &str, result: &Json, as_json: bool) {
    if as_json {
        println!("{}", result.pretty());
        return;
    }
    let status = result
        .get("status")
        .and_then(|v| v.as_str())
        .unwrap_or("?");
    let mean = result.get("total_mean_photons").and_then(|v| v.as_f64());
    match (status, mean) {
        ("done", Some(m)) => println!("{label}: done, total⟨n⟩={m:.4}"),
        _ => println!(
            "{label}: {status}{}",
            result
                .get("error")
                .and_then(|v| v.as_str())
                .map(|e| format!(" ({e})"))
                .unwrap_or_default()
        ),
    }
}

fn cmd_submit(args: &Args) -> Result<()> {
    let connect_to = args.str_opt("connect").map(String::from);
    let spec = job_spec_from_args(args)?;
    let wait = args.flag("wait");
    let timeout = args.f64_opt("timeout-s")?.unwrap_or(300.0);
    let poll_ms = args.u64_or("poll-ms", 20)?;
    let as_json = args.flag("json");

    if let Some(addr) = connect_to {
        args.finish()?;
        let mut client = connect(&addr)?;
        let id = client.submit(&spec)?;
        if !wait {
            println!("submitted job {id} ({} samples) to {addr}", spec.n_samples);
            return Ok(());
        }
        let label = format!("job {id}");
        match client.wait(id, std::time::Duration::from_secs_f64(timeout))? {
            Some(res) => {
                print_result(&label, &res.result, as_json);
                if let (false, Some(sink)) = (as_json, &res.sink) {
                    println!(
                        "  streamed sample block: {} samples over {} sites",
                        sink.total_samples(),
                        sink.m
                    );
                }
            }
            None => println!("{label}: still running after {timeout}s"),
        }
        return Ok(());
    }

    let jobs_dir = PathBuf::from(args.req("jobs")?);
    args.finish()?;
    let stem = crate::service::api::submit_file(&jobs_dir, &spec)?;
    if !wait {
        println!("submitted {stem} ({} samples)", spec.n_samples);
        return Ok(());
    }
    let result = crate::service::api::wait_result_poll(
        &jobs_dir,
        &stem,
        std::time::Duration::from_secs_f64(timeout),
        poll_ms,
    )?;
    print_result(&stem, &result, as_json);
    Ok(())
}

fn cmd_jobs(args: &Args) -> Result<()> {
    let connect_to = args.str_opt("connect").map(String::from);
    let as_json = args.flag("json");
    if let Some(addr) = connect_to {
        args.finish()?;
        let listed = connect(&addr)?.list()?;
        if as_json {
            println!("{}", listed.pretty());
            return Ok(());
        }
        let jobs = listed.as_arr().unwrap_or(&[]);
        if jobs.is_empty() {
            println!("no jobs on {addr}");
            return Ok(());
        }
        for j in jobs {
            println!(
                "job {}  {}  {}  {}/{}",
                j.get("id").and_then(|v| v.as_f64()).unwrap_or(0.0),
                // Pre-workload servers don't report the column; every job
                // they run is GBS by construction.
                j.get("workload").and_then(|v| v.as_str()).unwrap_or("gbs"),
                j.get("status").and_then(|v| v.as_str()).unwrap_or("?"),
                j.get("done").and_then(|v| v.as_f64()).unwrap_or(0.0),
                j.get("samples").and_then(|v| v.as_f64()).unwrap_or(0.0),
            );
        }
        return Ok(());
    }
    let jobs_dir = PathBuf::from(args.req("jobs")?);
    args.finish()?;
    let jobs = crate::service::api::list_jobs(&jobs_dir)?;
    if as_json {
        let j = Json::Arr(jobs.iter().map(|(_, v)| v.clone()).collect());
        println!("{}", j.pretty());
        return Ok(());
    }
    if jobs.is_empty() {
        println!("no jobs under {}", jobs_dir.display());
        return Ok(());
    }
    for (stem, j) in jobs {
        println!(
            "{stem}  {}  {}  {}/{}",
            j.get("workload").and_then(|v| v.as_str()).unwrap_or("gbs"),
            j.get("status").and_then(|v| v.as_str()).unwrap_or("?"),
            j.get("done").and_then(|v| v.as_f64()).unwrap_or(0.0),
            j.get("samples").and_then(|v| v.as_f64()).unwrap_or(0.0),
        );
    }
    Ok(())
}

fn cmd_metrics(args: &Args) -> Result<()> {
    let addr = args.req("connect")?.to_string();
    let as_json = args.flag("json");
    args.finish()?;
    let metrics = connect(&addr)?.metrics()?;
    if as_json {
        // The machine-readable document; shape documented in
        // docs/OBSERVABILITY.md and validated by docs/metrics.schema.json.
        println!("{}", metrics.pretty());
        return Ok(());
    }
    println!("metrics from {addr}:");
    let run = metrics.get("run");
    if let Some(Json::Obj(counters)) = run.and_then(|r| r.get("counters")) {
        for (k, v) in counters {
            if let Some(n) = v.as_f64() {
                println!("  {k:<28} {n}");
            }
        }
    }
    if let Some(Json::Obj(hists)) = run.and_then(|r| r.get("hists")) {
        for (k, h) in hists {
            let g = |key: &str| h.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0);
            println!(
                "  {k:<28} n={} p50={:.2} ms p99={:.2} ms max={:.2} ms",
                g("count"),
                g("p50_secs") * 1e3,
                g("p99_secs") * 1e3,
                g("max_secs") * 1e3,
            );
        }
    }
    println!("  (full document: fastmps metrics --connect {addr} --json)");
    Ok(())
}

fn cmd_top(args: &Args) -> Result<()> {
    apply_log_level(args)?;
    let addr = args.req("connect")?.to_string();
    let interval = args.f64_opt("interval")?.unwrap_or(1.0).max(0.05);
    let once = args.flag("once");
    args.finish()?;
    let mut client = connect(&addr)?;
    loop {
        let reply = client.telemetry()?;
        let view = crate::telemetry::top::TopView::parse(&addr, &reply);
        let frame = crate::telemetry::top::render(&view);
        if once {
            print!("{frame}");
            return Ok(());
        }
        // Clear + home between frames; the frame itself carries no ANSI,
        // so --once output stays pipe- and test-friendly.
        print!("\x1b[2J\x1b[H{frame}");
        use std::io::Write;
        let _ = std::io::stdout().flush();
        std::thread::sleep(std::time::Duration::from_secs_f64(interval));
    }
}

fn cmd_trace(args: &Args) -> Result<()> {
    let addr = args.req("connect")?.to_string();
    let job: u64 = match args.pos(0) {
        Some(v) => v
            .parse()
            .map_err(|_| Error::config(format!("trace: '{v}' is not a job id")))?,
        None => args.u64_or("job", 0)?,
    };
    let trace = match args.str_opt("trace") {
        Some(s) => crate::trace::parse_trace_id(s)
            .ok_or_else(|| Error::config(format!("--trace: '{s}' is not a 16-hex trace id")))?,
        None => 0,
    };
    if job == 0 && trace == 0 {
        return Err(Error::config(
            "trace needs a job id (fastmps trace <job> --connect ADDR) or --trace HEX",
        ));
    }
    let chrome_out = args.str_opt("chrome").map(PathBuf::from);
    let as_json = args.flag("json");
    args.finish()?;
    let reply = connect(&addr)?.trace_events(job, trace)?;
    if let Some(path) = chrome_out {
        let j = crate::trace::chrome_trace(&reply);
        std::fs::write(&path, j.pretty()).map_err(|e| Error::io(path.display(), e))?;
        eprintln!(
            "wrote Chrome trace_event JSON to {} (load in chrome://tracing or Perfetto)",
            path.display()
        );
    }
    if as_json {
        println!("{}", reply.pretty());
    } else {
        print!("{}", crate::trace::render_human(&reply));
    }
    Ok(())
}

fn cmd_stop(args: &Args) -> Result<()> {
    let addr = args.req("connect")?.to_string();
    let timeout = args.f64_opt("timeout-s")?.unwrap_or(600.0);
    let as_json = args.flag("json");
    args.finish()?;
    let metrics = connect(&addr)?
        .shutdown_server(std::time::Duration::from_secs_f64(timeout))?;
    if as_json {
        println!("{}", metrics.pretty());
    } else {
        let jobs = metrics
            .get("run")
            .and_then(|r| r.get("counters"))
            .and_then(|c| c.get("jobs_completed"))
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0);
        println!("{addr} drained and stopped ({jobs} jobs completed)");
    }
    Ok(())
}

fn cmd_bench_service(args: &Args) -> Result<()> {
    let n_jobs = args.usize_or("n-jobs", 4)?;
    let samples = args.u64_or("samples", 2000)?;
    let out = args.str_opt("out").map(PathBuf::from);
    args.finish()?;
    let scratch = std::env::temp_dir().join(format!("fastmps-bench-svc-{}", std::process::id()));
    std::fs::create_dir_all(&scratch).map_err(|e| Error::io(scratch.display(), e))?;
    let j = crate::service::smoke_benchmark(&scratch, n_jobs, samples)?;
    let _ = std::fs::remove_dir_all(&scratch);
    println!("{}", j.pretty());
    if let Some(path) = out {
        std::fs::write(&path, j.pretty()).map_err(|e| Error::io(path.display(), e))?;
        eprintln!("wrote {}", path.display());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn help_runs() {
        run_cli(&argv("help")).unwrap();
        run_cli(&[]).unwrap();
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run_cli(&argv("frobnicate")).is_err());
    }

    #[test]
    fn gen_sample_validate_info_flow() {
        let dir = std::env::temp_dir().join(format!("fastmps-cli-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let d = dir.to_str().unwrap();
        run_cli(&argv(&format!(
            "gen-data --m 6 --chi 8 --d 3 --out {d} --decay 0 --sigma 0"
        )))
        .unwrap();
        run_cli(&argv(&format!(
            "info --data {d}"
        )))
        .unwrap();
        run_cli(&argv(&format!(
            "sample --data {d} --samples 64 --n1 32 --n2 16 --p1 2 --compute f64 --json"
        )))
        .unwrap();
        run_cli(&argv(&format!(
            "sample --data {d} --samples 32 --n1 32 --n2 16 --threads 2 \
             --gemm-split cols --compute f64"
        )))
        .unwrap();
        assert!(
            run_cli(&argv(&format!(
                "sample --data {d} --samples 32 --gemm-split diagonal"
            )))
            .is_err(),
            "bad --gemm-split must be rejected"
        );
        run_cli(&argv(&format!(
            "sample --data {d} --samples 32 --n1 32 --n2 16 --threads 2 \
             --layout planar --compute f32"
        )))
        .unwrap();
        run_cli(&argv(&format!(
            "sample --data {d} --samples 32 --n1 32 --n2 16 --layout interleaved"
        )))
        .unwrap();
        assert!(
            run_cli(&argv(&format!(
                "sample --data {d} --samples 32 --layout diagonal"
            )))
            .is_err(),
            "bad --layout must be rejected"
        );
        run_cli(&argv(&format!(
            "sample --data {d} --samples 32 --n1 32 --n2 32 --scheme mp --compute f64"
        )))
        .unwrap();
        run_cli(&argv(&format!(
            "sample --data {d} --samples 32 --n1 32 --n2 32 --scheme tp --p2 2 --compute f64"
        )))
        .unwrap();
        run_cli(&argv(&format!(
            "validate --data {d} --samples 2000 --n1 500 --n2 250 --compute f64"
        )))
        .unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn qubit_gen_info_sample_flow() {
        let dir = std::env::temp_dir().join(format!("fastmps-cli-q-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let d = dir.to_str().unwrap();
        run_cli(&argv(&format!(
            "gen-data --workload qubit --m 6 --chi 8 --out {d}"
        )))
        .unwrap();
        run_cli(&argv(&format!("info --data {d}"))).unwrap();
        run_cli(&argv(&format!(
            "sample --data {d} --samples 64 --n1 32 --n2 16 --compute f64 --json"
        )))
        .unwrap();
        // GBS-only generator knobs are rejected on the qubit path.
        assert!(run_cli(&argv(&format!(
            "gen-data --workload qubit --m 4 --chi 4 --sigma 0.5 --out {d}"
        )))
        .is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn unknown_workload_rejected_with_valid_names() {
        // Dies locally in arg parsing — no server involved.
        let e = run_cli(&argv(
            "submit --connect 127.0.0.1:1 --key ff --samples 5 --workload ising",
        ))
        .unwrap_err()
        .to_string();
        assert!(e.contains("unknown workload"), "{e}");
        assert!(e.contains("gbs, qubit"), "{e}");
        let e = run_cli(&argv("gen-data --workload ising --out /tmp/x"))
            .unwrap_err()
            .to_string();
        assert!(e.contains("valid workloads"), "{e}");
    }

    #[test]
    fn serve_submit_jobs_cli_round_trip() {
        let root = std::env::temp_dir().join(format!("fastmps-cli-svc-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        std::fs::create_dir_all(&root).unwrap();
        let store = root.join("store");
        let jobs = root.join("jobs");
        run_cli(&argv(&format!(
            "gen-data --m 5 --chi 8 --d 3 --out {} --decay 0 --sigma 0",
            store.display()
        )))
        .unwrap();
        let serve_args = argv(&format!(
            "serve --jobs {} --workers 2 --n2 32 --target-batch 128 --compute f64 \
             --poll-ms 5 --linger-ms 2 --drain --max-seconds 60",
            jobs.display()
        ));
        let server = std::thread::spawn(move || run_cli(&serve_args));
        run_cli(&argv(&format!(
            "submit --jobs {} --data {} --samples 64 --wait --timeout-s 60 --json",
            jobs.display(),
            store.display()
        )))
        .unwrap();
        server.join().unwrap().unwrap();
        run_cli(&argv(&format!("jobs --jobs {}", jobs.display()))).unwrap();
        assert!(jobs.join("service_metrics.json").exists());
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn net_cli_commands_round_trip() {
        let root = std::env::temp_dir().join(format!("fastmps-cli-net-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        std::fs::create_dir_all(&root).unwrap();
        let store = root.join("store");
        run_cli(&argv(&format!(
            "gen-data --m 5 --chi 8 --d 3 --out {} --decay 0 --sigma 0",
            store.display()
        )))
        .unwrap();
        let cfg = ServiceConfig {
            workers: 2,
            n2_micro: 32,
            target_batch: Some(128),
            compute: ComputePrecision::F64,
            linger_ms: 2,
            ..Default::default()
        };
        let net = NetConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        };
        let server = NetServer::start(cfg, net).unwrap();
        let addr = server.local_addr().to_string();
        run_cli(&argv(&format!(
            "submit --connect {addr} --data {} --samples 64 --wait --timeout-s 60 --json",
            store.display()
        )))
        .unwrap();
        run_cli(&argv(&format!("jobs --connect {addr}"))).unwrap();
        run_cli(&argv(&format!("metrics --connect {addr}"))).unwrap();
        run_cli(&argv(&format!("metrics --connect {addr} --json"))).unwrap();
        // One dashboard frame over the telemetry ring (no ANSI in --once).
        run_cli(&argv(&format!("top --connect {addr} --once"))).unwrap();
        // The flight recorder is on by default: the job's timeline
        // replays in human form and exports as valid Chrome JSON.
        run_cli(&argv(&format!("trace 1 --connect {addr}"))).unwrap();
        let chrome = root.join("trace.json");
        run_cli(&argv(&format!(
            "trace 1 --connect {addr} --chrome {}",
            chrome.display()
        )))
        .unwrap();
        let cj = Json::parse(&std::fs::read_to_string(&chrome).unwrap()).unwrap();
        assert!(
            !cj.get("traceEvents").unwrap().as_arr().unwrap().is_empty(),
            "chrome export should carry the job's events"
        );
        assert!(
            run_cli(&argv(&format!("trace --connect {addr}"))).is_err(),
            "trace without a job or trace id is a usage error"
        );
        run_cli(&argv(&format!("stop --connect {addr}"))).unwrap();
        assert!(server.shutdown_requested());
        drop(server);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn router_cli_round_trip() {
        let root = std::env::temp_dir().join(format!("fastmps-cli-route-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        std::fs::create_dir_all(&root).unwrap();
        let store = root.join("store");
        run_cli(&argv(&format!(
            "gen-data --m 5 --chi 8 --d 3 --out {} --decay 0 --sigma 0",
            store.display()
        )))
        .unwrap();
        let backend_cfg = || ServiceConfig {
            workers: 2,
            n2_micro: 32,
            target_batch: Some(128),
            compute: ComputePrecision::F64,
            linger_ms: 2,
            ..Default::default()
        };
        let net0 = NetConfig {
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        };
        let b1 = NetServer::start(backend_cfg(), net0.clone()).unwrap();
        let b2 = NetServer::start(backend_cfg(), net0.clone()).unwrap();
        let rcfg = RouterConfig {
            backends: vec![b1.local_addr().to_string(), b2.local_addr().to_string()],
            probe_interval_ms: 50,
            ..Default::default()
        };
        let router = Router::start(rcfg, net0).unwrap();
        let addr = router.local_addr().to_string();
        run_cli(&argv(&format!(
            "submit --connect {addr} --data {} --samples 64 --wait --timeout-s 60 --json",
            store.display()
        )))
        .unwrap();
        run_cli(&argv(&format!("jobs --connect {addr}"))).unwrap();
        run_cli(&argv(&format!("metrics --connect {addr}"))).unwrap();
        // Stitched router+backend timeline through the same subcommand.
        run_cli(&argv(&format!("trace 1 --connect {addr}"))).unwrap();
        run_cli(&argv(&format!("stop --connect {addr}"))).unwrap();
        assert!(router.shutdown_requested());
        drop(router);
        drop(b1);
        drop(b2);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn route_requires_backends() {
        assert!(run_cli(&argv("route --listen 127.0.0.1:0")).is_err());
    }

    #[test]
    fn bad_log_level_rejected() {
        // apply_log_level runs before any socket is dialed, so this fails
        // fast with a config error, not a connect error.
        assert!(run_cli(&argv("top --connect 127.0.0.1:1 --log-level silly")).is_err());
    }

    #[test]
    fn push_cli_round_trip_and_key_submit() {
        let root = std::env::temp_dir().join(format!("fastmps-cli-push-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        std::fs::create_dir_all(&root).unwrap();
        let store = root.join("store");
        run_cli(&argv(&format!(
            "gen-data --m 5 --chi 8 --d 3 --out {} --decay 0 --sigma 0",
            store.display()
        )))
        .unwrap();
        let cfg = ServiceConfig {
            workers: 2,
            n2_micro: 32,
            target_batch: Some(128),
            compute: ComputePrecision::F64,
            linger_ms: 2,
            ..Default::default()
        };
        let net = NetConfig {
            addr: "127.0.0.1:0".into(),
            push_dir: Some(root.join("pushed")),
            ..Default::default()
        };
        let server = NetServer::start(cfg, net).unwrap();
        let addr = server.local_addr().to_string();
        run_cli(&argv(&format!(
            "push --connect {addr} --data {} --chunk-kb 2 --json",
            store.display()
        )))
        .unwrap();
        let key = crate::io::manifest_hash_at(&store).unwrap();
        run_cli(&argv(&format!(
            "submit --connect {addr} --key {key:016x} --samples 32 --wait --timeout-s 60 --json"
        )))
        .unwrap();
        // Second push dedups (exercises the dedup print path).
        run_cli(&argv(&format!(
            "push --connect {addr} --data {}",
            store.display()
        )))
        .unwrap();
        drop(server);
        std::fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn submit_requires_data_or_key() {
        assert!(run_cli(&argv("submit --connect 127.0.0.1:1 --samples 5")).is_err());
        assert!(run_cli(&argv(
            "submit --connect 127.0.0.1:1 --key not-hex --samples 5"
        ))
        .is_err());
    }

    #[test]
    fn bench_service_emits_kpi_json() {
        let out = std::env::temp_dir().join(format!(
            "fastmps-cli-benchsvc-{}.json",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&out);
        run_cli(&argv(&format!(
            "bench-service --n-jobs 2 --samples 100 --out {}",
            out.display()
        )))
        .unwrap();
        let j = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        assert_eq!(j.get("jobs").unwrap().as_f64(), Some(2.0));
        std::fs::remove_file(&out).unwrap();
    }

    #[test]
    fn perf_model_and_bench_comm_run() {
        run_cli(&argv("perf-model --preset jiuzhang2 --gpus 8")).unwrap();
        run_cli(&argv("bench-comm --net nvlink3 --p2 4")).unwrap();
    }

    #[test]
    fn bad_scheme_rejected() {
        let dir = std::env::temp_dir().join(format!("fastmps-cli2-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let d = dir.to_str().unwrap();
        run_cli(&argv(&format!("gen-data --m 4 --chi 4 --out {d}"))).unwrap();
        assert!(run_cli(&argv(&format!(
            "sample --data {d} --scheme bogus"
        )))
        .is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
