//! Subcommand implementations.

use std::path::PathBuf;
use std::sync::Arc;

use super::args::Args;
use crate::comm::NetPreset;
use crate::config::{ComputePrecision, EngineKind, Preset, RunConfig, ScalingMode};
use crate::coordinator::{data_parallel, model_parallel, tensor_parallel};
use crate::io::{GammaStore, StoreCodec, StorePrecision};
use crate::mps::gbs::GbsSpec;
use crate::perfmodel;
use crate::util::error::{Error, Result};
use crate::util::json::Json;

const HELP: &str = "fastmps — multi-level parallel MPS sampling (FastMPS reproduction)

USAGE: fastmps <command> [--options]

COMMANDS:
  gen-data    Generate a synthetic GBS MPS store
              --preset <jiuzhang2|jiuzhang3h|bm216h|bm288|m8176> | --m/--chi/--d/--asp
              --out DIR [--precision f64|f32|f16] [--codec raw|zstd]
              [--seed N] [--full-scale] [--fixed-chi] [--decay K] [--sigma S]
  sample      Run the sampler on a store
              --data DIR --samples N [--scheme dp|mp|tp] [--engine xla|native]
              [--p1 N] [--p2 N] [--single-site] [--n1 N] [--n2 N]
              [--compute f64|f32|tf32] [--scaling per-sample|global|none]
              [--net nvlink3|pcie4|ib|tianhe3|sunway|ideal] [--disk-bw BPS]
              [--artifacts DIR] [--json]
  validate    Sample + compare against exact marginals (Fig. 9)
              --data DIR [--samples N] [--engine ...] [--json]
  perf-model  Paper-scale analytic predictions (Tables 2/3 shape)
              [--preset P] [--gpus N] [--n1 N]
  bench-comm  AllReduce vs ReduceScatter decision probe (§4.3)
              [--net P] [--bytes B] [--p2 N]
  info        Describe a store
              --data DIR
  help        This text
";

pub fn run_cli(argv: &[String]) -> Result<()> {
    if argv.is_empty() || argv[0] == "help" || argv[0] == "--help" {
        println!("{HELP}");
        return Ok(());
    }
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "gen-data" => cmd_gen_data(&args),
        "sample" => cmd_sample(&args),
        "validate" => cmd_validate(&args),
        "perf-model" => cmd_perf_model(&args),
        "bench-comm" => cmd_bench_comm(&args),
        "info" => cmd_info(&args),
        other => Err(Error::config(format!(
            "unknown command '{other}' (try 'fastmps help')"
        ))),
    }
}

fn spec_from_args(args: &Args) -> Result<GbsSpec> {
    let seed = args.u64_or("seed", 1234)?;
    let mut spec = match args.str_opt("preset") {
        Some(p) => {
            let preset = Preset::parse(p)?;
            if args.flag("full-scale") {
                preset.full_spec(seed)
            } else {
                preset.scaled_spec(seed)
            }
        }
        None => {
            let m = args.usize_or("m", 64)?;
            let chi = args.usize_or("chi", 64)?;
            let d = args.usize_or("d", 3)?;
            GbsSpec {
                name: "custom".into(),
                m,
                d,
                chi_cap: chi,
                asp: 4.0,
                decay_k: 0.1,
                displacement_sigma: 0.3,
            branch_skew: 0.0,
                seed,
                dynamic_chi: true,
                step_ratio_override: None,
            }
        }
    };
    if let Some(asp) = args.f64_opt("asp")? {
        spec.asp = asp;
        spec.step_ratio_override = None;
    }
    if let Some(k) = args.f64_opt("decay")? {
        spec.decay_k = k;
    }
    if let Some(s) = args.f64_opt("sigma")? {
        spec.displacement_sigma = s;
    }
    if args.flag("fixed-chi") {
        spec.dynamic_chi = false;
    }
    Ok(spec)
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    let spec = spec_from_args(args)?;
    let out = PathBuf::from(args.req("out")?);
    let precision = StorePrecision::parse(&args.str_or("precision", "f16"))?;
    let codec = StoreCodec::parse(&args.str_or("codec", "raw"))?;
    args.finish()?;
    let t0 = std::time::Instant::now();
    let store = GammaStore::create(&out, &spec, precision, codec)?;
    println!(
        "wrote {} sites (χ cap {}, d {}, {}) to {} in {} — {}",
        spec.m,
        spec.chi_cap,
        spec.d,
        precision.as_str(),
        out.display(),
        crate::util::human_secs(t0.elapsed().as_secs_f64()),
        crate::util::human_bytes(store.total_bytes()),
    );
    let plan = spec.chi_plan();
    println!(
        "dynamic χ: equi {} | step ratio {:.2}% | comp ratio {:.2}%",
        plan.equivalent_chi().round(),
        plan.step_ratio() * 100.0,
        plan.comp_ratio() * 100.0
    );
    Ok(())
}

fn config_from_args(args: &Args, store: &GammaStore) -> Result<RunConfig> {
    let mut cfg = RunConfig::new(store.spec.clone());
    cfg.n_samples = args.u64_or("samples", 4096)?;
    cfg.n1_macro = args.usize_or("n1", 1024)?;
    cfg.n2_micro = args.usize_or("n2", 256)?;
    cfg.p1 = args.usize_or("p1", 1)?;
    cfg.p2 = args.usize_or("p2", 1)?;
    cfg.gemm_threads = args.usize_or("threads", 1)?;
    cfg.compute = ComputePrecision::parse(&args.str_or("compute", "f32"))?;
    cfg.scaling = ScalingMode::parse(&args.str_or("scaling", "per-sample"))?;
    cfg.engine = EngineKind::parse(&args.str_or("engine", "native"))?;
    cfg.net = NetPreset::parse(&args.str_or("net", "ideal"))
        .ok_or_else(|| Error::config("bad --net"))?;
    cfg.double_site = !args.flag("single-site");
    cfg.artifacts_dir = PathBuf::from(args.str_or("artifacts", "artifacts"));
    cfg.disk_bw = args.f64_opt("disk-bw")?;
    cfg.store_precision = store.precision;
    Ok(cfg)
}

fn cmd_sample(args: &Args) -> Result<()> {
    let data = PathBuf::from(args.req("data")?);
    let store = Arc::new(GammaStore::open(&data)?);
    let cfg = config_from_args(args, &store)?;
    let scheme = args.str_or("scheme", "dp");
    let as_json = args.flag("json");
    args.finish()?;

    let report = match scheme.as_str() {
        "dp" => data_parallel::run(&cfg, &store, &[])?,
        "mp" => model_parallel::run(&cfg, &store)?,
        "tp" => tensor_parallel::run(&cfg, &store)?,
        s => return Err(Error::config(format!("unknown scheme '{s}' (dp|mp|tp)"))),
    };

    let mean = report.sink.mean_photons();
    let total_mean: f64 = mean.iter().sum();
    if as_json {
        let j = Json::obj(vec![
            ("scheme", Json::Str(scheme)),
            ("config", cfg.to_json()),
            ("wall_secs", Json::Num(report.wall)),
            ("virtual_secs", Json::Num(report.vtime)),
            ("dead_rows", Json::Num(report.dead_rows as f64)),
            ("total_mean_photons", Json::Num(total_mean)),
            ("metrics", report.metrics.to_json()),
        ]);
        println!("{}", j.pretty());
    } else {
        println!("scheme={scheme} {}", report.metrics.summary());
        println!(
            "wall={} virtual={} total⟨n⟩={:.4} dead_rows={}",
            crate::util::human_secs(report.wall),
            crate::util::human_secs(report.vtime),
            total_mean,
            report.dead_rows
        );
    }
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    let data = PathBuf::from(args.req("data")?);
    let store = Arc::new(GammaStore::open(&data)?);
    let mut cfg = config_from_args(args, &store)?;
    if args.str_opt("samples").is_none() {
        cfg.n_samples = 20_000;
    }
    let as_json = args.flag("json");
    args.finish()?;

    let report = data_parallel::run(&cfg, &store, &[])?;
    let mps = store.load_all()?;
    let v = crate::validate::validate(&mps, &report.sink)?;
    if as_json {
        let j = Json::obj(vec![
            ("first_order_slope", Json::Num(v.first_order_slope)),
            ("second_order_slope", Json::Num(v.second_order_slope)),
            ("first_order_max_err", Json::Num(v.first_order_max_err)),
            ("sites", Json::Num(v.sites as f64)),
            ("pairs", Json::Num(v.pairs as f64)),
            ("samples", Json::Num(cfg.n_samples as f64)),
        ]);
        println!("{}", j.pretty());
    } else {
        println!(
            "validation over {} samples: 1st-order slope {:.4} (ideal 1; paper 0.97), \
             2nd-order slope {:.4} (paper 0.96), max ⟨n⟩ err {:.4}",
            cfg.n_samples, v.first_order_slope, v.second_order_slope, v.first_order_max_err
        );
    }
    Ok(())
}

fn cmd_perf_model(args: &Args) -> Result<()> {
    let preset = Preset::parse(&args.str_or("preset", "bm288"))?;
    let gpus = args.usize_or("gpus", 8)?;
    let n1 = args.usize_or("n1", 100_000)?;
    args.finish()?;
    let spec = preset.full_spec(1);
    let w_fast = perfmodel::Workload {
        m: spec.m,
        chi: spec.chi_cap as u64,
        d: 4,
        n_total: 10_000_000,
        n1: n1 as u64,
        scalar_bytes: 2,
    };
    let w_base = perfmodel::Workload {
        scalar_bytes: 8,
        ..w_fast
    };
    let net = NetPreset::InfinibandHdr.model();
    let t_mp = perfmodel::time_model_parallel(&w_base, &perfmodel::A100_FP64, &net);
    let t_dp = perfmodel::time_data_parallel(&w_fast, &perfmodel::A100_TF32, &net, gpus);
    let t_dp1 = perfmodel::time_data_parallel(&w_fast, &perfmodel::A100_TF32, &net, 1);
    println!("preset {} (M={}, χ=10⁴, d=4, N=10⁷, A100 constants)", preset.name(), spec.m);
    println!(
        "  baseline [19] model-parallel, {} GPUs (FP64):  {:8.1} min",
        spec.m,
        t_mp / 60.0
    );
    println!("  FastMPS data-parallel, 1 GPU (TF32+FP16 Γ): {:8.1} min", t_dp1 / 60.0);
    println!("  FastMPS data-parallel, {gpus} GPUs:              {:8.1} min", t_dp / 60.0);
    println!(
        "  memory/worker (Eq.3, complex64): {}",
        crate::util::human_bytes(perfmodel::memory_demand(
            w_fast.n1, w_fast.chi, w_fast.d, 4
        ))
    );
    println!(
        "  overlap N₁ threshold (§3.1): {}",
        perfmodel::min_macro_batch_for_overlap(&perfmodel::A100_TF32, 2)
    );
    Ok(())
}

fn cmd_bench_comm(args: &Args) -> Result<()> {
    let net = NetPreset::parse(&args.str_or("net", "nvlink3"))
        .ok_or_else(|| Error::config("bad --net"))?;
    let bytes = args.u64_or("bytes", 64 << 20)?;
    let p2 = args.usize_or("p2", 4)?;
    args.finish()?;
    let (t_ar, t_rs, prefer_double) = tensor_parallel::comm_bench(net, bytes, p2);
    println!(
        "{} @ {} over {p2} ranks: AllReduce {:.3} ms, ReduceScatter {:.3} ms → {} scheme",
        net.name(),
        crate::util::human_bytes(bytes),
        t_ar * 1e3,
        t_rs * 1e3,
        if prefer_double { "double-site" } else { "single-site" }
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let data = PathBuf::from(args.req("data")?);
    args.finish()?;
    let store = GammaStore::open(&data)?;
    let plan = store.spec.chi_plan();
    println!(
        "{}: M={} d={} χcap={} asp={} precision={} codec={} bytes={}",
        store.spec.name,
        store.spec.m,
        store.spec.d,
        store.spec.chi_cap,
        store.spec.asp,
        store.precision.as_str(),
        store.codec.as_str(),
        crate::util::human_bytes(store.total_bytes())
    );
    println!(
        "χ plan: equi {:.0} | step {:.2}% | comp {:.2}% | params {}",
        plan.equivalent_chi(),
        plan.step_ratio() * 100.0,
        plan.comp_ratio() * 100.0,
        store
            .bonds
            .iter()
            .map(|&(l, r)| (l * r * store.spec.d) as u64)
            .sum::<u64>()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn help_runs() {
        run_cli(&argv("help")).unwrap();
        run_cli(&[]).unwrap();
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run_cli(&argv("frobnicate")).is_err());
    }

    #[test]
    fn gen_sample_validate_info_flow() {
        let dir = std::env::temp_dir().join(format!("fastmps-cli-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let d = dir.to_str().unwrap();
        run_cli(&argv(&format!(
            "gen-data --m 6 --chi 8 --d 3 --out {d} --decay 0 --sigma 0"
        )))
        .unwrap();
        run_cli(&argv(&format!(
            "info --data {d}"
        )))
        .unwrap();
        run_cli(&argv(&format!(
            "sample --data {d} --samples 64 --n1 32 --n2 16 --p1 2 --compute f64 --json"
        )))
        .unwrap();
        run_cli(&argv(&format!(
            "sample --data {d} --samples 32 --n1 32 --n2 32 --scheme mp --compute f64"
        )))
        .unwrap();
        run_cli(&argv(&format!(
            "sample --data {d} --samples 32 --n1 32 --n2 32 --scheme tp --p2 2 --compute f64"
        )))
        .unwrap();
        run_cli(&argv(&format!(
            "validate --data {d} --samples 2000 --n1 500 --n2 250 --compute f64"
        )))
        .unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn perf_model_and_bench_comm_run() {
        run_cli(&argv("perf-model --preset jiuzhang2 --gpus 8")).unwrap();
        run_cli(&argv("bench-comm --net nvlink3 --p2 4")).unwrap();
    }

    #[test]
    fn bad_scheme_rejected() {
        let dir = std::env::temp_dir().join(format!("fastmps-cli2-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let d = dir.to_str().unwrap();
        run_cli(&argv(&format!("gen-data --m 4 --chi 4 --out {d}"))).unwrap();
        assert!(run_cli(&argv(&format!(
            "sample --data {d} --scheme bogus"
        )))
        .is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
