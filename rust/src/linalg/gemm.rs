//! Blocked, multi-threaded complex GEMM and the MPS bond contraction.
//!
//! The native engine must be fast enough to make the CPU-scaled paper
//! experiments (Table 3, Figs. 10/12) meaningful, so the kernel is cache
//! blocked (MC×KC panels), accumulates in registers across an unrolled k
//! loop, and splits work across scoped threads along one of two axes:
//!
//! - **row split** — partition C's rows (the sample axis N). Best when
//!   N ≥ threads: each thread streams its own disjoint C panel.
//! - **column split** — partition C's columns (the bond axis χ_r·d, the
//!   paper's tensor-parallel axis). When N is small and χ is huge a row
//!   split leaves most threads idle; the column split keeps them all busy
//!   on disjoint column stripes of every row.
//!
//! [`GemmSplit::Auto`] picks between them with a utilization heuristic
//! (see [`choose_split`]); both splits produce bit-identical results to
//! the single-threaded kernel because every C element is accumulated by
//! exactly one thread in the same k order. FLOP counts follow the paper's
//! convention: one complex MAC = 8 real FLOPs.
//!
//! Two kernel families share that discipline:
//!
//! - **interleaved** (`Complex<T>` AoS) — the original path, always
//!   available;
//! - **planar** (split re/im planes, [`PlanarScalar`]) — the SIMD hot
//!   path: the innermost loops are straight-line real FMA chains the
//!   compiler autovectorizes, or the explicit AVX2/NEON microkernels
//!   behind the `simd` feature. Bit-identical to interleaved because each
//!   lane evaluates the exact `Complex::mul_add` association.
//!
//! Threading goes through [`Exec`]: per-call scoped spawns or the
//! resident [`pool::WorkerPool`](super::pool::WorkerPool); the partition
//! arithmetic lives in one place (`dispatch_regions`) so the variants
//! cannot drift.

use crate::util::num::Float;

use super::pool::Exec;
use crate::tensor::{Complex, Mat, MatRef, PlanarMat, PlanarMatRef, PlanarTensor3, Tensor3};
use crate::util::error::{Error, Result};

/// Real FLOPs of an (m,k)×(k,n) complex GEMM (8 per complex MAC).
pub fn matmul_flops(m: usize, k: usize, n: usize) -> u64 {
    8 * m as u64 * k as u64 * n as u64
}

const MC: usize = 64; // row block
const KC: usize = 256; // depth block

/// Minimum columns per thread before a column split is worth the extra
/// passes over A (each stripe re-reads every A row).
const COL_MIN: usize = 16;

/// Which axis of C the threaded GEMM partitions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum GemmSplit {
    /// Pick per call from the shape (see [`choose_split`]).
    #[default]
    Auto,
    /// Always split C's rows (the sample axis).
    Rows,
    /// Always split C's columns (the bond axis — tensor-parallel style).
    Cols,
}

impl GemmSplit {
    pub fn as_str(self) -> &'static str {
        match self {
            GemmSplit::Auto => "auto",
            GemmSplit::Rows => "rows",
            GemmSplit::Cols => "cols",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "auto" => Ok(GemmSplit::Auto),
            "rows" => Ok(GemmSplit::Rows),
            "cols" | "bond" => Ok(GemmSplit::Cols),
            _ => Err(Error::config(format!(
                "unknown gemm split '{s}' (auto|rows|cols)"
            ))),
        }
    }
}

/// Resolve `Auto` for an (m × n) output on `threads` threads: prefer the
/// row split whenever it can occupy every thread (better A/C locality);
/// fall back to the bond split when rows are scarce but the bond axis is
/// wide enough to give each thread a ≥ [`COL_MIN`]-column stripe.
pub fn choose_split(split: GemmSplit, m: usize, n: usize, threads: usize) -> GemmSplit {
    match split {
        GemmSplit::Auto => {
            if m >= threads || n < threads * COL_MIN {
                GemmSplit::Rows
            } else {
                GemmSplit::Cols
            }
        }
        s => s,
    }
}

/// C ← A·B (complex). Single allocation; errors on shape mismatch.
pub fn gemm<T: Float + std::ops::AddAssign + Send + Sync>(
    a: &Mat<T>,
    b: &Mat<T>,
    threads: usize,
) -> Result<Mat<T>> {
    if a.cols != b.rows {
        return Err(Error::shape(format!(
            "gemm: ({},{})×({},{})",
            a.rows, a.cols, b.rows, b.cols
        )));
    }
    let mut c = Mat::zeros(a.rows, b.cols);
    gemm_acc(a, b, &mut c, threads)?;
    Ok(c)
}

/// C += A·B (complex), blocked and threaded over row panels (or column
/// stripes when the auto heuristic prefers the bond axis).
pub fn gemm_acc<T: Float + std::ops::AddAssign + Send + Sync>(
    a: &Mat<T>,
    b: &Mat<T>,
    c: &mut Mat<T>,
    threads: usize,
) -> Result<()> {
    gemm_acc_split(a.view(), b.view(), c, threads, GemmSplit::Auto)
}

/// C += A·B over borrowed views, with an explicit split policy. The core
/// kernel of the hot path: zero allocation when `threads == 1`.
pub fn gemm_acc_split<T: Float + std::ops::AddAssign + Send + Sync>(
    a: MatRef<'_, T>,
    b: MatRef<'_, T>,
    c: &mut Mat<T>,
    threads: usize,
    split: GemmSplit,
) -> Result<()> {
    gemm_acc_split_on(a, b, c, Exec::Scoped(threads), split)
}

/// [`gemm_acc_split`] on an explicit executor — the pooled form is what
/// the resident engines use so threaded steps stop paying per-call spawn
/// bookkeeping.
pub fn gemm_acc_split_on<T: Float + std::ops::AddAssign + Send + Sync>(
    a: MatRef<'_, T>,
    b: MatRef<'_, T>,
    c: &mut Mat<T>,
    exec: Exec<'_>,
    split: GemmSplit,
) -> Result<()> {
    check_gemm_shapes(a.rows, a.cols, b.rows, b.cols, c.rows, c.cols, c.data.len())?;
    let m = a.rows;
    let n = b.cols;
    if m == 0 || n == 0 {
        return Ok(());
    }
    let c_ptr = SendPtr(c.data.as_mut_ptr());
    // Safety: `c` is exclusively borrowed; dispatch_regions hands each
    // part a disjoint region of it and joins before returning.
    dispatch_regions(exec, split, m, n, |r0, r1, j0, j1| unsafe {
        kernel_blocked(a, b, c_ptr, r0, r1 - r0, j0, j1)
    });
    Ok(())
}

/// C ← A·B (β=0 overwrite): the same kernels and k order as the
/// accumulate form, but C's prior contents are ignored — callers drop
/// their zero-fill pass. Bit-identical to zero-fill + [`gemm_acc_split_on`]
/// including rows whose every `av == 0` skip fires (such rows are filled
/// with the same `+0.0` the zero-fill would have left).
pub fn gemm_ovw_split_on<T: Float + std::ops::AddAssign + Send + Sync>(
    a: MatRef<'_, T>,
    b: MatRef<'_, T>,
    c: &mut Mat<T>,
    exec: Exec<'_>,
    split: GemmSplit,
) -> Result<()> {
    check_gemm_shapes(a.rows, a.cols, b.rows, b.cols, c.rows, c.cols, c.data.len())?;
    let m = a.rows;
    let n = b.cols;
    if m == 0 || n == 0 {
        return Ok(());
    }
    let c_ptr = SendPtr(c.data.as_mut_ptr());
    // Safety: as in gemm_acc_split_on — disjoint regions, joined dispatch.
    dispatch_regions(exec, split, m, n, |r0, r1, j0, j1| unsafe {
        kernel_overwrite(a, b, c_ptr, r0, r1 - r0, j0, j1)
    });
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn check_gemm_shapes(
    a_rows: usize,
    a_cols: usize,
    b_rows: usize,
    b_cols: usize,
    c_rows: usize,
    c_cols: usize,
    c_len: usize,
) -> Result<()> {
    if a_cols != b_rows || c_rows != a_rows || c_cols != b_cols {
        return Err(Error::shape(format!(
            "gemm_acc: ({a_rows},{a_cols})×({b_rows},{b_cols})→({c_rows},{c_cols})"
        )));
    }
    // C is written through a raw base pointer; a hand-built Mat whose
    // buffer disagrees with its dims must fail here, not corrupt the heap.
    if c_len != c_rows * c_cols {
        return Err(Error::shape(format!(
            "gemm_acc: C buffer holds {c_len} elements for a {c_rows}×{c_cols} shape"
        )));
    }
    Ok(())
}

/// Partition the (m × n) output per `split` and run `body(r0, r1, j0, j1)`
/// exactly once per disjoint region on `exec`. The single source of
/// partition arithmetic for every kernel variant (interleaved/planar,
/// accumulate/overwrite), so their region boundaries — and hence which
/// part computes which element — cannot drift apart. Bit-identity never
/// depends on the partitioning anyway: each output element is fully
/// accumulated by exactly one part in the same k order.
fn dispatch_regions<F: Fn(usize, usize, usize, usize) + Sync>(
    exec: Exec<'_>,
    split: GemmSplit,
    m: usize,
    n: usize,
    body: F,
) {
    let width = exec.width();
    if width == 1 {
        // Inline fast path: no scope, no dispatch — the allocation-free
        // steady state the step workspace depends on.
        body(0, m, 0, n);
        return;
    }
    match choose_split(split, m, n, width) {
        GemmSplit::Rows | GemmSplit::Auto => {
            let parts = width.min(m);
            let rows_per = m.div_ceil(parts);
            exec.run_parts(parts, |t| {
                let r0 = t * rows_per;
                let r1 = ((t + 1) * rows_per).min(m);
                if r0 < r1 {
                    body(r0, r1, 0, n);
                }
            });
        }
        GemmSplit::Cols => {
            let parts = width.min(n.div_ceil(COL_MIN)).max(1).min(n);
            let cols_per = n.div_ceil(parts);
            exec.run_parts(parts, |t| {
                let j0 = t * cols_per;
                let j1 = ((t + 1) * cols_per).min(n);
                if j0 < j1 {
                    body(0, m, j0, j1);
                }
            });
        }
    }
}

/// Shared raw pointer for the splits' disjoint C-region writes.
#[derive(Clone, Copy)]
pub(crate) struct SendPtr<T>(pub(crate) *mut T);
unsafe impl<T> Send for SendPtr<T> {}
// SAFETY: shared across parts only while each writes a disjoint region.
unsafe impl<T> Sync for SendPtr<T> {}

/// Split-plane raw C pointer for the planar kernels.
#[derive(Clone, Copy)]
struct PlanarPtr<T> {
    re: SendPtr<T>,
    im: SendPtr<T>,
}

/// Inner axpy: `crow += av * brow`, unrolled by 4.
#[inline]
fn axpy_row<T: Float + std::ops::AddAssign>(
    crow: &mut [Complex<T>],
    av: Complex<T>,
    brow: &[Complex<T>],
) {
    let w = crow.len();
    let mut j = 0;
    while j + 4 <= w {
        crow[j] = crow[j].mul_add(av, brow[j]);
        crow[j + 1] = crow[j + 1].mul_add(av, brow[j + 1]);
        crow[j + 2] = crow[j + 2].mul_add(av, brow[j + 2]);
        crow[j + 3] = crow[j + 3].mul_add(av, brow[j + 3]);
        j += 4;
    }
    while j < w {
        crow[j] = crow[j].mul_add(av, brow[j]);
        j += 1;
    }
}

/// First-term overwrite: `crow = 0 + av * brow`. Evaluates the SAME
/// `zero.mul_add(av, b)` expression the accumulate form computes on a
/// zero-filled C — a bare product would differ in the sign of zero
/// (`0.0 + (-0.0)` is `+0.0`).
#[inline]
fn axpy_row_set<T: Float + std::ops::AddAssign>(
    crow: &mut [Complex<T>],
    av: Complex<T>,
    brow: &[Complex<T>],
) {
    let w = crow.len();
    let mut j = 0;
    while j + 4 <= w {
        crow[j] = Complex::zero().mul_add(av, brow[j]);
        crow[j + 1] = Complex::zero().mul_add(av, brow[j + 1]);
        crow[j + 2] = Complex::zero().mul_add(av, brow[j + 2]);
        crow[j + 3] = Complex::zero().mul_add(av, brow[j + 3]);
        j += 4;
    }
    while j < w {
        crow[j] = Complex::zero().mul_add(av, brow[j]);
        j += 1;
    }
}

/// THE blocked kernel — one body for the serial path, the row split, and
/// the column split, so their accumulation order (and hence bitwise
/// results) cannot drift apart. Processes C rows `[row0, row0+my_rows)`
/// × columns `[j0, j1)`; `c_ptr` is the base of the full (m×n) C buffer.
///
/// # Safety
/// The caller must guarantee that the `[row0, row0+my_rows) × [j0, j1)`
/// region of C is exclusively owned by this call (no concurrent reader
/// or writer overlaps it) and that the buffer outlives the call.
unsafe fn kernel_blocked<T: Float + std::ops::AddAssign>(
    a: MatRef<'_, T>,
    b: MatRef<'_, T>,
    c_ptr: SendPtr<Complex<T>>,
    row0: usize,
    my_rows: usize,
    j0: usize,
    j1: usize,
) {
    let n = b.cols;
    let k = a.cols;
    for ib in (0..my_rows).step_by(MC) {
        let ie = (ib + MC).min(my_rows);
        for kb in (0..k).step_by(KC) {
            let ke = (kb + KC).min(k);
            for i in ib..ie {
                let arow = a.row(row0 + i);
                // Safety (per the contract above): this row segment lies
                // inside the caller's exclusive region.
                let crow = unsafe {
                    std::slice::from_raw_parts_mut(
                        c_ptr.0.add((row0 + i) * n + j0),
                        j1 - j0,
                    )
                };
                for kk in kb..ke {
                    let av = arow[kk];
                    if av.re == T::zero() && av.im == T::zero() {
                        continue;
                    }
                    axpy_row(crow, av, &b.row(kk)[j0..j1]);
                }
            }
        }
    }
}

/// β=0 overwrite kernel. The first non-skipped k term of each row uses
/// [`axpy_row_set`]; later terms accumulate with [`axpy_row`]; rows whose
/// every `av` hit the zero skip are filled with `+0.0`. Per output
/// element, k still ascends monotonically, so the result is bitwise equal
/// to zero-filling C and running [`kernel_blocked`] (the MC row blocking
/// is dropped here — it never affected per-element accumulation order).
///
/// # Safety
/// Same exclusive-region contract as [`kernel_blocked`].
unsafe fn kernel_overwrite<T: Float + std::ops::AddAssign>(
    a: MatRef<'_, T>,
    b: MatRef<'_, T>,
    c_ptr: SendPtr<Complex<T>>,
    row0: usize,
    my_rows: usize,
    j0: usize,
    j1: usize,
) {
    let n = b.cols;
    for i in 0..my_rows {
        let arow = a.row(row0 + i);
        // Safety (per the contract above): this row segment lies inside
        // the caller's exclusive region.
        let crow = unsafe {
            std::slice::from_raw_parts_mut(c_ptr.0.add((row0 + i) * n + j0), j1 - j0)
        };
        let mut init = false;
        for (kk, av) in arow.iter().enumerate() {
            if av.re == T::zero() && av.im == T::zero() {
                continue;
            }
            let brow = &b.row(kk)[j0..j1];
            if init {
                axpy_row(crow, *av, brow);
            } else {
                axpy_row_set(crow, *av, brow);
                init = true;
            }
        }
        if !init {
            crow.fill(Complex::zero());
        }
    }
}

/// Scalar backing the planar (split re/im) kernels. The required methods
/// are the split-plane axpy the SIMD microkernels specialize; the scalar
/// bodies below evaluate, per lane, the exact association of
/// [`Complex::mul_add`]:
///
/// ```text
/// re' = (re + ar·b_re) - ai·b_im
/// im' = (im + ar·b_im) + ai·b_re
/// ```
///
/// which is what makes the planar path bit-identical to the interleaved
/// kernels. The `simd` feature swaps in explicit AVX2 (x86_64, runtime
/// detected) / NEON (aarch64) implementations that perform the same
/// mul/add/sub sequence lane-wise — never a fused `vfmadd`, whose single
/// rounding would break the identity; off-feature and on other targets
/// the scalar fallback runs.
pub trait PlanarScalar: Float {
    /// `crow += av * brow` over split planes (all slices equal length).
    fn planar_axpy(
        cre: &mut [Self],
        cim: &mut [Self],
        ar: Self,
        ai: Self,
        bre: &[Self],
        bim: &[Self],
    );
    /// First-term overwrite: the same expression starting from zero (see
    /// [`axpy_row_set`] for the sign-of-zero rationale).
    fn planar_axpy_set(
        cre: &mut [Self],
        cim: &mut [Self],
        ar: Self,
        ai: Self,
        bre: &[Self],
        bim: &[Self],
    );
}

#[inline]
fn planar_axpy_scalar<T: Float>(
    cre: &mut [T],
    cim: &mut [T],
    ar: T,
    ai: T,
    bre: &[T],
    bim: &[T],
) {
    for ((cr, ci), (&br, &bi)) in cre
        .iter_mut()
        .zip(cim.iter_mut())
        .zip(bre.iter().zip(bim))
    {
        *cr = (*cr + ar * br) - ai * bi;
        *ci = (*ci + ar * bi) + ai * br;
    }
}

#[inline]
fn planar_axpy_set_scalar<T: Float>(
    cre: &mut [T],
    cim: &mut [T],
    ar: T,
    ai: T,
    bre: &[T],
    bim: &[T],
) {
    for ((cr, ci), (&br, &bi)) in cre
        .iter_mut()
        .zip(cim.iter_mut())
        .zip(bre.iter().zip(bim))
    {
        *cr = (T::zero() + ar * br) - ai * bi;
        *ci = (T::zero() + ar * bi) + ai * br;
    }
}

/// Explicit AVX2 microkernels (runtime-detected behind the `simd`
/// feature). Separate mul/add/sub in the exact scalar association — no
/// `vfmadd`, whose fused rounding would break bit-identity.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod simd_arch {
    use core::arch::x86_64::*;

    macro_rules! avx2_axpy {
        ($name:ident, $t:ty, $lanes:expr, $set1:ident, $load:ident, $store:ident,
         $mul:ident, $add:ident, $sub:ident, $zero:ident) => {
            #[allow(clippy::too_many_arguments)]
            #[target_feature(enable = "avx2")]
            pub unsafe fn $name(
                set: bool,
                cre: *mut $t,
                cim: *mut $t,
                ar: $t,
                ai: $t,
                bre: *const $t,
                bim: *const $t,
                w: usize,
            ) {
                let var = $set1(ar);
                let vai = $set1(ai);
                let mut j = 0;
                while j + $lanes <= w {
                    let br = $load(bre.add(j));
                    let bi = $load(bim.add(j));
                    let (cr, ci) = if set {
                        ($zero(), $zero())
                    } else {
                        ($load(cre.add(j)), $load(cim.add(j)))
                    };
                    let nr = $sub($add(cr, $mul(var, br)), $mul(vai, bi));
                    let ni = $add($add(ci, $mul(var, bi)), $mul(vai, br));
                    $store(cre.add(j), nr);
                    $store(cim.add(j), ni);
                    j += $lanes;
                }
                while j < w {
                    let br = *bre.add(j);
                    let bi = *bim.add(j);
                    let (cr, ci) = if set {
                        (0.0, 0.0)
                    } else {
                        (*cre.add(j), *cim.add(j))
                    };
                    *cre.add(j) = (cr + ar * br) - ai * bi;
                    *cim.add(j) = (ci + ar * bi) + ai * br;
                    j += 1;
                }
            }
        };
    }

    avx2_axpy!(
        axpy_f32, f32, 8, _mm256_set1_ps, _mm256_loadu_ps, _mm256_storeu_ps, _mm256_mul_ps,
        _mm256_add_ps, _mm256_sub_ps, _mm256_setzero_ps
    );
    avx2_axpy!(
        axpy_f64, f64, 4, _mm256_set1_pd, _mm256_loadu_pd, _mm256_storeu_pd, _mm256_mul_pd,
        _mm256_add_pd, _mm256_sub_pd, _mm256_setzero_pd
    );
}

/// NEON microkernels (aarch64 baseline — no runtime detection needed).
#[cfg(all(feature = "simd", target_arch = "aarch64"))]
mod simd_arch {
    use core::arch::aarch64::*;

    macro_rules! neon_axpy {
        ($name:ident, $t:ty, $lanes:expr, $dup:ident, $load:ident, $store:ident,
         $mul:ident, $add:ident, $sub:ident) => {
            #[allow(clippy::too_many_arguments)]
            pub unsafe fn $name(
                set: bool,
                cre: *mut $t,
                cim: *mut $t,
                ar: $t,
                ai: $t,
                bre: *const $t,
                bim: *const $t,
                w: usize,
            ) {
                let var = $dup(ar);
                let vai = $dup(ai);
                let zero = $dup(0.0);
                let mut j = 0;
                while j + $lanes <= w {
                    let br = $load(bre.add(j));
                    let bi = $load(bim.add(j));
                    let (cr, ci) = if set {
                        (zero, zero)
                    } else {
                        ($load(cre.add(j)), $load(cim.add(j)))
                    };
                    let nr = $sub($add(cr, $mul(var, br)), $mul(vai, bi));
                    let ni = $add($add(ci, $mul(var, bi)), $mul(vai, br));
                    $store(cre.add(j), nr);
                    $store(cim.add(j), ni);
                    j += $lanes;
                }
                while j < w {
                    let br = *bre.add(j);
                    let bi = *bim.add(j);
                    let (cr, ci) = if set {
                        (0.0, 0.0)
                    } else {
                        (*cre.add(j), *cim.add(j))
                    };
                    *cre.add(j) = (cr + ar * br) - ai * bi;
                    *cim.add(j) = (ci + ar * bi) + ai * br;
                    j += 1;
                }
            }
        };
    }

    neon_axpy!(
        axpy_f32, f32, 4, vdupq_n_f32, vld1q_f32, vst1q_f32, vmulq_f32, vaddq_f32, vsubq_f32
    );
    neon_axpy!(
        axpy_f64, f64, 2, vdupq_n_f64, vld1q_f64, vst1q_f64, vmulq_f64, vaddq_f64, vsubq_f64
    );
}

macro_rules! impl_planar_scalar {
    ($t:ty, $kernel:ident) => {
        impl PlanarScalar for $t {
            #[inline]
            #[allow(unreachable_code)]
            fn planar_axpy(
                cre: &mut [Self],
                cim: &mut [Self],
                ar: Self,
                ai: Self,
                bre: &[Self],
                bim: &[Self],
            ) {
                #[cfg(all(feature = "simd", target_arch = "x86_64"))]
                if is_x86_feature_detected!("avx2") {
                    // Safety: equal-length slices (kernel invariant);
                    // AVX2 presence just checked.
                    unsafe {
                        simd_arch::$kernel(
                            false,
                            cre.as_mut_ptr(),
                            cim.as_mut_ptr(),
                            ar,
                            ai,
                            bre.as_ptr(),
                            bim.as_ptr(),
                            cre.len(),
                        )
                    };
                    return;
                }
                #[cfg(all(feature = "simd", target_arch = "aarch64"))]
                {
                    // Safety: equal-length slices; NEON is aarch64 baseline.
                    unsafe {
                        simd_arch::$kernel(
                            false,
                            cre.as_mut_ptr(),
                            cim.as_mut_ptr(),
                            ar,
                            ai,
                            bre.as_ptr(),
                            bim.as_ptr(),
                            cre.len(),
                        )
                    };
                    return;
                }
                planar_axpy_scalar(cre, cim, ar, ai, bre, bim)
            }

            #[inline]
            #[allow(unreachable_code)]
            fn planar_axpy_set(
                cre: &mut [Self],
                cim: &mut [Self],
                ar: Self,
                ai: Self,
                bre: &[Self],
                bim: &[Self],
            ) {
                #[cfg(all(feature = "simd", target_arch = "x86_64"))]
                if is_x86_feature_detected!("avx2") {
                    // Safety: as in planar_axpy.
                    unsafe {
                        simd_arch::$kernel(
                            true,
                            cre.as_mut_ptr(),
                            cim.as_mut_ptr(),
                            ar,
                            ai,
                            bre.as_ptr(),
                            bim.as_ptr(),
                            cre.len(),
                        )
                    };
                    return;
                }
                #[cfg(all(feature = "simd", target_arch = "aarch64"))]
                {
                    // Safety: as in planar_axpy.
                    unsafe {
                        simd_arch::$kernel(
                            true,
                            cre.as_mut_ptr(),
                            cim.as_mut_ptr(),
                            ar,
                            ai,
                            bre.as_ptr(),
                            bim.as_ptr(),
                            cre.len(),
                        )
                    };
                    return;
                }
                planar_axpy_set_scalar(cre, cim, ar, ai, bre, bim)
            }
        }
    };
}

impl_planar_scalar!(f32, axpy_f32);
impl_planar_scalar!(f64, axpy_f64);

/// Planar analogue of [`kernel_overwrite`]: identical row traversal,
/// identical `av == 0` skip, identical per-element k order — the planes
/// just carry re/im separately so the axpy is a straight real chain.
///
/// # Safety
/// Same exclusive-region contract as [`kernel_blocked`], applied to both
/// planes of C.
unsafe fn kernel_overwrite_planar<T: PlanarScalar>(
    a: PlanarMatRef<'_, T>,
    b: PlanarMatRef<'_, T>,
    c: PlanarPtr<T>,
    row0: usize,
    my_rows: usize,
    j0: usize,
    j1: usize,
) {
    let n = b.cols;
    for i in 0..my_rows {
        let r = row0 + i;
        let are = a.row_re(r);
        let aim = a.row_im(r);
        // Safety (per the contract above): these row segments lie inside
        // the caller's exclusive region of each plane.
        let cre = unsafe { std::slice::from_raw_parts_mut(c.re.0.add(r * n + j0), j1 - j0) };
        let cim = unsafe { std::slice::from_raw_parts_mut(c.im.0.add(r * n + j0), j1 - j0) };
        let mut init = false;
        for (kk, (&ar, &ai)) in are.iter().zip(aim).enumerate() {
            if ar == T::zero() && ai == T::zero() {
                continue;
            }
            let bre = &b.row_re(kk)[j0..j1];
            let bim = &b.row_im(kk)[j0..j1];
            if init {
                T::planar_axpy(cre, cim, ar, ai, bre, bim);
            } else {
                T::planar_axpy_set(cre, cim, ar, ai, bre, bim);
                init = true;
            }
        }
        if !init {
            cre.fill(T::zero());
            cim.fill(T::zero());
        }
    }
}

/// Planar analogue of [`contract_env_into_on`]: β=0 overwrite into a
/// reshaped (not zero-filled) planar temp. Bit-identical, element for
/// element, to the interleaved contraction on the same values.
pub fn planar_contract_env_into_on<T: PlanarScalar>(
    env: &PlanarMat<T>,
    gamma: &PlanarTensor3<T>,
    temp: &mut PlanarTensor3<T>,
    exec: Exec<'_>,
    split: GemmSplit,
) -> Result<()> {
    if env.cols != gamma.d0 {
        return Err(Error::shape(format!(
            "contract_env(planar): env (N,{}) vs Γ ({},{},{})",
            env.cols, gamma.d0, gamma.d1, gamma.d2
        )));
    }
    let m = env.rows;
    let n = gamma.d1 * gamma.d2;
    if env.re.len() != m * env.cols || env.im.len() != m * env.cols {
        return Err(Error::shape(format!(
            "contract_env(planar): env planes hold {}/{} elements for a {}×{} shape",
            env.re.len(),
            env.im.len(),
            m,
            env.cols
        )));
    }
    if gamma.re.len() != gamma.d0 * n || gamma.im.len() != gamma.d0 * n {
        return Err(Error::shape(format!(
            "contract_env(planar): Γ planes hold {}/{} elements for ({},{},{})",
            gamma.re.len(),
            gamma.im.len(),
            gamma.d0,
            gamma.d1,
            gamma.d2
        )));
    }
    temp.reshape(m, gamma.d1, gamma.d2);
    if m == 0 || n == 0 {
        return Ok(());
    }
    let a = env.view();
    let b = gamma.as_mat_ref();
    let c = PlanarPtr {
        re: SendPtr(temp.re.as_mut_ptr()),
        im: SendPtr(temp.im.as_mut_ptr()),
    };
    // Safety: `temp` is exclusively borrowed; dispatch_regions hands each
    // part a disjoint region of both planes and joins before returning.
    dispatch_regions(exec, split, m, n, |r0, r1, j0, j1| unsafe {
        kernel_overwrite_planar(a, b, c, r0, r1 - r0, j0, j1)
    });
    Ok(())
}

/// y ← A·x (complex matrix–vector). Allocates the output; hot paths use
/// [`gemv_into`].
pub fn gemv<T: Float + std::ops::AddAssign>(
    a: &Mat<T>,
    x: &[Complex<T>],
) -> Result<Vec<Complex<T>>> {
    let mut y = Vec::new();
    gemv_into(a, x, &mut y)?;
    Ok(y)
}

/// [`gemv`] into a caller-owned buffer (cleared and resized in place —
/// allocation-free once its capacity suffices).
pub fn gemv_into<T: Float + std::ops::AddAssign>(
    a: &Mat<T>,
    x: &[Complex<T>],
    y: &mut Vec<Complex<T>>,
) -> Result<()> {
    if a.cols != x.len() {
        return Err(Error::shape(format!(
            "gemv: ({},{})×({})",
            a.rows,
            a.cols,
            x.len()
        )));
    }
    y.clear();
    y.resize(a.rows, Complex::zero());
    for (r, yv) in y.iter_mut().enumerate() {
        let row = a.row(r);
        let mut acc = Complex::zero();
        for (av, xv) in row.iter().zip(x) {
            acc = acc.mul_add(*av, *xv);
        }
        *yv = acc;
    }
    Ok(())
}

/// The paper's per-site bond contraction:
/// `left_env (N, χ_l) × Γ (χ_l, χ_r, d) → temp (N, χ_r, d)`.
///
/// Γ is *viewed* as a `(χ_l, χ_r·d)` matrix over its own storage — the
/// physical index is innermost, so this is a single GEMM with no repacking
/// and no copy (the reason `Tensor3` uses that layout).
pub fn contract_env<T: Float + std::ops::AddAssign + Send + Sync>(
    env: &Mat<T>,
    gamma: &Tensor3<T>,
    threads: usize,
) -> Result<Tensor3<T>> {
    let mut temp = Tensor3::zeros(env.rows, gamma.d1, gamma.d2);
    contract_env_into(env, gamma, &mut temp, threads, GemmSplit::Auto)?;
    Ok(temp)
}

/// [`contract_env`] into a caller-owned output tensor (reshaped in place,
/// allocation-free once its capacity suffices) with an explicit split.
pub fn contract_env_into<T: Float + std::ops::AddAssign + Send + Sync>(
    env: &Mat<T>,
    gamma: &Tensor3<T>,
    temp: &mut Tensor3<T>,
    threads: usize,
    split: GemmSplit,
) -> Result<()> {
    contract_env_into_on(env, gamma, temp, Exec::Scoped(threads), split)
}

/// [`contract_env_into`] on an explicit executor. Uses the β=0 overwrite
/// kernel, so the old zero-fill pass over `temp` is gone — the output is
/// reshaped without zeroing and every element is written exactly once
/// (bit-identically to the zero-fill + accumulate form).
pub fn contract_env_into_on<T: Float + std::ops::AddAssign + Send + Sync>(
    env: &Mat<T>,
    gamma: &Tensor3<T>,
    temp: &mut Tensor3<T>,
    exec: Exec<'_>,
    split: GemmSplit,
) -> Result<()> {
    if env.cols != gamma.d0 {
        return Err(Error::shape(format!(
            "contract_env: env (N,{}) vs Γ ({},{},{})",
            env.cols, gamma.d0, gamma.d1, gamma.d2
        )));
    }
    temp.reshape(env.rows, gamma.d1, gamma.d2);
    let mut c = Mat {
        rows: env.rows,
        cols: gamma.d1 * gamma.d2,
        data: std::mem::take(&mut temp.data),
    };
    let r = gemm_ovw_split_on(env.view(), gamma.as_mat_ref(), &mut c, exec, split);
    temp.data = c.data;
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;
    use crate::tensor::C64;

    fn random_mat(rng: &mut Xoshiro256, r: usize, c: usize) -> Mat<f64> {
        let data = (0..r * c)
            .map(|_| C64::new(rng.normal(), rng.normal()))
            .collect();
        Mat::from_vec(r, c, data).unwrap()
    }

    fn naive_gemm(a: &Mat<f64>, b: &Mat<f64>) -> Mat<f64> {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = C64::zero();
                for k in 0..a.cols {
                    acc += a[(i, k)] * b[(k, j)];
                }
                c[(i, j)] = acc;
            }
        }
        c
    }

    #[test]
    fn gemm_matches_naive() {
        let mut rng = Xoshiro256::seed_from(5);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (17, 33, 9), (64, 128, 40)] {
            let a = random_mat(&mut rng, m, k);
            let b = random_mat(&mut rng, k, n);
            let want = naive_gemm(&a, &b);
            for threads in [1, 3] {
                let got = gemm(&a, &b, threads).unwrap();
                for (g, w) in got.data.iter().zip(&want.data) {
                    assert!((*g - *w).abs() < 1e-10, "m={m} k={k} n={n}");
                }
            }
        }
    }

    #[test]
    fn gemm_shape_errors() {
        let a: Mat<f64> = Mat::zeros(2, 3);
        let b: Mat<f64> = Mat::zeros(4, 2);
        assert!(gemm(&a, &b, 1).is_err());
    }

    #[test]
    fn gemm_identity() {
        let mut rng = Xoshiro256::seed_from(6);
        let a = random_mat(&mut rng, 7, 7);
        let i7: Mat<f64> = Mat::eye(7);
        let c = gemm(&a, &i7, 2).unwrap();
        for (g, w) in c.data.iter().zip(&a.data) {
            assert!((*g - *w).abs() < 1e-12);
        }
    }

    #[test]
    fn gemv_matches_gemm() {
        let mut rng = Xoshiro256::seed_from(7);
        let a = random_mat(&mut rng, 5, 9);
        let x: Vec<C64> = (0..9).map(|_| C64::new(rng.normal(), rng.normal())).collect();
        let xm = Mat::from_vec(9, 1, x.clone()).unwrap();
        let want = gemm(&a, &xm, 1).unwrap();
        let got = gemv(&a, &x).unwrap();
        for (g, w) in got.iter().zip(&want.data) {
            assert!((*g - *w).abs() < 1e-12);
        }
    }

    #[test]
    fn contract_env_matches_loops() {
        let mut rng = Xoshiro256::seed_from(8);
        let (n, chi_l, chi_r, d) = (4, 6, 5, 3);
        let env = random_mat(&mut rng, n, chi_l);
        let g = Tensor3::from_vec(
            chi_l,
            chi_r,
            d,
            (0..chi_l * chi_r * d)
                .map(|_| C64::new(rng.normal(), rng.normal()))
                .collect(),
        )
        .unwrap();
        let t = contract_env(&env, &g, 2).unwrap();
        for s in 0..n {
            for y in 0..chi_r {
                for p in 0..d {
                    let mut acc = C64::zero();
                    for x in 0..chi_l {
                        acc += env[(s, x)] * g.at(x, y, p);
                    }
                    assert!((t.at(s, y, p) - acc).abs() < 1e-10);
                }
            }
        }
    }

    #[test]
    fn contract_env_into_reuses_buffer() {
        let mut rng = Xoshiro256::seed_from(9);
        let env = random_mat(&mut rng, 8, 6);
        let g = Tensor3::from_vec(
            6,
            4,
            3,
            (0..6 * 4 * 3)
                .map(|_| C64::new(rng.normal(), rng.normal()))
                .collect(),
        )
        .unwrap();
        let want = contract_env(&env, &g, 1).unwrap();
        let mut temp: Tensor3<f64> = Tensor3::zeros(8, 4, 3); // right-sized
        let ptr = temp.data.as_ptr();
        for split in [GemmSplit::Auto, GemmSplit::Rows, GemmSplit::Cols] {
            contract_env_into(&env, &g, &mut temp, 2, split).unwrap();
            assert_eq!(temp.data, want.data, "{split:?} bit-identical");
        }
        contract_env_into(&env, &g, &mut temp, 1, GemmSplit::Auto).unwrap();
        assert_eq!(temp.data.as_ptr(), ptr, "no reallocation across calls");
    }

    #[test]
    fn column_split_bit_identical_to_serial() {
        // The bond-parallel kernel must match the single-thread result
        // EXACTLY — each C element is accumulated by one thread in the
        // same k order, so not even the last ulp may move.
        crate::util::prop::quickcheck("col-split == serial", |g| {
            let m = g.len(1, 12);
            let k = g.len(1, 24);
            let n = g.len(1, 48);
            let threads = g.len(2, 6);
            let mut rng = Xoshiro256::seed_from(g.u64());
            let a = random_mat(&mut rng, m, k);
            let b = random_mat(&mut rng, k, n);
            let mut serial = Mat::zeros(m, n);
            gemm_acc_split(a.view(), b.view(), &mut serial, 1, GemmSplit::Rows)
                .unwrap();
            for split in [GemmSplit::Cols, GemmSplit::Rows, GemmSplit::Auto] {
                let mut par = Mat::zeros(m, n);
                gemm_acc_split(a.view(), b.view(), &mut par, threads, split).unwrap();
                if par.data != serial.data {
                    return Err(format!(
                        "{split:?} with {threads} threads diverged at ({m},{k},{n})"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn auto_split_heuristic_prefers_busy_threads() {
        // Plenty of rows → row split regardless of width.
        assert_eq!(choose_split(GemmSplit::Auto, 64, 1024, 8), GemmSplit::Rows);
        // Few rows, wide bond axis → bond split.
        assert_eq!(choose_split(GemmSplit::Auto, 2, 1024, 8), GemmSplit::Cols);
        // Few rows AND narrow → rows (col stripes would be too thin).
        assert_eq!(choose_split(GemmSplit::Auto, 2, 32, 8), GemmSplit::Rows);
        // Explicit choices pass through.
        assert_eq!(choose_split(GemmSplit::Cols, 64, 64, 2), GemmSplit::Cols);
        assert_eq!(GemmSplit::parse("bond").unwrap(), GemmSplit::Cols);
        assert!(GemmSplit::parse("diag").is_err());
    }

    #[test]
    fn flops_convention() {
        assert_eq!(matmul_flops(2, 3, 4), 8 * 24);
    }

    /// Sparsify: zero individual entries and whole rows of A so the
    /// overwrite kernel's `init` bookkeeping (and the all-zero-row fill)
    /// is actually exercised, negative zeros included.
    fn sparsify(a: &mut Mat<f64>, rng: &mut Xoshiro256) {
        for z in &mut a.data {
            match rng.u64() % 5 {
                0 => *z = C64::zero(),
                1 => *z = C64::new(-0.0, -0.0),
                _ => {}
            }
        }
        if a.rows > 1 && rng.u64() % 2 == 0 {
            let dead = (rng.u64() as usize) % a.rows;
            for j in 0..a.cols {
                a[(dead, j)] = C64::zero();
            }
        }
    }

    #[test]
    fn overwrite_bit_identical_to_zero_fill_accumulate() {
        crate::util::prop::quickcheck("ovw == zerofill+acc", |g| {
            let m = g.len(1, 10);
            let k = g.len(1, 20);
            let n = g.len(1, 40);
            let threads = g.len(1, 5);
            let mut rng = Xoshiro256::seed_from(g.u64());
            let mut a = random_mat(&mut rng, m, k);
            sparsify(&mut a, &mut rng);
            let b = random_mat(&mut rng, k, n);
            for split in [GemmSplit::Auto, GemmSplit::Rows, GemmSplit::Cols] {
                let mut acc = Mat::zeros(m, n);
                gemm_acc_split(a.view(), b.view(), &mut acc, threads, split).unwrap();
                // Poison the overwrite target so stale contents leaking
                // through would be caught.
                let mut ovw = Mat::zeros(m, n);
                for z in &mut ovw.data {
                    *z = C64::new(f64::NAN, -7.5);
                }
                gemm_ovw_split_on(a.view(), b.view(), &mut ovw, Exec::Scoped(threads), split)
                    .unwrap();
                if !bits_equal(&ovw.data, &acc.data) {
                    return Err(format!("{split:?}×{threads} overwrite diverged"));
                }
            }
            Ok(())
        });
    }

    /// Bitwise comparison that treats NaN payloads and zero signs as
    /// significant — `==` would paper over `-0.0`.
    fn bits_equal(a: &[C64], b: &[C64]) -> bool {
        a.len() == b.len()
            && a.iter().zip(b).all(|(x, y)| {
                x.re.to_bits() == y.re.to_bits() && x.im.to_bits() == y.im.to_bits()
            })
    }

    #[test]
    fn planar_contraction_bit_identical_to_interleaved() {
        crate::util::prop::quickcheck("planar == interleaved", |g| {
            let n = g.len(1, 10);
            let chi_l = g.len(1, 12);
            let chi_r = g.len(1, 8);
            let d = g.len(1, 4);
            let mut rng = Xoshiro256::seed_from(g.u64());
            let mut env = random_mat(&mut rng, n, chi_l);
            sparsify(&mut env, &mut rng);
            let gam = Tensor3::from_vec(
                chi_l,
                chi_r,
                d,
                (0..chi_l * chi_r * d)
                    .map(|_| C64::new(rng.normal(), rng.normal()))
                    .collect(),
            )
            .unwrap();
            let mut want: Tensor3<f64> = Tensor3::zeros(0, 0, 0);
            contract_env_into(&env, &gam, &mut want, 1, GemmSplit::Rows).unwrap();

            // f64 planar, serial and threaded.
            let penv = PlanarMat::from_interleaved(&env);
            let pgam = PlanarTensor3::from_interleaved(&gam);
            let mut ptemp: PlanarTensor3<f64> = PlanarTensor3::zeros(0, 0, 0);
            for (exec, split) in [
                (Exec::Scoped(1), GemmSplit::Rows),
                (Exec::Scoped(3), GemmSplit::Rows),
                (Exec::Scoped(3), GemmSplit::Cols),
                (Exec::Scoped(3), GemmSplit::Auto),
            ] {
                planar_contract_env_into_on(&penv, &pgam, &mut ptemp, exec, split).unwrap();
                if !bits_equal(&ptemp.to_interleaved().data, &want.data) {
                    return Err(format!("f64 planar {split:?} diverged"));
                }
            }

            // f32: interleaved serial vs planar (the precision the auto
            // layout rule actually routes planar).
            let env32 = Mat::from_vec(
                n,
                chi_l,
                env.data.iter().map(|z| z.to_c32()).collect(),
            )
            .unwrap();
            let gam32 = Tensor3::from_vec(
                chi_l,
                chi_r,
                d,
                gam.data.iter().map(|z| z.to_c32()).collect(),
            )
            .unwrap();
            let mut want32: Tensor3<f32> = Tensor3::zeros(0, 0, 0);
            contract_env_into(&env32, &gam32, &mut want32, 1, GemmSplit::Rows).unwrap();
            let penv32 = PlanarMat::from_interleaved(&env32);
            let pgam32 = PlanarTensor3::from_interleaved(&gam32);
            let mut ptemp32: PlanarTensor3<f32> = PlanarTensor3::zeros(0, 0, 0);
            planar_contract_env_into_on(
                &penv32,
                &pgam32,
                &mut ptemp32,
                Exec::Scoped(2),
                GemmSplit::Auto,
            )
            .unwrap();
            let got32 = ptemp32.to_interleaved();
            for (x, y) in got32.data.iter().zip(&want32.data) {
                if x.re.to_bits() != y.re.to_bits() || x.im.to_bits() != y.im.to_bits() {
                    return Err("f32 planar diverged".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn pooled_dispatch_bit_identical_to_scoped_and_serial() {
        use super::super::pool::WorkerPool;
        let pool = WorkerPool::new(3);
        crate::util::prop::quickcheck("pooled == scoped == serial", |g| {
            let m = g.len(1, 12);
            let k = g.len(1, 16);
            let n = g.len(1, 40);
            let mut rng = Xoshiro256::seed_from(g.u64());
            let a = random_mat(&mut rng, m, k);
            let b = random_mat(&mut rng, k, n);
            let mut serial = Mat::zeros(m, n);
            gemm_acc_split_on(a.view(), b.view(), &mut serial, Exec::Scoped(1), GemmSplit::Rows)
                .unwrap();
            for split in [GemmSplit::Auto, GemmSplit::Rows, GemmSplit::Cols] {
                let mut scoped = Mat::zeros(m, n);
                gemm_acc_split_on(a.view(), b.view(), &mut scoped, Exec::Scoped(3), split)
                    .unwrap();
                let mut pooled = Mat::zeros(m, n);
                gemm_acc_split_on(a.view(), b.view(), &mut pooled, Exec::Pooled(&pool), split)
                    .unwrap();
                if !bits_equal(&scoped.data, &serial.data) {
                    return Err(format!("scoped {split:?} diverged"));
                }
                if !bits_equal(&pooled.data, &serial.data) {
                    return Err(format!("pooled {split:?} diverged"));
                }
                let mut pooled_ovw = Mat::zeros(m, n);
                gemm_ovw_split_on(
                    a.view(),
                    b.view(),
                    &mut pooled_ovw,
                    Exec::Pooled(&pool),
                    split,
                )
                .unwrap();
                if !bits_equal(&pooled_ovw.data, &serial.data) {
                    return Err(format!("pooled overwrite {split:?} diverged"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn gemv_into_matches_gemv_and_reuses_buffer() {
        let mut rng = Xoshiro256::seed_from(12);
        let a = random_mat(&mut rng, 6, 10);
        let x: Vec<C64> = (0..10)
            .map(|_| C64::new(rng.normal(), rng.normal()))
            .collect();
        let want = gemv(&a, &x).unwrap();
        let mut y = Vec::with_capacity(6);
        let ptr = y.as_ptr();
        gemv_into(&a, &x, &mut y).unwrap();
        assert!(bits_equal(&y, &want));
        gemv_into(&a, &x, &mut y).unwrap();
        assert_eq!(y.as_ptr(), ptr, "no reallocation across calls");
        let short = vec![C64::zero(); 3];
        assert!(gemv_into(&a, &short, &mut y).is_err());
    }

    #[test]
    fn gemm_property_associativity() {
        crate::util::prop::quickcheck("(AB)C == A(BC)", |g| {
            let m = g.len(1, 9);
            let k = g.len(1, 9);
            let n = g.len(1, 9);
            let p = g.len(1, 9);
            let mut rng = Xoshiro256::seed_from(g.u64());
            let a = random_mat(&mut rng, m, k);
            let b = random_mat(&mut rng, k, n);
            let c = random_mat(&mut rng, n, p);
            let l = gemm(&gemm(&a, &b, 1).unwrap(), &c, 1).unwrap();
            let r = gemm(&a, &gemm(&b, &c, 1).unwrap(), 1).unwrap();
            for (x, y) in l.data.iter().zip(&r.data) {
                crate::util::prop::close(x.re, y.re, 1e-8, "re")?;
                crate::util::prop::close(x.im, y.im, 1e-8, "im")?;
            }
            Ok(())
        });
    }
}
