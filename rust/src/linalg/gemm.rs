//! Blocked, multi-threaded complex GEMM and the MPS bond contraction.
//!
//! The native engine must be fast enough to make the CPU-scaled paper
//! experiments (Table 3, Figs. 10/12) meaningful, so the kernel is cache
//! blocked (MC×KC panels), accumulates in registers across an unrolled k
//! loop, and splits the row dimension across scoped threads. FLOP counts
//! follow the convention of the paper: one complex MAC = 8 real FLOPs.

use crate::util::num::Float;

use crate::tensor::{Complex, Mat, Tensor3};
use crate::util::error::{Error, Result};

/// Real FLOPs of an (m,k)×(k,n) complex GEMM (8 per complex MAC).
pub fn matmul_flops(m: usize, k: usize, n: usize) -> u64 {
    8 * m as u64 * k as u64 * n as u64
}

const MC: usize = 64; // row block
const KC: usize = 256; // depth block

/// C ← A·B (complex). Single allocation; panics only on shape mismatch.
pub fn gemm<T: Float + std::ops::AddAssign + Send + Sync>(
    a: &Mat<T>,
    b: &Mat<T>,
    threads: usize,
) -> Result<Mat<T>> {
    if a.cols != b.rows {
        return Err(Error::shape(format!(
            "gemm: ({},{})×({},{})",
            a.rows, a.cols, b.rows, b.cols
        )));
    }
    let mut c = Mat::zeros(a.rows, b.cols);
    gemm_acc(a, b, &mut c, threads)?;
    Ok(c)
}

/// C += A·B (complex), blocked and threaded over row panels.
pub fn gemm_acc<T: Float + std::ops::AddAssign + Send + Sync>(
    a: &Mat<T>,
    b: &Mat<T>,
    c: &mut Mat<T>,
    threads: usize,
) -> Result<()> {
    if a.cols != b.rows || c.rows != a.rows || c.cols != b.cols {
        return Err(Error::shape(format!(
            "gemm_acc: ({},{})×({},{})→({},{})",
            a.rows, a.cols, b.rows, b.cols, c.rows, c.cols
        )));
    }
    let n = b.cols;
    let k = a.cols;
    let threads = threads.max(1).min(a.rows.max(1));

    // Partition C's rows across threads; each thread owns a disjoint slice.
    let rows_per = a.rows.div_ceil(threads);
    let c_rows: Vec<&mut [Complex<T>]> = c.data.chunks_mut(rows_per * n).collect();

    std::thread::scope(|scope| {
        for (t, c_chunk) in c_rows.into_iter().enumerate() {
            let row0 = t * rows_per;
            scope.spawn(move || {
                let my_rows = c_chunk.len() / n;
                for ib in (0..my_rows).step_by(MC) {
                    let ie = (ib + MC).min(my_rows);
                    for kb in (0..k).step_by(KC) {
                        let ke = (kb + KC).min(k);
                        for i in ib..ie {
                            let arow = a.row(row0 + i);
                            let crow = &mut c_chunk[i * n..(i + 1) * n];
                            for kk in kb..ke {
                                let av = arow[kk];
                                if av.re == T::zero() && av.im == T::zero() {
                                    continue;
                                }
                                let brow = b.row(kk);
                                // Inner axpy: crow += av * brow, unrolled by 4.
                                let mut j = 0;
                                while j + 4 <= n {
                                    crow[j] = crow[j].mul_add(av, brow[j]);
                                    crow[j + 1] = crow[j + 1].mul_add(av, brow[j + 1]);
                                    crow[j + 2] = crow[j + 2].mul_add(av, brow[j + 2]);
                                    crow[j + 3] = crow[j + 3].mul_add(av, brow[j + 3]);
                                    j += 4;
                                }
                                while j < n {
                                    crow[j] = crow[j].mul_add(av, brow[j]);
                                    j += 1;
                                }
                            }
                        }
                    }
                }
            });
        }
    });
    Ok(())
}

/// y ← A·x (complex matrix–vector).
pub fn gemv<T: Float + std::ops::AddAssign>(
    a: &Mat<T>,
    x: &[Complex<T>],
) -> Result<Vec<Complex<T>>> {
    if a.cols != x.len() {
        return Err(Error::shape(format!(
            "gemv: ({},{})×({})",
            a.rows,
            a.cols,
            x.len()
        )));
    }
    let mut y = vec![Complex::zero(); a.rows];
    for (r, yv) in y.iter_mut().enumerate() {
        let row = a.row(r);
        let mut acc = Complex::zero();
        for (av, xv) in row.iter().zip(x) {
            acc = acc.mul_add(*av, *xv);
        }
        *yv = acc;
    }
    Ok(y)
}

/// The paper's per-site bond contraction:
/// `left_env (N, χ_l) × Γ (χ_l, χ_r, d) → temp (N, χ_r, d)`.
///
/// Γ is viewed as a `(χ_l, χ_r·d)` matrix — the physical index is innermost,
/// so this is a single GEMM with no repacking (the reason `Tensor3` uses
/// that layout).
pub fn contract_env<T: Float + std::ops::AddAssign + Send + Sync>(
    env: &Mat<T>,
    gamma: &Tensor3<T>,
    threads: usize,
) -> Result<Tensor3<T>> {
    if env.cols != gamma.d0 {
        return Err(Error::shape(format!(
            "contract_env: env (N,{}) vs Γ ({},{},{})",
            env.cols, gamma.d0, gamma.d1, gamma.d2
        )));
    }
    let gm = Mat {
        rows: gamma.d0,
        cols: gamma.d1 * gamma.d2,
        data: gamma.data.clone(),
    };
    let c = gemm(env, &gm, threads)?;
    Tensor3::from_vec(env.rows, gamma.d1, gamma.d2, c.data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;
    use crate::tensor::C64;

    fn random_mat(rng: &mut Xoshiro256, r: usize, c: usize) -> Mat<f64> {
        let data = (0..r * c)
            .map(|_| C64::new(rng.normal(), rng.normal()))
            .collect();
        Mat::from_vec(r, c, data).unwrap()
    }

    fn naive_gemm(a: &Mat<f64>, b: &Mat<f64>) -> Mat<f64> {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = C64::zero();
                for k in 0..a.cols {
                    acc += a[(i, k)] * b[(k, j)];
                }
                c[(i, j)] = acc;
            }
        }
        c
    }

    #[test]
    fn gemm_matches_naive() {
        let mut rng = Xoshiro256::seed_from(5);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (17, 33, 9), (64, 128, 40)] {
            let a = random_mat(&mut rng, m, k);
            let b = random_mat(&mut rng, k, n);
            let want = naive_gemm(&a, &b);
            for threads in [1, 3] {
                let got = gemm(&a, &b, threads).unwrap();
                for (g, w) in got.data.iter().zip(&want.data) {
                    assert!((*g - *w).abs() < 1e-10, "m={m} k={k} n={n}");
                }
            }
        }
    }

    #[test]
    fn gemm_shape_errors() {
        let a: Mat<f64> = Mat::zeros(2, 3);
        let b: Mat<f64> = Mat::zeros(4, 2);
        assert!(gemm(&a, &b, 1).is_err());
    }

    #[test]
    fn gemm_identity() {
        let mut rng = Xoshiro256::seed_from(6);
        let a = random_mat(&mut rng, 7, 7);
        let i7: Mat<f64> = Mat::eye(7);
        let c = gemm(&a, &i7, 2).unwrap();
        for (g, w) in c.data.iter().zip(&a.data) {
            assert!((*g - *w).abs() < 1e-12);
        }
    }

    #[test]
    fn gemv_matches_gemm() {
        let mut rng = Xoshiro256::seed_from(7);
        let a = random_mat(&mut rng, 5, 9);
        let x: Vec<C64> = (0..9).map(|_| C64::new(rng.normal(), rng.normal())).collect();
        let xm = Mat::from_vec(9, 1, x.clone()).unwrap();
        let want = gemm(&a, &xm, 1).unwrap();
        let got = gemv(&a, &x).unwrap();
        for (g, w) in got.iter().zip(&want.data) {
            assert!((*g - *w).abs() < 1e-12);
        }
    }

    #[test]
    fn contract_env_matches_loops() {
        let mut rng = Xoshiro256::seed_from(8);
        let (n, chi_l, chi_r, d) = (4, 6, 5, 3);
        let env = random_mat(&mut rng, n, chi_l);
        let g = Tensor3::from_vec(
            chi_l,
            chi_r,
            d,
            (0..chi_l * chi_r * d)
                .map(|_| C64::new(rng.normal(), rng.normal()))
                .collect(),
        )
        .unwrap();
        let t = contract_env(&env, &g, 2).unwrap();
        for s in 0..n {
            for y in 0..chi_r {
                for p in 0..d {
                    let mut acc = C64::zero();
                    for x in 0..chi_l {
                        acc += env[(s, x)] * g.at(x, y, p);
                    }
                    assert!((t.at(s, y, p) - acc).abs() < 1e-10);
                }
            }
        }
    }

    #[test]
    fn flops_convention() {
        assert_eq!(matmul_flops(2, 3, 4), 8 * 24);
    }

    #[test]
    fn gemm_property_associativity() {
        crate::util::prop::quickcheck("(AB)C == A(BC)", |g| {
            let m = g.len(1, 9);
            let k = g.len(1, 9);
            let n = g.len(1, 9);
            let p = g.len(1, 9);
            let mut rng = Xoshiro256::seed_from(g.u64());
            let a = random_mat(&mut rng, m, k);
            let b = random_mat(&mut rng, k, n);
            let c = random_mat(&mut rng, n, p);
            let l = gemm(&gemm(&a, &b, 1).unwrap(), &c, 1).unwrap();
            let r = gemm(&a, &gemm(&b, &c, 1).unwrap(), 1).unwrap();
            for (x, y) in l.data.iter().zip(&r.data) {
                crate::util::prop::close(x.re, y.re, 1e-8, "re")?;
                crate::util::prop::close(x.im, y.im, 1e-8, "im")?;
            }
            Ok(())
        });
    }
}
