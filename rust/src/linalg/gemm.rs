//! Blocked, multi-threaded complex GEMM and the MPS bond contraction.
//!
//! The native engine must be fast enough to make the CPU-scaled paper
//! experiments (Table 3, Figs. 10/12) meaningful, so the kernel is cache
//! blocked (MC×KC panels), accumulates in registers across an unrolled k
//! loop, and splits work across scoped threads along one of two axes:
//!
//! - **row split** — partition C's rows (the sample axis N). Best when
//!   N ≥ threads: each thread streams its own disjoint C panel.
//! - **column split** — partition C's columns (the bond axis χ_r·d, the
//!   paper's tensor-parallel axis). When N is small and χ is huge a row
//!   split leaves most threads idle; the column split keeps them all busy
//!   on disjoint column stripes of every row.
//!
//! [`GemmSplit::Auto`] picks between them with a utilization heuristic
//! (see [`choose_split`]); both splits produce bit-identical results to
//! the single-threaded kernel because every C element is accumulated by
//! exactly one thread in the same k order. FLOP counts follow the paper's
//! convention: one complex MAC = 8 real FLOPs.

use crate::util::num::Float;

use crate::tensor::{Complex, Mat, MatRef, Tensor3};
use crate::util::error::{Error, Result};

/// Real FLOPs of an (m,k)×(k,n) complex GEMM (8 per complex MAC).
pub fn matmul_flops(m: usize, k: usize, n: usize) -> u64 {
    8 * m as u64 * k as u64 * n as u64
}

const MC: usize = 64; // row block
const KC: usize = 256; // depth block

/// Minimum columns per thread before a column split is worth the extra
/// passes over A (each stripe re-reads every A row).
const COL_MIN: usize = 16;

/// Which axis of C the threaded GEMM partitions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum GemmSplit {
    /// Pick per call from the shape (see [`choose_split`]).
    #[default]
    Auto,
    /// Always split C's rows (the sample axis).
    Rows,
    /// Always split C's columns (the bond axis — tensor-parallel style).
    Cols,
}

impl GemmSplit {
    pub fn as_str(self) -> &'static str {
        match self {
            GemmSplit::Auto => "auto",
            GemmSplit::Rows => "rows",
            GemmSplit::Cols => "cols",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "auto" => Ok(GemmSplit::Auto),
            "rows" => Ok(GemmSplit::Rows),
            "cols" | "bond" => Ok(GemmSplit::Cols),
            _ => Err(Error::config(format!(
                "unknown gemm split '{s}' (auto|rows|cols)"
            ))),
        }
    }
}

/// Resolve `Auto` for an (m × n) output on `threads` threads: prefer the
/// row split whenever it can occupy every thread (better A/C locality);
/// fall back to the bond split when rows are scarce but the bond axis is
/// wide enough to give each thread a ≥ [`COL_MIN`]-column stripe.
pub fn choose_split(split: GemmSplit, m: usize, n: usize, threads: usize) -> GemmSplit {
    match split {
        GemmSplit::Auto => {
            if m >= threads || n < threads * COL_MIN {
                GemmSplit::Rows
            } else {
                GemmSplit::Cols
            }
        }
        s => s,
    }
}

/// C ← A·B (complex). Single allocation; errors on shape mismatch.
pub fn gemm<T: Float + std::ops::AddAssign + Send + Sync>(
    a: &Mat<T>,
    b: &Mat<T>,
    threads: usize,
) -> Result<Mat<T>> {
    if a.cols != b.rows {
        return Err(Error::shape(format!(
            "gemm: ({},{})×({},{})",
            a.rows, a.cols, b.rows, b.cols
        )));
    }
    let mut c = Mat::zeros(a.rows, b.cols);
    gemm_acc(a, b, &mut c, threads)?;
    Ok(c)
}

/// C += A·B (complex), blocked and threaded over row panels (or column
/// stripes when the auto heuristic prefers the bond axis).
pub fn gemm_acc<T: Float + std::ops::AddAssign + Send + Sync>(
    a: &Mat<T>,
    b: &Mat<T>,
    c: &mut Mat<T>,
    threads: usize,
) -> Result<()> {
    gemm_acc_split(a.view(), b.view(), c, threads, GemmSplit::Auto)
}

/// C += A·B over borrowed views, with an explicit split policy. The core
/// kernel of the hot path: zero allocation when `threads == 1`.
pub fn gemm_acc_split<T: Float + std::ops::AddAssign + Send + Sync>(
    a: MatRef<'_, T>,
    b: MatRef<'_, T>,
    c: &mut Mat<T>,
    threads: usize,
    split: GemmSplit,
) -> Result<()> {
    if a.cols != b.rows || c.rows != a.rows || c.cols != b.cols {
        return Err(Error::shape(format!(
            "gemm_acc: ({},{})×({},{})→({},{})",
            a.rows, a.cols, b.rows, b.cols, c.rows, c.cols
        )));
    }
    // C is written through a raw base pointer below; a hand-built Mat
    // whose buffer disagrees with its dims must fail here, not corrupt
    // the heap.
    if c.data.len() != c.rows * c.cols {
        return Err(Error::shape(format!(
            "gemm_acc: C buffer holds {} elements for a {}×{} shape",
            c.data.len(),
            c.rows,
            c.cols
        )));
    }
    let m = a.rows;
    let n = b.cols;
    if m == 0 || n == 0 {
        return Ok(());
    }
    let threads = threads.max(1);
    let c_ptr = SendPtr(c.data.as_mut_ptr());
    if threads == 1 {
        // Inline fast path: no scope, no spawn — the allocation-free
        // steady state the step workspace depends on.
        // Safety: `c` is exclusively borrowed and no other region is live.
        unsafe { kernel_blocked(a, b, c_ptr, 0, m, 0, n) };
        return Ok(());
    }
    match choose_split(split, m, n, threads) {
        GemmSplit::Rows | GemmSplit::Auto => {
            let threads = threads.min(m);
            let rows_per = m.div_ceil(threads);
            std::thread::scope(|scope| {
                for t in 0..threads {
                    let r0 = t * rows_per;
                    let r1 = ((t + 1) * rows_per).min(m);
                    if r0 >= r1 {
                        break;
                    }
                    let c_ptr = c_ptr;
                    scope.spawn(move || {
                        // Safety: row panels [r0, r1) are disjoint across
                        // threads; the buffer outlives the scope.
                        unsafe { kernel_blocked(a, b, c_ptr, r0, r1 - r0, 0, n) };
                    });
                }
            });
        }
        GemmSplit::Cols => {
            let threads = threads.min(n.div_ceil(COL_MIN)).max(1).min(n);
            let cols_per = n.div_ceil(threads);
            std::thread::scope(|scope| {
                for t in 0..threads {
                    let j0 = t * cols_per;
                    let j1 = ((t + 1) * cols_per).min(n);
                    if j0 >= j1 {
                        break;
                    }
                    let c_ptr = c_ptr;
                    scope.spawn(move || {
                        // Safety: column stripes [j0, j1) are disjoint
                        // across threads; the buffer outlives the scope.
                        unsafe { kernel_blocked(a, b, c_ptr, 0, m, j0, j1) };
                    });
                }
            });
        }
    }
    Ok(())
}

/// Shared raw pointer for the splits' disjoint C-region writes.
#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}

/// Inner axpy: `crow += av * brow`, unrolled by 4.
#[inline]
fn axpy_row<T: Float + std::ops::AddAssign>(
    crow: &mut [Complex<T>],
    av: Complex<T>,
    brow: &[Complex<T>],
) {
    let w = crow.len();
    let mut j = 0;
    while j + 4 <= w {
        crow[j] = crow[j].mul_add(av, brow[j]);
        crow[j + 1] = crow[j + 1].mul_add(av, brow[j + 1]);
        crow[j + 2] = crow[j + 2].mul_add(av, brow[j + 2]);
        crow[j + 3] = crow[j + 3].mul_add(av, brow[j + 3]);
        j += 4;
    }
    while j < w {
        crow[j] = crow[j].mul_add(av, brow[j]);
        j += 1;
    }
}

/// THE blocked kernel — one body for the serial path, the row split, and
/// the column split, so their accumulation order (and hence bitwise
/// results) cannot drift apart. Processes C rows `[row0, row0+my_rows)`
/// × columns `[j0, j1)`; `c_ptr` is the base of the full (m×n) C buffer.
///
/// # Safety
/// The caller must guarantee that the `[row0, row0+my_rows) × [j0, j1)`
/// region of C is exclusively owned by this call (no concurrent reader
/// or writer overlaps it) and that the buffer outlives the call.
unsafe fn kernel_blocked<T: Float + std::ops::AddAssign>(
    a: MatRef<'_, T>,
    b: MatRef<'_, T>,
    c_ptr: SendPtr<Complex<T>>,
    row0: usize,
    my_rows: usize,
    j0: usize,
    j1: usize,
) {
    let n = b.cols;
    let k = a.cols;
    for ib in (0..my_rows).step_by(MC) {
        let ie = (ib + MC).min(my_rows);
        for kb in (0..k).step_by(KC) {
            let ke = (kb + KC).min(k);
            for i in ib..ie {
                let arow = a.row(row0 + i);
                // Safety (per the contract above): this row segment lies
                // inside the caller's exclusive region.
                let crow = unsafe {
                    std::slice::from_raw_parts_mut(
                        c_ptr.0.add((row0 + i) * n + j0),
                        j1 - j0,
                    )
                };
                for kk in kb..ke {
                    let av = arow[kk];
                    if av.re == T::zero() && av.im == T::zero() {
                        continue;
                    }
                    axpy_row(crow, av, &b.row(kk)[j0..j1]);
                }
            }
        }
    }
}

/// y ← A·x (complex matrix–vector).
pub fn gemv<T: Float + std::ops::AddAssign>(
    a: &Mat<T>,
    x: &[Complex<T>],
) -> Result<Vec<Complex<T>>> {
    if a.cols != x.len() {
        return Err(Error::shape(format!(
            "gemv: ({},{})×({})",
            a.rows,
            a.cols,
            x.len()
        )));
    }
    let mut y = vec![Complex::zero(); a.rows];
    for (r, yv) in y.iter_mut().enumerate() {
        let row = a.row(r);
        let mut acc = Complex::zero();
        for (av, xv) in row.iter().zip(x) {
            acc = acc.mul_add(*av, *xv);
        }
        *yv = acc;
    }
    Ok(y)
}

/// The paper's per-site bond contraction:
/// `left_env (N, χ_l) × Γ (χ_l, χ_r, d) → temp (N, χ_r, d)`.
///
/// Γ is *viewed* as a `(χ_l, χ_r·d)` matrix over its own storage — the
/// physical index is innermost, so this is a single GEMM with no repacking
/// and no copy (the reason `Tensor3` uses that layout).
pub fn contract_env<T: Float + std::ops::AddAssign + Send + Sync>(
    env: &Mat<T>,
    gamma: &Tensor3<T>,
    threads: usize,
) -> Result<Tensor3<T>> {
    let mut temp = Tensor3::zeros(env.rows, gamma.d1, gamma.d2);
    contract_env_into(env, gamma, &mut temp, threads, GemmSplit::Auto)?;
    Ok(temp)
}

/// [`contract_env`] into a caller-owned output tensor (reshaped in place,
/// allocation-free once its capacity suffices) with an explicit split.
pub fn contract_env_into<T: Float + std::ops::AddAssign + Send + Sync>(
    env: &Mat<T>,
    gamma: &Tensor3<T>,
    temp: &mut Tensor3<T>,
    threads: usize,
    split: GemmSplit,
) -> Result<()> {
    if env.cols != gamma.d0 {
        return Err(Error::shape(format!(
            "contract_env: env (N,{}) vs Γ ({},{},{})",
            env.cols, gamma.d0, gamma.d1, gamma.d2
        )));
    }
    temp.reset(env.rows, gamma.d1, gamma.d2);
    let mut c = Mat {
        rows: env.rows,
        cols: gamma.d1 * gamma.d2,
        data: std::mem::take(&mut temp.data),
    };
    let r = gemm_acc_split(env.view(), gamma.as_mat_ref(), &mut c, threads, split);
    temp.data = c.data;
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;
    use crate::tensor::C64;

    fn random_mat(rng: &mut Xoshiro256, r: usize, c: usize) -> Mat<f64> {
        let data = (0..r * c)
            .map(|_| C64::new(rng.normal(), rng.normal()))
            .collect();
        Mat::from_vec(r, c, data).unwrap()
    }

    fn naive_gemm(a: &Mat<f64>, b: &Mat<f64>) -> Mat<f64> {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = C64::zero();
                for k in 0..a.cols {
                    acc += a[(i, k)] * b[(k, j)];
                }
                c[(i, j)] = acc;
            }
        }
        c
    }

    #[test]
    fn gemm_matches_naive() {
        let mut rng = Xoshiro256::seed_from(5);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (17, 33, 9), (64, 128, 40)] {
            let a = random_mat(&mut rng, m, k);
            let b = random_mat(&mut rng, k, n);
            let want = naive_gemm(&a, &b);
            for threads in [1, 3] {
                let got = gemm(&a, &b, threads).unwrap();
                for (g, w) in got.data.iter().zip(&want.data) {
                    assert!((*g - *w).abs() < 1e-10, "m={m} k={k} n={n}");
                }
            }
        }
    }

    #[test]
    fn gemm_shape_errors() {
        let a: Mat<f64> = Mat::zeros(2, 3);
        let b: Mat<f64> = Mat::zeros(4, 2);
        assert!(gemm(&a, &b, 1).is_err());
    }

    #[test]
    fn gemm_identity() {
        let mut rng = Xoshiro256::seed_from(6);
        let a = random_mat(&mut rng, 7, 7);
        let i7: Mat<f64> = Mat::eye(7);
        let c = gemm(&a, &i7, 2).unwrap();
        for (g, w) in c.data.iter().zip(&a.data) {
            assert!((*g - *w).abs() < 1e-12);
        }
    }

    #[test]
    fn gemv_matches_gemm() {
        let mut rng = Xoshiro256::seed_from(7);
        let a = random_mat(&mut rng, 5, 9);
        let x: Vec<C64> = (0..9).map(|_| C64::new(rng.normal(), rng.normal())).collect();
        let xm = Mat::from_vec(9, 1, x.clone()).unwrap();
        let want = gemm(&a, &xm, 1).unwrap();
        let got = gemv(&a, &x).unwrap();
        for (g, w) in got.iter().zip(&want.data) {
            assert!((*g - *w).abs() < 1e-12);
        }
    }

    #[test]
    fn contract_env_matches_loops() {
        let mut rng = Xoshiro256::seed_from(8);
        let (n, chi_l, chi_r, d) = (4, 6, 5, 3);
        let env = random_mat(&mut rng, n, chi_l);
        let g = Tensor3::from_vec(
            chi_l,
            chi_r,
            d,
            (0..chi_l * chi_r * d)
                .map(|_| C64::new(rng.normal(), rng.normal()))
                .collect(),
        )
        .unwrap();
        let t = contract_env(&env, &g, 2).unwrap();
        for s in 0..n {
            for y in 0..chi_r {
                for p in 0..d {
                    let mut acc = C64::zero();
                    for x in 0..chi_l {
                        acc += env[(s, x)] * g.at(x, y, p);
                    }
                    assert!((t.at(s, y, p) - acc).abs() < 1e-10);
                }
            }
        }
    }

    #[test]
    fn contract_env_into_reuses_buffer() {
        let mut rng = Xoshiro256::seed_from(9);
        let env = random_mat(&mut rng, 8, 6);
        let g = Tensor3::from_vec(
            6,
            4,
            3,
            (0..6 * 4 * 3)
                .map(|_| C64::new(rng.normal(), rng.normal()))
                .collect(),
        )
        .unwrap();
        let want = contract_env(&env, &g, 1).unwrap();
        let mut temp: Tensor3<f64> = Tensor3::zeros(8, 4, 3); // right-sized
        let ptr = temp.data.as_ptr();
        for split in [GemmSplit::Auto, GemmSplit::Rows, GemmSplit::Cols] {
            contract_env_into(&env, &g, &mut temp, 2, split).unwrap();
            assert_eq!(temp.data, want.data, "{split:?} bit-identical");
        }
        contract_env_into(&env, &g, &mut temp, 1, GemmSplit::Auto).unwrap();
        assert_eq!(temp.data.as_ptr(), ptr, "no reallocation across calls");
    }

    #[test]
    fn column_split_bit_identical_to_serial() {
        // The bond-parallel kernel must match the single-thread result
        // EXACTLY — each C element is accumulated by one thread in the
        // same k order, so not even the last ulp may move.
        crate::util::prop::quickcheck("col-split == serial", |g| {
            let m = g.len(1, 12);
            let k = g.len(1, 24);
            let n = g.len(1, 48);
            let threads = g.len(2, 6);
            let mut rng = Xoshiro256::seed_from(g.u64());
            let a = random_mat(&mut rng, m, k);
            let b = random_mat(&mut rng, k, n);
            let mut serial = Mat::zeros(m, n);
            gemm_acc_split(a.view(), b.view(), &mut serial, 1, GemmSplit::Rows)
                .unwrap();
            for split in [GemmSplit::Cols, GemmSplit::Rows, GemmSplit::Auto] {
                let mut par = Mat::zeros(m, n);
                gemm_acc_split(a.view(), b.view(), &mut par, threads, split).unwrap();
                if par.data != serial.data {
                    return Err(format!(
                        "{split:?} with {threads} threads diverged at ({m},{k},{n})"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn auto_split_heuristic_prefers_busy_threads() {
        // Plenty of rows → row split regardless of width.
        assert_eq!(choose_split(GemmSplit::Auto, 64, 1024, 8), GemmSplit::Rows);
        // Few rows, wide bond axis → bond split.
        assert_eq!(choose_split(GemmSplit::Auto, 2, 1024, 8), GemmSplit::Cols);
        // Few rows AND narrow → rows (col stripes would be too thin).
        assert_eq!(choose_split(GemmSplit::Auto, 2, 32, 8), GemmSplit::Rows);
        // Explicit choices pass through.
        assert_eq!(choose_split(GemmSplit::Cols, 64, 64, 2), GemmSplit::Cols);
        assert_eq!(GemmSplit::parse("bond").unwrap(), GemmSplit::Cols);
        assert!(GemmSplit::parse("diag").is_err());
    }

    #[test]
    fn flops_convention() {
        assert_eq!(matmul_flops(2, 3, 4), 8 * 24);
    }

    #[test]
    fn gemm_property_associativity() {
        crate::util::prop::quickcheck("(AB)C == A(BC)", |g| {
            let m = g.len(1, 9);
            let k = g.len(1, 9);
            let n = g.len(1, 9);
            let p = g.len(1, 9);
            let mut rng = Xoshiro256::seed_from(g.u64());
            let a = random_mat(&mut rng, m, k);
            let b = random_mat(&mut rng, k, n);
            let c = random_mat(&mut rng, n, p);
            let l = gemm(&gemm(&a, &b, 1).unwrap(), &c, 1).unwrap();
            let r = gemm(&a, &gemm(&b, &c, 1).unwrap(), 1).unwrap();
            for (x, y) in l.data.iter().zip(&r.data) {
                crate::util::prop::close(x.re, y.re, 1e-8, "re")?;
                crate::util::prop::close(x.im, y.im, 1e-8, "im")?;
            }
            Ok(())
        });
    }
}
