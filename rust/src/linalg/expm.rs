//! General complex matrix exponential — scaling-and-squaring with Padé-13
//! (Higham 2005, the algorithm behind SciPy's `expm`).
//!
//! This is the *baseline* for the paper's displacement-operator ablation
//! (§3.4.1 / Fig. 11): FastMPS replaces it with the analytic Zassenhaus
//! factorization for the specific tridiagonal generator `μa† − μ*a`, which
//! the paper reports as >10× faster. We keep the general routine both as
//! the ablation comparator and as the correctness oracle for the fast path.

use crate::util::num::Float;

use crate::linalg::{gemm, lu_decompose, lu_solve_in_place};
use crate::tensor::{Complex, Mat};
use crate::util::error::Result;

/// Padé-13 coefficients (Higham, Table 10.4).
const B13: [f64; 14] = [
    64764752532480000.0,
    32382376266240000.0,
    7771770303897600.0,
    1187353796428800.0,
    129060195264000.0,
    10559470521600.0,
    670442572800.0,
    33522128640.0,
    1323241920.0,
    40840800.0,
    960960.0,
    16380.0,
    182.0,
    1.0,
];

/// θ₁₃ from Higham: ‖A‖₁ below this needs no scaling.
const THETA13: f64 = 5.371920351148152;

fn one_norm<T: Float + std::ops::AddAssign>(a: &Mat<T>) -> T {
    let mut best = T::zero();
    for c in 0..a.cols {
        let mut s = T::zero();
        for r in 0..a.rows {
            s += a[(r, c)].abs();
        }
        if s > best {
            best = s;
        }
    }
    best
}

fn add_scaled<T: Float + std::ops::AddAssign>(acc: &mut Mat<T>, m: &Mat<T>, s: T) {
    for (a, b) in acc.data.iter_mut().zip(&m.data) {
        *a += b.scale(s);
    }
}

/// Matrix exponential of a square complex matrix.
pub fn expm<T: Float + std::ops::AddAssign + std::ops::SubAssign + Send + Sync>(
    a: &Mat<T>,
) -> Result<Mat<T>> {
    let n = a.rows;
    let norm = one_norm(a).to_f64().unwrap_or(f64::INFINITY);

    // Scaling: A/2^s with ‖A/2^s‖₁ ≤ θ₁₃.
    let s = if norm > THETA13 {
        (norm / THETA13).log2().ceil() as i32
    } else {
        0
    };
    let mut a_s = a.clone();
    if s > 0 {
        let f = T::from(2f64.powi(-s)).unwrap();
        a_s.scale_in_place(f);
    }

    // Powers A², A⁴, A⁶.
    let a2 = gemm(&a_s, &a_s, 1)?;
    let a4 = gemm(&a2, &a2, 1)?;
    let a6 = gemm(&a2, &a4, 1)?;

    let b = |i: usize| T::from(B13[i]).unwrap();

    // U = A·[A⁶·(b13·A⁶ + b11·A⁴ + b9·A²) + b7·A⁶ + b5·A⁴ + b3·A² + b1·I]
    let mut w1 = Mat::zeros(n, n);
    add_scaled(&mut w1, &a6, b(13));
    add_scaled(&mut w1, &a4, b(11));
    add_scaled(&mut w1, &a2, b(9));
    let mut u_inner = gemm(&a6, &w1, 1)?;
    add_scaled(&mut u_inner, &a6, b(7));
    add_scaled(&mut u_inner, &a4, b(5));
    add_scaled(&mut u_inner, &a2, b(3));
    for i in 0..n {
        u_inner[(i, i)] += Complex::from_re(b(1));
    }
    let u = gemm(&a_s, &u_inner, 1)?;

    // V = A⁶·(b12·A⁶ + b10·A⁴ + b8·A²) + b6·A⁶ + b4·A⁴ + b2·A² + b0·I
    let mut w2 = Mat::zeros(n, n);
    add_scaled(&mut w2, &a6, b(12));
    add_scaled(&mut w2, &a4, b(10));
    add_scaled(&mut w2, &a2, b(8));
    let mut v = gemm(&a6, &w2, 1)?;
    add_scaled(&mut v, &a6, b(6));
    add_scaled(&mut v, &a4, b(4));
    add_scaled(&mut v, &a2, b(2));
    for i in 0..n {
        v[(i, i)] += Complex::from_re(b(0));
    }

    // R = (V − U)⁻¹ (V + U)
    let mut vmu = v.clone();
    for (x, u_) in vmu.data.iter_mut().zip(&u.data) {
        *x -= *u_;
    }
    let mut vpu = v;
    for (x, u_) in vpu.data.iter_mut().zip(&u.data) {
        *x += *u_;
    }
    let f = lu_decompose(&vmu)?;
    lu_solve_in_place(&f, &mut vpu)?;
    let mut r = vpu;

    // Undo scaling: square s times.
    for _ in 0..s {
        r = gemm(&r, &r, 1)?;
    }
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;
    use crate::tensor::C64;

    #[test]
    fn expm_zero_is_identity() {
        let a: Mat<f64> = Mat::zeros(4, 4);
        let e = expm(&a).unwrap();
        for i in 0..4 {
            for j in 0..4 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((e[(i, j)].re - want).abs() < 1e-14);
                assert!(e[(i, j)].im.abs() < 1e-14);
            }
        }
    }

    #[test]
    fn expm_diagonal() {
        let mut a: Mat<f64> = Mat::zeros(3, 3);
        a[(0, 0)] = C64::new(1.0, 0.0);
        a[(1, 1)] = C64::new(-2.0, 0.5);
        a[(2, 2)] = C64::new(0.0, std::f64::consts::PI);
        let e = expm(&a).unwrap();
        for i in 0..3 {
            let want = a[(i, i)].exp();
            assert!((e[(i, i)] - want).abs() < 1e-12, "i={i}");
        }
        assert!(e[(0, 1)].abs() < 1e-13);
    }

    #[test]
    fn expm_nilpotent_exact() {
        // N = [[0,1],[0,0]] → e^N = I + N exactly.
        let mut a: Mat<f64> = Mat::zeros(2, 2);
        a[(0, 1)] = C64::one();
        let e = expm(&a).unwrap();
        assert!((e[(0, 0)].re - 1.0).abs() < 1e-14);
        assert!((e[(0, 1)].re - 1.0).abs() < 1e-14);
        assert!(e[(1, 0)].abs() < 1e-14);
        assert!((e[(1, 1)].re - 1.0).abs() < 1e-14);
    }

    #[test]
    fn expm_inverse_property() {
        // e^A · e^{-A} = I.
        let mut rng = Xoshiro256::seed_from(31);
        for n in [2, 5, 9] {
            let a = Mat::from_vec(
                n,
                n,
                (0..n * n)
                    .map(|_| C64::new(rng.normal() * 0.8, rng.normal() * 0.8))
                    .collect(),
            )
            .unwrap();
            let mut neg = a.clone();
            neg.scale_in_place(-1.0);
            let p = gemm(&expm(&a).unwrap(), &expm(&neg).unwrap(), 1).unwrap();
            for i in 0..n {
                for j in 0..n {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!(
                        (p[(i, j)].re - want).abs() < 1e-9 && p[(i, j)].im.abs() < 1e-9,
                        "n={n} i={i} j={j} got {}",
                        p[(i, j)]
                    );
                }
            }
        }
    }

    #[test]
    fn expm_large_norm_uses_scaling() {
        // ‖A‖ ≫ θ₁₃ exercises the squaring phase.
        let mut a: Mat<f64> = Mat::zeros(2, 2);
        a[(0, 0)] = C64::new(10.0, 0.0);
        a[(1, 1)] = C64::new(-30.0, 2.0);
        let e = expm(&a).unwrap();
        assert!((e[(0, 0)].re - 10f64.exp()).abs() / 10f64.exp() < 1e-10);
        let want = C64::new(-30.0, 2.0).exp();
        assert!((e[(1, 1)] - want).abs() < want.abs() * 1e-9 + 1e-14);
    }

    #[test]
    fn expm_commuting_sum() {
        // For commuting A,B: e^{A+B} = e^A e^B. Use two diagonals.
        let mut a: Mat<f64> = Mat::zeros(3, 3);
        let mut b: Mat<f64> = Mat::zeros(3, 3);
        for i in 0..3 {
            a[(i, i)] = C64::new(0.3 * i as f64, -0.2);
            b[(i, i)] = C64::new(-0.1, 0.4 * i as f64);
        }
        let mut ab = a.clone();
        for (x, y) in ab.data.iter_mut().zip(&b.data) {
            *x += *y;
        }
        let lhs = expm(&ab).unwrap();
        let rhs = gemm(&expm(&a).unwrap(), &expm(&b).unwrap(), 1).unwrap();
        for (l, r) in lhs.data.iter().zip(&rhs.data) {
            assert!((*l - *r).abs() < 1e-11);
        }
    }
}
