//! Native complex linear algebra.
//!
//! This is the substrate behind the native sampling engine (the correctness
//! oracle for the XLA hot path and the precision studies), the model-parallel
//! baseline, and the GBS displacement optimization (§3.4.1):
//!
//! - [`gemm`]: blocked, multi-threaded complex matrix multiply;
//! - [`contract_env`]: the paper's bond contraction `(N,χ)×(χ,χ,d)→(N,χ,d)`
//!   expressed as a GEMM over the flattened `(χ, χ·d)` site tensor;
//! - [`lu`]: LU decomposition with partial pivoting (complex solve, used by
//!   the Padé matrix exponential);
//! - [`expm`]: general scaling-and-squaring Padé-13 `expm` — the *baseline*
//!   the paper says Eigen/SciPy provide;
//! - [`displacement`]: the paper's fast analytic construction
//!   `e^{μa†−μ*a} ≈ e^{−|μ|²/2}·e^{μa†}·e^{−μ*a}` (Zassenhaus split into a
//!   lower- and an upper-triangular factor, >10× cheaper).

mod displacement;
mod expm;
mod gemm;
mod lu;
pub mod pool;

pub use displacement::{
    displacement_exact, displacement_fast, displacement_fast_batch,
    displacement_fast_batch_into, ladder_matrix, DisplacementWs,
};
pub use expm::expm;
pub use gemm::{
    choose_split, contract_env, contract_env_into, contract_env_into_on, gemm, gemm_acc,
    gemm_acc_split, gemm_acc_split_on, gemm_ovw_split_on, gemv, gemv_into, matmul_flops,
    planar_contract_env_into_on, GemmSplit, PlanarScalar,
};
pub use lu::{lu_decompose, lu_solve_in_place, Lu};
pub(crate) use gemm::SendPtr;
pub use pool::{Exec, WorkerPool};
