//! Resident worker pool for the step hot path.
//!
//! `std::thread::scope` spawns and joins OS threads on every call, which
//! the threaded GEMM/measurement kernels used to pay per step. The
//! [`WorkerPool`] parks `width - 1` worker threads once at engine (or TP
//! session) construction and hands them work through an epoch counter
//! under a mutex/condvar pair — no per-dispatch heap allocation, which
//! keeps pooled steps inside the counting-allocator zero-alloc gate.
//!
//! Dispatch contract: [`WorkerPool::run`] invokes `f(part, width)` for
//! every `part in 0..width`, exactly once each. Part 0 runs on the
//! calling thread (which then blocks until the remaining parts finish),
//! so borrowing caller-stack data inside `f` is sound: `run` returns only
//! after every worker has finished with it. Which thread executes which
//! part never affects results — callers partition output into disjoint
//! regions and each element is computed by exactly one part, which is
//! what preserves the bit-identity discipline of the kernels.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// How a threaded kernel call is executed. The partition arithmetic is
/// identical either way (see `dispatch_regions` in `gemm`), so switching
/// between variants never changes results — only who runs the parts.
#[derive(Clone, Copy)]
pub enum Exec<'p> {
    /// Per-call `std::thread::scope` spawns (the pre-pool behaviour);
    /// width ≤ 1 executes inline with no scope at all.
    Scoped(usize),
    /// Dispatch through a resident [`WorkerPool`] — no spawn, no
    /// steady-state allocation.
    Pooled(&'p WorkerPool),
}

impl Exec<'_> {
    /// Maximum useful partition count for this executor.
    pub fn width(self) -> usize {
        match self {
            Exec::Scoped(t) => t.max(1),
            Exec::Pooled(p) => p.width(),
        }
    }

    /// Run `f(part)` exactly once for every `part in 0..parts`, returning
    /// after all complete. `parts` beyond [`Exec::width`] are still
    /// honoured (pooled dispatch folds the excess onto part 0's thread
    /// order — callers never ask for more parts than `width`, but the
    /// contract stays total either way).
    pub fn run_parts<F: Fn(usize) + Sync>(self, parts: usize, f: F) {
        let parts = parts.max(1);
        if parts == 1 {
            f(0);
            return;
        }
        match self {
            Exec::Scoped(_) => {
                std::thread::scope(|scope| {
                    let fr = &f;
                    for t in 1..parts {
                        scope.spawn(move || fr(t));
                    }
                    fr(0);
                });
            }
            Exec::Pooled(pool) => {
                let width = pool.width();
                pool.run(&|part, _| {
                    // Parts are striped across the pool so a pool narrower
                    // than `parts` still covers every part exactly once.
                    let mut p = part;
                    while p < parts {
                        f(p);
                        p += width;
                    }
                });
            }
        }
    }
}

/// Type-erased task: a monomorphized trampoline plus a pointer to the
/// caller's closure on its stack. No `Box`, so dispatch never allocates.
#[derive(Clone, Copy)]
struct Task {
    call: unsafe fn(*const (), usize, usize),
    ctx: *const (),
}

// SAFETY: the ctx pointer is only dereferenced while `run` is blocked on
// the completion condvar, so the closure it points at outlives every use;
// the closure itself is required to be Sync.
unsafe impl Send for Task {}

struct State {
    epoch: u64,
    task: Option<Task>,
    remaining: usize,
    shutdown: bool,
}

struct Inner {
    state: Mutex<State>,
    work: Condvar,
    done: Condvar,
    wakeups: AtomicU64,
    park_ns: AtomicU64,
}

/// Parked resident worker threads; see the module docs for the dispatch
/// contract.
pub struct WorkerPool {
    inner: Arc<Inner>,
    handles: Vec<JoinHandle<()>>,
    width: usize,
    /// Serializes dispatches: `run` takes `&self`, so without this two
    /// threads could interleave epoch bumps and return while the other's
    /// closure is still executing.
    gate: Mutex<()>,
}

impl WorkerPool {
    /// A pool that partitions work `width` ways: the caller plus
    /// `width - 1` parked workers. `width <= 1` spawns no threads and
    /// `run` executes inline.
    pub fn new(width: usize) -> Self {
        let width = width.max(1);
        let inner = Arc::new(Inner {
            state: Mutex::new(State {
                epoch: 0,
                task: None,
                remaining: 0,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
            wakeups: AtomicU64::new(0),
            park_ns: AtomicU64::new(0),
        });
        let mut handles = Vec::with_capacity(width - 1);
        for part in 1..width {
            let inner = Arc::clone(&inner);
            handles.push(std::thread::spawn(move || worker_loop(&inner, part, width)));
        }
        WorkerPool {
            inner,
            handles,
            width,
            gate: Mutex::new(()),
        }
    }

    /// Number of parts `run` dispatches (caller included).
    pub fn width(&self) -> usize {
        self.width
    }

    /// Run `f(part, width)` for every part in `0..width`; returns after
    /// all parts complete. Zero heap allocations.
    pub fn run<F: Fn(usize, usize) + Sync>(&self, f: &F) {
        if self.handles.is_empty() {
            f(0, self.width);
            return;
        }
        let _gate = self.gate.lock().unwrap();
        unsafe fn trampoline<F: Fn(usize, usize) + Sync>(ctx: *const (), part: usize, n: usize) {
            (*(ctx as *const F))(part, n);
        }
        let task = Task {
            call: trampoline::<F>,
            ctx: f as *const F as *const (),
        };
        {
            let mut st = self.inner.state.lock().unwrap();
            st.task = Some(task);
            st.epoch += 1;
            st.remaining = self.handles.len();
            self.inner.work.notify_all();
        }
        // The caller is part 0 — it works instead of idling on the join.
        f(0, self.width);
        let mut st = self.inner.state.lock().unwrap();
        while st.remaining > 0 {
            st = self.inner.done.wait(st).unwrap();
        }
        st.task = None;
    }

    /// Drain the (wakeups, park nanoseconds) counters, resetting them to
    /// zero — fed into `pool_wakeups` / `pool_park_ns` metrics.
    pub fn take_counters(&self) -> (u64, u64) {
        (
            self.inner.wakeups.swap(0, Ordering::Relaxed),
            self.inner.park_ns.swap(0, Ordering::Relaxed),
        )
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.shutdown = true;
            self.inner.work.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(inner: &Inner, part: usize, width: usize) {
    let mut seen = 0u64;
    loop {
        let task = {
            let mut st = inner.state.lock().unwrap();
            let parked = Instant::now();
            while st.epoch == seen && !st.shutdown {
                st = inner.work.wait(st).unwrap();
            }
            inner
                .park_ns
                .fetch_add(parked.elapsed().as_nanos() as u64, Ordering::Relaxed);
            if st.shutdown {
                return;
            }
            seen = st.epoch;
            inner.wakeups.fetch_add(1, Ordering::Relaxed);
            st.task.expect("epoch advanced without a task")
        };
        // SAFETY: `run` blocks until `remaining` hits zero, so the closure
        // behind ctx is live for the whole call.
        unsafe { (task.call)(task.ctx, part, width) };
        let mut st = inner.state.lock().unwrap();
        st.remaining -= 1;
        if st.remaining == 0 {
            inner.done.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn every_part_runs_exactly_once_per_dispatch() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.width(), 4);
        let mut hits = vec![0usize; 4];
        for round in 1..=5 {
            let counters: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
            pool.run(&|part, n| {
                assert_eq!(n, 4);
                counters[part].fetch_add(1, Ordering::SeqCst);
            });
            for (h, c) in hits.iter_mut().zip(&counters) {
                *h += c.load(Ordering::SeqCst);
            }
            assert!(hits.iter().all(|&h| h == round));
        }
        let (wakeups, _park) = pool.take_counters();
        // 3 workers × 5 dispatches.
        assert_eq!(wakeups, 15);
        // Counters drain on read.
        assert_eq!(pool.take_counters().0, 0);
    }

    #[test]
    fn width_one_runs_inline() {
        let pool = WorkerPool::new(1);
        let ran = AtomicUsize::new(0);
        pool.run(&|part, n| {
            assert_eq!((part, n), (0, 1));
            ran.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ran.load(Ordering::SeqCst), 1);
        assert_eq!(pool.take_counters(), (0, 0));
    }

    #[test]
    fn caller_stack_borrows_are_visible_to_workers() {
        let pool = WorkerPool::new(3);
        let data = vec![0u64; 300];
        let out: Vec<AtomicU64> = data.iter().map(|_| AtomicU64::new(0)).collect();
        pool.run(&|part, n| {
            let per = data.len().div_ceil(n);
            let lo = part * per;
            let hi = ((part + 1) * per).min(data.len());
            for i in lo..hi {
                out[i].store(i as u64 + 1, Ordering::Relaxed);
            }
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(v.load(Ordering::Relaxed), i as u64 + 1);
        }
    }

    #[test]
    fn steady_state_dispatch_is_allocation_free() {
        let pool = WorkerPool::new(3);
        let sink = AtomicU64::new(0);
        // Warm up: first dispatches may fault in condvar/futex state.
        for _ in 0..4 {
            pool.run(&|p, _| {
                sink.fetch_add(p as u64, Ordering::Relaxed);
            });
        }
        // Other tests run concurrently under the same global counting
        // allocator, so retry for a clean window instead of asserting a
        // single quiet one.
        let mut clean = false;
        for _ in 0..128 {
            let before = crate::util::alloc::allocation_count();
            for _ in 0..8 {
                pool.run(&|p, _| {
                    sink.fetch_add(p as u64, Ordering::Relaxed);
                });
            }
            if crate::util::alloc::allocation_count() == before {
                clean = true;
                break;
            }
        }
        assert!(clean, "pooled dispatch allocated in every sampled window");
    }
}
