//! Complex LU decomposition with partial pivoting — the solve behind the
//! Padé matrix exponential (the paper's "general implementation in Eigen and
//! SciPy" baseline for the ablation).

use crate::util::num::Float;

use crate::tensor::Mat;
use crate::util::error::{Error, Result};

/// Packed LU factors: `lu` holds L (unit diagonal, below) and U (on/above),
/// `piv[i]` is the row swapped into position i.
#[derive(Debug, Clone)]
pub struct Lu<T> {
    pub lu: Mat<T>,
    pub piv: Vec<usize>,
}

/// Factor a square complex matrix (Doolittle with partial pivoting).
pub fn lu_decompose<T: Float + std::ops::AddAssign + std::ops::SubAssign>(
    a: &Mat<T>,
) -> Result<Lu<T>> {
    if a.rows != a.cols {
        return Err(Error::shape(format!("lu: {}×{} not square", a.rows, a.cols)));
    }
    let n = a.rows;
    let mut lu = a.clone();
    let mut piv: Vec<usize> = (0..n).collect();

    for k in 0..n {
        // Pivot: largest |entry| in column k at/below the diagonal.
        let mut p = k;
        let mut pmax = lu[(k, k)].norm_sq();
        for r in k + 1..n {
            let v = lu[(r, k)].norm_sq();
            if v > pmax {
                pmax = v;
                p = r;
            }
        }
        if pmax == T::zero() {
            return Err(Error::numeric(format!("lu: singular at column {k}")));
        }
        if p != k {
            piv.swap(k, p);
            for c in 0..n {
                let tmp = lu[(k, c)];
                lu[(k, c)] = lu[(p, c)];
                lu[(p, c)] = tmp;
            }
        }
        let inv_kk = lu[(k, k)].inv();
        for r in k + 1..n {
            let m = lu[(r, k)] * inv_kk;
            lu[(r, k)] = m;
            for c in k + 1..n {
                let s = m * lu[(k, c)];
                lu[(r, c)] -= s;
            }
        }
    }
    Ok(Lu { lu, piv })
}

/// Solve `A·X = B` in place: `b` enters as B (row-major, same row count as
/// A) and leaves as X.
pub fn lu_solve_in_place<T: Float + std::ops::AddAssign + std::ops::SubAssign>(
    f: &Lu<T>,
    b: &mut Mat<T>,
) -> Result<()> {
    let n = f.lu.rows;
    if b.rows != n {
        return Err(Error::shape(format!(
            "lu_solve: rhs has {} rows, expected {n}",
            b.rows
        )));
    }
    let ncols = b.cols;

    // Apply the pivot permutation.
    let mut x = Mat::zeros(n, ncols);
    for i in 0..n {
        let src = f.piv[i];
        x.row_mut(i).copy_from_slice(b.row(src));
    }

    // Forward substitution (L has unit diagonal).
    for i in 0..n {
        for k in 0..i {
            let l = f.lu[(i, k)];
            if l.re == T::zero() && l.im == T::zero() {
                continue;
            }
            let (head, tail) = x.data.split_at_mut(i * ncols);
            let xk = &head[k * ncols..(k + 1) * ncols];
            let xi = &mut tail[..ncols];
            for c in 0..ncols {
                let s = l * xk[c];
                xi[c] -= s;
            }
        }
    }

    // Back substitution.
    for i in (0..n).rev() {
        for k in i + 1..n {
            let u = f.lu[(i, k)];
            if u.re == T::zero() && u.im == T::zero() {
                continue;
            }
            let (head, tail) = x.data.split_at_mut(k * ncols);
            let xi = &mut head[i * ncols..(i + 1) * ncols];
            let xk = &tail[..ncols];
            for c in 0..ncols {
                let s = u * xk[c];
                xi[c] -= s;
            }
        }
        let inv = f.lu[(i, i)].inv();
        for c in 0..ncols {
            x[(i, c)] = x[(i, c)] * inv;
        }
    }

    *b = x;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm;
    use crate::rng::Xoshiro256;
    use crate::tensor::C64;

    fn random_mat(rng: &mut Xoshiro256, n: usize) -> Mat<f64> {
        Mat::from_vec(
            n,
            n,
            (0..n * n)
                .map(|_| C64::new(rng.normal(), rng.normal()))
                .collect(),
        )
        .unwrap()
    }

    #[test]
    fn solve_recovers_rhs() {
        let mut rng = Xoshiro256::seed_from(21);
        for n in [1, 2, 5, 12] {
            let a = random_mat(&mut rng, n);
            let x_true = random_mat(&mut rng, n);
            let b = gemm(&a, &x_true, 1).unwrap();
            let f = lu_decompose(&a).unwrap();
            let mut x = b.clone();
            lu_solve_in_place(&f, &mut x).unwrap();
            for (g, w) in x.data.iter().zip(&x_true.data) {
                assert!((*g - *w).abs() < 1e-9, "n={n}");
            }
        }
    }

    #[test]
    fn singular_detected() {
        let a: Mat<f64> = Mat::zeros(3, 3);
        assert!(lu_decompose(&a).is_err());
        let mut b: Mat<f64> = Mat::eye(3);
        b[(2, 2)] = C64::zero();
        assert!(lu_decompose(&b).is_err());
    }

    #[test]
    fn pivoting_handles_zero_diagonal() {
        // [[0,1],[1,0]] is perfectly conditioned but needs the pivot.
        let a = Mat::from_vec(
            2,
            2,
            vec![C64::zero(), C64::one(), C64::one(), C64::zero()],
        )
        .unwrap();
        let f = lu_decompose(&a).unwrap();
        let mut b = Mat::from_vec(2, 1, vec![C64::new(2.0, 0.0), C64::new(3.0, 0.0)]).unwrap();
        lu_solve_in_place(&f, &mut b).unwrap();
        assert!((b[(0, 0)] - C64::new(3.0, 0.0)).abs() < 1e-12);
        assert!((b[(1, 0)] - C64::new(2.0, 0.0)).abs() < 1e-12);
    }

    #[test]
    fn non_square_rejected() {
        let a: Mat<f64> = Mat::zeros(2, 3);
        assert!(lu_decompose(&a).is_err());
    }
}
