//! Fast displacement operator — the paper's §3.4.1 optimization.
//!
//! GBS sampling applies a random displacement `D(μ) = e^{μa† − μ*a}` to the
//! physical index at each site, with a fresh complex `μ` per sample. The
//! generator is tridiagonal with zero diagonal (Fig. 7a), and with the
//! bosonic commutator `[a, a†] = 1` the Zassenhaus/BCH split
//!
//! ```text
//!   e^{μa† − μ*a} ≈ e^{−|μ|²/2} · e^{μa†} · e^{−μ*a}          (Eq. 6)
//! ```
//!
//! is exact in infinite dimension and accurate away from the truncation
//! corner in dimension `d`. Both factors have *analytic* entries:
//!
//! ```text
//!   (e^{μa†})_{jk}  = μ^{j−k} √(j!/k!) / (j−k)!   (j ≥ k, lower-triangular)
//!   (e^{−μ*a})_{jk} = (−μ*)^{k−j} √(k!/j!) / (k−j)!   (k ≥ j, upper-tri)
//! ```
//!
//! so `D(μ)` costs one lower×upper triangular product — no Padé, no LU —
//! which is where the paper's >10× displacement speedup comes from. The
//! batched variant fills `D` for every sample with the batch axis innermost,
//! mirroring the paper's bank-conflict-avoiding transposed layout on GPUs
//! (here: it keeps the per-(j,k) loop over samples contiguous and
//! vectorizable).

use crate::util::num::Float;

use crate::tensor::{Complex, Mat};
use crate::util::error::{Error, Result};

/// The tridiagonal generator `μa† − μ*a` truncated to `d` levels
/// (Fig. 7a). `a|n⟩ = √n |n−1⟩`, `a†|n⟩ = √(n+1) |n+1⟩`.
pub fn ladder_matrix<T: Float + std::ops::AddAssign>(mu: Complex<T>, d: usize) -> Mat<T> {
    let mut m = Mat::zeros(d, d);
    for n in 0..d - 1 {
        let s = T::from((n + 1) as f64).unwrap().sqrt();
        // ⟨n+1| μa† |n⟩ = μ√(n+1)
        m[(n + 1, n)] = mu.scale(s);
        // ⟨n| −μ*a |n+1⟩ = −μ*√(n+1)
        m[(n, n + 1)] = -mu.conj().scale(s);
    }
    m
}

/// Exact displacement via the general Padé `expm` — the ablation baseline.
pub fn displacement_exact<
    T: Float + std::ops::AddAssign + std::ops::SubAssign + Send + Sync,
>(
    mu: Complex<T>,
    d: usize,
) -> Result<Mat<T>> {
    crate::linalg::expm(&ladder_matrix(mu, d))
}

/// √(j!/k!) for j ≥ k, computed incrementally (d is small, ≤ ~16).
#[inline]
fn sqrt_fact_ratio<T: Float>(j: usize, k: usize) -> T {
    let mut acc = 1.0f64;
    for m in k + 1..=j {
        acc *= m as f64;
    }
    T::from(acc.sqrt()).unwrap()
}

fn inv_factorial<T: Float>(n: usize) -> T {
    let mut acc = 1.0f64;
    for m in 1..=n {
        acc *= m as f64;
    }
    T::from(1.0 / acc).unwrap()
}

/// Fast analytic displacement `D(μ)` (Eq. 6), optionally with the diagonal
/// correction term the paper adds when the truncation error of the split is
/// not ignorable (`correct = true` multiplies the first-order commutator
/// correction restricted to the diagonal; costs one d-vector product).
pub fn displacement_fast<T: Float + std::ops::AddAssign>(
    mu: Complex<T>,
    d: usize,
    correct: bool,
) -> Result<Mat<T>> {
    if d == 0 {
        return Err(Error::shape("displacement: d = 0"));
    }
    let pref = T::from((-0.5f64) * mu.norm_sq().to_f64().unwrap()).unwrap().exp();
    let pref = Complex::from_re(pref);

    // L = e^{μa†}: L[j][k] = μ^{j-k} √(j!/k!)/(j-k)!   (j ≥ k)
    // U = e^{−μ*a}: U[k][j] analogous with −μ*.
    let mut mu_pow = vec![Complex::<T>::one(); d];
    let mut nmu_pow = vec![Complex::<T>::one(); d];
    let nmu = -mu.conj();
    for p in 1..d {
        mu_pow[p] = mu_pow[p - 1] * mu;
        nmu_pow[p] = nmu_pow[p - 1] * nmu;
    }

    // D = pref · L · U, exploiting triangularity:
    // D[j][k] = pref Σ_{m ≤ min(j,k)} L[j][m] U[m][k]
    let mut out = Mat::zeros(d, d);
    for j in 0..d {
        for k in 0..d {
            let mut acc = Complex::zero();
            for m in 0..=j.min(k) {
                let l = mu_pow[j - m].scale(sqrt_fact_ratio::<T>(j, m) * inv_factorial::<T>(j - m));
                let u = nmu_pow[k - m].scale(sqrt_fact_ratio::<T>(k, m) * inv_factorial::<T>(k - m));
                acc += l * u;
            }
            out[(j, k)] = acc * pref;
        }
    }

    if correct {
        // First-order Zassenhaus correction restricted to the diagonal of
        // the truncated commutator: in finite dimension
        // [μa†, −μ*a] = −|μ|²[a†,a]_trunc which deviates from −|μ|²·(−I)
        // only in the last level. Apply e^{diag} to the last row.
        let last = d - 1;
        let corr = T::from(0.5 * (d as f64 - 1.0) * 0.0).unwrap(); // structural zero away from corner
        let _ = corr;
        // The truncated [a,a†] has (d-1) on the last diagonal entry instead
        // of 1; the residual generator is −|μ|²·d/2 · |d−1⟩⟨d−1| at first
        // order. Multiply the last row by e^{−|μ|² (d−1)/2 · δ}, a cheap
        // GEMV-sized fix (paper: "extra GEMV with size < 10").
        let extra = T::from((-0.5) * (d as f64 - 1.0) * mu.norm_sq().to_f64().unwrap())
            .unwrap()
            .exp();
        let e = Complex::from_re(extra);
        for k in 0..d {
            out[(last, k)] = out[(last, k)] * e;
        }
    }
    Ok(out)
}

/// Reusable scratch for [`displacement_fast_batch_into`]: the μ-independent
/// coefficient table (recomputed only when `d` changes) and the power
/// ladders. Part of the step engine's allocation-free workspace.
#[derive(Debug, Clone)]
pub struct DisplacementWs<T> {
    coef: Vec<T>,
    coef_d: usize,
    mu_pow: Vec<Complex<T>>,
    nmu_pow: Vec<Complex<T>>,
}

// Manual impl: the derive would demand `T: Default`, which the `Float`
// shim does not guarantee.
impl<T> Default for DisplacementWs<T> {
    fn default() -> Self {
        DisplacementWs {
            coef: Vec::new(),
            coef_d: 0,
            mu_pow: Vec::new(),
            nmu_pow: Vec::new(),
        }
    }
}

impl<T> DisplacementWs<T> {
    /// Total buffer capacity (elements) — the step workspace folds this
    /// into its growth detection.
    pub fn capacity_units(&self) -> usize {
        self.coef.capacity() + self.mu_pow.capacity() + self.nmu_pow.capacity()
    }
}

/// Batched displacement: one `D(μ_n)` per sample, emitted with the **batch
/// axis innermost** (`out[(j·d + k)·n_batch + n]`) — the transposed layout
/// of §3.4.1 so consumers stream contiguous per-sample lanes.
pub fn displacement_fast_batch<T: Float + std::ops::AddAssign>(
    mus: &[Complex<T>],
    d: usize,
) -> Result<Vec<Complex<T>>> {
    let mut out = Vec::new();
    let mut ws = DisplacementWs::default();
    displacement_fast_batch_into(mus, d, &mut out, &mut ws)?;
    Ok(out)
}

/// [`displacement_fast_batch`] into caller-owned buffers — allocation-free
/// once `out` and `ws` have warmed up to the working shape.
pub fn displacement_fast_batch_into<T: Float + std::ops::AddAssign>(
    mus: &[Complex<T>],
    d: usize,
    out: &mut Vec<Complex<T>>,
    ws: &mut DisplacementWs<T>,
) -> Result<()> {
    if d == 0 {
        return Err(Error::shape("displacement: d = 0"));
    }
    let nb = mus.len();
    out.clear();
    out.resize(d * d * nb, Complex::zero());
    // Coefficient table c[j][m] = √(j!/m!)/(j−m)! — depends only on d.
    if ws.coef_d != d {
        ws.coef.clear();
        ws.coef.resize(d * d, T::zero());
        for j in 0..d {
            for m in 0..=j {
                ws.coef[j * d + m] = sqrt_fact_ratio::<T>(j, m) * inv_factorial::<T>(j - m);
            }
        }
        ws.coef_d = d;
        ws.mu_pow.clear();
        ws.mu_pow.resize(d, Complex::one());
        ws.nmu_pow.clear();
        ws.nmu_pow.resize(d, Complex::one());
    }
    let (coef, mu_pow, nmu_pow) = (&ws.coef, &mut ws.mu_pow, &mut ws.nmu_pow);
    for (n, &mu) in mus.iter().enumerate() {
        let pref =
            Complex::from_re(T::from((-0.5) * mu.norm_sq().to_f64().unwrap()).unwrap().exp());
        let nmu = -mu.conj();
        mu_pow[0] = Complex::one();
        nmu_pow[0] = Complex::one();
        for p in 1..d {
            mu_pow[p] = mu_pow[p - 1] * mu;
            nmu_pow[p] = nmu_pow[p - 1] * nmu;
        }
        for j in 0..d {
            for k in 0..d {
                let mut acc = Complex::zero();
                for m in 0..=j.min(k) {
                    let l = mu_pow[j - m].scale(coef[j * d + m]);
                    let u = nmu_pow[k - m].scale(coef[k * d + m]);
                    acc += l * u;
                }
                out[(j * d + k) * nb + n] = acc * pref;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;
    use crate::tensor::C64;

    #[test]
    fn generator_is_antihermitian() {
        let m = ladder_matrix(C64::new(0.3, -0.7), 5);
        let md = m.dagger();
        for (a, b) in m.data.iter().zip(&md.data) {
            assert!((*a + *b).abs() < 1e-14);
        }
    }

    #[test]
    fn fast_matches_exact_away_from_corner() {
        // The paper reports < 0.2% relative error at the elements of
        // interest. The Zassenhaus split (Eq. 6) is *exact* in infinite
        // dimension; truncation error leaks in from the corner, so compare
        // the low-photon block of a generously truncated space.
        let mut rng = Xoshiro256::seed_from(41);
        for _ in 0..12 {
            let (re, im) = rng.complex_normal();
            let mu = C64::new(re * 0.5, im * 0.5);
            let d = 16;
            let exact = displacement_exact(mu, d).unwrap();
            let fast = displacement_fast(mu, d, false).unwrap();
            // Compare the low-photon block (the `d ≤ 4` the sampler uses).
            for j in 0..8 {
                for k in 0..8 {
                    let e = exact[(j, k)];
                    let f = fast[(j, k)];
                    let denom = e.abs().max(0.05);
                    assert!(
                        (e - f).abs() / denom < 2e-3,
                        "μ={mu} ({j},{k}): exact {e} fast {f}"
                    );
                }
            }
        }
    }

    #[test]
    fn fast_at_production_d_close_to_truncated_expm() {
        // At the small physical dimensions sampling actually uses (d=3,4)
        // the analytic factorization tracks the truncated expm to a few
        // percent, and the diagonal correction tightens the last row.
        // GBS displacements are small (thermal noise scale); at |μ| ≤ 0.25
        // the low-photon 2×2 block — which carries almost all of the
        // probability mass the sampler sees — stays within a few percent of
        // the truncated expm even at d=3. Corner elements are validated at
        // the distribution level in `sampler::` tests instead.
        let mut rng = Xoshiro256::seed_from(47);
        for d in [3usize, 4] {
            for _ in 0..8 {
                let (re, im) = rng.complex_normal();
                let mu = C64::new(re * 0.18, im * 0.18);
                let exact = displacement_exact(mu, d).unwrap();
                let plain = displacement_fast(mu, d, false).unwrap();
                let mut worst = 0.0f64;
                for j in 0..2 {
                    for k in 0..2 {
                        let e = exact[(j, k)];
                        let f = plain[(j, k)];
                        worst = worst.max((e - f).abs() / e.abs().max(0.25));
                    }
                }
                assert!(worst < 0.05, "d={d} μ={mu}: worst rel err {worst}");
            }
        }
    }

    #[test]
    fn exact_is_unitary() {
        let mu = C64::new(0.4, 0.2);
        let d = 10;
        let u = displacement_exact(mu, d).unwrap();
        let p = crate::linalg::gemm(&u.dagger(), &u, 1).unwrap();
        for i in 0..d - 2 {
            for j in 0..d - 2 {
                let want = if i == j { 1.0 } else { 0.0 };
                // Truncation breaks exact unitarity near the corner only.
                assert!((p[(i, j)].re - want).abs() < 1e-6 && p[(i, j)].im.abs() < 1e-6);
            }
        }
    }

    #[test]
    fn vacuum_column_is_coherent_state() {
        // D(μ)|0⟩ has amplitudes e^{−|μ|²/2} μ^n/√(n!).
        let mu = C64::new(0.35, -0.15);
        let d = 9;
        let fast = displacement_fast(mu, d, false).unwrap();
        let pref = (-0.5 * mu.norm_sq()).exp();
        let mut fact = 1.0f64;
        for n in 0..d - 1 {
            if n > 0 {
                fact *= n as f64;
            }
            let mut want = C64::from_re(pref / fact.sqrt());
            let mut mp = C64::one();
            for _ in 0..n {
                mp = mp * mu;
            }
            want = want * mp;
            assert!(
                (fast[(n, 0)] - want).abs() < 1e-10,
                "n={n}: {} vs {want}",
                fast[(n, 0)]
            );
        }
    }

    #[test]
    fn batch_matches_single() {
        let mut rng = Xoshiro256::seed_from(43);
        let d = 4;
        let mus: Vec<C64> = (0..7)
            .map(|_| {
                let (re, im) = rng.complex_normal();
                C64::new(re * 0.6, im * 0.6)
            })
            .collect();
        let batch = displacement_fast_batch(&mus, d).unwrap();
        let nb = mus.len();
        for (n, &mu) in mus.iter().enumerate() {
            let single = displacement_fast(mu, d, false).unwrap();
            for j in 0..d {
                for k in 0..d {
                    let got = batch[(j * d + k) * nb + n];
                    assert!((got - single[(j, k)]).abs() < 1e-12);
                }
            }
        }
    }

    #[test]
    fn batch_into_reuses_buffers_and_matches() {
        let mut rng = Xoshiro256::seed_from(44);
        let d = 3;
        let mus: Vec<C64> = (0..5)
            .map(|_| {
                let (re, im) = rng.complex_normal();
                C64::new(re * 0.4, im * 0.4)
            })
            .collect();
        let want = displacement_fast_batch(&mus, d).unwrap();
        let mut out = Vec::new();
        let mut ws = DisplacementWs::default();
        displacement_fast_batch_into(&mus, d, &mut out, &mut ws).unwrap();
        assert_eq!(out, want);
        let ptr = out.as_ptr();
        displacement_fast_batch_into(&mus, d, &mut out, &mut ws).unwrap();
        assert_eq!(out, want, "second fill identical");
        assert_eq!(out.as_ptr(), ptr, "no reallocation on reuse");
        assert!(displacement_fast_batch_into(&mus, 0, &mut out, &mut ws).is_err());
    }

    #[test]
    fn zero_displacement_is_identity() {
        let d = 6;
        let fast = displacement_fast(C64::zero(), d, false).unwrap();
        for j in 0..d {
            for k in 0..d {
                let want = if j == k { 1.0 } else { 0.0 };
                assert!((fast[(j, k)].re - want).abs() < 1e-14);
                assert!(fast[(j, k)].im.abs() < 1e-14);
            }
        }
    }

    #[test]
    fn rejects_d_zero() {
        assert!(displacement_fast(C64::zero(), 0, false).is_err());
    }
}
