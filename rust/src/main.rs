//! FastMPS CLI entrypoint (L3 leader).

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = fastmps::cli::run_cli(&argv) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
