//! The resident sampling service — batching, caching, long-lived engines.
//!
//! One-shot CLI runs re-open the store and rebuild the engines on every
//! invocation; at service scale (the ROADMAP's heavy-traffic north star)
//! that tax dominates. This subsystem keeps everything hot:
//!
//! - [`JobQueue`] (`queue`) — admission control, FIFO ordering, per-job
//!   status/results, latency tracking;
//! - [`StoreCache`] (`cache`) — LRU of opened `GammaStore`s keyed by
//!   manifest hash, sharing one `DiskModel` across all prefetchers;
//! - `batcher` — coalesces compatible jobs into macro batches sized by the
//!   paper's §3.1 overlap condition (compute hides Γ I/O) under the Eq. 3
//!   memory budget;
//! - `worker` — a pool of threads with resident engines walking batches
//!   through the chain, one Γ stream per batch regardless of how many jobs
//!   share it;
//! - `api` — a transport: file-based job directory (`inbox/` → `status/` +
//!   `results/`) behind `fastmps serve` / `submit` / `jobs`.
//!
//! [`Service`] wires the pieces together; it is embeddable (tests and the
//! smoke benchmark run it in-process) and transport-agnostic.

pub mod api;
pub mod batcher;
pub mod cache;
pub mod job;
pub mod queue;
pub mod worker;

pub use batcher::{Batch, BatchKey};
pub use cache::StoreCache;
pub use job::{JobId, JobSpec, JobStatus, JobView, TpGroup, TpPeer};
pub use queue::{AdmissionLimits, Assignment, JobQueue};
pub use worker::Dispatch;

use std::path::Path;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::{ComputePrecision, ServiceConfig};
use crate::io::DiskModel;
use crate::metrics::{keys, Metrics};
use crate::trace::Recorder;
use crate::util::error::Result;
use crate::util::json::Json;

/// A running service instance. Dropping it drains and joins all threads.
pub struct Service {
    queue: Arc<JobQueue>,
    cache: Arc<StoreCache>,
    dispatch: Arc<Dispatch>,
    metrics: Arc<Mutex<Metrics>>,
    rec: Arc<Recorder>,
    cfg: ServiceConfig,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl Service {
    pub fn start(cfg: ServiceConfig) -> Result<Service> {
        cfg.validate()?;
        let disk = match cfg.disk_bw {
            Some(bw) => DiskModel::throttled(bw, false),
            None => DiskModel::unlimited(),
        };
        // One flight recorder shared by every service component, so a
        // job's queue, batcher, worker, and engine events interleave in
        // one ring and drain in one pass (`trace_json`).
        let rec = Arc::new(Recorder::new(cfg.trace_buf));
        let cache = Arc::new(StoreCache::new(cfg.cache_entries, disk.clone()));
        let queue = Arc::new(JobQueue::new_traced(
            AdmissionLimits {
                max_queue: cfg.max_queue,
                max_samples_per_job: cfg.max_samples_per_job,
            },
            rec.clone(),
        ));
        let dispatch = Arc::new(Dispatch::new());
        let metrics = Arc::new(Mutex::new(Metrics::new()));

        let workers = (0..cfg.workers)
            .map(|_| {
                let dispatch = dispatch.clone();
                let queue = queue.clone();
                let cfg = cfg.clone();
                let cache = cache.clone();
                let disk = disk.clone();
                let metrics = metrics.clone();
                let rec = rec.clone();
                std::thread::spawn(move || {
                    worker::worker_loop(dispatch, queue, cfg, cache, disk, metrics, rec)
                })
            })
            .collect();

        let dispatcher = {
            let queue = queue.clone();
            let cache = cache.clone();
            let dispatch = dispatch.clone();
            let cfg = cfg.clone();
            let metrics = metrics.clone();
            let rec = rec.clone();
            std::thread::spawn(move || {
                dispatcher_loop(queue, cache, dispatch, cfg, metrics, rec)
            })
        };

        Ok(Service {
            queue,
            cache,
            dispatch,
            metrics,
            rec,
            cfg,
            dispatcher: Some(dispatcher),
            workers,
        })
    }

    pub fn submit(&self, spec: JobSpec) -> Result<JobId> {
        // TP structural checks come before the key lookup: a TP *request*
        // is invalid at a backend no matter what stores it holds, and the
        // refusal should say so rather than "unknown store key".
        if let Some(tp) = &spec.tp {
            // A backend only ever sees the *placement* form (peers
            // resolved); the request form must go through a router that
            // knows where the shards live.
            if tp.peers.is_empty() {
                return Err(crate::util::error::Error::config(
                    "tp placement has no peers (submit tensor-parallel jobs through a \
                     routing tier that can resolve the shard group)",
                ));
            }
            if spec.key.is_none() {
                return Err(crate::util::error::Error::config(
                    "tp jobs must name their shard store by content key",
                ));
            }
            if spec.compute.unwrap_or(self.cfg.compute) != ComputePrecision::F32 {
                return Err(crate::util::error::Error::config(
                    "tensor-parallel jobs run f32 compute only",
                ));
            }
        }
        // Content-keyed jobs are checked at admission, not in the
        // dispatcher: an unknown key would otherwise be accepted and fail
        // asynchronously, which a router's spillover cannot react to —
        // synchronous refusal lets it try the backend that has the store.
        if let Some(k) = spec.key {
            if !self.cache.knows(k) {
                return Err(crate::util::error::Error::format(format!(
                    "unknown store key {k:016x} (push the store to this server first)"
                )));
            }
        }
        self.queue.submit(spec)
    }

    /// Block until `id` is terminal or `timeout` passes.
    pub fn wait(&self, id: JobId, timeout: Duration) -> Option<JobStatus> {
        self.queue.wait_job(id, timeout)
    }

    pub fn queue(&self) -> &Arc<JobQueue> {
        &self.queue
    }

    pub fn cache(&self) -> &Arc<StoreCache> {
        &self.cache
    }

    /// The validated configuration this service was started with.
    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// Merge a metrics delta produced outside the worker pool — the TP
    /// follower session driver (`net::tp::serve_tp`) accounts its
    /// data-plane traffic and compute this way.
    pub fn merge_metrics(&self, m: &Metrics) {
        self.metrics.lock().unwrap().merge(m);
    }

    /// The service-wide flight recorder (capacity 0 when tracing is off).
    pub fn recorder(&self) -> &Arc<Recorder> {
        &self.rec
    }

    /// Wire reply of the `trace` op: every retained event touching the
    /// job (by id and/or trace id), oldest first, plus ring bookkeeping
    /// so a caller can tell "no events" from "events rolled off".
    pub fn trace_json(&self, job: JobId, trace: u64) -> Json {
        let trace = if trace != 0 { trace } else { self.queue.trace_of(job) };
        let events = self.rec.events_for(job, trace);
        Json::obj(vec![
            ("job", Json::Num(job as f64)),
            (
                "trace",
                if trace != 0 {
                    Json::Str(format!("{trace:016x}"))
                } else {
                    Json::Null
                },
            ),
            ("events", self.rec.events_json(&events)),
            ("dropped", Json::Num(self.rec.dropped() as f64)),
            ("trace_buf", Json::Num(self.rec.capacity() as f64)),
        ])
    }

    /// Record one observation into a named service histogram — lets the
    /// net layer feed e.g. push chunk timings without holding the lock.
    pub fn observe(&self, key: &str, secs: f64) {
        self.metrics.lock().unwrap().observe(key, secs);
    }

    /// Batches formed and not yet retired by a worker — the telemetry
    /// inflight-batches gauge.
    pub fn inflight_batches(&self) -> usize {
        self.dispatch.len()
    }

    /// Run a closure against the live run metrics under the lock. The
    /// telemetry sampler reads a few counters this way every interval
    /// instead of cloning the whole registry.
    pub fn with_metrics<R>(&self, f: impl FnOnce(&Metrics) -> R) -> R {
        f(&self.metrics.lock().unwrap())
    }

    /// Nothing queued, running, or waiting for a worker.
    pub fn idle(&self) -> bool {
        self.queue.idle() && self.dispatch.is_empty()
    }

    /// Full machine-readable service state: merged run metrics, queue and
    /// cache counters, the latency distribution, and derived service KPIs
    /// (cache hit rate, batch occupancy).
    pub fn metrics_json(&self) -> Json {
        let mut m = self.metrics.lock().unwrap().clone();
        self.queue.account(&mut m);
        self.cache.account(&mut m);
        let hits = self.cache.hits();
        let lookups = hits + self.cache.misses();
        let hit_rate = if lookups > 0 {
            hits as f64 / lookups as f64
        } else {
            0.0
        };
        let occupancy = m.get(keys::BATCH_ROWS) as f64 / m.get(keys::BATCH_TARGET_ROWS).max(1) as f64;
        Json::obj(vec![
            ("config", self.cfg.to_json()),
            ("run", m.to_json()),
            ("latency", self.queue.latency_json()),
            ("cache_hit_rate", Json::Num(hit_rate)),
            ("batch_occupancy", Json::Num(occupancy)),
            (
                "prep_resident_bytes",
                Json::Num(self.cache.prepared_bytes() as f64),
            ),
            ("queue_depth", Json::Num(self.queue.depth() as f64)),
            (
                "inflight_batches",
                Json::Num(self.dispatch.len() as f64),
            ),
        ])
    }

    fn stop_and_join(&mut self) {
        self.queue.shutdown();
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join(); // the dispatcher closes `dispatch` on exit
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }

    /// Drain queued work and stop all threads, keeping the handle alive
    /// for final status/result/metrics queries (idempotent).
    pub fn stop(&mut self) {
        self.stop_and_join();
    }

    /// Drain queued work, stop all threads, and return the final metrics.
    pub fn shutdown(mut self) -> Json {
        self.stop_and_join();
        self.metrics_json()
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Batch-formation loop: anchor on the oldest pending job, resolve its
/// store through the cache, coalesce every compatible pending job (same
/// store by manifest hash + same compute) up to the §3.1 row target,
/// dispatch.
fn dispatcher_loop(
    queue: Arc<JobQueue>,
    cache: Arc<StoreCache>,
    dispatch: Arc<Dispatch>,
    cfg: ServiceConfig,
    metrics: Arc<Mutex<Metrics>>,
    rec: Arc<Recorder>,
) {
    // Per-job store resolution memo: each admitted job goes through the
    // cache once (that is the job-level reuse the cache-hit KPI measures)
    // and its manifest hash is remembered, so idle polling passes neither
    // inflate the counters nor churn the LRU, and a small cache cannot
    // evict the anchor out from under the compatibility check mid-pass.
    let mut resolved: std::collections::BTreeMap<JobId, Option<u64>> =
        std::collections::BTreeMap::new();
    loop {
        let has_pending = queue.wait_pending(Duration::from_millis(50));
        if !has_pending {
            if queue.is_shutdown() {
                break;
            }
            continue;
        }
        let t_form = Instant::now();
        if cfg.linger_ms > 0 && !queue.is_shutdown() {
            // Give compatible jobs a moment to arrive and fill the batch.
            std::thread::sleep(Duration::from_millis(cfg.linger_ms));
        }
        let Some((front_id, front_spec)) = queue.front_pending() else {
            continue;
        };
        // Anchor resolution goes through the memo first: a job spanning
        // many batches counts one cache lookup, not one per batch.
        let memoized = resolved
            .get(&front_id)
            .copied()
            .flatten()
            .and_then(|h| cache.peek(h).map(|s| (s, h)));
        let (store, store_hash) = match memoized {
            Some(x) => x,
            None => match cache.resolve(&front_spec) {
                Ok((store, _)) => match store.manifest_hash() {
                    Ok(h) => (store, h),
                    Err(e) => {
                        queue.fail_job(
                            front_id,
                            &format!("store manifest unreadable: {e}"),
                        );
                        continue;
                    }
                },
                Err(e) => {
                    let what = match front_spec.key {
                        Some(k) => format!("key {k:016x}"),
                        None => front_spec.data.display().to_string(),
                    };
                    queue.fail_job(front_id, &format!("cannot open store {what}: {e}"));
                    continue;
                }
            },
        };
        resolved.insert(front_id, Some(store_hash));
        // The store manifest is authoritative for the measurement model;
        // a job declaring a different workload than the store it resolved
        // to would sample the wrong distribution, so it fails here with a
        // typed error instead of returning mislabeled results.
        if front_spec.workload.as_str() != store.spec.tag() {
            queue.fail_job(
                front_id,
                &format!(
                    "workload mismatch: job declares {:?} but store {:?} is {:?}",
                    front_spec.workload.as_str(),
                    store.spec.name(),
                    store.spec.tag()
                ),
            );
            continue;
        }
        let key = BatchKey {
            store_hash,
            compute: front_spec.compute.unwrap_or(cfg.compute),
        };
        let target = batcher::target_rows(&cfg, &store);
        // Resolve batch membership OUTSIDE the queue lock: store lookups
        // read manifests (and on a miss open stores) — disk I/O that must
        // not stall submit/status/complete on the queue mutex.
        let pending = queue.pending_snapshot();
        for (id, spec) in &pending {
            if !resolved.contains_key(id) {
                let hash = cache
                    .resolve(spec)
                    .ok()
                    .and_then(|(s, _)| s.manifest_hash().ok());
                resolved.insert(*id, hash);
            }
        }
        resolved.retain(|id, _| pending.iter().any(|(p, _)| p == id));
        // A TP job forms a batch of exactly one: its rows drive a whole
        // backend group in lockstep, and another job's rows would have to
        // ride the same chunk schedule — forbidden by construction.
        // Symmetrically, a non-TP anchor never absorbs TP jobs.
        let compatible_ids: Vec<JobId> = if front_spec.tp.is_some() {
            vec![front_id]
        } else {
            pending
                .iter()
                .filter(|(id, spec)| {
                    spec.tp.is_none()
                        && spec.compute.unwrap_or(cfg.compute) == key.compute
                        && spec.workload == front_spec.workload
                        && resolved.get(id).copied().flatten() == Some(key.store_hash)
                })
                .map(|(id, _)| *id)
                .collect()
        };
        let assignments =
            queue.take_for_batch(target, |id, _| compatible_ids.contains(&id));
        if assignments.is_empty() {
            continue;
        }
        let batch = Batch {
            key,
            store,
            assignments,
            target,
            tp: front_spec.tp.clone(),
        };
        let form_secs = t_form.elapsed();
        {
            let mut m = metrics.lock().unwrap();
            m.add(keys::SERVICE_BATCHES, 1);
            m.add(keys::BATCH_ROWS, batch.rows() as u64);
            m.add(keys::BATCH_TARGET_ROWS, batch.target as u64);
            m.observe(keys::HIST_BATCH_FORM, form_secs.as_secs_f64());
        }
        // Formation span attributed to the batch anchor (linger + store
        // resolution + slicing); arg carries the rows actually filled.
        rec.span(
            crate::trace::Layer::Batcher,
            "form",
            front_id,
            queue.trace_of(front_id),
            form_secs.as_nanos() as u64,
            batch.rows() as u64,
        );
        dispatch.push(batch);
    }
    dispatch.close();
}

/// Small end-to-end benchmark of the service path: generate a scratch
/// store, run `jobs` jobs of `samples_per_job` against it through a real
/// [`Service`], and report throughput, batch occupancy, and cache hit rate
/// (the shape of `BENCH_service.json`).
pub fn smoke_benchmark(scratch: &Path, jobs: usize, samples_per_job: u64) -> Result<Json> {
    use crate::config::Preset;
    use crate::io::{GammaStore, StoreCodec, StorePrecision};

    let store_dir = scratch.join("fastmps-service-bench-store");
    let _ = std::fs::remove_dir_all(&store_dir);
    let mut spec = Preset::Jiuzhang2.scaled_spec(7);
    spec.m = 10;
    spec.chi_cap = 16;
    spec.decay_k = 0.0;
    spec.displacement_sigma = 0.0;
    GammaStore::create(&store_dir, &spec, StorePrecision::F16, StoreCodec::Lz)?;

    let cfg = ServiceConfig {
        workers: 2,
        n2_micro: 128,
        target_batch: Some(1024),
        compute: ComputePrecision::F32,
        linger_ms: 2,
        ..Default::default()
    };
    let svc = Service::start(cfg)?;
    let t0 = Instant::now();
    let ids = (0..jobs)
        .map(|k| {
            let mut s = JobSpec::new(&store_dir, samples_per_job);
            s.sample_base = k as u64 * samples_per_job;
            s.tag = format!("bench-{k}");
            svc.submit(s)
        })
        .collect::<Result<Vec<_>>>()?;
    let mut done = 0usize;
    for id in &ids {
        if svc.wait(*id, Duration::from_secs(300)) == Some(JobStatus::Done) {
            done += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let service = svc.shutdown();
    let _ = std::fs::remove_dir_all(&store_dir);

    let total = done as u64 * samples_per_job;
    Ok(Json::obj(vec![
        ("bench", Json::Str("service-smoke".into())),
        ("measured", Json::Bool(true)),
        ("jobs", Json::Num(jobs as f64)),
        ("samples_per_job", Json::Num(samples_per_job as f64)),
        ("jobs_done", Json::Num(done as f64)),
        ("wall_secs", Json::Num(wall)),
        (
            "throughput_samples_per_sec",
            Json::Num(if wall > 0.0 { total as f64 / wall } else { 0.0 }),
        ),
        ("service", service),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Preset;
    use crate::io::{GammaStore, StoreCodec, StorePrecision};
    use std::path::PathBuf;

    fn make_store(tag: &str) -> (Arc<GammaStore>, PathBuf) {
        let dir = std::env::temp_dir().join(format!(
            "fastmps-svc-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let mut spec = Preset::Jiuzhang2.scaled_spec(21);
        spec.m = 6;
        spec.chi_cap = 10;
        spec.decay_k = 0.0;
        spec.displacement_sigma = 0.0;
        let store = Arc::new(
            GammaStore::create(&dir, &spec, StorePrecision::F32, StoreCodec::Raw).unwrap(),
        );
        (store, dir)
    }

    fn small_cfg() -> ServiceConfig {
        ServiceConfig {
            workers: 2,
            n2_micro: 32,
            target_batch: Some(256),
            compute: ComputePrecision::F64,
            linger_ms: 2,
            ..Default::default()
        }
    }

    #[test]
    fn two_jobs_share_one_cached_store() {
        let (_, dir) = make_store("share");
        let svc = Service::start(small_cfg()).unwrap();
        let a = svc.submit(JobSpec::new(&dir, 64)).unwrap();
        let mut sb = JobSpec::new(&dir, 64);
        sb.sample_base = 64;
        let b = svc.submit(sb).unwrap();
        assert_eq!(svc.wait(a, Duration::from_secs(60)), Some(JobStatus::Done));
        assert_eq!(svc.wait(b, Duration::from_secs(60)), Some(JobStatus::Done));
        assert!(
            svc.cache().hits() > 0,
            "second job must hit the store cache (hits={}, misses={})",
            svc.cache().hits(),
            svc.cache().misses()
        );
        assert_eq!(svc.cache().misses(), 1, "one physical open");
        let j = svc.shutdown();
        assert!(j.get("cache_hit_rate").unwrap().as_f64().unwrap() > 0.0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn service_results_match_coordinator_run() {
        let (store, dir) = make_store("oracle");
        let svc = Service::start(small_cfg()).unwrap();
        let id = svc.submit(JobSpec::new(&dir, 128)).unwrap();
        assert_eq!(svc.wait(id, Duration::from_secs(60)), Some(JobStatus::Done));
        let sink = svc.queue().job_sink(id).unwrap();
        let mut rc = crate::config::RunConfig::new(store.spec.clone());
        rc.n_samples = 128;
        rc.n1_macro = 128;
        rc.n2_micro = 32;
        rc.compute = ComputePrecision::F64;
        rc.store_precision = store.precision;
        let reference = crate::coordinator::data_parallel::run(&rc, &store, &[]).unwrap();
        assert_eq!(sink.hist, reference.sink.hist);
        assert_eq!(sink.total_samples(), 128);
        drop(svc);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn compatible_jobs_coalesce_into_one_batch() {
        let (_, dir) = make_store("coalesce");
        // Large linger so both jobs are pending when the batcher wakes.
        let cfg = ServiceConfig {
            linger_ms: 80,
            ..small_cfg()
        };
        let svc = Service::start(cfg).unwrap();
        let a = svc.submit(JobSpec::new(&dir, 50)).unwrap();
        let mut sb = JobSpec::new(&dir, 50);
        sb.sample_base = 1000;
        let b = svc.submit(sb).unwrap();
        svc.wait(a, Duration::from_secs(60));
        svc.wait(b, Duration::from_secs(60));
        let m = svc.metrics_json();
        let run = m.get("run").unwrap().get("counters").unwrap();
        let batches = run.get(keys::SERVICE_BATCHES).unwrap().as_f64().unwrap();
        assert_eq!(batches, 1.0, "both jobs in one macro batch");
        let occupancy = m.get("batch_occupancy").unwrap().as_f64().unwrap();
        assert!((occupancy - 100.0 / 256.0).abs() < 1e-9, "{occupancy}");
        drop(svc);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bad_store_fails_cleanly_and_service_lives_on() {
        let (_, dir) = make_store("resilient");
        let svc = Service::start(small_cfg()).unwrap();
        let bad = svc
            .submit(JobSpec::new("/nonexistent/fastmps-store", 10))
            .unwrap();
        assert_eq!(
            svc.wait(bad, Duration::from_secs(60)),
            Some(JobStatus::Failed)
        );
        let v = svc.queue().status(bad).unwrap();
        assert!(v.error.unwrap().contains("cannot open store"));
        // The service still serves good jobs afterwards.
        let ok = svc.submit(JobSpec::new(&dir, 32)).unwrap();
        assert_eq!(svc.wait(ok, Duration::from_secs(60)), Some(JobStatus::Done));
        drop(svc);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn job_larger_than_target_spans_batches() {
        let (_, dir) = make_store("spans");
        let cfg = ServiceConfig {
            target_batch: Some(64),
            n2_micro: 32,
            ..small_cfg()
        };
        let svc = Service::start(cfg).unwrap();
        let id = svc.submit(JobSpec::new(&dir, 200)).unwrap();
        assert_eq!(svc.wait(id, Duration::from_secs(60)), Some(JobStatus::Done));
        let sink = svc.queue().job_sink(id).unwrap();
        assert_eq!(sink.total_samples(), 200);
        let m = svc.metrics_json();
        let run = m.get("run").unwrap().get("counters").unwrap();
        assert!(
            run.get(keys::SERVICE_BATCHES).unwrap().as_f64().unwrap() >= 4.0,
            "200 samples at target 64 needs ≥ 4 batches"
        );
        drop(svc);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn workload_tag_is_validated_against_the_store_manifest() {
        use crate::mps::qubit::QubitSpec;
        use crate::mps::workload::WorkloadKind;
        let (_, dir) = make_store("wl-gbs");
        let qdir = std::env::temp_dir().join(format!(
            "fastmps-svc-wl-qubit-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&qdir);
        GammaStore::create(
            &qdir,
            QubitSpec::new("svc-q", 5, 6, 11),
            StorePrecision::F32,
            StoreCodec::Raw,
        )
        .unwrap();
        let svc = Service::start(small_cfg()).unwrap();

        // Declaring qubit against a GBS store is a typed failure, not a
        // mislabeled result.
        let mut bad = JobSpec::new(&dir, 16);
        bad.workload = WorkloadKind::Qubit;
        let id = svc.submit(bad).unwrap();
        assert_eq!(
            svc.wait(id, Duration::from_secs(60)),
            Some(JobStatus::Failed)
        );
        let v = svc.queue().status(id).unwrap();
        assert!(v.error.unwrap().contains("workload mismatch"));
        assert_eq!(v.workload, WorkloadKind::Qubit, "view carries the tag");

        // A correctly-declared qubit job rides the same batching path.
        let mut good = JobSpec::new(&qdir, 48);
        good.workload = WorkloadKind::Qubit;
        let id = svc.submit(good).unwrap();
        assert_eq!(svc.wait(id, Duration::from_secs(60)), Some(JobStatus::Done));
        let sink = svc.queue().job_sink(id).unwrap();
        assert_eq!(sink.total_samples(), 48);
        assert_eq!(sink.hist[0].len(), 2, "d = 2 outcome alphabet");
        drop(svc);
        std::fs::remove_dir_all(&dir).unwrap();
        std::fs::remove_dir_all(&qdir).unwrap();
    }

    #[test]
    fn smoke_benchmark_reports_kpis() {
        let scratch = std::env::temp_dir().join(format!(
            "fastmps-svc-smoke-{}",
            std::process::id()
        ));
        let _ = std::fs::create_dir_all(&scratch);
        let j = smoke_benchmark(&scratch, 3, 200).unwrap();
        assert_eq!(j.get("jobs_done").unwrap().as_f64(), Some(3.0));
        assert!(j.get("throughput_samples_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert!(j.get("service").unwrap().get("cache_hit_rate").is_some());
        let _ = std::fs::remove_dir_all(&scratch);
    }
}
