//! The worker pool: resident engines driving macro batches.
//!
//! Each worker thread owns its engines for the life of the service (the
//! XLA client and its compiled-executable cache are per-thread and
//! expensive — reuse across jobs is the service's second amortization,
//! next to the store cache). A batch walk is the data-parallel inner loop
//! of `coordinator::data_parallel` with one twist: the environment rows
//! belong to *different jobs*, each stepped against its own
//! threshold/displacement stream, so one Γ pass serves every job in the
//! batch.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use super::batcher::Batch;
use super::cache::StoreCache;
use super::queue::JobQueue;
use crate::config::{ComputePrecision, EngineKind, RunConfig, ScalingMode, ServiceConfig};
use crate::coordinator::{env_rows, env_store_rows, EngineBox};
use crate::io::{DiskModel, Prefetcher};
use crate::metrics::{keys, Metrics};
use crate::sampler::sink::SampleSink;
use crate::sampler::{boundary_env, PreparedSite, PreparedStore};
use crate::tensor::SplitBuf;
use crate::trace::{Layer, Recorder};
use crate::util::error::{Error, Result};

/// A closable MPMC batch channel (std has no shared `Receiver`).
pub struct Dispatch {
    q: Mutex<(VecDeque<Batch>, bool)>,
    cv: Condvar,
}

impl Dispatch {
    pub fn new() -> Dispatch {
        Dispatch {
            q: Mutex::new((VecDeque::new(), false)),
            cv: Condvar::new(),
        }
    }

    pub fn push(&self, b: Batch) {
        let mut g = self.q.lock().unwrap();
        g.0.push_back(b);
        self.cv.notify_one();
    }

    /// Stop accepting work; blocked `pop`s drain the queue then see `None`.
    pub fn close(&self) {
        let mut g = self.q.lock().unwrap();
        g.1 = true;
        self.cv.notify_all();
    }

    /// Blocking pop; `None` once closed *and* drained.
    pub fn pop(&self) -> Option<Batch> {
        let mut g = self.q.lock().unwrap();
        loop {
            if let Some(b) = g.0.pop_front() {
                return Some(b);
            }
            if g.1 {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    pub fn len(&self) -> usize {
        self.q.lock().unwrap().0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for Dispatch {
    fn default() -> Self {
        Self::new()
    }
}

type EngineKey = (EngineKind, ComputePrecision, ScalingMode);

/// Worker thread body: pop batches until the dispatch channel closes.
pub(crate) fn worker_loop(
    dispatch: Arc<Dispatch>,
    queue: Arc<JobQueue>,
    cfg: ServiceConfig,
    cache: Arc<StoreCache>,
    disk: Arc<DiskModel>,
    service_metrics: Arc<Mutex<Metrics>>,
    rec: Arc<Recorder>,
) {
    // Engines persist across batches, keyed by execution mode.
    let mut engines: Vec<(EngineKey, EngineBox)> = Vec::new();
    while let Some(batch) = dispatch.pop() {
        // (job, trace) per assignment, resolved once — the batch span and
        // the per-phase engine spans below are recorded for every job
        // sharing the batch, so each job's timeline is complete.
        let jobs: Vec<(u64, u64)> = batch
            .assignments
            .iter()
            .map(|a| (a.job, queue.trace_of(a.job)))
            .collect();
        if batch.tp.is_some() {
            // Tensor-parallel batch: this worker is the group leader and
            // the walk runs over `net::tp` instead of a local engine. The
            // completion/failure plumbing mirrors the plain path below.
            let t_batch = Instant::now();
            match crate::net::tp::run_batch_tp(&batch, &cfg, &cache, &disk, &rec, &jobs) {
                Ok((metrics, sinks)) => {
                    for (a, sink) in batch.assignments.iter().zip(&sinks) {
                        queue.complete_slice(a.job, sink, a.len as u64);
                    }
                    let batch_ns = t_batch.elapsed().as_nanos() as u64;
                    for &(job, trace) in &jobs {
                        rec.span(Layer::Worker, "batch", job, trace, batch_ns, batch.rows() as u64);
                        for (phase, secs) in &metrics.phases {
                            if *secs <= 0.0 {
                                continue;
                            }
                            rec.span(
                                Layer::Engine,
                                phase_span_name(phase),
                                job,
                                trace,
                                (*secs * 1e9) as u64,
                                0,
                            );
                        }
                    }
                    service_metrics.lock().unwrap().merge(&metrics);
                }
                Err(e) => {
                    let msg = format!("tensor-parallel batch failed: {e}");
                    for a in &batch.assignments {
                        queue.fail_job(a.job, &msg);
                    }
                    for &(job, trace) in &jobs {
                        rec.instant(Layer::Worker, "batch_failed", job, trace, 0);
                    }
                    let mut m = service_metrics.lock().unwrap();
                    if matches!(e, Error::Fabric(_)) {
                        m.add(keys::TP_MEMBER_FAILURES, 1);
                    }
                    m.add(keys::TP_JOBS, 1);
                }
            }
            continue;
        }
        let key: EngineKey = (cfg.engine, batch.key.compute, cfg.scaling);
        let engine = match engine_for(&mut engines, key, &cfg, &batch) {
            Ok(e) => e,
            Err(e) => {
                let msg = format!("engine construction failed: {e}");
                for a in &batch.assignments {
                    queue.fail_job(a.job, &msg);
                }
                continue;
            }
        };
        // The residency tier: all batches against one (store, precision)
        // share a lazily-filled chain of prepared sites, so only the
        // first walk pays the Γ conversion (and, once fully resident,
        // later walks skip the store I/O too).
        let prep = engine.prep_key().map(|k| {
            cache.prepared(
                batch.key.store_hash,
                batch.store.num_sites(),
                k,
                cfg.prep_cache_bytes,
            )
        });
        let t_batch = Instant::now();
        match run_batch(engine, &batch, &cfg, &disk, prep.as_deref()) {
            Ok((mut metrics, sinks)) => {
                for (a, sink) in batch.assignments.iter().zip(&sinks) {
                    queue.complete_slice(a.job, sink, a.len as u64);
                }
                let (em, dead) = engine.drain();
                metrics.merge(&em);
                metrics.add("dead_rows", dead);
                let batch_ns = t_batch.elapsed().as_nanos() as u64;
                for &(job, trace) in &jobs {
                    rec.span(Layer::Worker, "batch", job, trace, batch_ns, batch.rows() as u64);
                    // Bridge the engines' accumulated PhaseTimer points
                    // into Engine-layer spans: one retroactive span per
                    // phase per job, covering this batch's walk.
                    for (phase, secs) in &metrics.phases {
                        if *secs <= 0.0 {
                            continue;
                        }
                        rec.span(
                            Layer::Engine,
                            phase_span_name(phase),
                            job,
                            trace,
                            (*secs * 1e9) as u64,
                            0,
                        );
                    }
                }
                service_metrics.lock().unwrap().merge(&metrics);
            }
            Err(e) => {
                let msg = format!("batch execution failed: {e}");
                for a in &batch.assignments {
                    queue.fail_job(a.job, &msg);
                }
                for &(job, trace) in &jobs {
                    rec.instant(Layer::Worker, "batch_failed", job, trace, 0);
                }
                // Reset accounting so the failed walk doesn't pollute the
                // next batch's numbers.
                let _ = engine.drain();
            }
        }
    }
}

/// Map a dynamic phase-timer name onto the `&'static str` the recorder's
/// preallocated slots require (unknown phases fold into "phase").
fn phase_span_name(phase: &str) -> &'static str {
    match phase {
        "compute" => "compute",
        "kernel_pooled" => "kernel_pooled",
        "io_virtual" => "io_virtual",
        "io_stall" => "io_stall",
        "comm" => "comm",
        "measure" => "measure",
        "bcast" => "bcast",
        "prep" => "prep",
        "displace" => "displace",
        _ => "phase",
    }
}

fn engine_for<'a>(
    engines: &'a mut Vec<(EngineKey, EngineBox)>,
    key: EngineKey,
    cfg: &ServiceConfig,
    batch: &Batch,
) -> Result<&'a mut EngineBox> {
    if let Some(i) = engines.iter().position(|(k, _)| *k == key) {
        return Ok(&mut engines[i].1);
    }
    let mut rc = RunConfig::new(batch.store.spec.clone());
    rc.engine = key.0;
    rc.compute = key.1;
    rc.scaling = key.2;
    rc.gemm_threads = cfg.gemm_threads;
    rc.gemm_split = cfg.gemm_split;
    rc.layout = cfg.layout;
    rc.artifacts_dir = cfg.artifacts_dir.clone();
    let e = EngineBox::build(&rc)?;
    engines.push((key, e));
    Ok(&mut engines.last_mut().unwrap().1)
}

/// Walk all `M` sites once, stepping every job slice of the batch, and
/// return the batch metrics plus one sink per assignment (same order).
///
/// With a [`PreparedStore`] the walk borrows converted Γ tensors instead
/// of converting per micro batch, and only the sites not yet resident
/// are streamed from the store — a partially resident chain saves I/O in
/// proportion, and a fully resident one performs zero store I/O.
pub(crate) fn run_batch(
    engine: &mut EngineBox,
    batch: &Batch,
    cfg: &ServiceConfig,
    disk: &Arc<DiskModel>,
    prep: Option<&PreparedStore>,
) -> Result<(Metrics, Vec<SampleSink>)> {
    let store = &batch.store;
    let spec = &store.spec;
    let m = spec.m();
    let rows = batch.rows();
    if rows == 0 {
        return Err(Error::other("empty batch dispatched"));
    }
    if !batch.key.compute.admissible_for(m) {
        return Err(Error::config(format!(
            "f16 compute requires M < 500 (store has M = {m})"
        )));
    }

    let mut metrics = Metrics::new();
    let mut sinks: Vec<SampleSink> = batch
        .assignments
        .iter()
        .map(|_| SampleSink::new(m, spec.d(), spec.sink_max_gap()))
        .collect();
    let mut env = boundary_env(rows);
    // Batch-local residency accounting (the chain's own counters are
    // shared across workers, so deltas there would double-count).
    let mut prep_hits = 0u64;
    let mut prep_convs = 0u64;

    // Stream only the sites whose prepared form is NOT yet resident —
    // I/O savings scale with residency instead of being all-or-nothing,
    // and a fully resident chain streams nothing. Residency is monotone
    // within a chain (sites are never evicted from it), so a site
    // resident when this plan is built is still resident when the walk
    // reaches it and the prefetch order cannot desynchronize.
    let stream_order: Vec<usize> = match prep {
        Some(p) => (0..m).filter(|&i| !p.is_resident(i)).collect(),
        None => (0..m).collect(),
    };
    let mut pf = (!stream_order.is_empty()).then(|| {
        Prefetcher::new(store.clone(), disk.clone(), stream_order.clone(), 2)
    });
    let mut next_streamed = 0usize;
    let mut samples_buf: Vec<i32> = Vec::new();
    for site_idx in 0..m {
        let from_disk =
            next_streamed < stream_order.len() && stream_order[next_streamed] == site_idx;
        let (raw_site, psite): (Option<crate::mps::Site>, Option<Arc<PreparedSite>>) =
            if from_disk {
                next_streamed += 1;
                let pf = pf.as_mut().expect("stream order non-empty");
                let (i, site) = pf
                    .next_site()
                    .ok_or_else(|| Error::other("prefetch ended early"))??;
                debug_assert_eq!(i, site_idx);
                metrics.add(keys::IO_OPS, 1);
                metrics.add(keys::IO_BYTES, store.site_bytes(site_idx));
                let ps = prep.map(|p| {
                    // `site` reports whether it really converted, so a
                    // concurrent worker publishing first counts as the
                    // hit this batch actually experienced.
                    let (ps, converted) = p.site(site_idx, &site);
                    if converted {
                        prep_convs += 1;
                    } else {
                        prep_hits += 1;
                    }
                    ps
                });
                (Some(site), ps)
            } else {
                let p = prep.expect("non-streamed site implies a prepared chain");
                let ps = p.resident(site_idx).ok_or_else(|| {
                    Error::other(format!("prepared site {site_idx} vanished mid-walk"))
                })?;
                prep_hits += 1;
                (None, Some(ps))
            };

        let chi_r = psite
            .as_ref()
            .map(|p| p.chi_r())
            .or_else(|| raw_site.as_ref().map(|s| s.gamma.d1))
            .expect("either raw or prepared site");
        let mut next = SplitBuf::zeros(&[rows, chi_r]);
        let mut row0 = 0usize;
        for (ai, a) in batch.assignments.iter().enumerate() {
            let mut site_samples: Vec<i32> = Vec::with_capacity(a.len);
            let mut off = 0usize;
            while off < a.len {
                let take = (a.len - off).min(cfg.n2_micro);
                let lo = row0 + off;
                let mut chunk = env_rows(&env, lo, lo + take);
                let th = spec.thresholds(site_idx, a.sample0 + off as u64, take);
                let mus = spec.displacements(site_idx, a.sample0 + off as u64, take);
                let t0 = Instant::now();
                engine.step_site(
                    &mut chunk,
                    raw_site.as_ref(),
                    psite.as_deref(),
                    &th,
                    mus.as_deref(),
                    &mut samples_buf,
                )?;
                metrics.add_phase("compute", t0.elapsed().as_secs_f64());
                metrics.add(keys::MICRO_BATCHES, 1);
                env_store_rows(&mut next, lo, &chunk);
                site_samples.extend_from_slice(&samples_buf);
                off += take;
            }
            sinks[ai].record(site_idx, &site_samples);
            row0 += a.len;
        }
        env = next;
    }
    if let Some(pf) = pf {
        metrics.add_phase("io_virtual", pf.io_secs);
        metrics.add_phase("io_stall", pf.stall_secs);
        pf.finish()?;
    }
    metrics.add(keys::STEP_PREP_HITS, prep_hits);
    metrics.add(keys::STEP_PREP_CONVERSIONS, prep_convs);
    metrics.add(keys::SITES, m as u64);
    metrics.add(keys::SAMPLES, rows as u64);
    metrics.add(keys::MACRO_BATCHES, 1);
    Ok((metrics, sinks))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Preset;
    use crate::io::{GammaStore, StoreCodec, StorePrecision};
    use crate::service::batcher::BatchKey;
    use crate::service::queue::Assignment;

    fn test_store(tag: &str, m: usize) -> (Arc<GammaStore>, std::path::PathBuf) {
        let dir = std::env::temp_dir().join(format!(
            "fastmps-worker-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let mut spec = Preset::Jiuzhang2.scaled_spec(11);
        spec.m = m;
        spec.chi_cap = 12;
        spec.decay_k = 0.0;
        spec.displacement_sigma = 0.0;
        let store = Arc::new(
            GammaStore::create(&dir, &spec, StorePrecision::F32, StoreCodec::Raw).unwrap(),
        );
        (store, dir)
    }

    fn service_cfg() -> ServiceConfig {
        ServiceConfig {
            n2_micro: 32,
            compute: ComputePrecision::F64,
            ..Default::default()
        }
    }

    fn dp_reference(store: &Arc<GammaStore>, n: u64, n2: usize) -> SampleSink {
        let mut rc = RunConfig::new(store.spec.clone());
        rc.n_samples = n;
        rc.n1_macro = n as usize;
        rc.n2_micro = n2;
        rc.compute = ComputePrecision::F64;
        // Match the store width so the coordinator's broadcast pack is
        // lossless, like the service's direct prefetch path.
        rc.store_precision = store.precision;
        crate::coordinator::data_parallel::run(&rc, store, &[])
            .unwrap()
            .sink
    }

    #[test]
    fn batch_of_one_job_matches_data_parallel_run() {
        let (store, dir) = test_store("oracle", 6);
        let cfg = service_cfg();
        let key = BatchKey {
            store_hash: store.manifest_hash().unwrap(),
            compute: ComputePrecision::F64,
        };
        let batch = Batch {
            key,
            store: store.clone(),
            assignments: vec![Assignment { job: 1, sample0: 0, len: 128 }],
            target: 128,
            tp: None,
        };
        let mut rc = RunConfig::new(store.spec.clone());
        rc.compute = ComputePrecision::F64;
        let mut engine = EngineBox::build(&rc).unwrap();
        let (metrics, sinks) =
            run_batch(&mut engine, &batch, &cfg, &DiskModel::unlimited(), None).unwrap();
        let reference = dp_reference(&store, 128, cfg.n2_micro);
        assert_eq!(sinks[0].hist, reference.hist, "service vs coordinator");
        assert_eq!(sinks[0].pair_sums, reference.pair_sums);
        assert_eq!(metrics.get(keys::SAMPLES), 128);
        assert_eq!(metrics.get(keys::SITES), 6);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn coalesced_jobs_get_independent_correct_streams() {
        // Two jobs in one batch, second with a shifted sample base: each
        // must match the standalone run over its own index range, and the
        // shifted stream must actually differ from the base stream.
        let (store, dir) = test_store("streams", 5);
        let cfg = service_cfg();
        let key = BatchKey {
            store_hash: store.manifest_hash().unwrap(),
            compute: ComputePrecision::F64,
        };
        let batch = Batch {
            key,
            store: store.clone(),
            assignments: vec![
                Assignment { job: 1, sample0: 0, len: 96 },
                Assignment { job: 2, sample0: 96, len: 96 },
            ],
            target: 192,
            tp: None,
        };
        let mut rc = RunConfig::new(store.spec.clone());
        rc.compute = ComputePrecision::F64;
        let mut engine = EngineBox::build(&rc).unwrap();
        let (_, sinks) =
            run_batch(&mut engine, &batch, &cfg, &DiskModel::unlimited(), None).unwrap();
        // The combined histogram equals one 192-sample standalone run
        // (job 2's range [96, 192) continues job 1's [0, 96)).
        let reference = dp_reference(&store, 192, cfg.n2_micro);
        let mut combined = sinks[0].clone();
        combined.merge(&sinks[1]);
        assert_eq!(combined.hist, reference.hist);
        assert_ne!(sinks[0].hist, sinks[1].hist, "streams must differ");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn engine_is_reused_across_batches() {
        let (store, dir) = test_store("reuse", 4);
        let cfg = service_cfg();
        let key = BatchKey {
            store_hash: store.manifest_hash().unwrap(),
            compute: ComputePrecision::F64,
        };
        let mut rc = RunConfig::new(store.spec.clone());
        rc.compute = ComputePrecision::F64;
        let mut engine = EngineBox::build(&rc).unwrap();
        for round in 0..2 {
            let batch = Batch {
                key,
                store: store.clone(),
                assignments: vec![Assignment {
                    job: round + 1,
                    sample0: 0,
                    len: 32,
                }],
                target: 32,
                tp: None,
            };
            let (m, _) =
                run_batch(&mut engine, &batch, &cfg, &DiskModel::unlimited(), None).unwrap();
            assert_eq!(m.get(keys::SAMPLES), 32);
            let (em, _) = engine.drain();
            assert!(em.get(keys::FLOPS) > 0, "round {round} engine accounting");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn warm_batch_walks_resident_tensors_with_zero_io() {
        let (store, dir) = test_store("resident", 6);
        let cfg = service_cfg();
        let key = BatchKey {
            store_hash: store.manifest_hash().unwrap(),
            compute: ComputePrecision::F64,
        };
        let batch = Batch {
            key,
            store: store.clone(),
            assignments: vec![Assignment { job: 1, sample0: 0, len: 64 }],
            target: 64,
            tp: None,
        };
        let mut rc = RunConfig::new(store.spec.clone());
        rc.compute = ComputePrecision::F64;
        let mut engine = EngineBox::build(&rc).unwrap();
        let prep = PreparedStore::new(store.num_sites(), engine.prep_key().unwrap(), u64::MAX);

        // Cold batch: streams Γ, converts every site once.
        let (m1, s1) =
            run_batch(&mut engine, &batch, &cfg, &DiskModel::unlimited(), Some(&prep)).unwrap();
        assert_eq!(m1.get(keys::IO_OPS), 6);
        assert_eq!(m1.get(keys::STEP_PREP_CONVERSIONS), 6);
        assert_eq!(m1.get(keys::STEP_PREP_HITS), 0);
        assert!(prep.fully_resident());

        // Warm batch: zero store I/O, every site a residency hit, and the
        // exact same sample stream.
        let (m2, s2) =
            run_batch(&mut engine, &batch, &cfg, &DiskModel::unlimited(), Some(&prep)).unwrap();
        assert_eq!(m2.get(keys::IO_OPS), 0, "resident walk reads nothing");
        assert_eq!(m2.get(keys::IO_BYTES), 0);
        assert_eq!(m2.get(keys::STEP_PREP_HITS), 6);
        assert_eq!(m2.get(keys::STEP_PREP_CONVERSIONS), 0);
        assert_eq!(s1[0].hist, s2[0].hist, "residency must not change outcomes");
        assert_eq!(s1[0].pair_sums, s2[0].pair_sums);

        // And the warm walk matches the plain (unprepared) path.
        let (_, s3) =
            run_batch(&mut engine, &batch, &cfg, &DiskModel::unlimited(), None).unwrap();
        assert_eq!(s2[0].hist, s3[0].hist);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn partially_resident_chain_streams_only_missing_sites() {
        let (store, dir) = test_store("partial", 6);
        let cfg = service_cfg();
        let key = BatchKey {
            store_hash: store.manifest_hash().unwrap(),
            compute: ComputePrecision::F64,
        };
        let batch = Batch {
            key,
            store: store.clone(),
            assignments: vec![Assignment { job: 1, sample0: 0, len: 64 }],
            target: 64,
            tp: None,
        };
        let mut rc = RunConfig::new(store.spec.clone());
        rc.compute = ComputePrecision::F64;
        let mut engine = EngineBox::build(&rc).unwrap();
        let prep = PreparedStore::new(store.num_sites(), engine.prep_key().unwrap(), u64::MAX);
        for i in [0usize, 2, 5] {
            prep.site(i, &store.load_site(i).unwrap());
        }
        let (m1, s1) =
            run_batch(&mut engine, &batch, &cfg, &DiskModel::unlimited(), Some(&prep)).unwrap();
        assert_eq!(m1.get(keys::IO_OPS), 3, "only the 3 missing sites stream");
        assert_eq!(m1.get(keys::STEP_PREP_CONVERSIONS), 3);
        assert_eq!(m1.get(keys::STEP_PREP_HITS), 3);
        let (_, s2) =
            run_batch(&mut engine, &batch, &cfg, &DiskModel::unlimited(), None).unwrap();
        assert_eq!(s1[0].hist, s2[0].hist, "partial residency must not change outcomes");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn dispatch_channel_drains_then_closes() {
        let (store, dir) = test_store("chan", 4);
        let d = Dispatch::new();
        let key = BatchKey {
            store_hash: 1,
            compute: ComputePrecision::F32,
        };
        d.push(Batch {
            key,
            store: store.clone(),
            assignments: vec![Assignment { job: 1, sample0: 0, len: 1 }],
            target: 1,
            tp: None,
        });
        d.close();
        assert!(d.pop().is_some());
        assert!(d.pop().is_none(), "closed + drained");
        assert!(d.is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
