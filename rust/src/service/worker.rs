//! The worker pool: resident engines driving macro batches.
//!
//! Each worker thread owns its engines for the life of the service (the
//! XLA client and its compiled-executable cache are per-thread and
//! expensive — reuse across jobs is the service's second amortization,
//! next to the store cache). A batch walk is the data-parallel inner loop
//! of `coordinator::data_parallel` with one twist: the environment rows
//! belong to *different jobs*, each stepped against its own
//! threshold/displacement stream, so one Γ pass serves every job in the
//! batch.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use super::batcher::Batch;
use super::queue::JobQueue;
use crate::config::{ComputePrecision, EngineKind, RunConfig, ScalingMode, ServiceConfig};
use crate::coordinator::{env_rows, env_store_rows, EngineBox};
use crate::io::{DiskModel, Prefetcher};
use crate::metrics::{keys, Metrics};
use crate::sampler::sink::SampleSink;
use crate::sampler::{boundary_env, StepEngine};
use crate::tensor::SplitBuf;
use crate::util::error::{Error, Result};

/// A closable MPMC batch channel (std has no shared `Receiver`).
pub struct Dispatch {
    q: Mutex<(VecDeque<Batch>, bool)>,
    cv: Condvar,
}

impl Dispatch {
    pub fn new() -> Dispatch {
        Dispatch {
            q: Mutex::new((VecDeque::new(), false)),
            cv: Condvar::new(),
        }
    }

    pub fn push(&self, b: Batch) {
        let mut g = self.q.lock().unwrap();
        g.0.push_back(b);
        self.cv.notify_one();
    }

    /// Stop accepting work; blocked `pop`s drain the queue then see `None`.
    pub fn close(&self) {
        let mut g = self.q.lock().unwrap();
        g.1 = true;
        self.cv.notify_all();
    }

    /// Blocking pop; `None` once closed *and* drained.
    pub fn pop(&self) -> Option<Batch> {
        let mut g = self.q.lock().unwrap();
        loop {
            if let Some(b) = g.0.pop_front() {
                return Some(b);
            }
            if g.1 {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    pub fn len(&self) -> usize {
        self.q.lock().unwrap().0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for Dispatch {
    fn default() -> Self {
        Self::new()
    }
}

type EngineKey = (EngineKind, ComputePrecision, ScalingMode);

/// Worker thread body: pop batches until the dispatch channel closes.
pub(crate) fn worker_loop(
    dispatch: Arc<Dispatch>,
    queue: Arc<JobQueue>,
    cfg: ServiceConfig,
    disk: Arc<DiskModel>,
    service_metrics: Arc<Mutex<Metrics>>,
) {
    // Engines persist across batches, keyed by execution mode.
    let mut engines: Vec<(EngineKey, EngineBox)> = Vec::new();
    while let Some(batch) = dispatch.pop() {
        let key: EngineKey = (cfg.engine, batch.key.compute, cfg.scaling);
        let engine = match engine_for(&mut engines, key, &cfg, &batch) {
            Ok(e) => e,
            Err(e) => {
                let msg = format!("engine construction failed: {e}");
                for a in &batch.assignments {
                    queue.fail_job(a.job, &msg);
                }
                continue;
            }
        };
        match run_batch(engine, &batch, &cfg, &disk) {
            Ok((mut metrics, sinks)) => {
                for (a, sink) in batch.assignments.iter().zip(&sinks) {
                    queue.complete_slice(a.job, sink, a.len as u64);
                }
                let (em, dead) = engine.drain();
                metrics.merge(&em);
                metrics.add("dead_rows", dead);
                service_metrics.lock().unwrap().merge(&metrics);
            }
            Err(e) => {
                let msg = format!("batch execution failed: {e}");
                for a in &batch.assignments {
                    queue.fail_job(a.job, &msg);
                }
                // Reset accounting so the failed walk doesn't pollute the
                // next batch's numbers.
                let _ = engine.drain();
            }
        }
    }
}

fn engine_for<'a>(
    engines: &'a mut Vec<(EngineKey, EngineBox)>,
    key: EngineKey,
    cfg: &ServiceConfig,
    batch: &Batch,
) -> Result<&'a mut EngineBox> {
    if let Some(i) = engines.iter().position(|(k, _)| *k == key) {
        return Ok(&mut engines[i].1);
    }
    let mut rc = RunConfig::new(batch.store.spec.clone());
    rc.engine = key.0;
    rc.compute = key.1;
    rc.scaling = key.2;
    rc.gemm_threads = cfg.gemm_threads;
    rc.artifacts_dir = cfg.artifacts_dir.clone();
    let e = EngineBox::build(&rc)?;
    engines.push((key, e));
    Ok(&mut engines.last_mut().unwrap().1)
}

/// Walk all `M` sites once, stepping every job slice of the batch, and
/// return the batch metrics plus one sink per assignment (same order).
pub(crate) fn run_batch(
    engine: &mut EngineBox,
    batch: &Batch,
    cfg: &ServiceConfig,
    disk: &Arc<DiskModel>,
) -> Result<(Metrics, Vec<SampleSink>)> {
    let store = &batch.store;
    let spec = &store.spec;
    let m = spec.m;
    let rows = batch.rows();
    if rows == 0 {
        return Err(Error::other("empty batch dispatched"));
    }
    if !batch.key.compute.admissible_for(m) {
        return Err(Error::config(format!(
            "f16 compute requires M < 500 (store has M = {m})"
        )));
    }

    let mut metrics = Metrics::new();
    let mut sinks: Vec<SampleSink> = batch
        .assignments
        .iter()
        .map(|_| SampleSink::new(m, spec.d, 4))
        .collect();
    let displaced = spec.displacement_sigma != 0.0;
    let mut env = boundary_env(rows);

    let mut pf = Prefetcher::new(store.clone(), disk.clone(), (0..m).collect(), 2);
    let mut expected_site = 0usize;
    while let Some(r) = pf.next_site() {
        let (site_idx, site) = r?;
        debug_assert_eq!(site_idx, expected_site);
        expected_site += 1;
        metrics.add(keys::IO_OPS, 1);
        metrics.add(keys::IO_BYTES, store.site_bytes(site_idx));

        let chi_r = site.gamma.d1;
        let mut next = SplitBuf::zeros(&[rows, chi_r]);
        let mut row0 = 0usize;
        for (ai, a) in batch.assignments.iter().enumerate() {
            let mut site_samples: Vec<i32> = Vec::with_capacity(a.len);
            let mut off = 0usize;
            while off < a.len {
                let take = (a.len - off).min(cfg.n2_micro);
                let lo = row0 + off;
                let mut chunk = env_rows(&env, lo, lo + take);
                let th = spec.thresholds(site_idx, a.sample0 + off as u64, take);
                let mus = displaced
                    .then(|| spec.displacement_draws(site_idx, a.sample0 + off as u64, take));
                let mut s = Vec::new();
                let t0 = Instant::now();
                engine.step(&mut chunk, &site, &th, mus.as_deref(), &mut s)?;
                metrics.add_phase("compute", t0.elapsed().as_secs_f64());
                metrics.add(keys::MICRO_BATCHES, 1);
                env_store_rows(&mut next, lo, &chunk);
                site_samples.extend_from_slice(&s);
                off += take;
            }
            sinks[ai].record(site_idx, &site_samples);
            row0 += a.len;
        }
        env = next;
    }
    if expected_site != m {
        return Err(Error::other(format!(
            "prefetch delivered {expected_site} of {m} sites"
        )));
    }
    metrics.add_phase("io_virtual", pf.io_secs);
    metrics.add_phase("io_stall", pf.stall_secs);
    pf.finish()?;
    metrics.add(keys::SITES, m as u64);
    metrics.add(keys::SAMPLES, rows as u64);
    metrics.add(keys::MACRO_BATCHES, 1);
    Ok((metrics, sinks))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Preset;
    use crate::io::{GammaStore, StoreCodec, StorePrecision};
    use crate::service::batcher::BatchKey;
    use crate::service::queue::Assignment;

    fn test_store(tag: &str, m: usize) -> (Arc<GammaStore>, std::path::PathBuf) {
        let dir = std::env::temp_dir().join(format!(
            "fastmps-worker-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let mut spec = Preset::Jiuzhang2.scaled_spec(11);
        spec.m = m;
        spec.chi_cap = 12;
        spec.decay_k = 0.0;
        spec.displacement_sigma = 0.0;
        let store = Arc::new(
            GammaStore::create(&dir, &spec, StorePrecision::F32, StoreCodec::Raw).unwrap(),
        );
        (store, dir)
    }

    fn service_cfg() -> ServiceConfig {
        ServiceConfig {
            n2_micro: 32,
            compute: ComputePrecision::F64,
            ..Default::default()
        }
    }

    fn dp_reference(store: &Arc<GammaStore>, n: u64, n2: usize) -> SampleSink {
        let mut rc = RunConfig::new(store.spec.clone());
        rc.n_samples = n;
        rc.n1_macro = n as usize;
        rc.n2_micro = n2;
        rc.compute = ComputePrecision::F64;
        // Match the store width so the coordinator's broadcast pack is
        // lossless, like the service's direct prefetch path.
        rc.store_precision = store.precision;
        crate::coordinator::data_parallel::run(&rc, store, &[])
            .unwrap()
            .sink
    }

    #[test]
    fn batch_of_one_job_matches_data_parallel_run() {
        let (store, dir) = test_store("oracle", 6);
        let cfg = service_cfg();
        let key = BatchKey {
            store_hash: store.manifest_hash().unwrap(),
            compute: ComputePrecision::F64,
        };
        let batch = Batch {
            key,
            store: store.clone(),
            assignments: vec![Assignment { job: 1, sample0: 0, len: 128 }],
            target: 128,
        };
        let mut rc = RunConfig::new(store.spec.clone());
        rc.compute = ComputePrecision::F64;
        let mut engine = EngineBox::build(&rc).unwrap();
        let (metrics, sinks) =
            run_batch(&mut engine, &batch, &cfg, &DiskModel::unlimited()).unwrap();
        let reference = dp_reference(&store, 128, cfg.n2_micro);
        assert_eq!(sinks[0].hist, reference.hist, "service vs coordinator");
        assert_eq!(sinks[0].pair_sums, reference.pair_sums);
        assert_eq!(metrics.get(keys::SAMPLES), 128);
        assert_eq!(metrics.get(keys::SITES), 6);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn coalesced_jobs_get_independent_correct_streams() {
        // Two jobs in one batch, second with a shifted sample base: each
        // must match the standalone run over its own index range, and the
        // shifted stream must actually differ from the base stream.
        let (store, dir) = test_store("streams", 5);
        let cfg = service_cfg();
        let key = BatchKey {
            store_hash: store.manifest_hash().unwrap(),
            compute: ComputePrecision::F64,
        };
        let batch = Batch {
            key,
            store: store.clone(),
            assignments: vec![
                Assignment { job: 1, sample0: 0, len: 96 },
                Assignment { job: 2, sample0: 96, len: 96 },
            ],
            target: 192,
        };
        let mut rc = RunConfig::new(store.spec.clone());
        rc.compute = ComputePrecision::F64;
        let mut engine = EngineBox::build(&rc).unwrap();
        let (_, sinks) =
            run_batch(&mut engine, &batch, &cfg, &DiskModel::unlimited()).unwrap();
        // The combined histogram equals one 192-sample standalone run
        // (job 2's range [96, 192) continues job 1's [0, 96)).
        let reference = dp_reference(&store, 192, cfg.n2_micro);
        let mut combined = sinks[0].clone();
        combined.merge(&sinks[1]);
        assert_eq!(combined.hist, reference.hist);
        assert_ne!(sinks[0].hist, sinks[1].hist, "streams must differ");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn engine_is_reused_across_batches() {
        let (store, dir) = test_store("reuse", 4);
        let cfg = service_cfg();
        let key = BatchKey {
            store_hash: store.manifest_hash().unwrap(),
            compute: ComputePrecision::F64,
        };
        let mut rc = RunConfig::new(store.spec.clone());
        rc.compute = ComputePrecision::F64;
        let mut engine = EngineBox::build(&rc).unwrap();
        for round in 0..2 {
            let batch = Batch {
                key,
                store: store.clone(),
                assignments: vec![Assignment {
                    job: round + 1,
                    sample0: 0,
                    len: 32,
                }],
                target: 32,
            };
            let (m, _) = run_batch(&mut engine, &batch, &cfg, &DiskModel::unlimited()).unwrap();
            assert_eq!(m.get(keys::SAMPLES), 32);
            let (em, _) = engine.drain();
            assert!(em.get(keys::FLOPS) > 0, "round {round} engine accounting");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn dispatch_channel_drains_then_closes() {
        let (store, dir) = test_store("chan", 4);
        let d = Dispatch::new();
        let key = BatchKey {
            store_hash: 1,
            compute: ComputePrecision::F32,
        };
        d.push(Batch {
            key,
            store: store.clone(),
            assignments: vec![Assignment { job: 1, sample0: 0, len: 1 }],
            target: 1,
        });
        d.close();
        assert!(d.pop().is_some());
        assert!(d.pop().is_none(), "closed + drained");
        assert!(d.is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
