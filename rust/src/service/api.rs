//! File-based job-directory transport (no sockets, no new dependencies).
//!
//! Layout under the jobs directory:
//! ```text
//! <jobs>/inbox/<stem>.json     — client-submitted JobSpec (atomic rename)
//! <jobs>/archive/<stem>.json   — ingested submissions (audit trail)
//! <jobs>/status/<stem>.json    — live status, rewritten on change
//! <jobs>/results/<stem>.json   — final result once terminal
//! <jobs>/service_metrics.json  — service KPIs, written at serve exit
//! <jobs>/stop                  — touch to stop the serve loop
//! ```
//!
//! The `<stem>` is chosen by the client (unique per submission); clients
//! never need to learn the service-side job id to find their results.
//! Writes into `inbox/` go through a temp file + rename so the server
//! never reads a half-written spec.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use super::job::{JobId, JobSpec};
use super::Service;
use crate::config::ServiceConfig;
use crate::util::error::{Error, Result};
use crate::util::json::Json;

/// Transport/loop options for [`serve`].
#[derive(Debug, Clone)]
pub struct ServeOptions {
    pub jobs_dir: PathBuf,
    /// Inbox scan interval.
    pub poll_ms: u64,
    /// Exit once ≥ 1 job was ingested and everything is idle (CI/tests).
    pub drain: bool,
    /// Hard wall-clock cap; `None` = run until `stop` (or drain).
    pub max_secs: Option<f64>,
}

impl ServeOptions {
    pub fn new(jobs_dir: impl Into<PathBuf>) -> ServeOptions {
        ServeOptions {
            jobs_dir: jobs_dir.into(),
            poll_ms: 20,
            drain: false,
            max_secs: None,
        }
    }
}

fn write_atomic(path: &Path, contents: &str) -> Result<()> {
    let tmp = path.with_extension("tmp");
    fs::write(&tmp, contents).map_err(|e| Error::io(tmp.display(), e))?;
    fs::rename(&tmp, path).map_err(|e| Error::io(path.display(), e))
}

/// A unique submission stem for this process.
pub fn unique_stem() -> String {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    format!(
        "job-{:08x}-{}-{}",
        nanos & 0xffff_ffff,
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    )
}

/// Client side: drop a spec into the inbox. Returns the submission stem.
pub fn submit_file(jobs_dir: &Path, spec: &JobSpec) -> Result<String> {
    let inbox = jobs_dir.join("inbox");
    fs::create_dir_all(&inbox).map_err(|e| Error::io(inbox.display(), e))?;
    let stem = unique_stem();
    let path = inbox.join(format!("{stem}.json"));
    write_atomic(&path, &spec.to_json().pretty())?;
    Ok(stem)
}

/// Client side: poll for the result of a submission with the default
/// 20 ms poll ceiling. Errors on timeout.
pub fn wait_result(jobs_dir: &Path, stem: &str, timeout: Duration) -> Result<Json> {
    wait_result_poll(jobs_dir, stem, timeout, 20)
}

/// Client side: poll for the result of a submission. The poll interval
/// backs off exponentially from 1 ms up to `poll_ms` — fast results are
/// seen almost immediately, while long jobs cost one directory stat per
/// `poll_ms` instead of a fixed hot spin. Errors on timeout.
pub fn wait_result_poll(
    jobs_dir: &Path,
    stem: &str,
    timeout: Duration,
    poll_ms: u64,
) -> Result<Json> {
    let path = jobs_dir.join("results").join(format!("{stem}.json"));
    let deadline = Instant::now() + timeout;
    let cap = Duration::from_millis(poll_ms.max(1));
    let mut delay = Duration::from_millis(1).min(cap);
    loop {
        if path.exists() {
            let text =
                fs::read_to_string(&path).map_err(|e| Error::io(path.display(), e))?;
            return Json::parse(&text);
        }
        let now = Instant::now();
        if now >= deadline {
            return Err(Error::other(format!(
                "timed out waiting for result {}",
                path.display()
            )));
        }
        std::thread::sleep(delay.min(deadline - now));
        delay = (delay * 2).min(cap);
    }
}

/// Client side: all status files (what `fastmps jobs` prints), sorted by
/// submit time then service job id — deterministic for scripting and
/// tests even when stems interleave across client processes.
pub fn list_jobs(jobs_dir: &Path) -> Result<Vec<(String, Json)>> {
    let status = jobs_dir.join("status");
    let mut out = Vec::new();
    let rd = match fs::read_dir(&status) {
        Ok(rd) => rd,
        Err(_) => return Ok(out), // no server ran here yet
    };
    let mut names: Vec<PathBuf> = rd
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "json"))
        .collect();
    names.sort();
    for p in names {
        let stem = p
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("?")
            .to_string();
        let text = fs::read_to_string(&p).map_err(|e| Error::io(p.display(), e))?;
        out.push((stem, Json::parse(&text)?));
    }
    let key = |j: &Json, k: &str| j.get(k).and_then(|v| v.as_f64()).unwrap_or(f64::MAX);
    out.sort_by(|(sa, a), (sb, b)| {
        key(a, "submitted_unix")
            .partial_cmp(&key(b, "submitted_unix"))
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(
                key(a, "id")
                    .partial_cmp(&key(b, "id"))
                    .unwrap_or(std::cmp::Ordering::Equal),
            )
            .then(sa.cmp(sb))
    });
    Ok(out)
}

/// Server side: run a [`Service`] against a job directory until stopped.
/// Returns the final service metrics (also written to
/// `service_metrics.json`).
pub fn serve(cfg: ServiceConfig, opts: &ServeOptions) -> Result<Json> {
    let dir = &opts.jobs_dir;
    for sub in ["inbox", "archive", "status", "results"] {
        let p = dir.join(sub);
        fs::create_dir_all(&p).map_err(|e| Error::io(p.display(), e))?;
    }
    // A stop file is a one-shot signal; a stale one from a previous run
    // must not brick the restarted server.
    let _ = fs::remove_file(dir.join("stop"));
    let mut svc = Service::start(cfg)?;
    let t0 = Instant::now();
    let mut served_any = false;
    let mut stem_of: BTreeMap<JobId, String> = BTreeMap::new();
    let mut last_status: BTreeMap<JobId, String> = BTreeMap::new();

    loop {
        ingest_inbox(dir, &svc, &mut stem_of, &mut served_any)?;
        sync_status(dir, &svc, &mut stem_of, &mut last_status)?;

        if dir.join("stop").exists() {
            break;
        }
        if opts.drain && served_any && svc.idle() && inbox_empty(dir) {
            break;
        }
        if let Some(max) = opts.max_secs {
            if t0.elapsed().as_secs_f64() >= max {
                break;
            }
        }
        std::thread::sleep(Duration::from_millis(opts.poll_ms));
    }
    // Drain first — the shutdown finishes all in-flight jobs — and only
    // then write the final sync, so results completed during the drain
    // still land on disk for waiting clients.
    svc.stop();
    sync_status(dir, &svc, &mut stem_of, &mut last_status)?;
    let metrics = svc.metrics_json();
    write_atomic(&dir.join("service_metrics.json"), &metrics.pretty())?;
    Ok(metrics)
}

fn inbox_empty(dir: &Path) -> bool {
    fs::read_dir(dir.join("inbox"))
        .map(|rd| {
            !rd.filter_map(|e| e.ok())
                .any(|e| e.path().extension().is_some_and(|x| x == "json"))
        })
        .unwrap_or(true)
}

fn ingest_inbox(
    dir: &Path,
    svc: &Service,
    stem_of: &mut BTreeMap<JobId, String>,
    served_any: &mut bool,
) -> Result<()> {
    let inbox = dir.join("inbox");
    let mut files: Vec<PathBuf> = fs::read_dir(&inbox)
        .map_err(|e| Error::io(inbox.display(), e))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "json"))
        .collect();
    files.sort();
    for f in files {
        // The inbox is a durable queue: under a momentary full queue (or
        // shutdown) leave submissions in place as backpressure rather
        // than converting them into hard rejections.
        if svc.queue().is_full() || svc.queue().is_shutdown() {
            break;
        }
        let stem = f
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("submission")
            .to_string();
        let outcome = fs::read_to_string(&f)
            .map_err(|e| Error::io(f.display(), e).to_string())
            .and_then(|text| Json::parse(&text).map_err(|e| e.to_string()))
            .and_then(|j| JobSpec::from_json(&j).map_err(|e| e.to_string()))
            .and_then(|spec| svc.submit(spec).map_err(|e| e.to_string()));
        match outcome {
            Ok(id) => {
                *served_any = true;
                stem_of.insert(id, stem);
            }
            // Races with the capacity guard above are transient too.
            Err(msg) if msg.contains("queue full") || msg.contains("shutting down") => {
                continue; // keep the file; retry next poll
            }
            Err(msg) => {
                // Malformed or over-limit: terminally rejected as a result.
                let rj = Json::obj(vec![
                    ("status", Json::Str("rejected".into())),
                    ("error", Json::Str(msg)),
                ]);
                write_atomic(
                    &dir.join("results").join(format!("{stem}.json")),
                    &rj.pretty(),
                )?;
            }
        }
        let archived = dir.join("archive").join(format!("{stem}.json"));
        if fs::rename(&f, &archived).is_err() {
            let _ = fs::remove_file(&f); // cross-device fallback: drop it
        }
    }
    Ok(())
}

fn sync_status(
    dir: &Path,
    svc: &Service,
    stem_of: &mut BTreeMap<JobId, String>,
    last_status: &mut BTreeMap<JobId, String>,
) -> Result<()> {
    let mut finished: Vec<JobId> = Vec::new();
    for view in svc.queue().snapshot() {
        let Some(stem) = stem_of.get(&view.id) else {
            continue; // submitted in-process, not through the inbox
        };
        let status_json = view.to_json().pretty();
        if last_status.get(&view.id) != Some(&status_json) {
            write_atomic(
                &dir.join("status").join(format!("{stem}.json")),
                &status_json,
            )?;
            last_status.insert(view.id, status_json);
        }
        if view.status.is_terminal() {
            if let Some(result) = svc.queue().result_json(view.id) {
                write_atomic(
                    &dir.join("results").join(format!("{stem}.json")),
                    &result.pretty(),
                )?;
                finished.push(view.id);
            }
        }
    }
    // Results are on disk; release the queue's retained state and the
    // loop's bookkeeping so a long-running server stays bounded.
    for id in finished {
        svc.queue().forget(id);
        stem_of.remove(&id);
        last_status.remove(&id);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ComputePrecision, Preset};
    use crate::io::{GammaStore, StoreCodec, StorePrecision};

    fn scratch(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "fastmps-api-{tag}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn make_store(root: &Path) -> PathBuf {
        let dir = root.join("store");
        let mut spec = Preset::Jiuzhang2.scaled_spec(5);
        spec.m = 5;
        spec.chi_cap = 8;
        spec.decay_k = 0.0;
        spec.displacement_sigma = 0.0;
        GammaStore::create(&dir, &spec, StorePrecision::F32, StoreCodec::Raw).unwrap();
        dir
    }

    fn serve_cfg() -> ServiceConfig {
        ServiceConfig {
            workers: 2,
            n2_micro: 32,
            target_batch: Some(128),
            compute: ComputePrecision::F64,
            linger_ms: 2,
            ..Default::default()
        }
    }

    #[test]
    fn malformed_submission_rejected_via_results_file() {
        let root = scratch("reject");
        let jobs = root.join("jobs");
        fs::create_dir_all(jobs.join("inbox")).unwrap();
        fs::write(jobs.join("inbox/bad.json"), "{not json").unwrap();
        let opts = ServeOptions {
            drain: false,
            max_secs: Some(1.0),
            poll_ms: 5,
            jobs_dir: jobs.clone(),
        };
        serve(serve_cfg(), &opts).unwrap();
        let r = fs::read_to_string(jobs.join("results/bad.json")).unwrap();
        let j = Json::parse(&r).unwrap();
        assert_eq!(j.get("status").unwrap().as_str(), Some("rejected"));
        assert!(!jobs.join("inbox/bad.json").exists(), "inbox consumed");
        fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn stop_file_halts_the_loop_without_bricking_restart() {
        let root = scratch("stop");
        let jobs = root.join("jobs");
        fs::create_dir_all(&jobs).unwrap();
        let opts = ServeOptions {
            drain: false,
            max_secs: Some(30.0),
            poll_ms: 5,
            jobs_dir: jobs.clone(),
        };
        let t0 = Instant::now();
        let server = {
            let o = opts.clone();
            std::thread::spawn(move || serve(serve_cfg(), &o))
        };
        std::thread::sleep(Duration::from_millis(50));
        fs::write(jobs.join("stop"), "").unwrap();
        server.join().unwrap().unwrap();
        assert!(t0.elapsed().as_secs_f64() < 10.0);
        assert!(jobs.join("service_metrics.json").exists());
        // The stale stop file must not stop the next server at boot: a
        // restart consumes it and serves until its own cap.
        let opts2 = ServeOptions {
            max_secs: Some(0.2),
            ..opts
        };
        serve(serve_cfg(), &opts2).unwrap();
        assert!(!jobs.join("stop").exists());
        fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn list_jobs_empty_when_no_server_ran() {
        let root = scratch("list");
        assert!(list_jobs(&root.join("nowhere")).unwrap().is_empty());
        fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn list_jobs_sorted_by_submit_time_then_id() {
        let root = scratch("sorted");
        let status = root.join("status");
        fs::create_dir_all(&status).unwrap();
        // Stem order (a, b, c) deliberately disagrees with submit order.
        let write = |stem: &str, id: f64, t: f64| {
            let j = Json::obj(vec![
                ("id", Json::Num(id)),
                ("status", Json::Str("done".into())),
                ("submitted_unix", Json::Num(t)),
            ]);
            fs::write(status.join(format!("{stem}.json")), j.pretty()).unwrap();
        };
        write("a", 3.0, 300.0);
        write("b", 1.0, 100.0);
        write("c", 2.0, 100.0);
        let listed = list_jobs(&root).unwrap();
        let stems: Vec<&str> = listed.iter().map(|(s, _)| s.as_str()).collect();
        assert_eq!(stems, vec!["b", "c", "a"], "time asc, then id");
        fs::remove_dir_all(&root).unwrap();
    }

    #[test]
    fn wait_result_backoff_sees_late_results_and_times_out() {
        let root = scratch("backoff");
        let results = root.join("results");
        fs::create_dir_all(&results).unwrap();
        // Timeout path is fast and reports the path.
        let e = wait_result_poll(&root, "nope", Duration::from_millis(40), 10)
            .unwrap_err()
            .to_string();
        assert!(e.contains("timed out"), "{e}");
        // A result landing mid-wait is picked up despite the backoff.
        let writer = {
            let results = results.clone();
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(60));
                fs::write(results.join("late.json"), "{\"status\": \"done\"}").unwrap();
            })
        };
        let j = wait_result_poll(&root, "late", Duration::from_secs(10), 50).unwrap();
        assert_eq!(j.get("status").unwrap().as_str(), Some("done"));
        writer.join().unwrap();
        fs::remove_dir_all(&root).unwrap();
    }
}
