//! Job descriptions and status — the service's unit of work.
//!
//! A [`JobSpec`] is what a client submits: which store to sample, how many
//! samples, and (optionally) a compute-precision override plus the base of
//! the job's sample-index stream. Sample streams are keyed by
//! `(site, sample index)` in the store spec's RNG, so two jobs with the
//! same base against the same store draw *identical* outcomes — callers
//! wanting fresh randomness pass distinct `sample_base`s (reproducibility
//! by default, the same partition-invariant-stream policy the coordinators
//! use).

use std::path::PathBuf;

use crate::config::ComputePrecision;
use crate::mps::workload::WorkloadKind;
use crate::util::error::{Error, Result};
use crate::util::json::Json;

/// Service-assigned job identifier (monotonic per service instance).
pub type JobId = u64;

/// One member of a tensor-parallel group, as seen by the group leader.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TpPeer {
    /// FMPN address (`host:port`) of the follower backend.
    pub addr: String,
    /// Content key of the Γ shard store that follower holds.
    pub key: u64,
}

/// Tensor-parallel placement of a job (`docs/TENSOR_PARALLEL.md`).
///
/// Two wire shapes share this struct. A *request* (client → router) has
/// `peers` empty: "run this against the `of`-way sharding of store
/// `base`". The router resolves it from its shard map into a *placement*
/// (router → leader backend) whose `peers` lists ranks 1.. in order —
/// rank 0 is the backend receiving the spec, whose own shard key replaces
/// [`JobSpec::key`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TpGroup {
    /// Group size (number of shards / backends).
    pub of: usize,
    /// Manifest hash of the *full* (unsharded) store.
    pub base: u64,
    /// Followers in rank order (ranks `1..of`); empty in a request.
    pub peers: Vec<TpPeer>,
}

impl TpGroup {
    fn from_json(j: &Json) -> Result<TpGroup> {
        let of = j
            .req("of")?
            .as_f64()
            .filter(|v| *v >= 2.0 && v.fract() == 0.0)
            .ok_or_else(|| Error::format("job: tp 'of' is not an integer ≥ 2"))?
            as usize;
        let base = j
            .req("base")?
            .as_str()
            .and_then(|s| u64::from_str_radix(s, 16).ok())
            .ok_or_else(|| Error::format("job: tp 'base' is not a hex store key"))?;
        let mut peers = Vec::new();
        if let Some(list) = j.get("peers") {
            let arr = list
                .as_arr()
                .ok_or_else(|| Error::format("job: tp 'peers' is not an array"))?;
            for p in arr {
                let addr = p
                    .req("addr")?
                    .as_str()
                    .filter(|a| !a.is_empty())
                    .ok_or_else(|| Error::format("job: tp peer 'addr' is not a string"))?
                    .to_string();
                let key = p
                    .req("key")?
                    .as_str()
                    .and_then(|s| u64::from_str_radix(s, 16).ok())
                    .ok_or_else(|| Error::format("job: tp peer 'key' is not a hex store key"))?;
                peers.push(TpPeer { addr, key });
            }
        }
        if !peers.is_empty() && peers.len() != of - 1 {
            return Err(Error::format(format!(
                "job: tp group of {of} needs {} peers, got {}",
                of - 1,
                peers.len()
            )));
        }
        Ok(TpGroup { of, base, peers })
    }

    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("of", Json::Num(self.of as f64)),
            ("base", Json::Str(format!("{:016x}", self.base))),
        ];
        if !self.peers.is_empty() {
            fields.push((
                "peers",
                Json::Arr(
                    self.peers
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("addr", Json::Str(p.addr.clone())),
                                ("key", Json::Str(format!("{:016x}", p.key))),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        Json::obj(fields)
    }
}

/// A client sampling request.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Path of the `GammaStore` directory (may be empty when `key` names
    /// the store by content instead).
    pub data: PathBuf,
    /// Content key of the store (its manifest hash) — how jobs reference
    /// a store uploaded with `fastmps push`, with no shared filesystem.
    /// When set, routing and resolution ignore `data`.
    pub key: Option<u64>,
    /// Samples requested.
    pub n_samples: u64,
    /// Base of the job's sample-index stream (see module docs).
    pub sample_base: u64,
    /// Per-job override of the service-wide compute precision.
    pub compute: Option<ComputePrecision>,
    /// Free-form client tag, echoed in status and results.
    pub tag: String,
    /// Flight-recorder trace id (`docs/PROTOCOL.md` § Trace propagation).
    /// Optional on the wire as a 16-hex-digit string; peers that predate
    /// tracing ignore it (unknown JSON keys are skipped) or omit it, and
    /// the job runs untraced either way. `None`/zero means untraced.
    pub trace: Option<u64>,
    /// Tensor-parallel placement (`docs/TENSOR_PARALLEL.md`). `None` for
    /// ordinary single-backend jobs; omitted from the wire form so
    /// non-TP submits stay byte-identical to pre-TP builds.
    pub tp: Option<TpGroup>,
    /// Measurement model the job declares (`docs/WORKLOADS.md`). The
    /// resolved store's manifest is authoritative — the service rejects
    /// the job if the two disagree. GBS is the default and is omitted
    /// from the wire form, so GBS submits stay byte-identical to
    /// pre-workload builds (same skew contract as `trace`).
    pub workload: WorkloadKind,
}

impl JobSpec {
    pub fn new(data: impl Into<PathBuf>, n_samples: u64) -> JobSpec {
        JobSpec {
            data: data.into(),
            key: None,
            n_samples,
            sample_base: 0,
            compute: None,
            tag: String::new(),
            trace: None,
            tp: None,
            workload: WorkloadKind::Gbs,
        }
    }

    /// A job that names its store by content key (see [`JobSpec::key`]).
    pub fn by_key(key: u64, n_samples: u64) -> JobSpec {
        JobSpec {
            data: PathBuf::new(),
            key: Some(key),
            n_samples,
            sample_base: 0,
            compute: None,
            tag: String::new(),
            trace: None,
            tp: None,
            workload: WorkloadKind::Gbs,
        }
    }

    /// Stable routing/affinity key of this job's store.
    ///
    /// A content-keyed job ([`JobSpec::key`]) *is* its affinity key — no
    /// filesystem involved, which is what lets a router without any data
    /// volume still key on content. For path jobs: when the manifest is
    /// readable from this process the key is its content hash
    /// ([`crate::io::manifest_hash_at`]) — every path to one store shares
    /// a key, and the router lands all of its jobs on the backend whose
    /// `StoreCache` already holds that store. When the manifest is *not*
    /// readable (a router without the data volume mounted), the key falls
    /// back to an FNV-1a hash of the path string: affinity is still
    /// deterministic, just keyed on path spelling instead of content —
    /// push the store and submit by key to avoid that degradation.
    pub fn store_key(&self) -> u64 {
        if let Some(k) = self.key {
            return k;
        }
        crate::io::manifest_hash_at(&self.data)
            .unwrap_or_else(|_| crate::util::fnv1a(self.data.to_string_lossy().as_bytes()))
    }

    /// Parse the wire form used by the file transport (`api`).
    pub fn from_json(j: &Json) -> Result<JobSpec> {
        let key = j
            .get("key")
            .filter(|v| !matches!(**v, Json::Null))
            .map(|v| {
                v.as_str()
                    .and_then(|s| u64::from_str_radix(s, 16).ok())
                    .ok_or_else(|| Error::format("job: 'key' is not a hex store key"))
            })
            .transpose()?;
        let data = match j.get("data") {
            Some(v) => v
                .as_str()
                .ok_or_else(|| Error::format("job: 'data' not a string"))?,
            None if key.is_some() => "",
            None => return Err(Error::format("job: needs 'data' or 'key'")),
        };
        if key.is_none() && data.is_empty() {
            return Err(Error::format("job: needs 'data' or 'key'"));
        }
        let n_samples = j
            .req("samples")?
            .as_f64()
            .filter(|v| *v >= 0.0 && v.fract() == 0.0)
            .ok_or_else(|| Error::format("job: 'samples' not a non-negative integer"))?
            as u64;
        let sample_base = j
            .get("sample_base")
            .map(|v| {
                v.as_f64()
                    .filter(|x| *x >= 0.0 && x.fract() == 0.0)
                    .ok_or_else(|| Error::format("job: bad 'sample_base'"))
            })
            .transpose()?
            .unwrap_or(0.0) as u64;
        let compute = j
            .get("compute")
            .map(|v| {
                v.as_str()
                    .ok_or_else(|| Error::format("job: 'compute' not a string"))
                    .and_then(ComputePrecision::parse)
            })
            .transpose()?;
        let tag = j
            .get("tag")
            .and_then(|v| v.as_str())
            .unwrap_or("")
            .to_string();
        // Deliberately lenient: a missing, null, or malformed trace id
        // degrades to "untraced", never to a rejected job — the skew
        // contract of docs/PROTOCOL.md § Trace propagation.
        let trace = j
            .get("trace")
            .and_then(|v| v.as_str())
            .and_then(crate::trace::parse_trace_id);
        // Unlike trace, a malformed tp section is a hard error: silently
        // running a TP request as a serial job would sample the wrong
        // store (one shard) and return garbage marked "done".
        let tp = j
            .get("tp")
            .filter(|v| !matches!(**v, Json::Null))
            .map(TpGroup::from_json)
            .transpose()?;
        // Absent/null means GBS (pre-workload peers). An unknown name is
        // a hard error — running a qubit job as GBS would silently sample
        // the wrong distribution, the same hazard class as `tp` above.
        let workload = j
            .get("workload")
            .filter(|v| !matches!(**v, Json::Null))
            .map(|v| {
                v.as_str()
                    .ok_or_else(|| Error::format("job: 'workload' not a string"))
                    .and_then(WorkloadKind::parse)
            })
            .transpose()?
            .unwrap_or(WorkloadKind::Gbs);
        Ok(JobSpec {
            data: PathBuf::from(data),
            key,
            n_samples,
            sample_base,
            compute,
            tag,
            trace,
            tp,
            workload,
        })
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("data", Json::Str(self.data.display().to_string())),
            (
                "key",
                self.key
                    .map(|k| Json::Str(format!("{k:016x}")))
                    .unwrap_or(Json::Null),
            ),
            ("samples", Json::Num(self.n_samples as f64)),
            ("sample_base", Json::Num(self.sample_base as f64)),
            (
                "compute",
                self.compute
                    .map(|c| Json::Str(c.as_str().into()))
                    .unwrap_or(Json::Null),
            ),
            ("tag", Json::Str(self.tag.clone())),
        ];
        // Omitted (not null) when untraced, so the wire form of an
        // untraced job is byte-identical to pre-tracing builds.
        if let Some(t) = self.trace.filter(|t| *t != 0) {
            fields.push(("trace", Json::Str(format!("{t:016x}"))));
        }
        if let Some(tp) = &self.tp {
            fields.push(("tp", tp.to_json()));
        }
        // Omitted (not null) for GBS, so the wire form of a GBS job is
        // byte-identical to pre-workload builds.
        if self.workload != WorkloadKind::Gbs {
            fields.push(("workload", Json::Str(self.workload.as_str().into())));
        }
        Json::obj(fields)
    }
}

/// Lifecycle of a job. `Queued → Running → Done` on success; admission
/// rejections never enter the queue, so `Failed` means a runtime error
/// (store open, engine, I/O) after acceptance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobStatus {
    Queued,
    Running,
    Done,
    Failed,
}

impl JobStatus {
    pub fn as_str(self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Running => "running",
            JobStatus::Done => "done",
            JobStatus::Failed => "failed",
        }
    }

    pub fn is_terminal(self) -> bool {
        matches!(self, JobStatus::Done | JobStatus::Failed)
    }
}

/// Public snapshot of a job (what `fastmps jobs` prints).
#[derive(Debug, Clone)]
pub struct JobView {
    pub id: JobId,
    pub tag: String,
    pub status: JobStatus,
    pub n_samples: u64,
    pub done: u64,
    pub error: Option<String>,
    /// Wall-clock submit time, unix seconds (listing sort key).
    pub submitted_unix: f64,
    pub latency_secs: Option<f64>,
    /// The job's trace id, when it was submitted traced.
    pub trace: Option<u64>,
    /// Measurement model the job declared at submit ("gbs", "qubit").
    pub workload: WorkloadKind,
}

/// Deterministic listing order: submit time, then id. Stable for
/// scripting and tests regardless of how a transport gathered the views.
pub fn sort_views(views: &mut [JobView]) {
    views.sort_by(|a, b| {
        a.submitted_unix
            .partial_cmp(&b.submitted_unix)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.id.cmp(&b.id))
    });
}

impl JobView {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("tag", Json::Str(self.tag.clone())),
            ("status", Json::Str(self.status.as_str().into())),
            ("samples", Json::Num(self.n_samples as f64)),
            ("done", Json::Num(self.done as f64)),
            ("submitted_unix", Json::Num(self.submitted_unix)),
            (
                "error",
                self.error
                    .clone()
                    .map(Json::Str)
                    .unwrap_or(Json::Null),
            ),
            (
                "latency_secs",
                self.latency_secs.map(Json::Num).unwrap_or(Json::Null),
            ),
            (
                "trace",
                self.trace
                    .filter(|t| *t != 0)
                    .map(|t| Json::Str(format!("{t:016x}")))
                    .unwrap_or(Json::Null),
            ),
            ("workload", Json::Str(self.workload.as_str().into())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_json_roundtrip() {
        let mut s = JobSpec::new("/tmp/store", 1000);
        s.sample_base = 42;
        s.compute = Some(ComputePrecision::F64);
        s.tag = "client-7".into();
        let j = s.to_json().dump();
        let back = JobSpec::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.data, s.data);
        assert_eq!(back.n_samples, 1000);
        assert_eq!(back.sample_base, 42);
        assert_eq!(back.compute, Some(ComputePrecision::F64));
        assert_eq!(back.tag, "client-7");
    }

    #[test]
    fn spec_json_defaults_optional_fields() {
        let j = Json::parse(r#"{"data": "/d", "samples": 5}"#).unwrap();
        let s = JobSpec::from_json(&j).unwrap();
        assert_eq!(s.sample_base, 0);
        assert_eq!(s.compute, None);
        assert!(s.tag.is_empty());
        assert_eq!(s.trace, None);
    }

    #[test]
    fn trace_id_roundtrips_and_degrades_tolerantly() {
        let mut s = JobSpec::new("/d", 5);
        s.trace = Some(0x00ab_cdef_0123_4567);
        let j = s.to_json();
        assert_eq!(j.get("trace").unwrap().as_str(), Some("00abcdef01234567"));
        let back = JobSpec::from_json(&j).unwrap();
        assert_eq!(back.trace, Some(0x00ab_cdef_0123_4567));
        // Untraced specs omit the field entirely (old-peer byte parity).
        assert!(JobSpec::new("/d", 5).to_json().get("trace").is_none());
        // Malformed / null / zero trace ids parse as untraced, never as
        // an error — new-server-old-client skew must not break submits.
        for wire in [
            r#"{"data": "/d", "samples": 5, "trace": null}"#,
            r#"{"data": "/d", "samples": 5, "trace": "zz"}"#,
            r#"{"data": "/d", "samples": 5, "trace": 12}"#,
            r#"{"data": "/d", "samples": 5, "trace": "0000000000000000"}"#,
        ] {
            let s = JobSpec::from_json(&Json::parse(wire).unwrap()).unwrap();
            assert_eq!(s.trace, None, "{wire}");
        }
    }

    #[test]
    fn workload_field_roundtrips_and_defaults_to_gbs() {
        // Qubit jobs carry the tag and round-trip it.
        let mut s = JobSpec::by_key(0xbeef, 16);
        s.workload = WorkloadKind::Qubit;
        let j = s.to_json();
        assert_eq!(j.get("workload").unwrap().as_str(), Some("qubit"));
        let back = JobSpec::from_json(&j).unwrap();
        assert_eq!(back.workload, WorkloadKind::Qubit);
        // GBS jobs omit the field entirely (old-peer byte parity).
        assert!(JobSpec::by_key(0xbeef, 16).to_json().get("workload").is_none());
        // Absent and null both parse as GBS.
        for wire in [
            r#"{"key": "ff", "samples": 5}"#,
            r#"{"key": "ff", "samples": 5, "workload": null}"#,
        ] {
            let s = JobSpec::from_json(&Json::parse(wire).unwrap()).unwrap();
            assert_eq!(s.workload, WorkloadKind::Gbs, "{wire}");
        }
        // An unknown name is a typed refusal that lists the valid set.
        let j = Json::parse(r#"{"key": "ff", "samples": 5, "workload": "ising"}"#).unwrap();
        let e = JobSpec::from_json(&j).unwrap_err().to_string();
        assert!(e.contains("unknown workload"), "{e}");
        assert!(e.contains("gbs, qubit"), "{e}");
        // Non-string workload is malformed, not silently GBS.
        let j = Json::parse(r#"{"key": "ff", "samples": 5, "workload": 2}"#).unwrap();
        assert!(JobSpec::from_json(&j).is_err());
    }

    #[test]
    fn tp_group_roundtrips_request_and_placement() {
        // Request shape: peers empty, omitted from the wire.
        let mut s = JobSpec::by_key(0xbeef, 64);
        s.tp = Some(TpGroup {
            of: 2,
            base: 0xbeef,
            peers: Vec::new(),
        });
        let j = s.to_json();
        assert!(j.get("tp").unwrap().get("peers").is_none());
        let back = JobSpec::from_json(&j).unwrap();
        assert_eq!(back.tp, s.tp);
        // Placement shape: the router filled peers in rank order.
        s.tp = Some(TpGroup {
            of: 3,
            base: 0xbeef,
            peers: vec![
                TpPeer {
                    addr: "b1:9000".into(),
                    key: 0x11,
                },
                TpPeer {
                    addr: "b2:9000".into(),
                    key: 0x22,
                },
            ],
        });
        let back = JobSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(back.tp, s.tp);
        // Non-TP specs omit the field entirely (old-peer byte parity).
        assert!(JobSpec::by_key(0xbeef, 64).to_json().get("tp").is_none());
    }

    #[test]
    fn tp_group_rejects_malformed() {
        for bad in [
            // of must be ≥ 2
            r#"{"key": "ff", "samples": 5, "tp": {"of": 1, "base": "aa"}}"#,
            // base must be hex
            r#"{"key": "ff", "samples": 5, "tp": {"of": 2, "base": 3}}"#,
            // missing base
            r#"{"key": "ff", "samples": 5, "tp": {"of": 2}}"#,
            // peer count must be of-1 when present
            r#"{"key": "ff", "samples": 5,
                "tp": {"of": 3, "base": "aa", "peers": [{"addr": "x:1", "key": "bb"}]}}"#,
            // peer addr/key malformed
            r#"{"key": "ff", "samples": 5,
                "tp": {"of": 2, "base": "aa", "peers": [{"addr": "", "key": "bb"}]}}"#,
            r#"{"key": "ff", "samples": 5,
                "tp": {"of": 2, "base": "aa", "peers": [{"addr": "x:1", "key": "zz"}]}}"#,
            r#"{"key": "ff", "samples": 5, "tp": {"of": 2, "base": "aa", "peers": 7}}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(JobSpec::from_json(&j).is_err(), "{bad}");
        }
        // Null tp degrades to non-TP (matches the key-field convention).
        let j = Json::parse(r#"{"key": "ff", "samples": 5, "tp": null}"#).unwrap();
        assert_eq!(JobSpec::from_json(&j).unwrap().tp, None);
    }

    #[test]
    fn spec_json_rejects_malformed() {
        for bad in [
            r#"{"samples": 5}"#,
            r#"{"data": "/d"}"#,
            r#"{"data": "/d", "samples": -1}"#,
            r#"{"data": "/d", "samples": 1.5}"#,
            r#"{"data": "/d", "samples": 5, "compute": "q8"}"#,
            r#"{"data": "", "samples": 5}"#,
            r#"{"key": "not-hex", "samples": 5}"#,
            r#"{"key": 17, "samples": 5}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(JobSpec::from_json(&j).is_err(), "{bad}");
        }
    }

    #[test]
    fn content_keyed_spec_roundtrips_and_keys_affinity() {
        let s = JobSpec::by_key(0xdead_beef_0042_1337, 64);
        assert_eq!(s.store_key(), 0xdead_beef_0042_1337, "key IS the affinity");
        let j = s.to_json().dump();
        let back = JobSpec::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.key, Some(0xdead_beef_0042_1337));
        assert_eq!(back.n_samples, 64);
        assert_eq!(back.store_key(), s.store_key());
        // Without "data" at all, a keyed spec still parses.
        let j = Json::parse(r#"{"key": "00000000000000ff", "samples": 3}"#).unwrap();
        let k = JobSpec::from_json(&j).unwrap();
        assert_eq!(k.key, Some(0xff));
        assert_eq!(k.store_key(), 0xff);
    }

    #[test]
    fn store_key_is_stable_and_distinguishes_paths() {
        let a = JobSpec::new("/nonexistent/fastmps-store-a", 1);
        let b = JobSpec::new("/nonexistent/fastmps-store-b", 1);
        assert_eq!(a.store_key(), a.store_key(), "deterministic");
        assert_eq!(
            a.store_key(),
            JobSpec::new("/nonexistent/fastmps-store-a", 999).store_key(),
            "key depends on the store, not the job shape"
        );
        assert_ne!(a.store_key(), b.store_key());
    }

    #[test]
    fn views_sort_by_submit_time_then_id() {
        let view = |id: JobId, t: f64| JobView {
            id,
            tag: String::new(),
            status: JobStatus::Queued,
            n_samples: 1,
            done: 0,
            error: None,
            submitted_unix: t,
            latency_secs: None,
            trace: None,
            workload: WorkloadKind::Gbs,
        };
        let mut vs = vec![view(3, 20.0), view(2, 10.0), view(1, 10.0), view(4, 5.0)];
        sort_views(&mut vs);
        let ids: Vec<JobId> = vs.iter().map(|v| v.id).collect();
        assert_eq!(ids, vec![4, 1, 2, 3]);
        assert!(vs[0].to_json().get("submitted_unix").is_some());
    }

    #[test]
    fn status_strings_and_terminality() {
        assert_eq!(JobStatus::Queued.as_str(), "queued");
        assert!(!JobStatus::Running.is_terminal());
        assert!(JobStatus::Done.is_terminal());
        assert!(JobStatus::Failed.is_terminal());
    }
}
