//! Macro-batch formation: compatibility keys and §3.1-driven sizing.
//!
//! The batcher turns queued jobs into [`Batch`]es of rows that walk the
//! chain together: one Γ stream (one prefetcher pass, one disk charge)
//! serves every job in the batch. Compatibility = same store (by manifest
//! hash, i.e. the same cached `Arc<GammaStore>`) and the same compute
//! precision, since rows of one batch run through one engine.
//!
//! Sizing realises the paper's overlap condition: compute at a site must
//! hide that site's I/O, which holds once the batch carries at least
//! `min_macro_batch_for_overlap` rows (§3.1); Eq. 3 caps the row count by
//! the per-worker memory budget. Both are taken from `perfmodel` through
//! `scheduler::suggest_n1`, so the service and the one-shot CLI agree on
//! what a well-sized macro batch is.

use std::sync::Arc;

use super::job::TpGroup;
use super::queue::Assignment;
use crate::config::{ComputePrecision, ServiceConfig};
use crate::coordinator::scheduler;
use crate::io::GammaStore;
use crate::perfmodel;

/// Jobs sharing a key may share a macro batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchKey {
    pub store_hash: u64,
    pub compute: ComputePrecision,
}

/// One dispatched macro batch: slices of one or more jobs against a single
/// cached store.
pub struct Batch {
    pub key: BatchKey,
    pub store: Arc<GammaStore>,
    pub assignments: Vec<Assignment>,
    /// Row target the batch was sized against (for occupancy accounting).
    pub target: usize,
    /// Tensor-parallel placement: the worker runs this batch as a group
    /// leader over `net::tp` instead of a local walk. Always a batch of
    /// exactly one job (the dispatcher never coalesces TP jobs).
    pub tp: Option<TpGroup>,
}

impl Batch {
    pub fn rows(&self) -> usize {
        self.assignments.iter().map(|a| a.len).sum()
    }

    /// Fill fraction vs the §3.1 target; > 1 never happens by construction.
    pub fn occupancy(&self) -> f64 {
        self.rows() as f64 / self.target.max(1) as f64
    }
}

/// Row target for batches against `store`: the configured override, or the
/// overlap/memory-derived suggestion for the CPU testbed device.
pub fn target_rows(cfg: &ServiceConfig, store: &GammaStore) -> usize {
    if let Some(t) = cfg.target_batch {
        return t.max(cfg.n2_micro);
    }
    let scalar = store.precision.bytes_per_scalar();
    let n1 = scheduler::suggest_n1(
        &perfmodel::XEON_CORE,
        store.spec.chi_cap(),
        store.spec.d(),
        scalar,
        cfg.mem_budget,
    );
    // Keep at least one micro batch and bound the env allocation.
    n1.clamp(cfg.n2_micro, 1 << 16)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Preset;
    use crate::io::{StoreCodec, StorePrecision};

    fn store_on_disk(tag: &str, precision: StorePrecision) -> (Arc<GammaStore>, std::path::PathBuf) {
        let dir = std::env::temp_dir().join(format!(
            "fastmps-batcher-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let mut spec = Preset::Jiuzhang2.scaled_spec(3);
        spec.m = 4;
        spec.chi_cap = 8;
        let s = Arc::new(GammaStore::create(&dir, &spec, precision, StoreCodec::Raw).unwrap());
        (s, dir)
    }

    #[test]
    fn explicit_target_wins_and_respects_micro_batch() {
        let (store, dir) = store_on_disk("explicit", StorePrecision::F32);
        let cfg = ServiceConfig {
            target_batch: Some(4096),
            ..Default::default()
        };
        assert_eq!(target_rows(&cfg, &store), 4096);
        let cfg = ServiceConfig {
            target_batch: Some(1),
            n2_micro: 64,
            ..Default::default()
        };
        assert_eq!(target_rows(&cfg, &store), 64);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn derived_target_scales_with_store_precision() {
        // §3.1: wider scalars mean more I/O bytes per site, so overlap
        // needs a larger macro batch.
        let (s16, d16) = store_on_disk("tf16", StorePrecision::F16);
        let (s64, d64) = store_on_disk("tf64", StorePrecision::F64);
        let cfg = ServiceConfig {
            n2_micro: 1,
            ..Default::default()
        };
        let t16 = target_rows(&cfg, &s16);
        let t64 = target_rows(&cfg, &s64);
        assert!(
            t64 >= t16,
            "f64 store target {t64} should be ≥ f16 target {t16}"
        );
        assert!(t16 >= 1 && t64 <= 1 << 16);
        std::fs::remove_dir_all(&d16).unwrap();
        std::fs::remove_dir_all(&d64).unwrap();
    }

    #[test]
    fn occupancy_reflects_fill() {
        let (store, dir) = store_on_disk("occ", StorePrecision::F32);
        let b = Batch {
            key: BatchKey {
                store_hash: 1,
                compute: ComputePrecision::F32,
            },
            store,
            assignments: vec![
                Assignment { job: 1, sample0: 0, len: 30 },
                Assignment { job: 2, sample0: 0, len: 20 },
            ],
            target: 100,
            tp: None,
        };
        assert_eq!(b.rows(), 50);
        assert!((b.occupancy() - 0.5).abs() < 1e-12);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
