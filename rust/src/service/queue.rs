//! The job queue: admission control, FIFO batching source, per-job status
//! and result accumulation, and completion latency tracking.
//!
//! One mutex guards the whole queue state; every mutation signals the
//! condvar so both the dispatcher (`wait_pending`) and blocked clients
//! (`wait_job`) wake promptly. Nothing inside the lock does I/O.

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::job::{JobId, JobSpec, JobStatus, JobView};
use crate::metrics::{keys, HistogramStats, LatencyStats, Metrics};
use crate::sampler::sink::SampleSink;
use crate::trace::{Layer, Recorder};
use crate::util::error::{Error, Result};
use crate::util::json::Json;

/// A contiguous slice of one job's samples placed into a macro batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Assignment {
    pub job: JobId,
    /// First sample index of the slice in the job's stream (includes the
    /// job's `sample_base`).
    pub sample0: u64,
    pub len: usize,
}

/// Admission limits enforced at submit time.
#[derive(Debug, Clone, Copy)]
pub struct AdmissionLimits {
    /// Max jobs queued or running at once.
    pub max_queue: usize,
    /// Max samples one job may request.
    pub max_samples_per_job: u64,
}

struct JobState {
    spec: JobSpec,
    status: JobStatus,
    /// Samples handed to batches so far.
    assigned: u64,
    /// Samples completed so far.
    done: u64,
    sink: Option<SampleSink>,
    error: Option<String>,
    t_submit: Instant,
    /// Wall-clock submit time (unix seconds) — listing sort key; `Instant`
    /// above stays the latency clock (monotonic).
    submitted_unix: f64,
    latency_secs: Option<f64>,
}

/// Terminal jobs retained for status/result queries before being evicted
/// oldest-first; bounds a long-lived service's memory. Transports that
/// persist results call [`JobQueue::forget`] to release jobs eagerly.
const MAX_TERMINAL_HISTORY: usize = 4096;

struct Inner {
    next_id: JobId,
    jobs: BTreeMap<JobId, JobState>,
    /// Jobs with unassigned samples, in arrival order.
    pending: VecDeque<JobId>,
    /// Non-terminal job count (admission control, O(1)).
    active: usize,
    /// Terminal jobs, completion order — the eviction queue.
    terminal_order: VecDeque<JobId>,
    shutdown: bool,
    peak_depth: usize,
    submitted: u64,
    rejected: u64,
    completed: u64,
    failed: u64,
    latencies: LatencyStats,
    /// Admission → first batch slice, per job (log-bucketed, mergeable).
    queue_wait: HistogramStats,
}

impl Inner {
    /// Called exactly once per job, at its terminal transition.
    fn note_terminal(&mut self, id: JobId) {
        self.active -= 1;
        self.terminal_order.push_back(id);
        while self.terminal_order.len() > MAX_TERMINAL_HISTORY {
            if let Some(old) = self.terminal_order.pop_front() {
                self.jobs.remove(&old);
            }
        }
    }
}

/// See module docs.
pub struct JobQueue {
    inner: Mutex<Inner>,
    cv: Condvar,
    limits: AdmissionLimits,
    rec: Arc<Recorder>,
}

impl JobQueue {
    pub fn new(limits: AdmissionLimits) -> JobQueue {
        // Standalone queues (tests, embedders) trace into a ring of their
        // own; the service passes its shared recorder via `new_traced`.
        Self::new_traced(limits, Arc::new(Recorder::new(0)))
    }

    pub fn new_traced(limits: AdmissionLimits, rec: Arc<Recorder>) -> JobQueue {
        JobQueue {
            inner: Mutex::new(Inner {
                next_id: 1,
                jobs: BTreeMap::new(),
                pending: VecDeque::new(),
                active: 0,
                terminal_order: VecDeque::new(),
                shutdown: false,
                peak_depth: 0,
                submitted: 0,
                rejected: 0,
                completed: 0,
                failed: 0,
                latencies: LatencyStats::new(4096),
                queue_wait: HistogramStats::new(),
            }),
            cv: Condvar::new(),
            limits,
            rec,
        }
    }

    /// Trace id of a live or retained job (0 when unknown/untraced).
    pub fn trace_of(&self, id: JobId) -> u64 {
        let g = self.inner.lock().unwrap();
        g.jobs
            .get(&id)
            .and_then(|j| j.spec.trace)
            .unwrap_or(0)
    }

    /// Admit a job or reject it with a config error.
    pub fn submit(&self, spec: JobSpec) -> Result<JobId> {
        let mut g = self.inner.lock().unwrap();
        if g.shutdown {
            g.rejected += 1;
            return Err(Error::config("service is shutting down"));
        }
        if spec.n_samples == 0 {
            g.rejected += 1;
            return Err(Error::config("job requests 0 samples"));
        }
        if spec.n_samples > self.limits.max_samples_per_job {
            g.rejected += 1;
            return Err(Error::config(format!(
                "job requests {} samples (limit {})",
                spec.n_samples, self.limits.max_samples_per_job
            )));
        }
        if g.active >= self.limits.max_queue {
            g.rejected += 1;
            // Typed as Busy: a well-formed request hitting a transient
            // capacity limit, which transports turn into backpressure
            // (net's `busy` frame, the inbox hold) rather than a failure.
            return Err(Error::busy(format!(
                "queue full ({} active jobs, limit {})",
                g.active, self.limits.max_queue
            )));
        }
        let id = g.next_id;
        g.next_id += 1;
        let trace = spec.trace.unwrap_or(0);
        g.jobs.insert(
            id,
            JobState {
                spec,
                status: JobStatus::Queued,
                assigned: 0,
                done: 0,
                sink: None,
                error: None,
                t_submit: Instant::now(),
                submitted_unix: std::time::SystemTime::now()
                    .duration_since(std::time::UNIX_EPOCH)
                    .map(|d| d.as_secs_f64())
                    .unwrap_or(0.0),
                latency_secs: None,
            },
        );
        g.pending.push_back(id);
        g.submitted += 1;
        g.active += 1;
        g.peak_depth = g.peak_depth.max(g.active);
        self.rec.instant(Layer::Queue, "admit", id, trace, g.active as u64);
        self.cv.notify_all();
        Ok(id)
    }

    /// Block until pending work exists, shutdown is requested, or `timeout`
    /// elapses. Returns whether pending work exists.
    pub fn wait_pending(&self, timeout: Duration) -> bool {
        let g = self.inner.lock().unwrap();
        let (g, _) = self
            .cv
            .wait_timeout_while(g, timeout, |g| g.pending.is_empty() && !g.shutdown)
            .unwrap();
        !g.pending.is_empty()
    }

    /// Spec of the oldest pending job (the batch anchor).
    pub fn front_pending(&self) -> Option<(JobId, JobSpec)> {
        let g = self.inner.lock().unwrap();
        g.pending
            .front()
            .map(|&id| (id, g.jobs[&id].spec.clone()))
    }

    /// Snapshot of all pending jobs, FIFO order. The dispatcher resolves
    /// batch compatibility against this *outside* the queue lock (store
    /// resolution does disk I/O, which must never happen under the lock).
    pub fn pending_snapshot(&self) -> Vec<(JobId, JobSpec)> {
        let g = self.inner.lock().unwrap();
        g.pending
            .iter()
            .map(|&id| (id, g.jobs[&id].spec.clone()))
            .collect()
    }

    /// Carve up to `max_rows` of samples off compatible pending jobs, in
    /// FIFO order. `compatible` decides membership (same store + execution
    /// mode — the batcher's key) and must be pure — it runs under the
    /// queue lock; sliced jobs move to `Running`, and jobs whose samples
    /// are fully assigned leave `pending`.
    pub fn take_for_batch(
        &self,
        max_rows: usize,
        compatible: impl Fn(JobId, &JobSpec) -> bool,
    ) -> Vec<Assignment> {
        let mut g = self.inner.lock().unwrap();
        // One explicit deref so `jobs` and `queue_wait` borrow as
        // disjoint fields inside the loop.
        let inner = &mut *g;
        let mut out = Vec::new();
        let mut taken = 0usize;
        let mut still_pending = VecDeque::with_capacity(inner.pending.len());
        let pending = std::mem::take(&mut inner.pending);
        for id in pending {
            let job = inner.jobs.get_mut(&id).expect("pending id has state");
            if taken < max_rows && compatible(id, &job.spec) {
                let remaining = job.spec.n_samples - job.assigned;
                let take = remaining.min((max_rows - taken) as u64);
                if take > 0 {
                    let first_slice = job.assigned == 0;
                    out.push(Assignment {
                        job: id,
                        sample0: job.spec.sample_base + job.assigned,
                        len: take as usize,
                    });
                    job.assigned += take;
                    job.status = JobStatus::Running;
                    taken += take as usize;
                    if first_slice {
                        // Queue wait ends at the job's first placement
                        // into a batch, not at completion.
                        let wait = job.t_submit.elapsed();
                        let trace = job.spec.trace.unwrap_or(0);
                        inner.queue_wait.record(wait.as_secs_f64());
                        self.rec.span(
                            Layer::Queue,
                            "queue_wait",
                            id,
                            trace,
                            wait.as_nanos() as u64,
                            0,
                        );
                    }
                }
                if job.assigned < job.spec.n_samples {
                    still_pending.push_back(id);
                }
            } else {
                still_pending.push_back(id);
            }
        }
        inner.pending = still_pending;
        out
    }

    /// Deliver one finished batch slice of a job. When the job's last
    /// sample lands it turns `Done` and its turnaround latency is recorded.
    pub fn complete_slice(&self, id: JobId, slice: &SampleSink, len: u64) {
        let mut g = self.inner.lock().unwrap();
        let Some(job) = g.jobs.get_mut(&id) else {
            return;
        };
        if job.status.is_terminal() {
            return; // late slice of an already-failed job
        }
        match &mut job.sink {
            Some(s) => s.merge(slice),
            None => job.sink = Some(slice.clone()),
        }
        job.done += len;
        if job.done >= job.spec.n_samples {
            job.status = JobStatus::Done;
            let secs = job.t_submit.elapsed().as_secs_f64();
            job.latency_secs = Some(secs);
            let trace = job.spec.trace.unwrap_or(0);
            let done = job.done;
            g.completed += 1;
            g.latencies.record(secs);
            g.note_terminal(id);
            self.rec.instant(Layer::Queue, "job_done", id, trace, done);
        }
        self.cv.notify_all();
    }

    /// Mark a job failed (admission passed but execution broke).
    pub fn fail_job(&self, id: JobId, error: &str) {
        let mut g = self.inner.lock().unwrap();
        let Some(job) = g.jobs.get_mut(&id) else {
            return;
        };
        if job.status.is_terminal() {
            return;
        }
        job.status = JobStatus::Failed;
        job.error = Some(error.to_string());
        let secs = job.t_submit.elapsed().as_secs_f64();
        job.latency_secs = Some(secs);
        let trace = job.spec.trace.unwrap_or(0);
        g.failed += 1;
        g.latencies.record(secs);
        g.note_terminal(id);
        g.pending.retain(|&p| p != id);
        self.rec.instant(Layer::Queue, "job_failed", id, trace, 0);
        self.cv.notify_all();
    }

    /// Release a terminal job's retained state eagerly (a transport that
    /// has persisted the result calls this; no-op for live jobs).
    pub fn forget(&self, id: JobId) -> bool {
        let mut g = self.inner.lock().unwrap();
        let terminal = g.jobs.get(&id).is_some_and(|j| j.status.is_terminal());
        if terminal {
            g.jobs.remove(&id);
        }
        terminal
    }

    /// Block until `id` reaches a terminal status or `timeout` elapses.
    pub fn wait_job(&self, id: JobId, timeout: Duration) -> Option<JobStatus> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().unwrap();
        loop {
            match g.jobs.get(&id) {
                None => return None,
                Some(j) if j.status.is_terminal() => return Some(j.status),
                Some(_) => {}
            }
            let now = Instant::now();
            if now >= deadline {
                return g.jobs.get(&id).map(|j| j.status);
            }
            let (back, _) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = back;
        }
    }

    fn view_of(id: JobId, j: &JobState) -> JobView {
        JobView {
            id,
            tag: j.spec.tag.clone(),
            status: j.status,
            n_samples: j.spec.n_samples,
            done: j.done,
            error: j.error.clone(),
            submitted_unix: j.submitted_unix,
            latency_secs: j.latency_secs,
            trace: j.spec.trace,
            workload: j.spec.workload,
        }
    }

    pub fn status(&self, id: JobId) -> Option<JobView> {
        let g = self.inner.lock().unwrap();
        g.jobs.get(&id).map(|j| Self::view_of(id, j))
    }

    /// All jobs, id order.
    pub fn snapshot(&self) -> Vec<JobView> {
        let g = self.inner.lock().unwrap();
        g.jobs.iter().map(|(&id, j)| Self::view_of(id, j)).collect()
    }

    /// Clone of a finished (or partial) job's sample statistics.
    pub fn job_sink(&self, id: JobId) -> Option<SampleSink> {
        let g = self.inner.lock().unwrap();
        g.jobs.get(&id).and_then(|j| j.sink.clone())
    }

    /// Full machine-readable result for a terminal job.
    pub fn result_json(&self, id: JobId) -> Option<Json> {
        let g = self.inner.lock().unwrap();
        let j = g.jobs.get(&id)?;
        let mut fields = vec![
            ("id", Json::Num(id as f64)),
            ("tag", Json::Str(j.spec.tag.clone())),
            ("workload", Json::Str(j.spec.workload.as_str().into())),
            ("status", Json::Str(j.status.as_str().into())),
            ("samples", Json::Num(j.spec.n_samples as f64)),
            ("done", Json::Num(j.done as f64)),
            (
                "latency_secs",
                j.latency_secs.map(Json::Num).unwrap_or(Json::Null),
            ),
            (
                "error",
                j.error.clone().map(Json::Str).unwrap_or(Json::Null),
            ),
        ];
        if let Some(sink) = &j.sink {
            let mean = sink.mean_photons();
            fields.push(("total_mean_photons", Json::Num(mean.iter().sum())));
            fields.push((
                "mean_photons",
                Json::Arr(mean.into_iter().map(Json::Num).collect()),
            ));
        }
        Some(Json::obj(fields))
    }

    /// No pending or running work.
    pub fn idle(&self) -> bool {
        let g = self.inner.lock().unwrap();
        g.pending.is_empty() && g.jobs.values().all(|j| j.status != JobStatus::Running)
    }

    pub fn shutdown(&self) {
        let mut g = self.inner.lock().unwrap();
        g.shutdown = true;
        self.cv.notify_all();
    }

    pub fn is_shutdown(&self) -> bool {
        self.inner.lock().unwrap().shutdown
    }

    /// At the admission-control capacity (new submits would be rejected).
    /// Durable transports use this for backpressure: hold submissions
    /// instead of converting a momentary full queue into hard rejections.
    pub fn is_full(&self) -> bool {
        self.inner.lock().unwrap().active >= self.limits.max_queue
    }

    /// Live (non-terminal) job count — the telemetry queue-depth gauge
    /// (`peak_depth` tracks this same quantity's high-water mark).
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().active
    }

    /// `(submitted, rejected, completed, failed)` lifetime counters,
    /// read without building a `Metrics` (the telemetry sampler calls
    /// this every interval).
    pub fn job_counters(&self) -> (u64, u64, u64, u64) {
        let g = self.inner.lock().unwrap();
        (g.submitted, g.rejected, g.completed, g.failed)
    }

    /// Copy of the queue-wait histogram. Fixed footprint — the clone
    /// is a stack copy, no heap traffic.
    pub fn queue_wait_stats(&self) -> HistogramStats {
        self.inner.lock().unwrap().queue_wait.clone()
    }

    /// Fold queue counters + the latency distribution into `m` / JSON.
    pub fn account(&self, m: &mut Metrics) {
        let g = self.inner.lock().unwrap();
        m.add(keys::JOBS_SUBMITTED, g.submitted);
        m.add(keys::JOBS_REJECTED, g.rejected);
        m.add(keys::JOBS_COMPLETED, g.completed);
        m.add(keys::JOBS_FAILED, g.failed);
        m.set_max(keys::QUEUE_PEAK, g.peak_depth as u64);
        if g.queue_wait.count > 0 {
            match m.hists.get_mut(keys::HIST_QUEUE_WAIT) {
                Some(h) => h.merge(&g.queue_wait),
                None => {
                    m.hists
                        .insert(keys::HIST_QUEUE_WAIT.to_string(), g.queue_wait.clone());
                }
            }
        }
    }

    pub fn latency_json(&self) -> Json {
        self.inner.lock().unwrap().latencies.to_json()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn limits() -> AdmissionLimits {
        AdmissionLimits {
            max_queue: 3,
            max_samples_per_job: 1000,
        }
    }

    fn spec(n: u64) -> JobSpec {
        JobSpec::new("/tmp/fake-store", n)
    }

    #[test]
    fn admission_limits_enforced() {
        let q = JobQueue::new(limits());
        assert!(q.submit(spec(0)).is_err());
        assert!(q.submit(spec(1001)).is_err());
        for _ in 0..3 {
            q.submit(spec(10)).unwrap();
        }
        let err = q.submit(spec(10)).unwrap_err().to_string();
        assert!(err.contains("queue full"), "{err}");
        let mut m = Metrics::new();
        q.account(&mut m);
        assert_eq!(m.get(keys::JOBS_SUBMITTED), 3);
        assert_eq!(m.get(keys::JOBS_REJECTED), 3);
        assert_eq!(m.get(keys::QUEUE_PEAK), 3);
    }

    #[test]
    fn fifo_slicing_across_jobs_and_batches() {
        let q = JobQueue::new(limits());
        let a = q.submit(spec(100)).unwrap();
        let mut sb = spec(50);
        sb.sample_base = 7000;
        let b = q.submit(sb).unwrap();
        // First batch: 120 rows → all of A, 20 of B.
        let asg = q.take_for_batch(120, |_, _| true);
        assert_eq!(
            asg,
            vec![
                Assignment { job: a, sample0: 0, len: 100 },
                Assignment { job: b, sample0: 7000, len: 20 },
            ]
        );
        // Second batch resumes B where the first stopped.
        let asg2 = q.take_for_batch(120, |_, _| true);
        assert_eq!(asg2, vec![Assignment { job: b, sample0: 7020, len: 30 }]);
        assert!(q.take_for_batch(120, |_, _| true).is_empty());
        assert_eq!(q.status(a).unwrap().status, JobStatus::Running);
    }

    #[test]
    fn incompatible_jobs_stay_pending() {
        let q = JobQueue::new(limits());
        let a = q.submit(spec(10)).unwrap();
        let mut other = spec(10);
        other.data = "/elsewhere".into();
        let b = q.submit(other).unwrap();
        let asg = q.take_for_batch(100, |_, s| s.data.to_str() == Some("/tmp/fake-store"));
        assert_eq!(asg.len(), 1);
        assert_eq!(asg[0].job, a);
        assert_eq!(q.status(b).unwrap().status, JobStatus::Queued);
        assert!(!q.idle()); // b pending
    }

    #[test]
    fn completion_merges_slices_and_records_latency() {
        let q = JobQueue::new(limits());
        let id = q.submit(spec(4)).unwrap();
        q.take_for_batch(2, |_, _| true);
        let mut s1 = SampleSink::new(2, 3, 1);
        s1.record(0, &[1, 2]);
        s1.record(1, &[0, 1]);
        q.complete_slice(id, &s1, 2);
        assert_eq!(q.status(id).unwrap().status, JobStatus::Running);
        q.take_for_batch(2, |_, _| true);
        q.complete_slice(id, &s1, 2);
        let v = q.status(id).unwrap();
        assert_eq!(v.status, JobStatus::Done);
        assert_eq!(v.done, 4);
        assert!(v.latency_secs.unwrap() >= 0.0);
        let sink = q.job_sink(id).unwrap();
        assert_eq!(sink.hist[0], vec![0, 2, 2]); // two merged slices
        assert!(q.idle());
        let r = q.result_json(id).unwrap();
        assert!(r.get("mean_photons").is_some());
        assert_eq!(q.wait_job(id, Duration::from_millis(1)), Some(JobStatus::Done));
    }

    #[test]
    fn failure_is_terminal_and_unblocks_waiters() {
        let q = JobQueue::new(limits());
        let id = q.submit(spec(10)).unwrap();
        q.fail_job(id, "store went away");
        let v = q.status(id).unwrap();
        assert_eq!(v.status, JobStatus::Failed);
        assert!(v.error.unwrap().contains("store went away"));
        assert!(q.idle());
        // Late slices of a failed job are dropped, not resurrected.
        let s = SampleSink::new(2, 3, 1);
        q.complete_slice(id, &s, 10);
        assert_eq!(q.status(id).unwrap().status, JobStatus::Failed);
        assert_eq!(q.wait_job(id, Duration::from_millis(1)), Some(JobStatus::Failed));
        assert_eq!(q.wait_job(999, Duration::from_millis(1)), None);
    }

    #[test]
    fn terminal_history_bounded_and_forgettable() {
        let q = JobQueue::new(AdmissionLimits {
            max_queue: 8,
            max_samples_per_job: 10,
        });
        let id = q.submit(spec(1)).unwrap();
        q.fail_job(id, "x");
        assert!(q.forget(id), "terminal job releasable");
        assert!(q.status(id).is_none());
        assert!(!q.forget(id), "double forget is a no-op");
        let live = q.submit(spec(1)).unwrap();
        assert!(!q.forget(live), "live jobs are not forgettable");
        assert!(q.status(live).is_some());
        q.fail_job(live, "x");
        // Auto-eviction keeps the retained history bounded. Terminal jobs
        // don't count against max_queue, so this loop never rejects.
        for _ in 0..(MAX_TERMINAL_HISTORY + 8) {
            let i = q.submit(spec(1)).unwrap();
            q.fail_job(i, "x");
        }
        assert!(q.snapshot().len() <= MAX_TERMINAL_HISTORY + 1);
    }

    #[test]
    fn shutdown_rejects_new_work_and_wakes_dispatcher() {
        let q = JobQueue::new(limits());
        q.shutdown();
        assert!(q.submit(spec(1)).is_err());
        assert!(!q.wait_pending(Duration::from_millis(1)));
        assert!(q.is_shutdown());
    }
}
