//! LRU cache of opened [`GammaStore`]s, keyed by manifest hash.
//!
//! Opening a store parses its manifest; the expensive part the cache
//! really amortizes is downstream: every job against a cached store shares
//! the same `Arc<GammaStore>` and the service's one shared [`DiskModel`],
//! so concurrent jobs in one macro batch pay each site's I/O once — the
//! tensor-residency amortization that motivates the resident service
//! (Adamski & Brown's block-cyclic distribution makes the same bet).
//!
//! Keying by *content* (manifest hash) rather than path means two paths to
//! the same store share an entry, while a regenerated store under the same
//! path misses and re-opens.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::io::{manifest_hash_at, DiskModel, GammaStore};
use crate::metrics::{keys, Metrics};
use crate::sampler::{PrepKey, PreparedStore};
use crate::service::JobSpec;
use crate::util::error::{Error, Result};

struct Entry {
    hash: u64,
    store: Arc<GammaStore>,
    last_use: u64,
}

struct CacheInner {
    entries: Vec<Entry>,
    tick: u64,
}

struct PrepEntry {
    hash: u64,
    key: PrepKey,
    prep: Arc<PreparedStore>,
    last_use: u64,
}

#[derive(Default)]
struct PrepInner {
    entries: Vec<PrepEntry>,
    tick: u64,
}

/// See module docs.
pub struct StoreCache {
    inner: Mutex<CacheInner>,
    capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    /// Content-key registry: manifest hash → install directory of a store
    /// this process can re-open (pushed stores register here). Unlike the
    /// LRU entries, registrations are never evicted — they are paths, not
    /// open stores — so a key stays resolvable after its entry ages out.
    registry: Mutex<BTreeMap<u64, PathBuf>>,
    /// Resident prepared-Γ chains, keyed by `(manifest hash, PrepKey)` —
    /// the precision-conversion amortization on top of the store LRU.
    /// Bounded by [`Self::prep_capacity`], NOT the store capacity: one
    /// store can legitimately hold several precision variants at once,
    /// and sharing the store bound would make distinct `(store,
    /// precision)` pairs evict each other every batch (silently
    /// re-converting whole stores).
    prepared: Mutex<PrepInner>,
    /// Entry bound of `prepared`: store capacity × the number of
    /// plausible precision variants per store.
    prep_capacity: usize,
    /// Shared bandwidth model handed to every prefetcher the service runs.
    pub disk: Arc<DiskModel>,
}

impl StoreCache {
    pub fn new(capacity: usize, disk: Arc<DiskModel>) -> StoreCache {
        StoreCache {
            inner: Mutex::new(CacheInner {
                entries: Vec::new(),
                tick: 0,
            }),
            capacity: capacity.max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            registry: Mutex::new(BTreeMap::new()),
            prepared: Mutex::new(PrepInner::default()),
            // The full PrepKey space per store: four compute precisions ×
            // the Γ-f16 toggle × the layout toggle — so no mix of
            // concurrent variants of one store can thrash a live chain.
            prep_capacity: capacity.max(1) * 16,
            disk,
        }
    }

    /// Get-or-create the resident prepared chain for `(hash, key)`. The
    /// chain itself fills lazily (sites are converted on first touch, up
    /// to `budget_bytes`); entries are LRU-bounded by `prep_capacity`.
    /// On a hit, `num_sites`/`budget_bytes` are IGNORED — a chain keeps
    /// the parameters it was created with (all service workers share one
    /// `ServiceConfig`, so they cannot disagree within a process).
    pub fn prepared(
        &self,
        hash: u64,
        num_sites: usize,
        key: PrepKey,
        budget_bytes: u64,
    ) -> Arc<PreparedStore> {
        let mut g = self.prepared.lock().unwrap();
        g.tick += 1;
        let tick = g.tick;
        if let Some(e) = g
            .entries
            .iter_mut()
            .find(|e| e.hash == hash && e.key == key)
        {
            e.last_use = tick;
            return e.prep.clone();
        }
        let prep = Arc::new(PreparedStore::new(num_sites, key, budget_bytes));
        if g.entries.len() >= self.prep_capacity {
            let lru = g
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.last_use)
                .map(|(i, _)| i)
                .expect("prep cache non-empty at capacity");
            g.entries.swap_remove(lru);
        }
        g.entries.push(PrepEntry {
            hash,
            key,
            prep: prep.clone(),
            last_use: tick,
        });
        prep
    }

    /// Total bytes of resident prepared tensors across cached chains.
    pub fn prepared_bytes(&self) -> u64 {
        self.prepared
            .lock()
            .unwrap()
            .entries
            .iter()
            .map(|e| e.prep.resident_bytes())
            .sum()
    }

    /// Open-or-reuse the store at `dir`. Returns the shared handle and
    /// whether it was a cache hit. The lock is held across a miss's open,
    /// deliberately serializing concurrent first-opens of the same store.
    pub fn get(&self, dir: &Path) -> Result<(Arc<GammaStore>, bool)> {
        let hash = manifest_hash_at(dir)?;
        let mut g = self.inner.lock().unwrap();
        g.tick += 1;
        let tick = g.tick;
        if let Some(e) = g.entries.iter_mut().find(|e| e.hash == hash) {
            e.last_use = tick;
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok((e.store.clone(), true));
        }
        let store = Arc::new(GammaStore::open(dir)?);
        Self::push_entry(&mut g, self.capacity, hash, store.clone(), tick);
        self.misses.fetch_add(1, Ordering::Relaxed);
        Ok((store, false))
    }

    fn push_entry(
        g: &mut CacheInner,
        capacity: usize,
        hash: u64,
        store: Arc<GammaStore>,
        tick: u64,
    ) {
        if g.entries.len() >= capacity {
            let lru = g
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.last_use)
                .map(|(i, _)| i)
                .expect("cache non-empty at capacity");
            g.entries.swap_remove(lru);
        }
        g.entries.push(Entry {
            hash,
            store,
            last_use: tick,
        });
    }

    /// Resolve a job's store: by content key when the spec carries one
    /// (pushed stores), else by path. The single entry point the
    /// dispatcher uses, so both spellings share the LRU and counters.
    pub fn resolve(&self, spec: &JobSpec) -> Result<(Arc<GammaStore>, bool)> {
        match spec.key {
            Some(k) => self.get_by_key(k),
            None => self.get(&spec.data),
        }
    }

    /// Open-or-reuse a store by content key. Hits the LRU first; on a
    /// miss, re-opens from the registered install directory. Unregistered
    /// keys are a terminal error — there is no path to fall back to.
    pub fn get_by_key(&self, hash: u64) -> Result<(Arc<GammaStore>, bool)> {
        let mut g = self.inner.lock().unwrap();
        g.tick += 1;
        let tick = g.tick;
        if let Some(e) = g.entries.iter_mut().find(|e| e.hash == hash) {
            e.last_use = tick;
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok((e.store.clone(), true));
        }
        let dir = self
            .registry
            .lock()
            .unwrap()
            .get(&hash)
            .cloned()
            .ok_or_else(|| {
                Error::format(format!(
                    "unknown store key {hash:016x} (push the store to this server first)"
                ))
            })?;
        let store = match GammaStore::open(&dir) {
            Ok(s) => Arc::new(s),
            Err(e) => {
                // The install directory is gone or corrupt: drop the
                // registration so a re-push can repair the key instead of
                // being dedup'd against a ghost forever.
                self.unregister(hash);
                return Err(e);
            }
        };
        Self::push_entry(&mut g, self.capacity, hash, store.clone(), tick);
        self.misses.fetch_add(1, Ordering::Relaxed);
        Ok((store, false))
    }

    /// Record that the store identified by `hash` lives at `dir` (without
    /// opening or caching it) — restart recovery scans call this.
    pub fn register(&self, hash: u64, dir: PathBuf) {
        self.registry.lock().unwrap().insert(hash, dir);
    }

    /// Drop a registration (its install directory disappeared).
    pub fn unregister(&self, hash: u64) {
        self.registry.lock().unwrap().remove(&hash);
    }

    /// True when `hash` is resolvable (cached or registered) — the push
    /// path's dedup check. A registration whose install directory no
    /// longer hashes to `hash` (deleted or replaced out-of-band) is
    /// dropped and reported unknown, so a re-push can repair it.
    pub fn knows(&self, hash: u64) -> bool {
        if self.inner.lock().unwrap().entries.iter().any(|e| e.hash == hash) {
            return true;
        }
        let Some(dir) = self.registry.lock().unwrap().get(&hash).cloned() else {
            return false;
        };
        // Verify outside the lock — this reads the manifest from disk.
        if manifest_hash_at(&dir).map(|h| h == hash).unwrap_or(false) {
            return true;
        }
        self.unregister(hash);
        false
    }

    /// Register + warm-insert a freshly installed store (the push path's
    /// final step). Counts neither hit nor miss: installation is not the
    /// job-level reuse those KPIs measure.
    pub fn install(&self, hash: u64, store: Arc<GammaStore>) {
        self.register(hash, store.dir.clone());
        let mut g = self.inner.lock().unwrap();
        g.tick += 1;
        let tick = g.tick;
        if let Some(e) = g.entries.iter_mut().find(|e| e.hash == hash) {
            e.last_use = tick;
            return;
        }
        Self::push_entry(&mut g, self.capacity, hash, store, tick);
    }

    /// Shared handle by identity, bumping LRU recency but not the hit/miss
    /// counters — for dispatcher-internal re-anchoring, which is not the
    /// job-level reuse those counters measure.
    pub fn peek(&self, hash: u64) -> Option<Arc<GammaStore>> {
        let mut g = self.inner.lock().unwrap();
        g.tick += 1;
        let tick = g.tick;
        g.entries.iter_mut().find(|e| e.hash == hash).map(|e| {
            e.last_use = tick;
            e.store.clone()
        })
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Fold hit/miss counters into a metrics snapshot.
    pub fn account(&self, m: &mut Metrics) {
        m.add(keys::CACHE_HITS, self.hits());
        m.add(keys::CACHE_MISSES, self.misses());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::{StoreCodec, StorePrecision};
    use crate::mps::gbs::GbsSpec;
    use std::path::PathBuf;

    fn make_store(tag: &str, seed: u64) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "fastmps-cache-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let spec = GbsSpec {
            name: format!("cache-{tag}"),
            m: 4,
            d: 3,
            chi_cap: 4,
            asp: 3.0,
            decay_k: 0.0,
            displacement_sigma: 0.0,
            branch_skew: 0.0,
            seed,
            dynamic_chi: false,
            step_ratio_override: None,
        };
        GammaStore::create(&dir, &spec, StorePrecision::F32, StoreCodec::Raw).unwrap();
        dir
    }

    #[test]
    fn second_open_is_a_hit_sharing_one_arc() {
        let dir = make_store("hit", 1);
        let c = StoreCache::new(2, DiskModel::unlimited());
        let (a, hit_a) = c.get(&dir).unwrap();
        let (b, hit_b) = c.get(&dir).unwrap();
        assert!(!hit_a);
        assert!(hit_b);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!((c.hits(), c.misses()), (1, 1));
        let mut m = Metrics::new();
        c.account(&mut m);
        assert_eq!(m.get(keys::CACHE_HITS), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let d1 = make_store("lru1", 1);
        let d2 = make_store("lru2", 2);
        let d3 = make_store("lru3", 3);
        let c = StoreCache::new(2, DiskModel::unlimited());
        c.get(&d1).unwrap();
        c.get(&d2).unwrap();
        c.get(&d1).unwrap(); // d1 now most recent
        c.get(&d3).unwrap(); // evicts d2
        assert_eq!(c.len(), 2);
        let (_, hit1) = c.get(&d1).unwrap();
        assert!(hit1, "d1 survived eviction");
        let (_, hit2) = c.get(&d2).unwrap();
        assert!(!hit2, "d2 was evicted");
        for d in [d1, d2, d3] {
            std::fs::remove_dir_all(&d).unwrap();
        }
    }

    #[test]
    fn regenerated_store_misses() {
        let dir = make_store("regen", 1);
        let c = StoreCache::new(2, DiskModel::unlimited());
        let (old, _) = c.get(&dir).unwrap();
        // Regenerate the store in place with a different seed → new
        // manifest → new identity.
        std::fs::remove_dir_all(&dir).unwrap();
        let spec = GbsSpec {
            seed: 99,
            ..old.spec.as_gbs().unwrap().clone()
        };
        GammaStore::create(&dir, &spec, StorePrecision::F32, StoreCodec::Raw).unwrap();
        let (new, hit) = c.get(&dir).unwrap();
        assert!(!hit);
        assert!(!Arc::ptr_eq(&old, &new));
        assert_eq!(new.spec.seed(), 99);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_store_is_an_error_not_a_panic() {
        let c = StoreCache::new(2, DiskModel::unlimited());
        assert!(c.get(Path::new("/nonexistent/fastmps-store")).is_err());
        assert_eq!(c.misses(), 0);
    }

    #[test]
    fn content_key_resolution_survives_eviction() {
        let d1 = make_store("key1", 1);
        let d2 = make_store("key2", 2);
        let c = StoreCache::new(1, DiskModel::unlimited());
        let hash = crate::io::manifest_hash_at(&d1).unwrap();

        // Unregistered key is a terminal error, not a panic.
        let e = c.get_by_key(hash).unwrap_err().to_string();
        assert!(e.contains("unknown store key"), "{e}");
        assert!(!c.knows(hash));

        // Install: resolvable by key, no hit/miss accounting.
        let store = Arc::new(GammaStore::open(&d1).unwrap());
        c.install(hash, store.clone());
        assert!(c.knows(hash));
        assert_eq!((c.hits(), c.misses()), (0, 0));
        let (got, hit) = c.get_by_key(hash).unwrap();
        assert!(hit);
        assert!(Arc::ptr_eq(&got, &store));

        // Evict via the 1-entry LRU; the registry still resolves the key
        // by re-opening the install dir.
        c.get(&d2).unwrap();
        let (reopened, hit) = c.get_by_key(hash).unwrap();
        assert!(!hit, "entry was evicted; registry re-open");
        assert_eq!(reopened.spec.seed(), 1);

        // resolve() routes key specs through get_by_key.
        let spec = JobSpec::by_key(hash, 10);
        let (via_spec, _) = c.resolve(&spec).unwrap();
        assert_eq!(via_spec.spec.seed(), 1);

        for d in [d1, d2] {
            std::fs::remove_dir_all(&d).unwrap();
        }
    }

    #[test]
    fn prepared_chains_shared_per_hash_and_key() {
        use crate::config::ComputePrecision;
        let dir = make_store("prep", 8);
        let c = StoreCache::new(1, DiskModel::unlimited());
        let (store, _) = c.get(&dir).unwrap();
        let hash = store.manifest_hash().unwrap();
        let key_for = |compute, gamma_f16, planar| PrepKey {
            compute,
            gamma_f16,
            planar,
        };
        let k32 = key_for(ComputePrecision::F32, false, false);
        let a = c.prepared(hash, store.num_sites(), k32, u64::MAX);
        let b = c.prepared(hash, store.num_sites(), k32, u64::MAX);
        assert!(Arc::ptr_eq(&a, &b), "same (hash, key) shares a chain");
        let k64 = key_for(ComputePrecision::F64, false, false);
        let d = c.prepared(hash, store.num_sites(), k64, u64::MAX);
        assert!(!Arc::ptr_eq(&a, &d), "different precision gets its own chain");
        assert_eq!(c.prepared_bytes(), 0, "chains fill lazily");
        let site = store.load_site(0).unwrap();
        let _ = a.site(0, &site);
        assert!(c.prepared_bytes() > 0);
        // The prep LRU holds 16× the store capacity — the full PrepKey
        // space (4 precisions × the Γ-f16 toggle × the layout toggle) —
        // so EVERY variant of one store coexists without thrash; only a
        // competing store's chain evicts the least-recently-used one.
        let k32t = key_for(ComputePrecision::F32, true, false);
        let oldest = c.prepared(hash, store.num_sites(), k32t, u64::MAX);
        for compute in [
            ComputePrecision::F32,
            ComputePrecision::F64,
            ComputePrecision::Tf32,
            ComputePrecision::F16,
        ] {
            for gamma_f16 in [false, true] {
                for planar in [false, true] {
                    if key_for(compute, gamma_f16, planar) != k32t {
                        c.prepared(
                            hash,
                            store.num_sites(),
                            key_for(compute, gamma_f16, planar),
                            u64::MAX,
                        );
                    }
                }
            }
        }
        let a_again = c.prepared(hash, store.num_sites(), k32, u64::MAX);
        assert!(Arc::ptr_eq(&a, &a_again), "all 16 variants coexist");
        let dir2 = make_store("prep2", 2);
        let hash2 = crate::io::manifest_hash_at(&dir2).unwrap();
        c.prepared(hash2, 8, k32, u64::MAX);
        let rebuilt = c.prepared(hash, store.num_sites(), k32t, u64::MAX);
        assert!(!Arc::ptr_eq(&oldest, &rebuilt), "LRU chain evicted past capacity");
        std::fs::remove_dir_all(&dir).unwrap();
        std::fs::remove_dir_all(&dir2).unwrap();
    }

    #[test]
    fn stale_registration_is_dropped_not_dedup_forever() {
        let dir = make_store("stale", 5);
        let c = StoreCache::new(1, DiskModel::unlimited());
        let hash = crate::io::manifest_hash_at(&dir).unwrap();
        c.register(hash, dir.clone());
        assert!(c.knows(hash));

        // The install directory vanishes out-of-band (operator cleanup).
        std::fs::remove_dir_all(&dir).unwrap();

        // knows() verifies on disk, drops the ghost, and reports unknown
        // — so a re-push is NOT dedup'd against nothing.
        assert!(!c.knows(hash), "ghost registration must not answer dedup");
        let e = c.get_by_key(hash).unwrap_err().to_string();
        assert!(e.contains("unknown store key"), "{e}");
    }
}
