//! Small self-contained utilities that substitute for crates unavailable in
//! the offline build environment (serde, half, proptest, env_logger).

pub mod alloc;
pub mod backoff;
pub mod bench;
pub mod compress;
pub mod error;
pub mod f16;
pub mod json;
pub mod logging;
pub mod num;
pub mod prop;

/// FNV-1a over a byte string — the crate's one content-hash primitive
/// (store-manifest identity, router store keys, rendezvous weights all
/// build on it; keep a single implementation so they stay in agreement).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = Fnv1a::new();
    h.update(bytes);
    h.digest()
}

/// Streaming form of [`fnv1a`] for data that arrives in pieces (the
/// chunked store-push path hashes gigabytes without buffering them).
/// `Fnv1a::new().update(b).digest() == fnv1a(b)` by construction.
#[derive(Debug, Clone)]
pub struct Fnv1a(u64);

impl Default for Fnv1a {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv1a {
    pub fn new() -> Fnv1a {
        Fnv1a(0xcbf2_9ce4_8422_2325)
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for b in bytes {
            self.0 ^= u64::from(*b);
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    pub fn digest(&self) -> u64 {
        self.0
    }
}

/// Round a f64 up to the next multiple of `m` (m > 0).
pub fn round_up(x: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    x.div_ceil(m) * m
}

/// Integer ceiling division.
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Next power of two ≥ x (x ≥ 1).
pub fn next_pow2(x: usize) -> usize {
    x.next_power_of_two()
}

/// Human-readable byte count.
pub fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Human-readable duration given seconds.
pub fn human_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2} s")
    } else if s < 7200.0 {
        format!("{:.1} min", s / 60.0)
    } else {
        format!("{:.2} h", s / 3600.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_matches_reference_vectors() {
        // Published FNV-1a 64-bit test vectors.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_ne!(fnv1a(b"ab"), fnv1a(b"ba"));
    }

    #[test]
    fn fnv1a_streaming_matches_one_shot_at_any_split() {
        let data = b"chunked-store-push running checksum";
        for split in 0..=data.len() {
            let mut h = Fnv1a::new();
            h.update(&data[..split]);
            h.update(&data[split..]);
            assert_eq!(h.digest(), fnv1a(data), "split at {split}");
        }
        assert_eq!(Fnv1a::new().digest(), fnv1a(b""));
    }

    #[test]
    fn round_up_works() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn ceil_div_works() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn human_secs_units() {
        assert!(human_secs(2e-9).ends_with("ns"));
        assert!(human_secs(2e-5).ends_with("µs"));
        assert!(human_secs(0.5).ends_with("ms"));
        assert!(human_secs(30.0).ends_with(" s"));
        assert!(human_secs(300.0).ends_with("min"));
        assert!(human_secs(10_000.0).ends_with(" h"));
    }
}
