//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Each `rust/benches/*.rs` binary (`harness = false`) regenerates one
//! table/figure of the paper: it prints a header, aligned data rows, and a
//! `paper:` reference line so EXPERIMENTS.md diffs are one `cargo bench`
//! away. Timing helper: warmup + `reps` timed runs → (mean, stddev).

use std::time::Instant;

/// Run `f` `reps` times after `warmup` runs; returns (mean_secs, std_secs).
pub fn time<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> (f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut xs = Vec::with_capacity(reps);
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        f();
        xs.push(t0.elapsed().as_secs_f64());
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
    (mean, var.sqrt())
}

/// Print a bench header (figure/table id + context).
pub fn header(id: &str, what: &str) {
    println!("\n=== {id}: {what} ===");
}

/// Print one aligned row of `key=value` cells.
pub fn row(cells: &[(&str, String)]) {
    let line: Vec<String> = cells.iter().map(|(k, v)| format!("{k}={v}")).collect();
    println!("  {}", line.join("  "));
}

/// Print the paper's reference values for comparison.
pub fn paper(note: &str) {
    println!("  paper: {note}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_returns_positive_mean() {
        let (mean, std) = time(1, 3, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(mean > 0.0);
        assert!(std >= 0.0);
    }
}
