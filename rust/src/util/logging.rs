//! Tiny leveled logger (env_logger is unavailable offline).
//!
//! Level is taken from `FASTMPS_LOG` (error|warn|info|debug|trace), default
//! `info`. Output goes to stderr so stdout stays machine-parseable for the
//! bench harnesses.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255);

fn start_instant() -> Instant {
    static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Current log level (initialized from `FASTMPS_LOG` on first use).
pub fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw != 255 {
        return unsafe { std::mem::transmute::<u8, Level>(raw) };
    }
    let lv = match std::env::var("FASTMPS_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    };
    LEVEL.store(lv as u8, Ordering::Relaxed);
    let _ = start_instant();
    lv
}

/// Override the level programmatically (used by `--verbose`/`--quiet`).
pub fn set_level(lv: Level) {
    LEVEL.store(lv as u8, Ordering::Relaxed);
}

/// Core log call — prefer the `log_*!` macros.
pub fn log(lv: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if lv > level() {
        return;
    }
    let t = start_instant().elapsed().as_secs_f64();
    let tag = match lv {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{t:9.3}s {tag} {module}] {msg}");
}

#[macro_export]
macro_rules! log_error {
    ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($a)*)) };
}
#[macro_export]
macro_rules! log_warn {
    ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($a)*)) };
}
#[macro_export]
macro_rules! log_info {
    ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($a)*)) };
}
#[macro_export]
macro_rules! log_debug {
    ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($a)*)) };
}
#[macro_export]
macro_rules! log_trace {
    ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Trace, module_path!(), format_args!($($a)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Info < Level::Trace);
    }

    #[test]
    fn set_level_and_log() {
        set_level(Level::Debug);
        assert_eq!(level(), Level::Debug);
        log_debug!("debug message {}", 42); // visible
        set_level(Level::Error);
        log_info!("should be suppressed");
        set_level(Level::Info);
    }
}
