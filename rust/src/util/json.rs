//! Minimal JSON parser + emitter (serde is unavailable offline).
//!
//! Used for the artifact manifest handshake with `python/compile/aot.py`,
//! run configuration files, and machine-readable bench/metrics output.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::error::{Error, Result};

/// A JSON value. Object keys are kept sorted (BTreeMap) so emission is
/// deterministic — important for artifact-manifest fingerprinting.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser {
            b: src.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with a path description — for manifest parsing.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::format(format!("missing key '{key}'")))
    }

    /// Serialize compactly.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else if n.is_finite() {
                    let _ = write!(out, "{n}");
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json {
            pos: self.i,
            msg: msg.to_string(),
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected character '{}'", c as char))),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            out.insert(k, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(s);
                }
                b'\\' => {
                    self.i += 1;
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs are rare in our inputs; map
                            // lone surrogates to U+FFFD.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(b'0'..=b'9') | Some(b'.') | Some(b'e') | Some(b'E') | Some(b'+') | Some(b'-')
        ) {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"x": true, "y": null}, "s": "hi\n\"q\""}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().get("x").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("s").unwrap().as_str(), Some("hi\n\"q\""));
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
        assert_eq!(Json::parse("-1.5").unwrap().as_f64(), Some(-1.5));
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(Json::Num(3.0).dump(), "3");
        assert_eq!(Json::Num(3.25).dump(), "3.25");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn pretty_is_parseable() {
        let v = Json::obj(vec![
            ("xs", Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)])),
            ("name", Json::Str("Γ".into())),
        ]);
        let p = v.pretty();
        assert!(p.contains('\n'));
        assert_eq!(Json::parse(&p).unwrap(), v);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }
}
