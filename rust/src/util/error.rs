//! Crate-wide error type.

use std::fmt;

/// Result alias used across the crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// FastMPS error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Shape/dimension mismatch in a tensor operation.
    #[error("shape error: {0}")]
    Shape(String),

    /// Invalid configuration or CLI input.
    #[error("config error: {0}")]
    Config(String),

    /// File-format violation in the Γ store or manifest.
    #[error("format error: {0}")]
    Format(String),

    /// A required AOT artifact is missing or incompatible.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// Error raised inside the simulated communication fabric.
    #[error("fabric error: {0}")]
    Fabric(String),

    /// Numerical failure (NaN/Inf/underflow collapse) detected at runtime.
    #[error("numeric error: {0}")]
    Numeric(String),

    /// I/O error with context.
    #[error("io error ({ctx}): {source}")]
    Io {
        ctx: String,
        #[source]
        source: std::io::Error,
    },

    /// JSON parse error.
    #[error("json error at byte {pos}: {msg}")]
    Json { pos: usize, msg: String },

    /// Error bubbled up from the XLA/PJRT runtime.
    #[error("xla error: {0}")]
    Xla(String),

    /// Anything else.
    #[error("{0}")]
    Other(String),
}

impl Error {
    /// Attach a path/context string to an `std::io::Error`.
    pub fn io(ctx: impl fmt::Display, source: std::io::Error) -> Self {
        Error::Io {
            ctx: ctx.to_string(),
            source,
        }
    }

    pub fn shape(msg: impl fmt::Display) -> Self {
        Error::Shape(msg.to_string())
    }

    pub fn config(msg: impl fmt::Display) -> Self {
        Error::Config(msg.to_string())
    }

    pub fn format(msg: impl fmt::Display) -> Self {
        Error::Format(msg.to_string())
    }

    pub fn artifact(msg: impl fmt::Display) -> Self {
        Error::Artifact(msg.to_string())
    }

    pub fn numeric(msg: impl fmt::Display) -> Self {
        Error::Numeric(msg.to_string())
    }

    pub fn other(msg: impl fmt::Display) -> Self {
        Error::Other(msg.to_string())
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io {
            ctx: "<unknown>".into(),
            source: e,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_variants() {
        assert!(Error::shape("bad").to_string().contains("shape"));
        assert!(Error::config("bad").to_string().contains("config"));
        let io = Error::io("/tmp/x", std::io::Error::other("boom"));
        assert!(io.to_string().contains("/tmp/x"));
    }
}
