//! Crate-wide error type.

use std::fmt;

/// Result alias used across the crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// FastMPS error type. `Display`/`Error` are hand-written — thiserror is
/// unavailable in the offline build environment.
#[derive(Debug)]
pub enum Error {
    /// Shape/dimension mismatch in a tensor operation.
    Shape(String),

    /// Invalid configuration or CLI input.
    Config(String),

    /// File-format violation in the Γ store or manifest.
    Format(String),

    /// A required AOT artifact is missing or incompatible.
    Artifact(String),

    /// Error raised inside the simulated communication fabric.
    Fabric(String),

    /// Numerical failure (NaN/Inf/underflow collapse) detected at runtime.
    Numeric(String),

    /// I/O error with context.
    Io {
        ctx: String,
        source: std::io::Error,
    },

    /// JSON parse error.
    Json { pos: usize, msg: String },

    /// Error bubbled up from the XLA/PJRT runtime.
    Xla(String),

    /// The service is at capacity *right now*; the request was well-formed
    /// and can be retried after backing off. Transports map this to their
    /// typed busy rejection (`net`'s `busy` frame, `api`'s inbox hold).
    Busy(String),

    /// Anything else.
    Other(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Format(m) => write!(f, "format error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Fabric(m) => write!(f, "fabric error: {m}"),
            Error::Numeric(m) => write!(f, "numeric error: {m}"),
            Error::Io { ctx, source } => write!(f, "io error ({ctx}): {source}"),
            Error::Json { pos, msg } => write!(f, "json error at byte {pos}: {msg}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Busy(m) => write!(f, "service busy: {m}"),
            Error::Other(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl Error {
    /// Attach a path/context string to an `std::io::Error`.
    pub fn io(ctx: impl fmt::Display, source: std::io::Error) -> Self {
        Error::Io {
            ctx: ctx.to_string(),
            source,
        }
    }

    pub fn shape(msg: impl fmt::Display) -> Self {
        Error::Shape(msg.to_string())
    }

    pub fn config(msg: impl fmt::Display) -> Self {
        Error::Config(msg.to_string())
    }

    pub fn format(msg: impl fmt::Display) -> Self {
        Error::Format(msg.to_string())
    }

    pub fn artifact(msg: impl fmt::Display) -> Self {
        Error::Artifact(msg.to_string())
    }

    pub fn numeric(msg: impl fmt::Display) -> Self {
        Error::Numeric(msg.to_string())
    }

    pub fn busy(msg: impl fmt::Display) -> Self {
        Error::Busy(msg.to_string())
    }

    /// A capacity condition worth retrying (vs a terminal rejection).
    pub fn is_busy(&self) -> bool {
        matches!(self, Error::Busy(_))
    }

    pub fn other(msg: impl fmt::Display) -> Self {
        Error::Other(msg.to_string())
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io {
            ctx: "<unknown>".into(),
            source: e,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_variants() {
        assert!(Error::shape("bad").to_string().contains("shape"));
        assert!(Error::config("bad").to_string().contains("config"));
        let io = Error::io("/tmp/x", std::io::Error::other("boom"));
        assert!(io.to_string().contains("/tmp/x"));
        let busy = Error::busy("queue full (3 active)");
        assert!(busy.is_busy());
        assert!(!Error::config("x").is_busy());
        assert!(busy.to_string().contains("queue full"));
    }
}
