//! IEEE 754 binary16 (half precision) conversion and TF32 emulation.
//!
//! The paper stores MPS tensors `Γ` and the streamed left environment in
//! FP16 (halving I/O, memcpy and broadcast volume) and computes in TF32 on
//! tensor cores. The offline build has no `half` crate, so conversions are
//! implemented directly on the bit patterns; `round_tf32` emulates the
//! 10-bit-mantissa truncation the A100 applies to tensor-core inputs.

/// Convert an `f32` to the nearest binary16 bit pattern (round-to-nearest-even,
/// with overflow → ±inf and subnormal handling).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x7f_ffff;

    if exp == 0xff {
        // Inf / NaN
        return if man == 0 {
            sign | 0x7c00
        } else {
            sign | 0x7e00 // quiet NaN
        };
    }

    // Re-bias exponent: f32 bias 127, f16 bias 15.
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7c00; // overflow → inf
    }
    if unbiased >= -14 {
        // Normal f16.
        let e16 = (unbiased + 15) as u32;
        // 23 → 10 bits mantissa; round to nearest even on the dropped 13 bits.
        let mut m16 = man >> 13;
        let rem = man & 0x1fff;
        if rem > 0x1000 || (rem == 0x1000 && (m16 & 1) == 1) {
            m16 += 1;
        }
        // Mantissa carry can roll into the exponent (still fine: 0x3ff+1
        // propagates, possibly to inf).
        let out = (e16 << 10) + m16;
        return sign | out as u16;
    }
    if unbiased >= -24 {
        // Subnormal f16: implicit leading 1 becomes explicit.
        let full = man | 0x80_0000;
        let shift = (-14 - unbiased) + 13;
        let mut m16 = full >> shift;
        let rem_mask = (1u32 << shift) - 1;
        let rem = full & rem_mask;
        let half = 1u32 << (shift - 1);
        if rem > half || (rem == half && (m16 & 1) == 1) {
            m16 += 1;
        }
        return sign | m16 as u16;
    }
    sign // underflow → signed zero
}

/// Convert a binary16 bit pattern to `f32` (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x3ff) as u32;

    let bits = if exp == 0 {
        if man == 0 {
            sign // ±0
        } else {
            // Subnormal: value = man × 2⁻²⁴. Normalize: if the MSB of the
            // 10-bit field is at position p (from LSB), the f32 exponent is
            // 127 + p − 24 and the mantissa is man shifted so the MSB lands
            // on the implicit bit.
            let lead = man.leading_zeros() - 21; // zeros within the 10-bit field + 1
            let m = (man << lead) & 0x3ff;
            let e = 113 - lead;
            sign | (e << 23) | (m << 13)
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (man << 13) // inf / NaN
    } else {
        sign | ((exp + 127 - 15) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// Round-trip an `f32` through binary16 (the paper's FP16 storage path).
pub fn round_f16(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// Emulate NVIDIA TF32: keep the f32 exponent (8 bits) but truncate the
/// mantissa to 10 bits with round-to-nearest-even. This is the precision a
/// tensor core sees on its inputs.
pub fn round_tf32(x: f32) -> f32 {
    if !x.is_finite() {
        return x;
    }
    let bits = x.to_bits();
    let rem = bits & 0x1fff;
    let mut out = bits >> 13;
    if rem > 0x1000 || (rem == 0x1000 && (out & 1) == 1) {
        out += 1;
    }
    f32::from_bits(out << 13)
}

/// Encode an f32 slice as packed little-endian f16 bytes.
pub fn encode_f16(src: &[f32], dst: &mut Vec<u8>) {
    dst.reserve(src.len() * 2);
    for &x in src {
        dst.extend_from_slice(&f32_to_f16_bits(x).to_le_bytes());
    }
}

/// Decode packed little-endian f16 bytes into f32s. `bytes.len()` must be even.
pub fn decode_f16(bytes: &[u8], dst: &mut Vec<f32>) {
    debug_assert_eq!(bytes.len() % 2, 0);
    dst.reserve(bytes.len() / 2);
    for c in bytes.chunks_exact(2) {
        dst.push(f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]])));
    }
}

/// Smallest positive normal f16.
pub const F16_MIN_POSITIVE: f32 = 6.103515625e-5;
/// Largest finite f16.
pub const F16_MAX: f32 = 65504.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_values_roundtrip() {
        for x in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 1024.0, 0.125, 65504.0] {
            assert_eq!(round_f16(x), x, "{x}");
        }
    }

    #[test]
    fn overflow_to_inf() {
        assert!(round_f16(1e6).is_infinite());
        assert!(round_f16(-1e6).is_infinite());
        assert!(round_f16(-1e6) < 0.0);
    }

    #[test]
    fn underflow_to_zero() {
        assert_eq!(round_f16(1e-10), 0.0);
        assert_eq!(round_f16(-1e-10), 0.0);
        assert!(round_f16(-1e-10).is_sign_negative());
    }

    #[test]
    fn subnormals_preserved() {
        // 2^-24 is the smallest positive subnormal f16.
        let tiny = 2f32.powi(-24);
        assert_eq!(round_f16(tiny), tiny);
        assert_eq!(round_f16(tiny * 0.4), 0.0);
        assert_eq!(round_f16(tiny * 3.0), tiny * 3.0);
    }

    #[test]
    fn nan_stays_nan() {
        assert!(round_f16(f32::NAN).is_nan());
        assert!(round_f16(f32::INFINITY).is_infinite());
    }

    #[test]
    fn relative_error_bounded() {
        // f16 has 11 significand bits → rel err ≤ 2^-11 for normals.
        let mut x = 1.0e-4f32;
        while x < 6.0e4 {
            let r = round_f16(x);
            assert!(((r - x) / x).abs() <= 1.0 / 2048.0, "x={x} r={r}");
            x *= 1.7;
        }
    }

    #[test]
    fn tf32_mantissa_10_bits() {
        let x = 1.0 + 1.0 / 1024.0; // representable in 10 bits
        assert_eq!(round_tf32(x), x);
        let y = 1.0 + 1.0 / 4096.0; // not representable
        assert_ne!(round_tf32(y), y);
        assert!((round_tf32(y) - y).abs() <= 1.0 / 2048.0);
        // Exponent range is f32's: no overflow at 1e30.
        assert!(round_tf32(1e30).is_finite());
    }

    #[test]
    fn exhaustive_bit_level_roundtrip() {
        // Every finite f16 bit pattern (normals, subnormals, ±0, max, ±inf)
        // must survive f16 → f32 → f16 exactly; NaNs must stay NaN (the
        // payload may quieten).
        for h in 0..=u16::MAX {
            let exp = (h >> 10) & 0x1f;
            let man = h & 0x3ff;
            let f = f16_bits_to_f32(h);
            if exp == 0x1f && man != 0 {
                assert!(f.is_nan(), "{h:#06x}");
                let back = f32_to_f16_bits(f);
                assert_eq!(back & 0x7c00, 0x7c00, "{h:#06x}");
                assert_ne!(back & 0x3ff, 0, "{h:#06x} must stay NaN");
            } else {
                assert_eq!(f32_to_f16_bits(f), h, "{h:#06x} ({f})");
            }
        }
    }

    #[test]
    fn mantissa_carry_rolls_into_exponent() {
        // Largest f32 below 2.0: all-ones mantissa rounds up and the carry
        // increments the f16 exponent.
        let just_below_two = f32::from_bits(0x3fff_ffff);
        assert_eq!(f32_to_f16_bits(just_below_two), 0x4000, "→ 2.0 exactly");
        // Carry at the top of the exponent range overflows to +inf: 65520
        // is the midpoint between f16::MAX (odd mantissa) and 2^16, so
        // round-to-even goes up, and 0x7bff + 1 = 0x7c00 = +inf.
        assert_eq!(f32_to_f16_bits(65519.0), 0x7bff, "below midpoint → MAX");
        assert_eq!(f32_to_f16_bits(65520.0), 0x7c00, "midpoint → +inf");
        assert_eq!(f32_to_f16_bits(-65520.0), 0xfc00);
        // Largest subnormal's upper midpoint rounds into the first normal.
        let mid = (1023.5f64 * 2f64.powi(-24)) as f32;
        assert_eq!(f32_to_f16_bits(mid), 0x0400, "subnormal → min normal carry");
    }

    #[test]
    fn specials_map_to_canonical_bits() {
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xfc00);
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        let n = f32_to_f16_bits(f32::NAN);
        assert_eq!(n & 0x7c00, 0x7c00);
        assert_ne!(n & 0x3ff, 0);
    }

    #[test]
    fn property_roundtrip_idempotent_monotone_signed() {
        crate::util::prop::quickcheck("f16 rounding laws", |g| {
            // Random finite f32s spanning the whole exponent range.
            let mut draw = |g: &mut crate::util::prop::Gen| -> f32 {
                loop {
                    let x = f32::from_bits(g.u64() as u32);
                    if x.is_finite() {
                        return x;
                    }
                }
            };
            let x = draw(g);
            let y = draw(g);
            let rx = round_f16(x);
            // Idempotence: a rounded value is a fixed point.
            if !rx.is_nan() && round_f16(rx).to_bits() != rx.to_bits() {
                return Err(format!("not idempotent at {x} → {rx}"));
            }
            // Sign preservation (including signed zero).
            if rx.is_sign_positive() != x.is_sign_positive() {
                return Err(format!("sign flipped at {x}"));
            }
            // Monotonicity of round-to-nearest.
            let (lo, hi) = if x <= y { (x, y) } else { (y, x) };
            let (rlo, rhi) = (round_f16(lo), round_f16(hi));
            if !(rlo <= rhi || rlo.is_nan() || rhi.is_nan()) {
                return Err(format!("non-monotone: {lo}→{rlo} vs {hi}→{rhi}"));
            }
            // Relative error ≤ 2⁻¹¹ for values in f16's normal range.
            let a = x.abs();
            if (F16_MIN_POSITIVE..=F16_MAX).contains(&a) {
                let err = ((rx - x) / x).abs();
                if err > 1.0 / 2048.0 {
                    return Err(format!("error {err} at {x}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn encode_decode_roundtrip() {
        let src: Vec<f32> = (0..257).map(|i| (i as f32 - 100.0) * 0.25).collect();
        let mut bytes = Vec::new();
        encode_f16(&src, &mut bytes);
        assert_eq!(bytes.len(), src.len() * 2);
        let mut back = Vec::new();
        decode_f16(&bytes, &mut back);
        assert_eq!(src, back); // all values exactly representable
    }
}
