//! Mini property-testing framework (proptest is unavailable offline).
//!
//! A `Gen` wraps a seeded PRNG and produces random structured inputs; a
//! property is a closure returning `Result<(), String>`. On failure the
//! framework re-runs the case with a bisected "size" parameter to report the
//! smallest failing size it can find (a lightweight stand-in for shrinking),
//! and always prints the seed so the case can be replayed.

use crate::rng::Xoshiro256;

/// Random input generator handed to properties.
pub struct Gen {
    rng: Xoshiro256,
    /// Soft upper bound on generated structure sizes; lowered during shrink.
    pub size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Self {
        Gen {
            rng: Xoshiro256::seed_from(seed),
            size,
        }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Uniform in `[lo, hi)` (hi > lo).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + (self.rng.next_u64() as usize) % (hi - lo)
    }

    /// A "sized" length: uniform in `[lo, max(lo+1, min(hi, lo+size)))`.
    pub fn len(&mut self, lo: usize, hi: usize) -> usize {
        let cap = (lo + self.size.max(1)).min(hi).max(lo + 1);
        self.usize_in(lo, cap)
    }

    /// Uniform f64 in [0,1).
    pub fn unit_f64(&mut self) -> f64 {
        self.rng.unit_f64()
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.unit_f64() * (hi - lo)
    }

    /// Standard normal (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        self.rng.normal()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Pick an element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len())]
    }

    /// Vec of f32 in [-scale, scale], sized length in [1, max_len].
    pub fn f32_vec(&mut self, max_len: usize, scale: f32) -> Vec<f32> {
        let n = self.len(1, max_len + 1);
        (0..n)
            .map(|_| (self.f64_in(-scale as f64, scale as f64)) as f32)
            .collect()
    }
}

/// Configuration for a property run.
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        // Honour FASTMPS_PROP_CASES so CI can crank coverage up.
        let cases = std::env::var("FASTMPS_PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(64);
        Config {
            cases,
            seed: 0x5eed_fa57_3535_0001,
            max_size: 32,
        }
    }
}

/// Run `prop` over `cfg.cases` random cases; panic with seed + smallest
/// failing size on the first failure.
pub fn check<F>(name: &str, cfg: Config, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let seed = cfg.seed ^ (case as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        // Grow sizes over the run: early cases are tiny.
        let size = 1 + (cfg.max_size * (case + 1)) / cfg.cases;
        let mut g = Gen::new(seed, size);
        if let Err(msg) = prop(&mut g) {
            // "Shrink": retry the same seed at smaller sizes and report the
            // smallest size that still fails.
            let mut smallest = (size, msg.clone());
            let mut s = size / 2;
            while s >= 1 {
                let mut g2 = Gen::new(seed, s);
                match prop(&mut g2) {
                    Err(m) => {
                        smallest = (s, m);
                        if s == 1 {
                            break;
                        }
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}, size {}): {}",
                smallest.0, smallest.1
            );
        }
    }
}

/// Shorthand for `check` with the default configuration.
pub fn quickcheck<F>(name: &str, prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    check(name, Config::default(), prop)
}

/// Property helper: assert approximate equality of two f64s.
pub fn close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    let scale = 1.0f64.max(a.abs()).max(b.abs());
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        quickcheck("add commutes", |g| {
            let a = g.f64_in(-1e6, 1e6);
            let b = g.f64_in(-1e6, 1e6);
            close(a + b, b + a, 1e-12, "a+b")
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        quickcheck("always fails", |_| Err("nope".into()));
    }

    #[test]
    fn generators_in_range() {
        quickcheck("ranges", |g| {
            let n = g.usize_in(3, 9);
            if !(3..9).contains(&n) {
                return Err(format!("usize_in out of range: {n}"));
            }
            let x = g.f64_in(-2.0, 2.0);
            if !(-2.0..2.0).contains(&x) {
                return Err(format!("f64_in out of range: {x}"));
            }
            let v = g.f32_vec(10, 1.0);
            if v.is_empty() || v.len() > 10 {
                return Err(format!("bad vec len {}", v.len()));
            }
            Ok(())
        });
    }

    #[test]
    fn sizes_grow_over_cases() {
        let mut max_seen = 0usize;
        check(
            "size growth",
            Config {
                cases: 16,
                seed: 7,
                max_size: 16,
            },
            |g| {
                max_seen = max_seen.max(g.size);
                Ok(())
            },
        );
        assert!(max_seen >= 8);
    }
}
