//! Counting allocator — the proof harness behind the zero-allocation step
//! contract (docs/PERF.md).
//!
//! [`CountingAlloc`] wraps the system allocator and counts every
//! allocation with a relaxed atomic. `lib.rs` installs it as the global
//! allocator **in test builds only**, so tests can assert that a
//! steady-state `NativeEngine::step_prepared` performs zero heap
//! allocations after warm-up; release builds keep the plain system
//! allocator. The counter is process-global — callers must diff
//! [`allocation_count`] around a single-threaded region to get a
//! meaningful number.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

/// System allocator plus a global allocation counter.
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // A realloc that moves (or grows in place) still counts: the hot
        // path must not grow buffers at steady state either.
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

/// Total allocations since process start (test builds; always 0 deltas in
/// builds where [`CountingAlloc`] is not the global allocator).
pub fn allocation_count() -> u64 {
    ALLOCATIONS.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_observes_heap_activity() {
        let before = allocation_count();
        let v: Vec<u64> = Vec::with_capacity(64);
        std::hint::black_box(&v);
        let after = allocation_count();
        assert!(after > before, "allocation not counted ({before}→{after})");
    }

    #[test]
    fn counter_is_quiet_for_stack_work() {
        // Pure arithmetic on the stack must not move the counter (in this
        // thread; other test threads may allocate concurrently, so allow
        // the check to retry a few times for a clean window).
        for _ in 0..16 {
            let before = allocation_count();
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            std::hint::black_box(acc);
            if allocation_count() == before {
                return;
            }
        }
        panic!("never observed an allocation-free arithmetic window");
    }
}
