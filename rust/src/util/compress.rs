//! Self-contained lossless blob codec (zstd is unavailable offline).
//!
//! A greedy LZ77 with a 64 KiB window and byte-oriented tokens — small,
//! auditable, and fast enough for the Γ-store path where compression
//! exists to cut §3.3.2 I/O bytes, not to win ratio benchmarks.
//!
//! Stream layout: LEB128 varint of the original length, then tokens:
//! - `0x00..=0x7f` — literal run of `ctrl + 1` bytes (follow inline);
//! - `0x80..=0xff` — match of `(ctrl & 0x7f) + 4` bytes at a 2-byte
//!   little-endian distance (1..=65535) back into the output.
//!
//! Matches may overlap their own output (run-length style), so the decoder
//! copies byte-by-byte. The decoder validates every length/distance and the
//! final size, so corrupt blobs fail loudly instead of producing garbage Γ.

const MIN_MATCH: usize = 4;
const MAX_MATCH: usize = MIN_MATCH + 127;
const MAX_DIST: usize = u16::MAX as usize;
const HASH_BITS: u32 = 16;

/// Hash-table slots hold candidate positions as `u32`. For inputs of
/// 4 GiB and beyond a raw byte offset would silently wrap, making the
/// encoder read "candidates" at the wrong position (garbage matches the
/// compare loop then rejects — quadratic slowdown at best, and a
/// correctness trap if this code ever changes). So the encoder works in
/// independent segments well under the `u32` bound, storing positions
/// relative to the segment start; matches never cross back over a
/// segment start, which costs at most one 64 KiB window of ratio per
/// 2 GiB. The stream format is unchanged — decoders don't know or care.
const SEG_BYTES: usize = 1 << 31;

fn hash4(b: &[u8]) -> usize {
    let v = u32::from_le_bytes([b[0], b[1], b[2], b[3]]);
    (v.wrapping_mul(2654435761) >> (32 - HASH_BITS)) as usize
}

/// LEB128 encode (shared with the net wire format — `net::frame`).
pub(crate) fn write_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// LEB128 decode from the front of `b`; returns (value, bytes consumed).
pub(crate) fn read_varint(b: &[u8]) -> Result<(u64, usize), String> {
    let mut v = 0u64;
    let mut shift = 0u32;
    for (i, &byte) in b.iter().enumerate() {
        if shift >= 64 {
            return Err("varint overflow".into());
        }
        v |= u64::from(byte & 0x7f) << shift;
        if byte & 0x80 == 0 {
            return Ok((v, i + 1));
        }
        shift += 7;
    }
    Err("truncated varint".into())
}

fn flush_literals(out: &mut Vec<u8>, lits: &[u8]) {
    for chunk in lits.chunks(128) {
        out.push((chunk.len() - 1) as u8);
        out.extend_from_slice(chunk);
    }
}

/// Compress `src`. Never fails; worst case output is `src` plus ~1% framing.
/// Inputs at or beyond 4 GiB are handled by segmenting (see [`SEG_BYTES`]).
pub fn compress(src: &[u8]) -> Vec<u8> {
    compress_segmented(src, SEG_BYTES)
}

/// [`compress`] with an explicit segment bound — factored out so tests can
/// exercise the ≥ 4 GiB boundary discipline with tiny segments instead of
/// allocating 4 GiB.
fn compress_segmented(src: &[u8], seg_bytes: usize) -> Vec<u8> {
    let seg_bytes = seg_bytes.max(MIN_MATCH);
    let mut out = Vec::with_capacity(src.len() / 2 + 16);
    write_varint(&mut out, src.len() as u64);
    if src.is_empty() {
        return out;
    }
    let mut head = vec![u32::MAX; 1 << HASH_BITS];
    let mut seg_start = 0usize;
    while seg_start < src.len() {
        let seg_end = seg_start.saturating_add(seg_bytes).min(src.len());
        if seg_start > 0 {
            // Candidates are relative to the segment start; stale entries
            // from the previous segment would alias into this one.
            head.fill(u32::MAX);
        }
        let mut i = seg_start;
        let mut lit_start = seg_start;
        while i < seg_end {
            let mut m_len = 0usize;
            let mut m_dist = 0usize;
            if i + MIN_MATCH <= seg_end {
                let h = hash4(&src[i..i + 4]);
                let cand = head[h];
                head[h] = (i - seg_start) as u32;
                if cand != u32::MAX {
                    let cand = seg_start + cand as usize;
                    if i - cand <= MAX_DIST {
                        let max_len = MAX_MATCH.min(seg_end - i);
                        let mut l = 0usize;
                        while l < max_len && src[cand + l] == src[i + l] {
                            l += 1;
                        }
                        if l >= MIN_MATCH {
                            m_len = l;
                            m_dist = i - cand;
                        }
                    }
                }
            }
            if m_len > 0 {
                flush_literals(&mut out, &src[lit_start..i]);
                out.push(0x80 | (m_len - MIN_MATCH) as u8);
                out.extend_from_slice(&(m_dist as u16).to_le_bytes());
                i += m_len;
                lit_start = i;
            } else {
                i += 1;
            }
        }
        flush_literals(&mut out, &src[lit_start..seg_end]);
        seg_start = seg_end;
    }
    out
}

/// Decompress a [`compress`] stream; errors on any framing violation.
pub fn decompress(blob: &[u8]) -> Result<Vec<u8>, String> {
    let (n, mut i) = read_varint(blob)?;
    let n = usize::try_from(n).map_err(|_| "blob too large".to_string())?;
    // The header length is untrusted: reject provably-corrupt claims
    // before allocating. A match token is 3 bytes for ≤ MAX_MATCH output,
    // so no valid stream expands more than ~44× its encoded size.
    let max_plausible = blob
        .len()
        .saturating_mul(MAX_MATCH.div_ceil(3));
    if n > max_plausible {
        return Err(format!(
            "length header {n} exceeds any valid expansion of {} input bytes",
            blob.len()
        ));
    }
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        let ctrl = *blob.get(i).ok_or("truncated stream")?;
        i += 1;
        if ctrl < 0x80 {
            let len = ctrl as usize + 1;
            let lits = blob
                .get(i..i + len)
                .ok_or_else(|| format!("truncated literal run of {len}"))?;
            out.extend_from_slice(lits);
            i += len;
        } else {
            let len = (ctrl & 0x7f) as usize + MIN_MATCH;
            let d = blob.get(i..i + 2).ok_or("truncated match token")?;
            let dist = u16::from_le_bytes([d[0], d[1]]) as usize;
            i += 2;
            if dist == 0 || dist > out.len() {
                return Err(format!(
                    "match distance {dist} invalid at output offset {}",
                    out.len()
                ));
            }
            let start = out.len() - dist;
            for k in 0..len {
                let b = out[start + k];
                out.push(b);
            }
        }
    }
    if out.len() != n {
        return Err(format!("decoded {} bytes, header says {n}", out.len()));
    }
    if i != blob.len() {
        return Err(format!("{} trailing bytes after stream", blob.len() - i));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(src: &[u8]) {
        let c = compress(src);
        let back = decompress(&c).unwrap();
        assert_eq!(back, src, "roundtrip of {} bytes", src.len());
    }

    #[test]
    fn empty_and_tiny() {
        roundtrip(b"");
        roundtrip(b"a");
        roundtrip(b"abc");
        roundtrip(b"abcd");
    }

    #[test]
    fn repetitive_input_shrinks() {
        let src: Vec<u8> = std::iter::repeat(b"fastmps!".as_slice())
            .take(512)
            .flatten()
            .copied()
            .collect();
        let c = compress(&src);
        assert!(c.len() < src.len() / 4, "{} vs {}", c.len(), src.len());
        roundtrip(&src);
    }

    #[test]
    fn overlapping_match_rle_style() {
        // "aaaa..." forces dist=1 matches longer than the distance.
        let src = vec![b'a'; 1000];
        roundtrip(&src);
        let mut src2 = vec![0u8; 0];
        src2.extend_from_slice(b"xyz");
        src2.extend(std::iter::repeat(b"xyz".as_slice()).take(100).flatten());
        roundtrip(&src2);
    }

    #[test]
    fn incompressible_input_bounded_expansion() {
        // A pseudo-random byte stream: expansion stays under 2%.
        let mut x = 0x9e3779b97f4a7c15u64;
        let src: Vec<u8> = (0..10_000)
            .map(|_| {
                x ^= x << 13;
                x ^= x >> 7;
                x ^= x << 17;
                x as u8
            })
            .collect();
        let c = compress(&src);
        assert!(c.len() <= src.len() + src.len() / 50 + 16);
        roundtrip(&src);
    }

    #[test]
    fn property_roundtrip_random_structures() {
        crate::util::prop::quickcheck("lz roundtrip", |g| {
            let n = g.usize_in(0, 4096);
            let mode = g.usize_in(0, 3);
            let src: Vec<u8> = match mode {
                0 => (0..n).map(|_| (g.u64() & 0xff) as u8).collect(),
                1 => (0..n).map(|i| (i / 7) as u8).collect(),
                _ => {
                    let period = g.usize_in(1, 40);
                    (0..n).map(|i| (i % period) as u8).collect()
                }
            };
            let back =
                decompress(&compress(&src)).map_err(|e| format!("decode failed: {e}"))?;
            if back != src {
                return Err(format!("mismatch at {} bytes (mode {mode})", src.len()));
            }
            Ok(())
        });
    }

    // Edge cases exercised by the net payload-frame path (`net::frame`
    // packs every sample block through this codec).

    #[test]
    fn empty_input_is_a_one_byte_stream() {
        let c = compress(b"");
        assert_eq!(c, vec![0u8], "varint 0, no tokens");
        assert_eq!(decompress(&c).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn incompressible_random_bytes_roundtrip_with_bounded_overhead() {
        // splitmix64-style stream: no 4-byte match survives, so the output
        // is all literal runs — 1 control byte per 128 literals plus the
        // length header.
        let mut x = 0x243f6a8885a308d3u64;
        let src: Vec<u8> = (0..100_000)
            .map(|_| {
                x = x.wrapping_add(0x9e3779b97f4a7c15);
                let mut z = x;
                z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
                z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
                (z ^ (z >> 31)) as u8
            })
            .collect();
        let c = compress(&src);
        assert!(
            c.len() <= src.len() + src.len() / 100 + 16,
            "expansion {} over {}",
            c.len(),
            src.len()
        );
        assert_eq!(decompress(&c).unwrap(), src);
    }

    #[test]
    fn multi_megabyte_repetitive_input_roundtrips_and_shrinks() {
        // ~4 MiB of period-24 structure: long matches at short distances,
        // the shape of a broadcast Γ block or a sink histogram run.
        let src: Vec<u8> = (0..4 << 20).map(|i| ((i % 24) * 7) as u8).collect();
        let c = compress(&src);
        assert!(
            c.len() < src.len() / 20,
            "repetitive 4 MiB should compress ≥ 20×, got {} from {}",
            c.len(),
            src.len()
        );
        assert_eq!(decompress(&c).unwrap(), src);
    }

    #[test]
    fn segmented_compression_roundtrips_across_boundaries() {
        // The ≥ 4 GiB discipline, scaled down. Hash-table candidates are
        // stored relative to each segment start, and the table is cleared
        // between segments; a bug in either would produce matches that
        // point at the wrong bytes and fail these roundtrips. Testing at
        // SEG_BYTES itself would need a > 4 GiB allocation, so the
        // boundary bookkeeping is exercised with tiny segments instead —
        // the code path is identical.
        let src: Vec<u8> = (0..10_000).map(|i| ((i % 7) * 3) as u8).collect();
        for seg in [5usize, 64, 100, 1000, 4096] {
            let c = compress_segmented(&src, seg);
            assert_eq!(decompress(&c).unwrap(), src, "seg {seg}");
        }
        // One segment covering everything is byte-identical to the
        // default path for inputs under the bound.
        assert_eq!(compress_segmented(&src, usize::MAX), compress(&src));
        // Incompressible data across many boundaries.
        let mut x = 0x2545_f491_4f6c_dd1du64;
        let rnd: Vec<u8> = (0..3000)
            .map(|_| {
                x ^= x << 13;
                x ^= x >> 7;
                x ^= x << 17;
                x as u8
            })
            .collect();
        for seg in [17usize, 256] {
            assert_eq!(decompress(&compress_segmented(&rnd, seg)).unwrap(), rnd);
        }
    }

    #[test]
    fn corrupted_streams_error_instead_of_panicking() {
        let src: Vec<u8> = (0..4096).map(|i| ((i / 5) % 251) as u8).collect();
        let c = compress(&src);
        // Every single-byte truncation must fail loudly or (for a byte
        // boundary that still parses) decode to the wrong length — never
        // panic, never return the original bytes as a false positive.
        for cut in [1, c.len() / 3, c.len() / 2, c.len() - 1] {
            match decompress(&c[..cut]) {
                Err(_) => {}
                Ok(out) => assert_ne!(out, src, "truncation at {cut} decoded clean"),
            }
        }
        // Systematic single-byte corruption over a smaller stream: every
        // flip must surface as `Err` or a well-formed (if wrong) decode —
        // never a panic, never an out-of-bounds copy. An `Ok` is possible
        // (e.g. a distance flip landing on equivalent periodic data), so
        // the property under test is purely "no panic + validated frame".
        let small: Vec<u8> = (0..512).map(|i| ((i / 3) % 17) as u8).collect();
        let cs = compress(&small);
        let mut errors = 0usize;
        for i in 0..cs.len() {
            let mut bad = cs.clone();
            bad[i] ^= 0x5a;
            if decompress(&bad).is_err() {
                errors += 1;
            }
        }
        assert!(errors > 0, "no flip of {} bytes was detected", cs.len());
    }

    #[test]
    fn corruption_detected() {
        let src: Vec<u8> = std::iter::repeat(b"fastmps!".as_slice())
            .take(64)
            .flatten()
            .copied()
            .collect();
        let c = compress(&src);
        // Truncation.
        assert!(decompress(&c[..c.len() - 3]).is_err());
        // Header/total-size mismatch via trailing garbage.
        let mut t = c.clone();
        t.push(0x00);
        t.push(0xab);
        assert!(decompress(&t).is_err());
        // Empty input is not a valid stream.
        assert!(decompress(&[]).is_err());
        // A corrupted length header may not trigger a giant allocation —
        // it must be rejected up front.
        let mut huge = Vec::new();
        write_varint(&mut huge, u64::MAX / 2);
        huge.extend_from_slice(&c[..8]);
        // Must return Err cheaply — not attempt a ~2^62-byte allocation.
        assert!(decompress(&huge).is_err());
    }
}
