//! Minimal float abstraction (num-traits is unavailable offline).
//!
//! The generic kernels (`linalg`, `tensor`, `sampler`) are written over a
//! [`Float`] trait so the same code runs the f64 oracle and the f32/TF32
//! production paths. This shim exposes exactly the surface those kernels
//! use, implemented for `f32` and `f64`; the method names and `Option`
//! signatures mirror `num_traits::Float`/`NumCast` so swapping the real
//! crate back in is a one-line import change.

use std::ops::{Add, AddAssign, Div, Mul, Neg, Sub};

/// Floating-point scalar: `f32` or `f64`.
pub trait Float:
    Copy
    + Clone
    + PartialEq
    + PartialOrd
    + Neg<Output = Self>
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Div<Output = Self>
    + AddAssign
    + Send
    + Sync
    + 'static
{
    fn zero() -> Self;
    fn one() -> Self;
    /// Lossy conversion from any primitive float (mirrors `NumCast::from`).
    fn from<S: Into<f64>>(v: S) -> Option<Self>;
    fn to_f64(self) -> Option<f64>;
    fn sqrt(self) -> Self;
    fn exp(self) -> Self;
    fn ln(self) -> Self;
    fn cos(self) -> Self;
    fn sin(self) -> Self;
    fn abs(self) -> Self;
    fn powi(self, n: i32) -> Self;
    fn powf(self, p: Self) -> Self;
    fn floor(self) -> Self;
    fn ceil(self) -> Self;
    fn round(self) -> Self;
    fn recip(self) -> Self;
    fn max(self, other: Self) -> Self;
    fn min(self, other: Self) -> Self;
    fn mul_add(self, a: Self, b: Self) -> Self;
    fn is_finite(self) -> bool;
    fn is_nan(self) -> bool;
    fn epsilon() -> Self;
    fn min_positive_value() -> Self;
    fn max_value() -> Self;
    fn infinity() -> Self;
    fn neg_infinity() -> Self;
    fn nan() -> Self;
}

macro_rules! impl_float {
    ($t:ty) => {
        impl Float for $t {
            #[inline]
            fn zero() -> Self {
                0.0
            }
            #[inline]
            fn one() -> Self {
                1.0
            }
            #[inline]
            fn from<S: Into<f64>>(v: S) -> Option<Self> {
                Some(v.into() as $t)
            }
            #[inline]
            fn to_f64(self) -> Option<f64> {
                Some(self as f64)
            }
            #[inline]
            fn sqrt(self) -> Self {
                <$t>::sqrt(self)
            }
            #[inline]
            fn exp(self) -> Self {
                <$t>::exp(self)
            }
            #[inline]
            fn ln(self) -> Self {
                <$t>::ln(self)
            }
            #[inline]
            fn cos(self) -> Self {
                <$t>::cos(self)
            }
            #[inline]
            fn sin(self) -> Self {
                <$t>::sin(self)
            }
            #[inline]
            fn abs(self) -> Self {
                <$t>::abs(self)
            }
            #[inline]
            fn powi(self, n: i32) -> Self {
                <$t>::powi(self, n)
            }
            #[inline]
            fn powf(self, p: Self) -> Self {
                <$t>::powf(self, p)
            }
            #[inline]
            fn floor(self) -> Self {
                <$t>::floor(self)
            }
            #[inline]
            fn ceil(self) -> Self {
                <$t>::ceil(self)
            }
            #[inline]
            fn round(self) -> Self {
                <$t>::round(self)
            }
            #[inline]
            fn recip(self) -> Self {
                <$t>::recip(self)
            }
            #[inline]
            fn max(self, other: Self) -> Self {
                <$t>::max(self, other)
            }
            #[inline]
            fn min(self, other: Self) -> Self {
                <$t>::min(self, other)
            }
            #[inline]
            fn mul_add(self, a: Self, b: Self) -> Self {
                <$t>::mul_add(self, a, b)
            }
            #[inline]
            fn is_finite(self) -> bool {
                <$t>::is_finite(self)
            }
            #[inline]
            fn is_nan(self) -> bool {
                <$t>::is_nan(self)
            }
            #[inline]
            fn epsilon() -> Self {
                <$t>::EPSILON
            }
            #[inline]
            fn min_positive_value() -> Self {
                <$t>::MIN_POSITIVE
            }
            #[inline]
            fn max_value() -> Self {
                <$t>::MAX
            }
            #[inline]
            fn infinity() -> Self {
                <$t>::INFINITY
            }
            #[inline]
            fn neg_infinity() -> Self {
                <$t>::NEG_INFINITY
            }
            #[inline]
            fn nan() -> Self {
                <$t>::NAN
            }
        }
    };
}

impl_float!(f32);
impl_float!(f64);

#[cfg(test)]
mod tests {
    use super::*;

    fn poly<T: Float>(x: T) -> T {
        // Exercise the generic surface the kernels rely on.
        let two = T::from(2.0f64).unwrap();
        (x * x + two).sqrt().max(T::one())
    }

    #[test]
    fn generic_surface_works_for_both_widths() {
        assert!((poly(1.0f64) - 3f64.sqrt()).abs() < 1e-12);
        assert!((poly(1.0f32) - 3f32.sqrt()).abs() < 1e-6);
        assert_eq!(<f32 as Float>::from(0.5f64).unwrap(), 0.5f32);
        assert_eq!(1.5f64.to_f64().unwrap(), 1.5);
        assert!(<f64 as Float>::nan().is_nan());
        assert!(!<f32 as Float>::infinity().is_finite());
    }

    #[test]
    fn constants_match_primitives() {
        assert_eq!(<f32 as Float>::epsilon(), f32::EPSILON);
        assert_eq!(<f64 as Float>::min_positive_value(), f64::MIN_POSITIVE);
        assert_eq!(<f64 as Float>::max_value(), f64::MAX);
    }
}
