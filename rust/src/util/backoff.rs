//! Capped exponential backoff with deterministic jitter — the retry
//! policy shared by the net client's `Busy` handling, the router's
//! spillover loop, and anything else that re-tries a transient capacity
//! condition. Delays double from a base up to a cap; each sleep gets up
//! to `jitter_ms` of extra pseudo-random delay so a fleet of retrying
//! clients does not thundering-herd in lockstep.

use std::time::{Duration, Instant};

/// splitmix64 finalizer: a cheap, high-quality 64-bit mixer. Public
/// because the router's rendezvous hash builds on the same primitive.
pub fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// See module docs. The jitter stream is seeded, so a given `(seed,
/// attempt)` pair always produces the same delay — tests stay
/// reproducible while distinct clients (distinct seeds) de-correlate.
#[derive(Debug, Clone)]
pub struct Backoff {
    next_ms: u64,
    cap_ms: u64,
    jitter_ms: u64,
    rng: u64,
    /// Delays handed out so far (observable for tests and metrics).
    pub attempts: u32,
}

impl Backoff {
    pub fn new(base_ms: u64, cap_ms: u64, jitter_ms: u64, seed: u64) -> Backoff {
        let base = base_ms.max(1);
        Backoff {
            next_ms: base,
            cap_ms: cap_ms.max(base),
            jitter_ms,
            rng: mix64(seed | 1),
            attempts: 0,
        }
    }

    /// The next delay (exponential step + jitter), advancing the schedule.
    pub fn next_delay(&mut self) -> Duration {
        let jitter = if self.jitter_ms == 0 {
            0
        } else {
            self.rng = mix64(self.rng);
            self.rng % (self.jitter_ms + 1)
        };
        let d = Duration::from_millis(self.next_ms + jitter);
        self.next_ms = self.next_ms.saturating_mul(2).min(self.cap_ms);
        self.attempts += 1;
        d
    }

    /// Sleep for the next delay, clipped to `deadline`. Returns `false`
    /// (without sleeping) when the deadline has already passed — the
    /// caller should give up instead of retrying.
    pub fn sleep_before(&mut self, deadline: Instant) -> bool {
        let now = Instant::now();
        if now >= deadline {
            return false;
        }
        std::thread::sleep(self.next_delay().min(deadline - now));
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delays_double_up_to_the_cap() {
        let mut b = Backoff::new(1, 8, 0, 42);
        let ms: Vec<u64> = (0..6).map(|_| b.next_delay().as_millis() as u64).collect();
        assert_eq!(ms, vec![1, 2, 4, 8, 8, 8]);
        assert_eq!(b.attempts, 6);
    }

    #[test]
    fn jitter_is_bounded_and_deterministic_per_seed() {
        let mut a = Backoff::new(10, 10, 5, 7);
        let mut b = Backoff::new(10, 10, 5, 7);
        for _ in 0..32 {
            let da = a.next_delay().as_millis() as u64;
            let db = b.next_delay().as_millis() as u64;
            assert_eq!(da, db, "same seed, same schedule");
            assert!((10..=15).contains(&da), "{da}");
        }
    }

    #[test]
    fn zero_base_is_clamped() {
        let mut b = Backoff::new(0, 0, 0, 1);
        assert_eq!(b.next_delay(), Duration::from_millis(1));
    }

    #[test]
    fn sleep_before_respects_deadline() {
        let mut b = Backoff::new(1, 4, 0, 1);
        assert!(!b.sleep_before(Instant::now() - Duration::from_millis(1)));
        assert_eq!(b.attempts, 0, "no delay consumed past the deadline");
        let t0 = Instant::now();
        assert!(b.sleep_before(t0 + Duration::from_millis(50)));
        assert!(t0.elapsed() >= Duration::from_millis(1));
    }

    #[test]
    fn mix64_spreads_nearby_inputs() {
        assert_ne!(mix64(1), mix64(2));
        assert_ne!(mix64(0), 0);
    }
}
