//! The real PJRT-backed step engine (feature `xla`; see `runtime`).

use super::registry::{ArtifactRegistry, Variant, VariantKind};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::metrics::{keys, Metrics};
use crate::mps::Site;
use crate::sampler::StepEngine;
use crate::tensor::SplitBuf;
use crate::util::error::{Error, Result};

fn xerr(e: xla::Error) -> Error {
    Error::Xla(e.to_string())
}

/// Per-thread XLA step engine.
pub struct XlaEngine {
    client: xla::PjRtClient,
    registry: ArtifactRegistry,
    dir: PathBuf,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    pub metrics: Metrics,
    /// Use the TF32-emulating artifacts when available.
    pub prefer_tf32: bool,
}

impl XlaEngine {
    pub fn new(artifacts_dir: &Path) -> Result<XlaEngine> {
        let registry = ArtifactRegistry::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(xerr)?;
        Ok(XlaEngine {
            client,
            registry,
            dir: artifacts_dir.to_path_buf(),
            cache: HashMap::new(),
            metrics: Metrics::new(),
            prefer_tf32: false,
        })
    }

    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }

    fn executable(&mut self, v: &Variant) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(&v.name) {
            let path = self.dir.join(&v.file);
            let t0 = std::time::Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| Error::artifact("non-utf8 artifact path"))?,
            )
            .map_err(xerr)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(xerr)?;
            self.metrics
                .add_phase("compile", t0.elapsed().as_secs_f64());
            crate::log_debug!("compiled {} in {:?}", v.name, t0.elapsed());
            self.cache.insert(v.name.clone(), exe);
        }
        Ok(self.cache.get(&v.name).unwrap())
    }

    fn literal_2d(buf: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
        debug_assert_eq!(buf.len(), rows * cols);
        xla::Literal::vec1(buf)
            .reshape(&[rows as i64, cols as i64])
            .map_err(xerr)
    }

    fn literal_3d(buf: &[f32], a: usize, b: usize, c: usize) -> Result<xla::Literal> {
        debug_assert_eq!(buf.len(), a * b * c);
        xla::Literal::vec1(buf)
            .reshape(&[a as i64, b as i64, c as i64])
            .map_err(xerr)
    }

    /// Pad a (n, x) plane pair to (np, xp).
    fn pad_env(env: &SplitBuf, np: usize, xp: usize) -> (Vec<f32>, Vec<f32>) {
        let (n, x) = (env.shape[0], env.shape[1]);
        let mut re = vec![0.0f32; np * xp];
        let mut im = vec![0.0f32; np * xp];
        for r in 0..n {
            re[r * xp..r * xp + x].copy_from_slice(&env.re[r * x..(r + 1) * x]);
            im[r * xp..r * xp + x].copy_from_slice(&env.im[r * x..(r + 1) * x]);
        }
        (re, im)
    }

    /// Pad Γ (x, y, d) planes to (xp, yp, d).
    fn pad_gamma(site: &Site, xp: usize, yp: usize) -> (Vec<f32>, Vec<f32>) {
        let g = &site.gamma;
        let (x, y, d) = (g.d0, g.d1, g.d2);
        let mut re = vec![0.0f32; xp * yp * d];
        let mut im = vec![0.0f32; xp * yp * d];
        for i in 0..x {
            for j in 0..y {
                for k in 0..d {
                    let z = g.at(i, j, k);
                    let dst = (i * yp + j) * d + k;
                    re[dst] = z.re as f32;
                    im[dst] = z.im as f32;
                }
            }
        }
        (re, im)
    }

    /// Run one padded step through the artifact and crop back.
    fn run_step(
        &mut self,
        v: Variant,
        env: &mut SplitBuf,
        site: &Site,
        thresholds: &[f32],
        displacements: Option<&[(f64, f64)]>,
        samples: &mut Vec<i32>,
    ) -> Result<()> {
        let n = env.shape[0];
        let (np, xp, yp, d) = (v.n, v.x, v.y, v.d);
        let y = site.gamma.d1;

        let t0 = std::time::Instant::now();
        let (ere, eim) = Self::pad_env(env, np, xp);
        let (gre, gim) = Self::pad_gamma(site, xp, yp);
        let mut lam = vec![0.0f32; yp];
        for (dst, &l) in lam.iter_mut().zip(&site.lambda) {
            *dst = l as f32;
        }
        let mut unif = vec![0.5f32; np];
        unif[..n].copy_from_slice(thresholds);
        self.metrics
            .add_phase("host_pack", t0.elapsed().as_secs_f64());
        self.metrics.add(
            keys::HOST_COPY_BYTES,
            ((ere.len() + eim.len() + gre.len() + gim.len()) * 4) as u64,
        );

        let mut inputs = vec![
            Self::literal_2d(&ere, np, xp)?,
            Self::literal_2d(&eim, np, xp)?,
            Self::literal_3d(&gre, xp, yp, d)?,
            Self::literal_3d(&gim, xp, yp, d)?,
            xla::Literal::vec1(&lam),
            xla::Literal::vec1(&unif),
        ];
        if v.kind == VariantKind::StepDisp {
            let mus = displacements.ok_or_else(|| {
                Error::artifact("displaced artifact chosen but no displacement draws")
            })?;
            let mut mre = vec![0.0f32; np];
            let mut mim = vec![0.0f32; np];
            for (i, &(r, im_)) in mus.iter().enumerate() {
                mre[i] = r as f32;
                mim[i] = im_ as f32;
            }
            inputs.push(xla::Literal::vec1(&mre));
            inputs.push(xla::Literal::vec1(&mim));
        }

        let exe = self.executable(&v)?;
        let t1 = std::time::Instant::now();
        let result = exe.execute::<xla::Literal>(&inputs).map_err(xerr)?[0][0]
            .to_literal_sync()
            .map_err(xerr)?;
        self.metrics.add_phase("compute", t1.elapsed().as_secs_f64());
        self.metrics.add(
            keys::FLOPS,
            crate::perfmodel::site_flops(n as u64, site.gamma.d0 as u64, y as u64, d as u64),
        );

        let t2 = std::time::Instant::now();
        let parts = result.to_tuple().map_err(xerr)?;
        if parts.len() != 3 {
            return Err(Error::artifact(format!(
                "step artifact returned {} outputs, expected 3",
                parts.len()
            )));
        }
        let out_re = parts[0].to_vec::<f32>().map_err(xerr)?;
        let out_im = parts[1].to_vec::<f32>().map_err(xerr)?;
        let out_s = parts[2].to_vec::<i32>().map_err(xerr)?;

        // Crop (np, yp) → (n, y).
        let mut cropped = SplitBuf::zeros(&[n, y]);
        for r in 0..n {
            cropped.re[r * y..(r + 1) * y].copy_from_slice(&out_re[r * yp..r * yp + y]);
            cropped.im[r * y..(r + 1) * y].copy_from_slice(&out_im[r * yp..r * yp + y]);
        }
        *env = cropped;
        samples.clear();
        samples.extend_from_slice(&out_s[..n]);
        self.metrics
            .add_phase("host_unpack", t2.elapsed().as_secs_f64());
        self.metrics.add(keys::SAMPLES, n as u64);
        Ok(())
    }
}

impl StepEngine for XlaEngine {
    fn step(
        &mut self,
        env: &mut SplitBuf,
        site: &Site,
        thresholds: &[f32],
        displacements: Option<&[(f64, f64)]>,
        samples: &mut Vec<i32>,
    ) -> Result<()> {
        let n = env.shape[0];
        if thresholds.len() != n {
            return Err(Error::shape(format!(
                "xla step: {} thresholds for N={n}",
                thresholds.len()
            )));
        }
        let displaced = displacements.is_some();
        let v = self.registry.select_step(
            n,
            site.gamma.d0,
            site.gamma.d1,
            site.gamma.d2,
            displaced,
            self.prefer_tf32,
        )?;
        self.run_step(v, env, site, thresholds, displacements, samples)
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}
