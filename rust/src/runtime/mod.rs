//! PJRT runtime: load the AOT HLO artifacts and execute them on the hot
//! path.
//!
//! The [`ArtifactRegistry`] indexes `artifacts/manifest.json` (written by
//! `python/compile/aot.py`); the [`XlaEngine`] compiles the needed shape
//! variants on its own PJRT CPU client (the `xla` crate's client is
//! `Rc`-based, hence one engine — and one client — per worker thread) and
//! caches the loaded executables. Inputs are zero-padded to the variant's
//! χ buckets — exact for both the contraction and the measurement because
//! padded Γ columns and Λ entries are zero.
//!
//! The `xla` crate is not available on the offline build image, so the
//! engine is feature-gated: without `--features xla` a stub with the same
//! public surface is compiled that fails loudly at construction, keeping
//! `--engine native` (and everything else in the crate) fully usable.

mod registry;

pub use registry::{ArtifactRegistry, Variant, VariantKind};

#[cfg(feature = "xla")]
mod xla_engine;
#[cfg(feature = "xla")]
pub use xla_engine::XlaEngine;

#[cfg(not(feature = "xla"))]
mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::XlaEngine;
