//! Stub [`XlaEngine`] compiled when the `xla` feature is off.
//!
//! Mirrors the public surface of the real engine so the coordinators and
//! CLI compile unchanged; every construction attempt returns a clear error
//! pointing at the feature flag instead of a confusing link failure.

use std::path::Path;

use crate::metrics::Metrics;
use crate::mps::Site;
use crate::sampler::StepEngine;
use crate::tensor::SplitBuf;
use crate::util::error::{Error, Result};

/// Placeholder for the PJRT engine; see the module docs.
pub struct XlaEngine {
    pub metrics: Metrics,
    /// Use the TF32-emulating artifacts when available.
    pub prefer_tf32: bool,
}

impl XlaEngine {
    pub fn new(_artifacts_dir: &Path) -> Result<XlaEngine> {
        Err(Error::Xla(
            "this build has no PJRT support (compiled without the `xla` \
             feature); rebuild with `--features xla` after adding the `xla` \
             dependency in Cargo.toml, or run with `--engine native`"
                .into(),
        ))
    }
}

impl StepEngine for XlaEngine {
    fn step(
        &mut self,
        _env: &mut SplitBuf,
        _site: &Site,
        _thresholds: &[f32],
        _displacements: Option<&[(f64, f64)]>,
        _samples: &mut Vec<i32>,
    ) -> Result<()> {
        Err(Error::Xla("stub engine cannot step".into()))
    }

    fn name(&self) -> &'static str {
        "xla-stub"
    }
}
