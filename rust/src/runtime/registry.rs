//! Artifact manifest index and shape-bucket selection.

use std::path::Path;

use crate::util::error::{Error, Result};
use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VariantKind {
    Step,
    StepDisp,
    Partial,
    Finalize,
}

impl VariantKind {
    fn parse(s: &str) -> Result<VariantKind> {
        match s {
            "step" => Ok(VariantKind::Step),
            "step_disp" => Ok(VariantKind::StepDisp),
            "partial" => Ok(VariantKind::Partial),
            "finalize" => Ok(VariantKind::Finalize),
            _ => Err(Error::artifact(format!("unknown variant kind '{s}'"))),
        }
    }
}

/// One AOT-compiled shape variant.
#[derive(Debug, Clone)]
pub struct Variant {
    pub kind: VariantKind,
    pub name: String,
    pub file: String,
    /// Micro batch N₂ the module was lowered for.
    pub n: usize,
    /// χ_l bucket (0 for finalize).
    pub x: usize,
    /// χ_r bucket.
    pub y: usize,
    pub d: usize,
    pub tf32: bool,
}

/// The loaded artifact index.
#[derive(Debug, Clone)]
pub struct ArtifactRegistry {
    pub variants: Vec<Variant>,
}

impl ArtifactRegistry {
    pub fn load(dir: &Path) -> Result<ArtifactRegistry> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| Error::io(path.display(), e))?;
        let j = Json::parse(&text)?;
        if j.req("format")?.as_str() != Some("fastmps-artifacts-v1") {
            return Err(Error::artifact("unknown artifact manifest format"));
        }
        let mut variants = Vec::new();
        for v in j
            .req("variants")?
            .as_arr()
            .ok_or_else(|| Error::artifact("variants not an array"))?
        {
            let kind = VariantKind::parse(
                v.req("kind")?
                    .as_str()
                    .ok_or_else(|| Error::artifact("kind"))?,
            )?;
            variants.push(Variant {
                kind,
                name: v
                    .req("name")?
                    .as_str()
                    .ok_or_else(|| Error::artifact("name"))?
                    .to_string(),
                file: v
                    .req("file")?
                    .as_str()
                    .ok_or_else(|| Error::artifact("file"))?
                    .to_string(),
                n: v.req("n")?.as_usize().ok_or_else(|| Error::artifact("n"))?,
                x: v.get("x").and_then(|x| x.as_usize()).unwrap_or(0),
                y: v.req("y")?.as_usize().ok_or_else(|| Error::artifact("y"))?,
                d: v.req("d")?.as_usize().ok_or_else(|| Error::artifact("d"))?,
                tf32: v.get("tf32").and_then(|b| b.as_bool()).unwrap_or(false),
            });
        }
        if variants.is_empty() {
            return Err(Error::artifact("empty artifact manifest"));
        }
        Ok(ArtifactRegistry { variants })
    }

    /// Pick the cheapest step variant covering `(n, x, y, d)`:
    /// exact `n`/`d`/`displaced`/`tf32` match, smallest `x`/`y` buckets
    /// ≥ the requested bonds (zero-padding is exact).
    pub fn select_step(
        &self,
        n: usize,
        x: usize,
        y: usize,
        d: usize,
        displaced: bool,
        tf32: bool,
    ) -> Result<Variant> {
        let kind = if displaced {
            VariantKind::StepDisp
        } else {
            VariantKind::Step
        };
        let mut best: Option<&Variant> = None;
        for v in &self.variants {
            if v.kind != kind || v.d != d || v.n < n || v.x < x || v.y < y {
                continue;
            }
            if v.tf32 != tf32 {
                continue;
            }
            let better = match best {
                None => true,
                Some(b) => (v.x * v.y, v.n) < (b.x * b.y, b.n),
            };
            if better {
                best = Some(v);
            }
        }
        // tf32 falls back to plain f32 artifacts rather than failing.
        if best.is_none() && tf32 {
            return self.select_step(n, x, y, d, displaced, false);
        }
        best.cloned().ok_or_else(|| {
            Error::artifact(format!(
                "no {} artifact covers n={n} x={x} y={y} d={d} (have: {})",
                if displaced { "step_disp" } else { "step" },
                self.variants
                    .iter()
                    .map(|v| v.name.as_str())
                    .collect::<Vec<_>>()
                    .join(", ")
            ))
        })
    }

    /// Largest micro batch any step artifact supports for `(d, displaced)`.
    pub fn max_micro_batch(&self, d: usize, displaced: bool) -> Option<usize> {
        let kind = if displaced {
            VariantKind::StepDisp
        } else {
            VariantKind::Step
        };
        self.variants
            .iter()
            .filter(|v| v.kind == kind && v.d == d)
            .map(|v| v.n)
            .max()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry() -> ArtifactRegistry {
        let mk = |kind, n, x, y, d, tf32| Variant {
            kind,
            name: format!("v{n}_{x}_{y}_{d}_{tf32}"),
            file: "f".into(),
            n,
            x,
            y,
            d,
            tf32,
        };
        ArtifactRegistry {
            variants: vec![
                mk(VariantKind::Step, 256, 32, 32, 3, false),
                mk(VariantKind::Step, 256, 64, 64, 3, false),
                mk(VariantKind::Step, 256, 96, 96, 3, false),
                mk(VariantKind::Step, 256, 96, 96, 3, true),
                mk(VariantKind::StepDisp, 256, 96, 96, 3, false),
                mk(VariantKind::Step, 256, 1, 32, 3, false),
            ],
        }
    }

    #[test]
    fn selects_smallest_cover() {
        let r = registry();
        let v = r.select_step(100, 20, 30, 3, false, false).unwrap();
        assert_eq!((v.x, v.y), (32, 32));
        let v = r.select_step(256, 33, 10, 3, false, false).unwrap();
        assert_eq!((v.x, v.y), (64, 64));
        let v = r.select_step(256, 1, 20, 3, false, false).unwrap();
        assert_eq!((v.x, v.y), (1, 32));
    }

    #[test]
    fn tf32_preference_and_fallback() {
        let r = registry();
        let v = r.select_step(256, 96, 96, 3, false, true).unwrap();
        assert!(v.tf32);
        // tf32 preference is strict: the (larger) tf32 bucket wins over a
        // tighter f32 one.
        let v = r.select_step(256, 20, 20, 3, false, true).unwrap();
        assert!(v.tf32);
        assert_eq!((v.x, v.y), (96, 96));
        // With no tf32 candidate at all (d=4 here), fall back to f32.
        let mut reg = registry();
        reg.variants.push(Variant {
            kind: VariantKind::Step,
            name: "f32only_d4".into(),
            file: "f".into(),
            n: 256,
            x: 64,
            y: 64,
            d: 4,
            tf32: false,
        });
        let v = reg.select_step(256, 20, 20, 4, false, true).unwrap();
        assert!(!v.tf32);
    }

    #[test]
    fn displaced_selection() {
        let r = registry();
        let v = r.select_step(256, 50, 50, 3, true, false).unwrap();
        assert_eq!(v.kind, VariantKind::StepDisp);
    }

    #[test]
    fn errors_when_nothing_covers() {
        let r = registry();
        assert!(r.select_step(256, 200, 96, 3, false, false).is_err());
        assert!(r.select_step(512, 32, 32, 3, false, false).is_err());
        assert!(r.select_step(256, 32, 32, 5, false, false).is_err());
    }

    #[test]
    fn max_micro_batch_reported() {
        let r = registry();
        assert_eq!(r.max_micro_batch(3, false), Some(256));
        assert_eq!(r.max_micro_batch(7, false), None);
    }
}
