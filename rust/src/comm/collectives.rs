//! Thread-rank fabric with real data movement and virtual-clock costing.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};

use super::netmodel::{NetModel, NetPreset};
use super::TpTransport;
use crate::util::error::{Error, Result};

/// Collective rendezvous state (one "round" at a time; SPMD ordering).
struct Round {
    generation: u64,
    arrived: usize,
    /// Per-rank contribution for the current round.
    slots: Vec<Option<Vec<f32>>>,
    /// Reduced/broadcast result shared by all ranks.
    result: Option<Arc<Vec<f32>>>,
    /// Max virtual time among arrivals (collectives synchronize clocks).
    vtime_max: f64,
    /// Op tag to catch SPMD ordering bugs.
    op: &'static str,
}

struct Shared {
    p: usize,
    model: NetModel,
    round: Mutex<Round>,
    cv: Condvar,
}

/// A p2p message with the sender's virtual timestamp.
struct P2pMsg {
    data: Vec<f32>,
    sent_vtime: f64,
    tag: u64,
}

/// The fabric: create once, take one [`Endpoint`] per rank thread.
pub struct Fabric {
    shared: Arc<Shared>,
    /// `mesh[src][dst]` sender sides.
    receivers: Vec<Vec<Receiver<P2pMsg>>>,
    senders: Vec<Vec<Sender<P2pMsg>>>,
}

impl Fabric {
    pub fn new(p: usize, preset: NetPreset) -> Fabric {
        assert!(p >= 1);
        let mut senders: Vec<Vec<Sender<P2pMsg>>> = (0..p).map(|_| Vec::new()).collect();
        let mut receivers: Vec<Vec<Receiver<P2pMsg>>> = (0..p).map(|_| Vec::new()).collect();
        // receivers[dst][src], senders[src][dst]
        let mut rx_grid: Vec<Vec<Option<Receiver<P2pMsg>>>> =
            (0..p).map(|_| (0..p).map(|_| None).collect()).collect();
        for src in 0..p {
            for dst in 0..p {
                let (tx, rx) = channel();
                senders[src].push(tx);
                rx_grid[dst][src] = Some(rx);
            }
        }
        for dst in 0..p {
            for src in 0..p {
                receivers[dst].push(rx_grid[dst][src].take().unwrap());
            }
        }
        Fabric {
            shared: Arc::new(Shared {
                p,
                model: preset.model(),
                round: Mutex::new(Round {
                    generation: 0,
                    arrived: 0,
                    slots: (0..p).map(|_| None).collect(),
                    result: None,
                    vtime_max: 0.0,
                    op: "",
                }),
                cv: Condvar::new(),
            }),
            receivers,
            senders,
        }
    }

    /// Split into per-rank endpoints (consumes the fabric).
    pub fn endpoints(mut self) -> Vec<Endpoint> {
        let p = self.shared.p;
        let mut out = Vec::with_capacity(p);
        for rank in (0..p).rev() {
            let rx = self.receivers.pop().unwrap();
            out.push(Endpoint {
                rank,
                shared: self.shared.clone(),
                tx: self.senders.iter().map(|row| row[rank].clone()).collect(),
                rx_from: rx,
                pending: HashMap::new(),
                vtime: 0.0,
                comm_bytes: 0,
                collectives: 0,
                tp_tag: 0,
            });
            let _ = rank;
        }
        out.reverse();
        // Fix tx wiring: endpoint r must hold senders[r][*] (to every dst).
        for (r, ep) in out.iter_mut().enumerate() {
            ep.tx = self.senders[r].clone();
        }
        out
    }
}

/// One rank's handle: collectives, p2p, virtual clock, traffic counters.
pub struct Endpoint {
    pub rank: usize,
    shared: Arc<Shared>,
    /// tx[dst] sends to rank dst.
    tx: Vec<Sender<P2pMsg>>,
    /// rx_from[src] receives from rank src.
    rx_from: Vec<Receiver<P2pMsg>>,
    /// Out-of-order tag buffer per src.
    pending: HashMap<(usize, u64), P2pMsg>,
    /// Virtual clock (seconds on the modelled network).
    pub vtime: f64,
    /// Bytes this rank moved through the fabric.
    pub comm_bytes: u64,
    /// Number of collective operations.
    pub collectives: u64,
    /// Sequence counter for [`TpTransport`] gathers (kept out of the
    /// user-visible p2p tag space by setting the top bit).
    tp_tag: u64,
}

impl Endpoint {
    pub fn num_ranks(&self) -> usize {
        self.shared.p
    }

    /// Advance this rank's virtual clock by local work `secs` (compute, IO).
    pub fn advance(&mut self, secs: f64) {
        self.vtime += secs;
    }

    /// Generic rendezvous. `contribute` slots this rank's data; `finish`
    /// (run by the last arrival, under the lock) folds slots into a result.
    fn rendezvous<F>(&mut self, op: &'static str, data: Vec<f32>, finish: F) -> Arc<Vec<f32>>
    where
        F: FnOnce(&mut Vec<Option<Vec<f32>>>) -> Vec<f32>,
    {
        let sh = &self.shared;
        let mut r = sh.round.lock().unwrap();
        let my_gen = r.generation;
        debug_assert!(
            r.arrived == 0 || r.op == op,
            "SPMD violation: rank {} called {op} while round is {}",
            self.rank,
            r.op
        );
        r.op = op;
        r.slots[self.rank] = Some(data);
        r.vtime_max = r.vtime_max.max(self.vtime);
        r.arrived += 1;
        if r.arrived == sh.p {
            let result = finish(&mut r.slots);
            r.result = Some(Arc::new(result));
            r.generation += 1;
            r.arrived = 0;
            sh.cv.notify_all();
        } else {
            while r.generation == my_gen {
                r = sh.cv.wait(r).unwrap();
            }
        }
        let out = r.result.clone().expect("rendezvous result");
        // Collectives synchronize virtual clocks: everyone resumes at the
        // max arrival time (cost added by the caller).
        self.vtime = r.vtime_max;
        out
    }

    /// Barrier (no data, no cost beyond clock sync).
    pub fn barrier(&mut self) {
        let _ = self.rendezvous("barrier", Vec::new(), |_slots| Vec::new());
    }

    /// Broadcast `buf` from `root`; non-root buffers are overwritten.
    /// Returns modelled seconds (also applied to the clock).
    pub fn bcast(&mut self, buf: &mut Vec<f32>, root: usize) -> f64 {
        let p = self.shared.p;
        let my = if self.rank == root {
            std::mem::take(buf)
        } else {
            Vec::new()
        };
        let result = self.rendezvous("bcast", my, move |slots| {
            slots[root].take().unwrap_or_default()
        });
        *buf = (*result).clone();
        let total = (buf.len() * 4) as u64;
        let cost = self.shared.model.cost_bcast(total, p);
        self.vtime += cost;
        self.comm_bytes += total;
        self.collectives += 1;
        cost
    }

    /// In-place sum AllReduce. Returns modelled seconds.
    pub fn allreduce_sum(&mut self, buf: &mut [f32]) -> f64 {
        let p = self.shared.p;
        let n = buf.len();
        let result = self.rendezvous("allreduce", buf.to_vec(), move |slots| {
            let mut acc = vec![0.0f32; n];
            for s in slots.iter_mut() {
                if let Some(v) = s.take() {
                    for (a, b) in acc.iter_mut().zip(&v) {
                        *a += *b;
                    }
                }
            }
            acc
        });
        buf.copy_from_slice(&result);
        let bytes = (n * 4) as u64;
        let cost = self.shared.model.cost_allreduce(bytes, p);
        self.vtime += cost;
        // Ring traffic per rank ≈ 2(p−1)/p · bytes.
        self.comm_bytes += (2 * (p as u64 - 1) * bytes) / p as u64;
        self.collectives += 1;
        cost
    }

    /// In-place max AllReduce (tiny vectors: per-sample scale factors).
    pub fn allreduce_max(&mut self, buf: &mut [f32]) -> f64 {
        let p = self.shared.p;
        let n = buf.len();
        let result = self.rendezvous("allreduce_max", buf.to_vec(), move |slots| {
            let mut acc = vec![f32::NEG_INFINITY; n];
            for s in slots.iter_mut() {
                if let Some(v) = s.take() {
                    for (a, b) in acc.iter_mut().zip(&v) {
                        *a = a.max(*b);
                    }
                }
            }
            acc
        });
        buf.copy_from_slice(&result);
        let bytes = (n * 4) as u64;
        let cost = self.shared.model.cost_allreduce(bytes, p);
        self.vtime += cost;
        self.comm_bytes += (2 * (p as u64 - 1) * bytes) / p as u64;
        self.collectives += 1;
        cost
    }

    /// Sum ReduceScatter: `input` has `p` equal chunks; this rank gets the
    /// reduced chunk `rank` in `out` (`out.len() == input.len()/p`).
    pub fn reduce_scatter_sum(&mut self, input: &[f32], out: &mut [f32]) -> Result<f64> {
        let p = self.shared.p;
        let n = input.len();
        if n % p != 0 || out.len() != n / p {
            return Err(Error::Fabric(format!(
                "reduce_scatter: input {n} not divisible into {p} chunks of {}",
                out.len()
            )));
        }
        let result = self.rendezvous("reduce_scatter", input.to_vec(), move |slots| {
            let mut acc = vec![0.0f32; n];
            for s in slots.iter_mut() {
                if let Some(v) = s.take() {
                    for (a, b) in acc.iter_mut().zip(&v) {
                        *a += *b;
                    }
                }
            }
            acc
        });
        let chunk = n / p;
        out.copy_from_slice(&result[self.rank * chunk..(self.rank + 1) * chunk]);
        let bytes = (n * 4) as u64;
        let cost = self.shared.model.cost_reduce_scatter(bytes, p);
        self.vtime += cost;
        self.comm_bytes += ((p as u64 - 1) * bytes) / p as u64;
        self.collectives += 1;
        Ok(cost)
    }

    /// Non-blocking-ish send (buffered channel, like the paper's Isend).
    pub fn send(&mut self, dst: usize, tag: u64, data: Vec<f32>) -> Result<()> {
        let bytes = (data.len() * 4) as u64;
        let cost = self.shared.model.cost_p2p(bytes);
        let msg = P2pMsg {
            data,
            sent_vtime: self.vtime + cost,
            tag,
        };
        self.comm_bytes += bytes;
        self.tx[dst]
            .send(msg)
            .map_err(|_| Error::Fabric(format!("send to dead rank {dst}")))
    }

    /// Blocking receive of `tag` from `src`; out-of-order tags are buffered.
    /// The receiver's clock advances to at least the message arrival time.
    pub fn recv(&mut self, src: usize, tag: u64) -> Result<Vec<f32>> {
        if let Some(msg) = self.pending.remove(&(src, tag)) {
            self.vtime = self.vtime.max(msg.sent_vtime);
            return Ok(msg.data);
        }
        loop {
            let msg = self.rx_from[src]
                .recv()
                .map_err(|_| Error::Fabric(format!("recv from dead rank {src}")))?;
            if msg.tag == tag {
                self.vtime = self.vtime.max(msg.sent_vtime);
                return Ok(msg.data);
            }
            self.pending.insert((src, msg.tag), msg);
        }
    }
}

/// The simulated fabric speaking the TP transport contract, so perfmodel
/// runs exercise exactly the collective sequence the socket data plane
/// uses (see `comm::socket`). Costing still applies: bcast through the
/// modelled tree, gathers as p2p sends into rank order.
impl TpTransport for Endpoint {
    fn rank(&self) -> usize {
        self.rank
    }

    fn num_ranks(&self) -> usize {
        self.shared.p
    }

    fn bcast(&mut self, _op: u8, data: &mut Vec<f32>, root: usize) -> Result<u64> {
        Endpoint::bcast(self, data, root);
        Ok((data.len() * 4) as u64)
    }

    fn gather(&mut self, _op: u8, mine: &[f32], out: &mut Vec<f32>, root: usize) -> Result<u64> {
        let tag = (1u64 << 63) | self.tp_tag;
        self.tp_tag += 1;
        if self.rank == root {
            out.clear();
            let mut moved = 0u64;
            // Ascending rank order — the same deterministic assembly rule
            // as the socket transport.
            for src in 0..self.shared.p {
                if src == self.rank {
                    out.extend_from_slice(mine);
                } else {
                    let v = self.recv(src, tag)?;
                    moved += (v.len() * 4) as u64;
                    out.extend_from_slice(&v);
                }
            }
            Ok(moved)
        } else {
            self.send(root, tag, mine.to_vec())?;
            Ok((mine.len() * 4) as u64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_ranks<F>(p: usize, preset: NetPreset, f: F) -> Vec<Endpoint>
    where
        F: Fn(&mut Endpoint) + Send + Sync + Copy,
    {
        let eps = Fabric::new(p, preset).endpoints();
        std::thread::scope(|s| {
            let handles: Vec<_> = eps
                .into_iter()
                .map(|mut ep| {
                    s.spawn(move || {
                        f(&mut ep);
                        ep
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    #[test]
    fn allreduce_sums_across_ranks() {
        let eps = run_ranks(4, NetPreset::Ideal, |ep| {
            let mut buf = vec![ep.rank as f32 + 1.0; 8];
            ep.allreduce_sum(&mut buf);
            assert!(buf.iter().all(|&x| x == 10.0)); // 1+2+3+4
        });
        assert!(eps.iter().all(|e| e.collectives == 1));
    }

    #[test]
    fn bcast_delivers_root_data() {
        run_ranks(3, NetPreset::Ideal, |ep| {
            let mut buf = if ep.rank == 1 {
                vec![3.5f32, -1.0, 2.0]
            } else {
                vec![0.0; 3]
            };
            ep.bcast(&mut buf, 1);
            assert_eq!(buf, vec![3.5, -1.0, 2.0]);
        });
    }

    #[test]
    fn reduce_scatter_gives_own_chunk() {
        run_ranks(4, NetPreset::Ideal, |ep| {
            // input chunk c of rank r = r+c (so reduced chunk c = Σ_r r+c·p...).
            let input: Vec<f32> = (0..8).map(|i| (ep.rank * 8 + i) as f32).collect();
            let mut out = vec![0.0f32; 2];
            ep.reduce_scatter_sum(&input, &mut out).unwrap();
            // Reduced full vector: Σ_r (8r + i) = 48 + 4i.
            let want: Vec<f32> = (0..2)
                .map(|k| 48.0 + 4.0 * (ep.rank * 2 + k) as f32)
                .collect();
            assert_eq!(out, want, "rank {}", ep.rank);
        });
    }

    #[test]
    fn reduce_scatter_shape_checked() {
        run_ranks(2, NetPreset::Ideal, |ep| {
            let input = vec![0.0f32; 3]; // not divisible by 2
            let mut out = vec![0.0f32; 1];
            if ep.rank == 0 {
                // Only check on one rank to keep SPMD round counts equal:
                // shape errors are caught before the rendezvous.
                assert!(ep.reduce_scatter_sum(&input, &mut out).is_err());
            } else {
                assert!(ep
                    .reduce_scatter_sum(&vec![0.0f32; 3], &mut vec![0.0f32; 1])
                    .is_err());
            }
        });
    }

    #[test]
    fn p2p_roundtrip_with_tags() {
        run_ranks(2, NetPreset::Ideal, |ep| {
            if ep.rank == 0 {
                ep.send(1, 7, vec![1.0, 2.0]).unwrap();
                ep.send(1, 8, vec![3.0]).unwrap();
            } else {
                // Receive out of order: tag 8 first.
                let b = ep.recv(0, 8).unwrap();
                assert_eq!(b, vec![3.0]);
                let a = ep.recv(0, 7).unwrap();
                assert_eq!(a, vec![1.0, 2.0]);
            }
        });
    }

    #[test]
    fn vtime_advances_with_costs() {
        let eps = run_ranks(4, NetPreset::Pcie4, |ep| {
            ep.advance(1.0);
            let mut buf = vec![0.0f32; 1 << 20];
            ep.allreduce_sum(&mut buf);
        });
        let m = NetPreset::Pcie4.model();
        let want = 1.0 + m.cost_allreduce(4 << 20, 4);
        for e in &eps {
            assert!((e.vtime - want).abs() < 1e-9, "vtime {}", e.vtime);
        }
    }

    #[test]
    fn collective_synchronizes_straggler_clock() {
        let eps = run_ranks(2, NetPreset::Ideal, |ep| {
            if ep.rank == 0 {
                ep.advance(5.0);
            }
            ep.barrier();
        });
        for e in &eps {
            assert!(e.vtime >= 5.0, "clock must sync to the straggler");
        }
    }

    #[test]
    fn many_rounds_in_sequence() {
        run_ranks(3, NetPreset::Ideal, |ep| {
            for round in 0..50 {
                let mut buf = vec![ep.rank as f32 + round as f32; 4];
                ep.allreduce_sum(&mut buf);
                let want = 3.0 * round as f32 + 3.0;
                assert!(buf.iter().all(|&x| (x - want).abs() < 1e-6));
            }
        });
    }

    #[test]
    fn single_rank_fabric_works() {
        run_ranks(1, NetPreset::NvLink3, |ep| {
            let mut buf = vec![2.0f32; 4];
            ep.allreduce_sum(&mut buf);
            assert_eq!(buf, vec![2.0; 4]);
            ep.barrier();
        });
    }
}
