//! Real-socket TP collectives: the data plane of a cross-backend TP group.
//!
//! A TP group is a **hub**: rank 0 (the leader, the backend the router
//! placed the job on) holds one [`TpLink`] per follower; followers hold a
//! single link back to the leader. All per-site collectives are rooted at
//! rank 0 — broadcast the lifted environment out, gather the partial
//! contractions back — so the hub shape is exactly the traffic pattern and
//! nothing is lost over a full mesh.
//!
//! [`TpLink`] abstracts one ordered, reliable byte pipe carrying TP
//! messages (`op`, `seq`, raw f32s). The production impl frames them over
//! an FMPN socket (`net/tp`); tests use in-memory channels. Every
//! collective bumps a per-group sequence number and the receiving side
//! checks both `op` and `seq`, so a desynchronised group fails with a
//! typed error instead of silently reducing the wrong site's data.

use super::TpTransport;
use crate::util::error::{Error, Result};

/// TP op: environment row-block broadcast, leader → followers.
pub const TP_ENV: u8 = 1;
/// TP op: partial contraction (shard-local temp), follower → leader.
pub const TP_PART: u8 = 2;
/// TP op: measurement outcomes broadcast from rank 0.
pub const TP_OUTCOME: u8 = 3;
/// TP op: job end (empty payload); followers release the group.
pub const TP_DONE: u8 = 4;

/// Human name of a TP op byte (error messages, trace spans).
pub fn tp_op_name(op: u8) -> &'static str {
    match op {
        TP_ENV => "tp_env",
        TP_PART => "tp_part",
        TP_OUTCOME => "tp_outcome",
        TP_DONE => "tp_done",
        _ => "tp_unknown",
    }
}

/// One ordered, reliable pipe to a single TP peer.
pub trait TpLink: Send {
    /// Send one TP message. Returns payload bytes written.
    fn send(&mut self, op: u8, seq: u64, data: &[f32]) -> Result<u64>;
    /// Receive one TP message, which must carry exactly (`op`, `seq`) —
    /// anything else is a desync and a typed error. Appends the payload
    /// to `out` and returns payload bytes read.
    fn recv_into(&mut self, op: u8, seq: u64, out: &mut Vec<f32>) -> Result<u64>;
    /// Confirm the peer released the group after [`TP_DONE`]. The FMPN
    /// link reads the follower's final control acknowledgement here, so a
    /// leader can distinguish "group wound down cleanly" from "the socket
    /// just closed"; in-memory links have nothing to confirm.
    fn finish(&mut self) -> Result<()> {
        Ok(())
    }
}

/// [`TpTransport`] over per-peer [`TpLink`]s (hub topology, root = 0).
pub struct SocketComm {
    rank: usize,
    /// `links[peer]` is the pipe to `peer`; `None` at `links[rank]` and,
    /// on followers, at every slot except the leader's.
    links: Vec<Option<Box<dyn TpLink>>>,
    /// Collective sequence number; both sides advance in lockstep.
    seq: u64,
}

impl SocketComm {
    /// Build a group member. `links.len()` is the group size; the slot for
    /// `rank` itself must be `None`.
    pub fn new(rank: usize, links: Vec<Option<Box<dyn TpLink>>>) -> Result<SocketComm> {
        if rank >= links.len() {
            return Err(Error::Fabric(format!(
                "TP rank {rank} outside group of {}",
                links.len()
            )));
        }
        if links[rank].is_some() {
            return Err(Error::Fabric(format!("TP rank {rank} has a link to itself")));
        }
        Ok(SocketComm { rank, links, seq: 0 })
    }

    fn link(&mut self, peer: usize) -> Result<&mut Box<dyn TpLink>> {
        self.links
            .get_mut(peer)
            .and_then(|l| l.as_mut())
            .ok_or_else(|| Error::Fabric(format!("no link to TP rank {peer}")))
    }

    /// Leader-side teardown: after broadcasting [`TP_DONE`], collect every
    /// peer's release confirmation (see [`TpLink::finish`]).
    pub fn finish(&mut self) -> Result<()> {
        for l in self.links.iter_mut().flatten() {
            l.finish()?;
        }
        Ok(())
    }
}

impl TpTransport for SocketComm {
    fn rank(&self) -> usize {
        self.rank
    }

    fn num_ranks(&self) -> usize {
        self.links.len()
    }

    fn bcast(&mut self, op: u8, data: &mut Vec<f32>, root: usize) -> Result<u64> {
        self.seq += 1;
        let seq = self.seq;
        if self.rank == root {
            let mut moved = 0u64;
            for peer in 0..self.links.len() {
                if peer == self.rank {
                    continue;
                }
                moved += self.link(peer)?.send(op, seq, data)?;
            }
            Ok(moved)
        } else {
            data.clear();
            self.link(root)?.recv_into(op, seq, data)
        }
    }

    fn gather(&mut self, op: u8, mine: &[f32], out: &mut Vec<f32>, root: usize) -> Result<u64> {
        self.seq += 1;
        let seq = self.seq;
        if self.rank == root {
            out.clear();
            let mut moved = 0u64;
            // Ascending rank order: the concatenation is deterministic no
            // matter when each peer's bytes actually arrive.
            for src in 0..self.links.len() {
                if src == self.rank {
                    out.extend_from_slice(mine);
                } else {
                    moved += self.link(src)?.recv_into(op, seq, out)?;
                }
            }
            Ok(moved)
        } else {
            self.link(root)?.send(op, seq, mine)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{Fabric, NetPreset};
    use std::sync::mpsc::{channel, Receiver, Sender};

    /// In-memory [`TpLink`]: one channel pair, like a loopback socket.
    struct ChanLink {
        tx: Sender<(u8, u64, Vec<f32>)>,
        rx: Receiver<(u8, u64, Vec<f32>)>,
    }

    impl TpLink for ChanLink {
        fn send(&mut self, op: u8, seq: u64, data: &[f32]) -> Result<u64> {
            self.tx
                .send((op, seq, data.to_vec()))
                .map_err(|_| Error::Fabric("TP peer hung up".into()))?;
            Ok((data.len() * 4) as u64)
        }

        fn recv_into(&mut self, op: u8, seq: u64, out: &mut Vec<f32>) -> Result<u64> {
            let (got_op, got_seq, data) = self
                .rx
                .recv()
                .map_err(|_| Error::Fabric("TP peer hung up mid-collective".into()))?;
            if (got_op, got_seq) != (op, seq) {
                return Err(Error::Fabric(format!(
                    "TP desync: expected {} seq {seq}, got {} seq {got_seq}",
                    tp_op_name(op),
                    tp_op_name(got_op)
                )));
            }
            out.extend_from_slice(&data);
            Ok((data.len() * 4) as u64)
        }
    }

    /// Hub-wire a group of `n`: member 0 gets a link per follower,
    /// followers get one link to member 0.
    fn hub_group(n: usize) -> Vec<SocketComm> {
        let mut leader_links: Vec<Option<Box<dyn TpLink>>> = vec![None];
        let mut followers = Vec::new();
        for rank in 1..n {
            let (to_f, from_l) = channel();
            let (to_l, from_f) = channel();
            leader_links.push(Some(Box::new(ChanLink { tx: to_f, rx: from_f }) as Box<dyn TpLink>));
            let mut links: Vec<Option<Box<dyn TpLink>>> = (0..n).map(|_| None).collect();
            links[0] = Some(Box::new(ChanLink { tx: to_l, rx: from_l }));
            followers.push(SocketComm::new(rank, links).unwrap());
        }
        let mut group = vec![SocketComm::new(0, leader_links).unwrap()];
        group.extend(followers);
        group
    }

    /// The scripted per-site exchange both transports must agree on:
    /// bcast an env from rank 0, every rank contributes a shard-local
    /// partial, gather to rank 0. Returns the gathered buffer (root only).
    fn run_script<T: TpTransport>(t: &mut T) -> Vec<f32> {
        let mut env = if t.rank() == 0 {
            vec![1.0f32, -2.0, 0.5]
        } else {
            Vec::new()
        };
        t.bcast(TP_ENV, &mut env, 0).unwrap();
        assert_eq!(env, vec![1.0, -2.0, 0.5], "rank {}", t.rank());
        let scale = (t.rank() + 1) as f32;
        let mine: Vec<f32> = env.iter().map(|x| x * scale).collect();
        let mut gathered = Vec::new();
        t.gather(TP_PART, &mine, &mut gathered, 0).unwrap();
        gathered
    }

    #[test]
    fn gather_appends_in_ascending_rank_order() {
        let mut group = hub_group(3);
        let followers = group.split_off(1);
        let handles: Vec<_> = followers
            .into_iter()
            .map(|mut f| std::thread::spawn(move || run_script(&mut f)))
            .collect();
        let gathered = run_script(&mut group[0]);
        for h in handles {
            h.join().unwrap();
        }
        // rank 0's shard, then rank 1's, then rank 2's — always.
        let want = vec![1.0, -2.0, 0.5, 2.0, -4.0, 1.0, 3.0, -6.0, 1.5];
        assert_eq!(gathered, want);
    }

    #[test]
    fn socket_and_sim_transports_agree() {
        // Simulated fabric ranks…
        let eps = Fabric::new(3, NetPreset::Ideal).endpoints();
        let sim = std::thread::scope(|s| {
            let handles: Vec<_> = eps
                .into_iter()
                .map(|mut ep| s.spawn(move || run_script(&mut ep)))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().unwrap())
                .next()
                .unwrap()
        });
        // …and socket ranks produce bit-identical gathers.
        let mut group = hub_group(3);
        let followers = group.split_off(1);
        let handles: Vec<_> = followers
            .into_iter()
            .map(|mut f| std::thread::spawn(move || run_script(&mut f)))
            .collect();
        let socket = run_script(&mut group[0]);
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(sim, socket, "sim and socket transports drifted apart");
    }

    #[test]
    fn member_drop_mid_reduce_is_a_typed_error() {
        let mut group = hub_group(3);
        let mut followers = group.split_off(1);
        let dead = followers.pop().unwrap(); // rank 2
        let good = followers.pop().unwrap(); // rank 1
        let h1 = std::thread::spawn(move || {
            let mut f = good;
            let mut env = Vec::new();
            f.bcast(TP_ENV, &mut env, 0).unwrap();
            f.gather(TP_PART, &[7.0], &mut Vec::new(), 0).unwrap();
        });
        let h2 = std::thread::spawn(move || {
            let mut f = dead;
            let mut env = Vec::new();
            f.bcast(TP_ENV, &mut env, 0).unwrap();
            // …and dies before contributing its partial.
            drop(f);
        });
        let leader = &mut group[0];
        let mut env = vec![1.0f32];
        leader.bcast(TP_ENV, &mut env, 0).unwrap();
        let mut out = Vec::new();
        let e = leader
            .gather(TP_PART, &[0.5], &mut out, 0)
            .unwrap_err()
            .to_string();
        assert!(e.contains("hung up"), "typed member-drop error: {e}");
        h1.join().unwrap();
        h2.join().unwrap();
    }

    #[test]
    fn desync_and_bad_wiring_are_typed_errors() {
        // Peer speaking the wrong op for this seq.
        let (tx, rx) = channel();
        let (tx2, _rx2) = channel();
        let mut link = ChanLink { tx: tx2, rx };
        tx.send((TP_OUTCOME, 1, vec![1.0])).unwrap();
        let e = link
            .recv_into(TP_ENV, 1, &mut Vec::new())
            .unwrap_err()
            .to_string();
        assert!(e.contains("desync"), "{e}");
        assert!(e.contains("tp_env") && e.contains("tp_outcome"), "{e}");

        // Constructor rejects malformed groups.
        assert!(SocketComm::new(2, vec![None, None]).is_err(), "rank ≥ size");
        let self_link: Vec<Option<Box<dyn TpLink>>> =
            vec![Some(Box::new(ChanLink { tx, rx: channel().1 }))];
        assert!(SocketComm::new(0, self_link).is_err(), "self link");

        // A follower asked to talk to a rank it has no pipe to.
        let mut lonely = SocketComm::new(1, vec![None, None, None]).unwrap();
        let e = lonely
            .gather(TP_PART, &[1.0], &mut Vec::new(), 0)
            .unwrap_err()
            .to_string();
        assert!(e.contains("no link"), "{e}");
    }

    #[test]
    fn op_names_cover_the_family() {
        assert_eq!(tp_op_name(TP_ENV), "tp_env");
        assert_eq!(tp_op_name(TP_PART), "tp_part");
        assert_eq!(tp_op_name(TP_OUTCOME), "tp_outcome");
        assert_eq!(tp_op_name(TP_DONE), "tp_done");
        assert_eq!(tp_op_name(0x7f), "tp_unknown");
    }
}
