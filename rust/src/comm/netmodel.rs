//! Network cost model and presets.
//!
//! Collective costs use the standard ring α–β forms; the AllReduce and
//! ReduceScatter *effective bandwidths* are separate knobs because the paper
//! measures them separately (§4.3: `B_a = 401 GB/s`, `B_r ≈ 46 GB/s` on
//! 4×A100 NVLink3 — the asymmetry that decides double- vs single-site).

/// Effective-bandwidth/latency model of one interconnect.
#[derive(Debug, Clone, Copy)]
pub struct NetModel {
    /// Effective AllReduce bandwidth (B/s) — paper's `B_a`.
    pub bw_allreduce: f64,
    /// Effective ReduceScatter bandwidth (B/s) — paper's `B_r`.
    pub bw_reduce_scatter: f64,
    /// Broadcast bandwidth (B/s).
    pub bw_bcast: f64,
    /// Point-to-point bandwidth (B/s).
    pub bw_p2p: f64,
    /// Per-message latency (s).
    pub latency: f64,
}

/// Named presets (paper-measured or vendor figures).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetPreset {
    /// 4×A100, 3rd-gen NVLink: the paper's measured B_a=401 GB/s,
    /// B_r≈46 GB/s.
    NvLink3,
    /// PCIe 4.0 x16 peer-to-peer: high latency, ~24 GB/s.
    Pcie4,
    /// HDR Infiniband (200 Gb/s) between nodes.
    InfinibandHdr,
    /// Tianhe-3 proprietary interconnect (per the paper's CPU scaling).
    Tianhe3,
    /// Sunway TaihuLight network.
    Sunway,
    /// Instantaneous network (isolates compute in tests).
    Ideal,
}

impl NetPreset {
    pub fn model(self) -> NetModel {
        match self {
            NetPreset::NvLink3 => NetModel {
                bw_allreduce: 401e9,
                bw_reduce_scatter: 46e9,
                bw_bcast: 250e9,
                bw_p2p: 250e9,
                latency: 5e-6,
            },
            NetPreset::Pcie4 => NetModel {
                bw_allreduce: 20e9,
                bw_reduce_scatter: 16e9,
                bw_bcast: 24e9,
                bw_p2p: 24e9,
                latency: 15e-6,
            },
            NetPreset::InfinibandHdr => NetModel {
                bw_allreduce: 24e9,
                bw_reduce_scatter: 22e9,
                bw_bcast: 25e9,
                bw_p2p: 25e9,
                latency: 2e-6,
            },
            NetPreset::Tianhe3 => NetModel {
                bw_allreduce: 11e9,
                bw_reduce_scatter: 10e9,
                bw_bcast: 12e9,
                bw_p2p: 12e9,
                latency: 3e-6,
            },
            NetPreset::Sunway => NetModel {
                bw_allreduce: 5.5e9,
                bw_reduce_scatter: 5e9,
                bw_bcast: 6e9,
                bw_p2p: 6e9,
                latency: 4e-6,
            },
            NetPreset::Ideal => NetModel {
                bw_allreduce: f64::INFINITY,
                bw_reduce_scatter: f64::INFINITY,
                bw_bcast: f64::INFINITY,
                bw_p2p: f64::INFINITY,
                latency: 0.0,
            },
        }
    }

    pub fn parse(s: &str) -> Option<NetPreset> {
        match s {
            "nvlink3" => Some(NetPreset::NvLink3),
            "pcie4" => Some(NetPreset::Pcie4),
            "ib" | "infiniband" => Some(NetPreset::InfinibandHdr),
            "tianhe3" | "th3" => Some(NetPreset::Tianhe3),
            "sunway" => Some(NetPreset::Sunway),
            "ideal" => Some(NetPreset::Ideal),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            NetPreset::NvLink3 => "nvlink3",
            NetPreset::Pcie4 => "pcie4",
            NetPreset::InfinibandHdr => "ib",
            NetPreset::Tianhe3 => "tianhe3",
            NetPreset::Sunway => "sunway",
            NetPreset::Ideal => "ideal",
        }
    }
}

impl NetModel {
    /// Ring AllReduce: 2·(p−1)/p · bytes / B_a + 2(p−1)·α.
    pub fn cost_allreduce(&self, bytes: u64, p: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        let pf = p as f64;
        2.0 * (pf - 1.0) / pf * bytes as f64 / self.bw_allreduce
            + 2.0 * (pf - 1.0) * self.latency
    }

    /// Ring ReduceScatter: (p−1)/p · bytes / B_r + (p−1)·α.
    /// `bytes` is the *full input* size (each rank keeps bytes/p).
    pub fn cost_reduce_scatter(&self, bytes: u64, p: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        let pf = p as f64;
        (pf - 1.0) / pf * bytes as f64 / self.bw_reduce_scatter + (pf - 1.0) * self.latency
    }

    /// Pipelined broadcast: bytes/B + log₂(p)·α.
    pub fn cost_bcast(&self, bytes: u64, p: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        bytes as f64 / self.bw_bcast + (p as f64).log2().ceil() * self.latency
    }

    /// Point-to-point: bytes/B + α.
    pub fn cost_p2p(&self, bytes: u64) -> f64 {
        bytes as f64 / self.bw_p2p + self.latency
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_rank_collectives_free() {
        let m = NetPreset::NvLink3.model();
        assert_eq!(m.cost_allreduce(1 << 30, 1), 0.0);
        assert_eq!(m.cost_reduce_scatter(1 << 30, 1), 0.0);
        assert_eq!(m.cost_bcast(1 << 30, 1), 0.0);
    }

    #[test]
    fn paper_bandwidth_asymmetry() {
        // With the paper's measured B_a ≫ B_r, AllReduce of the same buffer
        // is *cheaper* than ReduceScatter on NVLink3 at 4 ranks for large
        // messages — the basis of the double-site choice (§4.3).
        let m = NetPreset::NvLink3.model();
        let bytes = 256u64 << 20;
        assert!(m.cost_allreduce(bytes, 4) < m.cost_reduce_scatter(bytes, 4));
        // On a symmetric network the usual ordering holds.
        let ib = NetPreset::InfinibandHdr.model();
        assert!(ib.cost_allreduce(bytes, 4) > ib.cost_reduce_scatter(bytes, 4));
    }

    #[test]
    fn costs_scale_with_bytes_and_ranks() {
        let m = NetPreset::Pcie4.model();
        assert!(m.cost_allreduce(2 << 20, 4) > m.cost_allreduce(1 << 20, 4));
        assert!(m.cost_allreduce(1 << 20, 8) > m.cost_allreduce(1 << 20, 2));
        assert!(m.cost_p2p(1 << 20) > m.cost_p2p(0));
    }

    #[test]
    fn ideal_network_is_free() {
        let m = NetPreset::Ideal.model();
        assert_eq!(m.cost_allreduce(1 << 30, 64), 0.0);
        assert_eq!(m.cost_p2p(1 << 30), 0.0);
    }

    #[test]
    fn preset_parsing() {
        assert_eq!(NetPreset::parse("nvlink3"), Some(NetPreset::NvLink3));
        assert_eq!(NetPreset::parse("bogus"), None);
        for p in [
            NetPreset::NvLink3,
            NetPreset::Pcie4,
            NetPreset::InfinibandHdr,
            NetPreset::Tianhe3,
            NetPreset::Sunway,
            NetPreset::Ideal,
        ] {
            assert_eq!(NetPreset::parse(p.name()), Some(p));
        }
    }
}
