//! Communication fabrics: the simulated cost-model net and the real one.
//!
//! The paper's scaling studies run on MPI over NVLink/Infiniband/Sunway
//! networks; this testbed has neither MPI nor multiple nodes, so ranks are
//! **threads** exchanging real data through shared memory while a **cost
//! model** advances a per-rank *virtual clock* by what each operation would
//! cost on the modelled network (ring-algorithm α–β costs, with the paper's
//! measured AllReduce/ReduceScatter bandwidths as presets). Correctness is
//! real (actual bytes move); performance curves (Figs. 12/13) are read off
//! the virtual clocks; wall-clock numbers remain available for the
//! CPU-scaled head-to-head tables.
//!
//! Since the fleet grew a real data plane (FMPN), tensor-parallel groups
//! also run over **real sockets**: [`SocketComm`] speaks the TP op family
//! of `net/frame` between backends. Both the simulated [`Endpoint`] and
//! [`SocketComm`] implement [`TpTransport`], so the perfmodel's predictions
//! and the production collectives share one interface and cannot drift
//! apart silently. See `docs/TENSOR_PARALLEL.md` for the group contract.
//!
//! SPMD contract: all ranks of a fabric call the same collectives in the
//! same order (checked with an op-tag assertion in debug builds; enforced
//! with sequence numbers on the socket path).

mod collectives;
mod netmodel;
mod socket;

pub use collectives::{Endpoint, Fabric};
pub use netmodel::{NetModel, NetPreset};
pub use socket::{tp_op_name, SocketComm, TpLink, TP_DONE, TP_ENV, TP_OUTCOME, TP_PART};

use crate::util::error::Result;

/// The narrow collective interface the tensor-parallel sampling driver
/// needs — implemented by both the simulated [`Endpoint`] (thread ranks,
/// virtual-clock costing via `netmodel`) and the real-socket
/// [`SocketComm`], so simulation and production share one contract.
///
/// Both collectives are **deterministic**: `gather` appends contributions
/// in ascending rank order regardless of arrival timing, which is what
/// makes the sharded sampling step bit-identical to the serial kernel
/// (see `docs/TENSOR_PARALLEL.md` § Bit identity).
pub trait TpTransport {
    /// This rank's position in the group (`0` = leader).
    fn rank(&self) -> usize;
    /// Group size.
    fn num_ranks(&self) -> usize;
    /// Broadcast `data` from `root`; non-root buffers are replaced.
    /// `op` tags the message on the wire (ignored by the simulator).
    /// Returns the payload bytes this rank moved.
    fn bcast(&mut self, op: u8, data: &mut Vec<f32>, root: usize) -> Result<u64>;
    /// Gather every rank's `mine` to `root`, appended in ascending rank
    /// order. On `root`, `out` is cleared first; on other ranks it is
    /// untouched. Returns the payload bytes this rank moved.
    fn gather(&mut self, op: u8, mine: &[f32], out: &mut Vec<f32>, root: usize) -> Result<u64>;
}
