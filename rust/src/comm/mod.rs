//! Simulated communication fabric.
//!
//! The paper's scaling studies run on MPI over NVLink/Infiniband/Sunway
//! networks; this testbed has neither MPI nor multiple nodes, so ranks are
//! **threads** exchanging real data through shared memory while a **cost
//! model** advances a per-rank *virtual clock* by what each operation would
//! cost on the modelled network (ring-algorithm α–β costs, with the paper's
//! measured AllReduce/ReduceScatter bandwidths as presets). Correctness is
//! real (actual bytes move); performance curves (Figs. 12/13) are read off
//! the virtual clocks; wall-clock numbers remain available for the
//! CPU-scaled head-to-head tables.
//!
//! SPMD contract: all ranks of a fabric call the same collectives in the
//! same order (checked with an op-tag assertion in debug builds).

mod collectives;
mod netmodel;

pub use collectives::{Endpoint, Fabric};
pub use netmodel::{NetModel, NetPreset};
