//! Counters, timers and FLOP/byte accounting.
//!
//! Every coordinator records a [`Metrics`] snapshot: wall time per phase,
//! FLOPs executed, bytes moved by I/O / host copies / fabric traffic, and
//! derived quantities (achieved FLOP/s, computation-to-communication ratio —
//! the paper's CCR analysis in §2.2) for EXPERIMENTS.md and the bench
//! harnesses.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::util::json::Json;

/// Accumulating phase timer + counters. Not thread-safe by design — each
/// worker owns one and they are merged at the end (`merge`).
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    /// Seconds per named phase (wall).
    pub phases: BTreeMap<String, f64>,
    /// Monotonic counters (flops, io_bytes, comm_bytes, samples, ...).
    pub counters: BTreeMap<String, u64>,
    /// Log-bucketed duration histograms (queue wait, batch formation,
    /// net frame RTT, push chunk timings — see [`HistogramStats`]).
    pub hists: BTreeMap<String, HistogramStats>,
}

/// Standard counter names.
pub mod keys {
    pub const FLOPS: &str = "flops";
    pub const IO_BYTES: &str = "io_bytes";
    pub const COMM_BYTES: &str = "comm_bytes";
    pub const HOST_COPY_BYTES: &str = "host_copy_bytes";
    pub const SAMPLES: &str = "samples";
    pub const SITES: &str = "sites";
    pub const MICRO_BATCHES: &str = "micro_batches";
    pub const MACRO_BATCHES: &str = "macro_batches";
    pub const IO_OPS: &str = "io_ops";
    pub const COLLECTIVES: &str = "collectives";
    pub const STEPS_SKIPPED: &str = "steps_skipped"; // dynamic-χ fast path

    // Hot-path step counters (`sampler::native`).
    /// Engine step invocations (one per micro batch per site).
    pub const STEPS: &str = "steps";
    /// Workspace buffer growth events. After warm-up this stops moving —
    /// `step_ws_grows / steps` is the engine's allocs-per-step KPI and its
    /// steady state is 0 (see docs/PERF.md).
    pub const STEP_WS_GROWS: &str = "step_ws_grows";
    /// Γ precision conversions performed (PreparedSite constructions).
    pub const STEP_PREP_CONVERSIONS: &str = "step_prep_conversions";
    /// Steps served from an already-prepared Γ (no conversion, no clone).
    pub const STEP_PREP_HITS: &str = "step_prep_hits";
    /// Steps executed through the planar (split re/im) kernel path.
    pub const STEP_LAYOUT_PLANAR: &str = "step_layout_planar";
    /// Resident worker-pool wakeups (one per worker per dispatch).
    pub const POOL_WAKEUPS: &str = "pool_wakeups";
    /// Nanoseconds pool workers spent parked between dispatches.
    pub const POOL_PARK_NS: &str = "pool_park_ns";

    // Service-layer counters (`service::*`).
    pub const JOBS_SUBMITTED: &str = "jobs_submitted";
    pub const JOBS_REJECTED: &str = "jobs_rejected";
    pub const JOBS_COMPLETED: &str = "jobs_completed";
    pub const JOBS_FAILED: &str = "jobs_failed";
    pub const CACHE_HITS: &str = "cache_hits";
    pub const CACHE_MISSES: &str = "cache_misses";
    pub const SERVICE_BATCHES: &str = "service_batches";
    pub const BATCH_ROWS: &str = "batch_rows";
    /// Σ over dispatched batches of their row targets — occupancy is
    /// `batch_rows / batch_target_rows`.
    pub const BATCH_TARGET_ROWS: &str = "batch_target_rows";
    /// High-water mark of the job queue (gauge via [`Metrics::set_max`]).
    pub const QUEUE_PEAK: &str = "queue_peak";

    // Net-transport counters (`net::server`).
    pub const NET_BYTES_IN: &str = "net_bytes_in";
    pub const NET_BYTES_OUT: &str = "net_bytes_out";
    pub const NET_FRAMES_IN: &str = "net_frames_in";
    pub const NET_FRAMES_OUT: &str = "net_frames_out";
    /// Connections accepted over the server's lifetime.
    pub const NET_CONNS: &str = "net_conns";
    /// High-water mark of concurrent connections (gauge).
    pub const NET_CONN_PEAK: &str = "net_conn_peak";
    /// Connections turned away at the `max_conns` pool bound.
    pub const NET_REJECTS_CONN: &str = "net_rejects_conn";
    /// Submissions rejected with a typed `busy` frame (admission full).
    pub const NET_REJECTS_BUSY: &str = "net_rejects_busy";
    /// Stores installed through the chunked-push path (`push_begin`).
    pub const NET_PUSHES: &str = "net_pushes";
    /// Raw (decompressed) bytes landed by completed pushes.
    pub const NET_PUSH_BYTES: &str = "net_push_bytes";
    /// `push_begin` requests answered by dedup (store already present).
    pub const NET_PUSH_DEDUPS: &str = "net_push_dedups";
    /// Pushes aborted mid-transfer (disconnect, stall, checksum mismatch);
    /// each one left *no* partial store behind.
    pub const NET_PUSH_ABORTS: &str = "net_push_aborts";

    // Routing-tier counters (`router::gateway`).
    /// Jobs the router placed on a backend.
    pub const ROUTER_SUBMITS: &str = "router_submits";
    /// Jobs that landed on a backend other than their rendezvous-first
    /// choice (that backend was `Busy`, unhealthy, or unreachable).
    pub const ROUTER_SPILLOVERS: &str = "router_spillovers";
    /// Submits that exhausted the retry budget (the client saw `busy`).
    pub const ROUTER_BUSY_REJECTS: &str = "router_busy_rejects";
    /// Forwarded RPCs that failed at the transport level.
    pub const ROUTER_FORWARD_ERRORS: &str = "router_forward_errors";
    /// Non-submit ops (status/wait/cancel/list) forwarded to backends.
    pub const ROUTER_FORWARDS: &str = "router_forwards";
    /// Health probes issued / failed.
    pub const ROUTER_PROBES: &str = "router_probes";
    pub const ROUTER_PROBE_FAILURES: &str = "router_probe_failures";
    /// In-flight jobs the drain gave up on (backend unreachable); a clean
    /// drain leaves this at 0.
    pub const ROUTER_DROPPED_JOBS: &str = "router_dropped_jobs";
    /// Store pushes proxied through the router to a completed upload.
    pub const ROUTER_PUSHES: &str = "router_pushes";
    /// `push_begin` requests a backend answered by dedup (mirrors the
    /// server-side `net_pushes` / `net_push_dedups` split).
    pub const ROUTER_PUSH_DEDUPS: &str = "router_push_dedups";
    /// Proxied pushes that failed mid-stream (backend lost); the client
    /// saw a typed `busy` and can retry against the next-ranked backend.
    pub const ROUTER_PUSH_FAILURES: &str = "router_push_failures";
    /// Tensor-parallel submits the router resolved into a placed group.
    pub const ROUTER_TP_SUBMITS: &str = "router_tp_submits";
    /// TP submits refused with a typed error (incomplete shard group, a
    /// member down or draining, or a non-f32 compute request).
    pub const ROUTER_TP_REJECTS: &str = "router_tp_rejects";
    /// Completed shard pushes recorded into the router's shard map.
    pub const ROUTER_SHARD_PUSHES: &str = "router_shard_pushes";

    // Tensor-parallel data plane (`net::tp`, docs/TENSOR_PARALLEL.md).
    /// TP jobs this backend took part in (leader or follower).
    pub const TP_JOBS: &str = "tp_jobs";
    /// Payload bytes this backend moved in TP broadcasts (env chunks out
    /// on the leader / in on followers, plus outcome broadcasts).
    pub const TP_BCAST_BYTES: &str = "tp_bcast_bytes";
    /// Payload bytes this backend moved gathering shard partials.
    pub const TP_REDUCE_BYTES: &str = "tp_reduce_bytes";
    /// TP collectives that failed on a lost or desynchronised member.
    pub const TP_MEMBER_FAILURES: &str = "tp_member_failures";

    // Histogram names (`Metrics::observe`, [`super::HistogramStats`]).
    /// Admission → first batch assignment, per job.
    pub const HIST_QUEUE_WAIT: &str = "queue_wait_secs";
    /// Batch-anchor arrival → dispatch (linger + slicing), per batch.
    pub const HIST_BATCH_FORM: &str = "batch_form_secs";
    /// Client-observed control-frame round-trip time (surfaced through
    /// the router for its backend connections).
    pub const HIST_NET_RTT: &str = "net_rtt_secs";
    /// Server-side per-chunk handling time during a store push.
    pub const HIST_PUSH_CHUNK: &str = "push_chunk_secs";
    /// Leader-observed time per shard-partial gather (the TP "reduce"),
    /// covering every follower's contribution for one chunk of one site.
    pub const HIST_TP_REDUCE: &str = "tp_reduce_secs";

    // Health-state transition totals ([`crate::router::BackendHealth`]):
    // entries *into* the named state, summed over a router's backends.
    // Named with an explicit `_total` so the Prometheus exposition
    // keeps the key verbatim.
    /// Backend transitions into `degraded`.
    pub const ROUTER_HEALTH_DEGRADED: &str = "router_health_degraded_total";
    /// Backend transitions into `down`.
    pub const ROUTER_HEALTH_DOWN: &str = "router_health_down_total";

    /// Peak gauges ([`super::Metrics::set_max`]) that
    /// [`super::Metrics::merge`] combines with max instead of summing.
    pub const PEAK_GAUGES: [&str; 2] = [QUEUE_PEAK, NET_CONN_PEAK];
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, counter: &str, v: u64) {
        // get_mut-first: after a key's first use this is allocation-free,
        // which the engines' zero-alloc steady state relies on (`entry`
        // would build a `String` on every call).
        match self.counters.get_mut(counter) {
            Some(e) => *e += v,
            None => {
                self.counters.insert(counter.to_string(), v);
            }
        }
    }

    pub fn get(&self, counter: &str) -> u64 {
        self.counters.get(counter).copied().unwrap_or(0)
    }

    /// Raise a gauge-style counter to `v` if it is below it (high-water
    /// marks like queue depth). [`Metrics::merge`] combines the known
    /// peak gauges (`keys::PEAK_GAUGES`) with max, not sum.
    pub fn set_max(&mut self, counter: &str, v: u64) {
        // get_mut-first, like `add`: allocation-free after first use.
        match self.counters.get_mut(counter) {
            Some(e) => *e = (*e).max(v),
            None => {
                self.counters.insert(counter.to_string(), v);
            }
        }
    }

    pub fn add_phase(&mut self, phase: &str, secs: f64) {
        // See `add` — allocation-free after the phase's first use.
        match self.phases.get_mut(phase) {
            Some(e) => *e += secs,
            None => {
                self.phases.insert(phase.to_string(), secs);
            }
        }
    }

    pub fn phase(&self, phase: &str) -> f64 {
        self.phases.get(phase).copied().unwrap_or(0.0)
    }

    /// Time a closure into `phase`.
    pub fn time<R>(&mut self, phase: &str, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        self.add_phase(phase, t0.elapsed().as_secs_f64());
        r
    }

    /// Record one duration into the named log-bucketed histogram.
    /// get_mut-first like `add` — allocation-free after first use.
    pub fn observe(&mut self, hist: &str, secs: f64) {
        match self.hists.get_mut(hist) {
            Some(h) => h.record(secs),
            None => {
                let mut h = HistogramStats::new();
                h.record(secs);
                self.hists.insert(hist.to_string(), h);
            }
        }
    }

    pub fn hist(&self, name: &str) -> Option<&HistogramStats> {
        self.hists.get(name)
    }

    /// Merge another worker's metrics into this one. Phases and counters
    /// add (divide by worker count for averages if needed by the
    /// caller), histograms merge bucket-wise, and the known peak gauges
    /// (`keys::PEAK_GAUGES`) combine with max — summing two snapshots'
    /// high-water marks would fabricate a depth no queue ever reached.
    pub fn merge(&mut self, other: &Metrics) {
        for (k, v) in &other.phases {
            self.add_phase(k, *v);
        }
        for (k, v) in &other.counters {
            if keys::PEAK_GAUGES.contains(&k.as_str()) {
                self.set_max(k, *v);
            } else {
                self.add(k, *v);
            }
        }
        for (k, h) in &other.hists {
            match self.hists.get_mut(k) {
                Some(mine) => mine.merge(h),
                None => {
                    self.hists.insert(k.clone(), h.clone());
                }
            }
        }
    }

    /// Total wall seconds across phases.
    pub fn total_time(&self) -> f64 {
        self.phases.values().sum()
    }

    /// Achieved FLOP/s over the compute phase (or all phases if absent).
    pub fn achieved_flops(&self) -> f64 {
        let t = if self.phases.contains_key("compute") {
            self.phase("compute")
        } else {
            self.total_time()
        };
        if t <= 0.0 {
            return 0.0;
        }
        self.get(keys::FLOPS) as f64 / t
    }

    /// Computation-to-communication ratio in FLOPs/byte (paper §2.2).
    pub fn ccr(&self) -> f64 {
        let b = self.get(keys::COMM_BYTES);
        if b == 0 {
            return f64::INFINITY;
        }
        self.get(keys::FLOPS) as f64 / b as f64
    }

    pub fn to_json(&self) -> Json {
        let phases = Json::Obj(
            self.phases
                .iter()
                .map(|(k, v)| (k.clone(), Json::Num(*v)))
                .collect(),
        );
        let counters = Json::Obj(
            self.counters
                .iter()
                .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
                .collect(),
        );
        let mut pairs = vec![
            ("phases", phases),
            ("counters", counters),
            ("achieved_flops", Json::Num(self.achieved_flops())),
        ];
        if !self.hists.is_empty() {
            pairs.push((
                "hists",
                Json::Obj(
                    self.hists
                        .iter()
                        .map(|(k, h)| (k.clone(), h.to_json()))
                        .collect(),
                ),
            ));
        }
        Json::obj(pairs)
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "samples={} sites={} time={} flops={:.3e} ({:.2} GFLOP/s) io={} comm={}",
            self.get(keys::SAMPLES),
            self.get(keys::SITES),
            crate::util::human_secs(self.total_time()),
            self.get(keys::FLOPS) as f64,
            self.achieved_flops() / 1e9,
            crate::util::human_bytes(self.get(keys::IO_BYTES)),
            crate::util::human_bytes(self.get(keys::COMM_BYTES)),
        )
    }
}

/// RAII phase timer.
pub struct PhaseTimer<'a> {
    metrics: &'a mut Metrics,
    phase: &'static str,
    start: Instant,
}

impl<'a> PhaseTimer<'a> {
    pub fn new(metrics: &'a mut Metrics, phase: &'static str) -> Self {
        PhaseTimer {
            metrics,
            phase,
            start: Instant::now(),
        }
    }
}

impl Drop for PhaseTimer<'_> {
    fn drop(&mut self) {
        self.metrics
            .add_phase(self.phase, self.start.elapsed().as_secs_f64());
    }
}

/// Number of log₂ buckets in a [`HistogramStats`]. Bucket `i` covers
/// durations in `[2^(i + HIST_MIN_EXP), 2^(i + 1 + HIST_MIN_EXP))`
/// seconds; with `HIST_MIN_EXP = -30` bucket 0 starts at ~1 ns and the
/// last bucket tops out above 2⁴ hours — the full range a sampling
/// fleet can produce, at ≤ ×2 relative error per bucket.
pub const HIST_BUCKETS: usize = 44;
const HIST_MIN_EXP: i32 = -30;

/// Fixed-footprint log-bucketed duration histogram. Unlike
/// [`LatencyStats`] (a bounded sample window with exact order
/// statistics over *recent* observations), a histogram never evicts:
/// counts are exact over the whole lifetime, quantiles are approximate
/// (≤ ×√2 off, the bucket's geometric midpoint), and two histograms
/// merge losslessly by adding buckets — which is what fleet-level
/// aggregation (router + N backends) needs.
#[derive(Debug, Clone)]
pub struct HistogramStats {
    buckets: [u64; HIST_BUCKETS],
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Default for HistogramStats {
    fn default() -> Self {
        Self::new()
    }
}

impl HistogramStats {
    pub fn new() -> HistogramStats {
        HistogramStats {
            buckets: [0; HIST_BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: 0.0,
        }
    }

    fn bucket_of(secs: f64) -> usize {
        if !(secs > 0.0) {
            return 0;
        }
        let idx = secs.log2().floor() as i32 - HIST_MIN_EXP;
        idx.clamp(0, HIST_BUCKETS as i32 - 1) as usize
    }

    /// Lower bound (seconds) of bucket `i`.
    pub fn bucket_floor(i: usize) -> f64 {
        (2.0f64).powi(i as i32 + HIST_MIN_EXP)
    }

    /// Raw per-bucket counts (the telemetry exposition maps these to
    /// cumulative `le` buckets; see `telemetry::prom::cumulative_le`).
    pub fn bucket_counts(&self) -> &[u64; HIST_BUCKETS] {
        &self.buckets
    }

    pub fn record(&mut self, secs: f64) {
        let s = if secs.is_finite() { secs.max(0.0) } else { 0.0 };
        self.buckets[Self::bucket_of(s)] += 1;
        self.count += 1;
        self.sum += s;
        self.min = self.min.min(s);
        self.max = self.max.max(s);
    }

    pub fn merge(&mut self, other: &HistogramStats) {
        for (b, o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Approximate nearest-rank quantile: the geometric midpoint of the
    /// bucket holding the target rank, clamped to the observed min/max.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if self.count == 0 {
            return None;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, n) in self.buckets.iter().enumerate() {
            cum += n;
            if cum >= target {
                let mid = Self::bucket_floor(i) * std::f64::consts::SQRT_2;
                return Some(mid.clamp(self.min, self.max));
            }
        }
        Some(self.max)
    }

    pub fn mean(&self) -> Option<f64> {
        if self.count == 0 {
            None
        } else {
            Some(self.sum / self.count as f64)
        }
    }

    /// Schema (docs/metrics.schema.json): `count`, `sum_secs`,
    /// `min_secs`/`max_secs`/`mean_secs`, `p50_secs`/`p99_secs`, and a
    /// sparse `buckets` array of `[index, count]` pairs.
    pub fn to_json(&self) -> Json {
        let num_or_null = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
        let buckets = Json::Arr(
            self.buckets
                .iter()
                .enumerate()
                .filter(|(_, n)| **n > 0)
                .map(|(i, n)| Json::Arr(vec![Json::Num(i as f64), Json::Num(*n as f64)]))
                .collect(),
        );
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("sum_secs", Json::Num(self.sum)),
            (
                "min_secs",
                num_or_null((self.count > 0).then_some(self.min)),
            ),
            (
                "max_secs",
                num_or_null((self.count > 0).then_some(self.max)),
            ),
            ("mean_secs", num_or_null(self.mean())),
            ("p50_secs", num_or_null(self.quantile(0.5))),
            ("p99_secs", num_or_null(self.quantile(0.99))),
            ("buckets", buckets),
        ])
    }
}

/// Streaming latency recorder for the service layer: keeps up to `cap`
/// samples (ring overwrite once full, so long-running services track the
/// *recent* distribution) and reports order statistics. p50/p99 of job
/// turnaround is the service's user-facing SLO number.
#[derive(Debug, Clone)]
pub struct LatencyStats {
    samples: Vec<f64>,
    /// Next ring slot once `samples.len() == cap`.
    cursor: usize,
    cap: usize,
    /// Total observations ever recorded (≥ `samples.len()`).
    pub count: u64,
}

impl LatencyStats {
    pub fn new(cap: usize) -> LatencyStats {
        LatencyStats {
            samples: Vec::new(),
            cursor: 0,
            cap: cap.max(1),
            count: 0,
        }
    }

    pub fn record(&mut self, secs: f64) {
        self.count += 1;
        if self.samples.len() < self.cap {
            self.samples.push(secs);
        } else {
            self.samples[self.cursor] = secs;
            self.cursor = (self.cursor + 1) % self.cap;
        }
    }

    /// Nearest-rank quantile over the retained window; `q` in [0, 1].
    /// Clones + sorts the window — for several quantiles at once use
    /// [`LatencyStats::snapshot`], which sorts a single time.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if self.samples.is_empty() {
            return None;
        }
        let mut xs = self.samples.clone();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        Some(xs[Self::rank(q, xs.len())])
    }

    fn rank(q: f64, n: usize) -> usize {
        ((q.clamp(0.0, 1.0) * n as f64).ceil() as usize)
            .saturating_sub(1)
            .min(n - 1)
    }

    /// All exported order statistics from **one** sort of the window
    /// (`to_json` used to sort three times for p50 + p99 + max).
    pub fn snapshot(&self) -> Option<LatencySnapshot> {
        if self.samples.is_empty() {
            return None;
        }
        let mut xs = self.samples.clone();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        Some(LatencySnapshot {
            count: self.count,
            p50: xs[Self::rank(0.5, xs.len())],
            p99: xs[Self::rank(0.99, xs.len())],
            max: xs[xs.len() - 1],
        })
    }

    pub fn p50(&self) -> Option<f64> {
        self.quantile(0.5)
    }

    pub fn p99(&self) -> Option<f64> {
        self.quantile(0.99)
    }

    pub fn merge(&mut self, other: &LatencyStats) {
        // record() below re-counts the retained samples; pre-add only the
        // observations other's ring has already evicted.
        self.count += other.count - other.samples.len() as u64;
        for &s in &other.samples {
            self.record(s);
        }
    }

    pub fn to_json(&self) -> Json {
        let snap = self.snapshot();
        let pick = |f: fn(&LatencySnapshot) -> f64| {
            snap.as_ref().map(|s| Json::Num(f(s))).unwrap_or(Json::Null)
        };
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("p50_secs", pick(|s| s.p50)),
            ("p99_secs", pick(|s| s.p99)),
            ("max_secs", pick(|s| s.max)),
        ])
    }
}

/// Order statistics of a [`LatencyStats`] window, from a single sort.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencySnapshot {
    pub count: u64,
    pub p50: f64,
    pub p99: f64,
    pub max: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_quantiles_nearest_rank() {
        let mut l = LatencyStats::new(100);
        for i in 1..=100 {
            l.record(i as f64);
        }
        assert_eq!(l.p50(), Some(50.0));
        assert_eq!(l.p99(), Some(99.0));
        assert_eq!(l.quantile(1.0), Some(100.0));
        assert_eq!(l.quantile(0.0), Some(1.0));
        assert_eq!(l.count, 100);
        assert_eq!(LatencyStats::new(8).p50(), None);
    }

    #[test]
    fn latency_ring_keeps_recent_window() {
        let mut l = LatencyStats::new(4);
        for i in 0..8 {
            l.record(i as f64);
        }
        assert_eq!(l.count, 8);
        // Window holds {4,5,6,7}.
        assert_eq!(l.quantile(0.0), Some(4.0));
        assert_eq!(l.quantile(1.0), Some(7.0));
    }

    #[test]
    fn latency_merge_combines_counts_and_samples() {
        let mut a = LatencyStats::new(16);
        a.record(1.0);
        a.record(2.0);
        let mut b = LatencyStats::new(16);
        b.record(10.0);
        a.merge(&b);
        assert_eq!(a.count, 3);
        assert_eq!(a.quantile(1.0), Some(10.0));
        let j = a.to_json().dump();
        assert!(j.contains("p99_secs"));
    }

    #[test]
    fn set_max_is_a_gauge() {
        let mut m = Metrics::new();
        m.set_max(keys::QUEUE_PEAK, 3);
        m.set_max(keys::QUEUE_PEAK, 9);
        m.set_max(keys::QUEUE_PEAK, 5);
        assert_eq!(m.get(keys::QUEUE_PEAK), 9);
    }

    #[test]
    fn counters_accumulate() {
        let mut m = Metrics::new();
        m.add(keys::FLOPS, 100);
        m.add(keys::FLOPS, 50);
        assert_eq!(m.get(keys::FLOPS), 150);
        assert_eq!(m.get("nonexistent"), 0);
    }

    #[test]
    fn phases_accumulate_and_time() {
        let mut m = Metrics::new();
        m.add_phase("compute", 1.5);
        m.add_phase("compute", 0.5);
        assert_eq!(m.phase("compute"), 2.0);
        let r = m.time("io", || 42);
        assert_eq!(r, 42);
        assert!(m.phase("io") >= 0.0);
    }

    #[test]
    fn merge_sums_everything() {
        let mut a = Metrics::new();
        a.add(keys::SAMPLES, 10);
        a.add_phase("compute", 1.0);
        let mut b = Metrics::new();
        b.add(keys::SAMPLES, 5);
        b.add_phase("compute", 2.0);
        b.add_phase("comm", 0.5);
        a.merge(&b);
        assert_eq!(a.get(keys::SAMPLES), 15);
        assert_eq!(a.phase("compute"), 3.0);
        assert_eq!(a.phase("comm"), 0.5);
    }

    #[test]
    fn merge_combines_peak_gauges_with_max() {
        // Regression: summing two snapshots' high-water marks fabricated
        // a queue depth no queue ever reached.
        let mut a = Metrics::new();
        a.set_max(keys::QUEUE_PEAK, 7);
        a.set_max(keys::NET_CONN_PEAK, 2);
        a.add(keys::SAMPLES, 10);
        let mut b = Metrics::new();
        b.set_max(keys::QUEUE_PEAK, 4);
        b.set_max(keys::NET_CONN_PEAK, 5);
        b.add(keys::SAMPLES, 1);
        a.merge(&b);
        assert_eq!(a.get(keys::QUEUE_PEAK), 7, "max, not 11");
        assert_eq!(a.get(keys::NET_CONN_PEAK), 5, "max, not 7");
        assert_eq!(a.get(keys::SAMPLES), 11, "plain counters still sum");
        // A peak only present on one side survives the merge.
        let mut c = Metrics::new();
        c.merge(&a);
        assert_eq!(c.get(keys::QUEUE_PEAK), 7);
    }

    #[test]
    fn set_max_is_allocation_free_after_first_use() {
        let mut m = Metrics::new();
        m.set_max(keys::QUEUE_PEAK, 1);
        let mut clean = false;
        for _ in 0..128 {
            let before = crate::util::alloc::allocation_count();
            m.set_max(keys::QUEUE_PEAK, 2);
            if crate::util::alloc::allocation_count() == before {
                clean = true;
                break;
            }
        }
        assert!(clean, "set_max allocated on a warm key");
    }

    #[test]
    fn latency_snapshot_matches_triple_sort() {
        let mut l = LatencyStats::new(256);
        let mut x = 7u64;
        for _ in 0..200 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
            l.record((x % 1000) as f64 / 10.0);
        }
        let s = l.snapshot().unwrap();
        assert_eq!(Some(s.p50), l.p50());
        assert_eq!(Some(s.p99), l.p99());
        assert_eq!(Some(s.max), l.quantile(1.0));
        assert_eq!(s.count, l.count);
        assert_eq!(LatencyStats::new(4).snapshot(), None);
    }

    #[test]
    fn histogram_records_merges_and_quantiles() {
        let mut h = HistogramStats::new();
        assert_eq!(h.quantile(0.5), None);
        for _ in 0..90 {
            h.record(0.001); // 1 ms
        }
        for _ in 0..10 {
            h.record(1.0); // 1 s
        }
        assert_eq!(h.count, 100);
        let p50 = h.quantile(0.5).unwrap();
        assert!((0.0005..0.002).contains(&p50), "p50 in the 1 ms bucket: {p50}");
        let p99 = h.quantile(0.99).unwrap();
        assert!((0.5..2.0).contains(&p99), "p99 in the 1 s bucket: {p99}");
        assert_eq!(h.max, 1.0);
        assert_eq!(h.min, 0.001);

        let mut other = HistogramStats::new();
        other.record(10.0);
        h.merge(&other);
        assert_eq!(h.count, 101);
        assert_eq!(h.max, 10.0);
        assert!((h.sum - (90.0 * 0.001 + 10.0 + 10.0)).abs() < 1e-9);

        // Out-of-range and degenerate values land in the edge buckets
        // instead of panicking.
        h.record(0.0);
        h.record(-1.0);
        h.record(f64::INFINITY);
        h.record(1e12);
        assert_eq!(h.count, 105);
        assert_eq!(h.min, 0.0);
    }

    #[test]
    fn histogram_json_shape() {
        let mut h = HistogramStats::new();
        h.record(0.5);
        h.record(0.25);
        let j = h.to_json();
        assert_eq!(j.get("count").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("sum_secs").unwrap().as_f64(), Some(0.75));
        assert!(j.get("p50_secs").unwrap().as_f64().is_some());
        let buckets = j.get("buckets").unwrap().as_arr().unwrap();
        assert_eq!(buckets.len(), 2, "sparse pairs, one per hit bucket");
        for pair in buckets {
            assert_eq!(pair.as_arr().unwrap().len(), 2);
        }
        // Reparses cleanly (the metrics --json path).
        assert!(crate::util::json::Json::parse(&j.dump()).is_ok());
        // Empty histogram exports nulls, not NaN/Inf garbage.
        let empty = HistogramStats::new().to_json();
        assert_eq!(empty.get("min_secs"), Some(&Json::Null));
        assert!(Json::parse(&empty.dump()).is_ok());
    }

    #[test]
    fn metrics_observe_and_merge_histograms() {
        let mut a = Metrics::new();
        a.observe(keys::HIST_QUEUE_WAIT, 0.1);
        let mut b = Metrics::new();
        b.observe(keys::HIST_QUEUE_WAIT, 0.2);
        b.observe(keys::HIST_NET_RTT, 0.001);
        a.merge(&b);
        assert_eq!(a.hist(keys::HIST_QUEUE_WAIT).unwrap().count, 2);
        assert_eq!(a.hist(keys::HIST_NET_RTT).unwrap().count, 1);
        let j = a.to_json();
        assert!(j.get("hists").unwrap().get(keys::HIST_QUEUE_WAIT).is_some());
        // No histograms → no "hists" key (backward-compatible shape).
        assert!(Metrics::new().to_json().get("hists").is_none());
    }

    #[test]
    fn ccr_and_flops() {
        let mut m = Metrics::new();
        m.add(keys::FLOPS, 8000);
        m.add(keys::COMM_BYTES, 16);
        m.add_phase("compute", 2.0);
        assert_eq!(m.ccr(), 500.0);
        assert_eq!(m.achieved_flops(), 4000.0);
        let m2 = Metrics::new();
        assert!(m2.ccr().is_infinite());
    }

    #[test]
    fn json_export_parses() {
        let mut m = Metrics::new();
        m.add(keys::FLOPS, 1);
        m.add_phase("x", 0.25);
        let j = m.to_json().dump();
        let v = crate::util::json::Json::parse(&j).unwrap();
        assert_eq!(v.get("phases").unwrap().get("x").unwrap().as_f64(), Some(0.25));
    }

    #[test]
    fn empty_histogram_statistics_are_absent_not_zero() {
        let h = HistogramStats::new();
        assert_eq!(h.count, 0);
        assert_eq!(h.quantile(0.5), None);
        assert_eq!(h.quantile(0.99), None);
        assert_eq!(h.mean(), None);
        assert!(h.bucket_counts().iter().all(|&n| n == 0));
        let j = h.to_json();
        assert_eq!(j.get("p50_secs"), Some(&crate::util::json::Json::Null));
        assert_eq!(j.get("mean_secs"), Some(&crate::util::json::Json::Null));
    }

    #[test]
    fn huge_values_land_in_the_top_overflow_bucket() {
        let mut h = HistogramStats::new();
        // 2^13 s == the exact floor of the last bucket; anything
        // beyond (hours, or absurd values) clamps into it too.
        for v in [(2.0f64).powi(13), 1e6, 1e30] {
            h.record(v);
        }
        let counts = h.bucket_counts();
        assert_eq!(counts[HIST_BUCKETS - 1], 3);
        assert!(counts[..HIST_BUCKETS - 1].iter().all(|&n| n == 0));
        // Quantiles stay the geometric midpoint of the overflow
        // bucket, clamped inside the observed [min, max] window.
        let p99 = h.quantile(0.99).unwrap();
        let mid = HistogramStats::bucket_floor(HIST_BUCKETS - 1) * std::f64::consts::SQRT_2;
        assert_eq!(p99, mid);
        assert!(p99 >= h.min && p99 <= h.max);
        assert_eq!(h.min, (2.0f64).powi(13));
        assert_eq!(h.max, 1e30);
    }

    #[test]
    fn merge_of_disjoint_sparse_buckets_keeps_both() {
        let mut lo = HistogramStats::new();
        // Both land in bucket 0: [2^-30, 2^-29) covers ~0.93–1.86 ns.
        lo.record(1e-9);
        lo.record(1.5e-9);
        let mut hi = HistogramStats::new();
        hi.record(100.0);
        lo.merge(&hi);
        assert_eq!(lo.count, 3);
        assert_eq!(lo.min, 1e-9);
        assert_eq!(lo.max, 100.0);
        let occupied: Vec<usize> = lo
            .bucket_counts()
            .iter()
            .enumerate()
            .filter(|(_, &n)| n > 0)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(occupied.len(), 2, "disjoint buckets must not collapse");
        // Sparse JSON export keeps both, ascending, summing to count.
        let j = lo.to_json();
        let pairs = j.get("buckets").unwrap().as_arr().unwrap();
        assert_eq!(pairs.len(), 2);
        let idx: Vec<usize> =
            pairs.iter().map(|p| p.as_arr().unwrap()[0].as_usize().unwrap()).collect();
        assert!(idx[0] < idx[1]);
        let total: f64 =
            pairs.iter().map(|p| p.as_arr().unwrap()[1].as_f64().unwrap()).sum();
        assert_eq!(total as u64, lo.count);
    }
}
