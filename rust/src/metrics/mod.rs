//! Counters, timers and FLOP/byte accounting.
//!
//! Every coordinator records a [`Metrics`] snapshot: wall time per phase,
//! FLOPs executed, bytes moved by I/O / host copies / fabric traffic, and
//! derived quantities (achieved FLOP/s, computation-to-communication ratio —
//! the paper's CCR analysis in §2.2) for EXPERIMENTS.md and the bench
//! harnesses.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::util::json::Json;

/// Accumulating phase timer + counters. Not thread-safe by design — each
/// worker owns one and they are merged at the end (`merge`).
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    /// Seconds per named phase (wall).
    pub phases: BTreeMap<String, f64>,
    /// Monotonic counters (flops, io_bytes, comm_bytes, samples, ...).
    pub counters: BTreeMap<String, u64>,
}

/// Standard counter names.
pub mod keys {
    pub const FLOPS: &str = "flops";
    pub const IO_BYTES: &str = "io_bytes";
    pub const COMM_BYTES: &str = "comm_bytes";
    pub const HOST_COPY_BYTES: &str = "host_copy_bytes";
    pub const SAMPLES: &str = "samples";
    pub const SITES: &str = "sites";
    pub const MICRO_BATCHES: &str = "micro_batches";
    pub const MACRO_BATCHES: &str = "macro_batches";
    pub const IO_OPS: &str = "io_ops";
    pub const COLLECTIVES: &str = "collectives";
    pub const STEPS_SKIPPED: &str = "steps_skipped"; // dynamic-χ fast path
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, counter: &str, v: u64) {
        *self.counters.entry(counter.to_string()).or_insert(0) += v;
    }

    pub fn get(&self, counter: &str) -> u64 {
        self.counters.get(counter).copied().unwrap_or(0)
    }

    pub fn add_phase(&mut self, phase: &str, secs: f64) {
        *self.phases.entry(phase.to_string()).or_insert(0.0) += secs;
    }

    pub fn phase(&self, phase: &str) -> f64 {
        self.phases.get(phase).copied().unwrap_or(0.0)
    }

    /// Time a closure into `phase`.
    pub fn time<R>(&mut self, phase: &str, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        self.add_phase(phase, t0.elapsed().as_secs_f64());
        r
    }

    /// Merge another worker's metrics into this one (phases add — divide by
    /// worker count for averages if needed by the caller).
    pub fn merge(&mut self, other: &Metrics) {
        for (k, v) in &other.phases {
            self.add_phase(k, *v);
        }
        for (k, v) in &other.counters {
            self.add(k, *v);
        }
    }

    /// Total wall seconds across phases.
    pub fn total_time(&self) -> f64 {
        self.phases.values().sum()
    }

    /// Achieved FLOP/s over the compute phase (or all phases if absent).
    pub fn achieved_flops(&self) -> f64 {
        let t = if self.phases.contains_key("compute") {
            self.phase("compute")
        } else {
            self.total_time()
        };
        if t <= 0.0 {
            return 0.0;
        }
        self.get(keys::FLOPS) as f64 / t
    }

    /// Computation-to-communication ratio in FLOPs/byte (paper §2.2).
    pub fn ccr(&self) -> f64 {
        let b = self.get(keys::COMM_BYTES);
        if b == 0 {
            return f64::INFINITY;
        }
        self.get(keys::FLOPS) as f64 / b as f64
    }

    pub fn to_json(&self) -> Json {
        let phases = Json::Obj(
            self.phases
                .iter()
                .map(|(k, v)| (k.clone(), Json::Num(*v)))
                .collect(),
        );
        let counters = Json::Obj(
            self.counters
                .iter()
                .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
                .collect(),
        );
        Json::obj(vec![
            ("phases", phases),
            ("counters", counters),
            ("achieved_flops", Json::Num(self.achieved_flops())),
        ])
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "samples={} sites={} time={} flops={:.3e} ({:.2} GFLOP/s) io={} comm={}",
            self.get(keys::SAMPLES),
            self.get(keys::SITES),
            crate::util::human_secs(self.total_time()),
            self.get(keys::FLOPS) as f64,
            self.achieved_flops() / 1e9,
            crate::util::human_bytes(self.get(keys::IO_BYTES)),
            crate::util::human_bytes(self.get(keys::COMM_BYTES)),
        )
    }
}

/// RAII phase timer.
pub struct PhaseTimer<'a> {
    metrics: &'a mut Metrics,
    phase: &'static str,
    start: Instant,
}

impl<'a> PhaseTimer<'a> {
    pub fn new(metrics: &'a mut Metrics, phase: &'static str) -> Self {
        PhaseTimer {
            metrics,
            phase,
            start: Instant::now(),
        }
    }
}

impl Drop for PhaseTimer<'_> {
    fn drop(&mut self) {
        self.metrics
            .add_phase(self.phase, self.start.elapsed().as_secs_f64());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut m = Metrics::new();
        m.add(keys::FLOPS, 100);
        m.add(keys::FLOPS, 50);
        assert_eq!(m.get(keys::FLOPS), 150);
        assert_eq!(m.get("nonexistent"), 0);
    }

    #[test]
    fn phases_accumulate_and_time() {
        let mut m = Metrics::new();
        m.add_phase("compute", 1.5);
        m.add_phase("compute", 0.5);
        assert_eq!(m.phase("compute"), 2.0);
        let r = m.time("io", || 42);
        assert_eq!(r, 42);
        assert!(m.phase("io") >= 0.0);
    }

    #[test]
    fn merge_sums_everything() {
        let mut a = Metrics::new();
        a.add(keys::SAMPLES, 10);
        a.add_phase("compute", 1.0);
        let mut b = Metrics::new();
        b.add(keys::SAMPLES, 5);
        b.add_phase("compute", 2.0);
        b.add_phase("comm", 0.5);
        a.merge(&b);
        assert_eq!(a.get(keys::SAMPLES), 15);
        assert_eq!(a.phase("compute"), 3.0);
        assert_eq!(a.phase("comm"), 0.5);
    }

    #[test]
    fn ccr_and_flops() {
        let mut m = Metrics::new();
        m.add(keys::FLOPS, 8000);
        m.add(keys::COMM_BYTES, 16);
        m.add_phase("compute", 2.0);
        assert_eq!(m.ccr(), 500.0);
        assert_eq!(m.achieved_flops(), 4000.0);
        let m2 = Metrics::new();
        assert!(m2.ccr().is_infinite());
    }

    #[test]
    fn json_export_parses() {
        let mut m = Metrics::new();
        m.add(keys::FLOPS, 1);
        m.add_phase("x", 0.25);
        let j = m.to_json().dump();
        let v = crate::util::json::Json::parse(&j).unwrap();
        assert_eq!(v.get("phases").unwrap().get("x").unwrap().as_f64(), Some(0.25));
    }
}
