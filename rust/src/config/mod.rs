//! Run configuration and dataset presets.
//!
//! Presets mirror the paper's evaluation datasets (Table 1/2/3) in two
//! flavours: `full` (the paper's actual shapes — used by the analytic
//! performance models and the fabric simulator) and `scaled` (CPU-testbed
//! shapes that measure end-to-end on this machine; DESIGN.md
//! §Substitutions).

use std::path::PathBuf;

use crate::comm::NetPreset;
use crate::io::{StoreCodec, StorePrecision};
use crate::linalg::GemmSplit;
use crate::mps::gbs::GbsSpec;
use crate::mps::workload::WorkloadSpec;
use crate::util::error::{Error, Result};
use crate::util::json::Json;

/// Compute precision of the hot loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ComputePrecision {
    /// Native f64 (oracle; the "FP64" arm of the ablation).
    F64,
    /// f32 (XLA CPU default).
    F32,
    /// f32 with TF32-emulated inputs (mantissa truncated to 10 bits before
    /// every contraction — what tensor cores do).
    Tf32,
    /// Experimental FP16 emulation (§3.3.1: "developed only for datasets
    /// with M < 500"): inputs *and* the collapsed environment are rounded
    /// through binary16, modelling a ComplexHalf pipeline. The ~10³ valid
    /// range of f16 significands makes this sensitive to the intra-sample
    /// spread the paper bounds at ~10⁶ — expect extra rounding error.
    F16,
}

impl ComputePrecision {
    pub fn as_str(self) -> &'static str {
        match self {
            ComputePrecision::F64 => "f64",
            ComputePrecision::F32 => "f32",
            ComputePrecision::Tf32 => "tf32",
            ComputePrecision::F16 => "f16",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f64" => Ok(Self::F64),
            "f32" => Ok(Self::F32),
            "tf32" => Ok(Self::Tf32),
            "f16" => Ok(Self::F16),
            _ => Err(Error::config(format!("unknown compute precision '{s}'"))),
        }
    }

    /// §3.3.1's guard: the experimental FP16 arm is only admissible for
    /// short chains.
    pub fn admissible_for(self, m: usize) -> bool {
        !matches!(self, ComputePrecision::F16) || m < 500
    }
}

/// Left-environment rescaling strategy (§3.3.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScalingMode {
    /// No rescaling (fails early — Fig. 6's collapse).
    None,
    /// Global auto-scaling by the batch max (the baseline [19] method).
    Global,
    /// FastMPS per-sample adaptive scaling.
    PerSample,
}

impl ScalingMode {
    pub fn as_str(self) -> &'static str {
        match self {
            ScalingMode::None => "none",
            ScalingMode::Global => "global",
            ScalingMode::PerSample => "per-sample",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "none" => Ok(Self::None),
            "global" => Ok(Self::Global),
            "per-sample" | "persample" => Ok(Self::PerSample),
            _ => Err(Error::config(format!("unknown scaling mode '{s}'"))),
        }
    }
}

/// Memory layout of the native engine's step kernels.
///
/// `Interleaved` keeps complex values as `(re, im)` pairs (the classic
/// `Complex<T>` array); `Planar` splits each operand into separate
/// real/imaginary planes so the axpy inner loop vectorizes as plain
/// fused-free mul/add/sub lanes (and, under `--features simd`, an
/// explicit AVX2/NEON microkernel). Both paths accumulate every output
/// element in the same ascending-k order, so results are bit-identical —
/// the layout choice is purely a throughput knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Layout {
    /// Planar for the f32-family compute precisions (f32/tf32/f16, where
    /// the SIMD win is largest), interleaved for f64.
    #[default]
    Auto,
    /// Force `Complex<T>` pair layout everywhere.
    Interleaved,
    /// Force split real/imaginary planes for the step hot path.
    Planar,
}

impl Layout {
    pub fn as_str(self) -> &'static str {
        match self {
            Layout::Auto => "auto",
            Layout::Interleaved => "interleaved",
            Layout::Planar => "planar",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "auto" => Ok(Self::Auto),
            "interleaved" => Ok(Self::Interleaved),
            "planar" => Ok(Self::Planar),
            _ => Err(Error::config(format!("unknown layout '{s}'"))),
        }
    }

    /// Whether the planar path is used for `precision` under this policy.
    pub fn planar_for(self, precision: ComputePrecision) -> bool {
        match self {
            Layout::Planar => true,
            Layout::Interleaved => false,
            Layout::Auto => precision != ComputePrecision::F64,
        }
    }
}

/// Which engine executes the per-site step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// AOT-compiled XLA artifacts through PJRT (the production hot path).
    Xla,
    /// Native rust engine (oracle / precision studies).
    Native,
}

impl EngineKind {
    pub fn as_str(self) -> &'static str {
        match self {
            EngineKind::Xla => "xla",
            EngineKind::Native => "native",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "xla" => Ok(Self::Xla),
            "native" => Ok(Self::Native),
            _ => Err(Error::config(format!("unknown engine '{s}'"))),
        }
    }
}

/// Full run configuration for the coordinators.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub spec: WorkloadSpec,
    /// Total samples N.
    pub n_samples: u64,
    /// Macro batch size N₁ (per worker per round).
    pub n1_macro: usize,
    /// Micro batch size N₂.
    pub n2_micro: usize,
    /// Data-parallel groups p₁.
    pub p1: usize,
    /// Tensor-parallel ranks per group p₂.
    pub p2: usize,
    /// Threads for the native engine's GEMM.
    pub gemm_threads: usize,
    /// Which axis the threaded GEMM splits (rows = samples, cols = the
    /// bond dimension — the paper's tensor-parallel axis; auto picks by
    /// shape).
    pub gemm_split: GemmSplit,
    /// Step-kernel memory layout for the native engine (see [`Layout`]).
    pub layout: Layout,
    pub compute: ComputePrecision,
    pub store_precision: StorePrecision,
    pub store_codec: StoreCodec,
    pub scaling: ScalingMode,
    pub engine: EngineKind,
    pub net: NetPreset,
    /// Double-site (true) vs single-site (false) tensor parallelism.
    pub double_site: bool,
    pub data_dir: PathBuf,
    pub artifacts_dir: PathBuf,
    /// Simulated disk bandwidth (B/s); None = real disk speed.
    pub disk_bw: Option<f64>,
    /// Store the left environment in FP16 between sites (§3.3.2: halves the
    /// env memory, doubling N₁). Exposes the Fig. 6 underflow at testbed
    /// scale: f16's ~7.7 decades of range stand in for f32's 38 over the
    /// paper's 8176 sites.
    pub env_f16: bool,
    /// Virtual compute rate (FLOP/s) used to advance the fabric's virtual
    /// clock. `None` charges measured wall time (right for head-to-head
    /// wall benchmarks); `Some(rate)` charges `flops/rate` so scaling
    /// studies are not polluted by thread oversubscription on the testbed
    /// (the Figs. 12/13 runs model one device per rank).
    pub vdevice_flops: Option<f64>,
    pub seed: u64,
}

impl RunConfig {
    /// A small, fast default configuration around `spec` (any workload —
    /// `GbsSpec`/`QubitSpec` convert implicitly).
    pub fn new(spec: impl Into<WorkloadSpec>) -> RunConfig {
        let spec = spec.into();
        RunConfig {
            n_samples: 4096,
            n1_macro: 1024,
            n2_micro: 256,
            p1: 1,
            p2: 1,
            gemm_threads: 1,
            gemm_split: GemmSplit::Auto,
            layout: Layout::Auto,
            compute: ComputePrecision::F32,
            store_precision: StorePrecision::F16,
            store_codec: StoreCodec::Raw,
            scaling: ScalingMode::PerSample,
            engine: EngineKind::Native,
            net: NetPreset::Ideal,
            double_site: true,
            data_dir: PathBuf::from("data"),
            artifacts_dir: PathBuf::from("artifacts"),
            disk_bw: None,
            env_f16: false,
            vdevice_flops: None,
            seed: spec.seed(),
            spec,
        }
    }

    pub fn total_ranks(&self) -> usize {
        self.p1 * self.p2
    }

    pub fn validate(&self) -> Result<()> {
        if self.n_samples == 0 {
            return Err(Error::config("n_samples must be > 0"));
        }
        if self.n1_macro == 0 || self.n2_micro == 0 {
            return Err(Error::config("batch sizes must be > 0"));
        }
        if self.n2_micro > self.n1_macro {
            return Err(Error::config(format!(
                "micro batch N₂={} exceeds macro batch N₁={}",
                self.n2_micro, self.n1_macro
            )));
        }
        if self.p1 == 0 || self.p2 == 0 {
            return Err(Error::config("p1/p2 must be ≥ 1"));
        }
        if self.spec.m() == 0 || self.spec.d() < 2 {
            return Err(Error::config("need M ≥ 1 sites and d ≥ 2"));
        }
        if !self.compute.admissible_for(self.spec.m()) {
            return Err(Error::config(format!(
                "experimental f16 compute requires M < 500 (got M = {}; §3.3.1)",
                self.spec.m()
            )));
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dataset", Json::Str(self.spec.name().to_string())),
            ("workload", Json::Str(self.spec.tag().into())),
            ("m", Json::Num(self.spec.m() as f64)),
            ("d", Json::Num(self.spec.d() as f64)),
            ("chi_cap", Json::Num(self.spec.chi_cap() as f64)),
            ("n_samples", Json::Num(self.n_samples as f64)),
            ("n1_macro", Json::Num(self.n1_macro as f64)),
            ("n2_micro", Json::Num(self.n2_micro as f64)),
            ("p1", Json::Num(self.p1 as f64)),
            ("p2", Json::Num(self.p2 as f64)),
            ("compute", Json::Str(self.compute.as_str().into())),
            ("gemm_split", Json::Str(self.gemm_split.as_str().into())),
            ("layout", Json::Str(self.layout.as_str().into())),
            (
                "store_precision",
                Json::Str(self.store_precision.as_str().into()),
            ),
            ("scaling", Json::Str(self.scaling.as_str().into())),
            ("engine", Json::Str(self.engine.as_str().into())),
            ("net", Json::Str(self.net.name().into())),
            ("double_site", Json::Bool(self.double_site)),
            ("seed", Json::Num(self.seed as f64)),
        ])
    }
}

/// Configuration of the resident sampling service (`fastmps serve`). One
/// section per concern: admission control guards the queue, the batcher
/// sizing realises §3.1's overlap condition, and the execution knobs are
/// shared by every job the service runs (jobs may override `compute`).
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Worker threads driving macro batches (each owns a resident engine).
    pub workers: usize,
    /// Admission control: max jobs queued or in flight.
    pub max_queue: usize,
    /// Admission control: max samples a single job may request.
    pub max_samples_per_job: u64,
    /// LRU capacity of the `GammaStore` cache, in stores.
    pub cache_entries: usize,
    /// How long the batcher lingers for more compatible jobs before
    /// dispatching a partially filled macro batch.
    pub linger_ms: u64,
    /// Poll interval of the file-transport serve loop.
    pub poll_ms: u64,
    /// Micro batch size N₂ within service macro batches.
    pub n2_micro: usize,
    /// Macro-batch row target; `None` derives it per store from the §3.1
    /// overlap condition capped by the Eq. 3 budget (`mem_budget`).
    pub target_batch: Option<usize>,
    /// Eq. 3 memory budget per worker (bytes) for the derived target.
    pub mem_budget: u64,
    pub engine: EngineKind,
    pub compute: ComputePrecision,
    pub scaling: ScalingMode,
    pub gemm_threads: usize,
    /// GEMM split axis for the resident engines (see [`RunConfig`]).
    pub gemm_split: GemmSplit,
    /// Step-kernel memory layout for the resident engines (see [`Layout`]).
    pub layout: Layout,
    /// Byte budget for resident prepared-Γ chains per `(store, precision)`
    /// entry in the `StoreCache` — warm batches walk converted tensors
    /// with zero per-step conversion (and zero Γ I/O once fully resident).
    /// 0 disables residency (sites are still prepared once per batch).
    pub prep_cache_bytes: u64,
    /// Simulated disk bandwidth shared by all cached stores' prefetchers.
    pub disk_bw: Option<f64>,
    pub artifacts_dir: PathBuf,
    /// Capacity (events) of the service's flight-recorder ring
    /// (`crate::trace`). 0 disables tracing; the default keeps the last
    /// few thousand events at a fixed ~64 B/event memory cost.
    pub trace_buf: usize,
    /// Tensor-parallel step deadline: how long a TP group member waits on
    /// a collective (env broadcast, partial gather, teardown) before
    /// declaring the peer lost and failing the job. Generous by default —
    /// a follower may legitimately sit idle while the leader streams and
    /// converts a large site from disk.
    pub tp_step_timeout_ms: u64,
}

impl Default for ServiceConfig {
    fn default() -> ServiceConfig {
        ServiceConfig {
            workers: 2,
            max_queue: 256,
            max_samples_per_job: 10_000_000,
            cache_entries: 4,
            linger_ms: 5,
            poll_ms: 20,
            n2_micro: 256,
            target_batch: None,
            mem_budget: 1 << 30,
            engine: EngineKind::Native,
            compute: ComputePrecision::F32,
            scaling: ScalingMode::PerSample,
            gemm_threads: 1,
            gemm_split: GemmSplit::Auto,
            layout: Layout::Auto,
            prep_cache_bytes: 256 << 20,
            disk_bw: None,
            artifacts_dir: PathBuf::from("artifacts"),
            trace_buf: crate::trace::DEFAULT_BUF,
            tp_step_timeout_ms: 600_000,
        }
    }
}

impl ServiceConfig {
    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            return Err(Error::config("service: workers must be ≥ 1"));
        }
        if self.max_queue == 0 || self.max_samples_per_job == 0 {
            return Err(Error::config("service: admission limits must be ≥ 1"));
        }
        if self.cache_entries == 0 {
            return Err(Error::config("service: cache_entries must be ≥ 1"));
        }
        if self.n2_micro == 0 {
            return Err(Error::config("service: n2_micro must be ≥ 1"));
        }
        if let Some(t) = self.target_batch {
            if t < self.n2_micro {
                return Err(Error::config(format!(
                    "service: target_batch {t} below micro batch N₂={}",
                    self.n2_micro
                )));
            }
        }
        if self.tp_step_timeout_ms == 0 {
            return Err(Error::config("service: tp_step_timeout_ms must be ≥ 1"));
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("workers", Json::Num(self.workers as f64)),
            ("max_queue", Json::Num(self.max_queue as f64)),
            (
                "max_samples_per_job",
                Json::Num(self.max_samples_per_job as f64),
            ),
            ("cache_entries", Json::Num(self.cache_entries as f64)),
            ("linger_ms", Json::Num(self.linger_ms as f64)),
            ("n2_micro", Json::Num(self.n2_micro as f64)),
            (
                "target_batch",
                self.target_batch
                    .map(|t| Json::Num(t as f64))
                    .unwrap_or(Json::Null),
            ),
            ("mem_budget", Json::Num(self.mem_budget as f64)),
            ("engine", Json::Str(self.engine.as_str().into())),
            ("compute", Json::Str(self.compute.as_str().into())),
            ("scaling", Json::Str(self.scaling.as_str().into())),
            ("gemm_split", Json::Str(self.gemm_split.as_str().into())),
            ("layout", Json::Str(self.layout.as_str().into())),
            ("prep_cache_bytes", Json::Num(self.prep_cache_bytes as f64)),
            ("trace_buf", Json::Num(self.trace_buf as f64)),
            (
                "tp_step_timeout_ms",
                Json::Num(self.tp_step_timeout_ms as f64),
            ),
        ])
    }
}

/// Configuration of the TCP transport (`net::server` / `net::client`,
/// `fastmps serve --listen` / `--connect`). One struct serves both sides:
/// the server reads `addr` as the listen address and `max_conns` as its
/// connection-pool bound; clients read `addr` as the default connect
/// target; the frame cap and timeouts apply to both.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Listen/connect address, `host:port` (port 0 = ephemeral).
    pub addr: String,
    /// Server-side bound on concurrent connections; further connects get
    /// a typed `busy` frame and are closed.
    pub max_conns: usize,
    /// Cap on a single frame's payload length, enforced before allocating.
    pub max_frame_bytes: usize,
    /// Socket read timeout — the server's idle-poll tick and the client's
    /// per-RPC reply deadline.
    pub read_timeout_ms: u64,
    /// Socket write timeout (slow-peer guard).
    pub write_timeout_ms: u64,
    /// Server side: where pushed stores are staged and installed
    /// (`store-<hash>` directories). `None` disables the `push_begin` op
    /// with a clear error — a server without local scratch should say so
    /// rather than fill `/tmp`.
    pub push_dir: Option<PathBuf>,
    /// Client side: raw bytes per push chunk before compression (each
    /// chunk becomes one CHUNK frame; compressed size is bounded by
    /// `max_frame_bytes`).
    pub push_chunk_bytes: usize,
    /// Server side: max announced size of one incoming push — the staging
    /// quota a single `push_begin` may claim.
    pub push_staging_bytes: u64,
    /// Telemetry sampling period: how often `serve`/`route` snapshot
    /// counters and quantiles into their time-series ring, and how
    /// often a router scrapes its backends (`--telemetry-interval`).
    pub telemetry_interval_ms: u64,
    /// Where to serve the Prometheus `GET /metrics` endpoint
    /// (`--metrics-listen ADDR`, port 0 = ephemeral). `None` (the
    /// default) disables the HTTP exporter; the `telemetry` FMPN op
    /// and the ring sampler run regardless.
    pub metrics_listen: Option<String>,
}

impl Default for NetConfig {
    fn default() -> NetConfig {
        NetConfig {
            addr: "127.0.0.1:7733".into(),
            max_conns: 64,
            max_frame_bytes: 64 << 20,
            read_timeout_ms: 2000,
            write_timeout_ms: 10_000,
            push_dir: None,
            push_chunk_bytes: 1 << 20,
            push_staging_bytes: 4 << 30,
            telemetry_interval_ms: 1000,
            metrics_listen: None,
        }
    }
}

impl NetConfig {
    /// How long a push endpoint waits without receiving a frame before
    /// aborting the transfer. One definition shared by the server's chunk
    /// receiver, the router's relay, and the router's failure drain, so
    /// the tiers can never disagree about what "stalled" means.
    pub fn push_stall_cap(&self) -> std::time::Duration {
        std::time::Duration::from_millis((self.read_timeout_ms.saturating_mul(4)).max(1000))
    }

    /// Read deadline for a push's closing exchange (`push_end` → reply):
    /// finalization (checksum, manifest hash, open, rename) can outlast
    /// the per-RPC deadline, so both the client and the router's relay
    /// widen to this before waiting on the final verdict. Associated (not
    /// a method) because the client carries only its read timeout.
    pub fn push_end_timeout_ms(read_timeout_ms: u64) -> u64 {
        read_timeout_ms.max(30_000)
    }

    pub fn validate(&self) -> Result<()> {
        if self.addr.is_empty() {
            return Err(Error::config("net: addr must not be empty"));
        }
        if self.max_conns == 0 {
            return Err(Error::config("net: max_conns must be ≥ 1"));
        }
        if self.max_frame_bytes < 1024 {
            return Err(Error::config("net: max_frame_bytes must be ≥ 1024"));
        }
        if self.read_timeout_ms == 0 || self.write_timeout_ms == 0 {
            return Err(Error::config("net: timeouts must be ≥ 1 ms"));
        }
        if self.push_chunk_bytes < 1024 {
            return Err(Error::config("net: push_chunk_bytes must be ≥ 1024"));
        }
        // A compressed chunk can exceed its raw size by ~1%; leave margin
        // so every CHUNK frame fits under the frame cap.
        if self.push_chunk_bytes > self.max_frame_bytes / 2 {
            return Err(Error::config(format!(
                "net: push_chunk_bytes {} exceeds half the {} byte frame cap",
                self.push_chunk_bytes, self.max_frame_bytes
            )));
        }
        if self.push_staging_bytes < self.push_chunk_bytes as u64 {
            return Err(Error::config(
                "net: push_staging_bytes below push_chunk_bytes",
            ));
        }
        if self.telemetry_interval_ms < 10 {
            return Err(Error::config(
                "net: telemetry_interval_ms must be ≥ 10 ms",
            ));
        }
        if let Some(addr) = &self.metrics_listen {
            if addr.is_empty() {
                return Err(Error::config("net: metrics_listen must not be empty"));
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("addr", Json::Str(self.addr.clone())),
            ("max_conns", Json::Num(self.max_conns as f64)),
            ("max_frame_bytes", Json::Num(self.max_frame_bytes as f64)),
            ("read_timeout_ms", Json::Num(self.read_timeout_ms as f64)),
            ("write_timeout_ms", Json::Num(self.write_timeout_ms as f64)),
            (
                "push_dir",
                self.push_dir
                    .as_ref()
                    .map(|p| Json::Str(p.display().to_string()))
                    .unwrap_or(Json::Null),
            ),
            ("push_chunk_bytes", Json::Num(self.push_chunk_bytes as f64)),
            (
                "push_staging_bytes",
                Json::Num(self.push_staging_bytes as f64),
            ),
            (
                "telemetry_interval_ms",
                Json::Num(self.telemetry_interval_ms as f64),
            ),
            (
                "metrics_listen",
                self.metrics_listen
                    .as_ref()
                    .map(|a| Json::Str(a.clone()))
                    .unwrap_or(Json::Null),
            ),
        ])
    }
}

/// Configuration of the store-affinity routing tier (`fastmps route`,
/// `router::Router`). The router fronts a fleet of FMPN backends: it
/// speaks FMPN to clients on its listen side (listener knobs come from
/// [`NetConfig`], exactly like a plain server) and FMPN to each backend
/// on the other, so neither side needs protocol changes.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// FMPN backend addresses (`host:port`). Order-insensitive:
    /// placement comes from rendezvous hashing over the address strings,
    /// not from list position — restarts with a reordered flag list keep
    /// the same store→backend affinity.
    pub backends: Vec<String>,
    /// Health-probe period (one `ping` round-trip per backend per tick).
    pub probe_interval_ms: u64,
    /// Consecutive probe/forward failures before a backend is `Degraded`
    /// (still routable, ranked after every `Alive` backend).
    pub degraded_after: u32,
    /// Consecutive failures before `Down` (excluded from routing until a
    /// probe succeeds again).
    pub down_after: u32,
    /// Total submit attempts across backends before the router replies
    /// with a typed `busy` frame of its own.
    pub retry_budget: usize,
    /// Base / cap of the capped exponential backoff between spillover
    /// retry cycles.
    pub backoff_base_ms: u64,
    pub backoff_cap_ms: u64,
    /// Max extra jitter added to each backoff sleep (de-correlates
    /// retrying clients).
    pub jitter_ms: u64,
    /// Cap on the graceful drain triggered by the `shutdown` op.
    pub drain_cap_secs: u64,
    /// Seed of the jitter stream (deterministic tests).
    pub seed: u64,
    /// Capacity (events) of the router's flight-recorder ring
    /// (`crate::trace`); 0 disables tracing.
    pub trace_buf: usize,
    /// Auto tensor-parallel threshold: when a pushed store's recorded
    /// blob size exceeds this many bytes and a complete shard group for
    /// it is registered, plain submits against it are rewritten into TP
    /// placements. 0 (the default) disables auto-TP — clients opt in per
    /// job with `--tp`.
    pub shard_budget_bytes: u64,
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig {
            backends: Vec::new(),
            probe_interval_ms: 250,
            degraded_after: 1,
            down_after: 3,
            retry_budget: 6,
            backoff_base_ms: 10,
            backoff_cap_ms: 500,
            jitter_ms: 10,
            drain_cap_secs: 600,
            seed: 0x5eed,
            trace_buf: crate::trace::DEFAULT_BUF,
            shard_budget_bytes: 0,
        }
    }
}

impl RouterConfig {
    pub fn validate(&self) -> Result<()> {
        if self.backends.is_empty() {
            return Err(Error::config("router: at least one --backend is required"));
        }
        for b in &self.backends {
            if b.is_empty() {
                return Err(Error::config("router: backend address must not be empty"));
            }
        }
        let mut seen = self.backends.clone();
        seen.sort();
        seen.dedup();
        if seen.len() != self.backends.len() {
            return Err(Error::config(
                "router: duplicate backend address (each backend routes once)",
            ));
        }
        if self.probe_interval_ms == 0 {
            return Err(Error::config("router: probe_interval_ms must be ≥ 1"));
        }
        if self.degraded_after == 0 || self.down_after < self.degraded_after {
            return Err(Error::config(
                "router: need down_after ≥ degraded_after ≥ 1",
            ));
        }
        if self.retry_budget == 0 {
            return Err(Error::config("router: retry_budget must be ≥ 1"));
        }
        if self.backoff_base_ms == 0 || self.backoff_cap_ms < self.backoff_base_ms {
            return Err(Error::config(
                "router: need backoff_cap_ms ≥ backoff_base_ms ≥ 1",
            ));
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "backends",
                Json::Arr(self.backends.iter().cloned().map(Json::Str).collect()),
            ),
            ("probe_interval_ms", Json::Num(self.probe_interval_ms as f64)),
            ("degraded_after", Json::Num(self.degraded_after as f64)),
            ("down_after", Json::Num(self.down_after as f64)),
            ("retry_budget", Json::Num(self.retry_budget as f64)),
            ("backoff_base_ms", Json::Num(self.backoff_base_ms as f64)),
            ("backoff_cap_ms", Json::Num(self.backoff_cap_ms as f64)),
            ("jitter_ms", Json::Num(self.jitter_ms as f64)),
            ("drain_cap_secs", Json::Num(self.drain_cap_secs as f64)),
            ("trace_buf", Json::Num(self.trace_buf as f64)),
            (
                "shard_budget_bytes",
                Json::Num(self.shard_budget_bytes as f64),
            ),
        ])
    }
}

/// Paper datasets (Table 1). `scale` shrinks (M, χ) to CPU-testbed size
/// while keeping ASP (and hence the dynamic-χ profile shape) intact.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Preset {
    Jiuzhang2,
    Jiuzhang3H,
    BorealisM216H,
    BorealisM288,
    M8176,
}

pub const ALL_PRESETS: [Preset; 5] = [
    Preset::Jiuzhang2,
    Preset::Jiuzhang3H,
    Preset::BorealisM216H,
    Preset::BorealisM288,
    Preset::M8176,
];

impl Preset {
    pub fn parse(s: &str) -> Result<Preset> {
        match s {
            "jiuzhang2" => Ok(Preset::Jiuzhang2),
            "jiuzhang3h" => Ok(Preset::Jiuzhang3H),
            "bm216h" => Ok(Preset::BorealisM216H),
            "bm288" => Ok(Preset::BorealisM288),
            "m8176" => Ok(Preset::M8176),
            _ => Err(Error::config(format!(
                "unknown preset '{s}' (jiuzhang2|jiuzhang3h|bm216h|bm288|m8176)"
            ))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Preset::Jiuzhang2 => "jiuzhang2",
            Preset::Jiuzhang3H => "jiuzhang3h",
            Preset::BorealisM216H => "bm216h",
            Preset::BorealisM288 => "bm288",
            Preset::M8176 => "m8176",
        }
    }

    /// `(M, ASP, Table-1 measured step ratio)` at paper scale.
    fn paper_params(self) -> (usize, f64, f64) {
        match self {
            Preset::Jiuzhang2 => (144, 1.62, 0.0),
            Preset::Jiuzhang3H => (144, 3.56, 0.4792),
            Preset::BorealisM216H => (216, 6.54, 0.5879),
            Preset::BorealisM288 => (288, 10.69, 0.7951),
            Preset::M8176 => (8176, 8.82, 0.7429),
        }
    }

    /// The paper-scale spec (χ = 10⁴, d = 4) — for analytic models only.
    pub fn full_spec(self, seed: u64) -> GbsSpec {
        let (m, asp, step) = self.paper_params();
        GbsSpec {
            name: format!("{}-full", self.name()),
            m,
            d: 4,
            chi_cap: 10_000,
            asp,
            // Eq. 5 decay tuned so f32 underflows near site ~3000 of the
            // M8176 run (Fig. 6): 10^-38 ≈ 10^{-k·3000} ⇒ k ≈ 0.0127.
            decay_k: 38.0 / 3000.0,
            displacement_sigma: 0.3,
            branch_skew: 0.0,
            seed,
            dynamic_chi: true,
            step_ratio_override: Some(step),
        }
    }

    /// CPU-testbed spec: same ASP/profile, shrunk M and χ.
    pub fn scaled_spec(self, seed: u64) -> GbsSpec {
        let (m_full, asp, step) = self.paper_params();
        let m = (m_full / 4).clamp(24, 512);
        GbsSpec {
            name: format!("{}-scaled", self.name()),
            m,
            d: 3,
            chi_cap: 96,
            asp,
            // Keep the same *total* decay across the chain as the full run
            // so the precision experiments see the same dynamic range.
            decay_k: (38.0 / 3000.0) * (m_full as f64 / m as f64).min(8.0),
            displacement_sigma: 0.3,
            branch_skew: 0.0,
            seed,
            dynamic_chi: true,
            step_ratio_override: Some(step),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_parse_roundtrip() {
        for p in ALL_PRESETS {
            assert_eq!(Preset::parse(p.name()).unwrap(), p);
        }
        assert!(Preset::parse("nope").is_err());
    }

    #[test]
    fn full_specs_match_paper_shapes() {
        let s = Preset::BorealisM288.full_spec(1);
        assert_eq!(s.m, 288);
        assert_eq!(s.chi_cap, 10_000);
        assert!((s.asp - 10.69).abs() < 1e-9);
        let m = Preset::M8176.full_spec(1);
        assert_eq!(m.m, 8176);
    }

    #[test]
    fn scaled_specs_are_testbed_sized() {
        for p in ALL_PRESETS {
            let s = p.scaled_spec(3);
            assert!(s.m <= 512 && s.m >= 24, "{}: M={}", s.name, s.m);
            assert!(s.chi_cap <= 128);
            // Generating the scaled chain must be feasible.
            assert!(s.m * s.chi_cap * s.chi_cap * s.d < 50_000_000);
        }
    }

    #[test]
    fn run_config_validation() {
        let spec = Preset::Jiuzhang2.scaled_spec(1);
        let mut cfg = RunConfig::new(spec);
        cfg.validate().unwrap();
        cfg.n2_micro = cfg.n1_macro + 1;
        assert!(cfg.validate().is_err());
        cfg.n2_micro = 64;
        cfg.p1 = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn enums_parse() {
        assert_eq!(ComputePrecision::parse("tf32").unwrap(), ComputePrecision::Tf32);
        assert_eq!(ScalingMode::parse("per-sample").unwrap(), ScalingMode::PerSample);
        assert_eq!(EngineKind::parse("xla").unwrap(), EngineKind::Xla);
        assert!(ComputePrecision::parse("q8").is_err());
        assert!(ScalingMode::parse("?").is_err());
        assert!(EngineKind::parse("?").is_err());
    }

    #[test]
    fn service_config_validation() {
        let mut s = ServiceConfig::default();
        s.validate().unwrap();
        s.target_batch = Some(8); // below the default N₂ = 256
        assert!(s.validate().is_err());
        s.target_batch = None;
        s.workers = 0;
        assert!(s.validate().is_err());
        let j = ServiceConfig::default().to_json();
        assert_eq!(j.get("engine").unwrap().as_str(), Some("native"));
    }

    #[test]
    fn net_config_validation() {
        let n = NetConfig::default();
        n.validate().unwrap();
        assert_eq!(n.to_json().get("max_conns").unwrap().as_usize(), Some(64));
        assert_eq!(
            n.to_json().get("telemetry_interval_ms").unwrap().as_usize(),
            Some(1000)
        );
        assert_eq!(n.to_json().get("metrics_listen"), Some(&Json::Null));
        let bad = NetConfig {
            telemetry_interval_ms: 5,
            ..NetConfig::default()
        };
        assert!(bad.validate().is_err(), "sub-10ms sampling");
        let bad = NetConfig {
            metrics_listen: Some(String::new()),
            ..NetConfig::default()
        };
        assert!(bad.validate().is_err(), "empty metrics_listen");
        let ok = NetConfig {
            metrics_listen: Some("127.0.0.1:0".into()),
            ..NetConfig::default()
        };
        ok.validate().unwrap();
        let bad = NetConfig {
            max_conns: 0,
            ..NetConfig::default()
        };
        assert!(bad.validate().is_err());
        let bad = NetConfig {
            max_frame_bytes: 16,
            ..NetConfig::default()
        };
        assert!(bad.validate().is_err());
        let bad = NetConfig {
            addr: String::new(),
            ..NetConfig::default()
        };
        assert!(bad.validate().is_err());
        let bad = NetConfig {
            read_timeout_ms: 0,
            ..NetConfig::default()
        };
        assert!(bad.validate().is_err());
        let bad = NetConfig {
            push_chunk_bytes: 16,
            ..NetConfig::default()
        };
        assert!(bad.validate().is_err(), "tiny chunk");
        let bad = NetConfig {
            push_chunk_bytes: 60 << 20, // over half the 64 MiB frame cap
            ..NetConfig::default()
        };
        assert!(bad.validate().is_err(), "chunk vs frame cap");
        let bad = NetConfig {
            push_staging_bytes: 1,
            ..NetConfig::default()
        };
        assert!(bad.validate().is_err(), "staging below chunk");
        let n = NetConfig::default();
        assert_eq!(n.to_json().get("push_dir"), Some(&Json::Null));
    }

    #[test]
    fn router_config_validation() {
        let mut r = RouterConfig {
            backends: vec!["127.0.0.1:7734".into(), "127.0.0.1:7735".into()],
            ..RouterConfig::default()
        };
        r.validate().unwrap();
        assert_eq!(
            r.to_json().get("backends").unwrap().as_arr().map(|a| a.len()),
            Some(2)
        );
        r.backends.clear();
        assert!(r.validate().is_err(), "no backends");
        r.backends = vec!["a:1".into(), "a:1".into()];
        assert!(r.validate().is_err(), "duplicate backends");
        r.backends = vec!["a:1".into()];
        r.down_after = 0;
        assert!(r.validate().is_err(), "down_after below degraded_after");
        r.down_after = 3;
        r.retry_budget = 0;
        assert!(r.validate().is_err(), "zero retry budget");
        r.retry_budget = 1;
        r.backoff_cap_ms = 1;
        r.backoff_base_ms = 2;
        assert!(r.validate().is_err(), "cap below base");
    }

    #[test]
    fn config_json_has_key_fields() {
        let cfg = RunConfig::new(Preset::M8176.scaled_spec(1));
        let j = cfg.to_json();
        assert!(j.get("n_samples").is_some());
        assert_eq!(j.get("engine").unwrap().as_str(), Some("native"));
    }
}
