//! The FastMPS coordinators — the paper's system contribution (L3).
//!
//! - [`data_parallel`]: Fig. 3 — the revived data-parallel scheme: p₁
//!   workers each walk their own macro batches through all M sites while
//!   rank 0 streams + broadcasts Γ with double-buffered overlap (Eq. 2).
//! - [`model_parallel`]: Fig. 2 — the baseline of [19]: one rank per site,
//!   macro-batch pipeline with non-blocking sends (Eq. 1). Implemented as
//!   the comparator for Tables 2/3.
//! - [`tensor_parallel`]: Fig. 4 — χ-axis tensor parallelism inside a
//!   group: split-K GEMM with AllReduce (double-site) or ReduceScatter
//!   (single-site) collectives (Eqs. 4/7).
//! - [`scheduler`]: macro/micro batch planning under the Eq. 3 memory
//!   model.

pub mod data_parallel;
pub mod model_parallel;
pub mod scheduler;
pub mod tensor_parallel;

use crate::config::{EngineKind, RunConfig};
use crate::metrics::Metrics;
use crate::mps::Site;
use crate::sampler::native::NativeEngine;
use crate::sampler::sink::SampleSink;
use crate::sampler::StepEngine;
use crate::tensor::SplitBuf;
use crate::util::error::Result;

/// Engine dispatch (constructed per worker thread; the XLA client is not
/// Send).
pub enum EngineBox {
    Native(NativeEngine),
    Xla(Box<crate::runtime::XlaEngine>),
}

impl EngineBox {
    pub fn build(cfg: &RunConfig) -> Result<EngineBox> {
        match cfg.engine {
            EngineKind::Native => {
                let mut e = NativeEngine::new(cfg.compute, cfg.scaling, cfg.gemm_threads);
                e.split = cfg.gemm_split;
                e.layout = cfg.layout;
                Ok(EngineBox::Native(e))
            }
            EngineKind::Xla => {
                let mut e = crate::runtime::XlaEngine::new(&cfg.artifacts_dir)?;
                e.prefer_tf32 = cfg.compute == crate::config::ComputePrecision::Tf32;
                Ok(EngineBox::Xla(Box::new(e)))
            }
        }
    }

    pub fn metrics(&self) -> &Metrics {
        match self {
            EngineBox::Native(e) => &e.metrics,
            EngineBox::Xla(e) => &e.metrics,
        }
    }

    /// The precision-pipeline key a [`PreparedSite`] must be built with
    /// for this engine, or `None` when the engine consumes raw sites (the
    /// PJRT path does its own device staging).
    pub fn prep_key(&self) -> Option<crate::sampler::PrepKey> {
        match self {
            EngineBox::Native(e) => Some(e.prep_key()),
            EngineBox::Xla(_) => None,
        }
    }

    /// Step through the allocation-free prepared path when one is
    /// available, falling back to the raw-site path otherwise. Callers
    /// prepare once per site (via `prep_key`) and reuse across micro
    /// batches — that is where the per-step Γ clone/convert dies. A fully
    /// resident walk may pass `site: None`; engines without a prepared
    /// path then error instead of silently recomputing.
    pub fn step_site(
        &mut self,
        env: &mut SplitBuf,
        site: Option<&Site>,
        prepared: Option<&crate::sampler::PreparedSite>,
        thresholds: &[f32],
        displacements: Option<&[(f64, f64)]>,
        samples: &mut Vec<i32>,
    ) -> Result<()> {
        match (self, prepared) {
            (EngineBox::Native(e), Some(p)) => {
                e.step_prepared(env, p, thresholds, displacements, samples)
            }
            (me, _) => {
                let site = site.ok_or_else(|| {
                    crate::util::error::Error::other(
                        "step_site: engine has no prepared path and no raw site was given",
                    )
                })?;
                me.step(env, site, thresholds, displacements, samples)
            }
        }
    }

    pub fn dead_rows(&self) -> u64 {
        match self {
            EngineBox::Native(e) => e.dead_rows,
            EngineBox::Xla(_) => 0,
        }
    }

    /// Drain accumulated (metrics, dead-row count) and reset the engine's
    /// accounting to zero, so a resident engine can be reused across
    /// service jobs without double counting. The engine's compiled-kernel /
    /// executable caches survive — that reuse is the point of keeping the
    /// engine alive between runs.
    pub fn drain(&mut self) -> (Metrics, u64) {
        match self {
            EngineBox::Native(e) => {
                let m = std::mem::take(&mut e.metrics);
                let d = std::mem::replace(&mut e.dead_rows, 0);
                (m, d)
            }
            EngineBox::Xla(e) => (std::mem::take(&mut e.metrics), 0),
        }
    }
}

impl StepEngine for EngineBox {
    fn step(
        &mut self,
        env: &mut SplitBuf,
        site: &Site,
        thresholds: &[f32],
        displacements: Option<&[(f64, f64)]>,
        samples: &mut Vec<i32>,
    ) -> Result<()> {
        match self {
            EngineBox::Native(e) => e.step(env, site, thresholds, displacements, samples),
            EngineBox::Xla(e) => e.step(env, site, thresholds, displacements, samples),
        }
    }

    fn name(&self) -> &'static str {
        match self {
            EngineBox::Native(_) => "native",
            EngineBox::Xla(_) => "xla",
        }
    }
}

/// Result of a coordinated sampling run.
pub struct RunReport {
    pub metrics: Metrics,
    pub sink: SampleSink,
    /// Max virtual (modelled-network) seconds across ranks.
    pub vtime: f64,
    /// Wall seconds of the whole run.
    pub wall: f64,
    /// Underflow-collapsed rows observed (native engines only).
    pub dead_rows: u64,
    /// (site, per-sample (max, max/min)) probes for Fig. 5.
    pub env_probes: Vec<(usize, Vec<(f64, f64)>)>,
}

/// Extract a row range [a, b) of a (n, c) SplitBuf.
pub(crate) fn env_rows(env: &SplitBuf, a: usize, b: usize) -> SplitBuf {
    let c = env.shape[1];
    SplitBuf {
        shape: vec![b - a, c],
        re: env.re[a * c..b * c].to_vec(),
        im: env.im[a * c..b * c].to_vec(),
    }
}

/// Write back a row range (possibly with a new column count).
pub(crate) fn env_store_rows(dst: &mut SplitBuf, a: usize, rows: &SplitBuf) {
    let c = rows.shape[1];
    debug_assert_eq!(dst.shape[1], c);
    let n = rows.shape[0];
    dst.re[a * c..(a + n) * c].copy_from_slice(&rows.re);
    dst.im[a * c..(a + n) * c].copy_from_slice(&rows.im);
}

/// Per-sample (max, max/min) magnitudes of a SplitBuf env — Fig. 5 probes.
pub(crate) fn env_probe(env: &SplitBuf) -> Vec<(f64, f64)> {
    let (n, c) = (env.shape[0], env.shape[1]);
    let mut out = Vec::with_capacity(n);
    for r in 0..n {
        let mut maxv = 0.0f64;
        let mut minv = f64::INFINITY;
        for i in r * c..(r + 1) * c {
            let a = ((env.re[i] as f64).powi(2) + (env.im[i] as f64).powi(2)).sqrt();
            if a > maxv {
                maxv = a;
            }
            if a > 0.0 && a < minv {
                minv = a;
            }
        }
        let ratio = if minv.is_finite() && minv > 0.0 {
            maxv / minv
        } else {
            f64::INFINITY
        };
        out.push((maxv, ratio));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn env_row_roundtrip() {
        let mut e = SplitBuf::zeros(&[4, 3]);
        for (i, v) in e.re.iter_mut().enumerate() {
            *v = i as f32;
        }
        let rows = env_rows(&e, 1, 3);
        assert_eq!(rows.shape, vec![2, 3]);
        assert_eq!(rows.re[0], 3.0);
        let mut dst = SplitBuf::zeros(&[4, 3]);
        env_store_rows(&mut dst, 1, &rows);
        assert_eq!(dst.re[3], 3.0);
        assert_eq!(dst.re[0], 0.0);
    }

    #[test]
    fn engine_drain_resets_accounting() {
        let cfg = RunConfig::new(crate::config::Preset::Jiuzhang2.scaled_spec(1));
        let mut e = EngineBox::build(&cfg).unwrap();
        if let EngineBox::Native(n) = &mut e {
            n.metrics.add(crate::metrics::keys::FLOPS, 7);
            n.dead_rows = 3;
        }
        let (m, d) = e.drain();
        assert_eq!(m.get(crate::metrics::keys::FLOPS), 7);
        assert_eq!(d, 3);
        assert_eq!(e.metrics().get(crate::metrics::keys::FLOPS), 0);
        assert_eq!(e.dead_rows(), 0);
    }

    #[test]
    fn probe_reports_ranges() {
        let mut e = SplitBuf::zeros(&[1, 2]);
        e.re[0] = 2.0;
        e.im[1] = 0.5;
        let p = env_probe(&e);
        assert!((p[0].0 - 2.0).abs() < 1e-9);
        assert!((p[0].1 - 4.0).abs() < 1e-9);
    }
}
