//! Tensor parallelism along the bond dimension (Fig. 4, Eqs. 4/7).
//!
//! `p₂` ranks of a group cooperate on the *same* samples, with Γ split
//! along χ. Two schemes, chosen by interconnect (§4.3):
//!
//! - **double-site** (`AllReduce`, Fig. 4a): odd sites do a split-K GEMM
//!   over χ_l shards and AllReduce the full unmeasured temp (one big
//!   collective per *two* sites); measurement then runs redundantly on all
//!   ranks. Even sites slice Γ along χ_r so the GEMM is local and only a
//!   (N·d)-sized probability AllReduce is needed.
//! - **single-site** (`ReduceScatter`, Fig. 4b): every site reduces the
//!   split-K partials and scatters χ_r shards in one op; sampling
//!   decisions use an additional tiny probability AllReduce.
//!
//! Per-sample rescaling across shards uses a max-AllReduce of the N row
//! maxima (tiny). Bonds are zero-padded to multiples of p₂ (exact).
//!
//! Compute runs on the native f64 path; outcome statistics are recorded on
//! rank 0 (every rank makes identical decisions from the shared
//! thresholds).

use std::sync::Arc;

use crate::comm::{Endpoint, Fabric};
use crate::config::RunConfig;
use crate::coordinator::scheduler::BatchPlan;
use crate::coordinator::RunReport;
use crate::io::{DiskModel, GammaStore};
use crate::linalg::contract_env;
use crate::metrics::{keys, Metrics};
use crate::mps::Site;

use crate::sampler::sink::SampleSink;
use crate::tensor::{Complex, Mat, Tensor3, C64};
use crate::util::ceil_div;
use crate::util::error::{Error, Result};

/// Environment state within the TP walk.
enum TpEnv {
    /// (N, χ) on every rank.
    Full(Mat<f64>),
    /// (N, χ/p₂): this rank's bond shard.
    Sharded(Mat<f64>),
}

/// Pad a site's bonds up to multiples of `p2` (zero columns/rows — exact
/// for contraction and measurement).
fn pad_site(site: &Site, p2: usize, pad_left: bool) -> Site {
    let g = &site.gamma;
    let xl = if pad_left {
        ceil_div(g.d0, p2) * p2
    } else {
        g.d0
    };
    let yr = ceil_div(g.d1, p2) * p2;
    let mut gamma = Tensor3::zeros(xl, yr, g.d2);
    for i in 0..g.d0 {
        for j in 0..g.d1 {
            for k in 0..g.d2 {
                *gamma.at_mut(i, j, k) = g.at(i, j, k);
            }
        }
    }
    let mut lambda = vec![0.0; yr];
    lambda[..site.lambda.len()].copy_from_slice(&site.lambda);
    Site { gamma, lambda }
}

fn mat_to_f32(m: &Mat<f64>) -> Vec<f32> {
    let mut out = Vec::with_capacity(m.data.len() * 2);
    for z in &m.data {
        out.push(z.re as f32);
        out.push(z.im as f32);
    }
    out
}

fn f32_to_mat(buf: &[f32], rows: usize, cols: usize) -> Mat<f64> {
    let mut m = Mat::zeros(rows, cols);
    for (i, z) in m.data.iter_mut().enumerate() {
        *z = C64::new(buf[2 * i] as f64, buf[2 * i + 1] as f64);
    }
    m
}

fn tensor_to_f32(t: &Tensor3<f64>) -> Vec<f32> {
    let mut out = Vec::with_capacity(t.data.len() * 2);
    for z in &t.data {
        out.push(z.re as f32);
        out.push(z.im as f64 as f32);
    }
    out
}

fn f32_to_tensor(buf: &[f32], a: usize, b: usize, c: usize) -> Tensor3<f64> {
    let mut t = Tensor3::zeros(a, b, c);
    for (i, z) in t.data.iter_mut().enumerate() {
        *z = C64::new(buf[2 * i] as f64, buf[2 * i + 1] as f64);
    }
    t
}

/// Measurement from a (N, Y, d) temp given Λ and thresholds, with partial
/// probability support: `probs_partial` are summed across ranks by the
/// caller before the decision. Returns (env, samples).
fn partial_probs(temp: &Tensor3<f64>, lambda: &[f64]) -> Vec<f32> {
    let (n, y, d) = (temp.d0, temp.d1, temp.d2);
    let mut probs = vec![0.0f32; n * d];
    for s in 0..n {
        let panel = temp.panel(s);
        for yy in 0..y {
            let lam = lambda[yy];
            if lam == 0.0 {
                continue;
            }
            for j in 0..d {
                probs[s * d + j] += (panel[yy * d + j].norm_sq() * lam) as f32;
            }
        }
    }
    probs
}

fn decide(probs: &[f32], d: usize, thresholds: &[f32]) -> Vec<i32> {
    let n = thresholds.len();
    let mut out = vec![0i32; n];
    for s in 0..n {
        let row = &probs[s * d..(s + 1) * d];
        let tot: f32 = row.iter().sum();
        if tot <= 0.0 {
            continue;
        }
        let mut cum = 0.0f32;
        let mut k = 0i32;
        for &p in row {
            cum += p / tot;
            if thresholds[s] > cum {
                k += 1;
            }
        }
        out[s] = k.min(d as i32 - 1);
    }
    out
}

/// Gather the collapsed env from temp at the decided outcomes.
fn collapse(temp: &Tensor3<f64>, samples: &[i32]) -> Mat<f64> {
    let (n, y, d) = (temp.d0, temp.d1, temp.d2);
    let mut env = Mat::zeros(n, y);
    for s in 0..n {
        let o = samples[s] as usize;
        let panel = temp.panel(s);
        let row = env.row_mut(s);
        for yy in 0..y {
            row[yy] = panel[yy * d + o];
        }
    }
    env
}

/// Per-sample rescale with a cross-shard max-AllReduce.
fn rescale_sharded(env: &mut Mat<f64>, ep: &mut Endpoint) {
    let n = env.rows;
    let mut maxima = vec![0.0f32; n];
    for s in 0..n {
        let mut m2 = 0.0f64;
        for z in env.row(s) {
            m2 = m2.max(z.norm_sq());
        }
        maxima[s] = m2.sqrt() as f32;
    }
    ep.allreduce_max(&mut maxima);
    for s in 0..n {
        let m = maxima[s] as f64;
        if m > 0.0 {
            let inv = 1.0 / m;
            for z in env.row_mut(s) {
                *z = z.scale(inv);
            }
        }
    }
}

struct TpWorker<'a> {
    ep: Endpoint,
    p2: usize,
    cfg: &'a RunConfig,
    metrics: Metrics,
}

impl TpWorker<'_> {
    /// Advance the virtual clock by modelled or measured compute time.
    fn advance_compute(&mut self, wall: f64, flops: u64) {
        self.ep.advance(match self.cfg.vdevice_flops {
            Some(r) => flops as f64 / r,
            None => wall,
        });
    }

    /// Local-GEMM site (env Full, Γ sliced along χ_r).
    fn site_local(
        &mut self,
        env: &Mat<f64>,
        site: &Site,
        thresholds: &[f32],
    ) -> Result<(Mat<f64>, Vec<i32>)> {
        let p2 = self.p2;
        let r = self.ep.rank;
        let padded = pad_site(site, p2, false);
        let yk = padded.gamma.d1 / p2;
        let gslice = padded.gamma.slice_d1(r * yk, (r + 1) * yk)?;
        let lam = &padded.lambda[r * yk..(r + 1) * yk];

        let t0 = std::time::Instant::now();
        let temp = contract_env(env, &gslice, self.cfg.gemm_threads)?;
        let dt = t0.elapsed().as_secs_f64();
        self.metrics.add_phase("compute", dt);
        let flops = crate::linalg::matmul_flops(env.rows, gslice.d0, gslice.d1 * gslice.d2);
        self.advance_compute(dt, flops);
        self.metrics.add(keys::FLOPS, flops);

        let tm = std::time::Instant::now();
        let mut probs = partial_probs(&temp, lam);
        let m_flops = 8 * (temp.d0 * temp.d1 * temp.d2) as u64;
        self.advance_compute(tm.elapsed().as_secs_f64(), m_flops);
        let t1 = std::time::Instant::now();
        self.ep.allreduce_sum(&mut probs);
        self.metrics.add_phase("comm", t1.elapsed().as_secs_f64());
        let samples = decide(&probs, temp.d2, thresholds);
        let mut env_slice = collapse(&temp, &samples);
        rescale_sharded(&mut env_slice, &mut self.ep);
        Ok((env_slice, samples))
    }

    /// Split-K site, double-site flavour: AllReduce the full temp.
    fn site_splitk_allreduce(
        &mut self,
        env_shard: &Mat<f64>,
        site: &Site,
        thresholds: &[f32],
    ) -> Result<(Mat<f64>, Vec<i32>)> {
        let p2 = self.p2;
        let r = self.ep.rank;
        let padded = pad_site(site, p2, true);
        let xk = padded.gamma.d0 / p2;
        let grows = padded.gamma.slice_d0(r * xk, (r + 1) * xk)?;

        let t0 = std::time::Instant::now();
        let partial = contract_env(env_shard, &grows, self.cfg.gemm_threads)?;
        let dt = t0.elapsed().as_secs_f64();
        self.metrics.add_phase("compute", dt);
        let flops = crate::linalg::matmul_flops(env_shard.rows, grows.d0, grows.d1 * grows.d2);
        self.advance_compute(dt, flops);
        self.metrics.add(keys::FLOPS, flops);

        let mut flat = tensor_to_f32(&partial);
        let t1 = std::time::Instant::now();
        self.ep.allreduce_sum(&mut flat);
        self.metrics.add_phase("comm", t1.elapsed().as_secs_f64());
        let temp = f32_to_tensor(&flat, partial.d0, partial.d1, partial.d2);

        // Redundant (non-distributed) measurement — the double-site
        // overhead the paper quantifies.
        let t2 = std::time::Instant::now();
        let probs = partial_probs(&temp, &padded.lambda);
        // Redundant full-χ measurement: every rank pays it (the paper's
        // double-site measurement overhead).
        let m_flops = 8 * (temp.d0 * temp.d1 * temp.d2) as u64;
        self.advance_compute(1e-12, m_flops);
        let samples = decide(&probs, temp.d2, thresholds);
        let env_padded = collapse(&temp, &samples);
        // Crop the zero padding columns so the next (unpadded-χ_l) site
        // sees the true bond dimension.
        let y_true = site.gamma.d1;
        let mut env = Mat::zeros(env_padded.rows, y_true);
        for s in 0..env_padded.rows {
            env.row_mut(s)
                .copy_from_slice(&env_padded.row(s)[..y_true]);
        }
        crate::sampler::measurement::apply_scaling(
            &mut env,
            crate::config::ScalingMode::PerSample,
        );
        self.metrics
            .add_phase("measure", t2.elapsed().as_secs_f64());
        Ok((env, samples))
    }

    /// Split-K site, single-site flavour: ReduceScatter to own χ_r shard.
    fn site_splitk_reduce_scatter(
        &mut self,
        env_shard: &Mat<f64>,
        site: &Site,
        thresholds: &[f32],
    ) -> Result<(Mat<f64>, Vec<i32>)> {
        let p2 = self.p2;
        let r = self.ep.rank;
        let padded = pad_site(site, p2, true);
        let xk = padded.gamma.d0 / p2;
        let grows = padded.gamma.slice_d0(r * xk, (r + 1) * xk)?;

        let t0 = std::time::Instant::now();
        let partial = contract_env(env_shard, &grows, self.cfg.gemm_threads)?;
        let dt = t0.elapsed().as_secs_f64();
        self.metrics.add_phase("compute", dt);
        let flops = crate::linalg::matmul_flops(env_shard.rows, grows.d0, grows.d1 * grows.d2);
        self.advance_compute(dt, flops);
        self.metrics.add(keys::FLOPS, flops);

        // y-major flatten so ReduceScatter chunks are χ_r slices.
        let (n, y, d) = (partial.d0, partial.d1, partial.d2);
        let mut ymajor = vec![0.0f32; 2 * n * y * d];
        for s in 0..n {
            let panel = partial.panel(s);
            for yy in 0..y {
                for k in 0..d {
                    let z = panel[yy * d + k];
                    let dst = 2 * ((yy * n + s) * d + k);
                    ymajor[dst] = z.re as f32;
                    ymajor[dst + 1] = z.im as f32;
                }
            }
        }
        let yk = y / p2;
        let mut own = vec![0.0f32; 2 * yk * n * d];
        let t1 = std::time::Instant::now();
        self.ep.reduce_scatter_sum(&ymajor, &mut own)?;
        self.metrics.add_phase("comm", t1.elapsed().as_secs_f64());

        // Own reduced slice as (n, yk, d).
        let mut temp = Tensor3::zeros(n, yk, d);
        for yy in 0..yk {
            for s in 0..n {
                for k in 0..d {
                    let src = 2 * ((yy * n + s) * d + k);
                    *temp.at_mut(s, yy, k) =
                        C64::new(own[src] as f64, own[src + 1] as f64);
                }
            }
        }
        let lam = &padded.lambda[r * yk..(r + 1) * yk];
        let tm = std::time::Instant::now();
        let mut probs = partial_probs(&temp, lam);
        self.advance_compute(tm.elapsed().as_secs_f64(), 8 * (n * yk * d) as u64);
        let t2 = std::time::Instant::now();
        self.ep.allreduce_sum(&mut probs);
        self.metrics.add_phase("comm", t2.elapsed().as_secs_f64());
        let samples = decide(&probs, d, thresholds);
        let mut env_slice = collapse(&temp, &samples);
        rescale_sharded(&mut env_slice, &mut self.ep);
        Ok((env_slice, samples))
    }
}

/// Run tensor-parallel sampling on one group of `cfg.p2` ranks.
pub fn run(cfg: &RunConfig, store: &Arc<GammaStore>) -> Result<RunReport> {
    cfg.validate()?;
    let p2 = cfg.p2;
    let m = store.spec.m();
    let spec = store.spec.clone();
    if spec.has_displacement() {
        return Err(Error::config(
            "tensor-parallel path does not support displacement yet (use p2=1)",
        ));
    }
    let plan = BatchPlan::build(cfg.n_samples, 1, cfg.n1_macro, cfg.n2_micro)?;
    let batches = plan.for_worker(0);
    let disk = match cfg.disk_bw {
        Some(bw) => DiskModel::throttled(bw, false),
        None => DiskModel::unlimited(),
    };

    let endpoints = Fabric::new(p2, cfg.net).endpoints();
    let wall0 = std::time::Instant::now();

    let results: Vec<Result<(Metrics, SampleSink, f64)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = endpoints
            .into_iter()
            .map(|ep| {
                let store = store.clone();
                let spec = spec.clone();
                let disk = disk.clone();
                let batches = batches.clone();
                scope.spawn(move || {
                    let mut w = TpWorker {
                        ep,
                        p2,
                        cfg,
                        metrics: Metrics::new(),
                    };
                    let mut sink = SampleSink::new(m, spec.d(), spec.sink_max_gap());
                    for b in &batches {
                        sink.reset_walk();
                        let mut env = TpEnv::Full(boundary_mat(b.len));
                        for (site_idx, _) in (0..m).enumerate() {
                            let io = disk.charge(store.site_bytes(site_idx));
                            w.ep.advance(io);
                            w.metrics.add(keys::IO_BYTES, store.site_bytes(site_idx));
                            let site = store.load_site(site_idx)?;
                            let th = spec.thresholds(site_idx, b.sample0, b.len);

                            let (next, samples) = match (&env, cfg.double_site) {
                                // Full env: local slice GEMM (even sites of
                                // the double-site scheme; site 0 otherwise).
                                (TpEnv::Full(e), _) => {
                                    let (s_env, s) = w.site_local(e, &site, &th)?;
                                    (TpEnv::Sharded(s_env), s)
                                }
                                (TpEnv::Sharded(e), true) => {
                                    let (f_env, s) =
                                        w.site_splitk_allreduce(e, &site, &th)?;
                                    (TpEnv::Full(f_env), s)
                                }
                                (TpEnv::Sharded(e), false) => {
                                    let (s_env, s) =
                                        w.site_splitk_reduce_scatter(e, &site, &th)?;
                                    (TpEnv::Sharded(s_env), s)
                                }
                            };
                            env = next;
                            if w.ep.rank == 0 {
                                sink.record(site_idx, &samples);
                            }
                        }
                        w.metrics.add(keys::SAMPLES, b.len as u64);
                        w.metrics.add(keys::MACRO_BATCHES, 1);
                    }
                    w.metrics.add(keys::COMM_BYTES, w.ep.comm_bytes);
                    w.metrics.add(keys::COLLECTIVES, w.ep.collectives);
                    Ok((w.metrics, sink, w.ep.vtime))
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let wall = wall0.elapsed().as_secs_f64();
    let mut metrics = Metrics::new();
    let mut sink = SampleSink::new(m, spec.d(), spec.sink_max_gap());
    let mut vtime: f64 = 0.0;
    for r in results {
        let (wm, ws, wv) = r?;
        metrics.merge(&wm);
        sink.merge(&ws);
        vtime = vtime.max(wv);
    }
    Ok(RunReport {
        metrics,
        sink,
        vtime,
        wall,
        dead_rows: 0,
        env_probes: Vec::new(),
    })
}

fn boundary_mat(n: usize) -> Mat<f64> {
    let mut m = Mat::zeros(n, 1);
    for z in &mut m.data {
        *z = Complex::one();
    }
    m
}

/// §4.3's decision benchmark: measure (virtual) AllReduce vs ReduceScatter
/// bandwidth on a fabric preset and report which scheme Eq. 7 prefers.
pub fn comm_bench(preset: crate::comm::NetPreset, bytes: u64, p2: usize) -> (f64, f64, bool) {
    let model = preset.model();
    let t_ar = model.cost_allreduce(bytes, p2);
    let t_rs = model.cost_reduce_scatter(bytes, p2);
    // Double-site halves collective count but moves d× more data; at equal
    // bytes the paper's criterion reduces to B_a vs B_r with the measure
    // redundancy folded into Eq. 7 — here we report raw times.
    (t_ar, t_rs, t_ar <= t_rs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ComputePrecision, EngineKind, Preset, RunConfig, ScalingMode};
    use crate::io::{StoreCodec, StorePrecision};

    fn test_store(tag: &str, m: usize, chi: usize) -> (Arc<GammaStore>, std::path::PathBuf) {
        let dir = std::env::temp_dir().join(format!("fastmps-tp-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut spec = Preset::Jiuzhang2.scaled_spec(19);
        spec.m = m;
        spec.chi_cap = chi;
        spec.decay_k = 0.0;
        spec.displacement_sigma = 0.0;
        let store = Arc::new(
            GammaStore::create(&dir, &spec, StorePrecision::F32, StoreCodec::Raw).unwrap(),
        );
        (store, dir)
    }

    fn tp_cfg(store: &GammaStore, p2: usize, double: bool, n: u64) -> RunConfig {
        let mut cfg = RunConfig::new(store.spec.clone());
        cfg.n_samples = n;
        cfg.n1_macro = 32;
        cfg.n2_micro = 32;
        cfg.p2 = p2;
        cfg.double_site = double;
        cfg.engine = EngineKind::Native;
        cfg.compute = ComputePrecision::F64;
        cfg.scaling = ScalingMode::PerSample;
        cfg
    }

    #[test]
    fn double_site_matches_single_rank_statistics() {
        let (store, dir) = test_store("ds", 6, 8);
        let solo = crate::coordinator::data_parallel::run(
            &tp_cfg(&store, 1, true, 64),
            &store,
            &[],
        )
        .unwrap();
        let tp = run(&tp_cfg(&store, 2, true, 64), &store).unwrap();
        assert_eq!(tp.sink.hist, solo.sink.hist, "TP must not change outcomes");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn single_site_matches_single_rank_statistics() {
        let (store, dir) = test_store("ss", 6, 8);
        let solo = crate::coordinator::data_parallel::run(
            &tp_cfg(&store, 1, true, 64),
            &store,
            &[],
        )
        .unwrap();
        let tp = run(&tp_cfg(&store, 2, false, 64), &store).unwrap();
        assert_eq!(tp.sink.hist, solo.sink.hist);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn four_rank_group_works() {
        let (store, dir) = test_store("p4", 4, 12);
        let tp = run(&tp_cfg(&store, 4, true, 32), &store).unwrap();
        assert_eq!(tp.sink.total_samples(), 32);
        assert!(tp.metrics.get(keys::COLLECTIVES) > 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn comm_bench_prefers_double_on_nvlink() {
        let (ar, rs, double) = comm_bench(crate::comm::NetPreset::NvLink3, 64 << 20, 4);
        assert!(double, "AllReduce {ar} vs ReduceScatter {rs} on NVLink3");
        let (_, _, double_ib) = comm_bench(crate::comm::NetPreset::InfinibandHdr, 64 << 20, 4);
        assert!(!double_ib, "symmetric networks prefer ReduceScatter");
    }

    #[test]
    fn displacement_rejected() {
        let (store, dir) = test_store("disp", 4, 8);
        let mut cfg = tp_cfg(&store, 2, true, 16);
        let mut gbs = store.spec.as_gbs().unwrap().clone();
        gbs.displacement_sigma = 0.5;
        let store2 = Arc::new(GammaStore {
            spec: (&gbs).into(),
            ..(*store).clone()
        });
        cfg.spec = gbs.into();
        assert!(run(&cfg, &store2).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
