//! The model-parallel baseline of Oh et al. [19] (Fig. 2, Eq. 1) —
//! implemented as the comparator for Tables 2/3 and the §2.2 critique.
//!
//! One rank per site, each holding exactly one Γ (loaded once at startup —
//! which is where the disk-contention spike lives: all M ranks read
//! concurrently). Macro batches flow down the chain: rank `i` receives the
//! left environment of batch `b` from rank `i−1`, contracts + measures its
//! site, and forwards (non-blocking) while starting the next batch. The
//! pipeline-fill cost — the last rank idles for `M−1` steps — and the
//! `O(N·M·χ)` point-to-point traffic are both structural; this
//! implementation reproduces them faithfully (including the baseline's
//! FP64 compute and *global* auto-scaling).

use std::sync::Arc;

use crate::comm::Fabric;
use crate::config::RunConfig;
use crate::coordinator::scheduler::BatchPlan;
use crate::coordinator::{EngineBox, RunReport};
use crate::io::{DiskModel, GammaStore};
use crate::metrics::{keys, Metrics};
use crate::sampler::sink::SampleSink;
use crate::sampler::{boundary_env, StepEngine};
use crate::tensor::SplitBuf;
use crate::util::error::{Error, Result};

/// Serialize an env for the pipeline: [rows, cols, re.., im..].
fn pack_env(env: &SplitBuf) -> Vec<f32> {
    let mut out = Vec::with_capacity(2 + env.re.len() * 2);
    out.push(env.shape[0] as f32);
    out.push(env.shape[1] as f32);
    out.extend_from_slice(&env.re);
    out.extend_from_slice(&env.im);
    out
}

fn unpack_env(buf: &[f32]) -> Result<SplitBuf> {
    if buf.len() < 2 {
        return Err(Error::format("packed env too short"));
    }
    let (n, c) = (buf[0] as usize, buf[1] as usize);
    if buf.len() != 2 + 2 * n * c {
        return Err(Error::format("packed env size mismatch"));
    }
    Ok(SplitBuf {
        shape: vec![n, c],
        re: buf[2..2 + n * c].to_vec(),
        im: buf[2 + n * c..].to_vec(),
    })
}

/// Run the baseline: `p = M` ranks, macro-batch pipeline.
pub fn run(cfg: &RunConfig, store: &Arc<GammaStore>) -> Result<RunReport> {
    cfg.validate()?;
    let m = store.spec.m();
    let spec = store.spec.clone();
    let plan = BatchPlan::build(cfg.n_samples, 1, cfg.n1_macro, cfg.n2_micro)?;
    let batches = plan.for_worker(0);
    let disk = match cfg.disk_bw {
        Some(bw) => DiskModel::throttled(bw, false),
        None => DiskModel::unlimited(),
    };

    let endpoints = Fabric::new(m, cfg.net).endpoints();
    let wall0 = std::time::Instant::now();

    let results: Vec<Result<(Metrics, SampleSink, f64, u64)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = endpoints
            .into_iter()
            .map(|mut ep| {
                let store = store.clone();
                let spec = spec.clone();
                let disk = disk.clone();
                let batches = batches.clone();
                scope.spawn(move || {
                    let rank = ep.rank; // rank == site index
                    let mut engine = EngineBox::build(cfg)?;
                    let mut metrics = Metrics::new();
                    let mut sink = SampleSink::new(m, spec.d(), 0);

                    // Startup: every rank reads its own Γ concurrently —
                    // the Fig. 2 "disk contention may occur" moment.
                    let t0 = std::time::Instant::now();
                    let io_secs = disk.charge(store.site_bytes(rank));
                    let site = store.load_site(rank)?;
                    metrics.add_phase("startup_io", t0.elapsed().as_secs_f64() + io_secs);
                    metrics.add(keys::IO_BYTES, store.site_bytes(rank));
                    metrics.add(keys::IO_OPS, 1);
                    ep.advance(io_secs);

                    for (b_idx, b) in batches.iter().enumerate() {
                        // Receive env of batch b from the predecessor.
                        let mut env = if rank == 0 {
                            boundary_env(b.len)
                        } else {
                            let t = std::time::Instant::now();
                            let buf = ep.recv(rank - 1, b_idx as u64)?;
                            metrics.add_phase("pipe_recv", t.elapsed().as_secs_f64());
                            unpack_env(&buf)?
                        };

                        let th = spec.thresholds(rank, b.sample0, b.len);
                        let mus = spec.displacements(rank, b.sample0, b.len);
                        let mut samples = Vec::new();
                        let t0 = std::time::Instant::now();
                        engine.step(&mut env, &site, &th, mus.as_deref(), &mut samples)?;
                        let dt = t0.elapsed().as_secs_f64();
                        metrics.add_phase("compute", dt);
                        let flops = crate::perfmodel::site_flops(
                            b.len as u64,
                            site.gamma.d0 as u64,
                            site.gamma.d1 as u64,
                            site.gamma.d2 as u64,
                        );
                        ep.advance(match cfg.vdevice_flops {
                            Some(r) => flops as f64 / r,
                            None => dt,
                        });
                        sink.record(rank, &samples);
                        metrics.add(keys::MACRO_BATCHES, 1);

                        if rank + 1 < m {
                            ep.send(rank + 1, b_idx as u64, pack_env(&env))?;
                        } else {
                            metrics.add(keys::SAMPLES, b.len as u64);
                        }
                    }
                    metrics.add(keys::COMM_BYTES, ep.comm_bytes);
                    metrics.merge(engine.metrics());
                    Ok((metrics, sink, ep.vtime, engine.dead_rows()))
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let wall = wall0.elapsed().as_secs_f64();
    let mut metrics = Metrics::new();
    let mut sink = SampleSink::new(m, spec.d(), 0);
    let mut vtime: f64 = 0.0;
    let mut dead_rows = 0;
    for r in results {
        let (wm, ws, wv, wd) = r?;
        metrics.merge(&wm);
        sink.merge(&ws);
        vtime = vtime.max(wv);
        dead_rows += wd;
    }
    // Every site recorded every sample once.
    Ok(RunReport {
        metrics,
        sink,
        vtime,
        wall,
        dead_rows,
        env_probes: Vec::new(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ComputePrecision, EngineKind, Preset, RunConfig, ScalingMode};
    use crate::io::{StoreCodec, StorePrecision};

    fn test_store(tag: &str, m: usize) -> (Arc<GammaStore>, std::path::PathBuf) {
        let dir = std::env::temp_dir().join(format!("fastmps-mp-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut spec = Preset::Jiuzhang2.scaled_spec(11);
        spec.m = m;
        spec.chi_cap = 10;
        spec.decay_k = 0.0;
        spec.displacement_sigma = 0.0;
        let store = Arc::new(
            GammaStore::create(&dir, &spec, StorePrecision::F32, StoreCodec::Raw).unwrap(),
        );
        (store, dir)
    }

    fn baseline_cfg(store: &GammaStore, n: u64) -> RunConfig {
        let mut cfg = RunConfig::new(store.spec.clone());
        cfg.n_samples = n;
        cfg.n1_macro = 32;
        cfg.n2_micro = 32;
        cfg.engine = EngineKind::Native;
        cfg.compute = ComputePrecision::F64; // the baseline runs FP64
        cfg.scaling = ScalingMode::Global; // ... with global auto-scaling
        cfg
    }

    #[test]
    fn pipeline_produces_all_samples() {
        let (store, dir) = test_store("pipe", 6);
        let rep = run(&baseline_cfg(&store, 96), &store).unwrap();
        assert_eq!(rep.sink.counts, vec![96; 6]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn matches_data_parallel_statistics() {
        // Same seeds ⇒ the baseline and FastMPS sample identical outcomes
        // (the paper's "strictly consistent sampling results").
        let (store, dir) = test_store("vs-dp", 5);
        let mp = run(&baseline_cfg(&store, 64), &store).unwrap();
        let mut dp_cfg = baseline_cfg(&store, 64);
        dp_cfg.p1 = 2;
        dp_cfg.scaling = ScalingMode::PerSample;
        let dp = crate::coordinator::data_parallel::run(&dp_cfg, &store, &[]).unwrap();
        assert_eq!(mp.sink.hist, dp.sink.hist);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn vtime_includes_pipeline_fill() {
        // With a single macro batch the pipeline is pure fill: the last
        // rank's virtual time contains M-1 hops.
        let (store, dir) = test_store("fill", 8);
        let mut cfg = baseline_cfg(&store, 32);
        cfg.net = crate::comm::NetPreset::Pcie4;
        let rep = run(&cfg, &store).unwrap();
        let m = crate::comm::NetPreset::Pcie4.model();
        let per_hop = m.cost_p2p((2 + 2 * 32 * 10) as u64 * 4);
        assert!(
            rep.vtime >= per_hop * 7.0,
            "vtime {} < fill {}",
            rep.vtime,
            per_hop * 7.0
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn env_pack_roundtrip() {
        let mut e = SplitBuf::zeros(&[3, 4]);
        e.re[5] = 1.25;
        e.im[11] = -2.5;
        let b = pack_env(&e);
        let back = unpack_env(&b).unwrap();
        assert_eq!(back, e);
        assert!(unpack_env(&b[..5]).is_err());
    }
}
