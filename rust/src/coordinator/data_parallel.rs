//! The revived data-parallel sampler (Fig. 3, Eq. 2) — FastMPS's main
//! scheme.
//!
//! `p₁` worker ranks each own independent macro batches. Per round, every
//! rank walks its macro batch through all `M` sites; rank 0 streams `Γ_i`
//! from the store through the double-buffered [`Prefetcher`] and broadcasts
//! it (FP16-packed when the store precision is f16 — §3.3.2 halves the
//! broadcast bytes). There is no pipeline fill and no per-site point-to-
//! point traffic — the two structural costs of the model-parallel baseline
//! that Eq. 2 deletes.

use std::sync::Arc;

use crate::comm::Fabric;
use crate::config::RunConfig;
use crate::coordinator::scheduler::BatchPlan;
use crate::coordinator::{env_probe, env_rows, env_store_rows, EngineBox, RunReport};
use crate::io::{DiskModel, GammaStore, Prefetcher, StorePrecision};
use crate::metrics::{keys, Metrics};
use crate::mps::Site;
use crate::sampler::boundary_env;
use crate::sampler::sink::SampleSink;
use crate::tensor::{SplitBuf, Tensor3};
use crate::util::error::{Error, Result};
use crate::util::f16;

/// Serialize a site for broadcast: header [χ_l, χ_r, d, prec] + payload.
/// FP16 stores pack two scalars per f32 word — the broadcast really moves
/// half the bytes.
fn pack_site(site: &Site, precision: StorePrecision) -> Vec<f32> {
    let g = &site.gamma;
    let n = g.len();
    let mut out = Vec::with_capacity(4 + n);
    out.push(g.d0 as f32);
    out.push(g.d1 as f32);
    out.push(g.d2 as f32);
    match precision {
        StorePrecision::F16 => {
            out.push(16.0);
            let mut halves: Vec<u8> = Vec::with_capacity(n * 4);
            for z in &g.data {
                halves.extend_from_slice(&f16::f32_to_f16_bits(z.re as f32).to_le_bytes());
                halves.extend_from_slice(&f16::f32_to_f16_bits(z.im as f32).to_le_bytes());
            }
            while halves.len() % 4 != 0 {
                halves.push(0);
            }
            for w in halves.chunks_exact(4) {
                out.push(f32::from_bits(u32::from_le_bytes([w[0], w[1], w[2], w[3]])));
            }
        }
        _ => {
            out.push(32.0);
            for z in &g.data {
                out.push(z.re as f32);
                out.push(z.im as f32);
            }
        }
    }
    out
}

/// Inverse of [`pack_site`]; Λ is reconstructed as all-ones.
fn unpack_site(buf: &[f32]) -> Result<Site> {
    if buf.len() < 4 {
        return Err(Error::format("packed site too short"));
    }
    let (x, y, d) = (buf[0] as usize, buf[1] as usize, buf[2] as usize);
    let prec = buf[3] as usize;
    let n = x * y * d;
    let mut gamma = Tensor3::zeros(x, y, d);
    match prec {
        16 => {
            let words = &buf[4..];
            let mut scalars: Vec<f32> = Vec::with_capacity(n * 2);
            for w in words {
                let b = w.to_bits().to_le_bytes();
                scalars.push(f16::f16_bits_to_f32(u16::from_le_bytes([b[0], b[1]])));
                scalars.push(f16::f16_bits_to_f32(u16::from_le_bytes([b[2], b[3]])));
            }
            if scalars.len() < n * 2 {
                return Err(Error::format("packed f16 site truncated"));
            }
            for (i, z) in gamma.data.iter_mut().enumerate() {
                *z = crate::tensor::C64::new(scalars[2 * i] as f64, scalars[2 * i + 1] as f64);
            }
        }
        32 => {
            let words = &buf[4..];
            if words.len() < n * 2 {
                return Err(Error::format("packed f32 site truncated"));
            }
            for (i, z) in gamma.data.iter_mut().enumerate() {
                *z = crate::tensor::C64::new(words[2 * i] as f64, words[2 * i + 1] as f64);
            }
        }
        p => return Err(Error::format(format!("bad packed precision {p}"))),
    }
    Ok(Site {
        lambda: vec![1.0; y],
        gamma,
    })
}

/// Run the data-parallel sampler. `probe_sites` collects Fig. 5 env
/// statistics (from rank 0's first macro batch).
pub fn run(cfg: &RunConfig, store: &Arc<GammaStore>, probe_sites: &[usize]) -> Result<RunReport> {
    cfg.validate()?;
    let p1 = cfg.p1;
    let plan = BatchPlan::build(cfg.n_samples, p1, cfg.n1_macro, cfg.n2_micro)?;
    let m = store.spec.m();
    let spec = store.spec.clone();
    let disk = match cfg.disk_bw {
        Some(bw) => DiskModel::throttled(bw, false),
        None => DiskModel::unlimited(),
    };

    let endpoints = Fabric::new(p1, cfg.net).endpoints();
    let wall0 = std::time::Instant::now();

    let results: Vec<Result<(Metrics, SampleSink, f64, u64, Vec<(usize, Vec<(f64, f64)>)>)>> =
        std::thread::scope(|scope| {
            let handles: Vec<_> = endpoints
                .into_iter()
                .map(|mut ep| {
                    let plan = plan.clone();
                    let store = store.clone();
                    let spec = spec.clone();
                    let disk = disk.clone();
                    let probe_sites = probe_sites.to_vec();
                    scope.spawn(move || {
                        let rank = ep.rank;
                        let mut engine = EngineBox::build(cfg)?;
                        let mut metrics = Metrics::new();
                        let mut sink = SampleSink::new(m, spec.d(), spec.sink_max_gap());
                        let mut probes: Vec<(usize, Vec<(f64, f64)>)> = Vec::new();

                        // Rank 0 owns the store stream: one walk per round.
                        let mut prefetcher = if rank == 0 {
                            let order: Vec<usize> =
                                (0..plan.rounds).flat_map(|_| 0..m).collect();
                            Some(Prefetcher::new(store.clone(), disk.clone(), order, 2))
                        } else {
                            None
                        };

                        for round in 0..plan.rounds {
                            let batch = plan.at(rank, round);
                            let mut env = batch.map(|b| boundary_env(b.len));
                            if let Some(b) = &batch {
                                metrics.add(keys::MACRO_BATCHES, 1);
                                sink.reset_walk();
                                let _ = b;
                            }
                            for site_idx in 0..m {
                                // ---- Γ distribution (rank 0 reads, all bcast).
                                let mut packed: Vec<f32> = if let Some(pf) = &mut prefetcher {
                                    let (i, site) = pf
                                        .next_site()
                                        .ok_or_else(|| Error::other("prefetch ended early"))??;
                                    debug_assert_eq!(i, site_idx);
                                    metrics.add(keys::IO_OPS, 1);
                                    metrics.add(keys::IO_BYTES, store.site_bytes(i));
                                    ep.advance(0.0);
                                    pack_site(&site, cfg.store_precision)
                                } else {
                                    Vec::new()
                                };
                                let t_bcast = std::time::Instant::now();
                                ep.bcast(&mut packed, 0);
                                metrics.add_phase("bcast", t_bcast.elapsed().as_secs_f64());
                                let site = unpack_site(&packed)?;

                                // ---- local macro batch step (micro-batched).
                                if let (Some(b), Some(env_buf)) = (&batch, &mut env) {
                                    // Convert Γ to compute precision ONCE
                                    // per site; every micro batch below
                                    // borrows it (the per-step
                                    // clone/re-round is gone).
                                    let prepared = engine.prep_key().map(|k| {
                                        metrics.add(keys::STEP_PREP_CONVERSIONS, 1);
                                        crate::sampler::PreparedSite::prepare(&site, k)
                                    });
                                    let chi_r = site.gamma.d1;
                                    let mut next =
                                        SplitBuf::zeros(&[b.len, chi_r]);
                                    let mut site_samples: Vec<i32> =
                                        Vec::with_capacity(b.len);
                                    for (a, z) in plan.micro_ranges(b.len) {
                                        let mut chunk = env_rows(env_buf, a, z);
                                        let th = spec.thresholds(
                                            site_idx,
                                            b.sample0 + a as u64,
                                            z - a,
                                        );
                                        let mus = spec.displacements(
                                            site_idx,
                                            b.sample0 + a as u64,
                                            z - a,
                                        );
                                        let mut s = Vec::new();
                                        let t0 = std::time::Instant::now();
                                        engine.step_site(
                                            &mut chunk,
                                            Some(&site),
                                            prepared.as_ref(),
                                            &th,
                                            mus.as_deref(),
                                            &mut s,
                                        )?;
                                        let dt = t0.elapsed().as_secs_f64();
                                        metrics.add_phase("compute", dt);
                                        let flops = crate::perfmodel::site_flops(
                                            (z - a) as u64,
                                            site.gamma.d0 as u64,
                                            site.gamma.d1 as u64,
                                            site.gamma.d2 as u64,
                                        );
                                        ep.advance(match cfg.vdevice_flops {
                                            Some(r) => flops as f64 / r,
                                            None => dt,
                                        });
                                        metrics.add(keys::MICRO_BATCHES, 1);
                                        if cfg.env_f16 {
                                            // §3.3.2: FP16 left-env storage.
                                            chunk.round_f16_in_place();
                                        }
                                        env_store_rows(&mut next, a, &chunk);
                                        site_samples.extend_from_slice(&s);
                                    }
                                    sink.record(site_idx, &site_samples);
                                    if rank == 0
                                        && round == 0
                                        && probe_sites.contains(&site_idx)
                                    {
                                        probes.push((site_idx, env_probe(&next)));
                                    }
                                    *env_buf = next;
                                }
                            }
                            if let Some(b) = &batch {
                                metrics.add(keys::SAMPLES, b.len as u64);
                            }
                        }
                        if let Some(pf) = prefetcher.take() {
                            metrics.add_phase("io_virtual", pf.io_secs);
                            metrics.add_phase("io_stall", pf.stall_secs);
                            pf.finish()?;
                        }
                        metrics.add(keys::SITES, m as u64);
                        metrics.add(keys::COMM_BYTES, ep.comm_bytes);
                        metrics.add(keys::COLLECTIVES, ep.collectives);
                        metrics.merge(engine.metrics());
                        Ok((metrics, sink, ep.vtime, engine.dead_rows(), probes))
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });

    let wall = wall0.elapsed().as_secs_f64();
    let mut metrics = Metrics::new();
    let mut sink = SampleSink::new(m, spec.d(), spec.sink_max_gap());
    let mut vtime: f64 = 0.0;
    let mut dead_rows = 0u64;
    let mut env_probes = Vec::new();
    for r in results {
        let (wm, ws, wv, wd, wp) = r?;
        metrics.merge(&wm);
        sink.merge(&ws);
        vtime = vtime.max(wv);
        dead_rows += wd;
        env_probes.extend(wp);
    }
    Ok(RunReport {
        metrics,
        sink,
        vtime,
        wall,
        dead_rows,
        env_probes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ComputePrecision, EngineKind, Preset, RunConfig, ScalingMode};
    use crate::io::StoreCodec;

    fn test_store(tag: &str, m: usize, decay: f64) -> (Arc<GammaStore>, std::path::PathBuf) {
        let dir = std::env::temp_dir().join(format!("fastmps-dp-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut spec = Preset::Jiuzhang2.scaled_spec(11);
        spec.m = m;
        spec.chi_cap = 12;
        spec.decay_k = decay;
        spec.displacement_sigma = 0.0;
        let store = Arc::new(
            GammaStore::create(&dir, &spec, StorePrecision::F32, StoreCodec::Raw).unwrap(),
        );
        (store, dir)
    }

    fn cfg_for(store: &GammaStore, p1: usize, n: u64) -> RunConfig {
        let mut cfg = RunConfig::new(store.spec.clone());
        cfg.n_samples = n;
        cfg.n1_macro = 64;
        cfg.n2_micro = 32;
        cfg.p1 = p1;
        cfg.engine = EngineKind::Native;
        cfg.compute = ComputePrecision::F64;
        cfg.scaling = ScalingMode::PerSample;
        cfg
    }

    #[test]
    fn single_worker_samples_everything() {
        let (store, dir) = test_store("single", 8, 0.0);
        let cfg = cfg_for(&store, 1, 200);
        let rep = run(&cfg, &store, &[]).unwrap();
        assert_eq!(rep.sink.total_samples(), 200);
        assert_eq!(rep.sink.counts, vec![200; 8]);
        assert_eq!(rep.dead_rows, 0);
        assert!(rep.metrics.get(keys::FLOPS) > 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn worker_count_does_not_change_statistics() {
        // Partition-invariant RNG streams ⇒ identical histograms for any p1.
        let (store, dir) = test_store("invariant", 6, 0.0);
        let r1 = run(&cfg_for(&store, 1, 256), &store, &[]).unwrap();
        let r3 = run(&cfg_for(&store, 3, 256), &store, &[]).unwrap();
        assert_eq!(r1.sink.hist, r3.sink.hist);
        assert_eq!(r1.sink.pair_sums, r3.sink.pair_sums);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn uneven_tail_batch_handled() {
        let (store, dir) = test_store("tail", 5, 0.0);
        let cfg = cfg_for(&store, 2, 150); // 3 batches of 64/64/22 over 2 workers
        let rep = run(&cfg, &store, &[]).unwrap();
        assert_eq!(rep.sink.total_samples(), 150);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn f16_broadcast_path_works() {
        let (store, dir) = test_store("f16", 5, 0.0);
        let mut cfg = cfg_for(&store, 2, 128);
        cfg.store_precision = StorePrecision::F16;
        let rep = run(&cfg, &store, &[]).unwrap();
        assert_eq!(rep.sink.total_samples(), 128);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn probes_collected_at_requested_sites() {
        let (store, dir) = test_store("probe", 8, 0.3);
        let cfg = cfg_for(&store, 1, 64);
        let rep = run(&cfg, &store, &[2, 5]).unwrap();
        let sites: Vec<usize> = rep.env_probes.iter().map(|(s, _)| *s).collect();
        assert_eq!(sites, vec![2, 5]);
        assert_eq!(rep.env_probes[0].1.len(), 64);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let mut spec = Preset::Jiuzhang2.scaled_spec(3);
        spec.m = 4;
        spec.chi_cap = 6;
        let mps = spec.generate().unwrap();
        for prec in [StorePrecision::F32, StorePrecision::F16] {
            let buf = pack_site(&mps.sites[1], prec);
            let back = unpack_site(&buf).unwrap();
            assert_eq!(back.gamma.d0, mps.sites[1].gamma.d0);
            for (a, b) in back.gamma.data.iter().zip(&mps.sites[1].gamma.data) {
                assert!((a.re - b.re).abs() < 2e-3, "{} vs {}", a.re, b.re);
            }
        }
    }
}
