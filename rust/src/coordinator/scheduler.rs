//! Macro/micro batch planning (§3.1, Eq. 3).
//!
//! The scheduler turns `(N, p₁, N₁, N₂)` into per-worker macro-batch
//! assignments, validates them against a memory budget via the Eq. 3 model,
//! and can suggest `N₁` from the device's overlap threshold.

use crate::perfmodel;
use crate::util::error::{Error, Result};

/// One macro batch owned by one worker in one round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MacroBatch {
    pub worker: usize,
    pub round: usize,
    /// First global sample index.
    pub sample0: u64,
    pub len: usize,
}

/// The complete plan.
#[derive(Debug, Clone)]
pub struct BatchPlan {
    pub batches: Vec<MacroBatch>,
    pub rounds: usize,
    pub p1: usize,
    pub n1: usize,
    pub n2: usize,
}

impl BatchPlan {
    /// Partition `n_samples` into macro batches of `n1` dealt round-robin
    /// to `p1` workers; each macro batch is stepped in `n2`-sized micro
    /// batches.
    pub fn build(n_samples: u64, p1: usize, n1: usize, n2: usize) -> Result<BatchPlan> {
        if p1 == 0 || n1 == 0 || n2 == 0 {
            return Err(Error::config("scheduler: p1, N1, N2 must be ≥ 1"));
        }
        if n2 > n1 {
            return Err(Error::config("scheduler: N2 > N1"));
        }
        let n_batches = n_samples.div_ceil(n1 as u64);
        let rounds = n_batches.div_ceil(p1 as u64) as usize;
        let mut batches = Vec::with_capacity(n_batches as usize);
        for b in 0..n_batches {
            let sample0 = b * n1 as u64;
            let len = ((n_samples - sample0) as usize).min(n1);
            batches.push(MacroBatch {
                worker: (b % p1 as u64) as usize,
                round: (b / p1 as u64) as usize,
                sample0,
                len,
            });
        }
        Ok(BatchPlan {
            batches,
            rounds,
            p1,
            n1,
            n2,
        })
    }

    /// Batches of one worker, in round order.
    pub fn for_worker(&self, worker: usize) -> Vec<MacroBatch> {
        self.batches
            .iter()
            .filter(|b| b.worker == worker)
            .copied()
            .collect()
    }

    /// The batch a worker runs in `round`, if any (idle workers still join
    /// the Γ broadcast — SPMD).
    pub fn at(&self, worker: usize, round: usize) -> Option<MacroBatch> {
        self.batches
            .iter()
            .find(|b| b.worker == worker && b.round == round)
            .copied()
    }

    /// Split a macro batch into micro ranges `[a, b)` relative to batch
    /// start.
    pub fn micro_ranges(&self, len: usize) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        let mut a = 0;
        while a < len {
            let b = (a + self.n2).min(len);
            out.push((a, b));
            a = b;
        }
        out
    }

    /// Eq. 3 memory estimate per worker (bytes).
    pub fn memory_per_worker(&self, chi: usize, d: usize, scalar_bytes: usize) -> u64 {
        perfmodel::memory_demand(self.n1 as u64, chi as u64, d as u64, scalar_bytes as u64)
    }

    /// Check the plan fits a memory budget.
    pub fn check_memory(&self, chi: usize, d: usize, scalar_bytes: usize, budget: u64) -> Result<()> {
        let need = self.memory_per_worker(chi, d, scalar_bytes);
        if need > budget {
            return Err(Error::config(format!(
                "macro batch N1={} needs {} per worker (budget {}); shrink N1 or raise p2",
                self.n1,
                crate::util::human_bytes(need),
                crate::util::human_bytes(budget)
            )));
        }
        Ok(())
    }
}

/// Suggest `N₁` for a device so that compute hides I/O (§3.1), capped by
/// the memory budget through Eq. 3.
pub fn suggest_n1(
    dev: &perfmodel::DeviceSpec,
    chi: usize,
    d: usize,
    scalar_bytes: usize,
    mem_budget: u64,
) -> usize {
    let overlap = perfmodel::min_macro_batch_for_overlap(dev, scalar_bytes as u64) as usize;
    // Invert Eq. 3 for the largest N1 within budget.
    let gamma = (chi as u64 * chi as u64 * d as u64) * 2 * scalar_bytes as u64;
    let per_sample = (chi as u64 * d as u64) * 2 * scalar_bytes as u64;
    let max_fit = if mem_budget > gamma {
        ((mem_budget - gamma) / per_sample.max(1)) as usize
    } else {
        1
    };
    overlap.clamp(1, max_fit.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_covers_all_samples_exactly_once() {
        let p = BatchPlan::build(10_000, 3, 1024, 256).unwrap();
        let total: u64 = p.batches.iter().map(|b| b.len as u64).sum();
        assert_eq!(total, 10_000);
        // Ranges are disjoint and ordered.
        for w in p.batches.windows(2) {
            assert_eq!(w[1].sample0, w[0].sample0 + w[0].len as u64);
        }
        // Last batch is the remainder.
        assert_eq!(p.batches.last().unwrap().len, 10_000 % 1024);
    }

    #[test]
    fn round_robin_assignment() {
        let p = BatchPlan::build(5000, 2, 1000, 100).unwrap();
        assert_eq!(p.rounds, 3);
        assert_eq!(p.for_worker(0).len(), 3);
        assert_eq!(p.for_worker(1).len(), 2);
        assert!(p.at(1, 2).is_none()); // idle in last round
        assert_eq!(p.at(0, 2).unwrap().sample0, 4000);
    }

    #[test]
    fn micro_ranges_cover() {
        let p = BatchPlan::build(100, 1, 100, 32).unwrap();
        let r = p.micro_ranges(100);
        assert_eq!(r, vec![(0, 32), (32, 64), (64, 96), (96, 100)]);
    }

    #[test]
    fn memory_model_and_budget() {
        let p = BatchPlan::build(10_000, 1, 1000, 100).unwrap();
        let m = p.memory_per_worker(100, 3, 8);
        assert_eq!(m, (1000 * 100 * 3 + 100 * 100 * 3) * 16);
        assert!(p.check_memory(100, 3, 8, m).is_ok());
        assert!(p.check_memory(100, 3, 8, m - 1).is_err());
    }

    #[test]
    fn n1_suggestion_respects_budget() {
        let n1 = suggest_n1(&crate::perfmodel::A100_TF32, 10_000, 3, 2, 40 << 30);
        assert!(n1 >= 1000);
        let tight = suggest_n1(&crate::perfmodel::A100_TF32, 10_000, 3, 2, 2 << 30);
        assert!(tight < n1);
    }

    #[test]
    fn property_plan_partition() {
        crate::util::prop::quickcheck("plan partitions samples", |g| {
            let n = g.u64() % 100_000 + 1;
            let p1 = g.usize_in(1, 9);
            let n1 = g.usize_in(1, 5000);
            let n2 = g.usize_in(1, n1 + 1);
            let plan = BatchPlan::build(n, p1, n1, n2).map_err(|e| e.to_string())?;
            let total: u64 = plan.batches.iter().map(|b| b.len as u64).sum();
            if total != n {
                return Err(format!("covered {total} of {n}"));
            }
            for b in &plan.batches {
                if b.len == 0 || b.len > n1 || b.worker >= p1 {
                    return Err(format!("bad batch {b:?}"));
                }
            }
            Ok(())
        });
    }
}
