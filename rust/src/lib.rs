//! # FastMPS
//!
//! A multi-level parallel framework for large-scale Matrix Product State
//! (MPS) sampling, reproducing *"FastMPS: Revisit Data Parallel in
//! Large-scale Matrix Product State Sampling"* (CS.DC 2025) as a
//! three-layer rust + JAX + Pallas stack.
//!
//! Layers:
//! - **L1/L2 (build time)**: Pallas kernels + a JAX per-site step model are
//!   AOT-lowered to HLO text under `artifacts/` (`make artifacts`).
//! - **L3 (this crate)**: the coordinator — data parallelism across samples,
//!   tensor parallelism along the bond dimension, mixed-precision storage,
//!   dynamic bond dimensions, and the simulated communication fabric used
//!   for the paper's scaling studies. The hot path executes the AOT
//!   artifacts through the PJRT CPU client (`runtime`), with a native
//!   engine (`sampler::native`) as the correctness oracle.
//! - **Service (`service`)**: a resident batched sampling service — job
//!   queue + store cache + §3.1-sized batcher + worker pool — behind
//!   `fastmps serve`/`submit`/`jobs`, amortizing store opens, Γ streaming
//!   and engine construction across requests.
//! - **Net (`net`)**: the service's TCP transport — the versioned FMPN
//!   wire protocol (`docs/PROTOCOL.md`), a bounded-pool server, and a
//!   blocking client — behind `serve --listen` / `submit --connect`.
//! - **Router (`router`)**: the horizontal tier — a store-affinity
//!   gateway (rendezvous hashing on manifest hashes, health-probed
//!   backends, `Busy`-aware spillover, graceful drain) that fronts a
//!   fleet of FMPN servers behind `fastmps route`.
//! - **Trace (`trace`)**: flight-recorder tracing — fixed-capacity ring
//!   buffers of span events in every component, stitched by trace id
//!   into end-to-end per-job timelines (`fastmps trace`,
//!   `docs/OBSERVABILITY.md`).
//! - **Telemetry (`telemetry`)**: the continuous-monitoring plane —
//!   background time-series rings in `serve`/`route`, a Prometheus
//!   text exposition at `GET /metrics` (`--metrics-listen`), a router
//!   fleet poller labeling each backend's series, and the `fastmps
//!   top` live dashboard.

pub mod cli;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod io;
pub mod linalg;
pub mod metrics;
pub mod mps;
pub mod net;
pub mod perfmodel;
pub mod rng;
pub mod router;
pub mod runtime;
pub mod sampler;
pub mod service;
pub mod telemetry;
pub mod tensor;
pub mod trace;
pub mod util;
pub mod validate;

pub use util::error::{Error, Result};

/// Test builds run under the counting allocator so the zero-allocation
/// steady-state contract of the step engines is asserted, not assumed
/// (`sampler::native` tests; docs/PERF.md). Non-test builds use the
/// system allocator untouched.
#[cfg(test)]
#[global_allocator]
static COUNTING_ALLOC: util::alloc::CountingAlloc = util::alloc::CountingAlloc;
