//! Synthetic GBS-state generator — the data substitute for the paper's
//! experimental Jiuzhang/Borealis MPS inputs (see DESIGN.md §Substitutions).
//!
//! The generator produces a right-canonical MPS whose *systems behaviour*
//! matches what FastMPS exploits:
//!
//! - the bond-dimension profile follows the area-law ramp/plateau of
//!   [`super::entanglement`], parameterized by the actual squeezed photon
//!   number (ASP) exactly as Table 1 correlates;
//! - every site tensor is scaled by `10^{−k}` so the left environment decays
//!   as `μ_i ~ μ_0·10^{−ik}` (Eq. 5) — the numerical-range collapse that
//!   motivates per-sample adaptive scaling (Figs. 5/6). The scale factor is
//!   jittered per site so different samples spread over decades, like
//!   Fig. 5's scatter;
//! - per-sample displacement draws `μ ~ CN(0, σ²)` are derived from the
//!   run seed (purpose-keyed streams), matching §3.4.1's batched usage.
//!
//! Probabilities are invariant to the per-site scaling (Alg. 1 normalizes),
//! so the exact-marginal oracle in [`super::exact`] stays valid.

use crate::mps::canonical::random_right_canonical;
use crate::mps::entanglement::{plan_dynamic_chi, step_ratio_from_asp, ChiPlan};
use crate::mps::{Mps, Site};
use crate::rng::{purpose, Xoshiro256};
use crate::util::error::Result;

/// Specification of a synthetic GBS dataset.
#[derive(Debug, Clone)]
pub struct GbsSpec {
    /// Dataset name (preset id or "custom").
    pub name: String,
    /// Number of sites (modes).
    pub m: usize,
    /// Physical (Fock truncation) dimension, paper uses 3–4.
    pub d: usize,
    /// Bond dimension cap χ.
    pub chi_cap: usize,
    /// Actual squeezed photon number — drives the entanglement profile.
    pub asp: f64,
    /// Per-site magnitude decay exponent `k` of Eq. 5 (decade per site).
    pub decay_k: f64,
    /// Std-dev of the complex-normal displacement draws (0 disables
    /// displacement).
    pub displacement_sigma: f64,
    /// Physical-branch amplitude skew (0 disables): slice `s` of every Γ is
    /// scaled by `skew^s`, giving the vacuum-dominant structure of lossy
    /// GBS. Samples that measure a photon drop in magnitude by ~`skew`, so
    /// the *inter-sample* magnitude spread grows with the site index — the
    /// Fig. 5 range expansion that global auto-scaling cannot absorb.
    /// Breaks exact right-canonicality; keep 0 for validation runs.
    pub branch_skew: f64,
    /// Dataset seed.
    pub seed: u64,
    /// Use the dynamic-χ plan (§3.4.2); otherwise fixed χ.
    pub dynamic_chi: bool,
    /// Measured step-ratio override (paper Table 1 values); `None` uses the
    /// fitted ASP model.
    pub step_ratio_override: Option<f64>,
}

impl GbsSpec {
    /// The χ plan this spec induces.
    pub fn chi_plan(&self) -> ChiPlan {
        if self.dynamic_chi {
            let s = self
                .step_ratio_override
                .unwrap_or_else(|| step_ratio_from_asp(self.asp));
            plan_dynamic_chi(self.m, self.d, self.chi_cap, s, 8)
        } else {
            ChiPlan::fixed(self.m, self.d, self.chi_cap)
        }
    }

    /// Generate the full in-memory MPS (small/medium scales; the CLI's
    /// `gen-data` streams sites straight to the Γ store for large M).
    pub fn generate(&self) -> Result<Mps> {
        let plan = self.chi_plan();
        let mut sites = Vec::with_capacity(self.m);
        let mut chi_l = 1usize;
        for i in 0..self.m {
            let site = self.generate_site(i, chi_l, &plan)?;
            chi_l = site.chi_r();
            sites.push(site);
        }
        let mps = Mps {
            sites,
            d: self.d,
        };
        mps.check()?;
        Ok(mps)
    }

    /// Generate site `i` alone (deterministic in `(seed, i)` — the property
    /// the streaming generator and the model-parallel baseline rely on:
    /// every rank can materialize its own site without communication).
    pub fn generate_site(&self, i: usize, chi_l: usize, plan: &ChiPlan) -> Result<Site> {
        let chi_r = if i + 1 == self.m { 1 } else { plan.chi[i] };
        let mut rng = Xoshiro256::stream(self.seed, purpose::DATAGEN, i as u64);
        let mut gamma = random_right_canonical(&mut rng, chi_l, chi_r, self.d)?;
        // Eq. 5 magnitude decay with ±25% per-site jitter (spreads samples
        // across decades over many sites, as in Fig. 5).
        let jitter = 1.0 + 0.5 * (rng.unit_f64() - 0.5);
        let scale = 10f64.powf(-self.decay_k * jitter);
        for z in &mut gamma.data {
            *z = z.scale(scale);
        }
        if self.branch_skew > 0.0 {
            for i in 0..gamma.d0 {
                for j in 0..gamma.d1 {
                    for s in 1..self.d {
                        let f = self.branch_skew.powi(s as i32);
                        let z = gamma.at(i, j, s);
                        *gamma.at_mut(i, j, s) = z.scale(f);
                    }
                }
            }
        }
        Ok(Site {
            lambda: vec![1.0; chi_r],
            gamma,
        })
    }

    /// Displacement draws for samples `[sample0, sample0+n)` at site `i`,
    /// reproducible regardless of batch partitioning.
    pub fn displacement_draws(&self, site: usize, sample0: u64, n: usize) -> Vec<(f64, f64)> {
        let mut out = Vec::with_capacity(n);
        if self.displacement_sigma == 0.0 {
            out.resize(n, (0.0, 0.0));
            return out;
        }
        for s in 0..n as u64 {
            let mut rng = Xoshiro256::stream(
                self.seed ^ (site as u64).rotate_left(17),
                purpose::DISPLACE,
                sample0 + s,
            );
            let (re, im) = rng.complex_normal();
            out.push((re * self.displacement_sigma, im * self.displacement_sigma));
        }
        out
    }

    /// Measurement thresholds (Alg. 1's `rand(N₂)`) for samples
    /// `[sample0, sample0+n)` at site `site` — also partition-invariant.
    pub fn thresholds(&self, site: usize, sample0: u64, n: usize) -> Vec<f32> {
        (0..n as u64)
            .map(|s| {
                let mut rng = Xoshiro256::stream(
                    self.seed ^ (site as u64).rotate_left(33),
                    purpose::THRESHOLD,
                    sample0 + s,
                );
                rng.unit_f32()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mps::canonical::right_canonical_residual;

    fn small_spec() -> GbsSpec {
        GbsSpec {
            name: "test".into(),
            m: 12,
            d: 3,
            chi_cap: 16,
            asp: 4.0,
            decay_k: 0.0,
            displacement_sigma: 0.3,
            branch_skew: 0.0,
            seed: 7,
            dynamic_chi: true,
            step_ratio_override: None,
        }
    }

    #[test]
    fn generates_valid_canonical_chain() {
        let mps = small_spec().generate().unwrap();
        assert_eq!(mps.num_sites(), 12);
        mps.check().unwrap();
        for (i, s) in mps.sites.iter().enumerate() {
            let r = right_canonical_residual(&s.gamma);
            assert!(r < 1e-10, "site {i}: residual {r}");
        }
    }

    #[test]
    fn decay_scales_tensors() {
        let mut spec = small_spec();
        spec.decay_k = 1.0; // one decade per site (±25%)
        let mps = spec.generate().unwrap();
        for s in &mps.sites {
            let r = right_canonical_residual(&s.gamma);
            // Scaled tensor: Σ ΓΓ† = c²·I with c ∈ [10^-1.25, 10^-0.75].
            assert!(r > 0.9, "decayed site should not be unit-canonical");
            let c2 = 1.0 - r; // residual at diagonal = |c²−1|
            assert!(c2 < 0.1, "c² should be ≤ 10^-1.5, got residual {r}");
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let a = small_spec().generate().unwrap();
        let b = small_spec().generate().unwrap();
        for (x, y) in a.sites.iter().zip(&b.sites) {
            assert_eq!(x.gamma.data, y.gamma.data);
        }
    }

    #[test]
    fn site_generation_is_independent() {
        // generate_site(i) must equal the site from the full chain.
        let spec = small_spec();
        let plan = spec.chi_plan();
        let full = spec.generate().unwrap();
        let mut chi_l = 1;
        for i in 0..spec.m {
            let s = spec.generate_site(i, chi_l, &plan).unwrap();
            assert_eq!(s.gamma.data, full.sites[i].gamma.data, "site {i}");
            chi_l = s.chi_r();
        }
    }

    #[test]
    fn draws_partition_invariant() {
        let spec = small_spec();
        let all = spec.displacement_draws(3, 0, 10);
        let tail = spec.displacement_draws(3, 6, 4);
        assert_eq!(&all[6..], &tail[..]);
        let th_all = spec.thresholds(3, 0, 10);
        let th_tail = spec.thresholds(3, 6, 4);
        assert_eq!(&th_all[6..], &th_tail[..]);
    }

    #[test]
    fn zero_sigma_disables_displacement() {
        let mut spec = small_spec();
        spec.displacement_sigma = 0.0;
        let d = spec.displacement_draws(0, 0, 5);
        assert!(d.iter().all(|&(r, i)| r == 0.0 && i == 0.0));
    }

    #[test]
    fn branch_skew_suppresses_photon_branches() {
        let mut spec = small_spec();
        spec.branch_skew = 0.1;
        let mps = spec.generate().unwrap();
        for site in &mps.sites {
            let g = &site.gamma;
            let mut norms = vec![0.0f64; spec.d];
            for i in 0..g.d0 {
                for j in 0..g.d1 {
                    for s in 0..spec.d {
                        norms[s] += g.at(i, j, s).norm_sq();
                    }
                }
            }
            // Branch s is suppressed by skew^(2s) relative to branch 0.
            assert!(norms[1] < norms[0] * 0.05);
            assert!(norms[2] < norms[1] * 0.05);
        }
    }

    #[test]
    fn dynamic_plan_smaller_than_fixed() {
        let spec = small_spec();
        let dynamic = spec.chi_plan();
        let mut fixed_spec = spec.clone();
        fixed_spec.dynamic_chi = false;
        let fixed = fixed_spec.chi_plan();
        let sum_d: usize = dynamic.chi.iter().sum();
        let sum_f: usize = fixed.chi.iter().sum();
        assert!(sum_d <= sum_f);
    }
}
