//! The workload abstraction: everything the stack needs to know about a
//! measurement model, factored out of the GBS-specific generator.
//!
//! The paper frames MPS sequential sampling (Alg. 1) as a *fundamental
//! operation* — GBS is one instantiation. A [`Workload`] supplies the
//! pieces that differ between instantiations and nothing else:
//!
//! - the physical dimension `d` and site count `m` (tensor shapes);
//! - the χ plan (how bond dimension grows along the chain);
//! - deterministic site generation (so streaming stores and model-parallel
//!   ranks can materialize sites independently);
//! - the per-site measurement rule: partition-invariant threshold streams
//!   (Alg. 1's `rand(N₂)`) and an optional displacement hook (§3.4.1 —
//!   GBS-specific; workloads without the concept return `None`);
//! - the sink shape (max outcome gap [`crate::sampler::sink::SampleSink`]
//!   tracks);
//! - a stable *tag* written into the store manifest and carried in job
//!   specs, so content keys cannot collide across workloads and mixed
//!   tensor-parallel groups are refused typed.
//!
//! The hot path (engines, prepared sites, sinks, batching, routing, TP
//! collectives) is already parameter-driven and needs **no** per-workload
//! branches; layers hold a [`WorkloadSpec`] and call its accessors.

use crate::mps::entanglement::ChiPlan;
use crate::mps::gbs::GbsSpec;
use crate::mps::qubit::QubitSpec;
use crate::mps::{Mps, Site};
use crate::util::error::{Error, Result};

/// Identity of a measurement model. The `as_str` form is the store-manifest
/// tag and the wire name (`JobSpec.workload`, TP hello `workload` field).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WorkloadKind {
    /// Gaussian Boson Sampling (the paper's workload; d = 3–4 Fock cutoff).
    Gbs,
    /// Qubit-chain sampling (d = 2): circuit / generative MPS workloads.
    Qubit,
}

impl WorkloadKind {
    pub const ALL: [WorkloadKind; 2] = [WorkloadKind::Gbs, WorkloadKind::Qubit];

    /// Stable lowercase tag — manifest field, wire field, CLI name.
    pub fn as_str(&self) -> &'static str {
        match self {
            WorkloadKind::Gbs => "gbs",
            WorkloadKind::Qubit => "qubit",
        }
    }

    /// Comma-separated list of valid tags (for error messages).
    pub fn valid_names() -> String {
        Self::ALL
            .iter()
            .map(|k| k.as_str())
            .collect::<Vec<_>>()
            .join(", ")
    }

    /// Parse a tag; unknown names get a typed error listing the valid set
    /// (surfaced verbatim by `fastmps submit --workload`).
    pub fn parse(s: &str) -> Result<WorkloadKind> {
        for k in Self::ALL {
            if s == k.as_str() {
                return Ok(k);
            }
        }
        Err(Error::config(format!(
            "unknown workload {s:?} (valid workloads: {})",
            Self::valid_names()
        )))
    }
}

impl std::fmt::Display for WorkloadKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// The measurement-model contract. Implementations must keep every method
/// deterministic in the spec alone — in particular `generate_site` must be
/// a pure function of `(spec, i)` and the threshold/displacement streams
/// must be partition-invariant (`[s0, s0+n)` draws independent of batching).
pub trait Workload {
    /// Which model this is (drives the manifest/wire tag).
    fn kind(&self) -> WorkloadKind;
    /// Dataset name (preset id or "custom").
    fn dataset_name(&self) -> &str;
    /// Number of sites (modes / qubits).
    fn num_sites(&self) -> usize;
    /// Physical dimension of every site tensor.
    fn phys_d(&self) -> usize;
    /// Bond-dimension cap χ.
    fn chi_cap(&self) -> usize;
    /// Dataset seed.
    fn seed(&self) -> u64;
    /// The χ plan this spec induces.
    fn chi_plan(&self) -> ChiPlan;
    /// Generate site `i` alone (deterministic in `(seed, i)`).
    fn generate_site(&self, i: usize, chi_l: usize, plan: &ChiPlan) -> Result<Site>;
    /// Measurement thresholds for samples `[sample0, sample0+n)` at `site`.
    fn thresholds(&self, site: usize, sample0: u64, n: usize) -> Vec<f32>;
    /// Displacement hook: `Some(draws)` if this workload displaces the
    /// measurement basis (GBS §3.4.1), `None` if the concept doesn't exist
    /// or is disabled. Callers pass the result straight to the engine.
    fn displacements(&self, site: usize, sample0: u64, n: usize) -> Option<Vec<(f64, f64)>>;
    /// Whether any site will ever return `Some` from [`Self::displacements`]
    /// (lets TP refuse displaced jobs without probing sites).
    fn has_displacement(&self) -> bool {
        false
    }
    /// Max outcome gap the [`crate::sampler::sink::SampleSink`] tracks.
    fn sink_max_gap(&self) -> usize {
        4
    }
    /// Generate the full in-memory MPS (small/medium scales; `gen-data`
    /// streams sites straight to the Γ store for large M).
    fn generate(&self) -> Result<Mps> {
        let plan = self.chi_plan();
        let m = self.num_sites();
        let mut sites = Vec::with_capacity(m);
        let mut chi_l = 1usize;
        for i in 0..m {
            let site = self.generate_site(i, chi_l, &plan)?;
            chi_l = site.chi_r();
            sites.push(site);
        }
        let mps = Mps {
            sites,
            d: self.phys_d(),
        };
        mps.check()?;
        Ok(mps)
    }
}

impl Workload for GbsSpec {
    fn kind(&self) -> WorkloadKind {
        WorkloadKind::Gbs
    }

    fn dataset_name(&self) -> &str {
        &self.name
    }

    fn num_sites(&self) -> usize {
        self.m
    }

    fn phys_d(&self) -> usize {
        self.d
    }

    fn chi_cap(&self) -> usize {
        self.chi_cap
    }

    fn seed(&self) -> u64 {
        self.seed
    }

    fn chi_plan(&self) -> ChiPlan {
        GbsSpec::chi_plan(self)
    }

    fn generate_site(&self, i: usize, chi_l: usize, plan: &ChiPlan) -> Result<Site> {
        GbsSpec::generate_site(self, i, chi_l, plan)
    }

    fn thresholds(&self, site: usize, sample0: u64, n: usize) -> Vec<f32> {
        GbsSpec::thresholds(self, site, sample0, n)
    }

    fn displacements(&self, site: usize, sample0: u64, n: usize) -> Option<Vec<(f64, f64)>> {
        (self.displacement_sigma != 0.0).then(|| self.displacement_draws(site, sample0, n))
    }

    fn has_displacement(&self) -> bool {
        self.displacement_sigma != 0.0
    }

    fn generate(&self) -> Result<Mps> {
        GbsSpec::generate(self)
    }
}

/// A concrete, storable workload spec — the closed set of [`Workload`]
/// implementations the store manifest can round-trip. Every layer that used
/// to hold a `GbsSpec` now holds one of these and calls the accessors; no
/// layer matches on the variants.
#[derive(Debug, Clone)]
pub enum WorkloadSpec {
    Gbs(GbsSpec),
    Qubit(QubitSpec),
}

impl WorkloadSpec {
    /// The trait view — single dispatch point for all accessors.
    pub fn as_workload(&self) -> &dyn Workload {
        match self {
            WorkloadSpec::Gbs(s) => s,
            WorkloadSpec::Qubit(s) => s,
        }
    }

    pub fn kind(&self) -> WorkloadKind {
        self.as_workload().kind()
    }

    /// The manifest/wire tag ("gbs", "qubit").
    pub fn tag(&self) -> &'static str {
        self.kind().as_str()
    }

    pub fn name(&self) -> &str {
        self.as_workload().dataset_name()
    }

    pub fn m(&self) -> usize {
        self.as_workload().num_sites()
    }

    pub fn d(&self) -> usize {
        self.as_workload().phys_d()
    }

    pub fn chi_cap(&self) -> usize {
        self.as_workload().chi_cap()
    }

    pub fn seed(&self) -> u64 {
        self.as_workload().seed()
    }

    pub fn chi_plan(&self) -> ChiPlan {
        self.as_workload().chi_plan()
    }

    pub fn generate(&self) -> Result<Mps> {
        self.as_workload().generate()
    }

    pub fn generate_site(&self, i: usize, chi_l: usize, plan: &ChiPlan) -> Result<Site> {
        self.as_workload().generate_site(i, chi_l, plan)
    }

    pub fn thresholds(&self, site: usize, sample0: u64, n: usize) -> Vec<f32> {
        self.as_workload().thresholds(site, sample0, n)
    }

    pub fn displacements(&self, site: usize, sample0: u64, n: usize) -> Option<Vec<(f64, f64)>> {
        self.as_workload().displacements(site, sample0, n)
    }

    pub fn has_displacement(&self) -> bool {
        self.as_workload().has_displacement()
    }

    pub fn sink_max_gap(&self) -> usize {
        self.as_workload().sink_max_gap()
    }

    /// The GBS spec, if this is the GBS workload (perf presets and the
    /// spec-echo JSON need the concrete fields).
    pub fn as_gbs(&self) -> Option<&GbsSpec> {
        match self {
            WorkloadSpec::Gbs(s) => Some(s),
            _ => None,
        }
    }
}

impl From<GbsSpec> for WorkloadSpec {
    fn from(s: GbsSpec) -> Self {
        WorkloadSpec::Gbs(s)
    }
}

impl From<&GbsSpec> for WorkloadSpec {
    fn from(s: &GbsSpec) -> Self {
        WorkloadSpec::Gbs(s.clone())
    }
}

impl From<QubitSpec> for WorkloadSpec {
    fn from(s: QubitSpec) -> Self {
        WorkloadSpec::Qubit(s)
    }
}

impl From<&QubitSpec> for WorkloadSpec {
    fn from(s: &QubitSpec) -> Self {
        WorkloadSpec::Qubit(s.clone())
    }
}

impl From<&WorkloadSpec> for WorkloadSpec {
    fn from(s: &WorkloadSpec) -> Self {
        s.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrips_through_tag() {
        for k in WorkloadKind::ALL {
            assert_eq!(WorkloadKind::parse(k.as_str()).unwrap(), k);
        }
    }

    #[test]
    fn unknown_kind_lists_valid_names() {
        let err = WorkloadKind::parse("boson2").unwrap_err().to_string();
        assert!(err.contains("boson2"), "{err}");
        assert!(err.contains("gbs"), "{err}");
        assert!(err.contains("qubit"), "{err}");
    }

    #[test]
    fn gbs_spec_converts_and_delegates() {
        let gbs = GbsSpec {
            name: "t".into(),
            m: 8,
            d: 3,
            chi_cap: 16,
            asp: 4.0,
            decay_k: 0.0,
            displacement_sigma: 0.25,
            branch_skew: 0.0,
            seed: 11,
            dynamic_chi: false,
            step_ratio_override: None,
        };
        let w: WorkloadSpec = (&gbs).into();
        assert_eq!(w.kind(), WorkloadKind::Gbs);
        assert_eq!(w.tag(), "gbs");
        assert_eq!((w.m(), w.d(), w.chi_cap(), w.seed()), (8, 3, 16, 11));
        assert!(w.has_displacement());
        // Accessor streams must equal the inherent GBS streams bit-for-bit
        // (the PR 5 bit-identity discipline rides on this).
        assert_eq!(w.thresholds(3, 5, 7), gbs.thresholds(3, 5, 7));
        assert_eq!(
            w.displacements(2, 1, 4).unwrap(),
            gbs.displacement_draws(2, 1, 4)
        );
    }

    #[test]
    fn displacement_hook_is_none_when_disabled() {
        let gbs = GbsSpec {
            name: "t".into(),
            m: 4,
            d: 3,
            chi_cap: 8,
            asp: 4.0,
            decay_k: 0.0,
            displacement_sigma: 0.0,
            branch_skew: 0.0,
            seed: 1,
            dynamic_chi: false,
            step_ratio_override: None,
        };
        let w = WorkloadSpec::from(gbs);
        assert!(!w.has_displacement());
        assert!(w.displacements(0, 0, 4).is_none());
    }
}
