//! Exact marginals via transfer-matrix contraction — the "ideal" axis of the
//! paper's Fig. 9 validation.
//!
//! For a right-canonical MPS the unconditional distribution at site `i` is
//! `P(s) = tr(Γ_i[s]† ρ_i Γ_i[s])` where the left density matrix follows the
//! recursion `ρ_{i+1} = Σ_s Γ_i[s]† ρ_i Γ_i[s]`, `ρ_0 = (1)`. Per-site
//! renormalization by the trace makes the recursion exact for the scaled
//! (Eq. 5) tensors as well. Pair moments `E[n_i n_j]` insert the photon
//! number at site `i` and transfer the weighted matrix to `j`. Cost is
//! `O(M d χ³)` — fine at validation scales.



use crate::mps::Mps;
use crate::tensor::{Mat, Tensor3, C64};
use crate::util::error::{Error, Result};

/// Extract the χ_l×χ_r matrix Γ[s] at a fixed physical index.
fn phys_slice(g: &Tensor3<f64>, s: usize) -> Mat<f64> {
    let mut m = Mat::zeros(g.d0, g.d1);
    for i in 0..g.d0 {
        for j in 0..g.d1 {
            m[(i, j)] = g.at(i, j, s);
        }
    }
    m
}

/// ρ ← Σ_s w_s · Γ[s]† ρ Γ[s]; returns per-s traces tr(Γ[s]† ρ Γ[s]).
fn transfer(rho: &Mat<f64>, g: &Tensor3<f64>, weights: Option<&[f64]>) -> (Mat<f64>, Vec<f64>) {
    let d = g.d2;
    let mut out = Mat::zeros(g.d1, g.d1);
    let mut traces = vec![0.0; d];
    for s in 0..d {
        let a = phys_slice(g, s); // χ_l×χ_r
        // t = ρ·A  (χ_l×χ_r), then contribution A†·t (χ_r×χ_r).
        let t = crate::linalg::gemm(rho, &a, 1).expect("shape");
        let contrib = crate::linalg::gemm(&a.dagger(), &t, 1).expect("shape");
        let mut tr = 0.0;
        for k in 0..g.d1 {
            tr += contrib[(k, k)].re;
        }
        traces[s] = tr;
        let w = weights.map(|w| w[s]).unwrap_or(1.0);
        for (o, c) in out.data.iter_mut().zip(&contrib.data) {
            *o += c.scale(w);
        }
    }
    (out, traces)
}

fn trace(m: &Mat<f64>) -> f64 {
    (0..m.rows).map(|i| m[(i, i)].re).sum()
}

/// Exact per-site outcome distributions `P_i(s)` — `M × d` row-major.
pub fn exact_site_distributions(mps: &Mps) -> Result<Vec<Vec<f64>>> {
    mps.check()?;
    let mut rho = Mat::from_vec(1, 1, vec![C64::one()])?;
    let mut out = Vec::with_capacity(mps.num_sites());
    for site in &mps.sites {
        let (next, traces) = transfer(&rho, &site.gamma, None);
        let z: f64 = traces.iter().sum();
        if z <= 0.0 || !z.is_finite() {
            return Err(Error::numeric(format!("transfer trace {z}")));
        }
        out.push(traces.iter().map(|t| t / z).collect());
        rho = next;
        let tz = trace(&rho);
        rho.scale_in_place(1.0 / tz);
    }
    Ok(out)
}

/// Exact mean photon number ⟨n_i⟩ per site.
pub fn exact_mean_photons(mps: &Mps) -> Result<Vec<f64>> {
    Ok(exact_site_distributions(mps)?
        .iter()
        .map(|p| p.iter().enumerate().map(|(s, q)| s as f64 * q).sum())
        .collect())
}

/// Exact pair moments `E[n_i n_j]` for all pairs with `j − i ∈ [1, max_gap]`.
/// Returns `(i, j, value)` triples.
pub fn exact_pair_moments(mps: &Mps, max_gap: usize) -> Result<Vec<(usize, usize, f64)>> {
    mps.check()?;
    let m = mps.num_sites();
    // Precompute normalized left densities ρ_i.
    let mut rhos = Vec::with_capacity(m);
    let mut rho = Mat::from_vec(1, 1, vec![C64::one()])?;
    for site in &mps.sites {
        rhos.push(rho.clone());
        let (next, _) = transfer(&rho, &site.gamma, None);
        rho = next;
        let tz = trace(&rho);
        if tz <= 0.0 || !tz.is_finite() {
            return Err(Error::numeric(format!("transfer trace {tz}")));
        }
        rho.scale_in_place(1.0 / tz);
    }

    let mut out = Vec::new();
    let number_weights: Vec<f64> = (0..mps.d).map(|s| s as f64).collect();
    for i in 0..m {
        // Numerator chain carries the n̂ insertion at site i; denominator
        // chain is the plain transfer. Any per-site scale factors (Eq. 5)
        // multiply both identically, so the ratio is exact.
        let (mut num, _) = transfer(&rhos[i], &mps.sites[i].gamma, Some(&number_weights));
        let (mut den, _) = transfer(&rhos[i], &mps.sites[i].gamma, None);
        for j in i + 1..m.min(i + max_gap + 1) {
            let (num_next, num_traces) = transfer(&num, &mps.sites[j].gamma, None);
            let (den_next, den_traces) = transfer(&den, &mps.sites[j].gamma, None);
            let nval: f64 = num_traces
                .iter()
                .enumerate()
                .map(|(t, q)| t as f64 * q)
                .sum();
            let dval: f64 = den_traces.iter().sum();
            if dval <= 0.0 || !dval.is_finite() {
                return Err(Error::numeric(format!("pair moment norm {dval}")));
            }
            out.push((i, j, nval / dval));
            num = num_next;
            den = den_next;
            // Rescale both chains together to avoid drift over long gaps.
            let tz = trace(&den);
            if tz > 0.0 && tz.is_finite() {
                num.scale_in_place(1.0 / tz);
                den.scale_in_place(1.0 / tz);
            }
        }
    }
    Ok(out)
}

/// Estimate the first/second-order correlation slope (paper Fig. 9 a/c):
/// least-squares through the origin of (ideal, simulated) pairs.
pub fn correlation_slope(ideal: &[f64], simulated: &[f64]) -> f64 {
    let mut num = 0.0;
    let mut den = 0.0;
    for (&x, &y) in ideal.iter().zip(simulated) {
        num += x * y;
        den += x * x;
    }
    if den == 0.0 {
        0.0
    } else {
        num / den
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mps::gbs::GbsSpec;

    fn spec(m: usize, chi: usize, seed: u64) -> GbsSpec {
        GbsSpec {
            name: "t".into(),
            m,
            d: 3,
            chi_cap: chi,
            asp: 4.0,
            decay_k: 0.0,
            displacement_sigma: 0.0,
            branch_skew: 0.0,
            seed,
            dynamic_chi: false,
            step_ratio_override: None,
        }
    }

    #[test]
    fn distributions_are_normalized() {
        let mps = spec(10, 8, 3).generate().unwrap();
        let ps = exact_site_distributions(&mps).unwrap();
        assert_eq!(ps.len(), 10);
        for (i, p) in ps.iter().enumerate() {
            let z: f64 = p.iter().sum();
            assert!((z - 1.0).abs() < 1e-10, "site {i}: Σp = {z}");
            assert!(p.iter().all(|&q| q >= -1e-14));
        }
    }

    #[test]
    fn decay_scaling_does_not_change_distributions() {
        let base = spec(8, 6, 11).generate().unwrap();
        let mut decayed_spec = spec(8, 6, 11);
        decayed_spec.decay_k = 0.8;
        let decayed = decayed_spec.generate().unwrap();
        let p0 = exact_site_distributions(&base).unwrap();
        let p1 = exact_site_distributions(&decayed).unwrap();
        for (a, b) in p0.iter().zip(&p1) {
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < 1e-9, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn single_site_matches_brute_force() {
        // M=2, tiny χ: enumerate all outcomes from the raw amplitudes.
        let mps = spec(2, 3, 5).generate().unwrap();
        let d = mps.d;
        // amplitude(s0, s1) = Γ0[0, :, s0] · Γ1[:, 0, s1]
        let mut joint = vec![vec![0.0f64; d]; d];
        let mut z = 0.0;
        for s0 in 0..d {
            for s1 in 0..d {
                let mut amp = C64::zero();
                for x in 0..mps.sites[0].gamma.d1 {
                    amp += mps.sites[0].gamma.at(0, x, s0) * mps.sites[1].gamma.at(x, 0, s1);
                }
                let p = amp.norm_sq();
                joint[s0][s1] = p;
                z += p;
            }
        }
        let ps = exact_site_distributions(&mps).unwrap();
        for s0 in 0..d {
            let want: f64 = joint[s0].iter().sum::<f64>() / z;
            assert!((ps[0][s0] - want).abs() < 1e-10, "site0 s={s0}");
        }
        for s1 in 0..d {
            let want: f64 = (0..d).map(|s0| joint[s0][s1]).sum::<f64>() / z;
            assert!((ps[1][s1] - want).abs() < 1e-10, "site1 s={s1}");
        }
        // Pair moment from the joint too.
        let pm = exact_pair_moments(&mps, 1).unwrap();
        let want: f64 = (0..d)
            .flat_map(|a| (0..d).map(move |b| (a, b)))
            .map(|(a, b)| (a * b) as f64 * joint[a][b] / z)
            .sum();
        let got = pm.iter().find(|&&(i, j, _)| i == 0 && j == 1).unwrap().2;
        assert!((got - want).abs() < 1e-10, "pair moment {got} vs {want}");
    }

    #[test]
    fn mean_photons_in_range() {
        let mps = spec(12, 10, 9).generate().unwrap();
        let means = exact_mean_photons(&mps).unwrap();
        for m in means {
            assert!((0.0..=(mps.d - 1) as f64).contains(&m));
        }
    }

    #[test]
    fn slope_of_identical_data_is_one() {
        let x = [0.2, 0.5, 0.9, 1.4];
        assert!((correlation_slope(&x, &x) - 1.0).abs() < 1e-12);
        let y: Vec<f64> = x.iter().map(|v| v * 0.96).collect();
        assert!((correlation_slope(&x, &y) - 0.96).abs() < 1e-12);
        assert_eq!(correlation_slope(&[0.0, 0.0], &[1.0, 1.0]), 0.0);
    }

    #[test]
    fn pair_moments_bounded() {
        let mps = spec(8, 8, 13).generate().unwrap();
        let pm = exact_pair_moments(&mps, 3).unwrap();
        let dmax = (mps.d - 1) as f64;
        for (i, j, v) in pm {
            assert!(j > i && j - i <= 3);
            assert!((0.0..=dmax * dmax + 1e-9).contains(&v), "({i},{j}): {v}");
        }
    }
}
