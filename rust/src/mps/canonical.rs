//! Right-canonical form: construction and verification.
//!
//! A site tensor `Γ (χ_l, χ_r, d)` is right-canonical when the `(χ_l, χ_r·d)`
//! unfolding has orthonormal rows, i.e. `Σ_s Γ[s]·Γ[s]† = I_{χ_l}`. With the
//! whole chain in this form, left-to-right sequential measurement with unit
//! Λ is the exact Born rule — the property our validation experiments rely
//! on.

use crate::rng::Xoshiro256;
use crate::tensor::{Complex, Mat, Tensor3, C64};
use crate::util::error::{Error, Result};

/// Orthonormalize the rows of `m` in place with modified Gram–Schmidt +
/// one re-orthogonalization pass (numerically solid for χ ≤ a few thousand).
/// Requires rows ≤ cols.
pub fn orthonormalize_rows(m: &mut Mat<f64>) -> Result<()> {
    if m.rows > m.cols {
        return Err(Error::shape(format!(
            "orthonormalize_rows: {}×{} has more rows than cols",
            m.rows, m.cols
        )));
    }
    let n = m.cols;
    for pass in 0..2 {
        for i in 0..m.rows {
            // Subtract projections onto previous rows.
            for j in 0..i {
                let mut dot = C64::zero();
                {
                    let (rj, ri) = row_pair(m, j, i);
                    for (a, b) in rj.iter().zip(ri.iter()) {
                        dot = dot.mul_add(a.conj(), *b);
                    }
                }
                let (rj, ri) = row_pair(m, j, i);
                for (a, b) in rj.iter().zip(ri.iter_mut()) {
                    *b = *b - *a * dot;
                }
            }
            // Normalize.
            let row = m.row_mut(i);
            let norm: f64 = row.iter().map(|z| z.norm_sq()).sum::<f64>().sqrt();
            if norm < 1e-300 {
                // Degenerate row (probability ~0 with random input): replace
                // with a fresh unit vector orthogonal to nothing yet; only
                // valid on the first pass.
                if pass == 1 {
                    return Err(Error::numeric("orthonormalize_rows: rank deficient"));
                }
                for (k, z) in row.iter_mut().enumerate() {
                    *z = if k == i { Complex::one() } else { Complex::zero() };
                }
                let _ = n;
            } else {
                let inv = 1.0 / norm;
                for z in row.iter_mut() {
                    *z = z.scale(inv);
                }
            }
        }
    }
    Ok(())
}

fn row_pair<'a>(m: &'a mut Mat<f64>, j: usize, i: usize) -> (&'a [C64], &'a mut [C64]) {
    debug_assert!(j < i);
    let cols = m.cols;
    let (head, tail) = m.data.split_at_mut(i * cols);
    (&head[j * cols..(j + 1) * cols], &mut tail[..cols])
}

/// Draw a random right-canonical site tensor `(χ_l, χ_r, d)`; requires
/// `χ_l ≤ χ_r·d` (true for any admissible bond profile).
pub fn random_right_canonical(
    rng: &mut Xoshiro256,
    chi_l: usize,
    chi_r: usize,
    d: usize,
) -> Result<Tensor3<f64>> {
    if chi_l > chi_r * d {
        return Err(Error::shape(format!(
            "random_right_canonical: χ_l={chi_l} > χ_r·d={}",
            chi_r * d
        )));
    }
    let mut m = Mat::from_vec(
        chi_l,
        chi_r * d,
        (0..chi_l * chi_r * d)
            .map(|_| {
                let (re, im) = rng.complex_normal();
                C64::new(re, im)
            })
            .collect(),
    )?;
    orthonormalize_rows(&mut m)?;
    Tensor3::from_vec(chi_l, chi_r, d, m.data)
}

/// Max deviation of `Σ_s Γ[s]·Γ[s]† − I` (∞-norm over entries); ~0 for a
/// right-canonical tensor. The contraction over `(χ_r, d)` is exactly a
/// row-row inner product of the unfolding.
pub fn right_canonical_residual(g: &Tensor3<f64>) -> f64 {
    let chi_l = g.d0;
    let cols = g.d1 * g.d2;
    let mut worst = 0.0f64;
    for i in 0..chi_l {
        let ri = &g.data[i * cols..(i + 1) * cols];
        for j in i..chi_l {
            let rj = &g.data[j * cols..(j + 1) * cols];
            let mut dot = C64::zero();
            for (a, b) in ri.iter().zip(rj.iter()) {
                dot = dot.mul_add(*a, b.conj());
            }
            let want = if i == j { 1.0 } else { 0.0 };
            worst = worst.max((dot - C64::from_re(want)).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_site_is_right_canonical() {
        let mut rng = Xoshiro256::seed_from(101);
        for (chi_l, chi_r, d) in [(1, 4, 3), (4, 4, 3), (8, 3, 3), (16, 16, 2), (5, 2, 3)] {
            let g = random_right_canonical(&mut rng, chi_l, chi_r, d).unwrap();
            let res = right_canonical_residual(&g);
            assert!(res < 1e-12, "({chi_l},{chi_r},{d}): residual {res}");
        }
    }

    #[test]
    fn rejects_impossible_shape() {
        let mut rng = Xoshiro256::seed_from(102);
        assert!(random_right_canonical(&mut rng, 10, 3, 3).is_err());
    }

    #[test]
    fn orthonormalize_rejects_wide_rows() {
        let mut m: Mat<f64> = Mat::zeros(3, 2);
        assert!(orthonormalize_rows(&mut m).is_err());
    }

    #[test]
    fn residual_detects_non_canonical() {
        let mut rng = Xoshiro256::seed_from(103);
        let mut g = random_right_canonical(&mut rng, 4, 4, 2).unwrap();
        // Break it.
        *g.at_mut(0, 0, 0) = C64::new(2.0, 0.0);
        assert!(right_canonical_residual(&g) > 0.1);
    }

    #[test]
    fn property_random_shapes_canonical() {
        crate::util::prop::quickcheck("right canonical residual ~ 0", |pg| {
            let d = pg.usize_in(2, 5);
            let chi_r = pg.len(1, 12);
            let chi_l = pg.usize_in(1, (chi_r * d).min(12) + 1);
            let mut rng = Xoshiro256::seed_from(pg.u64());
            let g = random_right_canonical(&mut rng, chi_l, chi_r, d)
                .map_err(|e| e.to_string())?;
            let r = right_canonical_residual(&g);
            if r < 1e-10 {
                Ok(())
            } else {
                Err(format!("residual {r} for ({chi_l},{chi_r},{d})"))
            }
        });
    }
}
