//! Qubit-chain (d = 2) workload — the second [`crate::mps::workload::Workload`]
//! implementation, standing in for qubit-circuit MPS sampling and MPS
//! generative models (PAPERS.md: arxiv 2506.08395, 2406.17441).
//!
//! Structurally this is the simplest instantiation of Alg. 1: fixed χ plan,
//! right-canonical random chain, no displacement, no magnitude decay. Its
//! job in this codebase is architectural — it must ride the prepared-site
//! cache, service batching, router affinity, and TP collectives with zero
//! workload-specific branches downstream of the spec.
//!
//! Seed streams are salted so a qubit dataset never reuses a GBS dataset's
//! random draws even at an identical numeric seed; the store-manifest
//! `workload` tag (not the salt) is what keeps content keys distinct.

use crate::mps::canonical::random_right_canonical;
use crate::mps::entanglement::ChiPlan;
use crate::mps::workload::{Workload, WorkloadKind};
use crate::mps::Site;
use crate::rng::{purpose, Xoshiro256};
use crate::util::error::Result;

/// Physical dimension of every qubit site tensor.
pub const QUBIT_D: usize = 2;

/// Distinguishes qubit RNG streams from GBS streams at equal seeds.
const SEED_SALT: u64 = 0x7175_6269_7464_3221; // "qubitd2!"

/// Specification of a synthetic qubit-chain dataset.
#[derive(Debug, Clone)]
pub struct QubitSpec {
    /// Dataset name (preset id or "custom").
    pub name: String,
    /// Number of qubits (sites).
    pub m: usize,
    /// Bond dimension cap χ (fixed plan — no ASP ramp at d = 2).
    pub chi_cap: usize,
    /// Amplitude bias of the |1⟩ branch (`1.0` = unbiased). Values < 1
    /// suppress excited outcomes like GBS `branch_skew`; this breaks exact
    /// right-canonicality, so keep `1.0` for oracle/validation runs.
    pub bias: f64,
    /// Dataset seed.
    pub seed: u64,
}

impl QubitSpec {
    /// An unbiased chain — the validation-friendly default.
    pub fn new(name: &str, m: usize, chi_cap: usize, seed: u64) -> QubitSpec {
        QubitSpec {
            name: name.into(),
            m,
            chi_cap,
            bias: 1.0,
            seed,
        }
    }
}

impl Workload for QubitSpec {
    fn kind(&self) -> WorkloadKind {
        WorkloadKind::Qubit
    }

    fn dataset_name(&self) -> &str {
        &self.name
    }

    fn num_sites(&self) -> usize {
        self.m
    }

    fn phys_d(&self) -> usize {
        QUBIT_D
    }

    fn chi_cap(&self) -> usize {
        self.chi_cap
    }

    fn seed(&self) -> u64 {
        self.seed
    }

    fn chi_plan(&self) -> ChiPlan {
        ChiPlan::fixed(self.m, QUBIT_D, self.chi_cap)
    }

    /// Deterministic in `(seed, i)` — same independence property as GBS
    /// site generation (streaming stores, model-parallel ranks).
    fn generate_site(&self, i: usize, chi_l: usize, plan: &ChiPlan) -> Result<Site> {
        let chi_r = if i + 1 == self.m { 1 } else { plan.chi[i] };
        let mut rng = Xoshiro256::stream(self.seed ^ SEED_SALT, purpose::DATAGEN, i as u64);
        let mut gamma = random_right_canonical(&mut rng, chi_l, chi_r, QUBIT_D)?;
        if self.bias != 1.0 {
            for a in 0..gamma.d0 {
                for b in 0..gamma.d1 {
                    let z = gamma.at(a, b, 1);
                    *gamma.at_mut(a, b, 1) = z.scale(self.bias);
                }
            }
        }
        Ok(Site {
            lambda: vec![1.0; chi_r],
            gamma,
        })
    }

    /// Partition-invariant (same contract as GBS: `[s0, s0+n)` draws do not
    /// depend on how samples are batched).
    fn thresholds(&self, site: usize, sample0: u64, n: usize) -> Vec<f32> {
        (0..n as u64)
            .map(|s| {
                let mut rng = Xoshiro256::stream(
                    self.seed ^ SEED_SALT ^ (site as u64).rotate_left(33),
                    purpose::THRESHOLD,
                    sample0 + s,
                );
                rng.unit_f32()
            })
            .collect()
    }

    /// Qubit measurement has no displacement concept.
    fn displacements(&self, _site: usize, _sample0: u64, _n: usize) -> Option<Vec<(f64, f64)>> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mps::canonical::right_canonical_residual;
    use crate::mps::gbs::GbsSpec;

    fn small_spec() -> QubitSpec {
        QubitSpec::new("qtest", 10, 8, 7)
    }

    #[test]
    fn generates_valid_canonical_chain() {
        let mps = small_spec().generate().unwrap();
        assert_eq!(mps.num_sites(), 10);
        assert_eq!(mps.d, 2);
        mps.check().unwrap();
        for (i, s) in mps.sites.iter().enumerate() {
            let r = right_canonical_residual(&s.gamma);
            assert!(r < 1e-10, "site {i}: residual {r}");
        }
    }

    #[test]
    fn generation_is_deterministic_and_site_independent() {
        let spec = small_spec();
        let a = spec.generate().unwrap();
        let b = spec.generate().unwrap();
        let plan = spec.chi_plan();
        let mut chi_l = 1;
        for (i, (x, y)) in a.sites.iter().zip(&b.sites).enumerate() {
            assert_eq!(x.gamma.data, y.gamma.data);
            let s = spec.generate_site(i, chi_l, &plan).unwrap();
            assert_eq!(s.gamma.data, x.gamma.data, "site {i}");
            chi_l = s.chi_r();
        }
    }

    #[test]
    fn thresholds_partition_invariant() {
        let spec = small_spec();
        let all = spec.thresholds(4, 0, 12);
        let tail = spec.thresholds(4, 7, 5);
        assert_eq!(&all[7..], &tail[..]);
    }

    #[test]
    fn streams_distinct_from_gbs_at_equal_seed() {
        let q = small_spec();
        let g = GbsSpec {
            name: "g".into(),
            m: q.m,
            d: 2,
            chi_cap: q.chi_cap,
            asp: 4.0,
            decay_k: 0.0,
            displacement_sigma: 0.0,
            branch_skew: 0.0,
            seed: q.seed,
            dynamic_chi: false,
            step_ratio_override: None,
        };
        assert_ne!(Workload::thresholds(&q, 0, 0, 16), g.thresholds(0, 0, 16));
        let plan = Workload::chi_plan(&q);
        let qs = Workload::generate_site(&q, 0, 1, &plan).unwrap();
        let gs = g.generate_site(0, 1, &g.chi_plan()).unwrap();
        assert_ne!(qs.gamma.data, gs.gamma.data);
    }

    #[test]
    fn bias_suppresses_excited_branch() {
        let mut spec = small_spec();
        spec.bias = 0.1;
        let mps = spec.generate().unwrap();
        for site in &mps.sites {
            let g = &site.gamma;
            let mut norms = [0.0f64; 2];
            for a in 0..g.d0 {
                for b in 0..g.d1 {
                    for s in 0..2 {
                        norms[s] += g.at(a, b, s).norm_sq();
                    }
                }
            }
            assert!(norms[1] < norms[0] * 0.05);
        }
    }

    #[test]
    fn no_displacement_hook() {
        let spec = small_spec();
        assert!(!spec.has_displacement());
        assert!(spec.displacements(0, 0, 8).is_none());
    }

    #[test]
    fn sampled_distribution_matches_exact_enumeration_oracle() {
        // Born-rule check at d = 2: walk a tiny chain with the production
        // engine and compare the sampled per-site outcome distribution
        // against the transfer-matrix oracle in `mps::exact`.
        use crate::config::{ComputePrecision, ScalingMode};
        use crate::mps::exact::exact_site_distributions;
        use crate::sampler::native::NativeEngine;
        use crate::sampler::{boundary_env, StepEngine};

        let spec = QubitSpec::new("oracle", 6, 4, 23);
        let mps = spec.generate().unwrap();
        let exact = exact_site_distributions(&mps).unwrap();
        let n = 4096;
        let mut eng = NativeEngine::new(ComputePrecision::F64, ScalingMode::PerSample, 1);
        let mut env = boundary_env(n);
        for (i, site) in mps.sites.iter().enumerate() {
            let th = Workload::thresholds(&spec, i, 0, n);
            let mut s = Vec::new();
            eng.step(&mut env, site, &th, None, &mut s).unwrap();
            assert!(s.iter().all(|&b| b == 0 || b == 1), "site {i}: non-binary outcome");
            let p1 = s.iter().filter(|&&b| b == 1).count() as f64 / n as f64;
            // Binomial error at N=4096 is ≤ 0.5/√4096 ≈ 0.008; allow 5σ.
            assert!(
                (p1 - exact[i][1]).abs() < 0.04,
                "site {i}: sampled P(1) = {p1} vs exact {}",
                exact[i][1]
            );
            assert!((exact[i][0] + exact[i][1] - 1.0).abs() < 1e-10);
        }
    }
}
