//! Matrix Product State representation and the synthetic GBS state
//! generator.
//!
//! An MPS over `M` sites with physical dimension `d` is a chain of site
//! tensors `Γ_i (χ_i, χ_{i+1}, d)` with `χ_0 = χ_M = 1`, plus a per-bond
//! coefficient vector `Λ_i` (the paper's Alg. 1 input). We generate states
//! in **right-canonical form** (`Σ_s Γ_i[s]·Γ_i[s]† = I`), for which the
//! sequential measurement of Alg. 1 with unit Λ is exactly the Born rule —
//! that is what makes the validation experiments (Fig. 9) well-defined:
//! exact single-site and pair marginals are computable by a transfer-matrix
//! recursion ([`exact`]) and must match the sampler.
//!
//! The paper's datasets are experimental GBS states; we substitute
//! [`gbs::GbsSpec`]-driven synthetic states that preserve what the paper's
//! optimizations feed on (see DESIGN.md §Substitutions): the area-law
//! entanglement/χ profile ([`entanglement`]), the per-site magnitude decay
//! `μ_i ~ μ_0·10^{−ik}` (Eq. 5) that motivates adaptive scaling, and the
//! per-sample displacement draws of §3.4.1.

pub mod canonical;
pub mod entanglement;
pub mod exact;
pub mod gbs;
pub mod qubit;
pub mod workload;

use crate::tensor::Tensor3;

/// One site of an MPS: the Γ tensor plus the bond coefficient vector Λ for
/// its *right* bond (length `gamma.d1`). Λ enters Alg. 1's probability
/// contraction; right-canonical generation sets it to all-ones.
#[derive(Debug, Clone)]
pub struct Site {
    pub gamma: Tensor3<f64>,
    pub lambda: Vec<f64>,
}

impl Site {
    pub fn chi_l(&self) -> usize {
        self.gamma.d0
    }

    pub fn chi_r(&self) -> usize {
        self.gamma.d1
    }

    pub fn phys_d(&self) -> usize {
        self.gamma.d2
    }
}

/// An in-memory MPS (small scales / tests; large scales stream through
/// [`crate::io::GammaStore`] instead).
#[derive(Debug, Clone)]
pub struct Mps {
    pub sites: Vec<Site>,
    pub d: usize,
}

impl Mps {
    pub fn num_sites(&self) -> usize {
        self.sites.len()
    }

    /// Bond dimension profile `χ_1..χ_{M-1}` (interior bonds).
    pub fn chi_profile(&self) -> Vec<usize> {
        self.sites[..self.sites.len() - 1]
            .iter()
            .map(|s| s.chi_r())
            .collect()
    }

    /// Validate chain consistency: boundary bonds are 1, adjacent bonds
    /// match, Λ lengths match, uniform physical dimension.
    pub fn check(&self) -> crate::Result<()> {
        use crate::util::error::Error;
        if self.sites.is_empty() {
            return Err(Error::shape("empty MPS"));
        }
        if self.sites[0].chi_l() != 1 {
            return Err(Error::shape("left boundary bond != 1"));
        }
        if self.sites.last().unwrap().chi_r() != 1 {
            return Err(Error::shape("right boundary bond != 1"));
        }
        for (i, w) in self.sites.windows(2).enumerate() {
            if w[0].chi_r() != w[1].chi_l() {
                return Err(Error::shape(format!(
                    "bond mismatch between sites {i} and {}: {} vs {}",
                    i + 1,
                    w[0].chi_r(),
                    w[1].chi_l()
                )));
            }
        }
        for (i, s) in self.sites.iter().enumerate() {
            if s.lambda.len() != s.chi_r() {
                return Err(Error::shape(format!(
                    "site {i}: Λ length {} != χ_r {}",
                    s.lambda.len(),
                    s.chi_r()
                )));
            }
            if s.phys_d() != self.d {
                return Err(Error::shape(format!(
                    "site {i}: physical dim {} != {}",
                    s.phys_d(),
                    self.d
                )));
            }
        }
        Ok(())
    }

    /// Total parameter count (the paper's "2452B parameters" aside).
    pub fn num_params(&self) -> u64 {
        self.sites.iter().map(|s| s.gamma.len() as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor3;

    fn site(chi_l: usize, chi_r: usize, d: usize) -> Site {
        Site {
            gamma: Tensor3::zeros(chi_l, chi_r, d),
            lambda: vec![1.0; chi_r],
        }
    }

    #[test]
    fn check_accepts_valid_chain() {
        let mps = Mps {
            sites: vec![site(1, 3, 2), site(3, 4, 2), site(4, 1, 2)],
            d: 2,
        };
        mps.check().unwrap();
        assert_eq!(mps.chi_profile(), vec![3, 4]);
        assert_eq!(mps.num_params(), (6 + 24 + 8) as u64);
    }

    #[test]
    fn check_rejects_bond_mismatch() {
        let mps = Mps {
            sites: vec![site(1, 3, 2), site(4, 1, 2)],
            d: 2,
        };
        assert!(mps.check().is_err());
    }

    #[test]
    fn check_rejects_bad_boundaries() {
        let mps = Mps {
            sites: vec![site(2, 1, 2)],
            d: 2,
        };
        assert!(mps.check().is_err());
        let mps2 = Mps {
            sites: vec![site(1, 2, 2)],
            d: 2,
        };
        assert!(mps2.check().is_err());
    }

    #[test]
    fn check_rejects_lambda_mismatch() {
        let mut s = site(1, 3, 2);
        s.lambda.pop();
        let mps = Mps {
            sites: vec![s, site(3, 1, 2)],
            d: 2,
        };
        assert!(mps.check().is_err());
    }
}
