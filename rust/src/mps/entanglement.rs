//! Entanglement profile and dynamic bond dimensions (§3.4.2, Fig. 8,
//! Table 1).
//!
//! By the area law, entanglement entropy ramps up from the chain edges and
//! plateaus in the bulk, so a *fixed* bond dimension is redundant at the
//! edges. FastMPS assigns a per-site χ from the profile and only the region
//! under the entanglement curve is computed.
//!
//! Calibration: fitting Table 1 of the paper, the χ profile is modelled as a
//! **quadratic edge ramp to a plateau**, `χ(i)/χ_cap = min(1, (min(i+1, M−i)/w)²)`,
//! where the edge width `w` follows from the *step ratio* `s` (fraction of
//! sites computed at full χ): `w = M(1−s)/2`. A quadratic ramp reproduces
//! every comp-ratio in Table 1 within ~2 % given the paper's step ratio
//! (comp ≈ s + (1−s)/5). The step ratio itself is tied to the actual
//! squeezed photon number (ASP): `s(ASP) = max(0, 1 − 1.54·ASP^−0.85)`,
//! fitted to the five datasets; presets may override with measured values.

/// Per-site dynamic bond dimension plan.
#[derive(Debug, Clone)]
pub struct ChiPlan {
    /// χ for each *interior* bond `1..M` indexed by site (bond i is the
    /// right bond of site i); the final bond is 1.
    pub chi: Vec<usize>,
    /// The cap (the paper's fixed χ baseline).
    pub chi_cap: usize,
}

/// Step-ratio model from actual squeezed photons (fitted to Table 1).
pub fn step_ratio_from_asp(asp: f64) -> f64 {
    if asp <= 0.0 {
        return 0.0;
    }
    (1.0 - 1.54 * asp.powf(-0.85)).max(0.0)
}

/// Build the dynamic χ plan for `m` sites.
///
/// `step_ratio` is the fraction of bonds at full `chi_cap` (use
/// [`step_ratio_from_asp`] or a measured override). `chi_min` floors the
/// edge bonds (χ can also never exceed the exact Hilbert-space bound
/// `d^min(i+1, M−i−1)`).
pub fn plan_dynamic_chi(
    m: usize,
    d: usize,
    chi_cap: usize,
    step_ratio: f64,
    chi_min: usize,
) -> ChiPlan {
    assert!(m >= 1);
    let s = step_ratio.clamp(0.0, 1.0);
    let w = ((m as f64) * (1.0 - s) / 2.0).max(1.0);
    let mut chi = Vec::with_capacity(m);
    for i in 0..m {
        if i + 1 == m {
            chi.push(1); // right boundary
            continue;
        }
        let edge = ((i + 1).min(m - 1 - i)) as f64;
        let frac = (edge / w).min(1.0);
        let mut c = ((chi_cap as f64) * frac * frac).round() as usize;
        c = c.max(chi_min.min(chi_cap)).min(chi_cap);
        // Exact Hilbert-space bound: bond i supports at most d^(sites on the
        // smaller side).
        let exact_bound = pow_saturating(d, (i + 1).min(m - 1 - i));
        c = c.min(exact_bound);
        chi.push(c.max(1));
    }
    ChiPlan { chi, chi_cap }
}

fn pow_saturating(base: usize, exp: usize) -> usize {
    let mut acc: usize = 1;
    for _ in 0..exp {
        acc = acc.saturating_mul(base);
        if acc >= usize::MAX / base.max(2) {
            return usize::MAX;
        }
    }
    acc
}

impl ChiPlan {
    /// Fixed-χ plan (the baseline the ablation disables dynamic χ into).
    pub fn fixed(m: usize, d: usize, chi_cap: usize) -> ChiPlan {
        let mut chi = Vec::with_capacity(m);
        for i in 0..m {
            if i + 1 == m {
                chi.push(1);
            } else {
                let bound = pow_saturating(d, (i + 1).min(m - 1 - i));
                chi.push(chi_cap.min(bound));
            }
        }
        ChiPlan { chi, chi_cap }
    }

    /// Table 1 "equi χ" = √(avg χ²) over interior bonds.
    pub fn equivalent_chi(&self) -> f64 {
        let interior: Vec<f64> = self.interior().map(|c| (c * c) as f64).collect();
        if interior.is_empty() {
            return self.chi_cap as f64;
        }
        (interior.iter().sum::<f64>() / interior.len() as f64).sqrt()
    }

    /// Table 1 "step ratio": fraction of interior bonds at full χ_cap.
    pub fn step_ratio(&self) -> f64 {
        let (mut full, mut n) = (0usize, 0usize);
        for c in self.interior() {
            n += 1;
            if c >= self.chi_cap {
                full += 1;
            }
        }
        if n == 0 {
            0.0
        } else {
            full as f64 / n as f64
        }
    }

    /// Table 1 "comp ratio": Σχ_i² / (M·χ_cap²) — the fraction of the fixed-χ
    /// contraction cost that remains.
    pub fn comp_ratio(&self) -> f64 {
        let cap2 = (self.chi_cap * self.chi_cap) as f64;
        let (mut acc, mut n) = (0.0, 0usize);
        for c in self.interior() {
            acc += (c * c) as f64 / cap2;
            n += 1;
        }
        if n == 0 {
            1.0
        } else {
            acc / n as f64
        }
    }

    fn interior(&self) -> impl Iterator<Item = usize> + '_ {
        // All bonds except the final boundary bond; tiny exact-bound edge
        // bonds are part of the plan and belong in the averages.
        self.chi[..self.chi.len().saturating_sub(1)].iter().copied()
    }

    /// Bond entropy proxy `S_i = ln χ_i` (exact for maximally mixed spectra;
    /// used for Fig. 8-style output).
    pub fn entropy_profile(&self) -> Vec<f64> {
        self.chi.iter().map(|&c| (c as f64).ln()).collect()
    }

    /// Truncation-error model at bond `i` for a given χ: the synthetic
    /// Schmidt spectrum at bond i is exponential with rate set by the local
    /// plan χ (spectrum mass beyond rank χ). Used for Fig. 9b.
    pub fn truncation_error(&self, site: usize, chi: usize) -> f64 {
        let support = self.chi[site.min(self.chi.len() - 1)] as f64;
        if (chi as f64) >= support {
            return 0.0;
        }
        // Exponential spectrum λ_j² ∝ e^{−αj} with α s.t. the plan χ holds
        // 1−1e−10 of the mass: tail mass past rank χ.
        let alpha = 10.0 * std::f64::consts::LN_10 / support;
        (-alpha * chi as f64).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_plan_is_capped_everywhere() {
        let p = ChiPlan::fixed(100, 3, 64);
        assert_eq!(p.chi.len(), 100);
        assert_eq!(p.chi[50], 64);
        assert_eq!(p.chi[0], 3); // exact bound d^1
        assert_eq!(p.chi[99], 1); // boundary
        assert!((p.step_ratio() - 0.91).abs() < 0.05); // most bonds at cap
    }

    #[test]
    fn table1_shape_reproduced() {
        // Paper Table 1 rows: (M, step_ratio, comp_ratio). Using the
        // measured step ratio, the quadratic-ramp model must land near the
        // paper's comp ratio.
        for (m, step, comp_paper) in [
            (216usize, 0.5879, 0.6923),
            (288, 0.7951, 0.8339),
            (8176, 0.7429, 0.7961),
            (144, 0.4792, 0.5947),
        ] {
            let p = plan_dynamic_chi(m, 4, 10_000, step, 8);
            let comp = p.comp_ratio();
            assert!(
                (comp - comp_paper).abs() < 0.06,
                "M={m}: comp {comp} vs paper {comp_paper}"
            );
            assert!((p.step_ratio() - step).abs() < 0.03, "M={m}");
        }
    }

    #[test]
    fn jiuzhang2_never_reaches_cap() {
        // ASP 1.62 → step ratio 0; the profile stays under cap.
        let s = step_ratio_from_asp(1.62);
        assert_eq!(s, 0.0);
        let p = plan_dynamic_chi(144, 4, 10_000, s, 8);
        // The quadratic ramp touches the cap only at the single central
        // bond (≤ 1/M vs. the paper's 0%).
        assert!(p.step_ratio() <= 1.5 / 144.0, "step {}", p.step_ratio());
        assert!(p.comp_ratio() < 0.35);
    }

    #[test]
    fn asp_ordering_preserved() {
        // Higher ASP ⇒ higher equi-χ (Table 1's physical claim).
        let asps = [1.62, 3.56, 6.54, 8.82, 10.69];
        let equis: Vec<f64> = asps
            .iter()
            .map(|&a| {
                plan_dynamic_chi(288, 4, 10_000, step_ratio_from_asp(a), 8).equivalent_chi()
            })
            .collect();
        for w in equis.windows(2) {
            assert!(w[0] < w[1], "{equis:?}");
        }
    }

    #[test]
    fn equi_chi_is_sqrt_comp() {
        let p = plan_dynamic_chi(500, 3, 2000, 0.6, 4);
        let lhs = p.equivalent_chi() / p.chi_cap as f64;
        let rhs = p.comp_ratio().sqrt();
        assert!((lhs - rhs).abs() < 1e-9);
    }

    #[test]
    fn respects_exact_hilbert_bound() {
        let p = plan_dynamic_chi(10, 2, 1000, 0.9, 64);
        // Bond 0 can hold at most d^1 = 2.
        assert_eq!(p.chi[0], 2);
        assert_eq!(p.chi[1], 4);
        assert_eq!(p.chi[9], 1);
    }

    #[test]
    fn truncation_error_decays_with_chi() {
        let p = plan_dynamic_chi(100, 3, 512, 0.7, 8);
        let mid = 50;
        let e1 = p.truncation_error(mid, 64);
        let e2 = p.truncation_error(mid, 128);
        let e3 = p.truncation_error(mid, 512);
        assert!(e1 > e2 && e2 > e3);
        assert_eq!(e3, 0.0);
    }

    #[test]
    fn property_plan_invariants() {
        crate::util::prop::quickcheck("chi plan invariants", |g| {
            let m = g.usize_in(2, 80);
            let d = g.usize_in(2, 5);
            let cap = g.usize_in(2, 300);
            let s = g.unit_f64();
            let p = plan_dynamic_chi(m, d, cap, s, 2);
            if p.chi.len() != m {
                return Err("wrong length".into());
            }
            if *p.chi.last().unwrap() != 1 {
                return Err("final bond != 1".into());
            }
            for (i, &c) in p.chi.iter().enumerate() {
                if c > cap && i + 1 != m {
                    return Err(format!("bond {i} over cap: {c}"));
                }
                if c == 0 {
                    return Err(format!("bond {i} is zero"));
                }
            }
            let cr = p.comp_ratio();
            if !(0.0..=1.0 + 1e-9).contains(&cr) {
                return Err(format!("comp ratio {cr}"));
            }
            Ok(())
        });
    }
}
