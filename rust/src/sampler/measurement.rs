//! Native Alg. 1: measurement of the physical index + environment collapse,
//! with the three scaling strategies of §3.3.1.
//!
//! Mirrors `python/compile/kernels/ref.py` (same threshold semantics, same
//! degenerate-row handling). The threshold scan hoists the normalization
//! division out of the outcome loop (`inv_tot` computed once) and breaks
//! at the first `!(u > cum)` — index-equivalent to the full scan
//! (including on overflowed/NaN rows) and regression-tested against it,
//! though `p * inv_tot` can differ from ref.py's per-term `p / tot` in
//! the last ulp of a cumulative boundary, so native-vs-XLA agreement is
//! statistical (knife-edge outcome flips at ~2⁻²⁴ per comparison in f32),
//! not bitwise. [`measure_into`] runs rows in parallel into a caller-owned
//! workspace, bit-identically to the serial scan — the single-threaded hot
//! loop was rivalling the GEMM at large χ.

use crate::util::num::Float;

use crate::config::ScalingMode;
use crate::tensor::{Complex, Mat, Tensor3};
use crate::util::error::{Error, Result};

/// Measurement output.
pub struct Measured<T> {
    /// Collapsed (N, χ_r) left environment (scaled per `mode`).
    pub env: Mat<T>,
    /// Outcome per sample, in `[0, d)`.
    pub samples: Vec<i32>,
    /// Number of samples whose probability row was all-zero (underflow
    /// collapse — the Fig. 6 failure signal).
    pub dead_rows: usize,
}

/// Alg. 1 over the unmeasured temp tensor `(N, χ_r, d)`.
pub fn measure<T: Float + std::ops::AddAssign + Send + Sync>(
    temp: &Tensor3<T>,
    lambda: &[T],
    thresholds: &[f32],
    mode: ScalingMode,
) -> Result<Measured<T>> {
    let mut env = Mat::zeros(temp.d0, temp.d1);
    let mut samples = Vec::new();
    let mut probs = Vec::new();
    let dead_rows = measure_into(
        temp, lambda, thresholds, mode, 1, &mut env, &mut samples, &mut probs,
    )?;
    Ok(Measured {
        env,
        samples,
        dead_rows,
    })
}

/// One sample row of Alg. 1: probability contraction, threshold scan, and
/// environment collapse. Shared verbatim by the serial and row-parallel
/// drivers so their outcomes are bit-identical.
///
/// The threshold scan computes `inv_tot = 1/tot` once (one division
/// instead of `d`) and keeps the old counting form but breaks at the
/// first `!(u > cum)`: with non-negative probabilities `cum` is
/// non-decreasing, and once it is NaN (overflowed rows) it stays NaN, so
/// in both cases `u > cum` can never become true again after first
/// failing — the early exit is index-equivalent to the old full scan,
/// including on ±inf/NaN inputs.
#[inline]
fn measure_row<T: Float + std::ops::AddAssign>(
    panel: &[Complex<T>],
    lambda: &[T],
    threshold: f32,
    d: usize,
    probs: &mut [T],
    erow: &mut [Complex<T>],
) -> (i32, bool) {
    let y = lambda.len();
    // probs_j = Σ_y |temp[s,y,j]|²·Λ_y
    for p in probs.iter_mut() {
        *p = T::zero();
    }
    for yy in 0..y {
        let lam = lambda[yy];
        let row = &panel[yy * d..(yy + 1) * d];
        for (j, z) in row.iter().enumerate() {
            probs[j] += z.norm_sq() * lam;
        }
    }
    let tot: T = probs.iter().fold(T::zero(), |a, &b| a + b);
    let (outcome, dead) = if tot > T::zero() {
        let u = T::from(threshold).unwrap();
        let inv_tot = T::one() / tot;
        let mut cum = T::zero();
        let mut k = 0i32;
        for &p in probs.iter() {
            cum = cum + p * inv_tot;
            if u > cum {
                k += 1;
            } else {
                break;
            }
        }
        (k.min(d as i32 - 1), false)
    } else {
        (0, true)
    };

    // Collapse: env[s, :] = temp[s, :, outcome].
    let o = outcome as usize;
    for yy in 0..y {
        erow[yy] = panel[yy * d + o];
    }
    (outcome, dead)
}

/// Alg. 1 into caller-owned buffers (the step workspace): `env` is reshaped
/// in place to `(N, χ_r)`, `samples` to length `N`, `probs` to length `d` —
/// allocation-free once their capacities have warmed up. With `threads > 1`
/// the sample rows are partitioned across scoped threads (each row is
/// independent), bit-identically to the serial scan. Returns the dead-row
/// count.
#[allow(clippy::too_many_arguments)]
pub fn measure_into<T: Float + std::ops::AddAssign + Send + Sync>(
    temp: &Tensor3<T>,
    lambda: &[T],
    thresholds: &[f32],
    mode: ScalingMode,
    threads: usize,
    env: &mut Mat<T>,
    samples: &mut Vec<i32>,
    probs: &mut Vec<T>,
) -> Result<usize> {
    let (n, y, d) = (temp.d0, temp.d1, temp.d2);
    if lambda.len() != y {
        return Err(Error::shape(format!(
            "measure: Λ has {} entries for χ_r={y}",
            lambda.len()
        )));
    }
    if thresholds.len() != n {
        return Err(Error::shape(format!(
            "measure: {} thresholds for N={n}",
            thresholds.len()
        )));
    }

    // No zero-fill: the collapse below writes every (row, column) of the
    // environment, including dead rows (outcome-0 column).
    env.reshape(n, y);
    samples.clear();
    samples.resize(n, 0);
    probs.clear();
    probs.resize(d, T::zero());

    let threads = threads.max(1).min(n.max(1));
    let mut dead_rows = 0usize;
    if threads == 1 || y == 0 {
        for s in 0..n {
            let (outcome, dead) = measure_row(
                temp.panel(s),
                lambda,
                thresholds[s],
                d,
                probs,
                &mut env.data[s * y..(s + 1) * y],
            );
            samples[s] = outcome;
            dead_rows += dead as usize;
        }
    } else {
        let rows_per = n.div_ceil(threads);
        let env_chunks = env.data.chunks_mut(rows_per * y);
        let sample_chunks = samples.chunks_mut(rows_per);
        let th_chunks = thresholds.chunks(rows_per);
        dead_rows = std::thread::scope(|scope| {
            let handles: Vec<_> = env_chunks
                .zip(sample_chunks)
                .zip(th_chunks)
                .enumerate()
                .map(|(t, ((e_chunk, s_chunk), th_chunk))| {
                    let row0 = t * rows_per;
                    scope.spawn(move || {
                        let mut probs = vec![T::zero(); d];
                        let mut dead = 0usize;
                        for (i, (sv, &u)) in s_chunk.iter_mut().zip(th_chunk).enumerate() {
                            let (outcome, is_dead) = measure_row(
                                temp.panel(row0 + i),
                                lambda,
                                u,
                                d,
                                &mut probs,
                                &mut e_chunk[i * y..(i + 1) * y],
                            );
                            *sv = outcome;
                            dead += is_dead as usize;
                        }
                        dead
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).sum()
        });
    }

    apply_scaling(env, mode);
    Ok(dead_rows)
}

/// Apply the configured rescaling to a collapsed environment.
pub fn apply_scaling<T: Float + std::ops::AddAssign>(env: &mut Mat<T>, mode: ScalingMode) {
    match mode {
        ScalingMode::None => {}
        ScalingMode::Global => {
            // Baseline [19]: one factor for the whole batch (shifts toward
            // 1 but cannot narrow the inter-sample spread — Fig. 5/6).
            let m = env.max_abs();
            if m > T::zero() {
                let inv = T::one() / m;
                env.scale_in_place(inv);
            }
        }
        ScalingMode::PerSample => {
            let cols = env.cols;
            for r in 0..env.rows {
                let row = env.row_mut(r);
                let mut m2 = T::zero();
                for z in row.iter() {
                    let a = z.norm_sq();
                    if a > m2 {
                        m2 = a;
                    }
                }
                if m2 > T::zero() {
                    let inv = T::one() / m2.sqrt();
                    for z in row.iter_mut() {
                        *z = z.scale(inv);
                    }
                }
            }
            let _ = cols;
        }
    }
}

/// Per-sample max |env| and max/min ratio — the Fig. 5 scatter data.
pub fn env_sample_stats<T: Float + std::ops::AddAssign>(env: &Mat<T>) -> Vec<(f64, f64)> {
    let mut out = Vec::with_capacity(env.rows);
    for r in 0..env.rows {
        let mut maxv = 0.0f64;
        let mut minv = f64::INFINITY;
        for z in env.row(r) {
            let a = z.abs().to_f64().unwrap_or(0.0);
            if a > maxv {
                maxv = a;
            }
            if a > 0.0 && a < minv {
                minv = a;
            }
        }
        let ratio = if minv.is_finite() && minv > 0.0 {
            maxv / minv
        } else {
            f64::INFINITY
        };
        out.push((maxv, ratio));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::C64;

    fn temp_with_probs(probs: &[f64]) -> Tensor3<f64> {
        // One sample, y=1, amplitudes √p.
        let d = probs.len();
        let mut t = Tensor3::zeros(1, 1, d);
        for (j, &p) in probs.iter().enumerate() {
            *t.at_mut(0, 0, j) = C64::new(p.sqrt(), 0.0);
        }
        t
    }

    #[test]
    fn outcome_follows_threshold() {
        let t = temp_with_probs(&[0.2, 0.3, 0.5]);
        let lam = vec![1.0f64];
        for (u, want) in [(0.1f32, 0), (0.25, 1), (0.6, 2), (0.99, 2)] {
            let m = measure(&t, &lam, &[u], ScalingMode::None).unwrap();
            assert_eq!(m.samples[0], want, "u={u}");
        }
    }

    #[test]
    fn env_is_collapsed_column() {
        let mut t = Tensor3::zeros(1, 3, 2);
        for y in 0..3 {
            *t.at_mut(0, y, 0) = C64::new(y as f64 + 1.0, 0.0);
            *t.at_mut(0, y, 1) = C64::new(-(y as f64) - 10.0, 0.5);
        }
        let m = measure(&t, &[1.0, 1.0, 1.0], &[0.999], ScalingMode::None).unwrap();
        assert_eq!(m.samples[0], 1);
        assert_eq!(m.env[(0, 2)], C64::new(-12.0, 0.5));
    }

    #[test]
    fn dead_rows_counted() {
        let t: Tensor3<f64> = Tensor3::zeros(2, 2, 2);
        let m = measure(&t, &[1.0, 1.0], &[0.5, 0.5], ScalingMode::PerSample).unwrap();
        assert_eq!(m.dead_rows, 2);
        assert_eq!(m.samples, vec![0, 0]);
    }

    #[test]
    fn per_sample_scaling_unit_rows() {
        let mut env: Mat<f64> = Mat::zeros(2, 2);
        env[(0, 0)] = C64::new(1e-20, 0.0);
        env[(0, 1)] = C64::new(0.0, 2e-20);
        env[(1, 0)] = C64::new(3.0, 4.0);
        apply_scaling(&mut env, ScalingMode::PerSample);
        assert!((env[(0, 1)].abs() - 1.0).abs() < 1e-12);
        assert!((env[(1, 0)].abs() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn global_scaling_single_factor() {
        let mut env: Mat<f64> = Mat::zeros(2, 1);
        env[(0, 0)] = C64::new(4.0, 0.0);
        env[(1, 0)] = C64::new(1.0, 0.0);
        apply_scaling(&mut env, ScalingMode::Global);
        assert!((env[(0, 0)].re - 1.0).abs() < 1e-12);
        assert!((env[(1, 0)].re - 0.25).abs() < 1e-12);
    }

    #[test]
    fn lambda_weights_probabilities() {
        // Two bond channels with different Λ: outcome prefers the weighted one.
        let mut t = Tensor3::zeros(1, 2, 2);
        *t.at_mut(0, 0, 0) = C64::new(1.0, 0.0); // channel 0 → outcome 0
        *t.at_mut(0, 1, 1) = C64::new(1.0, 0.0); // channel 1 → outcome 1
        // Λ = [0, 1]: outcome 1 is certain.
        let m = measure(&t, &[0.0, 1.0], &[0.9999], ScalingMode::None).unwrap();
        assert_eq!(m.samples[0], 1);
        let m2 = measure(&t, &[1.0, 0.0], &[0.0001], ScalingMode::None).unwrap();
        assert_eq!(m2.samples[0], 0);
    }

    #[test]
    fn stats_report_spread() {
        let mut env: Mat<f64> = Mat::zeros(1, 3);
        env[(0, 0)] = C64::new(1.0, 0.0);
        env[(0, 1)] = C64::new(0.01, 0.0);
        let st = env_sample_stats(&env);
        assert!((st[0].0 - 1.0).abs() < 1e-12);
        assert!((st[0].1 - 100.0).abs() < 1e-9);
    }

    #[test]
    fn shape_errors() {
        let t: Tensor3<f64> = Tensor3::zeros(2, 3, 2);
        assert!(measure(&t, &[1.0; 2], &[0.5; 2], ScalingMode::None).is_err());
        assert!(measure(&t, &[1.0; 3], &[0.5; 1], ScalingMode::None).is_err());
    }

    /// The pre-optimization scan (full walk, per-outcome division) — the
    /// regression oracle for the hoisted-division early-break rewrite.
    fn reference_measure(
        temp: &Tensor3<f64>,
        lambda: &[f64],
        thresholds: &[f32],
        mode: ScalingMode,
    ) -> Measured<f64> {
        let (n, y, d) = (temp.d0, temp.d1, temp.d2);
        let mut env = Mat::zeros(n, y);
        let mut samples = vec![0i32; n];
        let mut dead_rows = 0usize;
        let mut probs = vec![0.0f64; d];
        for s in 0..n {
            for p in probs.iter_mut() {
                *p = 0.0;
            }
            let panel = temp.panel(s);
            for yy in 0..y {
                let lam = lambda[yy];
                let row = &panel[yy * d..(yy + 1) * d];
                for (j, z) in row.iter().enumerate() {
                    probs[j] += z.norm_sq() * lam;
                }
            }
            let tot: f64 = probs.iter().sum();
            let outcome = if tot > 0.0 {
                let u = thresholds[s] as f64;
                let mut cum = 0.0;
                let mut k = 0i32;
                for &p in probs.iter() {
                    cum += p / tot;
                    if u > cum {
                        k += 1;
                    }
                }
                k.min(d as i32 - 1)
            } else {
                dead_rows += 1;
                0
            };
            samples[s] = outcome;
            let o = outcome as usize;
            let erow = env.row_mut(s);
            for yy in 0..y {
                erow[yy] = panel[yy * d + o];
            }
        }
        apply_scaling(&mut env, mode);
        Measured {
            env,
            samples,
            dead_rows,
        }
    }

    fn random_temp(g: &mut crate::util::prop::Gen) -> (Tensor3<f64>, Vec<f64>, Vec<f32>) {
        let n = g.len(1, 12);
        let y = g.len(1, 10);
        let d = g.len(2, 6);
        let mut t = Tensor3::zeros(n, y, d);
        for z in &mut t.data {
            *z = C64::new(g.normal(), g.normal());
        }
        // Occasionally zero a whole sample row to exercise the dead path.
        if g.bool() {
            let s = g.usize_in(0, n);
            let panel = y * d;
            for z in &mut t.data[s * panel..(s + 1) * panel] {
                *z = C64::zero();
            }
        }
        let lambda: Vec<f64> = (0..y).map(|_| g.unit_f64()).collect();
        let thresholds: Vec<f32> = (0..n).map(|_| g.unit_f64() as f32).collect();
        (t, lambda, thresholds)
    }

    #[test]
    fn early_break_scan_matches_reference_outcomes() {
        crate::util::prop::quickcheck("measure == reference", |g| {
            let (t, lambda, thresholds) = random_temp(g);
            let mode = *g.choose(&[
                ScalingMode::None,
                ScalingMode::Global,
                ScalingMode::PerSample,
            ]);
            let want = reference_measure(&t, &lambda, &thresholds, mode);
            let got = measure(&t, &lambda, &thresholds, mode).unwrap();
            if got.samples != want.samples {
                return Err(format!("outcomes {:?} vs {:?}", got.samples, want.samples));
            }
            if got.dead_rows != want.dead_rows {
                return Err(format!("dead {} vs {}", got.dead_rows, want.dead_rows));
            }
            // Same outcome ⇒ same collapsed column ⇒ identical env bits.
            if got.env != want.env {
                return Err("collapsed env diverged".into());
            }
            Ok(())
        });
    }

    #[test]
    fn overflowed_rows_match_reference_scan() {
        // A probability that overflows to +inf poisons the cumulative sum
        // with NaN from that index on; the early-break counting scan must
        // land on the same outcome as the old full scan (stop counting at
        // the first non-(u > cum), i.e. at the inf entry).
        let mut t = Tensor3::zeros(1, 1, 4);
        *t.at_mut(0, 0, 0) = C64::new(1.0, 0.0);
        *t.at_mut(0, 0, 1) = C64::new(f64::MAX, 0.0); // norm_sq → +inf
        let lam = vec![1.0f64];
        let want = reference_measure(&t, &lam, &[0.5], ScalingMode::None);
        let got = measure(&t, &lam, &[0.5], ScalingMode::None).unwrap();
        assert_eq!(got.samples, want.samples);
        assert_eq!(got.samples, vec![1], "stops at the overflowed entry");
        assert_eq!(got.dead_rows, want.dead_rows);
    }

    #[test]
    fn parallel_measure_bit_identical_to_serial() {
        crate::util::prop::quickcheck("parallel measure == serial", |g| {
            let (t, lambda, thresholds) = random_temp(g);
            let threads = g.len(2, 6);
            let mode = *g.choose(&[
                ScalingMode::None,
                ScalingMode::Global,
                ScalingMode::PerSample,
            ]);
            let serial = measure(&t, &lambda, &thresholds, mode).unwrap();
            let mut env = Mat::zeros(1, 1);
            let mut samples = Vec::new();
            let mut probs = Vec::new();
            let dead = measure_into(
                &t, &lambda, &thresholds, mode, threads, &mut env, &mut samples, &mut probs,
            )
            .map_err(|e| e.to_string())?;
            if samples != serial.samples || env.data != serial.env.data {
                return Err(format!("{threads}-thread measure diverged"));
            }
            if dead != serial.dead_rows {
                return Err(format!("dead {} vs {}", dead, serial.dead_rows));
            }
            Ok(())
        });
    }

    #[test]
    fn measure_into_reuses_workspace_buffers() {
        let t = temp_with_probs(&[0.2, 0.3, 0.5]);
        let lam = vec![1.0f64];
        let mut env = Mat::zeros(1, 1);
        let mut samples = Vec::new();
        let mut probs = Vec::new();
        measure_into(
            &t, &lam, &[0.6], ScalingMode::None, 1, &mut env, &mut samples, &mut probs,
        )
        .unwrap();
        let (pe, ps, pp) = (env.data.as_ptr(), samples.as_ptr(), probs.as_ptr());
        measure_into(
            &t, &lam, &[0.6], ScalingMode::None, 1, &mut env, &mut samples, &mut probs,
        )
        .unwrap();
        assert_eq!(samples, vec![2]);
        assert_eq!(env.data.as_ptr(), pe);
        assert_eq!(samples.as_ptr(), ps);
        assert_eq!(probs.as_ptr(), pp);
    }
}
