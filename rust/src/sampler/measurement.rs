//! Native Alg. 1: measurement of the physical index + environment collapse,
//! with the three scaling strategies of §3.3.1.
//!
//! Mirrors `python/compile/kernels/ref.py` (same threshold semantics, same
//! degenerate-row handling). The threshold scan hoists the normalization
//! division out of the outcome loop (`inv_tot` computed once) and breaks
//! at the first `!(u > cum)` — index-equivalent to the full scan
//! (including on overflowed/NaN rows) and regression-tested against it,
//! though `p * inv_tot` can differ from ref.py's per-term `p / tot` in
//! the last ulp of a cumulative boundary, so native-vs-XLA agreement is
//! statistical (knife-edge outcome flips at ~2⁻²⁴ per comparison in f32),
//! not bitwise. [`measure_into`] runs rows in parallel into a caller-owned
//! workspace, bit-identically to the serial scan — the single-threaded hot
//! loop was rivalling the GEMM at large χ.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::util::num::Float;

use crate::config::ScalingMode;
use crate::linalg::{Exec, SendPtr};
use crate::tensor::{Complex, Mat, PlanarMat, PlanarTensor3, Tensor3};
use crate::util::error::{Error, Result};

/// Measurement output.
pub struct Measured<T> {
    /// Collapsed (N, χ_r) left environment (scaled per `mode`).
    pub env: Mat<T>,
    /// Outcome per sample, in `[0, d)`.
    pub samples: Vec<i32>,
    /// Number of samples whose probability row was all-zero (underflow
    /// collapse — the Fig. 6 failure signal).
    pub dead_rows: usize,
}

/// Alg. 1 over the unmeasured temp tensor `(N, χ_r, d)`.
pub fn measure<T: Float + std::ops::AddAssign + Send + Sync>(
    temp: &Tensor3<T>,
    lambda: &[T],
    thresholds: &[f32],
    mode: ScalingMode,
) -> Result<Measured<T>> {
    let mut env = Mat::zeros(temp.d0, temp.d1);
    let mut samples = Vec::new();
    let mut probs = Vec::new();
    let dead_rows = measure_into(
        temp, lambda, thresholds, mode, 1, &mut env, &mut samples, &mut probs,
    )?;
    Ok(Measured {
        env,
        samples,
        dead_rows,
    })
}

/// One sample row of Alg. 1: probability contraction, threshold scan, and
/// environment collapse. Shared verbatim by the serial and row-parallel
/// drivers so their outcomes are bit-identical.
///
/// The threshold scan computes `inv_tot = 1/tot` once (one division
/// instead of `d`) and keeps the old counting form but breaks at the
/// first `!(u > cum)`: with non-negative probabilities `cum` is
/// non-decreasing, and once it is NaN (overflowed rows) it stays NaN, so
/// in both cases `u > cum` can never become true again after first
/// failing — the early exit is index-equivalent to the old full scan,
/// including on ±inf/NaN inputs.
#[inline]
fn measure_row<T: Float + std::ops::AddAssign>(
    panel: &[Complex<T>],
    lambda: &[T],
    threshold: f32,
    d: usize,
    probs: &mut [T],
    erow: &mut [Complex<T>],
) -> (i32, bool) {
    let y = lambda.len();
    // probs_j = Σ_y |temp[s,y,j]|²·Λ_y
    for p in probs.iter_mut() {
        *p = T::zero();
    }
    for yy in 0..y {
        let lam = lambda[yy];
        let row = &panel[yy * d..(yy + 1) * d];
        for (j, z) in row.iter().enumerate() {
            probs[j] += z.norm_sq() * lam;
        }
    }
    let tot: T = probs.iter().fold(T::zero(), |a, &b| a + b);
    let (outcome, dead) = threshold_scan(probs, tot, threshold, d);

    // Collapse: env[s, :] = temp[s, :, outcome].
    let o = outcome as usize;
    for yy in 0..y {
        erow[yy] = panel[yy * d + o];
    }
    (outcome, dead)
}

/// The hoisted-division early-break threshold scan — factored out so the
/// interleaved and planar row kernels share it verbatim and their outcome
/// indices cannot drift (see [`measure_row`] for why the early break is
/// index-equivalent to the full scan).
#[inline]
fn threshold_scan<T: Float + std::ops::AddAssign>(
    probs: &[T],
    tot: T,
    threshold: f32,
    d: usize,
) -> (i32, bool) {
    if tot > T::zero() {
        let u = T::from(threshold).unwrap();
        let inv_tot = T::one() / tot;
        let mut cum = T::zero();
        let mut k = 0i32;
        for &p in probs.iter() {
            cum = cum + p * inv_tot;
            if u > cum {
                k += 1;
            } else {
                break;
            }
        }
        (k.min(d as i32 - 1), false)
    } else {
        (0, true)
    }
}

/// Planar replica of [`measure_row`]: identical probability accumulation
/// order (`norm_sq` expanded to `re·re + im·im`, the exact
/// [`Complex::norm_sq`] expression), the shared [`threshold_scan`], and a
/// per-plane collapse — bit-identical outcomes and environment values.
#[inline]
#[allow(clippy::too_many_arguments)]
fn measure_row_planar<T: Float + std::ops::AddAssign>(
    panel_re: &[T],
    panel_im: &[T],
    lambda: &[T],
    threshold: f32,
    d: usize,
    probs: &mut [T],
    erow_re: &mut [T],
    erow_im: &mut [T],
) -> (i32, bool) {
    for p in probs.iter_mut() {
        *p = T::zero();
    }
    for (yy, &lam) in lambda.iter().enumerate() {
        let rre = &panel_re[yy * d..(yy + 1) * d];
        let rim = &panel_im[yy * d..(yy + 1) * d];
        for ((p, &re), &im) in probs.iter_mut().zip(rre).zip(rim) {
            *p += (re * re + im * im) * lam;
        }
    }
    let tot: T = probs.iter().fold(T::zero(), |a, &b| a + b);
    let (outcome, dead) = threshold_scan(probs, tot, threshold, d);

    let o = outcome as usize;
    for (yy, (er, ei)) in erow_re.iter_mut().zip(erow_im.iter_mut()).enumerate() {
        *er = panel_re[yy * d + o];
        *ei = panel_im[yy * d + o];
    }
    (outcome, dead)
}

/// Alg. 1 into caller-owned buffers (the step workspace): `env` is reshaped
/// in place to `(N, χ_r)`, `samples` to length `N`, `probs` to length `d` —
/// allocation-free once their capacities have warmed up. With `threads > 1`
/// the sample rows are partitioned across scoped threads (each row is
/// independent), bit-identically to the serial scan. Returns the dead-row
/// count.
#[allow(clippy::too_many_arguments)]
pub fn measure_into<T: Float + std::ops::AddAssign + Send + Sync>(
    temp: &Tensor3<T>,
    lambda: &[T],
    thresholds: &[f32],
    mode: ScalingMode,
    threads: usize,
    env: &mut Mat<T>,
    samples: &mut Vec<i32>,
    probs: &mut Vec<T>,
) -> Result<usize> {
    measure_into_on(
        temp,
        lambda,
        thresholds,
        mode,
        Exec::Scoped(threads),
        env,
        samples,
        probs,
    )
}

/// [`measure_into`] on an explicit executor. The pooled form dispatches
/// row ranges to the resident [`WorkerPool`](crate::linalg::WorkerPool)
/// with per-part `probs` stripes carved out of the caller's buffer —
/// zero allocations at steady state, unlike the scoped form whose spawn
/// bookkeeping (and per-thread scratch) allocates every call.
#[allow(clippy::too_many_arguments)]
pub fn measure_into_on<T: Float + std::ops::AddAssign + Send + Sync>(
    temp: &Tensor3<T>,
    lambda: &[T],
    thresholds: &[f32],
    mode: ScalingMode,
    exec: Exec<'_>,
    env: &mut Mat<T>,
    samples: &mut Vec<i32>,
    probs: &mut Vec<T>,
) -> Result<usize> {
    let (n, y, d) = (temp.d0, temp.d1, temp.d2);
    check_measure_shapes(lambda.len(), thresholds.len(), n, y)?;

    // No zero-fill: the collapse below writes every (row, column) of the
    // environment, including dead rows (outcome-0 column).
    env.reshape(n, y);
    samples.clear();
    samples.resize(n, 0);

    let parts = exec.width().min(n.max(1));
    let mut dead_rows = 0usize;
    if parts == 1 || y == 0 {
        probs.clear();
        probs.resize(d, T::zero());
        for s in 0..n {
            let (outcome, dead) = measure_row(
                temp.panel(s),
                lambda,
                thresholds[s],
                d,
                probs,
                &mut env.data[s * y..(s + 1) * y],
            );
            samples[s] = outcome;
            dead_rows += dead as usize;
        }
    } else {
        // One probs stripe per part, all carved out of the caller's
        // buffer — the pooled path allocates nothing at steady state.
        probs.clear();
        probs.resize(parts * d, T::zero());
        let rows_per = n.div_ceil(parts);
        let env_ptr = SendPtr(env.data.as_mut_ptr());
        let samples_ptr = SendPtr(samples.as_mut_ptr());
        let probs_ptr = SendPtr(probs.as_mut_ptr());
        let dead = AtomicUsize::new(0);
        exec.run_parts(parts, |part| {
            let r0 = part * rows_per;
            let r1 = ((part + 1) * rows_per).min(n);
            if r0 >= r1 {
                return;
            }
            // Safety: parts own disjoint row ranges of env/samples and
            // disjoint d-length stripes of probs; run_parts joins before
            // returning, so the borrows behind the raw pointers are live.
            let probs_part =
                unsafe { std::slice::from_raw_parts_mut(probs_ptr.0.add(part * d), d) };
            let mut local_dead = 0usize;
            for s in r0..r1 {
                let erow = unsafe { std::slice::from_raw_parts_mut(env_ptr.0.add(s * y), y) };
                let (outcome, is_dead) =
                    measure_row(temp.panel(s), lambda, thresholds[s], d, probs_part, erow);
                unsafe { *samples_ptr.0.add(s) = outcome };
                local_dead += is_dead as usize;
            }
            dead.fetch_add(local_dead, Ordering::Relaxed);
        });
        dead_rows = dead.load(Ordering::Relaxed);
    }

    apply_scaling(env, mode);
    Ok(dead_rows)
}

/// Planar analogue of [`measure_into_on`]: same row kernel discipline
/// ([`measure_row_planar`] + the shared [`threshold_scan`]), same
/// partitioning, planar scaling — bit-identical outcomes, samples, and
/// environment planes.
#[allow(clippy::too_many_arguments)]
pub fn measure_planar_into_on<T: Float + std::ops::AddAssign + Send + Sync>(
    temp: &PlanarTensor3<T>,
    lambda: &[T],
    thresholds: &[f32],
    mode: ScalingMode,
    exec: Exec<'_>,
    env: &mut PlanarMat<T>,
    samples: &mut Vec<i32>,
    probs: &mut Vec<T>,
) -> Result<usize> {
    let (n, y, d) = (temp.d0, temp.d1, temp.d2);
    check_measure_shapes(lambda.len(), thresholds.len(), n, y)?;

    env.reshape(n, y);
    samples.clear();
    samples.resize(n, 0);

    let panel = y * d;
    let parts = exec.width().min(n.max(1));
    let mut dead_rows = 0usize;
    if parts == 1 || y == 0 {
        probs.clear();
        probs.resize(d, T::zero());
        for s in 0..n {
            let (outcome, dead) = measure_row_planar(
                &temp.re[s * panel..(s + 1) * panel],
                &temp.im[s * panel..(s + 1) * panel],
                lambda,
                thresholds[s],
                d,
                probs,
                &mut env.re[s * y..(s + 1) * y],
                &mut env.im[s * y..(s + 1) * y],
            );
            samples[s] = outcome;
            dead_rows += dead as usize;
        }
    } else {
        probs.clear();
        probs.resize(parts * d, T::zero());
        let rows_per = n.div_ceil(parts);
        let env_re = SendPtr(env.re.as_mut_ptr());
        let env_im = SendPtr(env.im.as_mut_ptr());
        let samples_ptr = SendPtr(samples.as_mut_ptr());
        let probs_ptr = SendPtr(probs.as_mut_ptr());
        let dead = AtomicUsize::new(0);
        exec.run_parts(parts, |part| {
            let r0 = part * rows_per;
            let r1 = ((part + 1) * rows_per).min(n);
            if r0 >= r1 {
                return;
            }
            // Safety: as in measure_into_on, applied to both planes.
            let probs_part =
                unsafe { std::slice::from_raw_parts_mut(probs_ptr.0.add(part * d), d) };
            let mut local_dead = 0usize;
            for s in r0..r1 {
                let erow_re =
                    unsafe { std::slice::from_raw_parts_mut(env_re.0.add(s * y), y) };
                let erow_im =
                    unsafe { std::slice::from_raw_parts_mut(env_im.0.add(s * y), y) };
                let (outcome, is_dead) = measure_row_planar(
                    &temp.re[s * panel..(s + 1) * panel],
                    &temp.im[s * panel..(s + 1) * panel],
                    lambda,
                    thresholds[s],
                    d,
                    probs_part,
                    erow_re,
                    erow_im,
                );
                unsafe { *samples_ptr.0.add(s) = outcome };
                local_dead += is_dead as usize;
            }
            dead.fetch_add(local_dead, Ordering::Relaxed);
        });
        dead_rows = dead.load(Ordering::Relaxed);
    }

    apply_scaling_planar(env, mode);
    Ok(dead_rows)
}

fn check_measure_shapes(lambda_len: usize, th_len: usize, n: usize, y: usize) -> Result<()> {
    if lambda_len != y {
        return Err(Error::shape(format!(
            "measure: Λ has {lambda_len} entries for χ_r={y}"
        )));
    }
    if th_len != n {
        return Err(Error::shape(format!("measure: {th_len} thresholds for N={n}")));
    }
    Ok(())
}

/// Apply the configured rescaling to a collapsed environment.
pub fn apply_scaling<T: Float + std::ops::AddAssign>(env: &mut Mat<T>, mode: ScalingMode) {
    match mode {
        ScalingMode::None => {}
        ScalingMode::Global => {
            // Baseline [19]: one factor for the whole batch (shifts toward
            // 1 but cannot narrow the inter-sample spread — Fig. 5/6).
            let m = env.max_abs();
            if m > T::zero() {
                let inv = T::one() / m;
                env.scale_in_place(inv);
            }
        }
        ScalingMode::PerSample => {
            let cols = env.cols;
            for r in 0..env.rows {
                let row = env.row_mut(r);
                let mut m2 = T::zero();
                for z in row.iter() {
                    let a = z.norm_sq();
                    if a > m2 {
                        m2 = a;
                    }
                }
                if m2 > T::zero() {
                    let inv = T::one() / m2.sqrt();
                    for z in row.iter_mut() {
                        *z = z.scale(inv);
                    }
                }
            }
            let _ = cols;
        }
    }
}

/// Planar replica of [`apply_scaling`]: the max scans expand `norm_sq`
/// to `re·re + im·im` in the same element order and the rescale is the
/// same per-component multiply, so the planes end bit-identical to the
/// interleaved environment's components.
pub fn apply_scaling_planar<T: Float + std::ops::AddAssign>(
    env: &mut PlanarMat<T>,
    mode: ScalingMode,
) {
    match mode {
        ScalingMode::None => {}
        ScalingMode::Global => {
            // Mat::max_abs replica: max norm_sq over the batch, sqrt once.
            let mut m2 = T::zero();
            for (&re, &im) in env.re.iter().zip(&env.im) {
                let a = re * re + im * im;
                if a > m2 {
                    m2 = a;
                }
            }
            let m = m2.sqrt();
            if m > T::zero() {
                let inv = T::one() / m;
                for v in env.re.iter_mut() {
                    *v = *v * inv;
                }
                for v in env.im.iter_mut() {
                    *v = *v * inv;
                }
            }
        }
        ScalingMode::PerSample => {
            let cols = env.cols;
            for r in 0..env.rows {
                let rre = &mut env.re[r * cols..(r + 1) * cols];
                let rim = &mut env.im[r * cols..(r + 1) * cols];
                let mut m2 = T::zero();
                for (&re, &im) in rre.iter().zip(rim.iter()) {
                    let a = re * re + im * im;
                    if a > m2 {
                        m2 = a;
                    }
                }
                if m2 > T::zero() {
                    let inv = T::one() / m2.sqrt();
                    for v in rre.iter_mut() {
                        *v = *v * inv;
                    }
                    for v in rim.iter_mut() {
                        *v = *v * inv;
                    }
                }
            }
        }
    }
}

/// Per-sample max |env| and max/min ratio — the Fig. 5 scatter data.
pub fn env_sample_stats<T: Float + std::ops::AddAssign>(env: &Mat<T>) -> Vec<(f64, f64)> {
    let mut out = Vec::with_capacity(env.rows);
    for r in 0..env.rows {
        let mut maxv = 0.0f64;
        let mut minv = f64::INFINITY;
        for z in env.row(r) {
            let a = z.abs().to_f64().unwrap_or(0.0);
            if a > maxv {
                maxv = a;
            }
            if a > 0.0 && a < minv {
                minv = a;
            }
        }
        let ratio = if minv.is_finite() && minv > 0.0 {
            maxv / minv
        } else {
            f64::INFINITY
        };
        out.push((maxv, ratio));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::C64;

    fn temp_with_probs(probs: &[f64]) -> Tensor3<f64> {
        // One sample, y=1, amplitudes √p.
        let d = probs.len();
        let mut t = Tensor3::zeros(1, 1, d);
        for (j, &p) in probs.iter().enumerate() {
            *t.at_mut(0, 0, j) = C64::new(p.sqrt(), 0.0);
        }
        t
    }

    #[test]
    fn outcome_follows_threshold() {
        let t = temp_with_probs(&[0.2, 0.3, 0.5]);
        let lam = vec![1.0f64];
        for (u, want) in [(0.1f32, 0), (0.25, 1), (0.6, 2), (0.99, 2)] {
            let m = measure(&t, &lam, &[u], ScalingMode::None).unwrap();
            assert_eq!(m.samples[0], want, "u={u}");
        }
    }

    #[test]
    fn env_is_collapsed_column() {
        let mut t = Tensor3::zeros(1, 3, 2);
        for y in 0..3 {
            *t.at_mut(0, y, 0) = C64::new(y as f64 + 1.0, 0.0);
            *t.at_mut(0, y, 1) = C64::new(-(y as f64) - 10.0, 0.5);
        }
        let m = measure(&t, &[1.0, 1.0, 1.0], &[0.999], ScalingMode::None).unwrap();
        assert_eq!(m.samples[0], 1);
        assert_eq!(m.env[(0, 2)], C64::new(-12.0, 0.5));
    }

    #[test]
    fn dead_rows_counted() {
        let t: Tensor3<f64> = Tensor3::zeros(2, 2, 2);
        let m = measure(&t, &[1.0, 1.0], &[0.5, 0.5], ScalingMode::PerSample).unwrap();
        assert_eq!(m.dead_rows, 2);
        assert_eq!(m.samples, vec![0, 0]);
    }

    #[test]
    fn per_sample_scaling_unit_rows() {
        let mut env: Mat<f64> = Mat::zeros(2, 2);
        env[(0, 0)] = C64::new(1e-20, 0.0);
        env[(0, 1)] = C64::new(0.0, 2e-20);
        env[(1, 0)] = C64::new(3.0, 4.0);
        apply_scaling(&mut env, ScalingMode::PerSample);
        assert!((env[(0, 1)].abs() - 1.0).abs() < 1e-12);
        assert!((env[(1, 0)].abs() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn global_scaling_single_factor() {
        let mut env: Mat<f64> = Mat::zeros(2, 1);
        env[(0, 0)] = C64::new(4.0, 0.0);
        env[(1, 0)] = C64::new(1.0, 0.0);
        apply_scaling(&mut env, ScalingMode::Global);
        assert!((env[(0, 0)].re - 1.0).abs() < 1e-12);
        assert!((env[(1, 0)].re - 0.25).abs() < 1e-12);
    }

    #[test]
    fn lambda_weights_probabilities() {
        // Two bond channels with different Λ: outcome prefers the weighted one.
        let mut t = Tensor3::zeros(1, 2, 2);
        *t.at_mut(0, 0, 0) = C64::new(1.0, 0.0); // channel 0 → outcome 0
        *t.at_mut(0, 1, 1) = C64::new(1.0, 0.0); // channel 1 → outcome 1
        // Λ = [0, 1]: outcome 1 is certain.
        let m = measure(&t, &[0.0, 1.0], &[0.9999], ScalingMode::None).unwrap();
        assert_eq!(m.samples[0], 1);
        let m2 = measure(&t, &[1.0, 0.0], &[0.0001], ScalingMode::None).unwrap();
        assert_eq!(m2.samples[0], 0);
    }

    #[test]
    fn stats_report_spread() {
        let mut env: Mat<f64> = Mat::zeros(1, 3);
        env[(0, 0)] = C64::new(1.0, 0.0);
        env[(0, 1)] = C64::new(0.01, 0.0);
        let st = env_sample_stats(&env);
        assert!((st[0].0 - 1.0).abs() < 1e-12);
        assert!((st[0].1 - 100.0).abs() < 1e-9);
    }

    #[test]
    fn shape_errors() {
        let t: Tensor3<f64> = Tensor3::zeros(2, 3, 2);
        assert!(measure(&t, &[1.0; 2], &[0.5; 2], ScalingMode::None).is_err());
        assert!(measure(&t, &[1.0; 3], &[0.5; 1], ScalingMode::None).is_err());
    }

    /// The pre-optimization scan (full walk, per-outcome division) — the
    /// regression oracle for the hoisted-division early-break rewrite.
    fn reference_measure(
        temp: &Tensor3<f64>,
        lambda: &[f64],
        thresholds: &[f32],
        mode: ScalingMode,
    ) -> Measured<f64> {
        let (n, y, d) = (temp.d0, temp.d1, temp.d2);
        let mut env = Mat::zeros(n, y);
        let mut samples = vec![0i32; n];
        let mut dead_rows = 0usize;
        let mut probs = vec![0.0f64; d];
        for s in 0..n {
            for p in probs.iter_mut() {
                *p = 0.0;
            }
            let panel = temp.panel(s);
            for yy in 0..y {
                let lam = lambda[yy];
                let row = &panel[yy * d..(yy + 1) * d];
                for (j, z) in row.iter().enumerate() {
                    probs[j] += z.norm_sq() * lam;
                }
            }
            let tot: f64 = probs.iter().sum();
            let outcome = if tot > 0.0 {
                let u = thresholds[s] as f64;
                let mut cum = 0.0;
                let mut k = 0i32;
                for &p in probs.iter() {
                    cum += p / tot;
                    if u > cum {
                        k += 1;
                    }
                }
                k.min(d as i32 - 1)
            } else {
                dead_rows += 1;
                0
            };
            samples[s] = outcome;
            let o = outcome as usize;
            let erow = env.row_mut(s);
            for yy in 0..y {
                erow[yy] = panel[yy * d + o];
            }
        }
        apply_scaling(&mut env, mode);
        Measured {
            env,
            samples,
            dead_rows,
        }
    }

    fn random_temp(g: &mut crate::util::prop::Gen) -> (Tensor3<f64>, Vec<f64>, Vec<f32>) {
        let n = g.len(1, 12);
        let y = g.len(1, 10);
        let d = g.len(2, 6);
        let mut t = Tensor3::zeros(n, y, d);
        for z in &mut t.data {
            *z = C64::new(g.normal(), g.normal());
        }
        // Occasionally zero a whole sample row to exercise the dead path.
        if g.bool() {
            let s = g.usize_in(0, n);
            let panel = y * d;
            for z in &mut t.data[s * panel..(s + 1) * panel] {
                *z = C64::zero();
            }
        }
        let lambda: Vec<f64> = (0..y).map(|_| g.unit_f64()).collect();
        let thresholds: Vec<f32> = (0..n).map(|_| g.unit_f64() as f32).collect();
        (t, lambda, thresholds)
    }

    #[test]
    fn early_break_scan_matches_reference_outcomes() {
        crate::util::prop::quickcheck("measure == reference", |g| {
            let (t, lambda, thresholds) = random_temp(g);
            let mode = *g.choose(&[
                ScalingMode::None,
                ScalingMode::Global,
                ScalingMode::PerSample,
            ]);
            let want = reference_measure(&t, &lambda, &thresholds, mode);
            let got = measure(&t, &lambda, &thresholds, mode).unwrap();
            if got.samples != want.samples {
                return Err(format!("outcomes {:?} vs {:?}", got.samples, want.samples));
            }
            if got.dead_rows != want.dead_rows {
                return Err(format!("dead {} vs {}", got.dead_rows, want.dead_rows));
            }
            // Same outcome ⇒ same collapsed column ⇒ identical env bits.
            if got.env != want.env {
                return Err("collapsed env diverged".into());
            }
            Ok(())
        });
    }

    #[test]
    fn overflowed_rows_match_reference_scan() {
        // A probability that overflows to +inf poisons the cumulative sum
        // with NaN from that index on; the early-break counting scan must
        // land on the same outcome as the old full scan (stop counting at
        // the first non-(u > cum), i.e. at the inf entry).
        let mut t = Tensor3::zeros(1, 1, 4);
        *t.at_mut(0, 0, 0) = C64::new(1.0, 0.0);
        *t.at_mut(0, 0, 1) = C64::new(f64::MAX, 0.0); // norm_sq → +inf
        let lam = vec![1.0f64];
        let want = reference_measure(&t, &lam, &[0.5], ScalingMode::None);
        let got = measure(&t, &lam, &[0.5], ScalingMode::None).unwrap();
        assert_eq!(got.samples, want.samples);
        assert_eq!(got.samples, vec![1], "stops at the overflowed entry");
        assert_eq!(got.dead_rows, want.dead_rows);
    }

    #[test]
    fn parallel_measure_bit_identical_to_serial() {
        crate::util::prop::quickcheck("parallel measure == serial", |g| {
            let (t, lambda, thresholds) = random_temp(g);
            let threads = g.len(2, 6);
            let mode = *g.choose(&[
                ScalingMode::None,
                ScalingMode::Global,
                ScalingMode::PerSample,
            ]);
            let serial = measure(&t, &lambda, &thresholds, mode).unwrap();
            let mut env = Mat::zeros(1, 1);
            let mut samples = Vec::new();
            let mut probs = Vec::new();
            let dead = measure_into(
                &t, &lambda, &thresholds, mode, threads, &mut env, &mut samples, &mut probs,
            )
            .map_err(|e| e.to_string())?;
            if samples != serial.samples || env.data != serial.env.data {
                return Err(format!("{threads}-thread measure diverged"));
            }
            if dead != serial.dead_rows {
                return Err(format!("dead {} vs {}", dead, serial.dead_rows));
            }
            Ok(())
        });
    }

    #[test]
    fn planar_measure_bit_identical_to_interleaved() {
        use crate::tensor::{PlanarMat, PlanarTensor3};
        crate::util::prop::quickcheck("planar measure == interleaved", |g| {
            let (t, lambda, thresholds) = random_temp(g);
            let mode = *g.choose(&[
                ScalingMode::None,
                ScalingMode::Global,
                ScalingMode::PerSample,
            ]);
            let serial = measure(&t, &lambda, &thresholds, mode).unwrap();
            let pt = PlanarTensor3::from_interleaved(&t);
            for width in [1, 3] {
                let mut env: PlanarMat<f64> = PlanarMat::zeros(0, 0);
                let mut samples = Vec::new();
                let mut probs = Vec::new();
                let dead = measure_planar_into_on(
                    &pt,
                    &lambda,
                    &thresholds,
                    mode,
                    Exec::Scoped(width),
                    &mut env,
                    &mut samples,
                    &mut probs,
                )
                .map_err(|e| e.to_string())?;
                if samples != serial.samples || dead != serial.dead_rows {
                    return Err(format!("planar outcomes diverged at width {width}"));
                }
                // Per-component bitwise equality, -0.0 included.
                for (i, z) in serial.env.data.iter().enumerate() {
                    if env.re[i].to_bits() != z.re.to_bits()
                        || env.im[i].to_bits() != z.im.to_bits()
                    {
                        return Err(format!("planar env diverged at {i} (width {width})"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn pooled_measure_bit_identical_to_serial() {
        let pool = crate::linalg::WorkerPool::new(3);
        crate::util::prop::quickcheck("pooled measure == serial", |g| {
            let (t, lambda, thresholds) = random_temp(g);
            let mode = *g.choose(&[
                ScalingMode::None,
                ScalingMode::Global,
                ScalingMode::PerSample,
            ]);
            let serial = measure(&t, &lambda, &thresholds, mode).unwrap();
            let mut env = Mat::zeros(1, 1);
            let mut samples = Vec::new();
            let mut probs = Vec::new();
            let dead = measure_into_on(
                &t,
                &lambda,
                &thresholds,
                mode,
                Exec::Pooled(&pool),
                &mut env,
                &mut samples,
                &mut probs,
            )
            .map_err(|e| e.to_string())?;
            if samples != serial.samples
                || env.data != serial.env.data
                || dead != serial.dead_rows
            {
                return Err("pooled measure diverged".into());
            }
            Ok(())
        });
    }

    #[test]
    fn measure_into_reuses_workspace_buffers() {
        let t = temp_with_probs(&[0.2, 0.3, 0.5]);
        let lam = vec![1.0f64];
        let mut env = Mat::zeros(1, 1);
        let mut samples = Vec::new();
        let mut probs = Vec::new();
        measure_into(
            &t, &lam, &[0.6], ScalingMode::None, 1, &mut env, &mut samples, &mut probs,
        )
        .unwrap();
        let (pe, ps, pp) = (env.data.as_ptr(), samples.as_ptr(), probs.as_ptr());
        measure_into(
            &t, &lam, &[0.6], ScalingMode::None, 1, &mut env, &mut samples, &mut probs,
        )
        .unwrap();
        assert_eq!(samples, vec![2]);
        assert_eq!(env.data.as_ptr(), pe);
        assert_eq!(samples.as_ptr(), ps);
        assert_eq!(probs.as_ptr(), pp);
    }
}
