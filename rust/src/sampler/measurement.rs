//! Native Alg. 1: measurement of the physical index + environment collapse,
//! with the three scaling strategies of §3.3.1.
//!
//! Mirrors `python/compile/kernels/ref.py` exactly (same threshold
//! semantics, same degenerate-row handling) so the native and XLA engines
//! sample identical outcomes from identical inputs.

use crate::util::num::Float;

use crate::config::ScalingMode;
use crate::tensor::{Mat, Tensor3};
use crate::util::error::{Error, Result};

/// Measurement output.
pub struct Measured<T> {
    /// Collapsed (N, χ_r) left environment (scaled per `mode`).
    pub env: Mat<T>,
    /// Outcome per sample, in `[0, d)`.
    pub samples: Vec<i32>,
    /// Number of samples whose probability row was all-zero (underflow
    /// collapse — the Fig. 6 failure signal).
    pub dead_rows: usize,
}

/// Alg. 1 over the unmeasured temp tensor `(N, χ_r, d)`.
pub fn measure<T: Float + std::ops::AddAssign>(
    temp: &Tensor3<T>,
    lambda: &[T],
    thresholds: &[f32],
    mode: ScalingMode,
) -> Result<Measured<T>> {
    let (n, y, d) = (temp.d0, temp.d1, temp.d2);
    if lambda.len() != y {
        return Err(Error::shape(format!(
            "measure: Λ has {} entries for χ_r={y}",
            lambda.len()
        )));
    }
    if thresholds.len() != n {
        return Err(Error::shape(format!(
            "measure: {} thresholds for N={n}",
            thresholds.len()
        )));
    }

    let mut env = Mat::zeros(n, y);
    let mut samples = vec![0i32; n];
    let mut dead_rows = 0usize;
    let mut probs = vec![T::zero(); d];

    for s in 0..n {
        // probs_j = Σ_y |temp[s,y,j]|²·Λ_y
        for p in probs.iter_mut() {
            *p = T::zero();
        }
        let panel = temp.panel(s); // (y, d) contiguous
        for yy in 0..y {
            let lam = lambda[yy];
            let row = &panel[yy * d..(yy + 1) * d];
            for (j, z) in row.iter().enumerate() {
                probs[j] += z.norm_sq() * lam;
            }
        }
        let tot: T = probs.iter().fold(T::zero(), |a, &b| a + b);
        let outcome = if tot > T::zero() {
            // cumulative > threshold count (matches ref.py).
            let u = T::from(thresholds[s]).unwrap();
            let mut cum = T::zero();
            let mut k = 0i32;
            for &p in probs.iter() {
                cum = cum + p / tot;
                if u > cum {
                    k += 1;
                }
            }
            k.min(d as i32 - 1)
        } else {
            dead_rows += 1;
            0
        };
        samples[s] = outcome;

        // Collapse: env[s, :] = temp[s, :, outcome].
        let o = outcome as usize;
        let erow = env.row_mut(s);
        for yy in 0..y {
            erow[yy] = panel[yy * d + o];
        }
    }

    apply_scaling(&mut env, mode);
    Ok(Measured {
        env,
        samples,
        dead_rows,
    })
}

/// Apply the configured rescaling to a collapsed environment.
pub fn apply_scaling<T: Float + std::ops::AddAssign>(env: &mut Mat<T>, mode: ScalingMode) {
    match mode {
        ScalingMode::None => {}
        ScalingMode::Global => {
            // Baseline [19]: one factor for the whole batch (shifts toward
            // 1 but cannot narrow the inter-sample spread — Fig. 5/6).
            let m = env.max_abs();
            if m > T::zero() {
                let inv = T::one() / m;
                env.scale_in_place(inv);
            }
        }
        ScalingMode::PerSample => {
            let cols = env.cols;
            for r in 0..env.rows {
                let row = env.row_mut(r);
                let mut m2 = T::zero();
                for z in row.iter() {
                    let a = z.norm_sq();
                    if a > m2 {
                        m2 = a;
                    }
                }
                if m2 > T::zero() {
                    let inv = T::one() / m2.sqrt();
                    for z in row.iter_mut() {
                        *z = z.scale(inv);
                    }
                }
            }
            let _ = cols;
        }
    }
}

/// Per-sample max |env| and max/min ratio — the Fig. 5 scatter data.
pub fn env_sample_stats<T: Float + std::ops::AddAssign>(env: &Mat<T>) -> Vec<(f64, f64)> {
    let mut out = Vec::with_capacity(env.rows);
    for r in 0..env.rows {
        let mut maxv = 0.0f64;
        let mut minv = f64::INFINITY;
        for z in env.row(r) {
            let a = z.abs().to_f64().unwrap_or(0.0);
            if a > maxv {
                maxv = a;
            }
            if a > 0.0 && a < minv {
                minv = a;
            }
        }
        let ratio = if minv.is_finite() && minv > 0.0 {
            maxv / minv
        } else {
            f64::INFINITY
        };
        out.push((maxv, ratio));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::C64;

    fn temp_with_probs(probs: &[f64]) -> Tensor3<f64> {
        // One sample, y=1, amplitudes √p.
        let d = probs.len();
        let mut t = Tensor3::zeros(1, 1, d);
        for (j, &p) in probs.iter().enumerate() {
            *t.at_mut(0, 0, j) = C64::new(p.sqrt(), 0.0);
        }
        t
    }

    #[test]
    fn outcome_follows_threshold() {
        let t = temp_with_probs(&[0.2, 0.3, 0.5]);
        let lam = vec![1.0f64];
        for (u, want) in [(0.1f32, 0), (0.25, 1), (0.6, 2), (0.99, 2)] {
            let m = measure(&t, &lam, &[u], ScalingMode::None).unwrap();
            assert_eq!(m.samples[0], want, "u={u}");
        }
    }

    #[test]
    fn env_is_collapsed_column() {
        let mut t = Tensor3::zeros(1, 3, 2);
        for y in 0..3 {
            *t.at_mut(0, y, 0) = C64::new(y as f64 + 1.0, 0.0);
            *t.at_mut(0, y, 1) = C64::new(-(y as f64) - 10.0, 0.5);
        }
        let m = measure(&t, &[1.0, 1.0, 1.0], &[0.999], ScalingMode::None).unwrap();
        assert_eq!(m.samples[0], 1);
        assert_eq!(m.env[(0, 2)], C64::new(-12.0, 0.5));
    }

    #[test]
    fn dead_rows_counted() {
        let t: Tensor3<f64> = Tensor3::zeros(2, 2, 2);
        let m = measure(&t, &[1.0, 1.0], &[0.5, 0.5], ScalingMode::PerSample).unwrap();
        assert_eq!(m.dead_rows, 2);
        assert_eq!(m.samples, vec![0, 0]);
    }

    #[test]
    fn per_sample_scaling_unit_rows() {
        let mut env: Mat<f64> = Mat::zeros(2, 2);
        env[(0, 0)] = C64::new(1e-20, 0.0);
        env[(0, 1)] = C64::new(0.0, 2e-20);
        env[(1, 0)] = C64::new(3.0, 4.0);
        apply_scaling(&mut env, ScalingMode::PerSample);
        assert!((env[(0, 1)].abs() - 1.0).abs() < 1e-12);
        assert!((env[(1, 0)].abs() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn global_scaling_single_factor() {
        let mut env: Mat<f64> = Mat::zeros(2, 1);
        env[(0, 0)] = C64::new(4.0, 0.0);
        env[(1, 0)] = C64::new(1.0, 0.0);
        apply_scaling(&mut env, ScalingMode::Global);
        assert!((env[(0, 0)].re - 1.0).abs() < 1e-12);
        assert!((env[(1, 0)].re - 0.25).abs() < 1e-12);
    }

    #[test]
    fn lambda_weights_probabilities() {
        // Two bond channels with different Λ: outcome prefers the weighted one.
        let mut t = Tensor3::zeros(1, 2, 2);
        *t.at_mut(0, 0, 0) = C64::new(1.0, 0.0); // channel 0 → outcome 0
        *t.at_mut(0, 1, 1) = C64::new(1.0, 0.0); // channel 1 → outcome 1
        // Λ = [0, 1]: outcome 1 is certain.
        let m = measure(&t, &[0.0, 1.0], &[0.9999], ScalingMode::None).unwrap();
        assert_eq!(m.samples[0], 1);
        let m2 = measure(&t, &[1.0, 0.0], &[0.0001], ScalingMode::None).unwrap();
        assert_eq!(m2.samples[0], 0);
    }

    #[test]
    fn stats_report_spread() {
        let mut env: Mat<f64> = Mat::zeros(1, 3);
        env[(0, 0)] = C64::new(1.0, 0.0);
        env[(0, 1)] = C64::new(0.01, 0.0);
        let st = env_sample_stats(&env);
        assert!((st[0].0 - 1.0).abs() < 1e-12);
        assert!((st[0].1 - 100.0).abs() < 1e-9);
    }

    #[test]
    fn shape_errors() {
        let t: Tensor3<f64> = Tensor3::zeros(2, 3, 2);
        assert!(measure(&t, &[1.0; 2], &[0.5; 2], ScalingMode::None).is_err());
        assert!(measure(&t, &[1.0; 3], &[0.5; 1], ScalingMode::None).is_err());
    }
}
