//! Environment precision adapters between the boundary representation
//! ([`SplitBuf`], f32 planes) and the native engines' `Mat<T>`.

use crate::config::ComputePrecision;
use crate::tensor::{Complex, Mat, PlanarMat, SplitBuf};
use crate::util::error::{Error, Result};
use crate::util::f16;

/// Lift a SplitBuf environment to f64 for the native-f64 oracle.
pub fn to_f64(env: &SplitBuf) -> Result<Mat<f64>> {
    env.to_mat_c64()
}

fn rank2(env: &SplitBuf) -> Result<(usize, usize)> {
    if env.shape.len() != 2 {
        return Err(Error::shape(format!(
            "env adapter: shape {:?} is not rank-2",
            env.shape
        )));
    }
    env.check()?;
    Ok((env.shape[0], env.shape[1]))
}

/// [`to_f64`] into a workspace matrix — allocation-free once `out` has
/// warmed up to the working shape; single write pass (no zero-fill).
pub fn to_f64_into(env: &SplitBuf, out: &mut Mat<f64>) -> Result<()> {
    let (r, c) = rank2(env)?;
    out.rows = r;
    out.cols = c;
    out.data.clear();
    out.data.extend(
        env.re
            .iter()
            .zip(&env.im)
            .map(|(&re, &im)| Complex::new(re as f64, im as f64)),
    );
    Ok(())
}

/// [`to_f32`] into a workspace matrix (same rounding semantics).
pub fn to_f32_into(env: &SplitBuf, precision: ComputePrecision, out: &mut Mat<f32>) -> Result<()> {
    let (r, c) = rank2(env)?;
    out.rows = r;
    out.cols = c;
    out.data.clear();
    out.data.extend(
        env.re
            .iter()
            .zip(&env.im)
            .map(|(&re, &im)| Complex::new(re, im)),
    );
    match precision {
        ComputePrecision::Tf32 => {
            for z in &mut out.data {
                z.re = f16::round_tf32(z.re);
                z.im = f16::round_tf32(z.im);
            }
        }
        ComputePrecision::F16 => {
            for z in &mut out.data {
                z.re = f16::round_f16(z.re);
                z.im = f16::round_f16(z.im);
            }
        }
        _ => {}
    }
    Ok(())
}

/// Store back into an existing boundary buffer, reusing its planes
/// (allocation-free at steady state; single write pass per plane).
pub fn from_f64_into(m: &Mat<f64>, env: &mut SplitBuf) {
    env.shape.clear();
    env.shape.push(m.rows);
    env.shape.push(m.cols);
    env.re.clear();
    env.re.extend(m.data.iter().map(|z| z.re as f32));
    env.im.clear();
    env.im.extend(m.data.iter().map(|z| z.im as f32));
}

pub fn from_f32_into(m: &Mat<f32>, env: &mut SplitBuf) {
    env.shape.clear();
    env.shape.push(m.rows);
    env.shape.push(m.cols);
    env.re.clear();
    env.re.extend(m.data.iter().map(|z| z.re));
    env.im.clear();
    env.im.extend(m.data.iter().map(|z| z.im));
}

/// [`to_f64_into`] for the planar layout. The boundary buffer already
/// stores split f32 planes, so the lift is a straight per-plane widening
/// copy — no interleave pass at all. Values are bit-identical to the
/// interleaved adapter's (same widening, per element).
pub fn to_planar_f64_into(env: &SplitBuf, out: &mut PlanarMat<f64>) -> Result<()> {
    let (r, c) = rank2(env)?;
    out.rows = r;
    out.cols = c;
    out.re.clear();
    out.re.extend(env.re.iter().map(|&v| v as f64));
    out.im.clear();
    out.im.extend(env.im.iter().map(|&v| v as f64));
    Ok(())
}

/// [`to_f32_into`] for the planar layout (same per-element rounding
/// semantics, applied per plane).
pub fn to_planar_f32_into(
    env: &SplitBuf,
    precision: ComputePrecision,
    out: &mut PlanarMat<f32>,
) -> Result<()> {
    let (r, c) = rank2(env)?;
    out.rows = r;
    out.cols = c;
    out.re.clear();
    out.re.extend_from_slice(&env.re);
    out.im.clear();
    out.im.extend_from_slice(&env.im);
    match precision {
        ComputePrecision::Tf32 => {
            for v in out.re.iter_mut().chain(out.im.iter_mut()) {
                *v = f16::round_tf32(*v);
            }
        }
        ComputePrecision::F16 => {
            for v in out.re.iter_mut().chain(out.im.iter_mut()) {
                *v = f16::round_f16(*v);
            }
        }
        _ => {}
    }
    Ok(())
}

/// [`from_f64_into`] for the planar layout (per-plane narrowing copy).
pub fn from_planar_f64_into(m: &PlanarMat<f64>, env: &mut SplitBuf) {
    env.shape.clear();
    env.shape.push(m.rows);
    env.shape.push(m.cols);
    env.re.clear();
    env.re.extend(m.re.iter().map(|&v| v as f32));
    env.im.clear();
    env.im.extend(m.im.iter().map(|&v| v as f32));
}

/// [`from_f32_into`] for the planar layout (straight per-plane copy).
pub fn from_planar_f32_into(m: &PlanarMat<f32>, env: &mut SplitBuf) {
    env.shape.clear();
    env.shape.push(m.rows);
    env.shape.push(m.cols);
    env.re.clear();
    env.re.extend_from_slice(&m.re);
    env.im.clear();
    env.im.extend_from_slice(&m.im);
}

/// Lift to f32 with optional TF32/FP16 input rounding (what tensor cores
/// resp. a ComplexHalf pipeline do to their operands).
pub fn to_f32(env: &SplitBuf, precision: ComputePrecision) -> Result<Mat<f32>> {
    let mut m = env.to_mat_c32()?;
    match precision {
        ComputePrecision::Tf32 => {
            for z in &mut m.data {
                z.re = f16::round_tf32(z.re);
                z.im = f16::round_tf32(z.im);
            }
        }
        ComputePrecision::F16 => {
            for z in &mut m.data {
                z.re = f16::round_f16(z.re);
                z.im = f16::round_f16(z.im);
            }
        }
        _ => {}
    }
    Ok(m)
}

/// Store back into the boundary representation.
pub fn from_f64(m: &Mat<f64>) -> SplitBuf {
    SplitBuf::from_mat_c64(m)
}

pub fn from_f32(m: &Mat<f32>) -> SplitBuf {
    SplitBuf::from_mat_c32(m)
}

/// §3.3.2: round the boundary buffer through FP16 (the stored/streamed left
/// environment) — used when the coordinator spills environments between
/// macro-batch rounds.
pub fn f16_storage_pass(env: &mut SplitBuf) {
    env.round_f16_in_place();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::C64;

    #[test]
    fn roundtrip_f64() {
        let mut m: Mat<f64> = Mat::zeros(2, 2);
        m[(0, 1)] = C64::new(0.5, -0.25);
        let sb = from_f64(&m);
        let back = to_f64(&sb).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn tf32_rounding_changes_values() {
        let mut sb = SplitBuf::zeros(&[1, 1]);
        sb.re[0] = 1.0 + 1.0 / 4096.0;
        let plain = to_f32(&sb, ComputePrecision::F32).unwrap();
        let tf = to_f32(&sb, ComputePrecision::Tf32).unwrap();
        assert_ne!(plain[(0, 0)].re, tf[(0, 0)].re);
        assert_eq!(tf[(0, 0)].re, 1.0);
    }

    #[test]
    fn into_adapters_match_allocating_forms() {
        let mut sb = SplitBuf::zeros(&[2, 3]);
        for (i, v) in sb.re.iter_mut().enumerate() {
            *v = 0.125 + i as f32;
        }
        sb.im[4] = -2.5;
        let mut m64 = Mat::zeros(1, 1);
        to_f64_into(&sb, &mut m64).unwrap();
        assert_eq!(m64, to_f64(&sb).unwrap());
        for prec in [
            ComputePrecision::F32,
            ComputePrecision::Tf32,
            ComputePrecision::F16,
        ] {
            let mut m32 = Mat::zeros(1, 1);
            to_f32_into(&sb, prec, &mut m32).unwrap();
            assert_eq!(m32, to_f32(&sb, prec).unwrap(), "{prec:?}");
        }
        let mut back = SplitBuf::zeros(&[1, 1]);
        from_f64_into(&m64, &mut back);
        assert_eq!(back, from_f64(&m64));
        let mut bad = sb.clone();
        bad.shape = vec![6];
        assert!(to_f64_into(&bad, &mut m64).is_err());
    }

    #[test]
    fn planar_adapters_match_interleaved_adapters() {
        let mut sb = SplitBuf::zeros(&[3, 4]);
        for (i, v) in sb.re.iter_mut().enumerate() {
            *v = 1.0 + 1.0 / 4096.0 + i as f32 * 0.37;
        }
        for (i, v) in sb.im.iter_mut().enumerate() {
            *v = -0.5 - i as f32 * 1e-5;
        }

        let mut m64 = Mat::zeros(0, 0);
        to_f64_into(&sb, &mut m64).unwrap();
        let mut p64 = PlanarMat::default();
        to_planar_f64_into(&sb, &mut p64).unwrap();
        assert_eq!(p64.to_interleaved(), m64);
        let mut back_i = SplitBuf::zeros(&[1, 1]);
        from_f64_into(&m64, &mut back_i);
        let mut back_p = SplitBuf::zeros(&[1, 1]);
        from_planar_f64_into(&p64, &mut back_p);
        assert_eq!(back_p, back_i);

        for prec in [
            ComputePrecision::F32,
            ComputePrecision::Tf32,
            ComputePrecision::F16,
        ] {
            let mut m32 = Mat::zeros(0, 0);
            to_f32_into(&sb, prec, &mut m32).unwrap();
            let mut p32 = PlanarMat::default();
            to_planar_f32_into(&sb, prec, &mut p32).unwrap();
            assert_eq!(p32.to_interleaved(), m32, "{prec:?}");
            let mut bi = SplitBuf::zeros(&[1, 1]);
            from_f32_into(&m32, &mut bi);
            let mut bp = SplitBuf::zeros(&[1, 1]);
            from_planar_f32_into(&p32, &mut bp);
            assert_eq!(bp, bi, "{prec:?}");
        }

        let mut bad = sb.clone();
        bad.shape = vec![12];
        assert!(to_planar_f64_into(&bad, &mut p64).is_err());
        let mut scratch = PlanarMat::default();
        assert!(to_planar_f32_into(&bad, ComputePrecision::F32, &mut scratch).is_err());
    }

    #[test]
    fn f16_pass_underflows_small() {
        let mut sb = SplitBuf::zeros(&[1, 2]);
        sb.re[0] = 1e-10;
        sb.re[1] = 0.5;
        f16_storage_pass(&mut sb);
        assert_eq!(sb.re[0], 0.0);
        assert_eq!(sb.re[1], 0.5);
    }
}
