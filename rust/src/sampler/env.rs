//! Environment precision adapters between the boundary representation
//! ([`SplitBuf`], f32 planes) and the native engines' `Mat<T>`.

use crate::config::ComputePrecision;
use crate::tensor::{Mat, SplitBuf};
use crate::util::error::Result;
use crate::util::f16;

/// Lift a SplitBuf environment to f64 for the native-f64 oracle.
pub fn to_f64(env: &SplitBuf) -> Result<Mat<f64>> {
    env.to_mat_c64()
}

/// Lift to f32 with optional TF32/FP16 input rounding (what tensor cores
/// resp. a ComplexHalf pipeline do to their operands).
pub fn to_f32(env: &SplitBuf, precision: ComputePrecision) -> Result<Mat<f32>> {
    let mut m = env.to_mat_c32()?;
    match precision {
        ComputePrecision::Tf32 => {
            for z in &mut m.data {
                z.re = f16::round_tf32(z.re);
                z.im = f16::round_tf32(z.im);
            }
        }
        ComputePrecision::F16 => {
            for z in &mut m.data {
                z.re = f16::round_f16(z.re);
                z.im = f16::round_f16(z.im);
            }
        }
        _ => {}
    }
    Ok(m)
}

/// Store back into the boundary representation.
pub fn from_f64(m: &Mat<f64>) -> SplitBuf {
    SplitBuf::from_mat_c64(m)
}

pub fn from_f32(m: &Mat<f32>) -> SplitBuf {
    SplitBuf::from_mat_c32(m)
}

/// §3.3.2: round the boundary buffer through FP16 (the stored/streamed left
/// environment) — used when the coordinator spills environments between
/// macro-batch rounds.
pub fn f16_storage_pass(env: &mut SplitBuf) {
    env.round_f16_in_place();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::C64;

    #[test]
    fn roundtrip_f64() {
        let mut m: Mat<f64> = Mat::zeros(2, 2);
        m[(0, 1)] = C64::new(0.5, -0.25);
        let sb = from_f64(&m);
        let back = to_f64(&sb).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn tf32_rounding_changes_values() {
        let mut sb = SplitBuf::zeros(&[1, 1]);
        sb.re[0] = 1.0 + 1.0 / 4096.0;
        let plain = to_f32(&sb, ComputePrecision::F32).unwrap();
        let tf = to_f32(&sb, ComputePrecision::Tf32).unwrap();
        assert_ne!(plain[(0, 0)].re, tf[(0, 0)].re);
        assert_eq!(tf[(0, 0)].re, 1.0);
    }

    #[test]
    fn f16_pass_underflows_small() {
        let mut sb = SplitBuf::zeros(&[1, 2]);
        sb.re[0] = 1e-10;
        sb.re[1] = 0.5;
        f16_storage_pass(&mut sb);
        assert_eq!(sb.re[0], 0.0);
        assert_eq!(sb.re[1], 0.5);
    }
}
