//! Prepared-site cache: Γ converted to the engine's compute precision
//! **once**, not once per micro batch.
//!
//! The old hot loop cloned the f64 Γ and re-ran the f16/tf32 rounding
//! loops on every `step` — at χ = 10⁴ that copy/convert churn dominates
//! the steady state instead of the GEMM (the failure mode resident,
//! pre-staged tensors eliminate; cf. "DMRG with Tensor Processing
//! Units"). A [`PreparedSite`] is the site tensor after the *entire*
//! precision pipeline of the native engine (optional Γ-f16 storage
//! rounding, f32 conversion, TF32/FP16 input rounding), built once and
//! then only borrowed; a [`PreparedStore`] keeps one lazily-filled chain
//! of them resident per `(store, PrepKey)` under a byte budget, so a
//! service batch after the first walks the chain with zero conversions
//! and zero Γ I/O.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

use crate::config::ComputePrecision;
use crate::mps::Site;
use crate::tensor::{PlanarTensor3, Tensor3};
use crate::util::f16;

/// Identity of a precision pipeline: two sites prepared under equal keys
/// are interchangeable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrepKey {
    pub compute: ComputePrecision,
    /// Round Γ through binary16 before compute (§3.3.2 storage modelling).
    pub gamma_f16: bool,
    /// Store Γ as split real/imaginary planes for the planar step kernel.
    /// Values are the interleaved pipeline's, split after rounding — the
    /// layout never perturbs a bit, only where each component lives.
    pub planar: bool,
}

/// The converted Γ, in the representation the engine contracts with.
#[derive(Debug, Clone)]
pub enum PreparedGamma {
    /// `ComputePrecision::F64` (post Γ-f16 rounding when enabled).
    F64(Tensor3<f64>),
    /// `F32` / `Tf32` / `F16` — f32 storage with the input rounding of the
    /// precision already applied.
    F32(Tensor3<f32>),
    /// `F64` under the planar layout: the `F64` arm's planes, split.
    P64(PlanarTensor3<f64>),
    /// `F32`-family under the planar layout: the `F32` arm's planes, split.
    P32(PlanarTensor3<f32>),
}

/// A site after one-time precision conversion. Steady-state steps borrow
/// it; nothing in here is cloned or re-rounded again.
#[derive(Debug, Clone)]
pub struct PreparedSite {
    pub key: PrepKey,
    pub gamma: PreparedGamma,
    /// Λ in the compute precision (exactly one of these is non-empty).
    pub lambda64: Vec<f64>,
    pub lambda32: Vec<f32>,
}

impl PreparedSite {
    /// Run the native engine's exact conversion pipeline once. The
    /// sequence (f64 Γ-f16 rounding → f32 conversion → TF32/FP16 input
    /// rounding) replicates the old per-step loops bit for bit, so a
    /// prepared step samples identical outcomes.
    pub fn prepare(site: &Site, key: PrepKey) -> PreparedSite {
        // Unconditional f16 rounding in the f64 domain; callers below
        // guard on `key.gamma_f16` (one idiom for the flag).
        let round64 = |z: crate::tensor::C64| {
            crate::tensor::C64::new(
                f16::round_f16(z.re as f32) as f64,
                f16::round_f16(z.im as f32) as f64,
            )
        };
        match key.compute {
            ComputePrecision::F64 => {
                let mut g = site.gamma.clone();
                if key.gamma_f16 {
                    for z in &mut g.data {
                        *z = round64(*z);
                    }
                }
                // The planar arm splits AFTER the full rounding pipeline,
                // so both layouts hold bit-identical values.
                let gamma = if key.planar {
                    PreparedGamma::P64(PlanarTensor3::from_interleaved(&g))
                } else {
                    PreparedGamma::F64(g)
                };
                PreparedSite {
                    key,
                    gamma,
                    lambda64: site.lambda.clone(),
                    lambda32: Vec::new(),
                }
            }
            ComputePrecision::F32 | ComputePrecision::Tf32 | ComputePrecision::F16 => {
                let mut g32 = Tensor3::zeros(site.gamma.d0, site.gamma.d1, site.gamma.d2);
                for (dst, src) in g32.data.iter_mut().zip(&site.gamma.data) {
                    let s = if key.gamma_f16 { round64(*src) } else { *src };
                    *dst = s.to_c32();
                }
                match key.compute {
                    ComputePrecision::Tf32 => {
                        for z in &mut g32.data {
                            z.re = f16::round_tf32(z.re);
                            z.im = f16::round_tf32(z.im);
                        }
                    }
                    ComputePrecision::F16 => {
                        for z in &mut g32.data {
                            z.re = f16::round_f16(z.re);
                            z.im = f16::round_f16(z.im);
                        }
                    }
                    _ => {}
                }
                let gamma = if key.planar {
                    PreparedGamma::P32(PlanarTensor3::from_interleaved(&g32))
                } else {
                    PreparedGamma::F32(g32)
                };
                PreparedSite {
                    key,
                    gamma,
                    lambda64: Vec::new(),
                    lambda32: site.lambda.iter().map(|&l| l as f32).collect(),
                }
            }
        }
    }

    pub fn chi_l(&self) -> usize {
        match &self.gamma {
            PreparedGamma::F64(g) => g.d0,
            PreparedGamma::F32(g) => g.d0,
            PreparedGamma::P64(g) => g.d0,
            PreparedGamma::P32(g) => g.d0,
        }
    }

    pub fn chi_r(&self) -> usize {
        match &self.gamma {
            PreparedGamma::F64(g) => g.d1,
            PreparedGamma::F32(g) => g.d1,
            PreparedGamma::P64(g) => g.d1,
            PreparedGamma::P32(g) => g.d1,
        }
    }

    pub fn phys_d(&self) -> usize {
        match &self.gamma {
            PreparedGamma::F64(g) => g.d2,
            PreparedGamma::F32(g) => g.d2,
            PreparedGamma::P64(g) => g.d2,
            PreparedGamma::P32(g) => g.d2,
        }
    }

    /// Resident heap bytes (budget accounting in [`PreparedStore`]).
    pub fn bytes(&self) -> u64 {
        let g = match &self.gamma {
            PreparedGamma::F64(g) => g.len() * 16,
            PreparedGamma::F32(g) => g.len() * 8,
            PreparedGamma::P64(g) => g.len() * 16,
            PreparedGamma::P32(g) => g.len() * 8,
        };
        (g + self.lambda64.len() * 8 + self.lambda32.len() * 4) as u64
    }
}

/// A lazily-filled chain of prepared sites for one `(store, PrepKey)` —
/// the residency layer the `StoreCache` hands to service workers. Sites
/// are prepared on first touch and kept while the byte budget allows;
/// over budget, `site()` still returns a (transient) prepared site, so
/// correctness never depends on residency.
pub struct PreparedStore {
    key: PrepKey,
    sites: Vec<OnceLock<Arc<PreparedSite>>>,
    budget_bytes: u64,
    resident_bytes: AtomicU64,
    /// One-time conversions performed (`step_prep_conversions`).
    pub conversions: AtomicU64,
    /// Lookups served from an already-resident site (`step_prep_hits`).
    pub hits: AtomicU64,
}

impl PreparedStore {
    pub fn new(num_sites: usize, key: PrepKey, budget_bytes: u64) -> PreparedStore {
        PreparedStore {
            key,
            sites: (0..num_sites).map(|_| OnceLock::new()).collect(),
            budget_bytes,
            resident_bytes: AtomicU64::new(0),
            conversions: AtomicU64::new(0),
            hits: AtomicU64::new(0),
        }
    }

    pub fn key(&self) -> PrepKey {
        self.key
    }

    pub fn num_sites(&self) -> usize {
        self.sites.len()
    }

    /// True when site `i` is already resident — callers that can skip
    /// loading the raw Γ (and its disk I/O) entirely check this first.
    pub fn is_resident(&self, i: usize) -> bool {
        self.sites.get(i).map(|c| c.get().is_some()).unwrap_or(false)
    }

    /// Get-or-prepare site `i` from `raw`. Returns the shared resident
    /// site when cached (second tuple element `false`), otherwise
    /// prepares (`true`; caching the result if the budget allows) — the
    /// flag lets callers account conversion work exactly, even when a
    /// concurrent preparer published between their residency check and
    /// this call.
    pub fn site(&self, i: usize, raw: &Site) -> (Arc<PreparedSite>, bool) {
        if let Some(p) = self.sites[i].get() {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return (p.clone(), false);
        }
        let p = Arc::new(PreparedSite::prepare(raw, self.key));
        self.conversions.fetch_add(1, Ordering::Relaxed);
        let b = p.bytes();
        // Reserve the bytes atomically BEFORE publishing, so concurrent
        // preparers cannot each pass a stale load and overshoot the
        // budget; a lost set race rolls its reservation back.
        let reserved = self
            .resident_bytes
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
                (cur + b <= self.budget_bytes).then_some(cur + b)
            })
            .is_ok();
        if reserved && self.sites[i].set(p.clone()).is_err() {
            self.resident_bytes.fetch_sub(b, Ordering::Relaxed);
        }
        // A concurrent preparer may have won the set; either Arc is a
        // bit-identical conversion of the same raw site.
        (p, true)
    }

    /// Resident site `i` without raw data (only when already prepared).
    pub fn resident(&self, i: usize) -> Option<Arc<PreparedSite>> {
        let p = self.sites.get(i)?.get()?.clone();
        self.hits.fetch_add(1, Ordering::Relaxed);
        Some(p)
    }

    pub fn resident_bytes(&self) -> u64 {
        self.resident_bytes.load(Ordering::Relaxed)
    }

    /// True once every site of the chain is resident — the walk can run
    /// with zero store I/O.
    pub fn fully_resident(&self) -> bool {
        self.sites.iter().all(|c| c.get().is_some())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mps::gbs::GbsSpec;

    fn spec() -> GbsSpec {
        GbsSpec {
            name: "prep".into(),
            m: 5,
            d: 3,
            chi_cap: 8,
            asp: 3.0,
            decay_k: 0.0,
            displacement_sigma: 0.0,
            branch_skew: 0.0,
            seed: 31,
            dynamic_chi: false,
            step_ratio_override: None,
        }
    }

    #[test]
    fn f64_preparation_is_the_identity_without_rounding() {
        let mps = spec().generate().unwrap();
        let site = &mps.sites[1];
        let p = PreparedSite::prepare(
            site,
            PrepKey {
                compute: ComputePrecision::F64,
                gamma_f16: false,
                planar: false,
            },
        );
        match &p.gamma {
            PreparedGamma::F64(g) => assert_eq!(g.data, site.gamma.data),
            _ => panic!("wrong precision arm"),
        }
        assert_eq!(p.lambda64, site.lambda);
        assert_eq!((p.chi_l(), p.chi_r(), p.phys_d()), (site.chi_l(), site.chi_r(), 3));
    }

    #[test]
    fn rounding_pipeline_matches_the_per_step_loops() {
        // Replicate the old NativeEngine::step conversion by hand and
        // compare bit for bit.
        let mps = spec().generate().unwrap();
        let site = &mps.sites[2];
        for compute in [
            ComputePrecision::F32,
            ComputePrecision::Tf32,
            ComputePrecision::F16,
        ] {
            for gamma_f16 in [false, true] {
                let p = PreparedSite::prepare(
                    site,
                    PrepKey {
                        compute,
                        gamma_f16,
                        planar: false,
                    },
                );
                let mut gamma = site.gamma.clone();
                if gamma_f16 {
                    for z in &mut gamma.data {
                        z.re = f16::round_f16(z.re as f32) as f64;
                        z.im = f16::round_f16(z.im as f32) as f64;
                    }
                }
                let mut want = Tensor3::zeros(gamma.d0, gamma.d1, gamma.d2);
                for (dst, src) in want.data.iter_mut().zip(&gamma.data) {
                    *dst = src.to_c32();
                }
                match compute {
                    ComputePrecision::Tf32 => {
                        for z in &mut want.data {
                            z.re = f16::round_tf32(z.re);
                            z.im = f16::round_tf32(z.im);
                        }
                    }
                    ComputePrecision::F16 => {
                        for z in &mut want.data {
                            z.re = f16::round_f16(z.re);
                            z.im = f16::round_f16(z.im);
                        }
                    }
                    _ => {}
                }
                match &p.gamma {
                    PreparedGamma::F32(g) => {
                        assert_eq!(g.data, want.data, "{compute:?} gamma_f16={gamma_f16}")
                    }
                    _ => panic!("wrong precision arm"),
                }
                assert!(p.lambda64.is_empty());
                assert_eq!(p.lambda32.len(), site.lambda.len());
            }
        }
    }

    #[test]
    fn planar_preparation_is_a_split_of_the_interleaved_pipeline() {
        let mps = spec().generate().unwrap();
        let site = &mps.sites[2];
        for compute in [
            ComputePrecision::F64,
            ComputePrecision::F32,
            ComputePrecision::Tf32,
            ComputePrecision::F16,
        ] {
            for gamma_f16 in [false, true] {
                let inter = PreparedSite::prepare(
                    site,
                    PrepKey {
                        compute,
                        gamma_f16,
                        planar: false,
                    },
                );
                let plan = PreparedSite::prepare(
                    site,
                    PrepKey {
                        compute,
                        gamma_f16,
                        planar: true,
                    },
                );
                match (&inter.gamma, &plan.gamma) {
                    (PreparedGamma::F64(g), PreparedGamma::P64(p)) => {
                        assert_eq!(p.to_interleaved().data, g.data);
                    }
                    (PreparedGamma::F32(g), PreparedGamma::P32(p)) => {
                        assert_eq!(p.to_interleaved().data, g.data);
                    }
                    _ => panic!("layout arms mismatched for {compute:?}"),
                }
                assert_eq!(inter.bytes(), plan.bytes());
                assert_eq!(
                    (inter.chi_l(), inter.chi_r(), inter.phys_d()),
                    (plan.chi_l(), plan.chi_r(), plan.phys_d())
                );
            }
        }
    }

    #[test]
    fn prepared_store_caches_and_respects_budget() {
        let mps = spec().generate().unwrap();
        let key = PrepKey {
            compute: ComputePrecision::F32,
            gamma_f16: false,
            planar: false,
        };
        // Generous budget: everything resident, second pass all hits.
        let ps = PreparedStore::new(mps.sites.len(), key, u64::MAX);
        for (i, s) in mps.sites.iter().enumerate() {
            assert!(!ps.is_resident(i));
            let (_, converted) = ps.site(i, s);
            assert!(converted, "cold site must convert");
            assert!(ps.is_resident(i));
        }
        assert!(ps.fully_resident());
        assert_eq!(ps.conversions.load(Ordering::Relaxed), 5);
        let (a, ca) = ps.site(1, &mps.sites[1]);
        let (b, cb) = ps.site(1, &mps.sites[1]);
        assert!(Arc::ptr_eq(&a, &b), "resident site is shared");
        assert!(!ca && !cb, "resident lookups must not report conversions");
        assert_eq!(ps.hits.load(Ordering::Relaxed), 2);
        assert!(ps.resident_bytes() > 0);
        assert!(ps.resident(0).is_some());

        // Tiny budget: nothing cached, every call converts, still correct.
        let tiny = PreparedStore::new(mps.sites.len(), key, 1);
        assert!(tiny.site(0, &mps.sites[0]).1);
        assert!(tiny.site(0, &mps.sites[0]).1, "uncached call converts again");
        assert!(!tiny.is_resident(0));
        assert_eq!(tiny.conversions.load(Ordering::Relaxed), 2);
        assert_eq!(tiny.resident_bytes(), 0);
        assert!(tiny.resident(0).is_none());
        assert!(!tiny.fully_resident());
    }
}
