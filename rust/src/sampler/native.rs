//! Native rust step engine — the correctness oracle and precision-study
//! workhorse.
//!
//! Executes the same per-site pipeline as the AOT XLA artifacts (contract →
//! optional displacement → measure → rescale) with full control over the
//! floating-point path: f64, f32, or TF32-emulated inputs, and any of the
//! three scaling strategies. The Fig. 5/6 experiments need exactly this
//! control; the XLA engine wins on throughput.

use crate::util::num::Float;

use crate::config::{ComputePrecision, ScalingMode};
use crate::linalg::{contract_env, displacement_fast_batch, matmul_flops};
use crate::metrics::{keys, Metrics};
use crate::mps::Site;
use crate::sampler::{env as envmod, measurement, StepEngine};
use crate::tensor::{Complex, Mat, SplitBuf, Tensor3};
use crate::util::error::{Error, Result};

/// Native engine configuration + counters.
pub struct NativeEngine {
    pub precision: ComputePrecision,
    pub scaling: ScalingMode,
    /// Threads for the bond-contraction GEMM.
    pub threads: usize,
    /// Round Γ through f16 before compute (models fp16-stored tensors that
    /// were only converted, §3.3.2).
    pub gamma_f16: bool,
    pub metrics: Metrics,
    /// Dead (underflowed) sample rows seen so far — Fig. 6's failure signal.
    pub dead_rows: u64,
}

impl NativeEngine {
    pub fn new(precision: ComputePrecision, scaling: ScalingMode, threads: usize) -> Self {
        NativeEngine {
            precision,
            scaling,
            threads: threads.max(1),
            gamma_f16: false,
            metrics: Metrics::new(),
            dead_rows: 0,
        }
    }

    fn step_typed<T>(
        &mut self,
        env: Mat<T>,
        gamma: &Tensor3<T>,
        lambda: &[T],
        thresholds: &[f32],
        displacements: Option<&[(f64, f64)]>,
        samples: &mut Vec<i32>,
    ) -> Result<Mat<T>>
    where
        T: Float + std::ops::AddAssign + Send + Sync,
    {
        let n = env.rows;
        let mut temp = self.metrics.time("compute", || {
            contract_env(&env, gamma, self.threads)
        })?;
        self.metrics.add(
            keys::FLOPS,
            matmul_flops(n, gamma.d0, gamma.d1 * gamma.d2),
        );

        if let Some(mus) = displacements {
            if mus.len() != n {
                return Err(Error::shape(format!(
                    "displacements: {} for N={n}",
                    mus.len()
                )));
            }
            self.metrics.time("displace", || {
                apply_displacement(&mut temp, mus);
            });
            self.metrics
                .add(keys::FLOPS, 8 * (n * gamma.d1 * gamma.d2 * gamma.d2) as u64);
        }

        let measured = self.metrics.time("measure", || {
            measurement::measure(&temp, lambda, thresholds, self.scaling)
        })?;
        self.metrics
            .add(keys::FLOPS, 8 * (n * gamma.d1 * gamma.d2) as u64);
        self.dead_rows += measured.dead_rows as u64;
        *samples = measured.samples;
        Ok(measured.env)
    }
}

/// Apply per-sample fast displacement matrices to the temp tensor in place:
/// `temp[s, y, :] ← temp[s, y, :] · D(μ_s)`.
fn apply_displacement<T: Float + std::ops::AddAssign>(temp: &mut Tensor3<T>, mus: &[(f64, f64)]) {
    let (n, y, d) = (temp.d0, temp.d1, temp.d2);
    let mu_c: Vec<Complex<T>> = mus
        .iter()
        .map(|&(re, im)| Complex::new(T::from(re).unwrap(), T::from(im).unwrap()))
        .collect();
    // Batched analytic D, batch-last layout (§3.4.1).
    let dmats = displacement_fast_batch(&mu_c, d).expect("d >= 1");
    let mut row = vec![Complex::<T>::zero(); d];
    for s in 0..n {
        for yy in 0..y {
            let base = (s * y + yy) * d;
            row.copy_from_slice(&temp.data[base..base + d]);
            for k in 0..d {
                let mut acc = Complex::zero();
                for (j, &r) in row.iter().enumerate() {
                    acc = acc.mul_add(r, dmats[(j * d + k) * n + s]);
                }
                temp.data[base + k] = acc;
            }
        }
    }
}

impl StepEngine for NativeEngine {
    fn step(
        &mut self,
        env: &mut SplitBuf,
        site: &Site,
        thresholds: &[f32],
        displacements: Option<&[(f64, f64)]>,
        samples: &mut Vec<i32>,
    ) -> Result<()> {
        let mut gamma = site.gamma.clone();
        if self.gamma_f16 {
            for z in &mut gamma.data {
                z.re = crate::util::f16::round_f16(z.re as f32) as f64;
                z.im = crate::util::f16::round_f16(z.im as f32) as f64;
            }
        }
        match self.precision {
            ComputePrecision::F64 => {
                let e = envmod::to_f64(env)?;
                let lambda: Vec<f64> = site.lambda.clone();
                let out =
                    self.step_typed(e, &gamma, &lambda, thresholds, displacements, samples)?;
                *env = envmod::from_f64(&out);
            }
            ComputePrecision::F32 | ComputePrecision::Tf32 | ComputePrecision::F16 => {
                let e = envmod::to_f32(env, self.precision)?;
                let mut g32 = Tensor3::zeros(gamma.d0, gamma.d1, gamma.d2);
                for (dst, src) in g32.data.iter_mut().zip(&gamma.data) {
                    *dst = src.to_c32();
                }
                match self.precision {
                    ComputePrecision::Tf32 => {
                        for z in &mut g32.data {
                            z.re = crate::util::f16::round_tf32(z.re);
                            z.im = crate::util::f16::round_tf32(z.im);
                        }
                    }
                    ComputePrecision::F16 => {
                        for z in &mut g32.data {
                            z.re = crate::util::f16::round_f16(z.re);
                            z.im = crate::util::f16::round_f16(z.im);
                        }
                    }
                    _ => {}
                }
                let lambda: Vec<f32> = site.lambda.iter().map(|&l| l as f32).collect();
                let mut out =
                    self.step_typed(e, &g32, &lambda, thresholds, displacements, samples)?;
                if self.precision == ComputePrecision::F16 {
                    // ComplexHalf result storage: round the collapsed env.
                    for z in &mut out.data {
                        z.re = crate::util::f16::round_f16(z.re);
                        z.im = crate::util::f16::round_f16(z.im);
                    }
                }
                *env = envmod::from_f32(&out);
            }
        }
        self.metrics.add(keys::SAMPLES, thresholds.len() as u64);
        Ok(())
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mps::gbs::GbsSpec;
    use crate::sampler::boundary_env;

    fn spec(decay: f64) -> GbsSpec {
        GbsSpec {
            name: "ne".into(),
            m: 10,
            d: 3,
            chi_cap: 12,
            asp: 4.0,
            decay_k: decay,
            displacement_sigma: 0.0,
            branch_skew: 0.0,
            seed: 77,
            dynamic_chi: false,
            step_ratio_override: None,
        }
    }

    fn walk(
        engine: &mut NativeEngine,
        spec: &GbsSpec,
        n: usize,
        displaced: bool,
    ) -> Vec<Vec<i32>> {
        let mps = spec.generate().unwrap();
        let mut env = boundary_env(n);
        let mut all = Vec::new();
        for (i, site) in mps.sites.iter().enumerate() {
            let th = spec.thresholds(i, 0, n);
            let mus = displaced.then(|| spec.displacement_draws(i, 0, n));
            let mut s = Vec::new();
            engine
                .step(&mut env, site, &th, mus.as_deref(), &mut s)
                .unwrap();
            all.push(s);
        }
        all
    }

    #[test]
    fn f64_and_f32_agree_without_decay() {
        let sp = spec(0.0);
        let mut e64 = NativeEngine::new(ComputePrecision::F64, ScalingMode::PerSample, 1);
        let mut e32 = NativeEngine::new(ComputePrecision::F32, ScalingMode::PerSample, 1);
        let a = walk(&mut e64, &sp, 64, false);
        let b = walk(&mut e32, &sp, 64, false);
        // Threshold knife-edges can flip a rare sample; demand 99% equality.
        let total: usize = a.iter().map(|v| v.len()).sum();
        let diff: usize = a
            .iter()
            .zip(&b)
            .map(|(x, y)| x.iter().zip(y).filter(|(p, q)| p != q).count())
            .sum();
        assert!(diff * 100 <= total, "{diff}/{total} outcomes differ");
    }

    #[test]
    fn outcomes_match_exact_marginals() {
        // Statistical Born-rule check against the transfer-matrix oracle.
        let sp = spec(0.0);
        let mps = sp.generate().unwrap();
        let ideal = crate::mps::exact::exact_mean_photons(&mps).unwrap();
        let n = 4096;
        let mut eng = NativeEngine::new(ComputePrecision::F64, ScalingMode::PerSample, 2);
        let all = walk(&mut eng, &sp, n, false);
        for (i, site_samples) in all.iter().enumerate() {
            let mean: f64 =
                site_samples.iter().map(|&s| s as f64).sum::<f64>() / n as f64;
            // Binomial-ish error bars at N=4096.
            assert!(
                (mean - ideal[i]).abs() < 0.08,
                "site {i}: sampled {mean} vs exact {}",
                ideal[i]
            );
        }
    }

    #[test]
    fn decay_with_per_sample_scaling_survives_f32() {
        // Strong decay: f32 without rescaling collapses, per-sample survives.
        let sp = spec(3.0); // 3 decades per site, 10 sites = 10^-30
        let mut good = NativeEngine::new(ComputePrecision::F32, ScalingMode::PerSample, 1);
        walk(&mut good, &sp, 32, false);
        assert_eq!(good.dead_rows, 0, "per-sample scaling must survive");

        let mut bad = NativeEngine::new(ComputePrecision::F32, ScalingMode::None, 1);
        walk(&mut bad, &sp, 32, false);
        assert!(bad.dead_rows > 0, "unscaled f32 must underflow");
    }

    #[test]
    fn scaling_does_not_change_outcomes_in_f64() {
        let sp = spec(0.5);
        let mut a = NativeEngine::new(ComputePrecision::F64, ScalingMode::PerSample, 1);
        let mut b = NativeEngine::new(ComputePrecision::F64, ScalingMode::Global, 1);
        let sa = walk(&mut a, &sp, 48, false);
        let sb = walk(&mut b, &sp, 48, false);
        assert_eq!(sa, sb, "scaling is probability-invariant in f64");
    }

    #[test]
    fn displaced_walk_runs_and_changes_outcomes() {
        let mut sp = spec(0.0);
        sp.displacement_sigma = 0.4;
        let mut eng = NativeEngine::new(ComputePrecision::F64, ScalingMode::PerSample, 1);
        let with = walk(&mut eng, &sp, 64, true);
        let mut eng2 = NativeEngine::new(ComputePrecision::F64, ScalingMode::PerSample, 1);
        let without = walk(&mut eng2, &sp, 64, false);
        assert_ne!(with, without, "displacement must change the distribution");
        // Outcomes remain valid occupations.
        assert!(with.iter().flatten().all(|&s| (0..3).contains(&s)));
    }

    #[test]
    fn tf32_close_to_f32() {
        let sp = spec(0.2);
        let mut a = NativeEngine::new(ComputePrecision::F32, ScalingMode::PerSample, 1);
        let mut b = NativeEngine::new(ComputePrecision::Tf32, ScalingMode::PerSample, 1);
        let sa = walk(&mut a, &sp, 128, false);
        let sb = walk(&mut b, &sp, 128, false);
        let total: usize = sa.iter().map(|v| v.len()).sum();
        let diff: usize = sa
            .iter()
            .zip(&sb)
            .map(|(x, y)| x.iter().zip(y).filter(|(p, q)| p != q).count())
            .sum();
        assert!(diff * 20 <= total, "{diff}/{total} tf32 outcome flips");
    }

    #[test]
    fn f16_experimental_mode_tracks_f32_on_short_chains() {
        // S3.3.1's experimental ComplexHalf arm: valid for M < 500; with
        // per-sample scaling the outcomes stay statistically close to f32.
        let sp = spec(0.1);
        let mut a = NativeEngine::new(ComputePrecision::F32, ScalingMode::PerSample, 1);
        let mut b = NativeEngine::new(ComputePrecision::F16, ScalingMode::PerSample, 1);
        let sa = walk(&mut a, &sp, 256, false);
        let sb = walk(&mut b, &sp, 256, false);
        assert_eq!(b.dead_rows, 0, "f16 + per-sample scaling must not die");
        let total: usize = sa.iter().map(|v| v.len()).sum();
        let diff: usize = sa
            .iter()
            .zip(&sb)
            .map(|(x, y)| x.iter().zip(y).filter(|(p, q)| p != q).count())
            .sum();
        // More rounding flips than tf32 but still a small fraction.
        assert!(diff * 10 <= total, "{diff}/{total} f16 outcome flips");
    }

    #[test]
    fn f16_mode_rejected_for_long_chains() {
        use crate::config::Preset;
        let mut spec = Preset::M8176.full_spec(1); // M = 8176
        spec.chi_cap = 8;
        let mut cfg = crate::config::RunConfig::new(spec);
        cfg.compute = ComputePrecision::F16;
        assert!(cfg.validate().is_err());
        cfg.compute = ComputePrecision::F32;
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn metrics_accumulate() {
        let sp = spec(0.0);
        let mut eng = NativeEngine::new(ComputePrecision::F32, ScalingMode::PerSample, 1);
        walk(&mut eng, &sp, 16, false);
        assert!(eng.metrics.get(keys::FLOPS) > 0);
        assert_eq!(eng.metrics.get(keys::SAMPLES), 160); // 16 × 10 sites
        assert!(eng.metrics.phase("compute") >= 0.0);
    }
}
