//! Native rust step engine — the correctness oracle and precision-study
//! workhorse.
//!
//! Executes the same per-site pipeline as the AOT XLA artifacts (contract →
//! optional displacement → measure → rescale) with full control over the
//! floating-point path: f64, f32, or TF32-emulated inputs, and any of the
//! three scaling strategies. The Fig. 5/6 experiments need exactly this
//! control; the XLA engine wins on throughput.
//!
//! ## Allocation-free steady state
//!
//! The hot entry point is [`NativeEngine::step_prepared`]: Γ arrives as a
//! [`PreparedSite`] (converted to compute precision **once**, at store
//! load) and every intermediate — environment precision lifts, the temp
//! tensor, displacement matrices, probabilities, the collapsed
//! environment — lives in a per-engine [`StepWorkspace`] that is only
//! *reshaped* between steps. After warm-up a single-threaded step performs
//! **zero** heap allocations (asserted by a counting-allocator test; the
//! `step_ws_grows` counter tracks residual growth events in production).
//! [`StepEngine::step`] remains as the compatibility path: it prepares a
//! transient site and delegates.

use crate::util::num::Float;

use crate::config::{ComputePrecision, Layout, ScalingMode};
use crate::linalg::{
    contract_env_into_on, displacement_fast_batch_into, matmul_flops,
    planar_contract_env_into_on, DisplacementWs, Exec, GemmSplit, PlanarScalar, WorkerPool,
};
use crate::metrics::{keys, Metrics};
use crate::mps::Site;
use crate::sampler::prepared::{PrepKey, PreparedGamma, PreparedSite};
use crate::sampler::{env as envmod, measurement, StepEngine};
use crate::tensor::{Complex, Mat, PlanarMat, PlanarTensor3, SplitBuf, Tensor3};
use crate::util::error::{Error, Result};

/// Per-precision scratch arena of the step loop. Buffers are reshaped in
/// place every step and grow only until the largest working set has been
/// seen; `capacity_units` feeds the engine's growth detection.
#[derive(Debug, Clone)]
pub struct StepWorkspace<T> {
    /// Environment lifted to compute precision (N, χ_l).
    env_in: Mat<T>,
    /// Unmeasured temp tensor (N, χ_r, d).
    temp: Tensor3<T>,
    /// Collapsed environment after measurement (N, χ_r).
    env_out: Mat<T>,
    /// Per-outcome probability accumulator (d).
    probs: Vec<T>,
    /// Displacement draws in compute precision (N).
    mus: Vec<Complex<T>>,
    /// Batched D(μ) matrices, batch-last layout (d·d·N).
    dmats: Vec<Complex<T>>,
    /// One sample's D repacked contiguously, transposed to `[k][j]` (d·d).
    dmat_t: Vec<Complex<T>>,
    /// One (χ_r-row, d) lane of temp during the displacement update (d).
    drow: Vec<Complex<T>>,
    /// Scratch of the batched displacement builder.
    disp: DisplacementWs<T>,
    /// Planar-layout arenas (split re/im planes). The planar step never
    /// repacks mid-step: the environment is lifted straight into planes,
    /// contracted, displaced, measured and written back plane-wise.
    penv_in: PlanarMat<T>,
    ptemp: PlanarTensor3<T>,
    penv_out: PlanarMat<T>,
    /// Planar displacement row lanes (d each).
    pdrow_re: Vec<T>,
    pdrow_im: Vec<T>,
}

impl<T: Float + std::ops::AddAssign> Default for StepWorkspace<T> {
    fn default() -> Self {
        StepWorkspace {
            env_in: Mat::zeros(0, 0),
            temp: Tensor3::zeros(0, 0, 0),
            env_out: Mat::zeros(0, 0),
            probs: Vec::new(),
            mus: Vec::new(),
            dmats: Vec::new(),
            dmat_t: Vec::new(),
            drow: Vec::new(),
            disp: DisplacementWs::default(),
            penv_in: PlanarMat::default(),
            ptemp: PlanarTensor3::default(),
            penv_out: PlanarMat::default(),
            pdrow_re: Vec::new(),
            pdrow_im: Vec::new(),
        }
    }
}

impl<T: Float + std::ops::AddAssign> StepWorkspace<T> {
    /// Total element capacity across all buffers — constant at steady
    /// state; any increase is a workspace growth event.
    fn capacity_units(&self) -> usize {
        self.env_in.data.capacity()
            + self.temp.data.capacity()
            + self.env_out.data.capacity()
            + self.probs.capacity()
            + self.mus.capacity()
            + self.dmats.capacity()
            + self.dmat_t.capacity()
            + self.drow.capacity()
            + self.disp.capacity_units()
            + self.penv_in.capacity_units()
            + self.ptemp.capacity_units()
            + self.penv_out.capacity_units()
            + self.pdrow_re.capacity()
            + self.pdrow_im.capacity()
    }
}

/// Native engine configuration + counters.
pub struct NativeEngine {
    pub precision: ComputePrecision,
    pub scaling: ScalingMode,
    /// Threads for the bond-contraction GEMM and the row-parallel measure.
    pub threads: usize,
    /// How the threaded GEMM partitions C (rows vs the bond axis).
    pub split: GemmSplit,
    /// Round Γ through f16 before compute (models fp16-stored tensors that
    /// were only converted, §3.3.2).
    pub gamma_f16: bool,
    /// Step-kernel memory layout policy (`Auto` → planar for the
    /// f32-family precisions). Changing this changes [`Self::prep_key`].
    pub layout: Layout,
    pub metrics: Metrics,
    /// Dead (underflowed) sample rows seen so far — Fig. 6's failure signal.
    pub dead_rows: u64,
    ws64: StepWorkspace<f64>,
    ws32: StepWorkspace<f32>,
    /// Resident worker pool for `threads > 1` — built once, reused every
    /// step, so the threaded hot path never spawns.
    pool: Option<WorkerPool>,
}

impl NativeEngine {
    pub fn new(precision: ComputePrecision, scaling: ScalingMode, threads: usize) -> Self {
        NativeEngine {
            precision,
            scaling,
            threads: threads.max(1),
            split: GemmSplit::Auto,
            gamma_f16: false,
            layout: Layout::Auto,
            metrics: Metrics::new(),
            dead_rows: 0,
            ws64: StepWorkspace::default(),
            ws32: StepWorkspace::default(),
            pool: None,
        }
    }

    /// The precision pipeline this engine expects its [`PreparedSite`]s to
    /// have been built with.
    pub fn prep_key(&self) -> PrepKey {
        PrepKey {
            compute: self.precision,
            gamma_f16: self.gamma_f16,
            planar: self.layout.planar_for(self.precision),
        }
    }

    /// (Re)build the resident pool to match `threads`. `threads == 1`
    /// drops it — the serial path needs no workers.
    fn ensure_pool(&mut self) {
        if self.threads > 1 {
            let stale = match &self.pool {
                Some(p) => p.width() != self.threads,
                None => true,
            };
            if stale {
                self.pool = Some(WorkerPool::new(self.threads));
            }
        } else {
            self.pool = None;
        }
    }

    /// Workspace growth events per step so far — the allocs-per-step KPI
    /// (0.0 at steady state; warm-up growth amortizes away).
    pub fn allocs_per_step(&self) -> f64 {
        let steps = self.metrics.get(keys::STEPS);
        if steps == 0 {
            return 0.0;
        }
        self.metrics.get(keys::STEP_WS_GROWS) as f64 / steps as f64
    }

    /// The allocation-free hot path: step a batch against a site that was
    /// converted to this engine's compute precision once, up front.
    pub fn step_prepared(
        &mut self,
        env: &mut SplitBuf,
        site: &PreparedSite,
        thresholds: &[f32],
        displacements: Option<&[(f64, f64)]>,
        samples: &mut Vec<i32>,
    ) -> Result<()> {
        if site.key != self.prep_key() {
            return Err(Error::config(format!(
                "prepared site key {:?} does not match engine {:?}",
                site.key,
                self.prep_key()
            )));
        }
        // Growth detection covers engine-owned workspace only: caller
        // buffers (env planes, samples) legitimately grow when a walk's χ
        // widens, and the counting-allocator test asserts the full
        // contract under a steady shape.
        self.ensure_pool();
        let exec = match &self.pool {
            Some(p) => Exec::Pooled(p),
            None => Exec::Scoped(self.threads),
        };
        match &site.gamma {
            PreparedGamma::F64(gamma) => {
                let ws = &mut self.ws64;
                let cap0 = ws.capacity_units();
                envmod::to_f64_into(env, &mut ws.env_in)?;
                let dead = step_in_workspace(
                    ws,
                    &mut self.metrics,
                    self.scaling,
                    exec,
                    self.split,
                    gamma,
                    &site.lambda64,
                    thresholds,
                    displacements,
                    samples,
                )?;
                self.dead_rows += dead as u64;
                envmod::from_f64_into(&self.ws64.env_out, env);
                let cap1 = self.ws64.capacity_units();
                self.note_step(cap0, cap1, thresholds.len(), false);
            }
            PreparedGamma::F32(gamma) => {
                let ws = &mut self.ws32;
                let cap0 = ws.capacity_units();
                envmod::to_f32_into(env, self.precision, &mut ws.env_in)?;
                let dead = step_in_workspace(
                    ws,
                    &mut self.metrics,
                    self.scaling,
                    exec,
                    self.split,
                    gamma,
                    &site.lambda32,
                    thresholds,
                    displacements,
                    samples,
                )?;
                self.dead_rows += dead as u64;
                if self.precision == ComputePrecision::F16 {
                    // ComplexHalf result storage: round the collapsed env.
                    for z in &mut self.ws32.env_out.data {
                        z.re = crate::util::f16::round_f16(z.re);
                        z.im = crate::util::f16::round_f16(z.im);
                    }
                }
                envmod::from_f32_into(&self.ws32.env_out, env);
                let cap1 = self.ws32.capacity_units();
                self.note_step(cap0, cap1, thresholds.len(), false);
            }
            PreparedGamma::P64(gamma) => {
                let ws = &mut self.ws64;
                let cap0 = ws.capacity_units();
                envmod::to_planar_f64_into(env, &mut ws.penv_in)?;
                let dead = step_in_workspace_planar(
                    ws,
                    &mut self.metrics,
                    self.scaling,
                    exec,
                    self.split,
                    gamma,
                    &site.lambda64,
                    thresholds,
                    displacements,
                    samples,
                )?;
                self.dead_rows += dead as u64;
                envmod::from_planar_f64_into(&self.ws64.penv_out, env);
                let cap1 = self.ws64.capacity_units();
                self.note_step(cap0, cap1, thresholds.len(), true);
            }
            PreparedGamma::P32(gamma) => {
                let ws = &mut self.ws32;
                let cap0 = ws.capacity_units();
                envmod::to_planar_f32_into(env, self.precision, &mut ws.penv_in)?;
                let dead = step_in_workspace_planar(
                    ws,
                    &mut self.metrics,
                    self.scaling,
                    exec,
                    self.split,
                    gamma,
                    &site.lambda32,
                    thresholds,
                    displacements,
                    samples,
                )?;
                self.dead_rows += dead as u64;
                if self.precision == ComputePrecision::F16 {
                    // ComplexHalf result storage: round the collapsed env.
                    let out = &mut self.ws32.penv_out;
                    for v in out.re.iter_mut().chain(out.im.iter_mut()) {
                        *v = crate::util::f16::round_f16(*v);
                    }
                }
                envmod::from_planar_f32_into(&self.ws32.penv_out, env);
                let cap1 = self.ws32.capacity_units();
                self.note_step(cap0, cap1, thresholds.len(), true);
            }
        }
        Ok(())
    }

    fn note_step(&mut self, cap_before: usize, cap_after: usize, n: usize, planar: bool) {
        self.metrics.add(keys::SAMPLES, n as u64);
        self.metrics.add(keys::STEPS, 1);
        self.metrics.add(keys::STEP_WS_GROWS, (cap_after > cap_before) as u64);
        self.metrics.add(keys::STEP_LAYOUT_PLANAR, planar as u64);
        if let Some(pool) = &self.pool {
            let (wakeups, park_ns) = pool.take_counters();
            self.metrics.add(keys::POOL_WAKEUPS, wakeups);
            self.metrics.add(keys::POOL_PARK_NS, park_ns);
        }
    }
}

/// The per-site pipeline over an already-lifted environment (`ws.env_in`)
/// and a borrowed prepared Γ: contract → optional displacement → measure.
/// Leaves the collapsed environment in `ws.env_out` and the outcomes in
/// `samples`; returns the dead-row count. Zero heap allocation once the
/// workspace has warmed up (threads = 1).
#[allow(clippy::too_many_arguments)]
fn step_in_workspace<T>(
    ws: &mut StepWorkspace<T>,
    metrics: &mut Metrics,
    scaling: ScalingMode,
    exec: Exec<'_>,
    split: GemmSplit,
    gamma: &Tensor3<T>,
    lambda: &[T],
    thresholds: &[f32],
    displacements: Option<&[(f64, f64)]>,
    samples: &mut Vec<i32>,
) -> Result<usize>
where
    T: Float + std::ops::AddAssign + Send + Sync,
{
    let StepWorkspace {
        env_in,
        temp,
        env_out,
        probs,
        mus,
        dmats,
        dmat_t,
        drow,
        disp,
    } = ws;
    let n = env_in.rows;
    let pooled = matches!(exec, Exec::Pooled(_));

    // Timed manually so the pooled dispatch can be attributed to its own
    // phase (`kernel_pooled`, surfaced as a trace span by the service
    // worker) on top of the usual `compute` total.
    let t0 = std::time::Instant::now();
    let contracted = contract_env_into_on(env_in, gamma, temp, exec, split);
    let dt = t0.elapsed().as_secs_f64();
    metrics.add_phase("compute", dt);
    if pooled {
        metrics.add_phase("kernel_pooled", dt);
    }
    contracted?;
    metrics.add(keys::FLOPS, matmul_flops(n, gamma.d0, gamma.d1 * gamma.d2));

    if let Some(raw_mus) = displacements {
        if raw_mus.len() != n {
            return Err(Error::shape(format!(
                "displacements: {} for N={n}",
                raw_mus.len()
            )));
        }
        metrics.time("displace", || -> Result<()> {
            mus.clear();
            mus.extend(
                raw_mus
                    .iter()
                    .map(|&(re, im)| Complex::new(T::from(re).unwrap(), T::from(im).unwrap())),
            );
            // Batched analytic D, batch-last layout (§3.4.1).
            displacement_fast_batch_into(mus, gamma.d2, dmats, disp)?;
            apply_displacement(temp, dmats, dmat_t, drow);
            Ok(())
        })?;
        metrics.add(keys::FLOPS, 8 * (n * gamma.d1 * gamma.d2 * gamma.d2) as u64);
    }

    let dead = metrics.time("measure", || {
        measurement::measure_into_on(
            temp, lambda, thresholds, scaling, exec, env_out, samples, probs,
        )
    })?;
    metrics.add(keys::FLOPS, 8 * (n * gamma.d1 * gamma.d2) as u64);
    Ok(dead)
}

/// [`step_in_workspace`] over the planar arenas and a planar Γ. Same
/// pipeline, same accumulation orders — outcomes and environment bits are
/// identical to the interleaved path (asserted in the tests below).
#[allow(clippy::too_many_arguments)]
fn step_in_workspace_planar<T>(
    ws: &mut StepWorkspace<T>,
    metrics: &mut Metrics,
    scaling: ScalingMode,
    exec: Exec<'_>,
    split: GemmSplit,
    gamma: &PlanarTensor3<T>,
    lambda: &[T],
    thresholds: &[f32],
    displacements: Option<&[(f64, f64)]>,
    samples: &mut Vec<i32>,
) -> Result<usize>
where
    T: PlanarScalar + std::ops::AddAssign + Send + Sync,
{
    let StepWorkspace {
        probs,
        mus,
        dmats,
        dmat_t,
        disp,
        penv_in,
        ptemp,
        penv_out,
        pdrow_re,
        pdrow_im,
        ..
    } = ws;
    let n = penv_in.rows;
    let pooled = matches!(exec, Exec::Pooled(_));

    let t0 = std::time::Instant::now();
    let contracted = planar_contract_env_into_on(penv_in, gamma, ptemp, exec, split);
    let dt = t0.elapsed().as_secs_f64();
    metrics.add_phase("compute", dt);
    if pooled {
        metrics.add_phase("kernel_pooled", dt);
    }
    contracted?;
    metrics.add(keys::FLOPS, matmul_flops(n, gamma.d0, gamma.d1 * gamma.d2));

    if let Some(raw_mus) = displacements {
        if raw_mus.len() != n {
            return Err(Error::shape(format!(
                "displacements: {} for N={n}",
                raw_mus.len()
            )));
        }
        metrics.time("displace", || -> Result<()> {
            mus.clear();
            mus.extend(
                raw_mus
                    .iter()
                    .map(|&(re, im)| Complex::new(T::from(re).unwrap(), T::from(im).unwrap())),
            );
            // The batched D builder stays interleaved (it is far off the
            // critical path); only the temp-tensor update is plane-wise.
            displacement_fast_batch_into(mus, gamma.d2, dmats, disp)?;
            apply_displacement_planar(ptemp, dmats, dmat_t, pdrow_re, pdrow_im);
            Ok(())
        })?;
        metrics.add(keys::FLOPS, 8 * (n * gamma.d1 * gamma.d2 * gamma.d2) as u64);
    }

    let dead = metrics.time("measure", || {
        measurement::measure_planar_into_on(
            ptemp, lambda, thresholds, scaling, exec, penv_out, samples, probs,
        )
    })?;
    metrics.add(keys::FLOPS, 8 * (n * gamma.d1 * gamma.d2) as u64);
    Ok(dead)
}

/// Apply per-sample fast displacement matrices to the temp tensor in place:
/// `temp[s, y, :] ← temp[s, y, :] · D(μ_s)`.
///
/// `dmats` is batch-last (`[(j·d + k)·n + s]`), which is ideal for the
/// builder but strides the innermost consumer loop by `n·d`; each sample's
/// D is therefore repacked once into `dmat_t` (transposed, `[k][j]`) so
/// the accumulation streams contiguously — verified against a naive
/// per-sample oracle in the tests.
fn apply_displacement<T: Float + std::ops::AddAssign>(
    temp: &mut Tensor3<T>,
    dmats: &[Complex<T>],
    dmat_t: &mut Vec<Complex<T>>,
    drow: &mut Vec<Complex<T>>,
) {
    let (n, y, d) = (temp.d0, temp.d1, temp.d2);
    dmat_t.clear();
    dmat_t.resize(d * d, Complex::zero());
    drow.clear();
    drow.resize(d, Complex::zero());
    for s in 0..n {
        for j in 0..d {
            for k in 0..d {
                dmat_t[k * d + j] = dmats[(j * d + k) * n + s];
            }
        }
        for yy in 0..y {
            let base = (s * y + yy) * d;
            drow.copy_from_slice(&temp.data[base..base + d]);
            for k in 0..d {
                let mut acc = Complex::zero();
                let dk = &dmat_t[k * d..(k + 1) * d];
                for (r, m) in drow.iter().zip(dk) {
                    acc = acc.mul_add(*r, *m);
                }
                temp.data[base + k] = acc;
            }
        }
    }
}

/// [`apply_displacement`] over split planes: the same repacked `dmat_t`
/// (interleaved — it is d·d and reloaded per sample either way) with the
/// row lane split into `drow_re`/`drow_im`. The accumulation replicates
/// `Complex::mul_add`'s exact expression per component, so the planar
/// update is bit-identical to the interleaved one.
fn apply_displacement_planar<T: Float + std::ops::AddAssign>(
    temp: &mut PlanarTensor3<T>,
    dmats: &[Complex<T>],
    dmat_t: &mut Vec<Complex<T>>,
    drow_re: &mut Vec<T>,
    drow_im: &mut Vec<T>,
) {
    let (n, y, d) = (temp.d0, temp.d1, temp.d2);
    dmat_t.clear();
    dmat_t.resize(d * d, Complex::zero());
    drow_re.clear();
    drow_re.resize(d, T::zero());
    drow_im.clear();
    drow_im.resize(d, T::zero());
    for s in 0..n {
        for j in 0..d {
            for k in 0..d {
                dmat_t[k * d + j] = dmats[(j * d + k) * n + s];
            }
        }
        for yy in 0..y {
            let base = (s * y + yy) * d;
            drow_re.copy_from_slice(&temp.re[base..base + d]);
            drow_im.copy_from_slice(&temp.im[base..base + d]);
            for k in 0..d {
                let mut acc_re = T::zero();
                let mut acc_im = T::zero();
                let dk = &dmat_t[k * d..(k + 1) * d];
                for ((&rr, &ri), m) in drow_re.iter().zip(drow_im.iter()).zip(dk) {
                    // acc = acc.mul_add(r, m) component-wise, same
                    // association: (acc + r.re·m) then the r.im term.
                    acc_re = (acc_re + rr * m.re) - ri * m.im;
                    acc_im = (acc_im + rr * m.im) + ri * m.re;
                }
                temp.re[base + k] = acc_re;
                temp.im[base + k] = acc_im;
            }
        }
    }
}

impl StepEngine for NativeEngine {
    fn step(
        &mut self,
        env: &mut SplitBuf,
        site: &Site,
        thresholds: &[f32],
        displacements: Option<&[(f64, f64)]>,
        samples: &mut Vec<i32>,
    ) -> Result<()> {
        // Compatibility path: one-shot conversion, then the prepared hot
        // path. Callers stepping one site many times should prepare once
        // and call `step_prepared` directly.
        let prepared = PreparedSite::prepare(site, self.prep_key());
        self.metrics.add(keys::STEP_PREP_CONVERSIONS, 1);
        self.step_prepared(env, &prepared, thresholds, displacements, samples)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mps::gbs::GbsSpec;
    use crate::sampler::boundary_env;
    use crate::tensor::C64;

    fn spec(decay: f64) -> GbsSpec {
        GbsSpec {
            name: "ne".into(),
            m: 10,
            d: 3,
            chi_cap: 12,
            asp: 4.0,
            decay_k: decay,
            displacement_sigma: 0.0,
            branch_skew: 0.0,
            seed: 77,
            dynamic_chi: false,
            step_ratio_override: None,
        }
    }

    fn walk(
        engine: &mut NativeEngine,
        spec: &GbsSpec,
        n: usize,
        displaced: bool,
    ) -> Vec<Vec<i32>> {
        let mps = spec.generate().unwrap();
        let mut env = boundary_env(n);
        let mut all = Vec::new();
        for (i, site) in mps.sites.iter().enumerate() {
            let th = spec.thresholds(i, 0, n);
            let mus = displaced.then(|| spec.displacement_draws(i, 0, n));
            let mut s = Vec::new();
            engine
                .step(&mut env, site, &th, mus.as_deref(), &mut s)
                .unwrap();
            all.push(s);
        }
        all
    }

    #[test]
    fn f64_and_f32_agree_without_decay() {
        let sp = spec(0.0);
        let mut e64 = NativeEngine::new(ComputePrecision::F64, ScalingMode::PerSample, 1);
        let mut e32 = NativeEngine::new(ComputePrecision::F32, ScalingMode::PerSample, 1);
        let a = walk(&mut e64, &sp, 64, false);
        let b = walk(&mut e32, &sp, 64, false);
        // Threshold knife-edges can flip a rare sample; demand 99% equality.
        let total: usize = a.iter().map(|v| v.len()).sum();
        let diff: usize = a
            .iter()
            .zip(&b)
            .map(|(x, y)| x.iter().zip(y).filter(|(p, q)| p != q).count())
            .sum();
        assert!(diff * 100 <= total, "{diff}/{total} outcomes differ");
    }

    #[test]
    fn outcomes_match_exact_marginals() {
        // Statistical Born-rule check against the transfer-matrix oracle.
        let sp = spec(0.0);
        let mps = sp.generate().unwrap();
        let ideal = crate::mps::exact::exact_mean_photons(&mps).unwrap();
        let n = 4096;
        let mut eng = NativeEngine::new(ComputePrecision::F64, ScalingMode::PerSample, 2);
        let all = walk(&mut eng, &sp, n, false);
        for (i, site_samples) in all.iter().enumerate() {
            let mean: f64 =
                site_samples.iter().map(|&s| s as f64).sum::<f64>() / n as f64;
            // Binomial-ish error bars at N=4096.
            assert!(
                (mean - ideal[i]).abs() < 0.08,
                "site {i}: sampled {mean} vs exact {}",
                ideal[i]
            );
        }
    }

    #[test]
    fn decay_with_per_sample_scaling_survives_f32() {
        // Strong decay: f32 without rescaling collapses, per-sample survives.
        let sp = spec(3.0); // 3 decades per site, 10 sites = 10^-30
        let mut good = NativeEngine::new(ComputePrecision::F32, ScalingMode::PerSample, 1);
        walk(&mut good, &sp, 32, false);
        assert_eq!(good.dead_rows, 0, "per-sample scaling must survive");

        let mut bad = NativeEngine::new(ComputePrecision::F32, ScalingMode::None, 1);
        walk(&mut bad, &sp, 32, false);
        assert!(bad.dead_rows > 0, "unscaled f32 must underflow");
    }

    #[test]
    fn scaling_does_not_change_outcomes_in_f64() {
        let sp = spec(0.5);
        let mut a = NativeEngine::new(ComputePrecision::F64, ScalingMode::PerSample, 1);
        let mut b = NativeEngine::new(ComputePrecision::F64, ScalingMode::Global, 1);
        let sa = walk(&mut a, &sp, 48, false);
        let sb = walk(&mut b, &sp, 48, false);
        assert_eq!(sa, sb, "scaling is probability-invariant in f64");
    }

    #[test]
    fn displaced_walk_runs_and_changes_outcomes() {
        let mut sp = spec(0.0);
        sp.displacement_sigma = 0.4;
        let mut eng = NativeEngine::new(ComputePrecision::F64, ScalingMode::PerSample, 1);
        let with = walk(&mut eng, &sp, 64, true);
        let mut eng2 = NativeEngine::new(ComputePrecision::F64, ScalingMode::PerSample, 1);
        let without = walk(&mut eng2, &sp, 64, false);
        assert_ne!(with, without, "displacement must change the distribution");
        // Outcomes remain valid occupations.
        assert!(with.iter().flatten().all(|&s| (0..3).contains(&s)));
    }

    #[test]
    fn tf32_close_to_f32() {
        let sp = spec(0.2);
        let mut a = NativeEngine::new(ComputePrecision::F32, ScalingMode::PerSample, 1);
        let mut b = NativeEngine::new(ComputePrecision::Tf32, ScalingMode::PerSample, 1);
        let sa = walk(&mut a, &sp, 128, false);
        let sb = walk(&mut b, &sp, 128, false);
        let total: usize = sa.iter().map(|v| v.len()).sum();
        let diff: usize = sa
            .iter()
            .zip(&sb)
            .map(|(x, y)| x.iter().zip(y).filter(|(p, q)| p != q).count())
            .sum();
        assert!(diff * 20 <= total, "{diff}/{total} tf32 outcome flips");
    }

    #[test]
    fn f16_experimental_mode_tracks_f32_on_short_chains() {
        // S3.3.1's experimental ComplexHalf arm: valid for M < 500; with
        // per-sample scaling the outcomes stay statistically close to f32.
        let sp = spec(0.1);
        let mut a = NativeEngine::new(ComputePrecision::F32, ScalingMode::PerSample, 1);
        let mut b = NativeEngine::new(ComputePrecision::F16, ScalingMode::PerSample, 1);
        let sa = walk(&mut a, &sp, 256, false);
        let sb = walk(&mut b, &sp, 256, false);
        assert_eq!(b.dead_rows, 0, "f16 + per-sample scaling must not die");
        let total: usize = sa.iter().map(|v| v.len()).sum();
        let diff: usize = sa
            .iter()
            .zip(&sb)
            .map(|(x, y)| x.iter().zip(y).filter(|(p, q)| p != q).count())
            .sum();
        // More rounding flips than tf32 but still a small fraction.
        assert!(diff * 10 <= total, "{diff}/{total} f16 outcome flips");
    }

    #[test]
    fn f16_mode_rejected_for_long_chains() {
        use crate::config::Preset;
        let mut spec = Preset::M8176.full_spec(1); // M = 8176
        spec.chi_cap = 8;
        let mut cfg = crate::config::RunConfig::new(spec);
        cfg.compute = ComputePrecision::F16;
        assert!(cfg.validate().is_err());
        cfg.compute = ComputePrecision::F32;
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn metrics_accumulate() {
        let sp = spec(0.0);
        let mut eng = NativeEngine::new(ComputePrecision::F32, ScalingMode::PerSample, 1);
        walk(&mut eng, &sp, 16, false);
        assert!(eng.metrics.get(keys::FLOPS) > 0);
        assert_eq!(eng.metrics.get(keys::SAMPLES), 160); // 16 × 10 sites
        assert_eq!(eng.metrics.get(keys::STEPS), 10);
        assert_eq!(eng.metrics.get(keys::STEP_PREP_CONVERSIONS), 10);
        assert!(eng.metrics.phase("compute") >= 0.0);
    }

    // --- prepared / workspace path -------------------------------------

    /// A square site (χ_l = χ_r) so one environment can be stepped against
    /// the same site repeatedly.
    fn square_site(chi: usize, d: usize, seed: u64) -> Site {
        let mut rng = crate::rng::Xoshiro256::seed_from(seed);
        let mut gamma = Tensor3::zeros(chi, chi, d);
        for z in &mut gamma.data {
            *z = C64::new(rng.normal() * 0.3, rng.normal() * 0.3);
        }
        Site {
            lambda: vec![1.0; chi],
            gamma,
        }
    }

    fn filled_env(n: usize, chi: usize, seed: u64) -> SplitBuf {
        let mut rng = crate::rng::Xoshiro256::seed_from(seed);
        let mut env = SplitBuf::zeros(&[n, chi]);
        for v in env.re.iter_mut().chain(env.im.iter_mut()) {
            *v = rng.normal() as f32;
        }
        env
    }

    #[test]
    fn step_and_step_prepared_sample_identically() {
        for (compute, gamma_f16) in [
            (ComputePrecision::F64, false),
            (ComputePrecision::F64, true),
            (ComputePrecision::F32, false),
            (ComputePrecision::Tf32, false),
            (ComputePrecision::F16, true),
        ] {
            let site = square_site(9, 3, 5);
            let th: Vec<f32> = (0..32).map(|i| (i as f32 + 0.5) / 32.0).collect();
            let mus: Vec<(f64, f64)> = (0..32).map(|i| (0.01 * i as f64, -0.02)).collect();

            let mut a = NativeEngine::new(compute, ScalingMode::PerSample, 1);
            a.gamma_f16 = gamma_f16;
            let mut env_a = filled_env(32, 9, 6);
            let mut s_a = Vec::new();
            a.step(&mut env_a, &site, &th, Some(&mus), &mut s_a).unwrap();

            let mut b = NativeEngine::new(compute, ScalingMode::PerSample, 1);
            b.gamma_f16 = gamma_f16;
            let prep = PreparedSite::prepare(&site, b.prep_key());
            let mut env_b = filled_env(32, 9, 6);
            let mut s_b = Vec::new();
            b.step_prepared(&mut env_b, &prep, &th, Some(&mus), &mut s_b)
                .unwrap();

            assert_eq!(s_a, s_b, "{compute:?} outcomes");
            assert_eq!(env_a, env_b, "{compute:?} environments bit-identical");
        }
    }

    #[test]
    fn prepared_key_mismatch_is_rejected() {
        let site = square_site(4, 3, 9);
        let prep = PreparedSite::prepare(
            &site,
            PrepKey {
                compute: ComputePrecision::F64,
                gamma_f16: false,
                planar: false,
            },
        );
        let mut eng = NativeEngine::new(ComputePrecision::F32, ScalingMode::PerSample, 1);
        let mut env = boundary_env(4);
        let mut s = Vec::new();
        let err = eng
            .step_prepared(&mut env, &prep, &[0.5; 4], None, &mut s)
            .unwrap_err();
        assert!(err.to_string().contains("does not match engine"), "{err}");
    }

    #[test]
    fn threaded_step_matches_single_thread_bit_identically() {
        // Row-split, bond-split, and row-parallel measure must not move a
        // single bit relative to the serial engine.
        let site = square_site(24, 3, 11);
        let th: Vec<f32> = (0..16).map(|i| (i as f32 + 0.3) / 16.0).collect();
        let mus: Vec<(f64, f64)> = (0..16).map(|i| (0.02 * i as f64, 0.01)).collect();
        let run = |threads: usize, split: GemmSplit| {
            let mut eng = NativeEngine::new(ComputePrecision::F32, ScalingMode::PerSample, threads);
            eng.split = split;
            let prep = PreparedSite::prepare(&site, eng.prep_key());
            let mut env = filled_env(16, 24, 3);
            let mut s = Vec::new();
            eng.step_prepared(&mut env, &prep, &th, Some(&mus), &mut s)
                .unwrap();
            (env, s)
        };
        let (env1, s1) = run(1, GemmSplit::Auto);
        for threads in [2, 4] {
            for split in [GemmSplit::Auto, GemmSplit::Rows, GemmSplit::Cols] {
                let (env_t, s_t) = run(threads, split);
                assert_eq!(s1, s_t, "outcomes t={threads} {split:?}");
                assert_eq!(env1, env_t, "env bits t={threads} {split:?}");
            }
        }
    }

    #[test]
    fn planar_and_pooled_steps_match_interleaved_serial_bit_identically() {
        // The tentpole contract: for every compute precision, the planar
        // layout (serial or pooled, any split) samples the same outcomes
        // and produces the same environment bits as the serial
        // interleaved engine.
        for (compute, gamma_f16) in [
            (ComputePrecision::F64, false),
            (ComputePrecision::F64, true),
            (ComputePrecision::F32, false),
            (ComputePrecision::Tf32, false),
            (ComputePrecision::F16, true),
        ] {
            let site = square_site(18, 3, 41);
            let th: Vec<f32> = (0..24).map(|i| (i as f32 + 0.4) / 24.0).collect();
            let mus: Vec<(f64, f64)> = (0..24).map(|i| (0.015 * i as f64, -0.01)).collect();
            let run = |layout: Layout, threads: usize, split: GemmSplit| {
                let mut eng = NativeEngine::new(compute, ScalingMode::PerSample, threads);
                eng.gamma_f16 = gamma_f16;
                eng.layout = layout;
                eng.split = split;
                let prep = PreparedSite::prepare(&site, eng.prep_key());
                let mut env = filled_env(24, 18, 7);
                let mut s = Vec::new();
                eng.step_prepared(&mut env, &prep, &th, Some(&mus), &mut s)
                    .unwrap();
                (env, s, eng)
            };
            let (env0, s0, eng0) = run(Layout::Interleaved, 1, GemmSplit::Auto);
            assert_eq!(eng0.metrics.get(keys::STEP_LAYOUT_PLANAR), 0);
            for threads in [1, 3] {
                for split in [GemmSplit::Auto, GemmSplit::Rows, GemmSplit::Cols] {
                    let (env_p, s_p, eng_p) = run(Layout::Planar, threads, split);
                    assert_eq!(s0, s_p, "{compute:?} outcomes t={threads} {split:?}");
                    assert_eq!(env0, env_p, "{compute:?} env bits t={threads} {split:?}");
                    assert_eq!(eng_p.metrics.get(keys::STEP_LAYOUT_PLANAR), 1);
                    if threads > 1 {
                        assert!(
                            eng_p.metrics.get(keys::POOL_WAKEUPS) > 0,
                            "pooled step must account worker wakeups"
                        );
                        assert!(eng_p.metrics.phase("kernel_pooled") >= 0.0);
                    }
                    // Interleaved pooled path agrees too.
                    let (env_i, s_i, _) = run(Layout::Interleaved, threads, split);
                    assert_eq!(s0, s_i, "{compute:?} interleaved t={threads} {split:?}");
                    assert_eq!(env0, env_i);
                }
            }
        }
    }

    #[test]
    fn auto_layout_goes_planar_for_f32_family_only() {
        for (compute, planar) in [
            (ComputePrecision::F64, false),
            (ComputePrecision::F32, true),
            (ComputePrecision::Tf32, true),
            (ComputePrecision::F16, true),
        ] {
            let eng = NativeEngine::new(compute, ScalingMode::PerSample, 1);
            assert_eq!(eng.layout, Layout::Auto);
            assert_eq!(eng.prep_key().planar, planar, "{compute:?}");
        }
    }

    #[test]
    fn steady_state_planar_pooled_step_is_allocation_free() {
        // The pooled planar hot path must hold the same zero-alloc
        // contract as the serial interleaved one: resident workers, no
        // scope spawns, arenas only reshaped. Same retry discipline as
        // `steady_state_step_is_allocation_free` (global counting
        // allocator, concurrent test threads).
        let site = square_site(12, 3, 33);
        let mut eng = NativeEngine::new(ComputePrecision::F32, ScalingMode::PerSample, 3);
        eng.layout = Layout::Planar;
        let prep = PreparedSite::prepare(&site, eng.prep_key());
        let th: Vec<f32> = (0..24).map(|i| (i as f32 + 0.5) / 24.0).collect();
        let mus: Vec<(f64, f64)> = (0..24).map(|i| (0.01 * i as f64, 0.005)).collect();
        let mut env = filled_env(24, 12, 8);
        let mut samples = Vec::new();
        for _ in 0..3 {
            eng.step_prepared(&mut env, &prep, &th, Some(&mus), &mut samples)
                .unwrap();
        }
        let grows_after_warmup = eng.metrics.get(keys::STEP_WS_GROWS);
        let mut clean = false;
        for _ in 0..128 {
            let before = crate::util::alloc::allocation_count();
            eng.step_prepared(&mut env, &prep, &th, Some(&mus), &mut samples)
                .unwrap();
            if crate::util::alloc::allocation_count() == before {
                clean = true;
                break;
            }
        }
        assert!(clean, "no allocation-free pooled planar step observed");
        assert_eq!(
            eng.metrics.get(keys::STEP_WS_GROWS),
            grows_after_warmup,
            "workspace grew after warm-up"
        );
        assert!(eng.metrics.get(keys::POOL_WAKEUPS) > 0);
    }

    #[test]
    fn displacement_repack_matches_naive_oracle() {
        // temp[s, y, :] · D(μ_s) via the repacked batch path vs a naive
        // per-sample matrix product over `displacement_fast`.
        let mut rng = crate::rng::Xoshiro256::seed_from(13);
        let (n, y, d) = (6, 4, 4);
        let mut temp: Tensor3<f64> = Tensor3::zeros(n, y, d);
        for z in &mut temp.data {
            *z = C64::new(rng.normal(), rng.normal());
        }
        let mus: Vec<(f64, f64)> = (0..n)
            .map(|_| (rng.normal() * 0.3, rng.normal() * 0.3))
            .collect();
        let naive = {
            let mut out = temp.clone();
            for s in 0..n {
                let dm =
                    crate::linalg::displacement_fast(C64::new(mus[s].0, mus[s].1), d, false)
                        .unwrap();
                for yy in 0..y {
                    let base = (s * y + yy) * d;
                    let row: Vec<C64> = temp.data[base..base + d].to_vec();
                    for k in 0..d {
                        let mut acc = C64::zero();
                        for (j, r) in row.iter().enumerate() {
                            acc += *r * dm[(j, k)];
                        }
                        out.data[base + k] = acc;
                    }
                }
            }
            out
        };
        let mut got = temp.clone();
        let mu_c: Vec<C64> = mus.iter().map(|&(re, im)| C64::new(re, im)).collect();
        let dmats = crate::linalg::displacement_fast_batch(&mu_c, d).unwrap();
        let mut dmat_t = Vec::new();
        let mut drow = Vec::new();
        apply_displacement(&mut got, &dmats, &mut dmat_t, &mut drow);
        for (g, w) in got.data.iter().zip(&naive.data) {
            assert!((*g - *w).abs() < 1e-12, "{g} vs {w}");
        }
    }

    #[test]
    fn steady_state_step_is_allocation_free() {
        // The tentpole contract: after warm-up, a single-threaded
        // step_prepared performs ZERO heap allocations — no Γ clone, no
        // re-rounding, no temp/env/displacement buffers. Flight-recorder
        // tracing must not break this: the clean window below records a
        // ring event per step exactly the way a traced worker would
        // (preallocated slots, `&'static str` names, Copy events). The
        // counting allocator is process-global and other test threads may
        // allocate concurrently, so retry until a clean window is
        // observed; a real per-step allocation would make every window
        // dirty.
        let rec = crate::trace::Recorder::new(crate::trace::DEFAULT_BUF);
        for compute in [ComputePrecision::F64, ComputePrecision::F32] {
            let site = square_site(12, 3, 21);
            let mut eng = NativeEngine::new(compute, ScalingMode::PerSample, 1);
            let prep = PreparedSite::prepare(&site, eng.prep_key());
            let th: Vec<f32> = (0..24).map(|i| (i as f32 + 0.5) / 24.0).collect();
            let mus: Vec<(f64, f64)> = (0..24).map(|i| (0.01 * i as f64, 0.005)).collect();
            let mut env = filled_env(24, 12, 8);
            let mut samples = Vec::new();
            for _ in 0..3 {
                eng.step_prepared(&mut env, &prep, &th, Some(&mus), &mut samples)
                    .unwrap();
            }
            let grows_after_warmup = eng.metrics.get(keys::STEP_WS_GROWS);
            let mut clean = false;
            for site_idx in 0..128u64 {
                let before = crate::util::alloc::allocation_count();
                // Default sampling only thins event *frequency*; the ring
                // write itself must be allocation-free, so every candidate
                // window records one — any clean window proves both.
                rec.instant(crate::trace::Layer::Engine, "site", 1, 1, site_idx);
                eng.step_prepared(&mut env, &prep, &th, Some(&mus), &mut samples)
                    .unwrap();
                if crate::util::alloc::allocation_count() == before {
                    clean = true;
                    break;
                }
            }
            assert!(crate::trace::site_sampled(0), "site 0 is always sampled");
            assert!(clean, "{compute:?}: no allocation-free step observed");
            assert_eq!(
                eng.metrics.get(keys::STEP_WS_GROWS),
                grows_after_warmup,
                "{compute:?}: workspace grew after warm-up"
            );
            let steps = eng.metrics.get(keys::STEPS) as f64;
            assert_eq!(eng.allocs_per_step(), grows_after_warmup as f64 / steps);
        }
    }

    #[test]
    fn workspace_capacities_stable_across_shapes_below_high_water() {
        // Walking a chain with varying χ must stop growing once the
        // largest site has been seen.
        let sp = spec(0.0);
        let mps = sp.generate().unwrap();
        let mut eng = NativeEngine::new(ComputePrecision::F32, ScalingMode::PerSample, 1);
        let preps: Vec<PreparedSite> = mps
            .sites
            .iter()
            .map(|s| PreparedSite::prepare(s, eng.prep_key()))
            .collect();
        let n = 32;
        let walk_once = |eng: &mut NativeEngine| {
            let mut env = boundary_env(n);
            let mut s = Vec::new();
            for (i, p) in preps.iter().enumerate() {
                let th = sp.thresholds(i, 0, n);
                eng.step_prepared(&mut env, p, &th, None, &mut s).unwrap();
            }
        };
        walk_once(&mut eng);
        let grows_first = eng.metrics.get(keys::STEP_WS_GROWS);
        walk_once(&mut eng);
        assert_eq!(
            eng.metrics.get(keys::STEP_WS_GROWS),
            grows_first,
            "second walk must not grow the workspace"
        );
        assert_eq!(eng.metrics.get(keys::STEPS), 20);
    }
}
