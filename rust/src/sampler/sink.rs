//! Sample statistics accumulator.
//!
//! Streams per-site outcomes from the coordinators and keeps what the
//! validation and benchmark layers need without storing raw samples:
//! per-site outcome histograms (→ mean photon numbers, Fig. 6/9a) and
//! near-diagonal pair sums (→ second-order correlations, Fig. 9c). A ring
//! buffer of the last `max_gap` outcome vectors provides the pair products.
//! Sinks merge across workers (data parallelism) by simple addition.

#[derive(Debug, Clone)]
pub struct SampleSink {
    pub m: usize,
    pub d: usize,
    pub max_gap: usize,
    /// hist[site][outcome] counts.
    pub hist: Vec<Vec<u64>>,
    /// pair_sums[(site_j - 1) * max_gap + (gap-1)] = Σ n_{j-gap}·n_j.
    pub pair_sums: Vec<f64>,
    /// Samples accounted per site (all sites equal unless a run aborts).
    pub counts: Vec<u64>,
    /// Rotating ring of the last `max_gap` outcome vectors for pair
    /// products: `ring[ring_head]` is the next write slot, `ring_live`
    /// slots hold vectors from the current walk. Fixed capacity — no
    /// front-shifting, no reallocation on the hot sampling path.
    ring: Vec<Vec<i32>>,
    ring_head: usize,
    ring_live: usize,
}

impl SampleSink {
    pub fn new(m: usize, d: usize, max_gap: usize) -> SampleSink {
        SampleSink {
            m,
            d,
            max_gap,
            hist: vec![vec![0; d]; m],
            pair_sums: vec![0.0; Self::pair_sum_len(m, max_gap)],
            counts: vec![0; m],
            ring: vec![Vec::new(); max_gap],
            ring_head: 0,
            ring_live: 0,
        }
    }

    /// Length of `pair_sums` for an `(m, max_gap)` sink — the single
    /// source of truth for this allocation, shared with the wire codec's
    /// pre-allocation bound (`net::frame::decode_sink`), which must count
    /// exactly these slots (note the `max_gap.max(1)`: a `max_gap == 0`
    /// sink still carries `m - 1` slots).
    pub fn pair_sum_len(m: usize, max_gap: usize) -> usize {
        m.saturating_sub(1) * max_gap.max(1)
    }

    /// Record the outcomes of one micro/macro batch at `site`. Sites must
    /// arrive in order 0..M per batch walk (the sampling order); `reset_walk`
    /// starts a new batch.
    pub fn reset_walk(&mut self) {
        // Slot allocations are kept; they are overwritten before any read
        // (only the `ring_live` most recent slots are ever dereferenced).
        self.ring_head = 0;
        self.ring_live = 0;
    }

    pub fn record(&mut self, site: usize, samples: &[i32]) {
        debug_assert!(site < self.m);
        for &s in samples {
            let s = (s.max(0) as usize).min(self.d - 1);
            self.hist[site][s] += 1;
        }
        self.counts[site] += samples.len() as u64;

        // Pair products with the previous `max_gap` sites of this walk.
        if self.max_gap > 0 {
            let cap = self.max_gap;
            if site > 0 {
                let hi_gap = cap.min(site).min(self.ring_live);
                for gap in 1..=hi_gap {
                    // gap = 1 is the most recently written slot.
                    let prev = &self.ring[(self.ring_head + cap - gap) % cap];
                    if prev.len() != samples.len() {
                        continue; // defensive: mismatched batch (shouldn't happen)
                    }
                    let sum: f64 = prev
                        .iter()
                        .zip(samples)
                        .map(|(&a, &b)| (a as f64) * (b as f64))
                        .sum();
                    self.pair_sums[(site - 1) * cap + (gap - 1)] += sum;
                }
            }
            let slot = &mut self.ring[self.ring_head];
            slot.clear();
            slot.extend_from_slice(samples);
            self.ring_head = (self.ring_head + 1) % cap;
            self.ring_live = (self.ring_live + 1).min(cap);
        }
    }

    /// Mean photon number per site.
    pub fn mean_photons(&self) -> Vec<f64> {
        self.hist
            .iter()
            .zip(&self.counts)
            .map(|(h, &c)| {
                if c == 0 {
                    0.0
                } else {
                    h.iter().enumerate().map(|(s, &n)| s as f64 * n as f64).sum::<f64>()
                        / c as f64
                }
            })
            .collect()
    }

    /// Sampled E[n_i n_j] for `(i, j = i+gap)` pairs.
    pub fn pair_moments(&self) -> Vec<(usize, usize, f64)> {
        let mut out = Vec::new();
        for j in 1..self.m {
            for gap in 1..=self.max_gap.min(j) {
                let c = self.counts[j];
                if c == 0 {
                    continue;
                }
                out.push((
                    j - gap,
                    j,
                    self.pair_sums[(j - 1) * self.max_gap + (gap - 1)] / c as f64,
                ));
            }
        }
        out
    }

    /// Merge a worker's sink (data-parallel reduction).
    pub fn merge(&mut self, other: &SampleSink) {
        assert_eq!(self.m, other.m);
        assert_eq!(self.d, other.d);
        assert_eq!(self.max_gap, other.max_gap);
        for (a, b) in self.hist.iter_mut().zip(&other.hist) {
            for (x, y) in a.iter_mut().zip(b) {
                *x += *y;
            }
        }
        for (a, b) in self.pair_sums.iter_mut().zip(&other.pair_sums) {
            *a += *b;
        }
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += *b;
        }
    }

    pub fn total_samples(&self) -> u64 {
        self.counts.first().copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_and_mean() {
        let mut s = SampleSink::new(2, 3, 1);
        s.reset_walk();
        s.record(0, &[0, 1, 2, 2]);
        s.record(1, &[1, 1, 1, 1]);
        assert_eq!(s.hist[0], vec![1, 1, 2]);
        let m = s.mean_photons();
        assert!((m[0] - 1.25).abs() < 1e-12);
        assert!((m[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pair_moments_adjacent() {
        let mut s = SampleSink::new(3, 3, 2);
        s.reset_walk();
        s.record(0, &[1, 2]);
        s.record(1, &[2, 0]);
        s.record(2, &[1, 1]);
        let pm = s.pair_moments();
        // E[n0 n1] = (1·2 + 2·0)/2 = 1; E[n1 n2] = (2+0)/2 = 1; E[n0 n2] = (1+2)/2 = 1.5.
        let get = |i: usize, j: usize| pm.iter().find(|&&(a, b, _)| a == i && b == j).unwrap().2;
        assert!((get(0, 1) - 1.0).abs() < 1e-12);
        assert!((get(1, 2) - 1.0).abs() < 1e-12);
        assert!((get(0, 2) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn multiple_walks_accumulate() {
        let mut s = SampleSink::new(2, 2, 1);
        for _ in 0..3 {
            s.reset_walk();
            s.record(0, &[1]);
            s.record(1, &[1]);
        }
        assert_eq!(s.counts, vec![3, 3]);
        let pm = s.pair_moments();
        assert!((pm[0].2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn merge_adds() {
        let mut a = SampleSink::new(2, 2, 1);
        a.reset_walk();
        a.record(0, &[0, 1]);
        a.record(1, &[1, 1]);
        let mut b = a.clone();
        b.reset_walk();
        b.record(0, &[1, 1]);
        b.record(1, &[0, 0]);
        a.merge(&b);
        // b started as a clone of a (2 samples) and recorded 2 more.
        assert_eq!(a.counts[0], 6);
        assert_eq!(a.hist[0], vec![2, 4]);
    }

    #[test]
    fn out_of_range_outcomes_clamped() {
        let mut s = SampleSink::new(1, 2, 0);
        s.record(0, &[-3, 9]);
        assert_eq!(s.hist[0], vec![1, 1]);
    }

    #[test]
    fn rotating_ring_matches_naive_window_reference() {
        use crate::util::prop::{quickcheck, Gen};

        // The pre-ring reference: a growing window shifted from the front
        // (`Vec::remove(0)`) — the semantics the O(1) rotating ring must
        // preserve exactly, bit-for-bit.
        #[allow(clippy::type_complexity)]
        fn naive(
            m: usize,
            d: usize,
            gap: usize,
            walks: &[Vec<Vec<i32>>],
        ) -> (Vec<Vec<u64>>, Vec<f64>, Vec<u64>) {
            let mut hist = vec![vec![0u64; d]; m];
            let mut counts = vec![0u64; m];
            let mut pair = vec![0.0; SampleSink::pair_sum_len(m, gap)];
            for walk in walks {
                let mut window: Vec<&Vec<i32>> = Vec::new();
                for (site, samples) in walk.iter().enumerate() {
                    for &s in samples {
                        hist[site][(s.max(0) as usize).min(d - 1)] += 1;
                    }
                    counts[site] += samples.len() as u64;
                    if gap > 0 && site > 0 {
                        let hi = gap.min(site).min(window.len());
                        for g in 1..=hi {
                            let prev = window[window.len() - g];
                            let sum: f64 = prev
                                .iter()
                                .zip(samples)
                                .map(|(&a, &b)| a as f64 * b as f64)
                                .sum();
                            pair[(site - 1) * gap + (g - 1)] += sum;
                        }
                    }
                    if gap > 0 {
                        window.push(samples);
                        if window.len() > gap {
                            window.remove(0);
                        }
                    }
                }
            }
            (hist, pair, counts)
        }

        fn random_walks(g: &mut Gen, m: usize, d: usize) -> Vec<Vec<Vec<i32>>> {
            (0..g.usize_in(1, 3))
                .map(|_| {
                    let n = g.usize_in(1, 5);
                    (0..m)
                        .map(|_| (0..n).map(|_| g.usize_in(0, d) as i32).collect())
                        .collect()
                })
                .collect()
        }

        quickcheck("rotating ring == naive window", |g| {
            let m = g.usize_in(1, 7);
            let d = g.usize_in(2, 4);
            let gap = g.usize_in(0, 5);
            let walks = random_walks(g, m, d);
            let mut s = SampleSink::new(m, d, gap);
            for walk in &walks {
                s.reset_walk();
                for (site, samples) in walk.iter().enumerate() {
                    s.record(site, samples);
                }
            }
            let (hist, pair, counts) = naive(m, d, gap, &walks);
            if s.hist != hist {
                return Err(format!("hist diverged at m={m} d={d} gap={gap}"));
            }
            if s.pair_sums != pair {
                return Err(format!("pair_sums diverged at m={m} d={d} gap={gap}"));
            }
            if s.counts != counts {
                return Err(format!("counts diverged at m={m} d={d} gap={gap}"));
            }
            Ok(())
        });
    }

    #[test]
    fn ring_capacity_fixed_and_pair_len_helper_is_truth() {
        let mut s = SampleSink::new(5, 3, 2);
        assert_eq!(s.pair_sums.len(), SampleSink::pair_sum_len(5, 2));
        assert_eq!(
            SampleSink::new(5, 3, 0).pair_sums.len(),
            SampleSink::pair_sum_len(5, 0)
        );
        assert_eq!(
            SampleSink::pair_sum_len(5, 0),
            4,
            "max_gap 0 still allocates (m-1) slots"
        );
        assert_eq!(SampleSink::pair_sum_len(1, 3), 0);
        for _ in 0..3 {
            s.reset_walk();
            for site in 0..5 {
                s.record(site, &[1, 2, 0]);
            }
            assert_eq!(s.ring.len(), 2, "ring capacity fixed at max_gap");
        }
    }

    #[test]
    fn property_merge_is_associative_and_commutative() {
        // Data-parallel reductions merge worker sinks in whatever order the
        // threads finish; the result must not depend on that order. All
        // accumulated quantities are integer-valued (counts and products of
        // small ints summed in f64), so even the f64 pair sums are exact
        // and the laws hold exactly.
        use crate::util::prop::{quickcheck, Gen};

        fn random_sink(g: &mut Gen, m: usize, d: usize, gap: usize) -> SampleSink {
            let mut s = SampleSink::new(m, d, gap);
            for _ in 0..g.usize_in(1, 4) {
                s.reset_walk();
                let n = g.usize_in(1, 6);
                for site in 0..m {
                    let outcomes: Vec<i32> =
                        (0..n).map(|_| g.usize_in(0, d) as i32).collect();
                    s.record(site, &outcomes);
                }
            }
            s
        }

        fn key(s: &SampleSink) -> (Vec<Vec<u64>>, Vec<f64>, Vec<u64>) {
            (s.hist.clone(), s.pair_sums.clone(), s.counts.clone())
        }

        quickcheck("sink merge laws", |g| {
            let m = g.usize_in(2, 6);
            let d = g.usize_in(2, 4);
            let gap = g.usize_in(0, 4);
            let a = random_sink(g, m, d, gap);
            let b = random_sink(g, m, d, gap);
            let c = random_sink(g, m, d, gap);

            // (a ⊕ b) ⊕ c
            let mut left = a.clone();
            left.merge(&b);
            left.merge(&c);
            // a ⊕ (b ⊕ c)
            let mut bc = b.clone();
            bc.merge(&c);
            let mut right = a.clone();
            right.merge(&bc);
            if key(&left) != key(&right) {
                return Err(format!("associativity broke at m={m} d={d} gap={gap}"));
            }

            // a ⊕ b == b ⊕ a
            let mut ab = a.clone();
            ab.merge(&b);
            let mut ba = b.clone();
            ba.merge(&a);
            if key(&ab) != key(&ba) {
                return Err(format!("commutativity broke at m={m} d={d} gap={gap}"));
            }

            // Identity: merging a fresh sink changes nothing.
            let mut id = a.clone();
            id.merge(&SampleSink::new(m, d, gap));
            if key(&id) != key(&a) {
                return Err("identity broke".into());
            }
            Ok(())
        });
    }

    #[test]
    fn property_merge_laws_hold_at_qubit_dimension() {
        // Qubit workloads run the same sink at d=2: a two-bin histogram per
        // site, outcomes in {0, 1}. The merge laws must hold there exactly —
        // the data-parallel reduction is workload-agnostic by design.
        use crate::util::prop::{quickcheck, Gen};

        fn random_qubit_sink(g: &mut Gen, m: usize, gap: usize) -> SampleSink {
            let mut s = SampleSink::new(m, 2, gap);
            for _ in 0..g.usize_in(1, 4) {
                s.reset_walk();
                let n = g.usize_in(1, 6);
                for site in 0..m {
                    let bits: Vec<i32> = (0..n).map(|_| g.usize_in(0, 2) as i32).collect();
                    s.record(site, &bits);
                }
            }
            s
        }

        quickcheck("qubit sink merge laws", |g| {
            let m = g.usize_in(2, 6);
            let gap = g.usize_in(0, 3);
            let a = random_qubit_sink(g, m, gap);
            let b = random_qubit_sink(g, m, gap);

            let mut ab = a.clone();
            ab.merge(&b);
            let mut ba = b.clone();
            ba.merge(&a);
            if (ab.hist, ab.pair_sums, ab.counts) != (ba.hist, ba.pair_sums, ba.counts) {
                return Err(format!("qubit merge commutativity broke at m={m} gap={gap}"));
            }

            // The alphabet stays binary through merges and every recorded
            // outcome landed in one of the two bins.
            let mut total = a.clone();
            total.merge(&b);
            for (site, h) in total.hist.iter().enumerate() {
                if h.len() != 2 {
                    return Err(format!("site {site} histogram is not binary"));
                }
                if h[0] + h[1] != total.counts[site] {
                    return Err(format!("site {site} lost outcomes in merge"));
                }
            }
            Ok(())
        });
    }
}
