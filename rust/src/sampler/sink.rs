//! Sample statistics accumulator.
//!
//! Streams per-site outcomes from the coordinators and keeps what the
//! validation and benchmark layers need without storing raw samples:
//! per-site outcome histograms (→ mean photon numbers, Fig. 6/9a) and
//! near-diagonal pair sums (→ second-order correlations, Fig. 9c). A ring
//! buffer of the last `max_gap` outcome vectors provides the pair products.
//! Sinks merge across workers (data parallelism) by simple addition.

#[derive(Debug, Clone)]
pub struct SampleSink {
    pub m: usize,
    pub d: usize,
    pub max_gap: usize,
    /// hist[site][outcome] counts.
    pub hist: Vec<Vec<u64>>,
    /// pair_sums[(site_j - 1) * max_gap + (gap-1)] = Σ n_{j-gap}·n_j.
    pub pair_sums: Vec<f64>,
    /// Samples accounted per site (all sites equal unless a run aborts).
    pub counts: Vec<u64>,
    /// Ring of recent outcome vectors for pair products.
    ring: Vec<Vec<i32>>,
    ring_site: usize,
}

impl SampleSink {
    pub fn new(m: usize, d: usize, max_gap: usize) -> SampleSink {
        SampleSink {
            m,
            d,
            max_gap,
            hist: vec![vec![0; d]; m],
            pair_sums: vec![0.0; m.saturating_sub(1) * max_gap.max(1)],
            counts: vec![0; m],
            ring: Vec::new(),
            ring_site: 0,
        }
    }

    /// Record the outcomes of one micro/macro batch at `site`. Sites must
    /// arrive in order 0..M per batch walk (the sampling order); `reset_walk`
    /// starts a new batch.
    pub fn reset_walk(&mut self) {
        self.ring.clear();
        self.ring_site = 0;
    }

    pub fn record(&mut self, site: usize, samples: &[i32]) {
        debug_assert!(site < self.m);
        for &s in samples {
            let s = (s.max(0) as usize).min(self.d - 1);
            self.hist[site][s] += 1;
        }
        self.counts[site] += samples.len() as u64;

        // Pair products with the previous `max_gap` sites of this walk.
        if self.max_gap > 0 && site > 0 {
            let lo_gap = 1usize;
            let hi_gap = self.max_gap.min(site).min(self.ring.len());
            for gap in lo_gap..=hi_gap {
                let prev = &self.ring[self.ring.len() - gap];
                if prev.len() != samples.len() {
                    continue; // defensive: mismatched batch (shouldn't happen)
                }
                let sum: f64 = prev
                    .iter()
                    .zip(samples)
                    .map(|(&a, &b)| (a as f64) * (b as f64))
                    .sum();
                self.pair_sums[(site - 1) * self.max_gap + (gap - 1)] += sum;
            }
        }
        if self.max_gap > 0 {
            self.ring.push(samples.to_vec());
            if self.ring.len() > self.max_gap {
                self.ring.remove(0);
            }
        }
        self.ring_site = site;
    }

    /// Mean photon number per site.
    pub fn mean_photons(&self) -> Vec<f64> {
        self.hist
            .iter()
            .zip(&self.counts)
            .map(|(h, &c)| {
                if c == 0 {
                    0.0
                } else {
                    h.iter().enumerate().map(|(s, &n)| s as f64 * n as f64).sum::<f64>()
                        / c as f64
                }
            })
            .collect()
    }

    /// Sampled E[n_i n_j] for `(i, j = i+gap)` pairs.
    pub fn pair_moments(&self) -> Vec<(usize, usize, f64)> {
        let mut out = Vec::new();
        for j in 1..self.m {
            for gap in 1..=self.max_gap.min(j) {
                let c = self.counts[j];
                if c == 0 {
                    continue;
                }
                out.push((
                    j - gap,
                    j,
                    self.pair_sums[(j - 1) * self.max_gap + (gap - 1)] / c as f64,
                ));
            }
        }
        out
    }

    /// Merge a worker's sink (data-parallel reduction).
    pub fn merge(&mut self, other: &SampleSink) {
        assert_eq!(self.m, other.m);
        assert_eq!(self.d, other.d);
        assert_eq!(self.max_gap, other.max_gap);
        for (a, b) in self.hist.iter_mut().zip(&other.hist) {
            for (x, y) in a.iter_mut().zip(b) {
                *x += *y;
            }
        }
        for (a, b) in self.pair_sums.iter_mut().zip(&other.pair_sums) {
            *a += *b;
        }
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += *b;
        }
    }

    pub fn total_samples(&self) -> u64 {
        self.counts.first().copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_and_mean() {
        let mut s = SampleSink::new(2, 3, 1);
        s.reset_walk();
        s.record(0, &[0, 1, 2, 2]);
        s.record(1, &[1, 1, 1, 1]);
        assert_eq!(s.hist[0], vec![1, 1, 2]);
        let m = s.mean_photons();
        assert!((m[0] - 1.25).abs() < 1e-12);
        assert!((m[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pair_moments_adjacent() {
        let mut s = SampleSink::new(3, 3, 2);
        s.reset_walk();
        s.record(0, &[1, 2]);
        s.record(1, &[2, 0]);
        s.record(2, &[1, 1]);
        let pm = s.pair_moments();
        // E[n0 n1] = (1·2 + 2·0)/2 = 1; E[n1 n2] = (2+0)/2 = 1; E[n0 n2] = (1+2)/2 = 1.5.
        let get = |i: usize, j: usize| pm.iter().find(|&&(a, b, _)| a == i && b == j).unwrap().2;
        assert!((get(0, 1) - 1.0).abs() < 1e-12);
        assert!((get(1, 2) - 1.0).abs() < 1e-12);
        assert!((get(0, 2) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn multiple_walks_accumulate() {
        let mut s = SampleSink::new(2, 2, 1);
        for _ in 0..3 {
            s.reset_walk();
            s.record(0, &[1]);
            s.record(1, &[1]);
        }
        assert_eq!(s.counts, vec![3, 3]);
        let pm = s.pair_moments();
        assert!((pm[0].2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn merge_adds() {
        let mut a = SampleSink::new(2, 2, 1);
        a.reset_walk();
        a.record(0, &[0, 1]);
        a.record(1, &[1, 1]);
        let mut b = a.clone();
        b.reset_walk();
        b.record(0, &[1, 1]);
        b.record(1, &[0, 0]);
        a.merge(&b);
        // b started as a clone of a (2 samples) and recorded 2 more.
        assert_eq!(a.counts[0], 6);
        assert_eq!(a.hist[0], vec![2, 4]);
    }

    #[test]
    fn out_of_range_outcomes_clamped() {
        let mut s = SampleSink::new(1, 2, 0);
        s.record(0, &[-3, 9]);
        assert_eq!(s.hist[0], vec![1, 1]);
    }

    #[test]
    fn property_merge_is_associative_and_commutative() {
        // Data-parallel reductions merge worker sinks in whatever order the
        // threads finish; the result must not depend on that order. All
        // accumulated quantities are integer-valued (counts and products of
        // small ints summed in f64), so even the f64 pair sums are exact
        // and the laws hold exactly.
        use crate::util::prop::{quickcheck, Gen};

        fn random_sink(g: &mut Gen, m: usize, d: usize, gap: usize) -> SampleSink {
            let mut s = SampleSink::new(m, d, gap);
            for _ in 0..g.usize_in(1, 4) {
                s.reset_walk();
                let n = g.usize_in(1, 6);
                for site in 0..m {
                    let outcomes: Vec<i32> =
                        (0..n).map(|_| g.usize_in(0, d) as i32).collect();
                    s.record(site, &outcomes);
                }
            }
            s
        }

        fn key(s: &SampleSink) -> (Vec<Vec<u64>>, Vec<f64>, Vec<u64>) {
            (s.hist.clone(), s.pair_sums.clone(), s.counts.clone())
        }

        quickcheck("sink merge laws", |g| {
            let m = g.usize_in(2, 6);
            let d = g.usize_in(2, 4);
            let gap = g.usize_in(0, 4);
            let a = random_sink(g, m, d, gap);
            let b = random_sink(g, m, d, gap);
            let c = random_sink(g, m, d, gap);

            // (a ⊕ b) ⊕ c
            let mut left = a.clone();
            left.merge(&b);
            left.merge(&c);
            // a ⊕ (b ⊕ c)
            let mut bc = b.clone();
            bc.merge(&c);
            let mut right = a.clone();
            right.merge(&bc);
            if key(&left) != key(&right) {
                return Err(format!("associativity broke at m={m} d={d} gap={gap}"));
            }

            // a ⊕ b == b ⊕ a
            let mut ab = a.clone();
            ab.merge(&b);
            let mut ba = b.clone();
            ba.merge(&a);
            if key(&ab) != key(&ba) {
                return Err(format!("commutativity broke at m={m} d={d} gap={gap}"));
            }

            // Identity: merging a fresh sink changes nothing.
            let mut id = a.clone();
            id.merge(&SampleSink::new(m, d, gap));
            if key(&id) != key(&a) {
                return Err("identity broke".into());
            }
            Ok(())
        });
    }
}
