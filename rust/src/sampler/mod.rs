//! The sampling engines: the per-site step (Fig. 1 / Alg. 1) over a batch
//! of samples.
//!
//! Two engines implement [`StepEngine`]:
//! - [`native::NativeEngine`] — rust compute at f64/f32/TF32-emulated
//!   precision with selectable scaling strategy. The correctness oracle and
//!   the precision-study workhorse (Figs. 5/6).
//! - [`crate::runtime::XlaEngine`] — the production hot path: executes the
//!   AOT-lowered Pallas/JAX step artifacts through PJRT.
//!
//! Both consume the same inputs (Γ site, Λ, thresholds, displacement draws)
//! and produce the next left environment plus collapsed outcomes, so they
//! are interchangeable under the coordinators.

pub mod env;
pub mod measurement;
pub mod native;
pub mod prepared;
pub mod sink;

pub use prepared::{PrepKey, PreparedGamma, PreparedSite, PreparedStore};

use crate::mps::Site;
use crate::tensor::SplitBuf;
use crate::util::error::Result;

/// A batch step executor. `env` is the (N, χ_l) split-plane left
/// environment; on success it becomes the (N, χ_r) environment after the
/// site, and `samples` receives the N collapsed outcomes.
pub trait StepEngine {
    fn step(
        &mut self,
        env: &mut SplitBuf,
        site: &Site,
        thresholds: &[f32],
        displacements: Option<&[(f64, f64)]>,
        samples: &mut Vec<i32>,
    ) -> Result<()>;

    /// Human-readable engine id for logs/metrics.
    fn name(&self) -> &'static str;
}

/// Initial left environment: ones at the single boundary bond.
pub fn boundary_env(n: usize) -> SplitBuf {
    let mut e = SplitBuf::zeros(&[n, 1]);
    for v in &mut e.re {
        *v = 1.0;
    }
    e
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boundary_env_is_ones() {
        let e = boundary_env(4);
        assert_eq!(e.shape, vec![4, 1]);
        assert!(e.re.iter().all(|&x| x == 1.0));
        assert!(e.im.iter().all(|&x| x == 0.0));
    }
}
