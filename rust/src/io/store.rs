//! On-disk MPS store ("FMPS1").
//!
//! Layout:
//! ```text
//! <dir>/manifest.json      — format/version, spec echo, per-site shapes,
//!                            precision, codec, blob sizes
//! <dir>/site_<i>.bin       — Γ_i as interleaved (re, im) pairs, row-major
//!                            (χ_l, χ_r, d), in the manifest precision,
//!                            optionally LZ-compressed (`util::compress`)
//! ```
//!
//! FP16 blobs implement §3.3.2: stored/moved at half width, converted back
//! to f32/f64 before contraction (precision is *not* recovered — that loss
//! is part of the design and is what the precision tests measure).

use std::fs;
use std::path::{Path, PathBuf};

use crate::mps::gbs::GbsSpec;
use crate::mps::{Mps, Site};
use crate::tensor::{Complex, Tensor3, C64};
use crate::util::compress;
use crate::util::error::{Error, Result};
use crate::util::f16;
use crate::util::json::Json;

/// Element precision of the stored blobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StorePrecision {
    F64,
    F32,
    F16,
}

impl StorePrecision {
    pub fn bytes_per_scalar(self) -> usize {
        match self {
            StorePrecision::F64 => 8,
            StorePrecision::F32 => 4,
            StorePrecision::F16 => 2,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            StorePrecision::F64 => "f64",
            StorePrecision::F32 => "f32",
            StorePrecision::F16 => "f16",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f64" => Ok(StorePrecision::F64),
            "f32" => Ok(StorePrecision::F32),
            "f16" => Ok(StorePrecision::F16),
            _ => Err(Error::config(format!("unknown precision '{s}'"))),
        }
    }
}

/// Blob compression. `Lz` is the built-in LZ77 codec ([`compress`]); the
/// string "zstd" is accepted as a legacy alias for it (the offline build
/// has no zstd crate, and no stores were ever written with real zstd).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreCodec {
    Raw,
    Lz,
}

impl StoreCodec {
    pub fn as_str(self) -> &'static str {
        match self {
            StoreCodec::Raw => "raw",
            StoreCodec::Lz => "lz",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "raw" => Ok(StoreCodec::Raw),
            "lz" | "zstd" => Ok(StoreCodec::Lz),
            _ => Err(Error::config(format!("unknown codec '{s}'"))),
        }
    }
}

/// An opened on-disk MPS.
#[derive(Debug, Clone)]
pub struct GammaStore {
    pub dir: PathBuf,
    pub spec: GbsSpec,
    pub precision: StorePrecision,
    pub codec: StoreCodec,
    /// (χ_l, χ_r) per site.
    pub bonds: Vec<(usize, usize)>,
    /// Compressed blob size per site (bytes actually read from disk).
    pub blob_bytes: Vec<u64>,
}

impl GammaStore {
    /// Generate the MPS from `spec` and write it site-by-site (streaming:
    /// only one site is in memory at a time).
    pub fn create(
        dir: &Path,
        spec: &GbsSpec,
        precision: StorePrecision,
        codec: StoreCodec,
    ) -> Result<GammaStore> {
        fs::create_dir_all(dir).map_err(|e| Error::io(dir.display(), e))?;
        let plan = spec.chi_plan();
        let mut bonds = Vec::with_capacity(spec.m);
        let mut blob_bytes = Vec::with_capacity(spec.m);
        let mut chi_l = 1usize;
        for i in 0..spec.m {
            let site = spec.generate_site(i, chi_l, &plan)?;
            let blob = encode_site(&site.gamma, precision, codec)?;
            let path = site_path(dir, i);
            fs::write(&path, &blob).map_err(|e| Error::io(path.display(), e))?;
            bonds.push((chi_l, site.chi_r()));
            blob_bytes.push(blob.len() as u64);
            chi_l = site.chi_r();
        }
        let store = GammaStore {
            dir: dir.to_path_buf(),
            spec: spec.clone(),
            precision,
            codec,
            bonds,
            blob_bytes,
        };
        store.write_manifest()?;
        Ok(store)
    }

    /// Write an already-materialized MPS (tests / conversions).
    pub fn create_from_mps(
        dir: &Path,
        spec: &GbsSpec,
        mps: &Mps,
        precision: StorePrecision,
        codec: StoreCodec,
    ) -> Result<GammaStore> {
        fs::create_dir_all(dir).map_err(|e| Error::io(dir.display(), e))?;
        let mut bonds = Vec::new();
        let mut blob_bytes = Vec::new();
        for (i, site) in mps.sites.iter().enumerate() {
            let blob = encode_site(&site.gamma, precision, codec)?;
            let path = site_path(dir, i);
            fs::write(&path, &blob).map_err(|e| Error::io(path.display(), e))?;
            bonds.push((site.chi_l(), site.chi_r()));
            blob_bytes.push(blob.len() as u64);
        }
        let store = GammaStore {
            dir: dir.to_path_buf(),
            spec: spec.clone(),
            precision,
            codec,
            bonds,
            blob_bytes,
        };
        store.write_manifest()?;
        Ok(store)
    }

    pub fn open(dir: &Path) -> Result<GammaStore> {
        let mpath = dir.join("manifest.json");
        let text = fs::read_to_string(&mpath).map_err(|e| Error::io(mpath.display(), e))?;
        let j = Json::parse(&text)?;
        if j.req("magic")?.as_str() != Some("FMPS1") {
            return Err(Error::format("bad magic (want FMPS1)"));
        }
        let spec = spec_from_json(j.req("spec")?)?;
        let precision = StorePrecision::parse(
            j.req("precision")?
                .as_str()
                .ok_or_else(|| Error::format("precision not a string"))?,
        )?;
        let codec = StoreCodec::parse(
            j.req("codec")?
                .as_str()
                .ok_or_else(|| Error::format("codec not a string"))?,
        )?;
        let bonds: Vec<(usize, usize)> = j
            .req("bonds")?
            .as_arr()
            .ok_or_else(|| Error::format("bonds not an array"))?
            .iter()
            .map(|b| {
                let pair = b.as_arr().ok_or_else(|| Error::format("bond not a pair"))?;
                Ok((
                    pair[0].as_usize().ok_or_else(|| Error::format("bond[0]"))?,
                    pair[1].as_usize().ok_or_else(|| Error::format("bond[1]"))?,
                ))
            })
            .collect::<Result<_>>()?;
        let blob_bytes: Vec<u64> = j
            .req("blob_bytes")?
            .as_arr()
            .ok_or_else(|| Error::format("blob_bytes not an array"))?
            .iter()
            .map(|b| {
                b.as_f64()
                    .map(|v| v as u64)
                    .ok_or_else(|| Error::format("blob size"))
            })
            .collect::<Result<_>>()?;
        if bonds.len() != spec.m || blob_bytes.len() != spec.m {
            return Err(Error::format("manifest site count mismatch"));
        }
        Ok(GammaStore {
            dir: dir.to_path_buf(),
            spec,
            precision,
            codec,
            bonds,
            blob_bytes,
        })
    }

    fn write_manifest(&self) -> Result<()> {
        let j = Json::obj(vec![
            ("magic", Json::Str("FMPS1".into())),
            ("version", Json::Num(1.0)),
            ("precision", Json::Str(self.precision.as_str().into())),
            ("codec", Json::Str(self.codec.as_str().into())),
            ("spec", spec_to_json(&self.spec)),
            (
                "bonds",
                Json::Arr(
                    self.bonds
                        .iter()
                        .map(|&(l, r)| {
                            Json::Arr(vec![Json::Num(l as f64), Json::Num(r as f64)])
                        })
                        .collect(),
                ),
            ),
            (
                "blob_bytes",
                Json::Arr(
                    self.blob_bytes
                        .iter()
                        .map(|&b| Json::Num(b as f64))
                        .collect(),
                ),
            ),
        ]);
        let path = self.dir.join("manifest.json");
        fs::write(&path, j.pretty()).map_err(|e| Error::io(path.display(), e))
    }

    pub fn num_sites(&self) -> usize {
        self.spec.m
    }

    /// FNV-1a hash of the manifest bytes — the identity key the service's
    /// `StoreCache` uses, so the same store reached through two paths (or
    /// symlinks) shares one cached entry, while a regenerated store gets a
    /// fresh one.
    pub fn manifest_hash(&self) -> Result<u64> {
        manifest_hash_at(&self.dir)
    }

    /// Bytes on disk for site `i` (what the disk model charges).
    pub fn site_bytes(&self, i: usize) -> u64 {
        self.blob_bytes[i]
    }

    pub fn total_bytes(&self) -> u64 {
        self.blob_bytes.iter().sum()
    }

    /// Load one site. The Λ vector is reconstructed as all-ones (the store
    /// keeps right-canonical states; a future version can persist Λ).
    pub fn load_site(&self, i: usize) -> Result<Site> {
        if i >= self.spec.m {
            return Err(Error::shape(format!("site {i} ≥ M={}", self.spec.m)));
        }
        let path = site_path(&self.dir, i);
        let blob = fs::read(&path).map_err(|e| Error::io(path.display(), e))?;
        let (chi_l, chi_r) = self.bonds[i];
        let gamma = decode_site(&blob, chi_l, chi_r, self.spec.d, self.precision, self.codec)?;
        Ok(Site {
            lambda: vec![1.0; chi_r],
            gamma,
        })
    }

    /// Load the full chain (small scales only).
    pub fn load_all(&self) -> Result<Mps> {
        let sites = (0..self.spec.m)
            .map(|i| self.load_site(i))
            .collect::<Result<Vec<_>>>()?;
        let mps = Mps {
            sites,
            d: self.spec.d,
        };
        mps.check()?;
        Ok(mps)
    }
}

fn site_path(dir: &Path, i: usize) -> PathBuf {
    dir.join(format!("site_{i:05}.bin"))
}

/// FNV-1a over the manifest file of the store at `dir` (see
/// [`GammaStore::manifest_hash`]).
pub fn manifest_hash_at(dir: &Path) -> Result<u64> {
    let path = dir.join("manifest.json");
    let bytes = fs::read(&path).map_err(|e| Error::io(path.display(), e))?;
    Ok(crate::util::fnv1a(&bytes))
}

fn encode_site(g: &Tensor3<f64>, precision: StorePrecision, codec: StoreCodec) -> Result<Vec<u8>> {
    let mut raw: Vec<u8> = Vec::with_capacity(g.len() * 2 * precision.bytes_per_scalar());
    match precision {
        StorePrecision::F64 => {
            for z in &g.data {
                raw.extend_from_slice(&z.re.to_le_bytes());
                raw.extend_from_slice(&z.im.to_le_bytes());
            }
        }
        StorePrecision::F32 => {
            for z in &g.data {
                raw.extend_from_slice(&(z.re as f32).to_le_bytes());
                raw.extend_from_slice(&(z.im as f32).to_le_bytes());
            }
        }
        StorePrecision::F16 => {
            for z in &g.data {
                raw.extend_from_slice(&f16::f32_to_f16_bits(z.re as f32).to_le_bytes());
                raw.extend_from_slice(&f16::f32_to_f16_bits(z.im as f32).to_le_bytes());
            }
        }
    }
    match codec {
        StoreCodec::Raw => Ok(raw),
        StoreCodec::Lz => Ok(compress::compress(&raw)),
    }
}

fn decode_site(
    blob: &[u8],
    chi_l: usize,
    chi_r: usize,
    d: usize,
    precision: StorePrecision,
    codec: StoreCodec,
) -> Result<Tensor3<f64>> {
    let raw: Vec<u8> = match codec {
        StoreCodec::Raw => blob.to_vec(),
        StoreCodec::Lz => compress::decompress(blob).map_err(Error::format)?,
    };
    let n = chi_l * chi_r * d;
    let want = n * 2 * precision.bytes_per_scalar();
    if raw.len() != want {
        return Err(Error::format(format!(
            "site blob: {} bytes, expected {want} for ({chi_l},{chi_r},{d}) {}",
            raw.len(),
            precision.as_str()
        )));
    }
    let mut data = Vec::with_capacity(n);
    match precision {
        StorePrecision::F64 => {
            for c in raw.chunks_exact(16) {
                let re = f64::from_le_bytes(c[0..8].try_into().unwrap());
                let im = f64::from_le_bytes(c[8..16].try_into().unwrap());
                data.push(C64::new(re, im));
            }
        }
        StorePrecision::F32 => {
            for c in raw.chunks_exact(8) {
                let re = f32::from_le_bytes(c[0..4].try_into().unwrap());
                let im = f32::from_le_bytes(c[4..8].try_into().unwrap());
                data.push(C64::new(re as f64, im as f64));
            }
        }
        StorePrecision::F16 => {
            for c in raw.chunks_exact(4) {
                let re = f16::f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]]));
                let im = f16::f16_bits_to_f32(u16::from_le_bytes([c[2], c[3]]));
                data.push(Complex::new(re as f64, im as f64));
            }
        }
    }
    Tensor3::from_vec(chi_l, chi_r, d, data)
}

fn spec_to_json(s: &GbsSpec) -> Json {
    Json::obj(vec![
        ("name", Json::Str(s.name.clone())),
        ("m", Json::Num(s.m as f64)),
        ("d", Json::Num(s.d as f64)),
        ("chi_cap", Json::Num(s.chi_cap as f64)),
        ("asp", Json::Num(s.asp)),
        ("decay_k", Json::Num(s.decay_k)),
        ("displacement_sigma", Json::Num(s.displacement_sigma)),
        ("branch_skew", Json::Num(s.branch_skew)),
        ("seed", Json::Num(s.seed as f64)),
        ("dynamic_chi", Json::Bool(s.dynamic_chi)),
        (
            "step_ratio_override",
            s.step_ratio_override.map(Json::Num).unwrap_or(Json::Null),
        ),
    ])
}

fn spec_from_json(j: &Json) -> Result<GbsSpec> {
    Ok(GbsSpec {
        name: j
            .req("name")?
            .as_str()
            .ok_or_else(|| Error::format("spec.name"))?
            .to_string(),
        m: j.req("m")?.as_usize().ok_or_else(|| Error::format("spec.m"))?,
        d: j.req("d")?.as_usize().ok_or_else(|| Error::format("spec.d"))?,
        chi_cap: j
            .req("chi_cap")?
            .as_usize()
            .ok_or_else(|| Error::format("spec.chi_cap"))?,
        asp: j.req("asp")?.as_f64().ok_or_else(|| Error::format("spec.asp"))?,
        decay_k: j
            .req("decay_k")?
            .as_f64()
            .ok_or_else(|| Error::format("spec.decay_k"))?,
        displacement_sigma: j
            .req("displacement_sigma")?
            .as_f64()
            .ok_or_else(|| Error::format("spec.displacement_sigma"))?,
        // Older stores predate the field; default to no skew.
        branch_skew: j.get("branch_skew").and_then(|v| v.as_f64()).unwrap_or(0.0),
        seed: j
            .req("seed")?
            .as_f64()
            .ok_or_else(|| Error::format("spec.seed"))? as u64,
        dynamic_chi: j
            .req("dynamic_chi")?
            .as_bool()
            .ok_or_else(|| Error::format("spec.dynamic_chi"))?,
        step_ratio_override: j.get("step_ratio_override").and_then(|v| v.as_f64()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> GbsSpec {
        GbsSpec {
            name: "store-test".into(),
            m: 6,
            d: 3,
            chi_cap: 8,
            asp: 3.0,
            decay_k: 0.0,
            displacement_sigma: 0.2,
            branch_skew: 0.0,
            seed: 99,
            dynamic_chi: true,
            step_ratio_override: None,
        }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("fastmps-store-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn roundtrip_f64_raw() {
        let dir = tmpdir("f64raw");
        let s = spec();
        let store = GammaStore::create(&dir, &s, StorePrecision::F64, StoreCodec::Raw).unwrap();
        let mem = s.generate().unwrap();
        let loaded = store.load_all().unwrap();
        for (a, b) in mem.sites.iter().zip(&loaded.sites) {
            assert_eq!(a.gamma.data, b.gamma.data); // f64 raw is lossless
        }
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn roundtrip_f16_lz_bounded_error() {
        let dir = tmpdir("f16lz");
        let s = spec();
        let store = GammaStore::create(&dir, &s, StorePrecision::F16, StoreCodec::Lz).unwrap();
        let mem = s.generate().unwrap();
        let loaded = store.load_all().unwrap();
        for (a, b) in mem.sites.iter().zip(&loaded.sites) {
            for (x, y) in a.gamma.data.iter().zip(&b.gamma.data) {
                // f16 relative error ≤ 2^-11 for normal values.
                let err = (*x - *y).abs();
                assert!(err <= x.abs() / 1024.0 + 1e-6, "{x} vs {y}");
            }
        }
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn reopen_reads_manifest() {
        let dir = tmpdir("reopen");
        let s = spec();
        let created =
            GammaStore::create(&dir, &s, StorePrecision::F32, StoreCodec::Lz).unwrap();
        let opened = GammaStore::open(&dir).unwrap();
        assert_eq!(opened.precision, StorePrecision::F32);
        assert_eq!(opened.codec, StoreCodec::Lz);
        assert_eq!(opened.bonds, created.bonds);
        assert_eq!(opened.spec.m, s.m);
        assert_eq!(opened.spec.seed, s.seed);
        let site = opened.load_site(2).unwrap();
        assert_eq!(site.chi_l(), created.bonds[2].0);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn f16_storage_halves_f32_bytes() {
        let dir16 = tmpdir("half16");
        let dir32 = tmpdir("half32");
        let s = spec();
        let s16 = GammaStore::create(&dir16, &s, StorePrecision::F16, StoreCodec::Raw).unwrap();
        let s32 = GammaStore::create(&dir32, &s, StorePrecision::F32, StoreCodec::Raw).unwrap();
        assert_eq!(s16.total_bytes() * 2, s32.total_bytes());
        fs::remove_dir_all(&dir16).unwrap();
        fs::remove_dir_all(&dir32).unwrap();
    }

    #[test]
    fn open_missing_fails_cleanly() {
        let err = GammaStore::open(Path::new("/nonexistent/fastmps")).unwrap_err();
        assert!(err.to_string().contains("manifest.json"));
    }

    #[test]
    fn out_of_range_site_rejected() {
        let dir = tmpdir("range");
        let store =
            GammaStore::create(&dir, &spec(), StorePrecision::F32, StoreCodec::Raw).unwrap();
        assert!(store.load_site(6).is_err());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_blob_detected() {
        let dir = tmpdir("corrupt");
        let store =
            GammaStore::create(&dir, &spec(), StorePrecision::F32, StoreCodec::Raw).unwrap();
        let p = dir.join("site_00001.bin");
        let mut blob = fs::read(&p).unwrap();
        blob.truncate(blob.len() - 4);
        fs::write(&p, &blob).unwrap();
        assert!(store.load_site(1).is_err());
        fs::remove_dir_all(&dir).unwrap();
    }
}
